//! LoRA fine-tuning proxy (paper Table 7 / Figure 4): a frozen
//! pseudo-pretrained transformer base with trainable rank-8 adapters,
//! fine-tuned with Adam vs SMMF — plus the exact LLaMA-7b LoRA memory
//! accounting from the full-scale inventory.
//!
//! ```bash
//! make artifacts && cargo run --release --example finetune_lora -- --steps 150
//! ```

use anyhow::Result;

use smmf_repro::coordinator::experiments::run_comparison;
use smmf_repro::coordinator::ExperimentConfig;
use smmf_repro::models::llama::llama7b_lora;
use smmf_repro::optim::{memory, OptKind, OptimConfig};
use smmf_repro::runtime::Runtime;
use smmf_repro::util::cli::Args;
use smmf_repro::util::fmt;

fn main() -> Result<()> {
    let args = Args::from_env();
    let rt = Runtime::open(args.str_or("artifacts", "artifacts"))?;

    // --- Trainable LoRA run on the small AOT artifact (Figure 4 proxy).
    let mut cfg = ExperimentConfig::default();
    cfg.artifact = "lora_tiny_grads".into();
    cfg.steps = args.u64_or("steps", 150);
    cfg.optim.lr = args.f64_or("lr", 1e-4) as f32; // LoRA-typical LR
    cfg.optim.decay_rate = -0.8;
    let summaries = run_comparison(&rt, &cfg, &[OptKind::Adam, OptKind::Smmf], "fig4")?;
    println!("\nAdam vs SMMF on LoRA adapters (loss curves in runs/fig4/):");
    for s in &summaries {
        println!(
            "  {:<5} final loss {:.4}  opt state {}",
            s.optimizer,
            s.final_loss,
            fmt::bytes(s.opt_state_bytes)
        );
    }

    // --- Full-scale LLaMA-7b LoRA memory accounting (paper Table 4/7).
    println!("\nLLaMA-7b + LoRA r=8 (paper Table 4/7 memory cells):");
    let inv = llama7b_lora(8);
    let shapes = inv.shapes();
    println!(
        "  trainable {} params, frozen base {}",
        fmt::count(inv.param_count()),
        fmt::bytes(inv.frozen_bytes)
    );
    for kind in OptKind::all() {
        let r = memory::report(kind, &shapes, &OptimConfig::paper_defaults(kind));
        println!(
            "  {:<10} opt {:>9}   e2e (incl frozen base) {:.1} GiB",
            kind.name(),
            fmt::bytes(r.opt_bytes),
            fmt::gib(r.e2e_bytes + inv.frozen_bytes)
        );
    }
    println!("  (paper: Adam 153 MiB / SMMF 3.9 MiB, e2e 24.9/24.8 GiB)");
    Ok(())
}
