//! End-to-end driver: train the char-level transformer LM on the real
//! embedded tiny corpus for a few hundred steps with SMMF, through the
//! AOT (JAX-lowered) fwd/bwd artifact, logging the loss curve — and run
//! an Adam reference for comparison. Results are recorded in
//! EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts && cargo run --release --example train_lm -- --steps 300
//! ```

use anyhow::Result;

use smmf_repro::coordinator::experiments::{run_comparison};
use smmf_repro::coordinator::ExperimentConfig;
use smmf_repro::optim::OptKind;
use smmf_repro::runtime::Runtime;
use smmf_repro::util::cli::Args;
use smmf_repro::util::fmt;

fn main() -> Result<()> {
    let args = Args::from_env();
    let rt = Runtime::open(args.str_or("artifacts", "artifacts"))?;

    let mut cfg = ExperimentConfig::default();
    cfg.artifact = args.str_or("artifact", "lm_e2e_grads");
    cfg.steps = args.u64_or("steps", 300);
    cfg.log_every = args.u64_or("log-every", 10);
    cfg.optim.lr = args.f64_or("lr", 1e-3) as f32;
    cfg.optim.decay_rate = -0.8; // transformer recipe (Appendix F)
    cfg.out_dir = args.str_or("out-dir", "runs");

    println!(
        "end-to-end: {} ({} params over {} tensors) on the embedded tiny corpus",
        cfg.artifact,
        {
            let g = smmf_repro::train::TrainGraph::load(&rt, &cfg.artifact)?;
            fmt::count(g.param_shapes().iter().map(|s| s.iter().product::<usize>() as u64).sum())
        },
        smmf_repro::train::TrainGraph::load(&rt, &cfg.artifact)?.n_params()
    );

    let kinds = [OptKind::Smmf, OptKind::Adam];
    let summaries = run_comparison(&rt, &cfg, &kinds, "train_lm")?;
    println!("\nfinal comparison:");
    for s in &summaries {
        println!(
            "  {:<6} loss {:.4} -> {:.4}  ppl {:.2}  opt state {}",
            s.optimizer,
            s.first_loss,
            s.final_loss,
            (s.final_loss as f64).exp(),
            fmt::bytes(s.opt_state_bytes)
        );
    }
    let smmf = &summaries[0];
    let adam = &summaries[1];
    println!(
        "\nSMMF matches Adam within {:.1}% final loss using {:.0}x less optimizer memory",
        100.0 * (smmf.final_loss - adam.final_loss).abs() / adam.final_loss,
        adam.opt_state_bytes as f64 / smmf.opt_state_bytes as f64
    );
    println!("loss curves: runs/train_lm/smmf/metrics.csv, runs/train_lm/adam/metrics.csv");
    Ok(())
}
