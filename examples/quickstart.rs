//! Quickstart: train a small MLP classifier with SMMF through the full
//! three-layer stack (Pallas-fused AOT train step executed from Rust),
//! then the framework path (HLO grads + Rust SMMF), and compare optimizer
//! memory against Adam.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! The Rust optimizers accept a `threads` knob (`OptimConfig::threads`,
//! CLI `--threads`, TOML `[optimizer] threads = N`) that dispatches
//! `step()` over the parallel work-sharding engine in `optim::parallel`;
//! `threads = 1` (the default here) is the serial reference path.

use anyhow::Result;

use smmf_repro::coordinator::experiments::BatchSource;
use smmf_repro::coordinator::ExperimentConfig;
use smmf_repro::optim::{memory, OptKind, OptimConfig};
use smmf_repro::runtime::Runtime;
use smmf_repro::train::FusedSmmfStep;
use smmf_repro::util::fmt;

fn main() -> Result<()> {
    let rt = Runtime::open("artifacts")?;

    // --- Path 1: the compiled whole-train-step (L1 Pallas SMMF kernel
    // fused into the XLA program; Rust only feeds batches).
    println!("=== compiled SMMF train step (Pallas kernel inside XLA) ===");
    let mut fused = FusedSmmfStep::load(&rt, "mlp_smmf_step", 0)?;
    let mut source = BatchSource::for_spec(fused.spec(), 1)?;
    let mut first = None;
    let mut last = 0.0;
    for step in 1..=60 {
        let loss = fused.train_step(&source.next()?)?;
        first.get_or_insert(loss);
        last = loss;
        if step % 15 == 0 {
            println!("  step {step:>3}: loss {loss:.4}");
        }
    }
    println!(
        "  loss {:.4} -> {last:.4}; persistent optimizer state {}\n",
        first.unwrap(),
        fmt::bytes(fused.state_bytes())
    );

    // --- Path 2: the framework path — HLO computes grads, the Rust SMMF
    // optimizer (bit-packed sign matrix) updates parameters.
    println!("=== framework path (HLO grads + Rust SMMF optimizer) ===");
    let mut cfg = ExperimentConfig::default();
    cfg.artifact = "mlp_grads".into();
    cfg.optimizer = OptKind::Smmf;
    cfg.steps = 60;
    cfg.name = "quickstart/smmf".into();
    let s = smmf_repro::coordinator::experiments::run_experiment(&rt, &cfg)?;
    println!("  loss {:.4} -> {:.4} in {} steps", s.first_loss, s.final_loss, s.steps);

    // --- Memory: SMMF vs the baselines on this model's shapes.
    let graph = smmf_repro::train::TrainGraph::load(&rt, "mlp_grads")?;
    let shapes = graph.param_shapes();
    println!("\n=== optimizer state on the MLP's parameter shapes ===");
    for kind in OptKind::all() {
        let b = memory::inventory_state_bytes(kind, &shapes, &OptimConfig::paper_defaults(kind));
        println!("  {:<10} {}", kind.name(), fmt::bytes(b));
    }
    Ok(())
}
