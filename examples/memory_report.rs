//! Regenerate the memory columns of every table in the paper from the
//! exact model-shape inventories, including the paper-vs-ours deltas.
//!
//! ```bash
//! cargo run --release --example memory_report
//! ```
//!
//! The `ckpt MiB` column is the on-disk size of the optimizer-state
//! section of a `SMMFCKPT` v2 checkpoint (native `StateSerde`
//! serialization, see docs/CHECKPOINT_FORMAT.md): because every
//! optimizer serializes its *native* compact state, the paper's memory
//! ratios carry over to disk within framing overhead.
//!
//! Memory accounting is thread-invariant: the parallel step engine
//! (`optim::parallel`, `OptimConfig::threads`) adds only transient
//! per-worker scratch, never persistent optimizer state, so every table
//! below is identical at any `threads` setting (asserted by
//! `rust/tests/parallel_step.rs`).

use anyhow::Result;

use smmf_repro::coordinator::experiments::{memory_rows, render_memory_table, table_models};
use smmf_repro::util::fmt;

/// Paper-reported optimizer memory (MiB) for the headline cells, used to
/// print side-by-side deltas. (Table 1 ImageNet / Table 2 / Table 3.)
const PAPER_CELLS: &[(&str, &str, f64)] = &[
    ("resnet50_imagenet", "adam", 195.0),
    ("resnet50_imagenet", "adafactor", 220.0),
    ("resnet50_imagenet", "sm3", 99.0),
    ("resnet50_imagenet", "came", 346.0),
    ("resnet50_imagenet", "smmf", 3.7),
    ("mobilenet_v2_imagenet", "adam", 27.0),
    ("mobilenet_v2_imagenet", "adafactor", 30.0),
    ("mobilenet_v2_imagenet", "sm3", 14.0),
    ("mobilenet_v2_imagenet", "came", 47.0),
    ("mobilenet_v2_imagenet", "smmf", 0.8),
    ("yolov5s", "adam", 57.0),
    ("yolov5s", "smmf", 1.4),
    ("transformer_base", "adam", 716.8),  // 0.7 GiB
    ("transformer_base", "smmf", 10.2),   // .01 GiB
    ("transformer_big", "adam", 2150.4),  // 2.1 GiB
    ("transformer_big", "smmf", 41.0),    // .04 GiB
    ("bert_345m", "adam", 2560.0),        // 2.5 GiB
    ("bert_345m", "smmf", 41.0),
    ("gpt2_124m", "adam", 957.0),
    ("gpt2_124m", "smmf", 16.0),
    ("t5_small", "adam", 464.0),
    ("t5_small", "smmf", 8.0),
    ("llama7b_lora_r8", "adam", 153.0),
    ("llama7b_lora_r8", "smmf", 3.9),
];

fn main() -> Result<()> {
    for table in [
        "table1", "table2", "table3", "table4", "table6", "table7", "table8", "table9",
        "table10", "table11", "table12", "table13",
    ] {
        let rows = memory_rows(&table_models(table)?)?;
        println!("{}", render_memory_table(table, &rows));
    }

    println!("== paper vs measured (optimizer memory, MiB) ==");
    let mut body = Vec::new();
    for (model, opt, paper) in PAPER_CELLS {
        let rows = memory_rows(&[model])?;
        let ours = rows
            .iter()
            .find(|r| r.optimizer == *opt)
            .map(|r| fmt::mib(r.opt_bytes))
            .unwrap_or(f64::NAN);
        body.push(vec![
            model.to_string(),
            opt.to_string(),
            format!("{paper:.1}"),
            format!("{ours:.1}"),
            format!("{:+.0}%", 100.0 * (ours - paper) / paper),
        ]);
    }
    println!(
        "{}",
        fmt::render_table(&["model", "optimizer", "paper MiB", "ours MiB", "delta"], &body)
    );

    // Headline: the paper's claimed up-to-96% reduction vs the best
    // memory-efficient baseline.
    let rows = memory_rows(&["resnet50_imagenet"])?;
    let get = |o: &str| rows.iter().find(|r| r.optimizer == o).unwrap().opt_bytes as f64;
    let best_baseline = get("sm3").min(get("adafactor")).min(get("came"));
    println!(
        "headline: SMMF vs best memory-efficient baseline on ResNet-50 = {:.1}% smaller (paper: up to 96%)",
        100.0 * (1.0 - get("smmf") / best_baseline)
    );
    let ck = |o: &str| rows.iter().find(|r| r.optimizer == o).unwrap().ckpt_bytes as f64;
    println!(
        "on-disk:  SMMF checkpoint optimizer-state section on ResNet-50 = {:.1}% of Adam's (acceptance: <= 10%)",
        100.0 * ck("smmf") / ck("adam")
    );

    // Per-group accounting: the paper-faithful grouped recipe (bias/norm
    // weight-decay exemption, dense Adam-style state for those tiny
    // tensors, embeddings at half LR) on Transformer-base — one row per
    // resolved group, so the cost of a per-group state policy is visible
    // before a run starts.
    use smmf_repro::models::inventory_by_name;
    use smmf_repro::optim::group::{GroupedConfig, ParamRole};
    use smmf_repro::optim::{memory, GroupPolicy, OptKind, OptimConfig, StatePolicy};
    println!("\n== per-group SMMF memory: transformer_base, grouped recipe ==");
    let inv = inventory_by_name("transformer_base").expect("known inventory");
    let mut gcfg =
        GroupedConfig::uniform(&OptimConfig::paper_defaults(OptKind::Smmf));
    gcfg.base.weight_decay = 0.01;
    gcfg.groups.push(GroupPolicy {
        name: "no_decay".into(),
        match_roles: vec![ParamRole::Bias, ParamRole::Norm],
        weight_decay: Some(0.0),
        state: StatePolicy::Dense,
        ..GroupPolicy::default()
    });
    gcfg.groups.push(GroupPolicy {
        name: "emb".into(),
        match_roles: vec![ParamRole::Embedding],
        lr_scale: 0.5,
        ..GroupPolicy::default()
    });
    let grows = memory::grouped_report(OptKind::Smmf, &inv.param_specs(), &gcfg);
    let body: Vec<Vec<String>> = grows
        .iter()
        .map(|r| {
            vec![
                r.group.clone(),
                r.tensors.to_string(),
                fmt::count(r.params),
                format!("{:.3}", fmt::mib(r.opt_bytes)),
                format!("{:.3}", fmt::mib(r.ckpt_opt_bytes)),
                r.state.name().to_string(),
                if r.frozen { "yes" } else { "no" }.to_string(),
            ]
        })
        .collect();
    println!(
        "{}",
        fmt::render_table(
            &["group", "tensors", "params", "opt MiB", "ckpt MiB", "state", "frozen"],
            &body
        )
    );
    Ok(())
}
