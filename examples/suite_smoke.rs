//! Run the smoke experiment suite end-to-end, pure Rust, and print the
//! generated paper-style report — the smallest demonstration of the
//! suite subsystem (`repro suite` is the CLI spelling).
//!
//! ```bash
//! cargo run --release --example suite_smoke
//! ```
//!
//! Everything here is artifact-free: the cells train the `synthetic:`
//! quadratic workload over the `tiny_lm` inventory, so this runs in
//! well under a second with no PJRT and no `make artifacts`. The suite
//! is executed twice into a temp directory to demonstrate resume-aware
//! re-entry: the second pass skips every cached cell and re-renders a
//! byte-identical report.

use anyhow::{bail, Result};

use smmf_repro::coordinator::report;
use smmf_repro::coordinator::suite::{run_suite, SuiteOptions};
use smmf_repro::coordinator::SuiteConfig;

const SUITE: &str = r#"
[suite]
name = "example"
seeds = [0, 1]

[optimizer]
lr = 0.05

[train]
steps = 20
log_every = 10

[[suite.run]]
optimizers = ["adam", "adafactor", "smmf"]
models = ["synthetic:tiny_lm"]
"#;

fn main() -> Result<()> {
    let mut cfg = SuiteConfig::parse(SUITE, "example")?;
    let tmp = std::env::temp_dir().join(format!("smmf_suite_example_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&tmp);
    cfg.out_dir = tmp.to_str().unwrap().to_string();

    let first = run_suite(&cfg, &SuiteOptions::default())?;
    let second = run_suite(&cfg, &SuiteOptions::default())?;
    let (_, skipped, failed) = second.counts();
    if failed > 0 || skipped != first.cells.len() {
        bail!("re-entry should skip every cached cell");
    }

    let cells = report::collect(&first.suite_dir)?;
    let (md, records) = report::generate(&cfg.name, &cells);
    println!("\n{md}");
    println!("({} machine-readable records would land in BENCH_suite.json)", records.len());
    let _ = std::fs::remove_dir_all(&tmp);
    Ok(())
}
