"""L1 correctness: the Pallas SMMF kernel vs the pure-jnp oracle.

This is the core correctness signal for the compiled optimizer. Hypothesis
sweeps shapes (including degenerate rows/cols and non-square aspect ratios)
and multi-step trajectories; explicit cases pin edge behaviour (zero
gradients, sign flips, normalization side).
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.smmf_update import _pick_block_rows, smmf_tensor_step


def run_kernel(st_in: ref.TensorState, g, beta_m, beta_v, eps=1e-8, block_rows=None):
    u, r_m, c_m, sign, r_v, c_v = smmf_tensor_step(
        g,
        st_in.r_m,
        st_in.c_m,
        st_in.sign,
        st_in.r_v,
        st_in.c_v,
        jnp.float32(beta_m),
        jnp.float32(beta_v),
        jnp.float32(eps),
        block_rows=block_rows,
    )
    return ref.TensorState(r_m, c_m, sign, r_v, c_v), u


def assert_state_close(a: ref.TensorState, b: ref.TensorState, atol=1e-5):
    np.testing.assert_allclose(a.r_m, b.r_m, atol=atol, rtol=1e-5)
    np.testing.assert_allclose(a.c_m, b.c_m, atol=atol, rtol=1e-5)
    np.testing.assert_allclose(a.r_v, b.r_v, atol=atol, rtol=1e-5)
    np.testing.assert_allclose(a.c_v, b.c_v, atol=atol, rtol=1e-5)
    # Sign may legitimately differ where M is (numerically) zero.
    disagree = np.asarray(a.sign) != np.asarray(b.sign)
    assert not disagree.any(), f"sign mismatch at {np.argwhere(disagree)[:5]}"


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(1, 48),
    m=st.integers(1, 48),
    seed=st.integers(0, 2**31 - 1),
    steps=st.integers(1, 4),
)
def test_kernel_matches_oracle_trajectory(n, m, seed, steps):
    key = jax.random.PRNGKey(seed)
    st_ref = ref.init_state((n, m))
    st_ker = ref.init_state((n, m))
    for t in range(1, steps + 1):
        key, sub = jax.random.split(key)
        g = jax.random.normal(sub, (n, m), jnp.float32)
        beta_m, beta_v = ref.betas(float(t), 0.9, 0.999, -0.5)
        st_ref, u_ref = ref.tensor_step(st_ref, g, beta_m, beta_v)
        st_ker, u_ker = run_kernel(st_ker, g, beta_m, beta_v)
        np.testing.assert_allclose(u_ker, u_ref, atol=1e-5, rtol=1e-5)
        assert_state_close(st_ker, st_ref)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(2, 64).filter(lambda x: x % 2 == 0),
    m=st.integers(1, 32),
    block=st.sampled_from([1, 2]),
    seed=st.integers(0, 1000),
)
def test_block_rows_invariance(n, m, block, seed):
    """The row-block tiling must not change the result."""
    key = jax.random.PRNGKey(seed)
    g = jax.random.normal(key, (n, m), jnp.float32)
    s0 = ref.init_state((n, m))
    _, u_full = run_kernel(s0, g, 0.9, 0.5, block_rows=n)
    _, u_blk = run_kernel(s0, g, 0.9, 0.5, block_rows=n // block)
    np.testing.assert_allclose(u_blk, u_full, atol=1e-6, rtol=1e-6)


def test_zero_gradient():
    """All-zero gradient: U must be exactly zero, state stays zero."""
    s0 = ref.init_state((8, 8))
    s1, u = run_kernel(s0, jnp.zeros((8, 8)), 0.9, 0.5)
    assert np.all(np.asarray(u) == 0.0)
    assert np.all(np.asarray(s1.r_m) == 0.0)
    assert np.all(np.asarray(s1.c_v) == 0.0)


def test_sign_restoration_negative_block():
    """A fully negative gradient must produce a fully negative update."""
    g = -jnp.ones((4, 4))
    s0 = ref.init_state((4, 4))
    s1, u = run_kernel(s0, g, 0.9, 0.5)
    assert np.all(np.asarray(u) < 0)
    assert not np.asarray(s1.sign).any()
    # Second step must decompress the stored negative momentum correctly.
    s2, u2 = run_kernel(s1, g, 0.9 * 0.999, 1.0 - 2.0**-0.5)
    s2_ref, u2_ref = ref.tensor_step(s1, g, 0.9 * 0.999, 1.0 - 2.0**-0.5)
    np.testing.assert_allclose(u2, u2_ref, atol=1e-6)


def test_normalization_side_wide():
    """n < m must normalize r (the shorter side)."""
    g = jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (3, 9))) + 0.1
    s1, _ = run_kernel(ref.init_state((3, 9)), g, 0.0, 0.0)
    np.testing.assert_allclose(np.asarray(s1.r_m).sum(), 1.0, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(s1.r_v).sum(), 1.0, rtol=1e-5)


def test_normalization_side_tall():
    """n >= m must normalize c."""
    g = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (9, 3))) + 0.1
    s1, _ = run_kernel(ref.init_state((9, 3)), g, 0.0, 0.0)
    np.testing.assert_allclose(np.asarray(s1.c_m).sum(), 1.0, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(s1.c_v).sum(), 1.0, rtol=1e-5)


@pytest.mark.parametrize("n,target,expect_div", [(7, 256, 7), (512, 256, 256), (1000, 256, 250), (997, 256, 1)])
def test_pick_block_rows(n, target, expect_div):
    bm = _pick_block_rows(n, target)
    assert n % bm == 0 and bm <= max(target, n)
    assert bm == expect_div


def test_rank1_consistency_after_compression():
    """After one step, decompress(compress(M)) row/col sums equal M's."""
    g = jax.random.normal(jax.random.PRNGKey(3), (16, 16))
    s1, _ = run_kernel(ref.init_state((16, 16)), g, 0.9, 0.5)
    m_rec = ref.decompress(s1.r_m, s1.c_m, s1.sign)
    # NNMF preserves total |mass|: sum of reconstruction == sum of |M|.
    m_exact = 0.1 * jnp.abs(g)  # (1-beta_m)=0.1 of |g| at step 1 (state was 0)
    np.testing.assert_allclose(
        np.abs(np.asarray(m_rec)).sum(), np.asarray(m_exact).sum(), rtol=1e-4
    )
