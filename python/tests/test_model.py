"""L2 model graph tests: shapes, gradients, and fused-step equivalence."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref
from compile.model import (
    CnnConfig,
    LmConfig,
    build_cnn,
    build_lm,
    build_lora_lm,
    build_mlp,
    smmf_fused_step,
    smmf_state_specs,
)

_DT = {"f32": np.float32, "i32": np.int32, "pred": bool}


def make_batch(graph, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for name, shape, dt in graph.batch:
        if dt == "i32":
            hi = graph.meta.get("vocab", graph.meta.get("classes", 10))
            out.append(rng.integers(0, hi, size=shape).astype(np.int32))
        else:
            out.append(rng.standard_normal(shape).astype(np.float32))
    return out


@pytest.mark.parametrize("builder", [build_mlp, lambda: build_lm(LmConfig()), lambda: build_cnn(CnnConfig())])
def test_grads_fn_shapes_and_finiteness(builder):
    graph = builder()
    params = graph.init_params(0)
    batch = make_batch(graph)
    out = jax.jit(graph.grads_fn())(*params, *batch)
    loss, grads = out[0], out[1:]
    assert np.isfinite(float(loss))
    assert len(grads) == len(graph.params)
    for g, spec in zip(grads, graph.params):
        assert g.shape == spec.shape, spec.name
        assert np.isfinite(np.asarray(g)).all(), spec.name


def test_lm_loss_decreases_under_smmf():
    """Ten SMMF steps on a fixed batch must reduce the LM loss."""
    graph = build_lm(LmConfig(d_model=32, n_layer=1, n_head=2, d_ff=64, seq_len=16, batch=4))
    params = [jnp.asarray(p) for p in graph.init_params(0)]
    batch = make_batch(graph)
    hyper = ref.SmmfHyper(lr=3e-3, decay_rate=-0.8)
    state = ref.smmf_init(params, hyper)
    fn = jax.jit(graph.grads_fn())
    losses = []
    for t in range(1, 11):
        out = fn(*params, *batch)
        losses.append(float(out[0]))
        params, state = ref.smmf_update(params, list(out[1:]), state, float(t), hyper)
    assert losses[-1] < losses[0] * 0.95, losses


def test_lora_only_adapters_trainable():
    cfg = LmConfig(d_model=32, n_layer=1, n_head=2, d_ff=64, seq_len=16, batch=2)
    graph = build_lora_lm(cfg, rank=4)
    # 2 adapters (A, B) per projection (wq, wv) per layer.
    assert len(graph.params) == cfg.n_layer * 2 * 2
    params = graph.init_params(0)
    batch_inputs = make_batch(graph)
    out = jax.jit(graph.grads_fn())(*params, *batch_inputs)
    assert len(out) == 1 + len(graph.params)
    # With B initialized to zero, grad wrt A flows through B=0 -> dA = 0,
    # but dB != 0 (standard LoRA property).
    names = [s.name for s in graph.params]
    for name, g in zip(names, out[1:]):
        if name.endswith("lora_b"):
            assert np.abs(np.asarray(g)).max() > 0, name


def test_fused_step_matches_reference_update():
    """The Pallas-fused whole-train-step == grads + oracle optimizer."""
    graph = build_mlp(in_dim=8, hidden=12, classes=4, batch=8)
    hyper_kw = dict(lr=1e-2, beta1=0.9, eps=1e-8, growth_rate=0.999, decay_rate=-0.8, weight_decay=0.0)
    fused, state_specs = smmf_fused_step(graph, **hyper_kw, use_pallas=True)

    params = [jnp.asarray(p) for p in graph.init_params(0)]
    batch = make_batch(graph)
    state_flat = [jnp.zeros(sh, _DT[dt]) for (_, sh, dt) in state_specs]

    # Reference path.
    hyper = ref.SmmfHyper(lr=1e-2, decay_rate=-0.8, weight_decay=0.0)
    ref_params = list(params)
    ref_state = ref.smmf_init(ref_params, hyper)
    fn = jax.jit(graph.grads_fn())
    fused_j = jax.jit(fused)

    cur_params, cur_state = list(params), list(state_flat)
    for t in range(1, 4):
        out = fused_j(jnp.float32(t), *cur_params, *cur_state, *batch)
        loss = out[0]
        cur_params = list(out[1 : 1 + len(params)])
        cur_state = list(out[1 + len(params) :])

        ref_out = fn(*ref_params, *batch)
        ref_params, ref_state = ref.smmf_update(
            ref_params, list(ref_out[1:]), ref_state, float(t), hyper
        )
        np.testing.assert_allclose(float(loss), float(ref_out[0]), rtol=1e-5)
        for a, b, spec in zip(cur_params, ref_params, graph.params):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5, err_msg=spec.name)


def test_state_specs_cover_every_param():
    graph = build_lm(LmConfig(d_model=32, n_layer=1, n_head=2, d_ff=64, seq_len=16, batch=2))
    specs = smmf_state_specs(graph)
    assert len(specs) == 5 * len(graph.params)
    for i, p in enumerate(graph.params):
        n, m = ref.effective_shape(int(np.prod(p.shape)))
        names = [specs[5 * i + k][0] for k in range(5)]
        assert names == [f"{p.name}.r_m", f"{p.name}.c_m", f"{p.name}.sign", f"{p.name}.r_v", f"{p.name}.c_v"]
        assert specs[5 * i][1] == (n,)
        assert specs[5 * i + 2][1] == (n, m)


def test_lm_param_count_formula():
    cfg = LmConfig()
    graph = build_lm(cfg)
    total = sum(int(np.prod(s.shape)) for s in graph.params)
    assert total == cfg.param_count()
