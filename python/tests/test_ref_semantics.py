"""Pin ref.py to the paper's Appendix M PyTorch code.

``PaperSmmf`` below is an independent numpy transliteration of the paper's
published optimizer (state dict per tensor, in-place order of operations,
weight-decay modes, the `_get_effective_shape` scan). ref.py must agree
with it bit-for-bit-ish over multi-step trajectories on random tensors of
every rank 0..4.
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import math

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref


class PaperSmmf:
    """Numpy transliteration of the paper's Appendix M torch code."""

    def __init__(self, lr=1e-3, beta=0.9, eps=1e-8, weight_decay=0.0,
                 decay_rate=-0.5, growth_rate=0.999, vector_reshape=True,
                 weight_decay_mode="adamw"):
        self.lr, self.beta, self.eps = lr, beta, eps
        self.weight_decay, self.decay_rate, self.growth_rate = weight_decay, decay_rate, growth_rate
        self.vector_reshape = vector_reshape
        self.weight_decay_mode = weight_decay_mode
        self.state = {}

    @staticmethod
    def _get_effective_shape(numel):
        sqrt_num = int(numel**0.5) ** 2
        if numel == sqrt_num:
            s = int(numel**0.5)
            return (s, s)
        for i in reversed(range(1, int(numel**0.5) + 1)):
            if numel % i == 0:
                return (numel // i, i)
        return (numel, 1)

    @staticmethod
    def _unnmf(row_col):
        return np.outer(row_col[0], row_col[1])

    @staticmethod
    def _nnmf(matrix):
        shape = matrix.shape
        r = matrix.sum(axis=1)
        c = matrix.sum(axis=0)
        if shape[0] < shape[1]:
            scale = r.sum()
            if scale != 0:
                r = r / scale
        else:
            scale = c.sum()
            if scale != 0:
                c = c / scale
        return r, c

    def step_param(self, pid, param, grad):
        param, grad = param.copy(), grad.copy()
        if self.weight_decay != 0.0 and self.weight_decay_mode == "adam":
            grad = grad + self.weight_decay * param
        elif self.weight_decay != 0.0 and self.weight_decay_mode == "adamw":
            param = param * (1 - self.lr * self.weight_decay)

        dimension = len(np.squeeze(grad).shape)
        factorization = not (dimension == 1 and (not self.vector_reshape))
        st = self.state.setdefault(pid, {})
        if factorization:
            if not st:
                st["step"] = 1
                st["effective_shape"] = self._get_effective_shape(param.size)
                n, m = st["effective_shape"]
                st["momentum_m"] = (np.zeros(n, np.float32), np.zeros(m, np.float32))
                st["sign"] = np.zeros((n, m), bool)
                st["momentum_v"] = (np.zeros(n, np.float32), np.zeros(m, np.float32))
            g = grad.reshape(st["effective_shape"])
            update_m = self._unnmf(st["momentum_m"])
            update_m = np.where(st["sign"], update_m, -update_m)
            update_v = self._unnmf(st["momentum_v"])
            beta_m = self.beta * self.growth_rate ** (st["step"] - 1.0)
            update_m = update_m * beta_m + g * (1.0 - beta_m)
            beta_v = 1.0 - st["step"] ** self.decay_rate
            update_v = update_v * beta_v + g * g * (1.0 - beta_v)
            st["sign"] = update_m > 0
            st["momentum_m"] = self._nnmf(np.abs(update_m))
            st["momentum_v"] = self._nnmf(update_v)
            update = update_m / (np.sqrt(update_v) + self.eps)
            update = update.reshape(param.shape)
            st["step"] += 1
        else:
            if not st:
                st["step"] = 1
                st["momentum_m"] = np.zeros_like(param)
                st["momentum_v"] = np.zeros_like(param)
            beta_m = self.beta * self.growth_rate ** (st["step"] - 1.0)
            st["momentum_m"] = st["momentum_m"] * beta_m + grad * (1.0 - beta_m)
            beta_v = 1.0 - st["step"] ** self.decay_rate
            st["momentum_v"] = st["momentum_v"] * beta_v + grad * grad * (1.0 - beta_v)
            update = st["momentum_m"] / (np.sqrt(st["momentum_v"]) + self.eps)
            st["step"] += 1
        return param - self.lr * update


SHAPES = [(5,), (12,), (4, 6), (3, 3, 4), (2, 3, 2, 5), (17,), (1,)]


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), steps=st.integers(1, 5))
def test_ref_matches_paper_code(seed, steps):
    rng = np.random.default_rng(seed)
    params = [rng.standard_normal(s).astype(np.float32) for s in SHAPES]
    hyper = ref.SmmfHyper(weight_decay=0.01, weight_decay_mode="adamw", decay_rate=-0.5)
    paper = PaperSmmf(weight_decay=0.01, weight_decay_mode="adamw", decay_rate=-0.5)

    jp = [jnp.asarray(p) for p in params]
    state = ref.smmf_init(jp, hyper)
    npp = [p.copy() for p in params]
    for t in range(1, steps + 1):
        grads = [rng.standard_normal(s).astype(np.float32) for s in SHAPES]
        jp, state = ref.smmf_update(jp, [jnp.asarray(g) for g in grads], state, float(t), hyper)
        npp = [paper.step_param(i, p, g) for i, (p, g) in enumerate(zip(npp, grads))]
        for a, b in zip(jp, npp):
            np.testing.assert_allclose(np.asarray(a), b, atol=2e-5, rtol=2e-4)


def test_adam_mode_weight_decay():
    rng = np.random.default_rng(0)
    p = rng.standard_normal((6, 4)).astype(np.float32)
    g = rng.standard_normal((6, 4)).astype(np.float32)
    hyper = ref.SmmfHyper(weight_decay=0.05, weight_decay_mode="adam")
    paper = PaperSmmf(weight_decay=0.05, weight_decay_mode="adam")
    jp, state = [jnp.asarray(p)], ref.smmf_init([jnp.asarray(p)], hyper)
    jp, state = ref.smmf_update(jp, [jnp.asarray(g)], state, 1.0, hyper)
    out = paper.step_param(0, p, g)
    np.testing.assert_allclose(np.asarray(jp[0]), out, atol=1e-6)


@settings(max_examples=200, deadline=None)
@given(numel=st.integers(1, 200_000))
def test_effective_shape_properties(numel):
    n, m = ref.effective_shape(numel)
    assert n * m == numel
    assert n >= m >= 1
    # m is the largest divisor <= floor(sqrt(numel)) -> optimal |n - m|.
    s = math.isqrt(numel)
    for i in range(s, m, -1):
        assert numel % i != 0
    assert (n, m) == PaperSmmf._get_effective_shape(numel)


@pytest.mark.parametrize(
    "numel,expect",
    [
        (1, (1, 1)),
        (12, (4, 3)),
        (16, (4, 4)),
        (17, (17, 1)),  # prime
        (30522 * 768, (5087, 4608)),  # BERT embedding — paper §5.2's example
    ],
)
def test_effective_shape_examples(numel, expect):
    assert ref.effective_shape(numel) == expect


def test_memory_reduction_bert_embedding():
    """Paper claim: square-matricization saves ~69% vs last-two-dims
    factorization on BERT's (30522, 768) embedding."""
    n, m = ref.effective_shape(30522 * 768)
    smmf_floats = 2 * (n + m)  # r,c for both moments
    adafactor_floats = 30522 + 768 + 30522 * 768 // (30522 * 768) * 0  # V factored
    # Compare factored-vector footprints only (excl. sign matrix):
    assert smmf_floats < 0.7 * 2 * (30522 + 768) + 1  # ~69% saving on vectors
