"""AOT pipeline tests: manifest integrity and HLO text round-trip."""

import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import numpy as np
import pytest

ART = Path(__file__).resolve().parents[2] / "artifacts"


def load_manifest():
    p = ART / "manifest.json"
    if not p.exists():
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(p) as f:
        return json.load(f)


def test_manifest_files_exist():
    man = load_manifest()
    assert man["artifacts"], "empty manifest"
    for name, art in man["artifacts"].items():
        f = ART / art["file"]
        assert f.exists(), f"{name}: missing {art['file']}"
        head = f.read_text()[:200]
        assert "HloModule" in head, f"{name}: not HLO text"


def test_manifest_io_shapes_well_formed():
    man = load_manifest()
    for name, art in man["artifacts"].items():
        assert art["inputs"] and art["outputs"], name
        for io in art["inputs"] + art["outputs"]:
            assert io["dtype"] in ("f32", "i32", "pred"), (name, io)
            assert all(isinstance(d, int) and d > 0 for d in io["shape"]), (name, io)
        if art["kind"] == "grads":
            # loss + one grad per param
            assert len(art["outputs"]) == 1 + len(art["params"]), name
        if art["kind"] == "smmf_step":
            n_p, n_s = len(art["params"]), len(art["state"])
            assert n_s == 5 * n_p, name
            assert len(art["outputs"]) == 1 + n_p + n_s, name
            assert len(art["inputs"]) == 1 + n_p + n_s + (
                len(art["inputs"]) - 1 - n_p - n_s
            ), name


def test_hlo_entry_parameter_count_matches_manifest():
    """The lowered HLO ENTRY must take exactly the manifest's inputs."""
    man = load_manifest()
    for name, art in man["artifacts"].items():
        text = (ART / art["file"]).read_text()
        idx = text.find("ENTRY")
        assert idx >= 0, name
        # Count parameter(k) declarations inside the ENTRY computation only
        # (nested fusions/reductions declare their own parameters).
        entry_body = text[idx:]
        n_params = entry_body.count(" = parameter(") or entry_body.count("parameter(")
        assert n_params == len(art["inputs"]), (name, n_params, len(art["inputs"]))


def test_lowering_smoke_small_graph():
    """Fresh lowering of a tiny graph must produce loadable HLO text."""
    from compile.aot import lower_grads, to_hlo_text
    from compile.model import build_mlp

    graph = build_mlp(in_dim=4, hidden=6, classes=3, batch=5)
    lowered, ins, outs = lower_grads(graph)
    text = to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert len(ins) == len(graph.params) + len(graph.batch)
    assert len(outs) == 1 + len(graph.params)
