"""AOT driver: lower every L2 graph to HLO text + manifest for the Rust runtime.

Run once at build time (``make artifacts``); Python never runs again.

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version behind the published ``xla`` crate) rejects
(``proto.id() <= INT_MAX``). The text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Artifacts built (``--only`` to restrict):

* ``<model>_grads``      — (params..., batch...) -> (loss, grads...)
* ``<model>_smmf_step``  — (step, params..., state..., batch...) ->
                           (loss, params'..., state'...), the SMMF update
                           fused through the L1 Pallas kernel.
* ``smmf_tensor_NxM``    — the bare Pallas per-tensor update, for runtime
                           microbenches against the native Rust hot path.

``artifacts/manifest.json`` records, per artifact: file, ordered inputs and
outputs (name/shape/dtype), parameter init specs, and model metadata.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .kernels import ref
from .kernels.smmf_update import smmf_tensor_step
from .model import (
    CnnConfig,
    LmConfig,
    ModelGraph,
    build_cnn,
    build_lm,
    build_lora_lm,
    build_mlp,
    smmf_fused_step,
    smmf_state_specs,
)

_DTYPES = {"f32": jnp.float32, "i32": jnp.int32, "pred": jnp.bool_}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype="f32"):
    return jax.ShapeDtypeStruct(tuple(shape), _DTYPES[dtype])


def _io(name, shape, dtype):
    return {"name": name, "shape": [int(s) for s in shape], "dtype": dtype}


def lower_grads(graph: ModelGraph):
    """Lower the (params, batch) -> (loss, grads) graph of a model."""
    args = [_spec(s.shape) for s in graph.params]
    args += [_spec(shape, dt) for (_, shape, dt) in graph.batch]
    lowered = jax.jit(graph.grads_fn()).lower(*args)
    inputs = [_io(s.name, s.shape, "f32") for s in graph.params]
    inputs += [_io(n, sh, dt) for (n, sh, dt) in graph.batch]
    outputs = [_io("loss", (), "f32")]
    outputs += [_io(f"grad.{s.name}", s.shape, "f32") for s in graph.params]
    return lowered, inputs, outputs


def lower_smmf_step(graph: ModelGraph, **hyper):
    fn, state_specs = smmf_fused_step(graph, **hyper)
    args = [_spec((), "f32")]  # step
    args += [_spec(s.shape) for s in graph.params]
    args += [_spec(shape, dt) for (_, shape, dt) in state_specs]
    args += [_spec(shape, dt) for (_, shape, dt) in graph.batch]
    lowered = jax.jit(fn).lower(*args)
    inputs = [_io("step", (), "f32")]
    inputs += [_io(s.name, s.shape, "f32") for s in graph.params]
    inputs += [_io(n, sh, dt) for (n, sh, dt) in state_specs]
    inputs += [_io(n, sh, dt) for (n, sh, dt) in graph.batch]
    outputs = [_io("loss", (), "f32")]
    outputs += [_io(f"new.{s.name}", s.shape, "f32") for s in graph.params]
    outputs += [_io(f"new.{n}", sh, dt) for (n, sh, dt) in state_specs]
    return lowered, inputs, outputs


def lower_smmf_tensor(n: int, m: int):
    """Bare Pallas per-tensor SMMF update for an (n, m) matricized tensor."""

    def fn(g, r_m, c_m, sign, r_v, c_v, beta_m, beta_v, eps):
        return smmf_tensor_step(g, r_m, c_m, sign, r_v, c_v, beta_m, beta_v, eps)

    args = [
        _spec((n, m)),
        _spec((n,)),
        _spec((m,)),
        _spec((n, m), "pred"),
        _spec((n,)),
        _spec((m,)),
        _spec(()),
        _spec(()),
        _spec(()),
    ]
    lowered = jax.jit(fn).lower(*args)
    inputs = [
        _io("g_bar", (n, m), "f32"),
        _io("r_m", (n,), "f32"),
        _io("c_m", (m,), "f32"),
        _io("sign", (n, m), "pred"),
        _io("r_v", (n,), "f32"),
        _io("c_v", (m,), "f32"),
        _io("beta_m", (), "f32"),
        _io("beta_v", (), "f32"),
        _io("eps", (), "f32"),
    ]
    outputs = [
        _io("u", (n, m), "f32"),
        _io("new.r_m", (n,), "f32"),
        _io("new.c_m", (m,), "f32"),
        _io("new.sign", (n, m), "pred"),
        _io("new.r_v", (n,), "f32"),
        _io("new.c_v", (m,), "f32"),
    ]
    return lowered, inputs, outputs


def _param_manifest(graph: ModelGraph):
    return [
        {
            "name": s.name,
            "shape": [int(x) for x in s.shape],
            "init": s.init,
            "scale": float(s.scale),
        }
        for s in graph.params
    ]


LM_E2E = LmConfig(vocab=96, d_model=256, n_head=8, n_layer=4, d_ff=1024, seq_len=128, batch=16)
LM_TINY = LmConfig()
LORA_CFG = LmConfig(vocab=96, d_model=128, n_head=4, n_layer=2, d_ff=512, seq_len=64, batch=8)


def build_all(only: list[str] | None = None):
    """Yield (name, lower-thunk) pairs; thunk returns (lowered, in, out, extra)."""

    def g(name, graph_fn, smmf_hyper=None):
        def thunk():
            graph = graph_fn()
            extra = {"kind": "grads", "model": graph.name, "params": _param_manifest(graph), "meta": graph.meta}
            if smmf_hyper is None:
                lowered, ins, outs = lower_grads(graph)
            else:
                lowered, ins, outs = lower_smmf_step(graph, **smmf_hyper)
                extra["kind"] = "smmf_step"
                extra["hyper"] = smmf_hyper
                extra["state"] = [
                    _io(n, sh, dt) for (n, sh, dt) in smmf_state_specs(graph)
                ]
            return lowered, ins, outs, extra

        return name, thunk

    hyper = dict(lr=1e-3, beta1=0.9, eps=1e-8, growth_rate=0.999, decay_rate=-0.8, weight_decay=0.0)
    items = [
        g("mlp_grads", build_mlp),
        g("cnn_grads", build_cnn),
        g("lm_tiny_grads", lambda: build_lm(LM_TINY)),
        g("lm_e2e_grads", lambda: build_lm(LM_E2E)),
        g("lora_tiny_grads", lambda: build_lora_lm(LORA_CFG, rank=8)),
        g("mlp_smmf_step", build_mlp, smmf_hyper=hyper),
        g("lm_tiny_smmf_step", lambda: build_lm(LM_TINY), smmf_hyper=hyper),
    ]

    def tensor_thunk(n, m):
        def thunk():
            lowered, ins, outs = lower_smmf_tensor(n, m)
            return lowered, ins, outs, {"kind": "smmf_tensor", "meta": {"n": n, "m": m}}

        return thunk

    items.append((f"smmf_tensor_1024x1024", tensor_thunk(1024, 1024)))

    if only:
        items = [(n, t) for (n, t) in items if n in only]
    return items


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", nargs="*", default=None)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest_path = os.path.join(args.out_dir, "manifest.json")
    manifest = {"artifacts": {}}
    if os.path.exists(manifest_path) and args.only:
        with open(manifest_path) as f:
            manifest = json.load(f)

    for name, thunk in build_all(args.only):
        t0 = time.time()
        lowered, inputs, outputs, extra = thunk()
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": fname,
            "inputs": inputs,
            "outputs": outputs,
            **extra,
        }
        print(f"[aot] {name}: {len(text)/1e6:.1f} MB HLO text in {time.time()-t0:.1f}s")

    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {manifest_path} ({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    main()
