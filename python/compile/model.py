"""L2 — JAX model graphs (build-time only).

Three trainable models, each exposed as a ``(params, batch) -> (loss,
*grads)`` graph that ``aot.py`` lowers to HLO text for the Rust runtime:

* ``mlp``      — 2-layer classifier over 32-d features (quickstart model).
* ``lm``       — char-level pre-norm transformer LM (the end-to-end driver
                 model; size set by ``LmConfig``). Stands in for the paper's
                 Transformer-base/WMT32k full-training workload.
* ``cnn``      — 3-conv + dense classifier over 32×32×3 images. Stands in
                 for the paper's MobileNetV2/ResNet-50 CIFAR workload.
* ``lora_lm``  — the ``lm`` with a frozen base and trainable rank-r LoRA
                 adapters on the attention projections (Table 7 / Figure 4
                 proxy). Only adapter grads are emitted.

Parameters are *ordered flat lists* of named tensors — the manifest records
the order so the Rust side can address buffers positionally. Additionally
``smmf_fused_step`` builds a whole-train-step graph (fwd + bwd + SMMF update
through the Pallas kernel) whose persistent state is exactly the factorized
vectors + sign matrices: the paper's optimizer compiled into one XLA
program.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref
from .kernels.smmf_update import smmf_tensor_step


# ---------------------------------------------------------------------------
# Parameter registry
# ---------------------------------------------------------------------------


@dataclass
class ParamSpec:
    name: str
    shape: tuple[int, ...]
    init: str = "normal"  # normal | zeros | ones
    scale: float = 0.02


@dataclass
class ModelGraph:
    """A model as the Rust runtime sees it: ordered params + a loss fn."""

    name: str
    params: list[ParamSpec]
    # loss_fn(list_of_param_arrays, batch_dict) -> scalar loss
    loss_fn: Callable
    # batch inputs, ordered: (name, shape, dtype)
    batch: list[tuple[str, tuple[int, ...], str]]
    meta: dict = field(default_factory=dict)

    def init_params(self, seed: int = 0) -> list[np.ndarray]:
        rng = np.random.default_rng(seed)
        out = []
        for spec in self.params:
            if spec.init == "zeros":
                out.append(np.zeros(spec.shape, np.float32))
            elif spec.init == "ones":
                out.append(np.ones(spec.shape, np.float32))
            else:
                out.append(
                    rng.standard_normal(spec.shape, np.float32) * np.float32(spec.scale)
                )
        return out

    def grads_fn(self):
        """(params..., batch...) -> (loss, grads...) as a flat-signature fn."""
        n_params = len(self.params)
        batch_names = [b[0] for b in self.batch]

        def fn(*args):
            params = list(args[:n_params])
            batch = dict(zip(batch_names, args[n_params:]))
            loss, grads = jax.value_and_grad(self.loss_fn)(params, batch)
            return (loss, *grads)

        return fn


# ---------------------------------------------------------------------------
# MLP classifier
# ---------------------------------------------------------------------------


def build_mlp(in_dim: int = 32, hidden: int = 64, classes: int = 10, batch: int = 64) -> ModelGraph:
    specs = [
        ParamSpec("w1", (in_dim, hidden), scale=1.0 / math.sqrt(in_dim)),
        ParamSpec("b1", (hidden,), init="zeros"),
        ParamSpec("w2", (hidden, classes), scale=1.0 / math.sqrt(hidden)),
        ParamSpec("b2", (classes,), init="zeros"),
    ]

    def loss_fn(params, b):
        w1, b1, w2, b2 = params
        h = jnp.tanh(b["x"] @ w1 + b1)
        logits = h @ w2 + b2
        logp = jax.nn.log_softmax(logits)
        onehot = jax.nn.one_hot(b["y"], classes)
        return -(onehot * logp).sum(axis=-1).mean()

    return ModelGraph(
        name="mlp",
        params=specs,
        loss_fn=loss_fn,
        batch=[("x", (batch, in_dim), "f32"), ("y", (batch,), "i32")],
        meta={"classes": classes, "in_dim": in_dim, "hidden": hidden, "batch": batch},
    )


# ---------------------------------------------------------------------------
# Char-level transformer LM
# ---------------------------------------------------------------------------


@dataclass
class LmConfig:
    vocab: int = 96
    d_model: int = 128
    n_head: int = 4
    n_layer: int = 2
    d_ff: int = 512
    seq_len: int = 64
    batch: int = 16

    def param_count(self) -> int:
        per_layer = 4 * self.d_model * self.d_model + 2 * self.d_model * self.d_ff
        return (
            self.vocab * self.d_model * 2
            + self.seq_len * self.d_model
            + self.n_layer * (per_layer + 4 * self.d_model + self.d_model + self.d_ff)
            + 2 * self.d_model
        )


def lm_param_specs(cfg: LmConfig) -> list[ParamSpec]:
    s = 0.02
    specs = [
        ParamSpec("tok_emb", (cfg.vocab, cfg.d_model), scale=s),
        ParamSpec("pos_emb", (cfg.seq_len, cfg.d_model), scale=s),
    ]
    for i in range(cfg.n_layer):
        p = f"l{i}."
        specs += [
            ParamSpec(p + "ln1_g", (cfg.d_model,), init="ones"),
            ParamSpec(p + "ln1_b", (cfg.d_model,), init="zeros"),
            ParamSpec(p + "wq", (cfg.d_model, cfg.d_model), scale=s),
            ParamSpec(p + "wk", (cfg.d_model, cfg.d_model), scale=s),
            ParamSpec(p + "wv", (cfg.d_model, cfg.d_model), scale=s),
            ParamSpec(p + "wo", (cfg.d_model, cfg.d_model), scale=s / math.sqrt(2 * cfg.n_layer)),
            ParamSpec(p + "ln2_g", (cfg.d_model,), init="ones"),
            ParamSpec(p + "ln2_b", (cfg.d_model,), init="zeros"),
            ParamSpec(p + "w_ff1", (cfg.d_model, cfg.d_ff), scale=s),
            ParamSpec(p + "b_ff1", (cfg.d_ff,), init="zeros"),
            ParamSpec(p + "w_ff2", (cfg.d_ff, cfg.d_model), scale=s / math.sqrt(2 * cfg.n_layer)),
            ParamSpec(p + "b_ff2", (cfg.d_model,), init="zeros"),
        ]
    specs += [
        ParamSpec("lnf_g", (cfg.d_model,), init="ones"),
        ParamSpec("lnf_b", (cfg.d_model,), init="zeros"),
        ParamSpec("head", (cfg.d_model, cfg.vocab), scale=s),
    ]
    return specs


def _layernorm(x, g, b, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _attention(x, wq, wk, wv, wo, n_head):
    b, t, d = x.shape
    hd = d // n_head
    q = (x @ wq).reshape(b, t, n_head, hd).transpose(0, 2, 1, 3)
    k = (x @ wk).reshape(b, t, n_head, hd).transpose(0, 2, 1, 3)
    v = (x @ wv).reshape(b, t, n_head, hd).transpose(0, 2, 1, 3)
    att = (q @ k.transpose(0, 1, 3, 2)) / math.sqrt(hd)
    mask = jnp.tril(jnp.ones((t, t), bool))
    att = jnp.where(mask, att, -1e9)
    att = jax.nn.softmax(att, axis=-1)
    y = (att @ v).transpose(0, 2, 1, 3).reshape(b, t, d)
    return y @ wo


def _lm_logits(params_by_name, tokens, cfg: LmConfig):
    p = params_by_name
    b, t = tokens.shape
    x = p["tok_emb"][tokens] + p["pos_emb"][:t]
    for i in range(cfg.n_layer):
        pre = f"l{i}."
        h = _layernorm(x, p[pre + "ln1_g"], p[pre + "ln1_b"])
        x = x + _attention(h, p[pre + "wq"], p[pre + "wk"], p[pre + "wv"], p[pre + "wo"], cfg.n_head)
        h = _layernorm(x, p[pre + "ln2_g"], p[pre + "ln2_b"])
        h = jax.nn.gelu(h @ p[pre + "w_ff1"] + p[pre + "b_ff1"])
        x = x + h @ p[pre + "w_ff2"] + p[pre + "b_ff2"]
    x = _layernorm(x, p["lnf_g"], p["lnf_b"])
    return x @ p["head"]


def build_lm(cfg: LmConfig = LmConfig()) -> ModelGraph:
    specs = lm_param_specs(cfg)
    names = [s.name for s in specs]

    def loss_fn(params, b):
        by_name = dict(zip(names, params))
        logits = _lm_logits(by_name, b["tokens"], cfg)
        logp = jax.nn.log_softmax(logits)
        tgt = jax.nn.one_hot(b["targets"], cfg.vocab)
        return -(tgt * logp).sum(-1).mean()

    return ModelGraph(
        name="lm",
        params=specs,
        loss_fn=loss_fn,
        batch=[
            ("tokens", (cfg.batch, cfg.seq_len), "i32"),
            ("targets", (cfg.batch, cfg.seq_len), "i32"),
        ],
        meta={
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_head": cfg.n_head,
            "n_layer": cfg.n_layer,
            "d_ff": cfg.d_ff,
            "seq_len": cfg.seq_len,
            "batch": cfg.batch,
            "param_count": int(sum(int(np.prod(s.shape)) for s in specs)),
        },
    )


# ---------------------------------------------------------------------------
# Small CNN (CIFAR-shaped stand-in)
# ---------------------------------------------------------------------------


@dataclass
class CnnConfig:
    channels: tuple[int, ...] = (16, 32, 64)
    classes: int = 10
    batch: int = 32
    image: int = 32


def build_cnn(cfg: CnnConfig = CnnConfig()) -> ModelGraph:
    specs = []
    cin = 3
    for i, cout in enumerate(cfg.channels):
        specs.append(ParamSpec(f"conv{i}_w", (cout, cin, 3, 3), scale=1.0 / math.sqrt(cin * 9)))
        specs.append(ParamSpec(f"conv{i}_b", (cout,), init="zeros"))
        cin = cout
    final_hw = cfg.image // (2 ** len(cfg.channels))
    flat = cfg.channels[-1] * final_hw * final_hw
    specs.append(ParamSpec("fc_w", (flat, cfg.classes), scale=1.0 / math.sqrt(flat)))
    specs.append(ParamSpec("fc_b", (cfg.classes,), init="zeros"))
    names = [s.name for s in specs]

    def loss_fn(params, b):
        p = dict(zip(names, params))
        x = b["x"]  # (B, 3, H, W)
        for i in range(len(cfg.channels)):
            x = jax.lax.conv_general_dilated(
                x, p[f"conv{i}_w"], window_strides=(1, 1), padding="SAME"
            ) + p[f"conv{i}_b"][None, :, None, None]
            x = jax.nn.relu(x)
            x = jax.lax.reduce_window(
                x, -jnp.inf, jax.lax.max, (1, 1, 2, 2), (1, 1, 2, 2), "VALID"
            )
        x = x.reshape(x.shape[0], -1)
        logits = x @ p["fc_w"] + p["fc_b"]
        logp = jax.nn.log_softmax(logits)
        onehot = jax.nn.one_hot(b["y"], cfg.classes)
        return -(onehot * logp).sum(-1).mean()

    return ModelGraph(
        name="cnn",
        params=specs,
        loss_fn=loss_fn,
        batch=[
            ("x", (cfg.batch, 3, cfg.image, cfg.image), "f32"),
            ("y", (cfg.batch,), "i32"),
        ],
        meta={"classes": cfg.classes, "batch": cfg.batch, "image": cfg.image},
    )


# ---------------------------------------------------------------------------
# LoRA LM: frozen base + trainable adapters (Table 7 / Figure 4 proxy)
# ---------------------------------------------------------------------------


def build_lora_lm(cfg: LmConfig = LmConfig(), rank: int = 8) -> ModelGraph:
    """The LM with LoRA adapters on wq/wv of every layer.

    The frozen base weights become *batch-like constants* (extra inputs) so
    the artifact can be fed any pre-trained base; trainable params are only
    the A/B adapter matrices, matching the paper's LLaMA-7b LoRA setup.
    """
    base_specs = lm_param_specs(cfg)
    base_names = [s.name for s in base_specs]
    specs = []
    for i in range(cfg.n_layer):
        for proj in ("wq", "wv"):
            specs.append(
                ParamSpec(f"l{i}.{proj}.lora_a", (cfg.d_model, rank), scale=1.0 / math.sqrt(cfg.d_model))
            )
            specs.append(ParamSpec(f"l{i}.{proj}.lora_b", (rank, cfg.d_model), init="zeros"))
    adapter_names = [s.name for s in specs]

    def loss_fn(params, b):
        adapters = dict(zip(adapter_names, params))
        base = {n: b[f"base.{n}"] for n in base_names}
        merged = dict(base)
        for i in range(cfg.n_layer):
            for proj in ("wq", "wv"):
                a = adapters[f"l{i}.{proj}.lora_a"]
                bb = adapters[f"l{i}.{proj}.lora_b"]
                merged[f"l{i}.{proj}"] = base[f"l{i}.{proj}"] + a @ bb
        logits = _lm_logits(merged, b["tokens"], cfg)
        logp = jax.nn.log_softmax(logits)
        tgt = jax.nn.one_hot(b["targets"], cfg.vocab)
        return -(tgt * logp).sum(-1).mean()

    batch = [
        ("tokens", (cfg.batch, cfg.seq_len), "i32"),
        ("targets", (cfg.batch, cfg.seq_len), "i32"),
    ] + [(f"base.{s.name}", s.shape, "f32") for s in base_specs]

    return ModelGraph(
        name="lora_lm",
        params=specs,
        loss_fn=loss_fn,
        batch=batch,
        meta={"rank": rank, "base_params": [s.name for s in base_specs], "seq_len": cfg.seq_len,
              "vocab": cfg.vocab, "batch": cfg.batch},
    )


# ---------------------------------------------------------------------------
# SMMF-fused whole-train-step graph (fwd + bwd + Pallas optimizer update)
# ---------------------------------------------------------------------------


def smmf_state_specs(graph: ModelGraph) -> list[tuple[str, tuple[int, ...], str]]:
    """Ordered (name, shape, dtype) for the factorized state of a model."""
    out = []
    for spec in graph.params:
        n, m = ref.effective_shape(int(np.prod(spec.shape)))
        out += [
            (f"{spec.name}.r_m", (n,), "f32"),
            (f"{spec.name}.c_m", (m,), "f32"),
            (f"{spec.name}.sign", (n, m), "pred"),
            (f"{spec.name}.r_v", (n,), "f32"),
            (f"{spec.name}.c_v", (m,), "f32"),
        ]
    return out


def smmf_fused_step(
    graph: ModelGraph,
    lr: float = 1e-3,
    beta1: float = 0.9,
    eps: float = 1e-8,
    growth_rate: float = 0.999,
    decay_rate: float = -0.8,
    weight_decay: float = 0.0,
    use_pallas: bool = True,
):
    """Build ``(step, params..., state..., batch...) -> (loss, params'...,
    state'...)`` — the paper's optimizer fused into one XLA program.

    ``use_pallas=True`` routes the per-tensor update through the L1 kernel;
    ``False`` uses the jnp oracle (used by tests to pin equivalence of the
    *lowered* graphs).
    """
    n_params = len(graph.params)
    state_specs = smmf_state_specs(graph)
    n_state = len(state_specs)
    batch_names = [b[0] for b in graph.batch]

    def fn(*args):
        step = args[0]
        params = list(args[1 : 1 + n_params])
        flat_state = list(args[1 + n_params : 1 + n_params + n_state])
        batch = dict(zip(batch_names, args[1 + n_params + n_state :]))

        loss, grads = jax.value_and_grad(graph.loss_fn)(params, batch)
        beta_m, beta_v = ref.betas(step.astype(jnp.float32), beta1, growth_rate, decay_rate)

        new_params, new_state = [], []
        for i, spec in enumerate(graph.params):
            p, g = params[i], grads[i]
            if weight_decay != 0.0:
                p = p * (1.0 - lr * weight_decay)  # adamw mode
            r_m, c_m, sign, r_v, c_v = flat_state[5 * i : 5 * i + 5]
            n, m = ref.effective_shape(int(np.prod(spec.shape)))
            g_bar = g.reshape(n, m)
            if use_pallas:
                u, r_m2, c_m2, sign2, r_v2, c_v2 = smmf_tensor_step(
                    g_bar, r_m, c_m, sign, r_v, c_v,
                    beta_m.astype(jnp.float32), beta_v.astype(jnp.float32),
                    jnp.float32(eps),
                )
            else:
                st = ref.TensorState(r_m, c_m, sign, r_v, c_v)
                st2, u = ref.tensor_step(st, g_bar, beta_m, beta_v, eps)
                r_m2, c_m2, sign2, r_v2, c_v2 = st2
            new_params.append(p - lr * u.reshape(p.shape))
            new_state += [r_m2, c_m2, sign2, r_v2, c_v2]
        return (loss, *new_params, *new_state)

    return fn, state_specs


MODELS = {
    "mlp": build_mlp,
    "lm": lambda: build_lm(LmConfig()),
    "cnn": lambda: build_cnn(CnnConfig()),
}
