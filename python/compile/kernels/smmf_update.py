"""L1 — Pallas kernel for the fused SMMF per-tensor update.

The paper's compute hot-spot is the per-tensor decompression → moment
update → compression → update-term chain (Algorithms 3–4). On a naive
implementation this is five full passes over the (n̂, m̂) moment matrix; the
kernel below fuses them into a *single* pass per row-block:

    for each row block (bm, m̂) of the square-matricized gradient:
        M̂  = r_m ⊗ c_m, sign-restored            (decompress, never hits HBM)
        V̂  = r_v ⊗ c_v
        M   = β₁ₜ·M̂ + (1−β₁ₜ)·Ḡ                  (moment update)
        V   = β₂ₜ·V̂ + (1−β₂ₜ)·Ḡ²
        U   = M / (√V + ε)                        (update term, written out)
        S'  = M > 0                               (new sign bits)
        row/col partial sums of |M| and V         (compression reductions)

HBM traffic per step is therefore one read of Ḡ + one write of U + the
vectors, versus Adam's read-modify-write of two dense moments: the fused
SMMF step moves *less* memory than Adam even though it does more arithmetic.

TPU adaptation (DESIGN.md §5): the block is sized for VMEM; reductions
accumulate per-block partials that a cheap jnp epilogue combines (the
epilogue is O(n̂+m̂)). The kernel is VPU-bound — there is no MXU work — so
the roofline is HBM bandwidth. ``interpret=True`` everywhere: the CPU PJRT
plugin cannot execute Mosaic custom-calls; on a real TPU the same
``pallas_call`` lowers natively.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _smmf_kernel(
    scal_ref,  # (1, 3) f32: [beta_m, beta_v, eps]
    g_ref,  # (bm, m) f32 — square-matricized gradient block
    r_m_ref,  # (bm, 1) f32
    c_m_ref,  # (1, m) f32
    sign_ref,  # (bm, m) bool
    r_v_ref,  # (bm, 1) f32
    c_v_ref,  # (1, m) f32
    u_ref,  # (bm, m) f32 out — update term
    sign_out_ref,  # (bm, m) bool out
    rsum_m_ref,  # (bm, 1) f32 out — |M| row sums
    csum_m_ref,  # (1, m) f32 out — |M| col partial sums for this block
    rsum_v_ref,  # (bm, 1) f32 out
    csum_v_ref,  # (1, m) f32 out
):
    beta_m = scal_ref[0, 0]
    beta_v = scal_ref[0, 1]
    eps = scal_ref[0, 2]

    g = g_ref[...]
    # Decompress: M̂ = ±(r ⊗ c), V̂ = r ⊗ c. Broadcasting (bm,1)*(1,m)
    # materializes only in VMEM/registers, never in HBM.
    m_hat = r_m_ref[...] * c_m_ref[...]
    m_hat = jnp.where(sign_ref[...], m_hat, -m_hat)
    v_hat = r_v_ref[...] * c_v_ref[...]

    m = beta_m * m_hat + (1.0 - beta_m) * g
    v = beta_v * v_hat + (1.0 - beta_v) * (g * g)

    u_ref[...] = m / (jnp.sqrt(v) + eps)
    sign_out_ref[...] = m > 0

    am = jnp.abs(m)
    rsum_m_ref[...] = am.sum(axis=1, keepdims=True)
    csum_m_ref[...] = am.sum(axis=0, keepdims=True)
    rsum_v_ref[...] = v.sum(axis=1, keepdims=True)
    csum_v_ref[...] = v.sum(axis=0, keepdims=True)


def _pick_block_rows(n: int, target: int = 256) -> int:
    """Largest divisor of n that is <= target (VMEM-sized row block)."""
    if n <= target:
        return n
    best = 1
    for bm in range(1, target + 1):
        if n % bm == 0:
            best = bm
    return best


@functools.partial(jax.jit, static_argnames=("block_rows",))
def smmf_tensor_step(
    g_bar: jnp.ndarray,
    r_m: jnp.ndarray,
    c_m: jnp.ndarray,
    sign: jnp.ndarray,
    r_v: jnp.ndarray,
    c_v: jnp.ndarray,
    beta_m: jnp.ndarray,
    beta_v: jnp.ndarray,
    eps: jnp.ndarray,
    *,
    block_rows: int | None = None,
):
    """Fused SMMF step over one square-matricized tensor.

    Args mirror ``ref.tensor_step`` but flattened: vectors are 1-D, ``sign``
    is the (n, m) bool matrix, and the three scalars are 0-D f32 arrays.

    Returns ``(u, r_m', c_m', sign', r_v', c_v')`` with the same semantics
    as the reference (including the normalize-shorter-side rule).
    """
    n, m = g_bar.shape
    bm = block_rows if block_rows is not None else _pick_block_rows(n)
    assert n % bm == 0, (n, bm)
    grid = (n // bm,)

    scal = jnp.stack([beta_m, beta_v, eps]).astype(jnp.float32).reshape(1, 3)

    out_shapes = (
        jax.ShapeDtypeStruct((n, m), g_bar.dtype),  # u
        jax.ShapeDtypeStruct((n, m), jnp.bool_),  # sign'
        jax.ShapeDtypeStruct((n, 1), g_bar.dtype),  # rsum_m
        jax.ShapeDtypeStruct((grid[0], m), g_bar.dtype),  # csum_m partials
        jax.ShapeDtypeStruct((n, 1), g_bar.dtype),  # rsum_v
        jax.ShapeDtypeStruct((grid[0], m), g_bar.dtype),  # csum_v partials
    )
    row_block = lambda i: (i, 0)
    full = lambda i: (0, 0)
    u, sign2, rsum_m, csum_m_p, rsum_v, csum_v_p = pl.pallas_call(
        _smmf_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 3), full),
            pl.BlockSpec((bm, m), row_block),
            pl.BlockSpec((bm, 1), row_block),
            pl.BlockSpec((1, m), full),
            pl.BlockSpec((bm, m), row_block),
            pl.BlockSpec((bm, 1), row_block),
            pl.BlockSpec((1, m), full),
        ],
        out_specs=[
            pl.BlockSpec((bm, m), row_block),
            pl.BlockSpec((bm, m), row_block),
            pl.BlockSpec((bm, 1), row_block),
            pl.BlockSpec((1, m), row_block),
            pl.BlockSpec((bm, 1), row_block),
            pl.BlockSpec((1, m), row_block),
        ],
        out_shape=out_shapes,
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(
        scal,
        g_bar,
        r_m.reshape(n, 1),
        c_m.reshape(1, m),
        sign,
        r_v.reshape(n, 1),
        c_v.reshape(1, m),
    )

    # O(n+m) epilogue: combine per-block column partials and apply the
    # normalize-shorter-side rule (paper Algorithm 4 / Appendix M code).
    r_m2 = rsum_m.reshape(n)
    c_m2 = csum_m_p.sum(axis=0)
    r_v2 = rsum_v.reshape(n)
    c_v2 = csum_v_p.sum(axis=0)
    if n < m:
        tot_m, tot_v = r_m2.sum(), r_v2.sum()
        r_m2 = jnp.where(tot_m != 0, r_m2 / tot_m, r_m2)
        r_v2 = jnp.where(tot_v != 0, r_v2 / tot_v, r_v2)
    else:
        tot_m, tot_v = c_m2.sum(), c_v2.sum()
        c_m2 = jnp.where(tot_m != 0, c_m2 / tot_m, c_m2)
        c_v2 = jnp.where(tot_v != 0, c_v2 / tot_v, c_v2)
    return u, r_m2, c_m2, sign2, r_v2, c_v2
