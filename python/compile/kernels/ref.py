"""Pure-jnp SMMF reference: the correctness oracle for the Pallas kernel.

This module is a line-faithful port of the paper's Appendix M PyTorch code
(https://github.com/eai-lab/SMMF) to jax.numpy. Every quirk of the original
is preserved and pinned by tests (python/tests/test_ref_semantics.py):

* ``effective_shape`` scans ``i = floor(sqrt(N)) .. 1`` for the largest
  divisor and returns ``(N // i, i)`` — so ``shape[0] >= shape[1]`` always.
* Compression stores ``sign = (M > 0)`` but decompression negates where the
  sign bit is *unset* (exact zeros land in the negative class; harmless
  because |M| = 0 there).
* The normalization side rule is ``if shape[0] < shape[1]: r /= sum(r) else:
  c /= sum(c)`` — with the effective-shape convention above the ``else``
  branch is the one that fires in practice.
* ``beta1_t = beta1 * growth_rate**(t-1)`` (AdamNC-style growth schedule),
  ``beta2_t = 1 - t**decay_rate`` (Adafactor-style decay), ``t`` starting
  at 1.
* epsilon is added *after* ``sqrt(V)`` (Adafactor-style), and there is no
  bias correction.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp


def effective_shape(numel: int) -> tuple[int, int]:
    """Square-matricization target shape (Algorithm 2).

    Returns (n, m), n >= m, n * m == numel, |n - m| minimal.
    """
    s = int(math.isqrt(numel))
    if s * s == numel:
        return (s, s)
    for i in range(s, 0, -1):
        if numel % i == 0:
            return (numel // i, i)
    return (numel, 1)  # unreachable: i == 1 always divides


def decompress(r: jnp.ndarray, c: jnp.ndarray, sign: jnp.ndarray | None) -> jnp.ndarray:
    """Algorithm 3: M = r ⊗ c, negated where the sign bit is unset."""
    m = jnp.outer(r, c)
    if sign is not None:
        m = jnp.where(sign, m, -m)
    return m


def compress(m: jnp.ndarray, signed: bool):
    """Algorithm 4 (one-pass NNMF, Algorithm 5).

    Returns (r, c, sign). ``sign`` is None when ``signed`` is False (the
    2nd momentum is non-negative).
    """
    if signed:
        sign = m > 0
        am = jnp.abs(m)
    else:
        sign = None
        am = m
    r = am.sum(axis=1)
    c = am.sum(axis=0)
    n, mm = m.shape
    if n < mm:
        total = r.sum()
        r = jnp.where(total != 0, r / total, r)
    else:
        total = c.sum()
        c = jnp.where(total != 0, c / total, c)
    return r, c, sign


class TensorState(NamedTuple):
    """SMMF per-tensor factorized state (the only persistent memory)."""

    r_m: jnp.ndarray  # (n,)  1st-momentum row factor
    c_m: jnp.ndarray  # (m,)  1st-momentum col factor
    sign: jnp.ndarray  # (n, m) bool — sign of the 1st momentum
    r_v: jnp.ndarray  # (n,)  2nd-momentum row factor
    c_v: jnp.ndarray  # (m,)  2nd-momentum col factor


def init_state(shape: tuple[int, int], dtype=jnp.float32) -> TensorState:
    n, m = shape
    return TensorState(
        r_m=jnp.zeros((n,), dtype),
        c_m=jnp.zeros((m,), dtype),
        sign=jnp.zeros((n, m), dtype=bool),
        r_v=jnp.zeros((n,), dtype),
        c_v=jnp.zeros((m,), dtype),
    )


def betas(step, beta1: float, growth_rate: float, decay_rate: float):
    """The default beta schedules (paper Algorithm 8)."""
    beta_m = beta1 * growth_rate ** (step - 1.0)
    beta_v = 1.0 - step**decay_rate
    return beta_m, beta_v


def tensor_step(
    state: TensorState,
    g_bar: jnp.ndarray,
    beta_m,
    beta_v,
    eps: float = 1e-8,
):
    """One SMMF step over a square-matricized gradient ``g_bar`` (n, m).

    The decompression→compression scheme (paper §3.2): moments are
    reconstructed, updated with the *intact* current gradient, re-factorized,
    and only then the update term U = M / (sqrt(V) + eps) is formed.

    Returns (new_state, u) where ``u`` has the matricized shape.
    """
    m_hat = decompress(state.r_m, state.c_m, state.sign)
    v_hat = decompress(state.r_v, state.c_v, None)
    m = beta_m * m_hat + (1.0 - beta_m) * g_bar
    v = beta_v * v_hat + (1.0 - beta_v) * (g_bar * g_bar)
    r_m, c_m, sign = compress(m, signed=True)
    r_v, c_v, _ = compress(v, signed=False)
    u = m / (jnp.sqrt(v) + eps)
    return TensorState(r_m, c_m, sign, r_v, c_v), u


# ---------------------------------------------------------------------------
# Full-optimizer reference over a pytree of parameters (mirrors the paper's
# torch.optim.Optimizer class, including weight-decay modes and the
# non-factorized fallback for rank-1 tensors when vector_reshape=False).
# ---------------------------------------------------------------------------


class SmmfHyper(NamedTuple):
    lr: float = 1e-3
    beta1: float = 0.9
    eps: float = 1e-8
    weight_decay: float = 0.0
    decay_rate: float = -0.5
    growth_rate: float = 0.999
    vector_reshape: bool = True
    weight_decay_mode: str = "adamw"  # "adam" | "adamw"


def smmf_init(params, hyper: SmmfHyper = SmmfHyper()):
    """Build the factorized state pytree for a parameter pytree."""

    def one(p):
        if p.ndim <= 1 and not hyper.vector_reshape:
            # Non-factorized fallback: dense Adam-style moments.
            return (jnp.zeros_like(p), jnp.zeros_like(p))
        shape = effective_shape(p.size)
        return init_state(shape, p.dtype)

    return jax.tree_util.tree_map(one, params)


def smmf_update(params, grads, state, step, hyper: SmmfHyper = SmmfHyper()):
    """One SMMF optimizer step over pytrees. ``step`` starts at 1."""
    beta_m, beta_v = betas(step, hyper.beta1, hyper.growth_rate, hyper.decay_rate)

    def one(p, g, s):
        if hyper.weight_decay != 0.0 and hyper.weight_decay_mode == "adam":
            g = g + hyper.weight_decay * p
        elif hyper.weight_decay != 0.0 and hyper.weight_decay_mode == "adamw":
            p = p * (1.0 - hyper.lr * hyper.weight_decay)
        if isinstance(s, TensorState):
            shape = (s.r_m.shape[0], s.c_m.shape[0])
            g_bar = g.reshape(shape)
            s2, u = tensor_step(s, g_bar, beta_m, beta_v, hyper.eps)
            return p - hyper.lr * u.reshape(p.shape), s2
        m, v = s
        m = beta_m * m + (1.0 - beta_m) * g
        v = beta_v * v + (1.0 - beta_v) * g * g
        u = m / (jnp.sqrt(v) + hyper.eps)
        return p - hyper.lr * u, (m, v)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_s = treedef.flatten_up_to(state)
    out = [one(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_s = treedef.unflatten([o[1] for o in out])
    return new_p, new_s
