//! `repro` — the SMMF reproduction CLI (leader entrypoint).
//!
//! Every table/figure of the paper is a subcommand (DESIGN.md §3):
//!
//! ```text
//! repro list                      # artifacts + model inventories
//! repro memory --table table1    # memory columns of a paper table
//! repro table1 .. table13        # shortcuts for the above
//! repro table5 [--quick]         # optimizer step-time table
//! repro fig1|fig2|fig4           # optimizer-comparison training curves
//! repro e2e [--steps 300]        # end-to-end LM training driver (SMMF)
//! repro train --artifact lm_tiny_grads --optimizer smmf --steps 100
//! repro suite rust/tests/suite_smoke.toml   # optimizer × model × seed sweep
//! repro worker --listen 127.0.0.1:7131      # remote suite-cell executor
//! repro suite s.toml --workers remote:127.0.0.1:7131   # …dispatched over SMMFCELL
//! repro report runs/smoke        # re-render docs/RESULTS.md from a suite dir
//! repro dp --workers 2           # data-parallel demo
//! repro fused --steps 50         # compiled (Pallas) SMMF train step
//! repro ablate                   # SMMF design ablations
//! repro serve --shards 2 --clients 4     # optimizer-state server
//! repro loadgen --clients 4 --steps 50   # drive it + bench it
//! repro replay commits.bin --shards 2    # re-apply an async commit log
//! ```

use anyhow::{anyhow, bail, Result};
use std::path::Path;

use smmf_repro::coordinator::experiments as exp;
use smmf_repro::coordinator::{report, suite, workers, ExperimentConfig, SuiteConfig, WorkerSpec};
use smmf_repro::models;
use smmf_repro::obs;
use smmf_repro::optim::OptKind;
use smmf_repro::runtime::Runtime;
use smmf_repro::train::FusedSmmfStep;
use smmf_repro::util::cli::Args;
use smmf_repro::util::fmt;

fn main() {
    let args = Args::from_env();
    if let Err(e) = run_top(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// The observability lifecycle around the dispatch: read `[obs]` +
/// `--trace`/`--metrics`, flip the global switches, run the command,
/// export on the way out. `repro trace` manages its own lifecycle
/// (it rebuilds the inner command line from raw argv), so it is
/// dispatched bare.
fn run_top(args: &Args) -> Result<()> {
    if args.command.as_deref() == Some("trace") {
        return run(args);
    }
    let cfg = obs::ObsConfig::load(args)?;
    obs::init(&cfg);
    let out = run(args);
    // Export even when the command failed — a trace of the failing run
    // is exactly the trace you want.
    let fin = obs::finish(&cfg);
    out.and(fin)
}

fn artifacts_dir(args: &Args) -> String {
    args.str_or("artifacts", "artifacts")
}

fn base_config(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = ExperimentConfig::default();
    if let Some(path) = args.opt("config") {
        cfg = ExperimentConfig::from_toml(std::path::Path::new(path))?;
    }
    cfg.apply_args(args)?;
    Ok(cfg)
}

fn run(args: &Args) -> Result<()> {
    let cmd = args.command.clone().unwrap_or_else(|| "help".into());
    match cmd.as_str() {
        "help" | "--help" => {
            println!("{}", HELP);
            Ok(())
        }
        "list" => cmd_list(args),
        "memory" => {
            let table = args.str_or("table", "all");
            cmd_memory(&table)
        }
        t if t.starts_with("table") && t != "table5" => cmd_memory(t),
        "table5" => cmd_table5(args),
        "fig1" => cmd_fig(args, "fig1"),
        "fig2" => cmd_fig(args, "fig2"),
        "fig4" => cmd_fig(args, "fig4"),
        "e2e" => cmd_e2e(args),
        "train" => cmd_train(args),
        "suite" => cmd_suite(args),
        "worker" => cmd_worker(args),
        "report" => cmd_report(args),
        "dp" => cmd_dp(args),
        "fused" => cmd_fused(args),
        "ablate" => cmd_ablate(args),
        "serve" => cmd_serve(args),
        "loadgen" => cmd_loadgen(args),
        "replay" => cmd_replay(args),
        "trace" => cmd_trace(),
        other => bail!("unknown command {other} (try `repro help`)"),
    }
}

const HELP: &str = "repro — SMMF (AAAI 2025) reproduction
commands:
  help              this message
  list              artifacts and model inventories (+ per-role breakdown)
  memory --table T  memory columns (table1..table4, table6..table13, all)
  tableN            shortcut for `memory --table tableN`
  table5 [--quick]  optimizer step-time measurements
  fig1|fig2|fig4    optimizer-comparison training curves -> runs/
  e2e               end-to-end char-LM training with SMMF -> runs/e2e
  train             one training run (--artifact, --optimizer, --steps,
                    --lr, --config file.toml, --out-dir,
                    --save-every N [writes runs/<name>/checkpoint.bin],
                    --resume <checkpoint.bin> [bit-identical restart])
  suite FILE.toml   run a declarative optimizer × model × seed sweep
                    ([[suite.run]] blocks; see rust/tests/suite_smoke.toml)
                    with failure isolation + resume-aware re-entry, then
                    regenerate the paper-style report
                    (--workers \"N | local:N | remote:HOST:PORT,...\" —
                    remote specs dispatch cells to `repro worker`
                    daemons over SMMFCELL with lease-based re-dispatch
                    [--lease-timeout-ms MS, default 10000]; reports stay
                    byte-identical to a local run,
                    --force re-runs cached cells, --out-dir DIR,
                    --docs PATH [default docs/RESULTS.md],
                    --bench-json PATH [default BENCH_suite.json])
  worker            suite-cell execution daemon: accepts cells over the
                    SMMFCELL wire protocol and runs them through the
                    same path as a local suite (--listen HOST:PORT
                    [default 127.0.0.1:0], --capacity N [concurrent
                    cells, default 1], --artifacts DIR; stops on a
                    Shutdown op; see docs/SUITE_WIRE.md)
  report DIR        re-render the report from an existing suite dir
                    (runs/<suite>) without training (--name, --docs,
                    --bench-json as above)
  dp --workers K    synchronous data-parallel training demo
  fused             compiled whole-train-step (Pallas SMMF) demo
  ablate            SMMF design ablations (scheme / sign width /
                    matricization / vector_reshape) on the LM workload
  serve             optimizer-state server: sharded, batched gradient
                    ingestion over the SMMFWIRE binary protocol
                    (--model synthetic:tiny_lm, --shards K, --clients N,
                    --addr HOST:PORT, --max-pending Q,
                    --client-timeout-ms MS [evict barrier members that
                    stop pushing; 0 = never], --resilient [respawn dead
                    shard workers from a per-step recovery image],
                    --resume SNAPSHOT.bin [restore params + optimizer
                    state, re-sharding if --shards differs],
                    --staleness S [bounded-staleness async ingestion:
                    whatever is pending commits as one partial batch,
                    pushes more than S steps stale bounce as TooStale;
                    0 = synchronous step barrier],
                    --commit-log PATH [async only: append every applied
                    commit for `repro replay`],
                    [server] TOML; stops on a client Shutdown op; see
                    docs/SERVER_PROTOCOL.md)
  loadgen           drive a state server with N concurrent gradient
                    clients and emit throughput + p50/p99 push latency
                    (--clients N, --steps S; self-spawns a loopback
                    server [--shards K] unless --connect HOST:PORT;
                    --snapshot PATH, --check [assert the snapshot is
                    bit-identical to the single-process reference
                    trainer, elastic-aware under --drop-client],
                    --bench-json PATH [default BENCH_server.json];
                    chaos faults: --slow-client MS [p95 exponential
                    think time on the highest-id client],
                    --drop-client STEP [that client crashes after
                    pushing STEP; needs --client-timeout-ms],
                    --kill-shard STEP [kill a shard worker once the
                    server passes STEP; implies --resilient]; any
                    fault also runs a healthy baseline first and
                    reports degraded vs healthy steps/s; with
                    --staleness S the drivers run the async pull/push
                    loop, a synchronous baseline runs first for the
                    sync-vs-async steps/s comparison, and --check /
                    --drop-client are refused [replay pins async runs])
  replay LOG.bin    re-apply a --commit-log file through the synchronous
                    sharded machinery to a bit-identical snapshot — the
                    determinism oracle for async runs (--shards K
                    [default 1, free to differ from the recording run],
                    --snapshot OUT.bin [default LOG.bin.replay.bin];
                    config/seed/optimizer must match the recording run)
  trace -- CMD …    run any repro command with the flight recorder +
                    metrics registry forced on, exporting on exit:
                    Chrome trace-event JSON (--trace-out PATH [default
                    trace.json]; open at ui.perfetto.dev) and the
                    Prometheus text exposition (--metrics-out PATH
                    [default metrics.prom]); see docs/OBSERVABILITY.md
common flags: --trace / --metrics (observability on any command:
              span recording / metric export, also `[obs]` TOML;
              --trace implies --metrics),
              --trace-out PATH, --metrics-out PATH,
              --artifacts DIR (default ./artifacts), --seed N,
              --threads N (parallel optimizer step engine; 1 = serial),
              --save-every N / --resume PATH (SMMFCKPT v2 checkpoints;
              see docs/CHECKPOINT_FORMAT.md),
              --bias-correction true|false (Adam/AdamW; paper defaults
              disable it for pre-training — surfaced in summary.json)
param groups: --group \"name=no_decay,role=bias|norm,wd=0; match=*emb*,
              lr_scale=0.5,state=dense\" — per-group hyperparameter
              overrides (role/name-glob matchers, first match wins;
              state=factored|dense|none, frozen). TOML spelling:
              [[optimizer.group]] blocks (see README quickstart)";

/// `repro trace [--] CMD [args…]`: run CMD with the flight recorder and
/// the metrics registry forced on, then export the Chrome trace JSON +
/// Prometheus text on exit. The inner command line is rebuilt from raw
/// argv because [`Args::parse`] treats a bare `--` as an empty-named
/// option that swallows the token after it.
fn cmd_trace() -> Result<()> {
    let mut raw: Vec<String> = std::env::args().skip(1).collect();
    match raw.first().map(String::as_str) {
        Some("trace") => {
            raw.remove(0);
        }
        _ => bail!("`trace` must be the leading subcommand (repro trace -- <command> [args…])"),
    }
    if raw.first().map(String::as_str) == Some("--") {
        raw.remove(0);
    }
    if raw.is_empty() || raw[0] == "trace" {
        bail!("usage: repro trace -- <command> [args…] (e.g. repro trace -- loadgen --steps 50)");
    }
    let inner = Args::parse(raw.into_iter());
    let mut cfg = obs::ObsConfig::load(&inner)?;
    cfg.trace = true;
    cfg.metrics = true;
    obs::init(&cfg);
    let out = run(&inner);
    let fin = obs::finish(&cfg);
    out.and(fin)
}

fn cmd_list(args: &Args) -> Result<()> {
    println!("model inventories (memory accounting):");
    println!("  (role rows: tensors/params per role — sanity-check [[optimizer.group]] matchers)");
    for (name, ctx) in models::list_inventories() {
        let inv = models::inventory_by_name(name).unwrap();
        println!("  {name:<26} {:>8} params   {ctx}", fmt::count(inv.param_count()));
        let roles: Vec<String> = inv
            .role_breakdown()
            .iter()
            .map(|(role, count, params)| format!("{} {}/{}", role.name(), count, fmt::count(*params)))
            .collect();
        println!("  {:<26} {}", "", roles.join("  "));
    }
    let dir = artifacts_dir(args);
    match Runtime::open(&dir) {
        Ok(rt) => {
            println!("\nAOT artifacts in {dir}/:");
            for (name, spec) in &rt.manifest().artifacts {
                println!(
                    "  {name:<26} kind={:<10} {} inputs / {} outputs",
                    spec.kind,
                    spec.inputs.len(),
                    spec.outputs.len()
                );
            }
        }
        Err(_) => println!("\n(artifacts not built — run `make artifacts`)"),
    }
    Ok(())
}

fn cmd_memory(table: &str) -> Result<()> {
    let tables: Vec<String> = if table == "all" {
        vec![
            "table1", "table2", "table3", "table4", "table6", "table7", "table8", "table9",
            "table10", "table11", "table12", "table13",
        ]
        .into_iter()
        .map(String::from)
        .collect()
    } else {
        vec![table.to_string()]
    };
    for t in tables {
        let rows = exp::memory_rows(&exp::table_models(&t)?)?;
        println!("{}", exp::render_memory_table(&t, &rows));
    }
    Ok(())
}

fn cmd_table5(args: &Args) -> Result<()> {
    let quick = args.has_flag("quick");
    let models: Vec<&str> = if quick {
        vec!["mobilenet_v2_imagenet", "transformer_base"]
    } else {
        vec!["mobilenet_v2_imagenet", "resnet50_imagenet", "transformer_base", "transformer_big"]
    };
    let reps = args.usize_or("reps", if quick { 3 } else { 5 });
    let threads = args.positive_usize_or("threads", 1);
    let rows = exp::time_rows(&models, reps, threads)?;
    println!("{}", exp::render_time_table(&rows));
    Ok(())
}

fn fig_defaults(fig: &str, cfg: &mut ExperimentConfig) {
    match fig {
        // Figure 1: CNN image classification (γ = -0.5 per Appendix F).
        "fig1" => {
            cfg.artifact = "cnn_grads".into();
            cfg.steps = 200;
            cfg.optim.lr = 1e-3;
            cfg.optim.decay_rate = -0.5;
            // Paper Table 15: weight-decay 5e-4, Adam-coupled.
            cfg.optim.weight_decay = 5e-4;
            cfg.optim.weight_decay_mode = smmf_repro::optim::WeightDecayMode::Adam;
        }
        // Figure 2: transformer LM (γ = -0.8).
        "fig2" => {
            cfg.artifact = "lm_tiny_grads".into();
            cfg.steps = 300;
            cfg.optim.lr = 1e-3;
            cfg.optim.decay_rate = -0.8;
        }
        // Figure 4: LoRA fine-tune, Adam vs SMMF.
        "fig4" => {
            cfg.artifact = "lora_tiny_grads".into();
            cfg.steps = 200;
            cfg.optim.lr = 1e-4;
            cfg.optim.decay_rate = -0.8;
        }
        _ => {}
    }
}

fn cmd_fig(args: &Args, fig: &str) -> Result<()> {
    let rt = Runtime::open(artifacts_dir(args))?;
    let mut cfg = base_config(args)?;
    let user_steps = args.opt("steps").map(|s| s.parse::<u64>().ok()).flatten();
    fig_defaults(fig, &mut cfg);
    if let Some(steps) = user_steps {
        cfg.steps = steps;
    }
    let kinds: Vec<OptKind> = if fig == "fig4" {
        vec![OptKind::Adam, OptKind::Smmf]
    } else {
        OptKind::all().to_vec()
    };
    let summaries = exp::run_comparison(&rt, &cfg, &kinds, fig)?;
    println!("\n== {fig} summary (final loss after {} steps) ==", cfg.steps);
    let rows: Vec<Vec<String>> = summaries
        .iter()
        .map(|s| {
            vec![
                s.optimizer.clone(),
                format!("{:.4}", s.final_loss),
                format!("{:.4}", (s.final_loss as f64).exp()),
                format!("{:.1}", s.mean_step_ms),
                fmt::bytes(s.opt_state_bytes),
            ]
        })
        .collect();
    println!(
        "{}",
        fmt::render_table(&["optimizer", "final loss", "ppl", "ms/step", "opt state"], &rows)
    );
    Ok(())
}

fn cmd_e2e(args: &Args) -> Result<()> {
    let rt = Runtime::open(artifacts_dir(args))?;
    let mut cfg = base_config(args)?;
    if args.opt("artifact").is_none() {
        cfg.artifact = "lm_e2e_grads".into();
    }
    if args.opt("steps").is_none() {
        cfg.steps = 300;
    }
    cfg.name = args.str_or("name", "e2e/smmf");
    cfg.optim.decay_rate = -0.8;
    println!(
        "[e2e] training {} with {} for {} steps (tiny real corpus)…",
        cfg.artifact,
        cfg.optimizer.name(),
        cfg.steps
    );
    let s = exp::run_experiment(&rt, &cfg)?;
    // Compare the optimizer state against Adam on the same shapes.
    let graph = smmf_repro::train::TrainGraph::load(&rt, &cfg.artifact)?;
    let shapes = graph.param_shapes();
    let adam = smmf_repro::optim::memory::inventory_state_bytes(
        OptKind::Adam,
        &shapes,
        &smmf_repro::optim::OptimConfig::default(),
    );
    println!(
        "\n[e2e] loss {:.4} -> {:.4} over {} steps ({:.0} ms/step)",
        s.first_loss, s.final_loss, s.steps, s.mean_step_ms
    );
    println!(
        "[e2e] optimizer state: {} ({}) vs Adam {} — {:.1}x smaller",
        fmt::bytes(s.opt_state_bytes),
        s.optimizer,
        fmt::bytes(adam),
        adam as f64 / s.opt_state_bytes as f64
    );
    println!("[e2e] curves in runs/{}/metrics.csv", s.name);
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let rt = Runtime::open(artifacts_dir(args))?;
    let mut cfg = base_config(args)?;
    if args.opt("name").is_none() {
        cfg.name = format!("{}_{}", cfg.artifact, cfg.optimizer.name());
    }
    let s = exp::run_experiment(&rt, &cfg)?;
    println!(
        "[train:{}] loss {:.4} -> {:.4}   {:.1} ms/step   opt {}",
        s.optimizer,
        s.first_loss,
        s.final_loss,
        s.mean_step_ms,
        fmt::bytes(s.opt_state_bytes)
    );
    Ok(())
}

/// Default report paths: repo-root-relative when invoked from the repo
/// root, `../`-prefixed when invoked from `rust/` (the two places the
/// Makefile and README run `repro` from).
fn default_report_paths() -> (String, String) {
    if Path::new("docs").is_dir() || !Path::new("../docs").is_dir() {
        ("docs/RESULTS.md".into(), "BENCH_suite.json".into())
    } else {
        ("../docs/RESULTS.md".into(), "../BENCH_suite.json".into())
    }
}

fn report_paths(args: &Args) -> (String, String) {
    let (d_docs, d_bench) = default_report_paths();
    (args.str_or("docs", &d_docs), args.str_or("bench-json", &d_bench))
}

fn cmd_suite(args: &Args) -> Result<()> {
    let file = args
        .positional
        .first()
        .map(String::as_str)
        .or_else(|| args.opt("file"))
        .ok_or_else(|| {
            anyhow!(
                "usage: repro suite <suite.toml> [--workers N] [--force] \
                 [--docs PATH] [--bench-json PATH]"
            )
        })?;
    let mut suite_cfg = SuiteConfig::from_toml(Path::new(file))?;
    suite_cfg.out_dir = args.str_or("out-dir", &suite_cfg.out_dir);
    // `--workers` accepts the full spec grammar ("3", "local:2",
    // "remote:host:port,host:port", mixes) and overrides `[suite]
    // workers`; absent means the file (or its default) decides.
    let workers = args
        .opt("workers")
        .map(|s| WorkerSpec::parse(s).map_err(|e| anyhow!("--workers: {e}")))
        .transpose()?;
    let opts = suite::SuiteOptions {
        force: args.has_flag("force"),
        workers,
        artifacts_dir: artifacts_dir(args),
        lease_timeout_ms: args.u64_or("lease-timeout-ms", 10_000),
    };
    let t0 = std::time::Instant::now();
    let outcome = suite::run_suite(&suite_cfg, &opts)?;
    let elapsed_s = t0.elapsed().as_secs_f64();
    let (ran, skipped, failed) = outcome.counts();
    let (docs, bench) = report_paths(args);
    report::write_report(&suite_cfg.name, &outcome.suite_dir, Path::new(&docs), Path::new(&bench))?;
    println!("\n[suite {}] {ran} ran, {skipped} cached, {failed} failed", suite_cfg.name);
    // Lane retries = Busy bounces + requeues, read from the same global
    // registry the remote dispatcher bumps (0 for a purely local run).
    let reg = obs::metrics::global();
    let lane_retries = reg.value("remote.busy_retries_total").unwrap_or(0)
        + reg.value("remote.requeues_total").unwrap_or(0);
    println!(
        "[suite {}] digest: {ran} ran in {:.1}s ({:.2} cells/s) | {} lane retries",
        suite_cfg.name,
        elapsed_s,
        ran as f64 / elapsed_s.max(1e-12),
        lane_retries
    );
    println!("[suite {}] report -> {docs} (records -> {bench})", suite_cfg.name);
    // Failure isolation keeps the suite (and the report) going, but the
    // exit code must still tell CI the truth.
    if failed > 0 {
        bail!(
            "{failed} suite cell(s) FAILED (report still written to {docs}; \
             see the FAILED markers under {:?} — failed cells retry on re-run)",
            outcome.suite_dir
        );
    }
    Ok(())
}

fn cmd_worker(args: &Args) -> Result<()> {
    use smmf_repro::coordinator::remote::{WorkerOptions, WorkerServer};
    let capacity = args.count_or("capacity", 1).map_err(|e| anyhow!(e))?;
    let opts = WorkerOptions {
        listen: args.str_or("listen", "127.0.0.1:0"),
        capacity,
        artifacts_dir: artifacts_dir(args),
        // Test-only chaos knob (undocumented in HELP on purpose): go
        // silent after N accepted submits, like a kill -9.
        crash_after_accepts: args.u64_or("crash-after", 0),
        ..WorkerOptions::default()
    };
    let server = WorkerServer::start(&opts)?;
    println!("[worker] listening on {} (capacity {})", server.addr, opts.capacity);
    println!(
        "[worker] point a suite at it: repro suite <suite.toml> --workers \"remote:{}\"",
        server.addr
    );
    let stats = server.wait();
    println!(
        "[worker] stopped — {} accepted, {} done, {} failed, {} busy bounce(s)",
        stats.accepted, stats.done, stats.failed, stats.busy
    );
    Ok(())
}

fn cmd_report(args: &Args) -> Result<()> {
    let dir = args
        .positional
        .first()
        .map(String::as_str)
        .or_else(|| args.opt("dir"))
        .ok_or_else(|| {
            anyhow!("usage: repro report runs/<suite> [--name NAME] [--docs PATH] [--bench-json PATH]")
        })?;
    let dirp = Path::new(dir);
    let default_name =
        dirp.file_name().and_then(|s| s.to_str()).unwrap_or("suite").to_string();
    let name = args.str_or("name", &default_name);
    let (docs, bench) = report_paths(args);
    let n = report::write_report(&name, dirp, Path::new(&docs), Path::new(&bench))?;
    println!("[report {name}] {n} cells -> {docs} (records -> {bench})");
    Ok(())
}

fn cmd_dp(args: &Args) -> Result<()> {
    let mut cfg = base_config(args)?;
    if args.opt("artifact").is_none() {
        cfg.artifact = "mlp_grads".into();
    }
    if args.opt("steps").is_none() {
        cfg.steps = 30;
    }
    let workers = args.positive_usize_or("workers", 2);
    println!("[dp] {} workers, {} steps on {}", workers, cfg.steps, cfg.artifact);
    let losses = workers::train_data_parallel(&artifacts_dir(args), &cfg, workers)?;
    println!(
        "[dp] loss {:.4} -> {:.4} (synchronous gradient averaging over {} workers)",
        losses.first().copied().unwrap_or(f32::NAN),
        losses.last().copied().unwrap_or(f32::NAN),
        workers
    );
    Ok(())
}

fn cmd_ablate(args: &Args) -> Result<()> {
    use smmf_repro::optim::{MatricizeMode, SignMode, SmmfScheme};
    let rt = Runtime::open(artifacts_dir(args))?;
    let mut base = base_config(args)?;
    if args.opt("artifact").is_none() {
        base.artifact = "lm_tiny_grads".into();
    }
    if args.opt("steps").is_none() {
        base.steps = 150;
    }
    base.optimizer = OptKind::Smmf;
    base.optim.decay_rate = -0.8;

    let variants: Vec<(&str, Box<dyn Fn(&mut ExperimentConfig)>)> = vec![
        ("default (decompress→compress, 1-bit, square)", Box::new(|_| {})),
        (
            "compress→decompress scheme (§3.2 ablation)",
            Box::new(|c| c.optim.smmf_scheme = SmmfScheme::CompressFirst),
        ),
        (
            "8-bit S_M (Table 5 timing variant)",
            Box::new(|c| c.optim.smmf_sign_mode = SignMode::Byte8),
        ),
        (
            "fold-last matricization (no Algorithm 2)",
            Box::new(|c| c.optim.smmf_matricize = MatricizeMode::FoldLast),
        ),
        (
            "vector_reshape = false (dense rank-1 state)",
            Box::new(|c| c.optim.vector_reshape = false),
        ),
    ];
    println!("== SMMF design ablations on {} ({} steps) ==", base.artifact, base.steps);
    let mut rows = Vec::new();
    for (i, (label, tweak)) in variants.iter().enumerate() {
        let mut cfg = base.clone();
        tweak(&mut cfg);
        cfg.name = format!("ablate/v{i}");
        let s = exp::run_experiment(&rt, &cfg)?;
        rows.push(vec![
            label.to_string(),
            format!("{:.4}", s.final_loss),
            format!("{:.1}", s.mean_step_ms),
            fmt::bytes(s.opt_state_bytes),
        ]);
    }
    println!(
        "{}",
        fmt::render_table(&["variant", "final loss", "ms/step", "opt state"], &rows)
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    use smmf_repro::server::{ServeOptions, Server};
    let cfg = base_config(args)?;
    let opts = ServeOptions::load(args)?;
    let server = Server::start(&cfg, &opts)?;
    let mode = if opts.staleness == 0 {
        format!("step barrier over {} client(s)", opts.clients)
    } else {
        format!(
            "async ingestion over {} member(s), staleness window {}",
            opts.clients, opts.staleness
        )
    };
    println!(
        "[serve] {} on {} — {} shard(s), {}, optimizer {}",
        opts.model,
        server.addr,
        opts.shards,
        mode,
        cfg.optimizer.name()
    );
    if let Some(log) = &opts.commit_log {
        println!("[serve] commit log -> {log} (replay with `repro replay {log}`)");
    }
    if opts.client_timeout_ms > 0 || opts.resilient || opts.resume.is_some() {
        println!(
            "[serve] fault tolerance: client_timeout_ms={} resilient={}{}",
            opts.client_timeout_ms,
            opts.resilient,
            opts.resume
                .as_deref()
                .map(|p| format!(", resumed from {p}"))
                .unwrap_or_default()
        );
    }
    println!("[serve] drive it with `repro loadgen --connect {}` (a Shutdown op stops it)", server.addr);
    let stats = server.wait()?;
    println!(
        "[serve] stopped at step {} (epoch {}) — {} pushes, {} busy bounces, {} snapshot(s), \
         {} eviction(s), {} shard respawn(s)",
        stats.step, stats.epoch, stats.pushes, stats.busy, stats.snapshots, stats.evictions,
        stats.respawns
    );
    Ok(())
}

/// Default `BENCH_server.json` location: repo-root-relative from the
/// repo root, `../`-prefixed from `rust/` (same rule as the report
/// paths).
fn default_server_bench() -> String {
    if Path::new("docs").is_dir() || !Path::new("../docs").is_dir() {
        "BENCH_server.json".into()
    } else {
        "../BENCH_server.json".into()
    }
}

fn cmd_loadgen(args: &Args) -> Result<()> {
    use smmf_repro::server::{self as srv, ServeOptions};
    use smmf_repro::util::bench::JsonSink;
    use smmf_repro::util::json::ObjBuilder;

    let cfg = base_config(args)?;
    // Strictly validated (not silently defaulted): a typo'd --steps must
    // not quietly drive the wrong number of steps.
    let steps = args.count_or("steps", 50).map_err(|e| anyhow!(e))? as u64;
    let mut opts = ServeOptions::load(args)?;
    let check = args.has_flag("check");
    if check && args.opt("connect").is_some() {
        bail!(
            "--check needs a self-spawned server (omit --connect): the snapshot is \
             written on the server host, so the byte-compare against the local \
             reference trainer is only meaningful when both share this process's \
             working directory and config"
        );
    }

    // Chaos-fault knobs (docs/ARCHITECTURE.md has the failure model).
    let slow_client_ms = match args.opt("slow-client") {
        None => 0.0,
        Some(s) => {
            let v: f64 = s
                .parse()
                .map_err(|_| anyhow!("--slow-client wants a p95 in milliseconds, got {s:?}"))?;
            if v < 0.0 {
                bail!("--slow-client must be >= 0 (got {v})");
            }
            v
        }
    };
    let drop_client_at = args.count_or("drop-client", 0).map_err(|e| anyhow!(e))? as u64;
    let kill_shard_at = args.count_or("kill-shard", 0).map_err(|e| anyhow!(e))? as u64;
    if drop_client_at > 0 {
        if opts.clients < 2 {
            bail!("--drop-client needs --clients >= 2 (someone must survive the barrier)");
        }
        if opts.client_timeout_ms == 0 {
            bail!(
                "--drop-client needs --client-timeout-ms > 0, or the surviving clients \
                 wait on the dropped one forever"
            );
        }
    }
    if kill_shard_at > 0 {
        if args.opt("connect").is_some() {
            bail!("--kill-shard injects the fault in-process — it needs a self-spawned server");
        }
        // A killed shard without resilience is just a dead server.
        opts.resilient = true;
    }
    if check && slow_client_ms > 0.0 {
        bail!(
            "--check with --slow-client is unsupported: whether the slow client gets \
             evicted depends on wall-clock timing, so there is no fixed membership \
             schedule for the reference trainer to replay"
        );
    }
    if opts.staleness > 0 {
        if check {
            bail!(
                "--check is the synchronous-mode oracle (the reference trainer replays a \
                 fixed barrier schedule); async runs are pinned by `repro replay` over a \
                 --commit-log instead"
            );
        }
        if drop_client_at > 0 {
            bail!(
                "--drop-client drives the synchronous eviction path; async mode has no \
                 barrier to evict from — a straggler only ever delays itself \
                 (use --slow-client to exercise that)"
            );
        }
    }
    let snapshot_was_temp = check && args.opt("snapshot").is_none();
    let snapshot: Option<String> = args.opt("snapshot").map(String::from).or_else(|| {
        check.then(|| {
            std::env::temp_dir()
                .join(format!("smmf_loadgen_{}.bin", std::process::id()))
                .to_string_lossy()
                .into_owned()
        })
    });

    // Self-spawn a loopback server unless --connect points elsewhere.
    let external = args.opt("connect").map(String::from);
    let (addr, server) = match &external {
        Some(a) => (a.clone(), None),
        None => {
            if args.opt("addr").is_none() {
                opts.addr = "127.0.0.1:0".into();
            }
            let server = srv::Server::start(&cfg, &opts)?;
            (server.addr.to_string(), Some(server))
        }
    };

    let inv_name =
        opts.model.strip_prefix("synthetic:").unwrap_or(&opts.model).to_string();
    let shapes = srv::resolve_inventory(&opts.model)?.shapes();

    // With a fault injected, first measure the same run healthy on its
    // own throwaway server — the degraded-vs-healthy throughput ratio
    // is the recovery-cost headline of BENCH_server.json. Sync mode
    // only: the async comparison below is sync-vs-async instead (and a
    // cloned async server would contend for the same --commit-log).
    let faults = slow_client_ms > 0.0 || drop_client_at > 0 || kill_shard_at > 0;
    let healthy_steps_per_s = if faults && external.is_none() && opts.staleness == 0 {
        let mut hopts = opts.clone();
        hopts.addr = "127.0.0.1:0".into();
        let hsrv = srv::Server::start(&cfg, &hopts)?;
        let haddr = hsrv.addr.to_string();
        let hstart = srv::Client::connect(&haddr)?.stats()?.step + 1;
        let rep = srv::run_loadgen(
            &haddr,
            &shapes,
            cfg.seed,
            &srv::LoadgenOptions {
                clients: opts.clients,
                steps,
                start_step: hstart,
                slow_client_ms: 0.0,
                drop_client_at: 0,
            },
        )?;
        srv::Client::connect(&haddr)?.shutdown()?;
        hsrv.wait()?;
        println!("[loadgen] healthy baseline: {:.1} steps/s", rep.steps_per_s);
        Some(rep.steps_per_s)
    } else {
        None
    };

    // Async mode: measure the identical workload (same clients, same
    // straggler fault) against a synchronous-barrier server first —
    // the sync-vs-async steps/s ratio is what bounded staleness buys.
    let sync_steps_per_s = if opts.staleness > 0 && external.is_none() {
        let mut sopts = opts.clone();
        sopts.addr = "127.0.0.1:0".into();
        sopts.staleness = 0;
        sopts.commit_log = None;
        let ssrv = srv::Server::start(&cfg, &sopts)?;
        let saddr = ssrv.addr.to_string();
        let rep = srv::run_loadgen(
            &saddr,
            &shapes,
            cfg.seed,
            &srv::LoadgenOptions {
                clients: opts.clients,
                steps,
                start_step: 1,
                slow_client_ms,
                drop_client_at: 0,
            },
        )?;
        srv::Client::connect(&saddr)?.shutdown()?;
        ssrv.wait()?;
        println!("[loadgen] synchronous baseline: {:.1} steps/s", rep.steps_per_s);
        Some(rep.steps_per_s)
    } else {
        None
    };

    println!(
        "[loadgen] {} client(s) × {} steps on {} against {} ({} shard(s), optimizer {})",
        opts.clients,
        steps,
        opts.model,
        addr,
        opts.shards,
        cfg.optimizer.name()
    );
    if opts.staleness > 0 {
        println!(
            "[loadgen] async mode: staleness window {} step(s){}",
            opts.staleness,
            opts.commit_log
                .as_deref()
                .map(|p| format!(", commit log -> {p}"))
                .unwrap_or_default()
        );
    }
    // A resumed server sits past step 0 — start where it left off (the
    // gradient-noise streams fast-forward to match).
    let start_step = srv::Client::connect(&addr)?.stats()?.step + 1;
    if check && start_step > 1 {
        bail!(
            "--check compares against a from-scratch reference trainer, but the server \
             is already at step {} — re-run without --resume/--check together",
            start_step - 1
        );
    }
    let lopts = srv::LoadgenOptions {
        clients: opts.clients,
        steps,
        start_step,
        slow_client_ms,
        drop_client_at,
    };
    let report = {
        use std::sync::atomic::{AtomicBool, Ordering};
        let done = AtomicBool::new(false);
        let server_ref = server.as_ref();
        std::thread::scope(|s| -> Result<srv::LoadgenReport> {
            // Chaos harness: poll the server's applied step from a side
            // connection and kill shard 0's worker thread once the run
            // passes --kill-shard. Recovery happens mid-run, under load.
            let killer = (kill_shard_at > 0).then(|| {
                let done = &done;
                let addr = addr.clone();
                s.spawn(move || {
                    let Ok(mut c) = srv::Client::connect(&addr) else { return };
                    while !done.load(Ordering::SeqCst) {
                        match c.stats() {
                            Ok(st) if st.step >= kill_shard_at => {
                                if let Some(sv) = server_ref {
                                    sv.kill_shard(0);
                                }
                                return;
                            }
                            Ok(_) => std::thread::sleep(std::time::Duration::from_millis(2)),
                            Err(_) => return,
                        }
                    }
                })
            });
            let r = srv::run_loadgen(&addr, &shapes, cfg.seed, &lopts);
            done.store(true, Ordering::SeqCst);
            if let Some(k) = killer {
                let _ = k.join();
            }
            r
        })?
    };

    // Control connection: snapshot + stats, then stop a self-spawned
    // server (an external server keeps running).
    let mut ctl = srv::Client::connect(&addr)?;
    let snap_bytes = match &snapshot {
        Some(path) => Some(ctl.snapshot(path)?),
        None => None,
    };
    let stats = ctl.stats()?;
    if server.is_some() {
        ctl.shutdown()?;
    }
    if let Some(s) = server {
        s.wait()?;
    }

    println!(
        "[loadgen] {} steps in {:.2}s — {:.1} steps/s; push latency p50 {:.3} ms  p99 {:.3} ms  mean {:.3} ms",
        report.steps, report.elapsed_s, report.steps_per_s, report.push_p50_ms,
        report.push_p99_ms, report.push_mean_ms
    );
    println!(
        "[loadgen] {} pushes accepted, {} busy retries (client), {} busy bounces (server), final loss {:.4}",
        report.pushes, report.busy_retries, stats.busy, report.final_loss
    );
    println!(
        "[loadgen] wire traffic: {} per applied step (all clients, both directions)",
        smmf_repro::util::fmt::bytes(report.bytes_per_step as u64)
    );
    // The one-line digest: the four numbers a dashboard (or a PR diff)
    // wants, in one greppable place.
    println!(
        "[loadgen] digest: {:.1} steps/s | push p50/p99 {:.3}/{:.3} ms | {}/step | {} busy retries",
        report.steps_per_s,
        report.push_p50_ms,
        report.push_p99_ms,
        smmf_repro::util::fmt::bytes(report.bytes_per_step as u64),
        report.busy_retries
    );
    if faults {
        println!(
            "[loadgen] faults: {} client(s) evicted, {} eviction(s) server-side, \
             {} shard respawn(s) ({} ms recovering), final epoch {}",
            report.evicted, stats.evictions, stats.respawns, stats.recovery_ms, stats.epoch
        );
    }
    if let Some(h) = healthy_steps_per_s {
        println!(
            "[loadgen] degraded {:.1} steps/s vs healthy {:.1} steps/s ({:.0}% of healthy)",
            report.steps_per_s,
            h,
            100.0 * report.steps_per_s / h.max(1e-12)
        );
    }
    if let Some(sy) = sync_steps_per_s {
        println!(
            "[loadgen] async {:.1} steps/s vs synchronous {:.1} steps/s ({:.2}x)",
            report.steps_per_s,
            sy,
            report.steps_per_s / sy.max(1e-12)
        );
    }
    if kill_shard_at > 0 && stats.respawns == 0 {
        bail!(
            "--kill-shard {kill_shard_at} was requested but the server reports no \
             respawns — the kill never landed (did the run end before step \
             {kill_shard_at}?)"
        );
    }
    // (Eviction lands at drop + 1, so it only exists when the run has a
    // step after the drop.)
    if drop_client_at > 0 && drop_client_at < start_step + steps - 1 && stats.evictions == 0 {
        bail!(
            "--drop-client {drop_client_at} was requested but the server reports no \
             evictions — the drop never landed"
        );
    }
    if let (Some(path), Some(bytes)) = (&snapshot, snap_bytes) {
        let locus = if external.is_some() { " on the server host" } else { "" };
        println!("[loadgen] snapshot -> {path}{locus} ({} bytes, SMMFCKPT v2)", bytes);
    }

    let bench_path = args.str_or("bench-json", &default_server_bench());
    let mut sink = JsonSink::new("server_loadgen", &bench_path);
    let mut record = ObjBuilder::new()
        .str("name", &format!("loadgen/{inv_name}"))
        .str("model", &opts.model)
        .str("optimizer", cfg.optimizer.name())
        .num("shards", opts.shards as f64)
        .num("clients", opts.clients as f64)
        .num("steps", report.steps as f64)
        .num("steps_per_s", report.steps_per_s)
        .num("bytes_per_step", report.bytes_per_step)
        .num("push_p50_ms", report.push_p50_ms)
        .num("push_p99_ms", report.push_p99_ms)
        .num("push_mean_ms", report.push_mean_ms)
        .num("pushes", report.pushes as f64)
        .num("busy", stats.busy as f64)
        .num("final_loss", report.final_loss as f64)
        .num("epoch", stats.epoch as f64)
        .num("evictions", stats.evictions as f64)
        .num("respawns", stats.respawns as f64)
        .num("recovery_ms", stats.recovery_ms as f64)
        .num("staleness", opts.staleness as f64);
    if let Some(h) = healthy_steps_per_s {
        record = record.num("healthy_steps_per_s", h);
    }
    if let Some(sy) = sync_steps_per_s {
        record = record.num("sync_steps_per_s", sy);
    }
    sink.push(record.build());
    sink.write()?;
    println!("[loadgen] bench record -> {bench_path}");

    if check {
        let snap = snapshot.as_ref().expect("--check implies a snapshot path");
        let ref_path = format!("{snap}.ref");
        // Under --drop-client the membership schedule is deterministic
        // (eviction lands exactly at drop + 1), so the oracle is the
        // elastic reference trainer over that schedule.
        let ref_loss = if drop_client_at > 0 {
            let all: Vec<u32> = (0..opts.clients as u32).collect();
            let survivors: Vec<u32> = (0..opts.clients as u32 - 1).collect();
            srv::reference_checkpoint_elastic(
                &cfg,
                &opts.model,
                &[(1, all), (drop_client_at + 1, survivors)],
                steps,
                Path::new(&ref_path),
            )?
        } else {
            srv::reference_checkpoint(&cfg, &opts.model, opts.clients, steps, Path::new(&ref_path))?
        };
        let got = std::fs::read(snap)?;
        let want = std::fs::read(&ref_path)?;
        if got != want {
            bail!(
                "determinism contract broken: snapshot {snap} ({} bytes) differs from the \
                 single-process reference {ref_path} ({} bytes)",
                got.len(),
                want.len()
            );
        }
        if ref_loss.to_bits() != report.final_loss.to_bits() {
            bail!(
                "loadgen final loss {} != reference final loss {ref_loss}",
                report.final_loss
            );
        }
        std::fs::remove_file(&ref_path).ok();
        if snapshot_was_temp {
            std::fs::remove_file(snap).ok();
        }
        println!(
            "[loadgen] check OK: {}-shard × {}-client snapshot is bit-identical to the \
             single-process reference trainer",
            opts.shards, opts.clients
        );
    }
    Ok(())
}

fn cmd_replay(args: &Args) -> Result<()> {
    use smmf_repro::server as srv;
    let log = args
        .positional
        .first()
        .map(String::as_str)
        .or_else(|| args.opt("log"))
        .ok_or_else(|| {
            anyhow!("usage: repro replay <commits.bin> [--shards K] [--snapshot OUT.bin]")
        })?;
    let cfg = base_config(args)?;
    let shards = args.count_or("shards", 1).map_err(|e| anyhow!(e))?;
    let out = args.str_or("snapshot", &format!("{log}.replay.bin"));
    let rep = srv::replay_commit_log(&cfg, Path::new(log), shards, Path::new(&out))?;
    println!(
        "[replay] {} commit(s) from {log} re-applied on {} shard(s) ({}, optimizer {}) — \
         final step {}",
        rep.commits,
        shards,
        rep.model,
        cfg.optimizer.name(),
        rep.final_step
    );
    println!("[replay] snapshot -> {out} ({} bytes, SMMFCKPT v2)", rep.snapshot_bytes);
    Ok(())
}

fn cmd_fused(args: &Args) -> Result<()> {
    let rt = Runtime::open(artifacts_dir(args))?;
    let name = args.str_or("artifact", "mlp_smmf_step");
    let steps = args.u64_or("steps", 50);
    let mut fused = FusedSmmfStep::load(&rt, &name, args.u64_or("seed", 0))?;
    let mut source = exp::BatchSource::for_spec(fused.spec(), 1)?;
    println!(
        "[fused] {} — whole train step (fwd+bwd+SMMF w/ Pallas kernel) compiled into one XLA program",
        name
    );
    let t0 = std::time::Instant::now();
    let (mut first, mut last) = (f32::NAN, f32::NAN);
    for step in 1..=steps {
        let batch = source.next()?;
        let loss = fused.train_step(&batch)?;
        if step == 1 {
            first = loss;
        }
        last = loss;
        if step % 10 == 0 || step == 1 {
            println!("  step {step:>4}: loss {loss:.4}");
        }
    }
    let ms = t0.elapsed().as_secs_f64() * 1e3 / steps as f64;
    println!(
        "[fused] loss {first:.4} -> {last:.4} over {steps} steps, {ms:.1} ms/step, state {} (PRED sign = the paper's 8-bit S_M variant)",
        fmt::bytes(fused.state_bytes())
    );
    if last >= first {
        bail!("fused path did not reduce the loss");
    }
    Ok(())
}
