//! Gradient coalescing: the per-step barrier that makes a K-shard,
//! N-client server bit-identical to the single-process trainer.
//!
//! Clients push complete gradient sets tagged with `(client id, step)`.
//! The [`StepBatcher`] holds them until every client `0..N` has pushed
//! for the current step (the *step barrier*), then combines them into
//! one coalesced gradient by accumulating `(1/N)·g_c` **in ascending
//! client-id order** onto a zero buffer. Floating-point addition is not
//! associative, so pinning the reduction order — rather than coalescing
//! in arrival order — is what makes the applied step independent of
//! network timing: any interleaving of pushes produces the same bits.
//! The single-process reference trainer
//! (`server::service::reference_checkpoint`) performs the identical
//! reduction, which is what the snapshot bit-identity e2e asserts.
//!
//! The batcher is pure bookkeeping (no threads, no IO), so the barrier
//! logic is unit-testable in isolation.

use crate::tensor::Tensor;

/// Outcome of offering one client push to the current step's barrier.
#[derive(Debug, PartialEq)]
pub enum Offer {
    /// Stored; the barrier still waits for other clients.
    Accepted,
    /// Stored, and this push completed the barrier — the caller must now
    /// [`StepBatcher::take_coalesced`] and apply the step.
    Completed,
    /// Rejected (unknown client, wrong step, duplicate, bad shapes); the
    /// barrier state is unchanged.
    Rejected(String),
}

/// Accumulates per-client gradient pushes for one step at a time.
pub struct StepBatcher {
    n_clients: usize,
    shapes: Vec<Vec<usize>>,
    /// The step currently being assembled (first step is 1).
    step: u64,
    pending: Vec<Option<Vec<Tensor>>>,
    received: usize,
}

impl StepBatcher {
    /// A barrier over clients `0..n_clients` pushing gradients for the
    /// given tensor shapes (inventory registration order).
    pub fn new(n_clients: usize, shapes: Vec<Vec<usize>>) -> StepBatcher {
        assert!(n_clients >= 1, "barrier needs at least one client");
        StepBatcher {
            n_clients,
            shapes,
            step: 1,
            pending: (0..n_clients).map(|_| None).collect(),
            received: 0,
        }
    }

    /// The step currently being assembled (= applied steps + 1).
    pub fn pending_step(&self) -> u64 {
        self.step
    }

    /// Steps fully applied so far.
    pub fn applied_step(&self) -> u64 {
        self.step - 1
    }

    /// Offer client `client`'s gradient set for `step`. Flat per-tensor
    /// data is validated against the inventory shapes before it is
    /// stored.
    pub fn offer(&mut self, client: u32, step: u64, grads: Vec<Vec<f32>>) -> Offer {
        let c = client as usize;
        if c >= self.n_clients {
            return Offer::Rejected(format!(
                "unknown client {client} (barrier width {})",
                self.n_clients
            ));
        }
        if step != self.step {
            return Offer::Rejected(format!(
                "push for step {step}, server is assembling step {}",
                self.step
            ));
        }
        if self.pending[c].is_some() {
            return Offer::Rejected(format!("client {client} already pushed for step {step}"));
        }
        if grads.len() != self.shapes.len() {
            return Offer::Rejected(format!(
                "push holds {} tensors, inventory has {}",
                grads.len(),
                self.shapes.len()
            ));
        }
        let mut tensors = Vec::with_capacity(grads.len());
        for (i, (data, shape)) in grads.into_iter().zip(&self.shapes).enumerate() {
            let numel: usize = shape.iter().product();
            if data.len() != numel {
                return Offer::Rejected(format!(
                    "tensor {i}: push holds {} elements, shape {shape:?} needs {numel}",
                    data.len()
                ));
            }
            tensors.push(Tensor::from_vec(shape, data));
        }
        self.pending[c] = Some(tensors);
        self.received += 1;
        if self.received == self.n_clients {
            Offer::Completed
        } else {
            Offer::Accepted
        }
    }

    /// Drain the completed barrier into the coalesced gradient
    /// (`Σ_c g_c / N`, accumulated in ascending client-id order) and
    /// advance to the next step. Panics if the barrier is incomplete —
    /// callers only reach this after [`Offer::Completed`].
    pub fn take_coalesced(&mut self) -> Vec<Tensor> {
        assert_eq!(self.received, self.n_clients, "barrier incomplete");
        let scale = 1.0 / self.n_clients as f32;
        let mut out: Vec<Tensor> = self.shapes.iter().map(|s| Tensor::zeros(s)).collect();
        for slot in self.pending.iter_mut() {
            let grads = slot.take().expect("complete barrier has every slot");
            for (acc, g) in out.iter_mut().zip(&grads) {
                acc.axpy(scale, g);
            }
        }
        self.received = 0;
        self.step += 1;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shapes() -> Vec<Vec<usize>> {
        vec![vec![2, 2], vec![3]]
    }

    fn grads_for(c: u32) -> Vec<Vec<f32>> {
        let b = c as f32;
        vec![vec![b, b + 0.5, -b, 1.0], vec![0.25 * b, -1.0, b]]
    }

    #[test]
    fn barrier_completes_and_coalesces_in_client_order() {
        let mut b = StepBatcher::new(3, shapes());
        assert_eq!(b.pending_step(), 1);
        assert_eq!(b.applied_step(), 0);
        // arrival order 2, 0, 1 — must not matter
        assert_eq!(b.offer(2, 1, grads_for(2)), Offer::Accepted);
        assert_eq!(b.offer(0, 1, grads_for(0)), Offer::Accepted);
        assert_eq!(b.offer(1, 1, grads_for(1)), Offer::Completed);
        let out = b.take_coalesced();
        assert_eq!(b.pending_step(), 2);

        // reference reduction: fixed client order 0, 1, 2
        let mut want: Vec<Tensor> = shapes().iter().map(|s| Tensor::zeros(s)).collect();
        for c in 0..3u32 {
            let g = grads_for(c);
            for (w, (data, shape)) in want.iter_mut().zip(g.iter().zip(shapes().iter())) {
                w.axpy(1.0 / 3.0, &Tensor::from_vec(shape, data.clone()));
            }
        }
        assert_eq!(out, want);
    }

    #[test]
    fn arrival_order_never_changes_the_bits() {
        let orders: [[u32; 3]; 3] = [[0, 1, 2], [2, 1, 0], [1, 2, 0]];
        let mut results = Vec::new();
        for order in orders {
            let mut b = StepBatcher::new(3, shapes());
            for &c in &order[..2] {
                assert_eq!(b.offer(c, 1, grads_for(c)), Offer::Accepted);
            }
            assert_eq!(b.offer(order[2], 1, grads_for(order[2])), Offer::Completed);
            results.push(b.take_coalesced());
        }
        assert_eq!(results[0], results[1]);
        assert_eq!(results[1], results[2]);
    }

    #[test]
    fn rejects_bad_pushes_without_disturbing_the_barrier() {
        let mut b = StepBatcher::new(2, shapes());
        assert_eq!(b.offer(0, 1, grads_for(0)), Offer::Accepted);
        // duplicate client
        assert!(matches!(b.offer(0, 1, grads_for(0)), Offer::Rejected(_)));
        // unknown client
        assert!(matches!(b.offer(9, 1, grads_for(1)), Offer::Rejected(_)));
        // wrong step
        assert!(matches!(b.offer(1, 2, grads_for(1)), Offer::Rejected(_)));
        // wrong tensor count
        assert!(matches!(b.offer(1, 1, vec![vec![1.0]]), Offer::Rejected(_)));
        // wrong element count
        let mut bad = grads_for(1);
        bad[1].pop();
        assert!(matches!(b.offer(1, 1, bad), Offer::Rejected(_)));
        // the good push still completes the barrier
        assert_eq!(b.offer(1, 1, grads_for(1)), Offer::Completed);
        b.take_coalesced();
        // next step accepts the same clients again
        assert_eq!(b.offer(0, 2, grads_for(0)), Offer::Accepted);
    }

    #[test]
    fn single_client_barrier_is_immediate() {
        let mut b = StepBatcher::new(1, shapes());
        assert_eq!(b.offer(0, 1, grads_for(5)), Offer::Completed);
        let out = b.take_coalesced();
        // N = 1: coalesced = 0 + 1.0 * g
        let want: Vec<Tensor> = grads_for(5)
            .into_iter()
            .zip(shapes())
            .map(|(d, s)| {
                let mut t = Tensor::zeros(&s);
                t.axpy(1.0, &Tensor::from_vec(&s, d));
                t
            })
            .collect();
        assert_eq!(out, want);
    }
}
