//! Gradient coalescing: the per-step barrier that makes a K-shard,
//! N-client server bit-identical to the single-process trainer.
//!
//! Clients push complete gradient sets tagged with `(client id, step)`.
//! Under wire protocol v4 a "push" arrives at the connection handler as
//! a `PushBegin` → chunk → `StreamEnd` stream and is reassembled into
//! the whole-tensor set *before* it reaches this module — the batcher
//! is deliberately chunking-blind, so the determinism argument below is
//! untouched by how the bytes crossed the wire.
//! The [`StepBatcher`] holds them until every *member* of the current
//! epoch has pushed for the current step (the *step barrier*), then
//! combines them into one coalesced gradient by accumulating
//! `(1/width)·g_c` **in ascending client-id order** onto a zero buffer.
//! Floating-point addition is not associative, so pinning the reduction
//! order — rather than coalescing in arrival order — is what makes the
//! applied step independent of network timing: any interleaving of
//! pushes produces the same bits. The single-process reference trainer
//! (`server::service::reference_checkpoint_elastic`) performs the
//! identical reduction over the identical membership schedule, which is
//! what the snapshot bit-identity e2e asserts.
//!
//! Membership is elastic: [`StepBatcher::join`] and
//! [`StepBatcher::leave`] restructure the barrier between (or during)
//! steps, and [`StepBatcher::evict_unpushed`] removes every member that
//! has not pushed for the assembling step — the deadline path that
//! keeps one stalled client from wedging the world. The epoch counter
//! itself lives in the coordinator (`service.rs`); the batcher is pure
//! bookkeeping (no threads, no IO), so the barrier logic is
//! unit-testable in isolation.
//!
//! The [`AsyncAccumulator`] is the bounded-staleness alternative to the
//! barrier (`[server] staleness = S`, S >= 1): it accepts a gradient
//! whenever its `base_step` — the applied step the client computed it
//! against — is at most S steps behind the current applied step, and
//! commits *whatever is pending* as one partial batch per
//! [`AsyncAccumulator::take_commit`] call. Within a commit the
//! contributions are still coalesced in ascending member-id order, so
//! the committed bits depend only on *which* members contributed —
//! never on arrival order — which is what lets the ordered commit log
//! (`server::commitlog`) replay an async run bit-identically.

use crate::tensor::Tensor;

/// Validate a flat pushed gradient set against the inventory shapes and
/// build the tensors — shared by the barrier and the async accumulator
/// so both ingestion modes reject malformed pushes identically.
fn validate_grads(shapes: &[Vec<usize>], grads: Vec<Vec<f32>>) -> Result<Vec<Tensor>, String> {
    if grads.len() != shapes.len() {
        return Err(format!(
            "push holds {} tensors, inventory has {}",
            grads.len(),
            shapes.len()
        ));
    }
    let mut tensors = Vec::with_capacity(grads.len());
    for (i, (data, shape)) in grads.into_iter().zip(shapes).enumerate() {
        let numel: usize = shape.iter().product();
        if data.len() != numel {
            return Err(format!(
                "tensor {i}: push holds {} elements, shape {shape:?} needs {numel}",
                data.len()
            ));
        }
        tensors.push(Tensor::from_vec(shape, data));
    }
    Ok(tensors)
}

/// Outcome of offering one client push to the current step's barrier.
#[derive(Debug, PartialEq)]
pub enum Offer {
    /// Stored; the barrier still waits for other members.
    Accepted,
    /// Stored, and this push completed the barrier — the caller must now
    /// [`StepBatcher::take_coalesced`] and apply the step.
    Completed,
    /// Rejected (non-member, wrong step, duplicate, bad shapes); the
    /// barrier state is unchanged.
    Rejected(String),
}

/// Outcome of a member leaving mid-barrier.
#[derive(Debug, PartialEq)]
pub struct LeaveOutcome {
    /// The departing member had a pending (un-coalesced) push that was
    /// discarded — its deferred reply must be failed by the caller.
    pub had_pending: bool,
    /// Removing the member completed the barrier for the remaining
    /// members — the caller must now [`StepBatcher::take_coalesced`].
    pub completed: bool,
}

/// Accumulates per-member gradient pushes for one step at a time.
pub struct StepBatcher {
    /// Barrier members, ascending client id (the reduction order).
    members: Vec<u32>,
    shapes: Vec<Vec<usize>>,
    /// The step currently being assembled (first step is 1).
    step: u64,
    /// Pending push per member, parallel to `members`.
    pending: Vec<Option<Vec<Tensor>>>,
    received: usize,
}

impl StepBatcher {
    /// A barrier over clients `0..n_clients` pushing gradients for the
    /// given tensor shapes (inventory registration order).
    pub fn new(n_clients: usize, shapes: Vec<Vec<usize>>) -> StepBatcher {
        StepBatcher::with_members((0..n_clients as u32).collect(), shapes, 1)
    }

    /// A barrier over an explicit member set, assembling `first_step`
    /// next (a resumed server starts past 1). Members must be distinct;
    /// they are kept in ascending id order.
    pub fn with_members(
        mut members: Vec<u32>,
        shapes: Vec<Vec<usize>>,
        first_step: u64,
    ) -> StepBatcher {
        assert!(!members.is_empty(), "barrier needs at least one member");
        assert!(first_step >= 1, "steps are 1-based");
        members.sort_unstable();
        assert!(members.windows(2).all(|w| w[0] < w[1]), "duplicate member ids");
        let pending = members.iter().map(|_| None).collect();
        StepBatcher { members, shapes, step: first_step, pending, received: 0 }
    }

    /// Current members, ascending client id.
    pub fn members(&self) -> &[u32] {
        &self.members
    }

    /// Barrier width (= member count).
    pub fn width(&self) -> usize {
        self.members.len()
    }

    /// Pushes stored for the assembling step so far.
    pub fn received(&self) -> usize {
        self.received
    }

    /// The step currently being assembled (= applied steps + 1).
    pub fn pending_step(&self) -> u64 {
        self.step
    }

    /// Steps fully applied so far.
    pub fn applied_step(&self) -> u64 {
        self.step - 1
    }

    /// Offer member `client`'s gradient set for `step`. Flat per-tensor
    /// data is validated against the inventory shapes before it is
    /// stored.
    pub fn offer(&mut self, client: u32, step: u64, grads: Vec<Vec<f32>>) -> Offer {
        let Ok(slot) = self.members.binary_search(&client) else {
            return Offer::Rejected(format!(
                "client {client} is not a member of the barrier (width {})",
                self.members.len()
            ));
        };
        if step != self.step {
            return Offer::Rejected(format!(
                "push for step {step}, server is assembling step {}",
                self.step
            ));
        }
        if self.pending[slot].is_some() {
            return Offer::Rejected(format!("client {client} already pushed for step {step}"));
        }
        let tensors = match validate_grads(&self.shapes, grads) {
            Ok(t) => t,
            Err(msg) => return Offer::Rejected(msg),
        };
        self.pending[slot] = Some(tensors);
        self.received += 1;
        if self.received == self.members.len() {
            Offer::Completed
        } else {
            Offer::Accepted
        }
    }

    /// Add a member to the barrier (effective for the assembling step:
    /// the widened barrier now also waits on the joiner). Errs on a
    /// duplicate id.
    pub fn join(&mut self, client: u32) -> Result<(), String> {
        match self.members.binary_search(&client) {
            Ok(_) => Err(format!("client {client} is already a member")),
            Err(slot) => {
                self.members.insert(slot, client);
                self.pending.insert(slot, None);
                Ok(())
            }
        }
    }

    /// Remove a member; any pending push it had for the assembling step
    /// is discarded. Errs on a non-member or when it is the last member
    /// (an empty barrier can never complete — the caller keeps the world
    /// at width >= 1).
    pub fn leave(&mut self, client: u32) -> Result<LeaveOutcome, String> {
        let slot = self
            .members
            .binary_search(&client)
            .map_err(|_| format!("client {client} is not a member"))?;
        if self.members.len() == 1 {
            return Err(format!("client {client} is the last member — the barrier cannot empty"));
        }
        self.members.remove(slot);
        let had_pending = self.pending.remove(slot).is_some();
        if had_pending {
            self.received -= 1;
        }
        let completed = self.received > 0 && self.received == self.members.len();
        Ok(LeaveOutcome { had_pending, completed })
    }

    /// Evict every member that has NOT pushed for the assembling step
    /// (the `client_timeout_ms` deadline path). Requires at least one
    /// pending push — afterwards the barrier is complete over the
    /// survivors. Returns the evicted ids, ascending.
    pub fn evict_unpushed(&mut self) -> Vec<u32> {
        assert!(self.received >= 1, "eviction needs at least one pushed member to survive");
        let mut evicted = Vec::new();
        let mut keep_members = Vec::with_capacity(self.received);
        let mut keep_pending = Vec::with_capacity(self.received);
        for (m, p) in self.members.drain(..).zip(self.pending.drain(..)) {
            if p.is_some() {
                keep_members.push(m);
                keep_pending.push(p);
            } else {
                evicted.push(m);
            }
        }
        self.members = keep_members;
        self.pending = keep_pending;
        debug_assert_eq!(self.received, self.members.len());
        evicted
    }

    /// Drain the completed barrier into the coalesced gradient
    /// (`Σ_c g_c / width`, accumulated in ascending client-id order) and
    /// advance to the next step. Panics if the barrier is incomplete —
    /// callers only reach this after [`Offer::Completed`] (or a
    /// completing leave/eviction).
    pub fn take_coalesced(&mut self) -> Vec<Tensor> {
        let _span = crate::obs::trace::span("server", "server.coalesce");
        assert_eq!(self.received, self.members.len(), "barrier incomplete");
        let scale = 1.0 / self.members.len() as f32;
        let mut out: Vec<Tensor> = self.shapes.iter().map(|s| Tensor::zeros(s)).collect();
        for slot in self.pending.iter_mut() {
            let grads = slot.take().expect("complete barrier has every slot");
            for (acc, g) in out.iter_mut().zip(&grads) {
                acc.axpy(scale, g);
            }
        }
        self.received = 0;
        self.step += 1;
        out
    }
}

/// Outcome of offering one client push to the async accumulator.
#[derive(Debug, PartialEq)]
pub enum AsyncOffer {
    /// Stored; the contribution will ride the next commit.
    Accepted,
    /// The gradient's `base_step` is more than `staleness` steps behind
    /// the `applied` step — the client must re-pull (any step >=
    /// `required`) and recompute.
    TooStale { applied: u64, required: u64 },
    /// Rejected (non-member, duplicate pending, bad shapes, or a
    /// `base_step` the server has not reached); state unchanged.
    Rejected(String),
}

/// Bounded-staleness gradient accumulator: the async alternative to the
/// [`StepBatcher`] barrier.
///
/// Contributions pile up in `pending` as they arrive;
/// [`AsyncAccumulator::take_commit`] drains them all as one partial
/// batch (sorted by ascending member id) and advances the step. The
/// staleness check happens at offer time against the *applied* step, so
/// the lag recorded in the commit log obeys
/// `commit.step - 1 - base_step <= staleness` for every contributor —
/// the invariant `commitlog::CommitLog::max_lag` exposes.
pub struct AsyncAccumulator {
    /// Members, ascending client id (commit reduction order).
    members: Vec<u32>,
    shapes: Vec<Vec<usize>>,
    /// The step the next commit will apply (first step is 1).
    step: u64,
    staleness: u64,
    /// Contributions awaiting the next commit, arrival order:
    /// `(client, base_step, grads)`.
    pending: Vec<(u32, u64, Vec<Tensor>)>,
}

impl AsyncAccumulator {
    /// An accumulator over an explicit member set with window
    /// `staleness >= 1`, committing `first_step` next (a resumed server
    /// starts past 1).
    pub fn with_members(
        mut members: Vec<u32>,
        shapes: Vec<Vec<usize>>,
        staleness: u64,
        first_step: u64,
    ) -> AsyncAccumulator {
        assert!(staleness >= 1, "staleness 0 is the synchronous barrier (StepBatcher)");
        assert!(!members.is_empty(), "async ingestion needs at least one member");
        assert!(first_step >= 1, "steps are 1-based");
        members.sort_unstable();
        assert!(members.windows(2).all(|w| w[0] < w[1]), "duplicate member ids");
        AsyncAccumulator { members, shapes, step: first_step, staleness, pending: Vec::new() }
    }

    /// Current members, ascending client id.
    pub fn members(&self) -> &[u32] {
        &self.members
    }

    /// Member count.
    pub fn width(&self) -> usize {
        self.members.len()
    }

    /// Contributions awaiting the next commit.
    pub fn pending(&self) -> usize {
        self.pending.len()
    }

    /// The step the next commit will apply.
    pub fn pending_step(&self) -> u64 {
        self.step
    }

    /// Steps fully applied so far.
    pub fn applied_step(&self) -> u64 {
        self.step - 1
    }

    /// Offer member `client`'s gradient set computed against applied
    /// step `base_step`. Checks run cheapest-first — membership,
    /// duplicate pending, future base, staleness window — so a
    /// [`AsyncOffer::TooStale`] reply is issued *before* the tensor
    /// payload is validated or copied.
    pub fn offer(&mut self, client: u32, base_step: u64, grads: Vec<Vec<f32>>) -> AsyncOffer {
        if self.members.binary_search(&client).is_err() {
            return AsyncOffer::Rejected(format!(
                "client {client} is not a member of the server ({} member(s))",
                self.members.len()
            ));
        }
        if self.pending.iter().any(|(c, ..)| *c == client) {
            return AsyncOffer::Rejected(format!(
                "client {client} already has a contribution pending for the next commit"
            ));
        }
        let applied = self.applied_step();
        if base_step > applied {
            return AsyncOffer::Rejected(format!(
                "gradient claims base step {base_step}, server has applied only {applied}"
            ));
        }
        if applied - base_step > self.staleness {
            return AsyncOffer::TooStale { applied, required: applied - self.staleness };
        }
        match validate_grads(&self.shapes, grads) {
            Ok(tensors) => {
                self.pending.push((client, base_step, tensors));
                AsyncOffer::Accepted
            }
            Err(msg) => AsyncOffer::Rejected(msg),
        }
    }

    /// Add a member. Errs on a duplicate id.
    pub fn join(&mut self, client: u32) -> Result<(), String> {
        match self.members.binary_search(&client) {
            Ok(_) => Err(format!("client {client} is already a member")),
            Err(slot) => {
                self.members.insert(slot, client);
                Ok(())
            }
        }
    }

    /// Remove a member, discarding any pending contribution it had;
    /// returns whether one was discarded (its deferred reply must be
    /// failed by the caller). Errs on a non-member or the last member.
    pub fn leave(&mut self, client: u32) -> Result<bool, String> {
        let slot = self
            .members
            .binary_search(&client)
            .map_err(|_| format!("client {client} is not a member"))?;
        if self.members.len() == 1 {
            return Err(format!("client {client} is the last member — the server cannot empty"));
        }
        self.members.remove(slot);
        let before = self.pending.len();
        self.pending.retain(|(c, ..)| *c != client);
        Ok(self.pending.len() != before)
    }

    /// Drain every pending contribution as the next commit — sorted by
    /// ascending member id, the order `shard::coalesce_commit` reduces
    /// in — and advance the step. `None` when nothing is pending (no
    /// empty commits: the step only advances when gradients applied).
    pub fn take_commit(&mut self) -> Option<Vec<(u32, u64, Vec<Tensor>)>> {
        if self.pending.is_empty() {
            return None;
        }
        let _span = crate::obs::trace::span("server", "server.coalesce");
        let mut commit = std::mem::take(&mut self.pending);
        commit.sort_by_key(|(c, ..)| *c);
        self.step += 1;
        Some(commit)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shapes() -> Vec<Vec<usize>> {
        vec![vec![2, 2], vec![3]]
    }

    fn grads_for(c: u32) -> Vec<Vec<f32>> {
        let b = c as f32;
        vec![vec![b, b + 0.5, -b, 1.0], vec![0.25 * b, -1.0, b]]
    }

    /// Fixed-order reference reduction over an explicit member set.
    fn reference(members: &[u32]) -> Vec<Tensor> {
        let scale = 1.0 / members.len() as f32;
        let mut want: Vec<Tensor> = shapes().iter().map(|s| Tensor::zeros(s)).collect();
        for &c in members {
            let g = grads_for(c);
            for (w, (data, shape)) in want.iter_mut().zip(g.iter().zip(shapes().iter())) {
                w.axpy(scale, &Tensor::from_vec(shape, data.clone()));
            }
        }
        want
    }

    #[test]
    fn barrier_completes_and_coalesces_in_client_order() {
        let mut b = StepBatcher::new(3, shapes());
        assert_eq!(b.pending_step(), 1);
        assert_eq!(b.applied_step(), 0);
        assert_eq!(b.members(), &[0, 1, 2]);
        // arrival order 2, 0, 1 — must not matter
        assert_eq!(b.offer(2, 1, grads_for(2)), Offer::Accepted);
        assert_eq!(b.offer(0, 1, grads_for(0)), Offer::Accepted);
        assert_eq!(b.offer(1, 1, grads_for(1)), Offer::Completed);
        let out = b.take_coalesced();
        assert_eq!(b.pending_step(), 2);
        assert_eq!(out, reference(&[0, 1, 2]));
    }

    #[test]
    fn arrival_order_never_changes_the_bits() {
        let orders: [[u32; 3]; 3] = [[0, 1, 2], [2, 1, 0], [1, 2, 0]];
        let mut results = Vec::new();
        for order in orders {
            let mut b = StepBatcher::new(3, shapes());
            for &c in &order[..2] {
                assert_eq!(b.offer(c, 1, grads_for(c)), Offer::Accepted);
            }
            assert_eq!(b.offer(order[2], 1, grads_for(order[2])), Offer::Completed);
            results.push(b.take_coalesced());
        }
        assert_eq!(results[0], results[1]);
        assert_eq!(results[1], results[2]);
    }

    #[test]
    fn rejects_bad_pushes_without_disturbing_the_barrier() {
        let mut b = StepBatcher::new(2, shapes());
        assert_eq!(b.offer(0, 1, grads_for(0)), Offer::Accepted);
        // duplicate client
        assert!(matches!(b.offer(0, 1, grads_for(0)), Offer::Rejected(_)));
        // non-member
        assert!(matches!(b.offer(9, 1, grads_for(1)), Offer::Rejected(_)));
        // wrong step
        assert!(matches!(b.offer(1, 2, grads_for(1)), Offer::Rejected(_)));
        // wrong tensor count
        assert!(matches!(b.offer(1, 1, vec![vec![1.0]]), Offer::Rejected(_)));
        // wrong element count
        let mut bad = grads_for(1);
        bad[1].pop();
        assert!(matches!(b.offer(1, 1, bad), Offer::Rejected(_)));
        // the good push still completes the barrier
        assert_eq!(b.offer(1, 1, grads_for(1)), Offer::Completed);
        b.take_coalesced();
        // next step accepts the same clients again
        assert_eq!(b.offer(0, 2, grads_for(0)), Offer::Accepted);
    }

    #[test]
    fn single_client_barrier_is_immediate() {
        let mut b = StepBatcher::new(1, shapes());
        assert_eq!(b.offer(0, 1, grads_for(5)), Offer::Completed);
        let out = b.take_coalesced();
        // width = 1: coalesced = 0 + 1.0 * g
        let want: Vec<Tensor> = grads_for(5)
            .into_iter()
            .zip(shapes())
            .map(|(d, s)| {
                let mut t = Tensor::zeros(&s);
                t.axpy(1.0, &Tensor::from_vec(&s, d));
                t
            })
            .collect();
        assert_eq!(out, want);
    }

    #[test]
    fn join_widens_the_assembling_barrier() {
        let mut b = StepBatcher::with_members(vec![0, 2], shapes(), 1);
        assert_eq!(b.offer(0, 1, grads_for(0)), Offer::Accepted);
        assert_eq!(b.offer(2, 1, grads_for(2)), Offer::Completed);
        b.take_coalesced();
        // joiner takes the freed id slot the coordinator assigns
        b.join(1).unwrap();
        assert_eq!(b.members(), &[0, 1, 2]);
        assert!(b.join(1).is_err(), "duplicate join must be rejected");
        // the widened barrier waits on all three
        assert_eq!(b.offer(0, 2, grads_for(0)), Offer::Accepted);
        assert_eq!(b.offer(2, 2, grads_for(2)), Offer::Accepted);
        assert_eq!(b.offer(1, 2, grads_for(1)), Offer::Completed);
        assert_eq!(b.take_coalesced(), reference(&[0, 1, 2]));
    }

    #[test]
    fn leave_discards_pending_and_can_complete_the_barrier() {
        let mut b = StepBatcher::new(3, shapes());
        assert_eq!(b.offer(0, 1, grads_for(0)), Offer::Accepted);
        assert_eq!(b.offer(1, 1, grads_for(1)), Offer::Accepted);
        // the member that has NOT pushed leaves: the barrier completes
        // over the two that did
        let out = b.leave(2).unwrap();
        assert_eq!(out, LeaveOutcome { had_pending: false, completed: true });
        assert_eq!(b.take_coalesced(), reference(&[0, 1]));
        // a member WITH a pending push leaves: the push is discarded
        assert_eq!(b.offer(0, 2, grads_for(0)), Offer::Accepted);
        let out = b.leave(0).unwrap();
        assert_eq!(out, LeaveOutcome { had_pending: true, completed: false });
        assert_eq!(b.members(), &[1]);
        // non-member and last-member errors
        assert!(b.leave(7).is_err());
        assert!(b.leave(1).is_err(), "last member may not leave");
        assert_eq!(b.offer(1, 2, grads_for(1)), Offer::Completed);
    }

    #[test]
    fn evict_unpushed_completes_over_the_survivors() {
        let mut b = StepBatcher::new(4, shapes());
        assert_eq!(b.offer(3, 1, grads_for(3)), Offer::Accepted);
        assert_eq!(b.offer(1, 1, grads_for(1)), Offer::Accepted);
        assert_eq!(b.evict_unpushed(), vec![0, 2]);
        assert_eq!(b.members(), &[1, 3]);
        assert_eq!(b.received(), 2);
        // barrier is now complete: the survivors' pushes coalesce at the
        // new width
        assert_eq!(b.take_coalesced(), reference(&[1, 3]));
        assert_eq!(b.pending_step(), 2);
    }

    #[test]
    fn resumed_barrier_starts_past_step_one() {
        let mut b = StepBatcher::with_members(vec![0], shapes(), 7);
        assert_eq!(b.applied_step(), 6);
        assert!(matches!(b.offer(0, 1, grads_for(0)), Offer::Rejected(_)));
        assert_eq!(b.offer(0, 7, grads_for(0)), Offer::Completed);
    }

    #[test]
    fn async_commit_sorts_contributors_and_advances_one_step() {
        let mut a = AsyncAccumulator::with_members(vec![0, 1, 2], shapes(), 2, 1);
        assert_eq!(a.applied_step(), 0);
        assert_eq!(a.take_commit(), None, "no empty commits");
        // arrival order 2, 0 — the commit must come out sorted
        assert_eq!(a.offer(2, 0, grads_for(2)), AsyncOffer::Accepted);
        assert_eq!(a.offer(0, 0, grads_for(0)), AsyncOffer::Accepted);
        let commit = a.take_commit().unwrap();
        let ids: Vec<u32> = commit.iter().map(|(c, ..)| *c).collect();
        assert_eq!(ids, vec![0, 2]);
        assert_eq!(a.applied_step(), 1);
        assert_eq!(a.pending(), 0);
    }

    #[test]
    fn async_staleness_window_bounds_accepted_base_steps() {
        let mut a = AsyncAccumulator::with_members(vec![0, 1], shapes(), 2, 1);
        // advance to applied step 3 via three single-contributor commits
        for base in 0..3 {
            assert_eq!(a.offer(0, base, grads_for(0)), AsyncOffer::Accepted);
            a.take_commit().unwrap();
        }
        assert_eq!(a.applied_step(), 3);
        // lag 3 > staleness 2: typed TooStale, issued before the (empty,
        // invalid) payload is even looked at
        assert_eq!(a.offer(1, 0, vec![]), AsyncOffer::TooStale { applied: 3, required: 1 });
        // lag exactly at the window is accepted
        assert_eq!(a.offer(1, 1, grads_for(1)), AsyncOffer::Accepted);
        // a base step the server has not reached is rejected outright
        assert!(matches!(a.offer(0, 4, grads_for(0)), AsyncOffer::Rejected(_)));
        // duplicate pending contribution is rejected
        assert!(matches!(a.offer(1, 3, grads_for(1)), AsyncOffer::Rejected(_)));
        // non-member
        assert!(matches!(a.offer(9, 3, grads_for(9)), AsyncOffer::Rejected(_)));
        // bad shapes
        assert!(matches!(a.offer(0, 3, vec![vec![1.0]]), AsyncOffer::Rejected(_)));
    }

    #[test]
    fn async_leave_discards_pending_and_join_widens() {
        let mut a = AsyncAccumulator::with_members(vec![0, 1], shapes(), 1, 1);
        assert_eq!(a.offer(1, 0, grads_for(1)), AsyncOffer::Accepted);
        assert!(a.leave(1).unwrap(), "pending contribution was discarded");
        assert_eq!(a.members(), &[0]);
        assert!(a.leave(0).is_err(), "last member may not leave");
        a.join(5).unwrap();
        assert!(a.join(5).is_err(), "duplicate join must be rejected");
        assert_eq!(a.offer(5, 0, grads_for(5)), AsyncOffer::Accepted);
        assert!(!a.leave(0).unwrap(), "member without pending work");
        let commit = a.take_commit().unwrap();
        assert_eq!(commit.len(), 1);
        assert_eq!(commit[0].0, 5);
    }
}
