//! The optimizer-state server: TCP accept loop, bounded request queue
//! with explicit backpressure, the step coordinator, the single-process
//! reference trainer, and the load generator.
//!
//! Thread topology (all `std::thread`, mirroring
//! `coordinator::workers::train_data_parallel`):
//!
//! * **acceptor** — non-blocking accept loop; spawns one handler thread
//!   per connection.
//! * **handlers** (one per connection) — read a frame, forward it to the
//!   coordinator over a *bounded* `sync_channel`, wait for the reply,
//!   write it back. A full queue is answered with [`Msg::Busy`]
//!   immediately — the server never buffers unbounded work.
//! * **coordinator** — owns the master parameters, the
//!   [`StepBatcher`](super::batch::StepBatcher) step barrier and the
//!   [`ShardSet`](super::shard::ShardSet); applies coalesced steps,
//!   serves pulls/snapshots/stats, and drives shutdown.
//! * **shard workers** (K) — own the optimizer state for their tensor
//!   subsets (see [`super::shard`]).
//!
//! Determinism contract: a K-shard server driven by N concurrent
//! loadgen clients writes a snapshot bit-identical to
//! [`reference_checkpoint`] — the equivalent single-process trainer over
//! the same workload — for any K, N, and any network interleaving. The
//! e2e test (`rust/tests/server_e2e.rs`) and `make serve-smoke` pin this
//! at shards {1,2} × clients {1,4}.

use anyhow::{anyhow, bail, Context, Result};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use crate::coordinator::config::ExperimentConfig;
use crate::models::{inventory_by_name, Inventory};
use crate::optim::group::{self, Resolution};
use crate::optim::{self, Optimizer, StateSerde};
use crate::server::batch::{Offer, StepBatcher};
use crate::server::client::{Client, GradSource};
use crate::server::protocol::{self, Frame, Msg, ServerStats};
use crate::server::shard::ShardSet;
use crate::tensor::Tensor;
use crate::train::checkpoint::{self, ConfigSection};
use crate::util::cli::Args;
use crate::util::toml::TomlDoc;

// ---------------------------------------------------------------------------
// Options
// ---------------------------------------------------------------------------

/// Server knobs: `[server]` TOML section + CLI flags (CLI wins). All
/// count knobs are validated to `>= 1` at this layer with a clear error
/// — a zero-shard server or zero-client barrier is meaningless and
/// would otherwise surface as a deadlock or divide-by-zero deep in the
/// step path.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Listen address (`127.0.0.1:0` picks an ephemeral port).
    pub addr: String,
    /// Workload inventory: `synthetic:<name>` or a bare inventory name.
    pub model: String,
    /// State shards (worker threads owning optimizer state).
    pub shards: usize,
    /// Step-barrier width: gradient pushes per optimizer step.
    pub clients: usize,
    /// Bounded request-queue depth; a full queue answers `Busy`.
    pub max_pending: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7070".into(),
            model: "synthetic:tiny_lm".into(),
            shards: 1,
            clients: 1,
            max_pending: 256,
        }
    }
}

fn toml_count(doc: &TomlDoc, key: &str, default: usize) -> Result<usize> {
    doc.count_or(key, default).map_err(|e| anyhow!("[server]: {e}"))
}

impl ServeOptions {
    /// Load from the `--config` file's `[server]` section (if any), then
    /// apply CLI overrides.
    pub fn load(args: &Args) -> Result<ServeOptions> {
        let mut o = ServeOptions::default();
        if let Some(path) = args.opt("config") {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading {path:?}"))?;
            let doc = TomlDoc::parse(&text).map_err(|e| anyhow!("{path:?}: {e}"))?;
            o.apply_toml(&doc)?;
        }
        o.apply_args(args)?;
        Ok(o)
    }

    /// Apply `[server]` TOML keys.
    pub fn apply_toml(&mut self, doc: &TomlDoc) -> Result<()> {
        self.addr = doc.str_or("server.addr", &self.addr).to_string();
        self.model = doc.str_or("server.model", &self.model).to_string();
        self.shards = toml_count(doc, "server.shards", self.shards)?;
        self.clients = toml_count(doc, "server.clients", self.clients)?;
        self.max_pending = toml_count(doc, "server.max_pending", self.max_pending)?;
        Ok(())
    }

    /// Apply `--addr/--model/--shards/--clients/--max-pending` CLI
    /// overrides (strictly validated, not silently clamped).
    pub fn apply_args(&mut self, args: &Args) -> Result<()> {
        self.addr = args.str_or("addr", &self.addr);
        if let Some(m) = args.opt("model") {
            self.model = m.to_string();
        }
        self.shards = args.count_or("shards", self.shards).map_err(|e| anyhow!(e))?;
        self.clients = args.count_or("clients", self.clients).map_err(|e| anyhow!(e))?;
        self.max_pending =
            args.count_or("max-pending", self.max_pending).map_err(|e| anyhow!(e))?;
        Ok(())
    }
}

/// Resolve a workload spec (`synthetic:<name>` or a bare inventory
/// name) to its inventory — shared by the server, the reference
/// trainer, and the `repro loadgen` CLI so the model-spec syntax lives
/// in one place.
pub fn resolve_inventory(model: &str) -> Result<Inventory> {
    let name = model.strip_prefix("synthetic:").unwrap_or(model);
    inventory_by_name(name)
        .ok_or_else(|| anyhow!("unknown inventory {name} (see `repro list`)"))
}

/// Refuse inventories whose gradient/parameter messages cannot fit in
/// one wire frame — a clear startup error instead of an encoder assert
/// on the first push. (The protocol is a single-frame-per-tensor-set
/// design; the paper-scale BERT/LLaMA inventories are out of scope for
/// the serving demo.)
fn check_wire_capacity(model: &str, shapes: &[Vec<usize>]) -> Result<()> {
    let bytes = protocol::grads_payload_bytes(shapes);
    if bytes > protocol::MAX_PAYLOAD {
        bail!(
            "inventory {model} needs {bytes}-byte gradient frames, over the SMMFWIRE \
             payload cap ({} bytes) — pick a smaller inventory (e.g. synthetic:tiny_lm)",
            protocol::MAX_PAYLOAD
        );
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Server
// ---------------------------------------------------------------------------

struct Request {
    reply: mpsc::Sender<Msg>,
    msg: Msg,
}

/// A running optimizer-state server. [`Server::start`] returns once the
/// listener is bound; [`Server::wait`] blocks until a client sends
/// [`Msg::Shutdown`] and returns the final counters.
pub struct Server {
    /// The bound address (resolves `:0` to the real ephemeral port).
    pub addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    coordinator: Option<JoinHandle<Result<ServerStats>>>,
    acceptor: Option<JoinHandle<()>>,
}

impl Server {
    /// Bind, spawn the shard workers, the coordinator and the accept
    /// loop. `cfg` supplies the optimizer recipe (kind, hyperparameters,
    /// `[[optimizer.group]]` policies, LR schedule, seed); `opts` the
    /// serving topology.
    pub fn start(cfg: &ExperimentConfig, opts: &ServeOptions) -> Result<Server> {
        let inv = resolve_inventory(&opts.model)?;
        let specs = inv.param_specs();
        let shapes = inv.shapes();
        check_wire_capacity(&opts.model, &shapes)?;
        let names: Vec<String> = inv.tensors.iter().map(|t| t.name.clone()).collect();
        let res = group::resolve(&specs, &cfg.grouped());
        let config_section = ConfigSection::from_config(&cfg.optim, &res);
        let shards =
            ShardSet::spawn(cfg.optimizer, &shapes, &cfg.optim, &res.tensor, opts.shards);
        // Parameters start at the origin, like the synthetic suite
        // workload — clients own the loss surface (targets + noise).
        let params: Vec<Tensor> = shapes.iter().map(|s| Tensor::zeros(s)).collect();

        let listener = TcpListener::bind(&opts.addr)
            .with_context(|| format!("binding {}", opts.addr))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;

        let shutdown = Arc::new(AtomicBool::new(false));
        let busy = Arc::new(AtomicU64::new(0));
        let (req_tx, req_rx) = mpsc::sync_channel::<Request>(opts.max_pending);

        let acceptor = {
            let shutdown = shutdown.clone();
            let busy = busy.clone();
            thread::spawn(move || loop {
                if shutdown.load(Ordering::SeqCst) {
                    return;
                }
                match listener.accept() {
                    Ok((stream, _)) => {
                        let req_tx = req_tx.clone();
                        let busy = busy.clone();
                        thread::spawn(move || handle_conn(stream, req_tx, busy));
                    }
                    // WouldBlock (idle) and transient accept errors both
                    // back off briefly; only the shutdown flag exits.
                    Err(_) => thread::sleep(Duration::from_millis(2)),
                }
            })
        };

        let coordinator = {
            let shutdown = shutdown.clone();
            let busy = busy.clone();
            let mut stats = ServerStats {
                shards: opts.shards as u32,
                clients: opts.clients as u32,
                ..ServerStats::default()
            };
            let n_clients = opts.clients;
            let base_lr = cfg.optim.lr;
            let schedule = cfg.schedule.clone();
            let kind = cfg.optimizer;
            let mut params = params;
            let mut batcher = StepBatcher::new(n_clients, shapes.clone());
            thread::spawn(move || -> Result<ServerStats> {
                let mut waiters: Vec<(u32, mpsc::Sender<Msg>)> = Vec::new();
                let run = (|| -> Result<()> {
                    while let Ok(req) = req_rx.recv() {
                        match req.msg {
                            Msg::PushGrad { client, step, grads } => {
                                match batcher.offer(client, step, grads) {
                                    Offer::Rejected(msg) => {
                                        req.reply.send(Msg::Err { msg }).ok();
                                    }
                                    Offer::Accepted => waiters.push((client, req.reply)),
                                    Offer::Completed => {
                                        waiters.push((client, req.reply));
                                        let applied = batcher.pending_step();
                                        let grads = batcher.take_coalesced();
                                        let lr = schedule.at(base_lr, applied);
                                        shards.step(lr, &mut params, grads)?;
                                        stats.pushes += n_clients as u64;
                                        stats.step = applied;
                                        // Reply in client-id order, like
                                        // the coalescing reduction.
                                        waiters.sort_by_key(|w| w.0);
                                        for (_, tx) in waiters.drain(..) {
                                            tx.send(Msg::Ack { step: applied }).ok();
                                        }
                                    }
                                }
                            }
                            Msg::PullParams => {
                                let tensors =
                                    params.iter().map(|t| t.data().to_vec()).collect();
                                req.reply
                                    .send(Msg::Params {
                                        step: batcher.applied_step(),
                                        tensors,
                                    })
                                    .ok();
                            }
                            Msg::Snapshot { path } => {
                                let reply = shards.collect_state().and_then(
                                    |(opt_step, _live, blobs)| {
                                        checkpoint::save_snapshot(
                                            Path::new(&path),
                                            batcher.applied_step(),
                                            &names,
                                            &params,
                                            base_lr,
                                            &schedule,
                                            kind,
                                            opt_step,
                                            blobs,
                                            &config_section,
                                        )
                                    },
                                );
                                match reply {
                                    Ok(bytes) => {
                                        stats.snapshots += 1;
                                        req.reply.send(Msg::SnapshotDone { bytes }).ok();
                                    }
                                    Err(e) => {
                                        req.reply
                                            .send(Msg::Err { msg: format!("{e:#}") })
                                            .ok();
                                    }
                                }
                            }
                            Msg::Stats => {
                                stats.busy = busy.load(Ordering::Relaxed);
                                req.reply.send(Msg::StatsReply(stats)).ok();
                            }
                            Msg::Shutdown => {
                                req.reply.send(Msg::Bye).ok();
                                return Ok(());
                            }
                            other => {
                                req.reply
                                    .send(Msg::Err {
                                        msg: format!("{} is not a request", other.name()),
                                    })
                                    .ok();
                            }
                        }
                    }
                    Ok(())
                })();
                // Teardown: unblock any barrier waiters, stop accepting,
                // join the shard workers — whether we exit via Shutdown
                // or a shard failure.
                for (_, tx) in waiters.drain(..) {
                    tx.send(Msg::Err { msg: "server shutting down".into() }).ok();
                }
                shutdown.store(true, Ordering::SeqCst);
                shards.stop();
                run?;
                stats.busy = busy.load(Ordering::Relaxed);
                Ok(stats)
            })
        };

        Ok(Server { addr, shutdown, coordinator: Some(coordinator), acceptor: Some(acceptor) })
    }

    /// Block until the server shuts down; returns the final counters.
    pub fn wait(mut self) -> Result<ServerStats> {
        let stats = self
            .coordinator
            .take()
            .expect("wait() is called once")
            .join()
            .map_err(|_| anyhow!("server coordinator panicked"))?;
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        stats
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        // Belt and braces: an abandoned handle must not keep the accept
        // loop spinning.
        self.shutdown.store(true, Ordering::SeqCst);
    }
}

/// Per-connection handler: strictly sequential request → reply. A full
/// coordinator queue is answered with `Busy` right here — the explicit
/// backpressure path.
fn handle_conn(stream: TcpStream, req_tx: SyncSender<Request>, busy: Arc<AtomicU64>) {
    stream.set_nodelay(true).ok();
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = std::io::BufReader::new(read_half);
    let mut writer = std::io::BufWriter::new(stream);
    loop {
        // Read errors (EOF on client disconnect, or a malformed frame)
        // end the connection; the protocol has no resync point.
        let Ok(frame) = protocol::read_frame(&mut reader) else { return };
        let id = frame.request_id;
        let is_request = matches!(
            frame.msg,
            Msg::PushGrad { .. }
                | Msg::PullParams
                | Msg::Snapshot { .. }
                | Msg::Stats
                | Msg::Shutdown
        );
        let reply = if !is_request {
            Msg::Err { msg: format!("{} is not a request", frame.msg.name()) }
        } else {
            let (rtx, rrx) = mpsc::channel::<Msg>();
            match req_tx.try_send(Request { reply: rtx, msg: frame.msg }) {
                Ok(()) => rrx.recv().unwrap_or(Msg::Err { msg: "server stopped".into() }),
                Err(TrySendError::Full(_)) => {
                    busy.fetch_add(1, Ordering::Relaxed);
                    Msg::Busy
                }
                Err(TrySendError::Disconnected(_)) => Msg::Err { msg: "server stopped".into() },
            }
        };
        let done = matches!(reply, Msg::Bye);
        if protocol::write_frame(&mut writer, &Frame { request_id: id, msg: reply }).is_err() {
            return;
        }
        if done {
            return;
        }
    }
}

// ---------------------------------------------------------------------------
// Single-process reference trainer
// ---------------------------------------------------------------------------

/// The equivalent single-process trainer: one optimizer over the full
/// inventory, fed the identical per-client gradient streams coalesced
/// through the identical [`StepBatcher`] reduction, snapshotted through
/// the identical [`checkpoint::save_snapshot`] writer. A K-shard,
/// N-client server run must produce a byte-identical file — this is the
/// oracle of the determinism e2e and of `repro loadgen --check`.
/// Returns client 0's final (noise-free) loss.
pub fn reference_checkpoint(
    cfg: &ExperimentConfig,
    model: &str,
    n_clients: usize,
    steps: u64,
    path: &Path,
) -> Result<f32> {
    assert!(n_clients >= 1);
    let inv = resolve_inventory(model)?;
    let specs = inv.param_specs();
    let shapes = inv.shapes();
    let names: Vec<String> = inv.tensors.iter().map(|t| t.name.clone()).collect();
    let res: Resolution = group::resolve(&specs, &cfg.grouped());
    let mut opt = optim::build_with_policies(cfg.optimizer, &shapes, &cfg.optim, &res.tensor);
    let mut params: Vec<Tensor> = shapes.iter().map(|s| Tensor::zeros(s)).collect();
    let mut sources: Vec<GradSource> =
        (0..n_clients).map(|c| GradSource::new(&shapes, cfg.seed, c as u32)).collect();
    let mut final_loss = f32::NAN;
    for step in 1..=steps {
        let flat: Vec<Vec<f32>> = params.iter().map(|t| t.data().to_vec()).collect();
        let mut barrier = StepBatcher::new(n_clients, shapes.clone());
        for (c, src) in sources.iter_mut().enumerate() {
            let (loss, grads) = src.grads(&flat)?;
            if c == 0 {
                final_loss = loss;
            }
            match barrier.offer(c as u32, 1, grads) {
                Offer::Rejected(msg) => bail!("reference barrier rejected client {c}: {msg}"),
                _ => {}
            }
        }
        let grads = barrier.take_coalesced();
        opt.set_lr(cfg.schedule.at(cfg.optim.lr, step));
        opt.step(&mut params, &grads);
    }
    checkpoint::save_snapshot(
        path,
        steps,
        &names,
        &params,
        cfg.optim.lr,
        &cfg.schedule,
        cfg.optimizer,
        opt.opt_step(),
        opt.state_blobs(),
        &ConfigSection::from_config(&cfg.optim, &res),
    )?;
    Ok(final_loss)
}

// ---------------------------------------------------------------------------
// Load generator
// ---------------------------------------------------------------------------

/// Loadgen knobs.
#[derive(Clone, Debug)]
pub struct LoadgenOptions {
    /// Concurrent connections (must equal the server's barrier width).
    pub clients: usize,
    /// Optimizer steps to drive.
    pub steps: u64,
}

/// Aggregate loadgen measurements: throughput plus push round-trip
/// latency percentiles (a push's round trip spans the step barrier and
/// the sharded optimizer step — it is the end-to-end step latency as one
/// client observes it).
#[derive(Clone, Debug)]
pub struct LoadgenReport {
    pub clients: usize,
    pub steps: u64,
    /// Total accepted pushes (= clients × steps).
    pub pushes: u64,
    /// `Busy` bounces absorbed by client-side retries.
    pub busy_retries: u64,
    pub elapsed_s: f64,
    /// Optimizer steps per second.
    pub steps_per_s: f64,
    pub push_p50_ms: f64,
    pub push_p99_ms: f64,
    pub push_mean_ms: f64,
    /// Client 0's final noise-free loss (sanity: the well converges).
    pub final_loss: f32,
}

fn percentile(sorted_ms: &[f64], q: f64) -> f64 {
    if sorted_ms.is_empty() {
        return f64::NAN;
    }
    sorted_ms[((sorted_ms.len() - 1) as f64 * q).round() as usize]
}

/// Drive `opts.clients` concurrent connections for `opts.steps` steps
/// against the server at `addr`. `shapes`/`seed` must match the
/// server's workload (the CLI derives both from the same config).
pub fn run_loadgen(
    addr: &str,
    shapes: &[Vec<usize>],
    seed: u64,
    opts: &LoadgenOptions,
) -> Result<LoadgenReport> {
    assert!(opts.clients >= 1 && opts.steps >= 1);
    check_wire_capacity("workload", shapes)?;
    // A client count that disagrees with the server's barrier width
    // would deadlock the first push (the barrier never completes) —
    // probe the server's Stats once and fail loudly instead.
    let server = Client::connect(addr)?.stats()?;
    if server.clients as usize != opts.clients {
        bail!(
            "loadgen drives {} client(s) but the server's step barrier is {} wide — \
             pass --clients {} (or restart the server)",
            opts.clients,
            server.clients,
            server.clients
        );
    }
    let t0 = Instant::now();
    let results: Vec<Result<(Vec<f64>, u64, f32)>> = thread::scope(|s| {
        let handles: Vec<_> = (0..opts.clients)
            .map(|c| {
                let steps = opts.steps;
                s.spawn(move || -> Result<(Vec<f64>, u64, f32)> {
                    let mut client = Client::connect(addr)?;
                    let mut src = GradSource::new(shapes, seed, c as u32);
                    let mut latencies_ms = Vec::with_capacity(steps as usize);
                    let mut final_loss = f32::NAN;
                    for step in 1..=steps {
                        let (at, params) = client.pull_params()?;
                        if at != step - 1 {
                            bail!(
                                "client {c}: server at step {at}, expected {} — \
                                 is another loadgen driving it?",
                                step - 1
                            );
                        }
                        let (loss, grads) = src.grads(&params)?;
                        final_loss = loss;
                        let t = Instant::now();
                        let applied = client.push_grad(c as u32, step, grads)?;
                        latencies_ms.push(t.elapsed().as_secs_f64() * 1e3);
                        if applied != step {
                            bail!("client {c}: pushed step {step}, server applied {applied}");
                        }
                    }
                    Ok((latencies_ms, client.busy_retries, final_loss))
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().unwrap_or_else(|_| Err(anyhow!("loadgen client panicked"))))
            .collect()
    });
    let elapsed_s = t0.elapsed().as_secs_f64();

    let mut all_ms = Vec::with_capacity(opts.clients * opts.steps as usize);
    let mut busy_retries = 0u64;
    let mut final_loss = f32::NAN;
    for (c, r) in results.into_iter().enumerate() {
        let (lat, busy, loss) = r.with_context(|| format!("loadgen client {c}"))?;
        all_ms.extend(lat);
        busy_retries += busy;
        if c == 0 {
            final_loss = loss;
        }
    }
    all_ms.sort_by(|a, b| a.partial_cmp(b).expect("latencies are finite"));
    let mean = all_ms.iter().sum::<f64>() / all_ms.len().max(1) as f64;
    Ok(LoadgenReport {
        clients: opts.clients,
        steps: opts.steps,
        pushes: opts.clients as u64 * opts.steps,
        busy_retries,
        elapsed_s,
        steps_per_s: opts.steps as f64 / elapsed_s.max(1e-12),
        push_p50_ms: percentile(&all_ms, 0.50),
        push_p99_ms: percentile(&all_ms, 0.99),
        push_mean_ms: mean,
        final_loss,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_options_validate_counts() {
        // TOML layer
        let doc = TomlDoc::parse("[server]\nshards = 2\nclients = 4\nmax_pending = 8").unwrap();
        let mut o = ServeOptions::default();
        o.apply_toml(&doc).unwrap();
        assert_eq!((o.shards, o.clients, o.max_pending), (2, 4, 8));
        for bad in ["[server]\nshards = 0", "[server]\nclients = -3", "[server]\nshards = \"x\""]
        {
            let doc = TomlDoc::parse(bad).unwrap();
            let e = ServeOptions::default().apply_toml(&doc).unwrap_err();
            assert!(format!("{e:#}").contains(">= 1"), "{bad}: {e:#}");
        }
        // CLI layer
        let args = Args::parse(["--shards", "3", "--clients", "2"].iter().map(|s| s.to_string()));
        let mut o = ServeOptions::default();
        o.apply_args(&args).unwrap();
        assert_eq!((o.shards, o.clients), (3, 2));
        let args = Args::parse(["--clients", "0"].iter().map(|s| s.to_string()));
        let e = ServeOptions::default().apply_args(&args).unwrap_err();
        assert!(format!("{e:#}").contains(">= 1"), "{e:#}");
    }

    #[test]
    fn percentile_picks_expected_ranks() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.50), 51.0);
        assert_eq!(percentile(&v, 0.99), 99.0);
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 1.0), 100.0);
        assert!(percentile(&[], 0.5).is_nan());
    }
}
