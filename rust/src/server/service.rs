//! The optimizer-state server: TCP accept loop, bounded request queue
//! with explicit backpressure, the step coordinator, the single-process
//! reference trainer, and the load generator.
//!
//! Thread topology (all `std::thread`, mirroring
//! `coordinator::workers::train_data_parallel`):
//!
//! * **acceptor** — non-blocking accept loop; spawns one handler thread
//!   per connection.
//! * **handlers** (one per connection) — read a frame, forward it to the
//!   coordinator over a *bounded* `sync_channel`, wait for the reply,
//!   write it back. A full queue is answered with [`Msg::Busy`]
//!   immediately — the server never buffers unbounded work. Under wire
//!   protocol v4 the handler is also the chunking layer: it reassembles
//!   a client's `PushBegin` → chunk → `StreamEnd` sequence into one
//!   internal [`Msg::PushGrad`] before the coordinator sees it, and
//!   fans a pull reply back out as a `ParamsBegin` → chunk →
//!   `StreamEnd` sequence (retaining the encoded reply so a
//!   [`Msg::Resend`] is answered without another coordinator round
//!   trip). Framing buffers are O(chunk); only the in-flight reply a
//!   handler is already serving is held whole.
//! * **coordinator** — owns the master parameters, the
//!   [`StepBatcher`](super::batch::StepBatcher) step barrier and the
//!   [`ShardSet`](super::shard::ShardSet); applies coalesced steps,
//!   serves pulls/snapshots/stats/membership, and drives shutdown.
//! * **shard workers** (K) — own the optimizer state for their tensor
//!   subsets (see [`super::shard`]).
//!
//! Fault tolerance (wire protocol v2):
//!
//! * **Membership epochs** — `Join`/`Leave` renegotiate the barrier
//!   width and bump the epoch counter; a push tagged with a superseded
//!   epoch is answered [`Msg::StaleEpoch`] so the client refreshes its
//!   view and retries instead of deadlocking the barrier.
//! * **Eviction** — with `client_timeout_ms` set, a partially assembled
//!   barrier older than the deadline evicts every member that has not
//!   pushed, bumps the epoch, and completes the step over the
//!   survivors. A crashed client therefore stalls the fleet for at most
//!   one timeout.
//! * **Shard crash-resume** — in `resilient` mode the coordinator keeps
//!   an in-memory SMMFCKPT v2 image of the state after every applied
//!   step; a dead shard worker (poisoned channel) is respawned, its
//!   optimizer state restored tensor-by-tensor from the image (CONFIG
//!   cross-checked), and the interrupted step replayed — the run
//!   continues bit-identically.
//!
//! Bounded-staleness async mode (wire protocol v3): with
//! `staleness = S >= 1` the step barrier is replaced by an
//! [`AsyncAccumulator`](super::batch::AsyncAccumulator) — the
//! coordinator drains every queued push, coalesces the batch in member-id
//! order (scale `1/n`), applies it as one optimizer step, and
//! acknowledges exactly the contributors. A push whose `base_step` lags
//! the applied step by more than `S` is answered [`Msg::TooStale`]; a
//! pull may carry a `min_step` floor and gets the same typed answer when
//! the server cannot honor it. Every applied partial batch is appended
//! to the commit log (`--commit-log`): step, epoch, contributor ids and
//! base steps, a digest, and the coalesced gradient. [`replay_commit_log`]
//! re-executes that log through the synchronous sharded machinery to a
//! bit-identical snapshot — the commit log is the determinism oracle for
//! async runs, where wall-clock interleaving decides which pushes share
//! a commit.
//!
//! Determinism contract: a K-shard server driven by N concurrent
//! loadgen clients writes a snapshot bit-identical to
//! [`reference_checkpoint`] — the equivalent single-process trainer over
//! the same workload — for any K, N, and any network interleaving.
//! Within one epoch the coalesced step is a fixed-member-id-order
//! reduction, so the contract extends to elastic runs: a run whose
//! membership changes at known step boundaries matches
//! [`reference_checkpoint_elastic`] over the same epoch schedule. The
//! e2e test (`rust/tests/server_e2e.rs`) and `make serve-smoke` pin the
//! fixed-membership case at shards {1,2} × clients {1,4}; the chaos e2e
//! and `make chaos-smoke` pin the elastic case under injected faults.

use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use crate::coordinator::config::ExperimentConfig;
use crate::models::{inventory_by_name, Inventory};
use crate::obs::{self, metrics::Histogram, trace as obs_trace};
use crate::optim::group::{self, Resolution, TensorPolicy};
use crate::optim::schedule::LrSchedule;
use crate::optim::{self, OptKind, Optimizer, StateSerde};
use crate::server::batch::{AsyncAccumulator, AsyncOffer, Offer, StepBatcher};
use crate::server::client::{Client, GradSource, PullReply, PushOutcome};
use crate::server::commitlog::{CommitLog, CommitLogWriter, LogInfo};
use crate::server::protocol::{self, Contributor, EpochView, Frame, Msg, ServerStats};
use crate::server::shard::{self, RecoveryImage, ShardSet};
use crate::tensor::Tensor;
use crate::train::checkpoint::{self, ConfigSection};
use crate::util::cli::Args;
use crate::util::rng::Pcg32;
use crate::util::toml::TomlDoc;

// ---------------------------------------------------------------------------
// Options
// ---------------------------------------------------------------------------

/// Server knobs: `[server]` TOML section + CLI flags (CLI wins). All
/// count knobs are validated to `>= 1` at this layer with a clear error
/// — a zero-shard server or zero-client barrier is meaningless and
/// would otherwise surface as a deadlock or divide-by-zero deep in the
/// step path.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Listen address (`127.0.0.1:0` picks an ephemeral port).
    pub addr: String,
    /// Workload inventory: `synthetic:<name>` or a bare inventory name.
    pub model: String,
    /// State shards (worker threads owning optimizer state).
    pub shards: usize,
    /// Step-barrier width: gradient pushes per optimizer step.
    pub clients: usize,
    /// Bounded request-queue depth; a full queue answers `Busy`.
    pub max_pending: usize,
    /// Barrier deadline in milliseconds: a partially assembled step
    /// older than this evicts its unpushed members and completes over
    /// the survivors. `0` disables eviction (a missing client stalls
    /// the barrier forever — the pre-v2 behavior).
    pub client_timeout_ms: u64,
    /// Keep a per-step in-memory recovery image and respawn dead shard
    /// workers mid-step instead of failing the run.
    pub resilient: bool,
    /// Resume serving from an SMMFCKPT snapshot: parameters, optimizer
    /// state and the step counter are restored (re-sharded onto the
    /// configured shard count if it differs from the writing run's).
    pub resume: Option<String>,
    /// Bounded-staleness window: `0` keeps the synchronous step barrier;
    /// `S >= 1` switches to async ingestion, where a push based on
    /// parameters at most `S` steps behind the applied step joins the
    /// next commit and anything older is answered `TooStale`.
    pub staleness: u64,
    /// Append every applied async commit to this log file (async mode
    /// only — the synchronous path is already pinned by `--check`).
    pub commit_log: Option<String>,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7070".into(),
            model: "synthetic:tiny_lm".into(),
            shards: 1,
            clients: 1,
            max_pending: 256,
            client_timeout_ms: 0,
            resilient: false,
            resume: None,
            staleness: 0,
            commit_log: None,
        }
    }
}

fn toml_count(doc: &TomlDoc, key: &str, default: usize) -> Result<usize> {
    doc.count_or(key, default).map_err(|e| anyhow!("[server]: {e}"))
}

impl ServeOptions {
    /// Load from the `--config` file's `[server]` section (if any), then
    /// apply CLI overrides.
    pub fn load(args: &Args) -> Result<ServeOptions> {
        let mut o = ServeOptions::default();
        if let Some(path) = args.opt("config") {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading {path:?}"))?;
            let doc = TomlDoc::parse(&text).map_err(|e| anyhow!("{path:?}: {e}"))?;
            o.apply_toml(&doc)?;
        }
        o.apply_args(args)?;
        Ok(o)
    }

    /// Apply `[server]` TOML keys.
    pub fn apply_toml(&mut self, doc: &TomlDoc) -> Result<()> {
        self.addr = doc.str_or("server.addr", &self.addr).to_string();
        self.model = doc.str_or("server.model", &self.model).to_string();
        self.shards = toml_count(doc, "server.shards", self.shards)?;
        self.clients = toml_count(doc, "server.clients", self.clients)?;
        self.max_pending = toml_count(doc, "server.max_pending", self.max_pending)?;
        let t = doc.i64_or("server.client_timeout_ms", self.client_timeout_ms as i64);
        if t < 0 {
            bail!("[server]: client_timeout_ms must be >= 0 (got {t}; 0 disables eviction)");
        }
        self.client_timeout_ms = t as u64;
        self.resilient = doc.bool_or("server.resilient", self.resilient);
        let s = doc.i64_or("server.staleness", self.staleness as i64);
        if s < 0 {
            bail!("[server]: staleness must be >= 0 (got {s}; 0 is the synchronous barrier)");
        }
        self.staleness = s as u64;
        let cur = self.commit_log.clone().unwrap_or_default();
        let log = doc.str_or("server.commit_log", &cur).to_string();
        if !log.is_empty() {
            self.commit_log = Some(log);
        }
        Ok(())
    }

    /// Apply `--addr/--model/--shards/--clients/--max-pending/
    /// --client-timeout-ms/--resilient/--resume/--staleness/
    /// --commit-log` CLI overrides (strictly validated, not silently
    /// clamped).
    pub fn apply_args(&mut self, args: &Args) -> Result<()> {
        self.addr = args.str_or("addr", &self.addr);
        if let Some(m) = args.opt("model") {
            self.model = m.to_string();
        }
        self.shards = args.count_or("shards", self.shards).map_err(|e| anyhow!(e))?;
        self.clients = args.count_or("clients", self.clients).map_err(|e| anyhow!(e))?;
        self.max_pending =
            args.count_or("max-pending", self.max_pending).map_err(|e| anyhow!(e))?;
        if let Some(t) = args.opt("client-timeout-ms") {
            self.client_timeout_ms = t.parse().map_err(|_| {
                anyhow!("--client-timeout-ms wants a non-negative integer, got {t:?}")
            })?;
        }
        if args.has_flag("resilient") {
            self.resilient = true;
        }
        if let Some(p) = args.opt("resume") {
            self.resume = Some(p.to_string());
        }
        if let Some(s) = args.opt("staleness") {
            self.staleness = s
                .parse()
                .map_err(|_| anyhow!("--staleness wants a non-negative integer, got {s:?}"))?;
        }
        if let Some(p) = args.opt("commit-log") {
            self.commit_log = Some(p.to_string());
        }
        Ok(())
    }
}

/// Resolve a workload spec (`synthetic:<name>` or a bare inventory
/// name) to its inventory — shared by the server, the reference
/// trainer, and the `repro loadgen` CLI so the model-spec syntax lives
/// in one place.
pub fn resolve_inventory(model: &str) -> Result<Inventory> {
    let name = model.strip_prefix("synthetic:").unwrap_or(model);
    inventory_by_name(name)
        .ok_or_else(|| anyhow!("unknown inventory {name} (see `repro list`)"))
}

// ---------------------------------------------------------------------------
// Server
// ---------------------------------------------------------------------------

struct Request {
    reply: mpsc::Sender<Msg>,
    msg: Msg,
}

/// A running optimizer-state server. [`Server::start`] returns once the
/// listener is bound; [`Server::wait`] blocks until a client sends
/// [`Msg::Shutdown`] and returns the final counters.
pub struct Server {
    /// The bound address (resolves `:0` to the real ephemeral port).
    pub addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    kill_shard: Arc<AtomicUsize>,
    coordinator: Option<JoinHandle<Result<ServerStats>>>,
    acceptor: Option<JoinHandle<()>>,
}

/// Parse and cross-check a recovery image (an in-memory SMMFCKPT v2
/// written by `snapshot_to_bytes`) into the pieces a shard respawn
/// needs. The CONFIG/kind/names checks mirror the `--resume` path: a
/// respawned worker restoring state that disagrees with the serving
/// run would silently diverge, so mismatches fail the recovery instead.
fn parse_recovery_image(
    bytes: Option<&[u8]>,
    names: &[String],
    config: &ConfigSection,
    kind: OptKind,
) -> Result<RecoveryImage> {
    let bytes = bytes
        .ok_or_else(|| anyhow!("no recovery image yet — resilient mode keeps one per step"))?;
    let ck = checkpoint::load_bytes(bytes)?;
    if ck.names.as_slice() != names {
        bail!("recovery image tensor names disagree with the serving inventory");
    }
    let opt = ck
        .opt
        .ok_or_else(|| anyhow!("recovery image carries no optimizer-state section"))?;
    if opt.kind != kind {
        bail!("recovery image optimizer {:?} vs serving {:?}", opt.kind, kind);
    }
    if let Some(c) = &ck.config {
        let mm = c.mismatches(config);
        if !mm.is_empty() {
            bail!("recovery image config disagrees with the run: {}", mm.join("; "));
        }
    }
    Ok(RecoveryImage { opt_step: opt.opt_step, params: ck.params, blobs: opt.blobs })
}

/// Load a snapshot for `--resume` and rebuild the serving state from
/// it: parameters from PARAMS, optimizer state re-sharded onto
/// `n_shards` workers (free to differ from the writing run — the
/// FLOP-balancing planner re-runs and the per-tensor blobs migrate),
/// with names/shapes/kind/schedule/CONFIG all cross-checked against the
/// serving config first.
#[allow(clippy::too_many_arguments)]
fn restore_serving_state(
    path: &str,
    cfg: &ExperimentConfig,
    names: &[String],
    shapes: &[Vec<usize>],
    config_section: &ConfigSection,
    policies: &[TensorPolicy],
    n_shards: usize,
) -> Result<(ShardSet, Vec<Tensor>, u64)> {
    let ck = checkpoint::load_any(Path::new(path))?;
    if ck.names.as_slice() != names {
        bail!(
            "snapshot {path:?} holds tensors {:?}, the serving inventory expects {:?}",
            ck.names,
            names
        );
    }
    for (t, (have, want)) in ck.params.iter().zip(shapes).enumerate() {
        if have.shape() != &want[..] {
            bail!(
                "snapshot {path:?} tensor {t} has shape {:?}, inventory expects {:?}",
                have.shape(),
                want
            );
        }
    }
    let opt = ck.opt.ok_or_else(|| {
        anyhow!("snapshot {path:?} carries no optimizer-state section — cannot resume serving")
    })?;
    if opt.kind != cfg.optimizer {
        bail!("snapshot {path:?} optimizer {:?} vs configured {:?}", opt.kind, cfg.optimizer);
    }
    if let Some(c) = &ck.config {
        let mm = c.mismatches(config_section);
        if !mm.is_empty() {
            bail!("snapshot {path:?} disagrees with the run config: {}", mm.join("; "));
        }
    }
    if let Some(s) = &ck.schedule {
        if s.base_lr.to_bits() != cfg.optim.lr.to_bits() || s.schedule != cfg.schedule {
            bail!("snapshot {path:?} was written under a different LR schedule");
        }
    }
    let shards = ShardSet::spawn_restored(
        cfg.optimizer,
        shapes,
        &cfg.optim,
        policies,
        n_shards,
        opt.opt_step,
        &opt.blobs,
    )
    .with_context(|| format!("restoring shard state from {path:?}"))?;
    Ok((shards, ck.params, ck.step + 1))
}

/// The coordinator's ingestion discipline — the synchronous step
/// barrier (`staleness = 0`) or the bounded-staleness accumulator
/// (`staleness >= 1`). The mode is fixed at startup; everything the
/// membership and pull paths need is shared here so they are
/// mode-agnostic.
enum Ingest {
    Sync(StepBatcher),
    Async(AsyncAccumulator),
}

impl Ingest {
    fn members(&self) -> &[u32] {
        match self {
            Ingest::Sync(b) => b.members(),
            Ingest::Async(a) => a.members(),
        }
    }

    fn width(&self) -> usize {
        match self {
            Ingest::Sync(b) => b.width(),
            Ingest::Async(a) => a.width(),
        }
    }

    fn pending_step(&self) -> u64 {
        match self {
            Ingest::Sync(b) => b.pending_step(),
            Ingest::Async(a) => a.pending_step(),
        }
    }

    fn applied_step(&self) -> u64 {
        match self {
            Ingest::Sync(b) => b.applied_step(),
            Ingest::Async(a) => a.applied_step(),
        }
    }

    fn join(&mut self, client: u32) -> Result<(), String> {
        match self {
            Ingest::Sync(b) => b.join(client),
            Ingest::Async(a) => a.join(client),
        }
    }
}

/// The server's counters and latency histograms, shared atomics all the
/// way down. These same handles back **both** the wire replies
/// ([`Msg::StatsReply`] / [`Msg::MetricsText`]) and the process-wide
/// exposition (each handle is published into the global
/// [`obs::metrics`] registry at construction), so the wire numbers and
/// the exported metrics can never drift — there is exactly one atomic
/// per counter. A process that starts two servers (loadgen's
/// healthy-baseline pass) re-publishes under the same names — the
/// registry follows the newest server, while each server's own wire
/// stats keep reading its own handles.
#[derive(Clone)]
pub(crate) struct ServerMetrics {
    step: Arc<AtomicU64>,
    shards: Arc<AtomicU64>,
    clients: Arc<AtomicU64>,
    pushes: Arc<AtomicU64>,
    busy: Arc<AtomicU64>,
    snapshots: Arc<AtomicU64>,
    epoch: Arc<AtomicU64>,
    evictions: Arc<AtomicU64>,
    respawns: Arc<AtomicU64>,
    recovery_ms: Arc<AtomicU64>,
    staleness: Arc<AtomicU64>,
    /// Push-stream bytes received by connection handlers (chunk frames
    /// included) — the server-side half of the bytes/step accounting.
    stream_rx_bytes: Arc<AtomicU64>,
    /// Pull-stream (and resent-chunk) bytes written by handlers.
    stream_tx_bytes: Arc<AtomicU64>,
    /// `Resend` recoveries served from the per-connection pull cache.
    resends: Arc<AtomicU64>,
    /// Coalesced-commit apply latency (shard step + recovery image).
    commit_ms: Arc<Histogram>,
    /// Commit-log append+flush latency (the fsync-ish cost per commit).
    log_append_ms: Arc<Histogram>,
}

impl ServerMetrics {
    fn new() -> ServerMetrics {
        let m = ServerMetrics {
            step: Arc::new(AtomicU64::new(0)),
            shards: Arc::new(AtomicU64::new(0)),
            clients: Arc::new(AtomicU64::new(0)),
            pushes: Arc::new(AtomicU64::new(0)),
            busy: Arc::new(AtomicU64::new(0)),
            snapshots: Arc::new(AtomicU64::new(0)),
            epoch: Arc::new(AtomicU64::new(0)),
            evictions: Arc::new(AtomicU64::new(0)),
            respawns: Arc::new(AtomicU64::new(0)),
            recovery_ms: Arc::new(AtomicU64::new(0)),
            staleness: Arc::new(AtomicU64::new(0)),
            stream_rx_bytes: Arc::new(AtomicU64::new(0)),
            stream_tx_bytes: Arc::new(AtomicU64::new(0)),
            resends: Arc::new(AtomicU64::new(0)),
            commit_ms: Arc::new(Histogram::new_ms()),
            log_append_ms: Arc::new(Histogram::new_ms()),
        };
        m.publish_into(obs::metrics::global());
        m
    }

    /// Register every handle under its canonical name. Used for the
    /// process-global registry at construction and for the throwaway
    /// registry [`ServerMetrics::exposition`] renders from.
    fn publish_into(&self, reg: &obs::metrics::Registry) {
        reg.publish_gauge("server.step", Arc::clone(&self.step));
        reg.publish_gauge("server.shards", Arc::clone(&self.shards));
        reg.publish_gauge("server.clients", Arc::clone(&self.clients));
        reg.publish_gauge("server.epoch", Arc::clone(&self.epoch));
        reg.publish_gauge("server.staleness", Arc::clone(&self.staleness));
        reg.publish_counter("server.pushes_total", Arc::clone(&self.pushes));
        reg.publish_counter("server.busy_total", Arc::clone(&self.busy));
        reg.publish_counter("server.snapshots_total", Arc::clone(&self.snapshots));
        reg.publish_counter("server.evictions_total", Arc::clone(&self.evictions));
        reg.publish_counter("server.respawns_total", Arc::clone(&self.respawns));
        reg.publish_counter("server.recovery_ms_total", Arc::clone(&self.recovery_ms));
        reg.publish_counter("server.stream_rx_bytes_total", Arc::clone(&self.stream_rx_bytes));
        reg.publish_counter("server.stream_tx_bytes_total", Arc::clone(&self.stream_tx_bytes));
        reg.publish_counter("server.resends_total", Arc::clone(&self.resends));
        reg.publish_histogram("server.commit_ms", Arc::clone(&self.commit_ms));
        reg.publish_histogram("server.log_append_ms", Arc::clone(&self.log_append_ms));
    }

    /// The wire [`ServerStats`], read from the same atomics the
    /// exposition exports.
    fn stats(&self) -> ServerStats {
        ServerStats {
            step: self.step.load(Ordering::Relaxed),
            shards: self.shards.load(Ordering::Relaxed) as u32,
            clients: self.clients.load(Ordering::Relaxed) as u32,
            pushes: self.pushes.load(Ordering::Relaxed),
            busy: self.busy.load(Ordering::Relaxed),
            snapshots: self.snapshots.load(Ordering::Relaxed),
            epoch: self.epoch.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            respawns: self.respawns.load(Ordering::Relaxed),
            recovery_ms: self.recovery_ms.load(Ordering::Relaxed),
            staleness: self.staleness.load(Ordering::Relaxed),
        }
    }

    /// This server's Prometheus text exposition (the [`Msg::MetricsDump`]
    /// reply) — rendered from its own handles via a throwaway registry,
    /// so two servers in one process never leak into each other's dump.
    fn exposition(&self) -> String {
        let reg = obs::metrics::Registry::new();
        self.publish_into(&reg);
        obs::export::prometheus_text(&reg.snapshot())
    }
}

/// The coordinator's owned state plus the step/epoch logic, a struct so
/// the apply-step path is shared between its triggers: a push
/// completing the barrier, a leave whose discarded pending push
/// completes it, a deadline eviction, and (async mode) the post-drain
/// commit flush.
struct Coordinator {
    metrics: ServerMetrics,
    params: Vec<Tensor>,
    ingest: Ingest,
    /// Async mode with `--commit-log`: every applied commit is appended
    /// here before its contributors are acknowledged.
    log: Option<CommitLogWriter>,
    shards: ShardSet,
    /// Blocked pushers of the assembling step: `(client, reply)`.
    waiters: Vec<(u32, mpsc::Sender<Msg>)>,
    names: Vec<String>,
    base_lr: f32,
    schedule: LrSchedule,
    kind: OptKind,
    config_section: ConfigSection,
    /// Membership epoch: starts at 1, bumps on every join/leave/evict.
    epoch: u64,
    /// Next id handed to a `Join` (monotonic — ids are never reused, so
    /// a late push from a departed client can only be a non-member
    /// rejection, never a hijack of a new member's slot).
    next_client_id: u32,
    resilient: bool,
    /// Serialized SMMFCKPT v2 image of the state after the last applied
    /// step (resilient mode only) — the crash-recovery source of truth.
    recovery_bytes: Option<Vec<u8>>,
    /// `client_timeout_ms` as a duration (`None` = never evict).
    client_timeout: Option<Duration>,
    /// When the assembling barrier received its first push.
    barrier_since: Option<Instant>,
}

impl Coordinator {
    fn epoch_view(&self, client: u32) -> Msg {
        Msg::EpochReply(EpochView {
            epoch: self.epoch,
            next_step: self.ingest.pending_step(),
            client,
            members: self.ingest.members().to_vec(),
        })
    }

    fn bump_epoch(&mut self) {
        self.epoch += 1;
        self.metrics.epoch.store(self.epoch, Ordering::Relaxed);
        self.metrics.clients.store(self.ingest.width() as u64, Ordering::Relaxed);
    }

    /// Re-serialize the post-step state (resilient mode only). Runs
    /// after every applied step: the image must always describe the
    /// state a respawned shard should return to.
    fn refresh_recovery_image(&mut self) -> Result<()> {
        if !self.resilient {
            return Ok(());
        }
        let (opt_step, _bytes, blobs) = self.shards.collect_state()?;
        self.recovery_bytes = Some(checkpoint::snapshot_to_bytes(
            self.ingest.applied_step(),
            &self.names,
            &self.params,
            self.base_lr,
            &self.schedule,
            self.kind,
            opt_step,
            blobs,
            &self.config_section,
        ));
        Ok(())
    }

    /// Apply one coalesced gradient as optimizer step `step`
    /// (resiliently if enabled), advance the step counter, refresh the
    /// recovery image. Shared by the synchronous barrier path and the
    /// async commit path — both modes step the identical sharded
    /// machinery, which is what makes the commit log replayable.
    fn apply_coalesced(&mut self, step: u64, grads: Vec<Tensor>) -> Result<()> {
        let _span = obs_trace::span("server", "server.commit");
        let t0 = obs::metrics_enabled().then(Instant::now);
        let lr = self.schedule.at(self.base_lr, step);
        if self.resilient {
            let bytes = &self.recovery_bytes;
            let names = &self.names;
            let config = &self.config_section;
            let kind = self.kind;
            let rec = self.shards.step_resilient(lr, &mut self.params, grads, &mut || {
                parse_recovery_image(bytes.as_deref(), names, config, kind)
            })?;
            self.metrics.respawns.fetch_add(rec.respawns, Ordering::Relaxed);
            self.metrics
                .recovery_ms
                .fetch_add(rec.elapsed.as_millis() as u64, Ordering::Relaxed);
        } else {
            self.shards.step(lr, &mut self.params, grads)?;
        }
        self.metrics.step.store(step, Ordering::Relaxed);
        let out = self.refresh_recovery_image();
        if let Some(t0) = t0 {
            self.metrics.commit_ms.observe(t0.elapsed().as_secs_f64() * 1e3);
        }
        out
    }

    /// The barrier is complete: coalesce, step the shards, acknowledge
    /// the waiters in client-id order. Synchronous mode only.
    fn apply_pending_step(&mut self) -> Result<()> {
        let (applied, grads) = match &mut self.ingest {
            Ingest::Sync(b) => (b.pending_step(), b.take_coalesced()),
            Ingest::Async(_) => bail!("apply_pending_step is the synchronous barrier path"),
        };
        self.apply_coalesced(applied, grads)?;
        self.barrier_since = None;
        // Reply in client-id order, like the coalescing reduction.
        self.waiters.sort_by_key(|w| w.0);
        for (_, tx) in self.waiters.drain(..) {
            tx.send(Msg::Ack { step: applied }).ok();
        }
        Ok(())
    }

    /// Async mode: commit everything pending as one coalesced partial
    /// batch — fixed member-id order, scale `1/n` — append it to the
    /// commit log, and acknowledge exactly the contributors. A no-op
    /// when nothing is pending (or in sync mode), so the coordinator
    /// loop calls it unconditionally after draining the queue.
    fn flush_async(&mut self) -> Result<()> {
        let (step, commit) = match &mut self.ingest {
            Ingest::Async(acc) => match acc.take_commit() {
                Some(c) => (acc.applied_step(), c),
                None => return Ok(()),
            },
            Ingest::Sync(_) => return Ok(()),
        };
        let meta: Vec<Contributor> = commit
            .iter()
            .map(|(c, base, _)| Contributor { client: *c, base_step: *base })
            .collect();
        let parts: Vec<(u32, Vec<Tensor>)> =
            commit.into_iter().map(|(c, _, g)| (c, g)).collect();
        let coalesced = shard::coalesce_commit(&parts)?;
        let flat: Vec<Vec<f32>> = coalesced.iter().map(|t| t.data().to_vec()).collect();
        self.apply_coalesced(step, coalesced)?;
        if let Some(log) = &mut self.log {
            log.append(step, self.epoch, &meta, &flat)
                .context("appending to the commit log")?;
        }
        // Acknowledge exactly the contributors, in member-id order
        // (meta is already sorted — take_commit sorts the batch).
        for m in &meta {
            let mut i = 0;
            while i < self.waiters.len() {
                if self.waiters[i].0 == m.client {
                    let (_, tx) = self.waiters.remove(i);
                    tx.send(Msg::Ack { step }).ok();
                } else {
                    i += 1;
                }
            }
        }
        Ok(())
    }

    fn is_async(&self) -> bool {
        matches!(self.ingest, Ingest::Async(_))
    }

    /// Deadline check: an assembling barrier older than the timeout
    /// evicts every member that has not pushed and completes the step
    /// over the survivors.
    fn tick(&mut self) -> Result<()> {
        let Some(timeout) = self.client_timeout else { return Ok(()) };
        let Ingest::Sync(batcher) = &mut self.ingest else {
            // Async mode has no barrier to time out: a straggler delays
            // only its own contribution, never the fleet.
            return Ok(());
        };
        if batcher.received() == 0 {
            // Nothing pending (or a leave drained the barrier) — the
            // deadline re-arms at the next first push.
            self.barrier_since = None;
            return Ok(());
        }
        let Some(since) = self.barrier_since else { return Ok(()) };
        if since.elapsed() < timeout {
            return Ok(());
        }
        let evicted = batcher.evict_unpushed();
        self.metrics.evictions.fetch_add(evicted.len() as u64, Ordering::Relaxed);
        self.bump_epoch();
        self.apply_pending_step()
    }

    /// Serve one request. Returns `true` when the request was a
    /// `Shutdown`.
    fn handle(&mut self, req: Request, busy: &AtomicU64) -> Result<bool> {
        match req.msg {
            Msg::PushGrad { client, epoch, step, base_step, grads } => {
                let _span = obs_trace::span("server", "server.push");
                if epoch != self.epoch {
                    // The membership changed since this client last
                    // looked: a typed reply, so the client refreshes and
                    // retries instead of string-matching an error.
                    req.reply.send(Msg::StaleEpoch { epoch: self.epoch }).ok();
                } else {
                    let mut complete = false;
                    match &mut self.ingest {
                        Ingest::Sync(batcher) => {
                            // v3 pushes carry the step the gradient was
                            // computed at; the barrier path demands the
                            // previous step exactly — anything else is a
                            // client driving the wrong mode.
                            if step == 0 || base_step != step - 1 {
                                req.reply
                                    .send(Msg::Err {
                                        msg: format!(
                                            "synchronous push for step {step} must carry \
                                             base_step {} (got {base_step})",
                                            step.saturating_sub(1)
                                        ),
                                    })
                                    .ok();
                            } else {
                                match batcher.offer(client, step, grads) {
                                    Offer::Rejected(msg) => {
                                        req.reply.send(Msg::Err { msg }).ok();
                                    }
                                    Offer::Accepted => {
                                        self.metrics.pushes.fetch_add(1, Ordering::Relaxed);
                                        self.barrier_since.get_or_insert_with(Instant::now);
                                        self.waiters.push((client, req.reply));
                                    }
                                    Offer::Completed => {
                                        self.metrics.pushes.fetch_add(1, Ordering::Relaxed);
                                        self.waiters.push((client, req.reply));
                                        complete = true;
                                    }
                                }
                            }
                        }
                        Ingest::Async(acc) => {
                            // `step` is advisory here — the server, not
                            // the client, decides which commit a push
                            // joins; `base_step` is what the window
                            // check runs on.
                            match acc.offer(client, base_step, grads) {
                                AsyncOffer::Rejected(msg) => {
                                    req.reply.send(Msg::Err { msg }).ok();
                                }
                                AsyncOffer::TooStale { applied, required } => {
                                    req.reply
                                        .send(Msg::TooStale { applied, required })
                                        .ok();
                                }
                                AsyncOffer::Accepted => {
                                    self.metrics.pushes.fetch_add(1, Ordering::Relaxed);
                                    self.waiters.push((client, req.reply));
                                }
                            }
                        }
                    }
                    if complete {
                        self.apply_pending_step()?;
                    }
                }
            }
            Msg::Join => {
                if self.ingest.width() >= protocol::MAX_MEMBERS {
                    req.reply
                        .send(Msg::Err {
                            msg: format!(
                                "membership is full ({} members)",
                                protocol::MAX_MEMBERS
                            ),
                        })
                        .ok();
                } else {
                    let id = self.next_client_id;
                    self.next_client_id += 1;
                    match self.ingest.join(id) {
                        Ok(()) => {
                            self.bump_epoch();
                            req.reply.send(self.epoch_view(id)).ok();
                        }
                        // Unreachable (the id is fresh), but never panic
                        // the coordinator over a reply.
                        Err(msg) => {
                            req.reply.send(Msg::Err { msg }).ok();
                        }
                    }
                }
            }
            Msg::Leave { client } => {
                let outcome = match &mut self.ingest {
                    Ingest::Sync(b) => b.leave(client).map(|o| (o.had_pending, o.completed)),
                    // An async leave can never complete a barrier; it
                    // only narrows the membership and discards the
                    // leaver's pending contribution (if any).
                    Ingest::Async(a) => a.leave(client).map(|had| (had, false)),
                };
                match outcome {
                    Ok((had_pending, completed)) => {
                        self.bump_epoch();
                        req.reply.send(self.epoch_view(client)).ok();
                        if had_pending {
                            // The leaver's pending push was discarded — its
                            // blocked waiter (if the leave came from another
                            // connection) must not see an Ack for a step its
                            // gradient did not join.
                            let mut i = 0;
                            while i < self.waiters.len() {
                                if self.waiters[i].0 == client {
                                    let (_, tx) = self.waiters.remove(i);
                                    tx.send(Msg::Err {
                                        msg: format!("client {client} left the barrier"),
                                    })
                                    .ok();
                                } else {
                                    i += 1;
                                }
                            }
                        }
                        if completed {
                            self.apply_pending_step()?;
                        }
                    }
                    Err(msg) => {
                        req.reply.send(Msg::Err { msg }).ok();
                    }
                }
            }
            Msg::EpochInfo => {
                req.reply.send(self.epoch_view(protocol::NO_CLIENT)).ok();
            }
            Msg::PullParams { min_step, mode } => {
                // The bounded-staleness read contract, honored in both
                // modes (a sync client always sends floor 0): a pull
                // never hands out parameters older than the caller's
                // declared floor.
                let applied = self.ingest.applied_step();
                if applied < min_step {
                    req.reply.send(Msg::TooStale { applied, required: min_step }).ok();
                } else if mode == protocol::PULL_FACTORED {
                    // Factored mode ships the optimizer state in its
                    // native compressed encoding — for SMMF, the u/v
                    // factor vectors plus packed 1-bit sign planes —
                    // and the client reconstructs dense momenta. The
                    // decode layer already validated `mode`.
                    match self.shards.collect_state() {
                        Ok((_opt_step, _live, blobs)) => {
                            req.reply.send(Msg::StateBlobs { step: applied, blobs }).ok();
                        }
                        Err(e) => {
                            req.reply.send(Msg::Err { msg: format!("{e:#}") }).ok();
                        }
                    }
                } else {
                    let tensors = self.params.iter().map(|t| t.data().to_vec()).collect();
                    req.reply.send(Msg::Params { step: applied, tensors }).ok();
                }
            }
            Msg::Snapshot { path } => {
                // In resilient mode the per-step recovery image *is* the
                // snapshot (same writer, byte-identical) — and it stays
                // serveable even while a killed shard worker is down.
                let result = if self.resilient {
                    match &self.recovery_bytes {
                        Some(bytes) => checkpoint::write_snapshot_bytes(Path::new(&path), bytes),
                        None => Err(anyhow!("no recovery image yet")),
                    }
                } else {
                    // Streamed: a sizing pass collects only the blob
                    // lengths, then each tensor's state crosses the
                    // coordinator one blob at a time on its way into
                    // the file — the full optimizer state is never
                    // materialized here, so any-size inventories
                    // snapshot in O(largest tensor) memory. Byte-
                    // identical to the dense `save_snapshot` path by
                    // construction (pinned in checkpoint.rs).
                    (|| {
                        let (opt_step, lens) = self.shards.collect_blob_lens()?;
                        let shards = &self.shards;
                        checkpoint::save_snapshot_streamed(
                            Path::new(&path),
                            self.ingest.applied_step(),
                            &self.names,
                            &self.params,
                            self.base_lr,
                            &self.schedule,
                            self.kind,
                            opt_step,
                            &lens,
                            &self.config_section,
                            &mut |t| shards.collect_blob(t),
                        )
                    })()
                };
                match result {
                    Ok(bytes) => {
                        self.metrics.snapshots.fetch_add(1, Ordering::Relaxed);
                        req.reply.send(Msg::SnapshotDone { bytes }).ok();
                    }
                    Err(e) => {
                        req.reply.send(Msg::Err { msg: format!("{e:#}") }).ok();
                    }
                }
            }
            Msg::Stats => {
                self.metrics.busy.store(busy.load(Ordering::Relaxed), Ordering::Relaxed);
                req.reply.send(Msg::StatsReply(self.metrics.stats())).ok();
            }
            Msg::MetricsDump => {
                // The observability sibling of Stats: same atomics,
                // richer rendering (histograms included).
                self.metrics.busy.store(busy.load(Ordering::Relaxed), Ordering::Relaxed);
                req.reply.send(Msg::MetricsText { text: self.metrics.exposition() }).ok();
            }
            Msg::Shutdown => {
                req.reply.send(Msg::Bye).ok();
                return Ok(true);
            }
            other => {
                req.reply
                    .send(Msg::Err { msg: format!("{} is not a request", other.name()) })
                    .ok();
            }
        }
        Ok(false)
    }
}

impl Server {
    /// Bind, spawn the shard workers, the coordinator and the accept
    /// loop. `cfg` supplies the optimizer recipe (kind, hyperparameters,
    /// `[[optimizer.group]]` policies, LR schedule, seed); `opts` the
    /// serving topology and fault-tolerance knobs.
    pub fn start(cfg: &ExperimentConfig, opts: &ServeOptions) -> Result<Server> {
        if opts.commit_log.is_some() && opts.staleness == 0 {
            bail!(
                "--commit-log needs --staleness >= 1 — the synchronous barrier path is \
                 already pinned by `repro loadgen --check`, the log exists to replay \
                 async runs"
            );
        }
        let inv = resolve_inventory(&opts.model)?;
        let specs = inv.param_specs();
        let shapes = inv.shapes();
        let names: Vec<String> = inv.tensors.iter().map(|t| t.name.clone()).collect();
        let res = group::resolve(&specs, &cfg.grouped());
        let config_section = ConfigSection::from_config(&cfg.optim, &res);
        let (shards, params, first_step) = match &opts.resume {
            None => {
                let shards = ShardSet::spawn(
                    cfg.optimizer,
                    &shapes,
                    &cfg.optim,
                    &res.tensor,
                    opts.shards,
                );
                // Parameters start at the origin, like the synthetic
                // suite workload — clients own the loss surface
                // (targets + noise).
                let params: Vec<Tensor> = shapes.iter().map(|s| Tensor::zeros(s)).collect();
                (shards, params, 1)
            }
            Some(path) => restore_serving_state(
                path,
                cfg,
                &names,
                &shapes,
                &config_section,
                &res.tensor,
                opts.shards,
            )?,
        };

        let listener = TcpListener::bind(&opts.addr)
            .with_context(|| format!("binding {}", opts.addr))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;

        let shutdown = Arc::new(AtomicBool::new(false));
        let kill_shard = Arc::new(AtomicUsize::new(0));
        let busy = Arc::new(AtomicU64::new(0));
        let metrics = ServerMetrics::new();
        metrics.shards.store(opts.shards as u64, Ordering::Relaxed);
        metrics.clients.store(opts.clients as u64, Ordering::Relaxed);
        metrics.step.store(first_step - 1, Ordering::Relaxed);
        metrics.epoch.store(1, Ordering::Relaxed);
        metrics.staleness.store(opts.staleness, Ordering::Relaxed);
        let (req_tx, req_rx) = mpsc::sync_channel::<Request>(opts.max_pending);

        let acceptor = {
            let shutdown = shutdown.clone();
            let busy = busy.clone();
            let metrics = metrics.clone();
            // Handlers need the inventory shapes to size push-stream
            // reassembly up front (the trusted-length fast path).
            let shapes = Arc::new(shapes.clone());
            thread::spawn(move || loop {
                if shutdown.load(Ordering::SeqCst) {
                    return;
                }
                match listener.accept() {
                    Ok((stream, _)) => {
                        let req_tx = req_tx.clone();
                        let busy = busy.clone();
                        let shapes = shapes.clone();
                        let metrics = metrics.clone();
                        thread::spawn(move || handle_conn(stream, req_tx, busy, shapes, metrics));
                    }
                    // WouldBlock (idle) and transient accept errors both
                    // back off briefly; only the shutdown flag exits.
                    Err(_) => thread::sleep(Duration::from_millis(2)),
                }
            })
        };

        let ingest = if opts.staleness == 0 {
            Ingest::Sync(StepBatcher::with_members(
                (0..opts.clients as u32).collect(),
                shapes.clone(),
                first_step,
            ))
        } else {
            Ingest::Async(AsyncAccumulator::with_members(
                (0..opts.clients as u32).collect(),
                shapes.clone(),
                opts.staleness,
                first_step,
            ))
        };
        let log = match &opts.commit_log {
            None => None,
            Some(path) => Some(
                CommitLogWriter::create(
                    Path::new(path),
                    &LogInfo {
                        model: opts.model.clone(),
                        optimizer: cfg.optimizer.name().to_string(),
                        seed: cfg.seed,
                        base_lr: cfg.optim.lr,
                        staleness: opts.staleness,
                        first_step,
                    },
                )
                .with_context(|| format!("creating commit log {path:?}"))?
                .with_append_timing(metrics.log_append_ms.clone()),
            ),
        };

        let coordinator = {
            let shutdown = shutdown.clone();
            let busy = busy.clone();
            let kill = kill_shard.clone();
            let mut coord = Coordinator {
                metrics: metrics.clone(),
                params,
                ingest,
                log,
                shards,
                waiters: Vec::new(),
                names,
                base_lr: cfg.optim.lr,
                schedule: cfg.schedule.clone(),
                kind: cfg.optimizer,
                config_section,
                epoch: 1,
                next_client_id: opts.clients as u32,
                resilient: opts.resilient,
                recovery_bytes: None,
                client_timeout: (opts.client_timeout_ms > 0)
                    .then(|| Duration::from_millis(opts.client_timeout_ms)),
                barrier_since: None,
            };
            // Seed the recovery image before serving: a shard killed
            // before the first applied step must still be restorable.
            coord.refresh_recovery_image().context("seeding the crash-recovery image")?;
            thread::spawn(move || -> Result<ServerStats> {
                let run = (|| -> Result<()> {
                    loop {
                        // Chaos harness: an injected shard kill lands
                        // here, on the coordinator thread, between
                        // requests.
                        let k = kill.swap(0, Ordering::SeqCst);
                        if k > 0 {
                            coord.shards.kill(k - 1);
                        }
                        // A short recv timeout keeps the eviction
                        // deadline live while the barrier is stalled
                        // (no requests arriving to drive the loop).
                        match req_rx.recv_timeout(Duration::from_millis(5)) {
                            Ok(req) => {
                                if coord.handle(req, &busy)? {
                                    return Ok(());
                                }
                                // Async mode: drain everything already
                                // queued before committing, so pushes
                                // that arrived together coalesce into
                                // one partial batch instead of one
                                // commit each.
                                if coord.is_async() {
                                    while let Ok(req) = req_rx.try_recv() {
                                        if coord.handle(req, &busy)? {
                                            return Ok(());
                                        }
                                    }
                                    coord.flush_async()?;
                                }
                            }
                            Err(RecvTimeoutError::Timeout) => {}
                            Err(RecvTimeoutError::Disconnected) => return Ok(()),
                        }
                        coord.tick()?;
                    }
                })();
                // Teardown: unblock any barrier waiters, stop accepting,
                // join the shard workers — whether we exit via Shutdown
                // or a shard failure.
                for (_, tx) in coord.waiters.drain(..) {
                    tx.send(Msg::Err { msg: "server shutting down".into() }).ok();
                }
                shutdown.store(true, Ordering::SeqCst);
                let Coordinator { shards, metrics, .. } = coord;
                shards.stop();
                run?;
                metrics.busy.store(busy.load(Ordering::Relaxed), Ordering::Relaxed);
                Ok(metrics.stats())
            })
        };

        Ok(Server {
            addr,
            shutdown,
            kill_shard,
            coordinator: Some(coordinator),
            acceptor: Some(acceptor),
        })
    }

    /// Chaos harness: kill shard `s`'s worker thread (simulated crash).
    /// The coordinator notices the poisoned channel on the next step and
    /// — in resilient mode — respawns and resumes it; without
    /// `resilient` the server fails, which is the point of the knob.
    pub fn kill_shard(&self, s: usize) {
        self.kill_shard.store(s + 1, Ordering::SeqCst);
    }

    /// Block until the server shuts down; returns the final counters.
    pub fn wait(mut self) -> Result<ServerStats> {
        let stats = self
            .coordinator
            .take()
            .expect("wait() is called once")
            .join()
            .map_err(|_| anyhow!("server coordinator panicked"))?;
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        stats
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        // Belt and braces: an abandoned handle must not keep the accept
        // loop spinning.
        self.shutdown.store(true, Ordering::SeqCst);
    }
}

/// The last pull reply a handler served, retained so a [`Msg::Resend`]
/// is answered locally instead of re-asking the coordinator (whose
/// state may have advanced — a resent chunk must come from the *same*
/// reply the client is assembling). Holding one encoded reply per
/// connection costs exactly what v3 spent buffering the whole `Params`
/// frame; the O(chunk) memory guarantee is about framing buffers, not
/// the reply a handler is mid-way through serving.
struct PullCache {
    step: u64,
    mode: u8,
    /// Per-tensor encoded payloads: f32 LE for dense pulls, native
    /// optimizer state blobs for factored ones.
    tensors: Vec<Vec<u8>>,
    /// `chunk_plan` of each tensor — deterministic, so the plan both
    /// ends derive is the address space `Resend {tensor_idx, seq}`
    /// indexes into.
    plans: Vec<Vec<(u64, u64)>>,
}

impl PullCache {
    fn new(step: u64, mode: u8, tensors: Vec<Vec<u8>>, row_bytes: u64) -> PullCache {
        let plans = tensors
            .iter()
            .map(|b| protocol::chunk_plan(b.len() as u64, row_bytes, protocol::CHUNK_MAX_BYTES))
            .collect();
        PullCache { step, mode, tensors, plans }
    }

    /// Write one `ChunkHeader` + `ChunkData` pair. `None` when the
    /// `(tensor, seq)` address is outside this reply.
    fn write_chunk(
        &self,
        w: &mut impl std::io::Write,
        id: u64,
        tensor_idx: u32,
        seq: u32,
    ) -> Option<std::io::Result<()>> {
        let bytes = self.tensors.get(tensor_idx as usize)?;
        let plan = self.plans.get(tensor_idx as usize)?;
        let &(start, count) = plan.get(seq as usize)?;
        let hdr = Msg::ChunkHeader {
            tensor_idx,
            seq,
            total: plan.len() as u32,
            start,
            count,
            tensor_len: bytes.len() as u64,
        };
        let data = Msg::ChunkData {
            tensor_idx,
            seq,
            bytes: bytes[start as usize..(start + count) as usize].to_vec(),
        };
        Some(
            protocol::write_frame(w, &Frame { request_id: id, msg: hdr })
                .and_then(|()| protocol::write_frame(w, &Frame { request_id: id, msg: data })),
        )
    }

    /// Stream the whole reply: `ParamsBegin`, every chunk pair in
    /// order, `StreamEnd`.
    fn write_stream(&self, w: &mut impl std::io::Write, id: u64) -> std::io::Result<()> {
        let n = self.tensors.len() as u32;
        protocol::write_frame(
            w,
            &Frame {
                request_id: id,
                msg: Msg::ParamsBegin { step: self.step, mode: self.mode, n_tensors: n },
            },
        )?;
        for t in 0..self.tensors.len() {
            for seq in 0..self.plans[t].len() {
                self.write_chunk(w, id, t as u32, seq as u32)
                    .expect("iterating our own plan")?;
            }
        }
        protocol::write_frame(
            w,
            &Frame { request_id: id, msg: Msg::StreamEnd { step: self.step, tensors: n } },
        )
    }
}

/// How a push stream (the frames after a `PushBegin`) ended.
enum PushStream {
    /// Fully assembled — forward to the coordinator.
    Grads(Vec<Vec<f32>>),
    /// Assembly failed, but the stream was drained through its
    /// `StreamEnd`, so the connection is still framed: answer `Err`
    /// and keep serving.
    Bad(String),
    /// Framing violation or read error — close the connection (after
    /// one last `Err` frame when there is a message to send).
    Dead(Option<String>),
}

/// Consume chunk frames until `StreamEnd`, reassembling them against
/// the inventory's known per-tensor byte lengths. A chunk the
/// assembler rejects (duplicate, overlap, out of bounds) poisons the
/// stream but does NOT abort the read: the remaining frames are
/// drained so the typed error can be delivered in-band and the
/// connection survives. Only a frame that breaks the stream discipline
/// itself — a different request id, a non-chunk op, a read error — is
/// unrecoverable.
fn read_push_stream(
    reader: &mut impl std::io::Read,
    id: u64,
    n_tensors: u32,
    shapes: &[Vec<usize>],
    rx_bytes: &AtomicU64,
) -> PushStream {
    let mut err: Option<String> = None;
    let mut asm = if n_tensors as usize == shapes.len() {
        let lens: Vec<u64> =
            shapes.iter().map(|s| 4 * s.iter().product::<usize>() as u64).collect();
        Some(protocol::ChunkAssembler::for_lens(&lens))
    } else {
        err = Some(format!(
            "push announces {n_tensors} tensors, the workload has {}",
            shapes.len()
        ));
        None
    };
    loop {
        let frame = match protocol::read_frame_counted(reader) {
            Ok((f, n)) => {
                rx_bytes.fetch_add(n, Ordering::Relaxed);
                f
            }
            Err(_) => return PushStream::Dead(None),
        };
        if frame.request_id != id {
            return PushStream::Dead(Some(format!(
                "request id changed mid-stream ({id} -> {})",
                frame.request_id
            )));
        }
        match frame.msg {
            Msg::ChunkHeader { tensor_idx, seq, total, start, count, tensor_len } => {
                if let (Some(a), None) = (asm.as_mut(), &err) {
                    if let Err(e) = a.header(tensor_idx, seq, total, start, count, tensor_len)
                    {
                        err = Some(e.to_string());
                    }
                }
            }
            Msg::ChunkData { tensor_idx, seq, bytes } => {
                if let (Some(a), None) = (asm.as_mut(), &err) {
                    if let Err(e) = a.data(tensor_idx, seq, &bytes) {
                        err = Some(e.to_string());
                    }
                }
            }
            Msg::StreamEnd { .. } => break,
            other => {
                return PushStream::Dead(Some(format!(
                    "{} inside a push stream",
                    other.name()
                )))
            }
        }
    }
    if let Some(msg) = err {
        return PushStream::Bad(msg);
    }
    // err is None, so the tensor-count check passed and asm exists.
    match asm.expect("assembler exists when no error was recorded").finish_f32() {
        Ok(grads) => PushStream::Grads(grads),
        Err(e) => PushStream::Bad(format!("{e:#}")),
    }
}

/// Forwarding writer that counts every byte it passes through — the
/// tx half of the handler's stream-byte accounting (pull streams and
/// resent chunks).
struct CountWriter<'a, W: std::io::Write> {
    inner: &'a mut W,
    counter: &'a AtomicU64,
}

impl<W: std::io::Write> std::io::Write for CountWriter<'_, W> {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        let n = self.inner.write(buf)?;
        self.counter.fetch_add(n as u64, Ordering::Relaxed);
        Ok(n)
    }

    fn flush(&mut self) -> std::io::Result<()> {
        self.inner.flush()
    }
}

/// Forward one assembled request to the coordinator and wait for its
/// reply. A full queue is answered with `Busy` right here — the
/// explicit backpressure path.
fn forward(req_tx: &SyncSender<Request>, busy: &AtomicU64, msg: Msg) -> Msg {
    let (rtx, rrx) = mpsc::channel::<Msg>();
    match req_tx.try_send(Request { reply: rtx, msg }) {
        Ok(()) => rrx.recv().unwrap_or(Msg::Err { msg: "server stopped".into() }),
        Err(TrySendError::Full(_)) => {
            busy.fetch_add(1, Ordering::Relaxed);
            Msg::Busy
        }
        Err(TrySendError::Disconnected(_)) => Msg::Err { msg: "server stopped".into() },
    }
}

/// Per-connection handler: strictly sequential request → reply, where
/// a "request" is either a single frame or a whole chunk stream
/// (`PushBegin` … `StreamEnd`) and a reply is either a single frame or
/// a whole pull stream. The handler is the chunking boundary — the
/// coordinator only ever sees assembled [`Msg::PushGrad`] /
/// [`Msg::PullParams`] and answers with whole-tensor internal
/// messages.
fn handle_conn(
    stream: TcpStream,
    req_tx: SyncSender<Request>,
    busy: Arc<AtomicU64>,
    shapes: Arc<Vec<Vec<usize>>>,
    metrics: ServerMetrics,
) {
    stream.set_nodelay(true).ok();
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = std::io::BufReader::new(read_half);
    let mut writer = std::io::BufWriter::new(stream);
    let mut last_pull: Option<PullCache> = None;
    loop {
        // Read errors (EOF on client disconnect, or a malformed frame)
        // end the connection; the protocol has no resync point.
        let Ok((frame, frame_bytes)) = protocol::read_frame_counted(&mut reader) else {
            return;
        };
        let id = frame.request_id;
        match frame.msg {
            Msg::PushBegin { client, epoch, step, base_step, n_tensors } => {
                metrics.stream_rx_bytes.fetch_add(frame_bytes, Ordering::Relaxed);
                let reply = match read_push_stream(
                    &mut reader,
                    id,
                    n_tensors,
                    &shapes,
                    &metrics.stream_rx_bytes,
                ) {
                    PushStream::Grads(grads) => forward(
                        &req_tx,
                        &busy,
                        Msg::PushGrad { client, epoch, step, base_step, grads },
                    ),
                    PushStream::Bad(msg) => Msg::Err { msg },
                    PushStream::Dead(last_words) => {
                        if let Some(msg) = last_words {
                            protocol::write_frame(
                                &mut writer,
                                &Frame { request_id: id, msg: Msg::Err { msg } },
                            )
                            .ok();
                        }
                        return;
                    }
                };
                if protocol::write_frame(&mut writer, &Frame { request_id: id, msg: reply })
                    .is_err()
                {
                    return;
                }
            }
            Msg::PullParams { min_step, mode } => {
                let cache = match forward(&req_tx, &busy, Msg::PullParams { min_step, mode }) {
                    Msg::Params { step, tensors } => PullCache::new(
                        step,
                        protocol::PULL_DENSE,
                        tensors.iter().map(|t| protocol::f32s_to_bytes(t)).collect(),
                        4, // row-align chunks to whole f32s
                    ),
                    Msg::StateBlobs { step, blobs } => {
                        // Opaque blobs have no row structure to align.
                        PullCache::new(step, protocol::PULL_FACTORED, blobs, 0)
                    }
                    other => {
                        // TooStale / Busy / Err — a single typed frame,
                        // no stream, nothing cached.
                        if protocol::write_frame(
                            &mut writer,
                            &Frame { request_id: id, msg: other },
                        )
                        .is_err()
                        {
                            return;
                        }
                        continue;
                    }
                };
                let ok = cache
                    .write_stream(
                        &mut CountWriter {
                            inner: &mut writer,
                            counter: &metrics.stream_tx_bytes,
                        },
                        id,
                    )
                    .is_ok();
                last_pull = Some(cache);
                if !ok {
                    return;
                }
            }
            Msg::Resend { tensor_idx, seq } => {
                // Recovery is local: re-emit the chunk from the cached
                // reply. The reply pair echoes the *Resend's* id — the
                // assembler addresses chunks by (tensor, seq), not id.
                let outcome = match &last_pull {
                    None => Some("no pull reply on this connection to resend from".into()),
                    Some(cache) => {
                        let mut counted = CountWriter {
                            inner: &mut writer,
                            counter: &metrics.stream_tx_bytes,
                        };
                        match cache.write_chunk(&mut counted, id, tensor_idx, seq) {
                            Some(Ok(())) => {
                                metrics.resends.fetch_add(1, Ordering::Relaxed);
                                None
                            }
                            Some(Err(_)) => return,
                            None => Some(format!(
                                "resend ({tensor_idx}, {seq}) is outside the last pull reply"
                            )),
                        }
                    }
                };
                if let Some(msg) = outcome {
                    if protocol::write_frame(
                        &mut writer,
                        &Frame { request_id: id, msg: Msg::Err { msg } },
                    )
                    .is_err()
                    {
                        return;
                    }
                }
            }
            msg @ (Msg::Snapshot { .. }
            | Msg::Stats
            | Msg::MetricsDump
            | Msg::Shutdown
            | Msg::Join
            | Msg::Leave { .. }
            | Msg::EpochInfo) => {
                let reply = forward(&req_tx, &busy, msg);
                let done = matches!(reply, Msg::Bye);
                if protocol::write_frame(&mut writer, &Frame { request_id: id, msg: reply })
                    .is_err()
                {
                    return;
                }
                if done {
                    return;
                }
            }
            other => {
                let msg = format!("{} is not a request", other.name());
                if protocol::write_frame(
                    &mut writer,
                    &Frame { request_id: id, msg: Msg::Err { msg } },
                )
                .is_err()
                {
                    return;
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Single-process reference trainer
// ---------------------------------------------------------------------------

/// The equivalent single-process trainer: one optimizer over the full
/// inventory, fed the identical per-client gradient streams coalesced
/// through the identical [`StepBatcher`] reduction, snapshotted through
/// the identical [`checkpoint::save_snapshot`] writer. A K-shard,
/// N-client server run must produce a byte-identical file — this is the
/// oracle of the determinism e2e and of `repro loadgen --check`.
/// Returns client 0's final (noise-free) loss.
pub fn reference_checkpoint(
    cfg: &ExperimentConfig,
    model: &str,
    n_clients: usize,
    steps: u64,
    path: &Path,
) -> Result<f32> {
    assert!(n_clients >= 1);
    reference_checkpoint_elastic(cfg, model, &[(1, (0..n_clients as u32).collect())], steps, path)
}

/// [`reference_checkpoint`] generalized to an *elastic* membership
/// schedule: `epochs` lists `(start_step, members)` entries, ascending
/// by start step and covering step 1 — at each step the last entry
/// whose start is `<= step` is the active member set. Only active
/// members draw from their gradient-noise streams, exactly like a
/// dropped or late-joining client on the server (a [`GradSource`] draws
/// nothing while it is not pushing). This is the oracle for chaos runs
/// whose membership changes at known step boundaries (a `--drop-client`
/// eviction lands deterministically at `drop + 1`). Returns the lowest
/// active member's final noise-free loss.
pub fn reference_checkpoint_elastic(
    cfg: &ExperimentConfig,
    model: &str,
    epochs: &[(u64, Vec<u32>)],
    steps: u64,
    path: &Path,
) -> Result<f32> {
    assert!(!epochs.is_empty() && epochs[0].0 == 1, "the schedule must cover step 1");
    assert!(epochs.windows(2).all(|w| w[0].0 < w[1].0), "epoch starts must ascend");
    let inv = resolve_inventory(model)?;
    let specs = inv.param_specs();
    let shapes = inv.shapes();
    let names: Vec<String> = inv.tensors.iter().map(|t| t.name.clone()).collect();
    let res: Resolution = group::resolve(&specs, &cfg.grouped());
    let mut opt = optim::build_with_policies(cfg.optimizer, &shapes, &cfg.optim, &res.tensor);
    let mut params: Vec<Tensor> = shapes.iter().map(|s| Tensor::zeros(s)).collect();
    // One source per member id appearing anywhere in the schedule.
    // Construction draws nothing from the noise stream, so a member's
    // stream position depends only on how many steps it was active for.
    let mut sources: BTreeMap<u32, GradSource> = epochs
        .iter()
        .flat_map(|(_, m)| m)
        .map(|&c| (c, GradSource::new(&shapes, cfg.seed, c)))
        .collect();
    let mut final_loss = f32::NAN;
    for step in 1..=steps {
        let members =
            &epochs.iter().rev().find(|(s, _)| *s <= step).expect("step 1 is covered").1;
        let flat: Vec<Vec<f32>> = params.iter().map(|t| t.data().to_vec()).collect();
        let mut barrier = StepBatcher::with_members(members.clone(), shapes.clone(), step);
        let mut sorted = members.clone();
        sorted.sort_unstable();
        for &c in &sorted {
            let src = sources.get_mut(&c).expect("every member has a source");
            let (loss, grads) = src.grads(&flat)?;
            if c == sorted[0] {
                final_loss = loss;
            }
            if let Offer::Rejected(msg) = barrier.offer(c, step, grads) {
                bail!("reference barrier rejected client {c}: {msg}");
            }
        }
        let grads = barrier.take_coalesced();
        opt.set_lr(cfg.schedule.at(cfg.optim.lr, step));
        opt.step(&mut params, &grads);
    }
    checkpoint::save_snapshot(
        path,
        steps,
        &names,
        &params,
        cfg.optim.lr,
        &cfg.schedule,
        cfg.optimizer,
        opt.opt_step(),
        opt.state_blobs(),
        &ConfigSection::from_config(&cfg.optim, &res),
    )?;
    Ok(final_loss)
}

// ---------------------------------------------------------------------------
// Commit-log replay
// ---------------------------------------------------------------------------

/// What [`replay_commit_log`] did, for the CLI summary line.
#[derive(Clone, Debug)]
pub struct ReplayReport {
    /// Workload the log was recorded against (from its header).
    pub model: String,
    /// Commits re-executed.
    pub commits: u64,
    /// Step counter after the last commit.
    pub final_step: u64,
    /// Size of the written snapshot.
    pub snapshot_bytes: u64,
}

/// Re-execute a commit log through the synchronous sharded machinery
/// and write the resulting SMMFCKPT snapshot to `out`. Because every
/// commit records the *coalesced* gradient in member-id order, replay
/// is deterministic even though the run it describes was asynchronous:
/// the log is the serialization the wall clock chose, and re-applying
/// it step by step reproduces the server's parameters and optimizer
/// state bit-for-bit — for any `n_shards`, equal to the recording run's
/// or not. The loader has already verified digests, step contiguity and
/// the staleness window by the time this runs.
pub fn replay_commit_log(
    cfg: &ExperimentConfig,
    log_path: &Path,
    n_shards: usize,
    out: &Path,
) -> Result<ReplayReport> {
    assert!(n_shards >= 1);
    let log = CommitLog::load(log_path)?;
    let h = &log.header;
    if h.optimizer != cfg.optimizer.name() {
        bail!(
            "commit log {log_path:?} was recorded under optimizer {}, the config says {}",
            h.optimizer,
            cfg.optimizer.name()
        );
    }
    if h.seed != cfg.seed {
        bail!(
            "commit log {log_path:?} was recorded under seed {}, the config says {}",
            h.seed,
            cfg.seed
        );
    }
    if h.base_lr.to_bits() != cfg.optim.lr.to_bits() {
        bail!(
            "commit log {log_path:?} was recorded under base LR {}, the config says {}",
            h.base_lr,
            cfg.optim.lr
        );
    }
    if h.first_step != 1 {
        bail!(
            "commit log {log_path:?} starts at step {} — it was recorded by a --resume'd \
             server; replay needs a log covering the run from step 1 (fresh optimizer \
             state has nothing to fast-forward from)",
            h.first_step
        );
    }
    let inv = resolve_inventory(&h.model)?;
    let specs = inv.param_specs();
    let shapes = inv.shapes();
    let names: Vec<String> = inv.tensors.iter().map(|t| t.name.clone()).collect();
    let res = group::resolve(&specs, &cfg.grouped());
    let config_section = ConfigSection::from_config(&cfg.optim, &res);
    let shards = ShardSet::spawn(cfg.optimizer, &shapes, &cfg.optim, &res.tensor, n_shards);
    let mut params: Vec<Tensor> = shapes.iter().map(|s| Tensor::zeros(s)).collect();
    let mut final_step = h.first_step - 1;
    for c in &log.commits {
        if c.grads.len() != shapes.len() {
            bail!(
                "commit {}: the log holds {} tensors, inventory {} has {}",
                c.step,
                c.grads.len(),
                h.model,
                shapes.len()
            );
        }
        let mut grads = Vec::with_capacity(shapes.len());
        for (i, (g, shape)) in c.grads.iter().zip(&shapes).enumerate() {
            let numel: usize = shape.iter().product();
            if g.len() != numel {
                bail!(
                    "commit {} tensor {i}: the log holds {} elements, shape {shape:?} \
                     needs {numel}",
                    c.step,
                    g.len()
                );
            }
            grads.push(Tensor::from_vec(shape, g.clone()));
        }
        let lr = cfg.schedule.at(cfg.optim.lr, c.step);
        shards
            .step(lr, &mut params, grads)
            .with_context(|| format!("replaying commit {}", c.step))?;
        final_step = c.step;
    }
    let (opt_step, _live, blobs) = shards.collect_state()?;
    let snapshot_bytes = checkpoint::save_snapshot(
        out,
        final_step,
        &names,
        &params,
        cfg.optim.lr,
        &cfg.schedule,
        cfg.optimizer,
        opt_step,
        blobs,
        &config_section,
    )?;
    shards.stop();
    Ok(ReplayReport {
        model: h.model.clone(),
        commits: log.commits.len() as u64,
        final_step,
        snapshot_bytes,
    })
}

// ---------------------------------------------------------------------------
// Load generator
// ---------------------------------------------------------------------------

/// Loadgen knobs, including the chaos-harness fault injectors. Faults
/// always target the *highest-id* client, so the surviving low ids
/// (client 0 in particular) drive the run to completion.
#[derive(Clone, Debug)]
pub struct LoadgenOptions {
    /// Concurrent connections (must equal the server's barrier width).
    pub clients: usize,
    /// Optimizer steps to drive.
    pub steps: u64,
    /// First step to drive (for resumed servers: the server is at
    /// `start_step - 1`; gradient-noise streams are fast-forwarded).
    pub start_step: u64,
    /// Slow-client fault: p95 milliseconds of an exponential think time
    /// injected before each of the highest-id client's pushes (0 = off).
    pub slow_client_ms: f64,
    /// Drop-client fault: the highest-id client silently stops after
    /// pushing this step — no polite `Leave`, like a crash (0 = off).
    /// With `client_timeout_ms` set the server evicts it at step
    /// `drop + 1`.
    pub drop_client_at: u64,
}

impl Default for LoadgenOptions {
    fn default() -> Self {
        Self { clients: 1, steps: 10, start_step: 1, slow_client_ms: 0.0, drop_client_at: 0 }
    }
}

/// Aggregate loadgen measurements: throughput plus push round-trip
/// latency percentiles (a push's round trip spans the step barrier and
/// the sharded optimizer step — it is the end-to-end step latency as one
/// client observes it).
#[derive(Clone, Debug)]
pub struct LoadgenReport {
    pub clients: usize,
    pub steps: u64,
    /// Total applied pushes (= clients × steps when nothing drops).
    pub pushes: u64,
    /// `Busy` bounces absorbed by client-side retries.
    pub busy_retries: u64,
    /// Clients that exited early because the server evicted them.
    pub evicted: u64,
    pub elapsed_s: f64,
    /// The server's staleness window (0 = synchronous barrier).
    pub staleness: u64,
    /// Optimizer steps per second. Sync: `steps / elapsed` (the barrier
    /// applies exactly `steps` of them). Async: the server-side step
    /// delta over `elapsed` — commit throughput, the number async mode
    /// exists to improve under stragglers.
    pub steps_per_s: f64,
    pub push_p50_ms: f64,
    pub push_p99_ms: f64,
    pub push_mean_ms: f64,
    /// Client 0's final noise-free loss (sanity: the well converges).
    pub final_loss: f32,
    /// Total wire traffic (both directions, all clients) divided by
    /// the applied steps — the per-step bandwidth cost of the chunked
    /// v4 protocol at this inventory scale.
    pub bytes_per_step: f64,
}

/// One client's share of a loadgen run.
struct ClientRun {
    latencies_ms: Vec<f64>,
    applied: u64,
    busy_retries: u64,
    final_loss: f32,
    evicted: bool,
    /// Wire bytes this client moved, both directions.
    bytes: u64,
}

fn drive_client(
    addr: &str,
    shapes: &[Vec<usize>],
    seed: u64,
    opts: &LoadgenOptions,
    c: usize,
) -> Result<ClientRun> {
    let mut client = Client::connect(addr)?;
    let mut src = GradSource::new(shapes, seed, c as u32);
    if opts.start_step > 1 {
        src.skip_steps(opts.start_step - 1);
    }
    let mut epoch = client.epoch_info()?.epoch;
    // Fault injection targets the highest-id client only.
    let faulty = c + 1 == opts.clients;
    let slow_ms = if faulty { opts.slow_client_ms } else { 0.0 };
    let drop_at = if faulty { opts.drop_client_at } else { 0 };
    let mut think = Pcg32::with_stream(seed ^ 0x51de_c43e, 0x51de + c as u64);
    let mut run = ClientRun {
        latencies_ms: Vec::with_capacity(opts.steps as usize),
        applied: 0,
        busy_retries: 0,
        final_loss: f32::NAN,
        evicted: false,
        bytes: 0,
    };
    let last = opts.start_step + opts.steps - 1;
    'steps: for step in opts.start_step..=last {
        if drop_at != 0 && step > drop_at {
            // Simulated crash: stop driving mid-run, no polite Leave —
            // the server's eviction deadline has to notice on its own.
            break;
        }
        let (at, params) = client.pull_params()?;
        if at >= step {
            // The barrier advanced without us: we were evicted.
            run.evicted = true;
            break;
        }
        if at != step - 1 {
            bail!(
                "client {c}: server at step {at}, expected {} — \
                 is another loadgen driving it?",
                step - 1
            );
        }
        let (loss, grads) = src.grads(&params)?;
        run.final_loss = loss;
        if slow_ms > 0.0 {
            // Exponential think time with p95 = slow_ms (the p95 of an
            // exponential is ln 20 ≈ 3.0 mean lifetimes).
            let u = (think.uniform() as f64).min(0.999_999);
            let ms = -(slow_ms / 3.0) * (1.0 - u).ln();
            thread::sleep(Duration::from_secs_f64(ms / 1e3));
        }
        let t = Instant::now();
        loop {
            match client.push_grad(c as u32, epoch, step, step - 1, grads.clone())? {
                PushOutcome::Applied(applied) => {
                    run.latencies_ms.push(t.elapsed().as_secs_f64() * 1e3);
                    run.applied += 1;
                    if applied != step {
                        bail!("client {c}: pushed step {step}, server applied {applied}");
                    }
                    break;
                }
                // Membership changed under us (someone joined, left or
                // was evicted): adopt the current epoch, retry the same
                // step — our pending slot is untouched.
                PushOutcome::Stale(current) => epoch = current,
                PushOutcome::TooStale { applied, required } => bail!(
                    "client {c}: a synchronous push was answered TooStale \
                     ({applied} < {required}) — is the server in async mode?"
                ),
                PushOutcome::Rejected(msg) if msg.contains("not a member") => {
                    run.evicted = true;
                    break 'steps;
                }
                PushOutcome::Rejected(msg) => bail!("client {c}: push rejected: {msg}"),
            }
        }
    }
    run.busy_retries = client.busy_retries;
    run.bytes = client.bytes_sent + client.bytes_received;
    Ok(run)
}

/// The async counterpart of [`drive_client`]: pull with a staleness
/// floor derived from the last acknowledged commit, compute a gradient
/// against whatever the server handed out, push it tagged with that
/// base step. A `TooStale` answer (the window moved on while this
/// client was thinking — stragglers earn these) re-pulls fresher
/// parameters and recomputes instead of retrying the stale gradient.
/// `opts.steps` counts *applied contributions* per client, so a run's
/// total work matches the sync mode's `clients × steps` pushes.
fn drive_client_async(
    addr: &str,
    shapes: &[Vec<usize>],
    seed: u64,
    opts: &LoadgenOptions,
    c: usize,
    staleness: u64,
) -> Result<ClientRun> {
    let mut client = Client::connect(addr)?;
    let mut src = GradSource::new(shapes, seed, c as u32);
    if opts.start_step > 1 {
        src.skip_steps(opts.start_step - 1);
    }
    let mut epoch = client.epoch_info()?.epoch;
    let faulty = c + 1 == opts.clients;
    let slow_ms = if faulty { opts.slow_client_ms } else { 0.0 };
    let mut think = Pcg32::with_stream(seed ^ 0x51de_c43e, 0x51de + c as u64);
    let mut run = ClientRun {
        latencies_ms: Vec::with_capacity(opts.steps as usize),
        applied: 0,
        busy_retries: 0,
        final_loss: f32::NAN,
        evicted: false,
        bytes: 0,
    };
    // The commit our last contribution landed in. Pulling with floor
    // `last_acked - staleness` pins the bounded-staleness read contract
    // from the client side: the server must never hand out parameters
    // further behind our own acknowledged progress than the window.
    let mut last_acked: u64 = 0;
    'pushes: while run.applied < opts.steps {
        let min_step = last_acked.saturating_sub(staleness);
        let (at, params) = match client.pull_params_at_least(min_step)? {
            PullReply::Params { step, tensors } => (step, tensors),
            PullReply::TooStale { applied, required } => bail!(
                "client {c}: pull floor {required} answered TooStale at step {applied} — \
                 did the server move backwards?"
            ),
        };
        if at < min_step {
            bail!(
                "client {c}: staleness window violated — the server handed out step {at} \
                 under a floor of {min_step}"
            );
        }
        let (loss, grads) = src.grads(&params)?;
        run.final_loss = loss;
        if slow_ms > 0.0 {
            // Exponential think time with p95 = slow_ms, same
            // distribution as the sync straggler fault.
            let u = (think.uniform() as f64).min(0.999_999);
            let ms = -(slow_ms / 3.0) * (1.0 - u).ln();
            thread::sleep(Duration::from_secs_f64(ms / 1e3));
        }
        let t = Instant::now();
        loop {
            match client.push_grad(c as u32, epoch, at + 1, at, grads.clone())? {
                PushOutcome::Applied(step) => {
                    run.latencies_ms.push(t.elapsed().as_secs_f64() * 1e3);
                    run.applied += 1;
                    last_acked = step;
                    break;
                }
                // Our base fell out of the window while we computed:
                // the gradient is unusably old, start the iteration
                // over with fresh parameters.
                PushOutcome::TooStale { .. } => continue 'pushes,
                PushOutcome::Stale(current) => epoch = current,
                PushOutcome::Rejected(msg) if msg.contains("not a member") => {
                    run.evicted = true;
                    break 'pushes;
                }
                PushOutcome::Rejected(msg) => bail!("client {c}: push rejected: {msg}"),
            }
        }
    }
    run.busy_retries = client.busy_retries;
    run.bytes = client.bytes_sent + client.bytes_received;
    Ok(run)
}

/// Drive `opts.clients` concurrent connections for `opts.steps` steps
/// against the server at `addr`. `shapes`/`seed` must match the
/// server's workload (the CLI derives both from the same config).
pub fn run_loadgen(
    addr: &str,
    shapes: &[Vec<usize>],
    seed: u64,
    opts: &LoadgenOptions,
) -> Result<LoadgenReport> {
    assert!(opts.clients >= 1 && opts.steps >= 1 && opts.start_step >= 1);
    // Probe the server's Stats to learn its mode and width, and fail
    // loudly on a driver/server mismatch instead of wedging:
    // * sync — a client count that disagrees with the barrier width
    //   would deadlock the first push (the barrier never completes);
    // * async — extra drivers are not members and every one of their
    //   pushes would bounce, so over-subscription is the same config
    //   error (fewer drivers than members is fine: nobody waits on an
    //   absent member in async mode).
    //
    // The probe must not race a concurrently *joining* member (elastic
    // runs Join on separate connections while a loadgen starts up): a
    // one-shot read could see the membership mid-negotiation and bail
    // on a width that would have settled a few milliseconds later. So
    // poll until the membership covers the driver count, and only
    // declare a mismatch once the deadline passes — a genuinely wrong
    // width still fails, just not spuriously early.
    let mut probe = Client::connect(addr)?;
    let deadline = Instant::now() + Duration::from_secs(5);
    let server = loop {
        let s = probe.stats()?;
        let settled = if s.staleness == 0 {
            s.clients as usize == opts.clients
        } else {
            opts.clients <= s.clients as usize
        };
        if settled || Instant::now() >= deadline {
            break s;
        }
        thread::sleep(Duration::from_millis(10));
    };
    drop(probe);
    let staleness = server.staleness;
    if staleness == 0 {
        if server.clients as usize != opts.clients {
            bail!(
                "loadgen drives {} client(s) but the server's step barrier is {} wide — \
                 pass --clients {} (or restart the server)",
                opts.clients,
                server.clients,
                server.clients
            );
        }
    } else if opts.clients > server.clients as usize {
        bail!(
            "loadgen drives {} client(s) but the async server's member table holds {} — \
             a non-member push is rejected; pass --clients {} or fewer \
             (or restart the server wider)",
            opts.clients,
            server.clients,
            server.clients
        );
    }
    let steps_before = server.step;
    let t0 = Instant::now();
    let results: Vec<Result<ClientRun>> = thread::scope(|s| {
        let handles: Vec<_> = (0..opts.clients)
            .map(|c| {
                s.spawn(move || {
                    if staleness == 0 {
                        drive_client(addr, shapes, seed, opts, c)
                    } else {
                        drive_client_async(addr, shapes, seed, opts, c, staleness)
                    }
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().unwrap_or_else(|_| Err(anyhow!("loadgen client panicked"))))
            .collect()
    });
    let elapsed_s = t0.elapsed().as_secs_f64();

    let mut all_ms = Vec::with_capacity(opts.clients * opts.steps as usize);
    let mut busy_retries = 0u64;
    let mut pushes = 0u64;
    let mut evicted = 0u64;
    let mut total_bytes = 0u64;
    let mut final_loss = f32::NAN;
    for (c, r) in results.into_iter().enumerate() {
        let run = r.with_context(|| format!("loadgen client {c}"))?;
        all_ms.extend(run.latencies_ms);
        busy_retries += run.busy_retries;
        pushes += run.applied;
        evicted += run.evicted as u64;
        total_bytes += run.bytes;
        if c == 0 {
            final_loss = run.final_loss;
        }
    }
    all_ms.sort_by(|a, b| a.partial_cmp(b).expect("latencies are finite"));
    let applied_steps = if staleness == 0 {
        // The barrier applies exactly `steps` optimizer steps.
        opts.steps
    } else {
        // Commit throughput: the server decides how pushes batch into
        // steps, so count what it actually applied.
        let after = Client::connect(addr)?.stats()?.step;
        after.saturating_sub(steps_before)
    };
    let steps_per_s = applied_steps as f64 / elapsed_s.max(1e-12);
    Ok(LoadgenReport {
        clients: opts.clients,
        steps: opts.steps,
        pushes,
        busy_retries,
        evicted,
        elapsed_s,
        staleness,
        steps_per_s,
        push_p50_ms: obs::metrics::percentile(&all_ms, 0.50),
        push_p99_ms: obs::metrics::percentile(&all_ms, 0.99),
        push_mean_ms: obs::metrics::mean(&all_ms),
        final_loss,
        bytes_per_step: total_bytes as f64 / applied_steps.max(1) as f64,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_options_validate_counts() {
        // TOML layer
        let doc = TomlDoc::parse(
            "[server]\nshards = 2\nclients = 4\nmax_pending = 8\n\
             client_timeout_ms = 250\nresilient = true",
        )
        .unwrap();
        let mut o = ServeOptions::default();
        o.apply_toml(&doc).unwrap();
        assert_eq!((o.shards, o.clients, o.max_pending), (2, 4, 8));
        assert_eq!((o.client_timeout_ms, o.resilient), (250, true));
        for bad in ["[server]\nshards = 0", "[server]\nclients = -3", "[server]\nshards = \"x\""]
        {
            let doc = TomlDoc::parse(bad).unwrap();
            let e = ServeOptions::default().apply_toml(&doc).unwrap_err();
            assert!(format!("{e:#}").contains(">= 1"), "{bad}: {e:#}");
        }
        // client_timeout_ms = 0 is valid (eviction off); negatives are not.
        let doc = TomlDoc::parse("[server]\nclient_timeout_ms = 0").unwrap();
        let mut o = ServeOptions::default();
        o.apply_toml(&doc).unwrap();
        assert_eq!(o.client_timeout_ms, 0);
        let doc = TomlDoc::parse("[server]\nclient_timeout_ms = -5").unwrap();
        let e = ServeOptions::default().apply_toml(&doc).unwrap_err();
        assert!(format!("{e:#}").contains(">= 0"), "{e:#}");
        // CLI layer
        let args = Args::parse(
            ["--shards", "3", "--clients", "2", "--client-timeout-ms", "100", "--resilient"]
                .iter()
                .map(|s| s.to_string()),
        );
        let mut o = ServeOptions::default();
        o.apply_args(&args).unwrap();
        assert_eq!((o.shards, o.clients), (3, 2));
        assert_eq!((o.client_timeout_ms, o.resilient), (100, true));
        let args = Args::parse(["--clients", "0"].iter().map(|s| s.to_string()));
        let e = ServeOptions::default().apply_args(&args).unwrap_err();
        assert!(format!("{e:#}").contains(">= 1"), "{e:#}");
        let args = Args::parse(["--client-timeout-ms", "-1"].iter().map(|s| s.to_string()));
        let e = ServeOptions::default().apply_args(&args).unwrap_err();
        assert!(format!("{e:#}").contains("non-negative"), "{e:#}");
    }

    #[test]
    fn serve_options_parse_staleness_and_commit_log() {
        let doc =
            TomlDoc::parse("[server]\nstaleness = 4\ncommit_log = \"log.bin\"").unwrap();
        let mut o = ServeOptions::default();
        o.apply_toml(&doc).unwrap();
        assert_eq!(o.staleness, 4);
        assert_eq!(o.commit_log.as_deref(), Some("log.bin"));
        let doc = TomlDoc::parse("[server]\nstaleness = -1").unwrap();
        let e = ServeOptions::default().apply_toml(&doc).unwrap_err();
        assert!(format!("{e:#}").contains(">= 0"), "{e:#}");
        let args = Args::parse(
            ["--staleness", "2", "--commit-log", "x.bin"].iter().map(|s| s.to_string()),
        );
        let mut o = ServeOptions::default();
        o.apply_args(&args).unwrap();
        assert_eq!((o.staleness, o.commit_log.as_deref()), (2, Some("x.bin")));
        let args = Args::parse(["--staleness", "-3"].iter().map(|s| s.to_string()));
        let e = ServeOptions::default().apply_args(&args).unwrap_err();
        assert!(format!("{e:#}").contains("non-negative"), "{e:#}");
    }

}
