//! State sharding: partition an inventory across K worker threads, each
//! owning the optimizer state for its tensor subset.
//!
//! The partition reuses the FLOP-balancing planner from the parallel
//! step engine ([`crate::optim::parallel::ParamPartition`]) over
//! whole-tensor units — one unsplittable [`TensorGeom`] per tensor, with
//! per-tensor cost weights derived from the resolved group policies
//! (stateless/frozen tensors are cheap to update, so the LPT packing
//! balances *effective* work, exactly like the intra-step engine). Every
//! optimizer in this crate updates tensors independently of each other
//! (the per-tensor state machines share only the internal step counter,
//! which each shard advances identically), so a sharded step is
//! bit-identical, tensor by tensor, to a single optimizer over the full
//! inventory — the property the server's snapshot e2e pins.
//!
//! Execution mirrors the persistent-worker topology of
//! `coordinator::workers::train_data_parallel`: each shard is one
//! long-lived `std::thread` owning its optimizer, driven over channels.
//! Tensor ownership *moves* through the channels (a `Vec<Tensor>` move
//! is pointer-sized — no data copies), so there is no shared mutable
//! state and no unsafe.

use anyhow::{anyhow, Result};
use std::sync::mpsc::{self, Receiver, Sender};
use std::thread::JoinHandle;

use crate::optim::parallel::{ParamPartition, TensorGeom};
use crate::optim::{self, OptKind, OptimConfig, Optimizer, StateSerde, TensorPolicy};
use crate::tensor::Tensor;

/// Assignment of inventory tensors to shards.
#[derive(Clone, Debug)]
pub struct ShardPlan {
    /// Number of shards (>= 1).
    pub n_shards: usize,
    /// Original tensor index -> owning shard.
    pub assign: Vec<usize>,
    /// Shard -> original tensor indices, ascending (the shard's local
    /// registration order).
    pub locals: Vec<Vec<usize>>,
}

/// Plan a K-way shard assignment over the inventory with the
/// FLOP-balancing partition planner (whole-tensor units; policy-aware
/// cost weights).
pub fn plan_shards(
    shapes: &[Vec<usize>],
    policies: &[TensorPolicy],
    n_shards: usize,
) -> ShardPlan {
    assert_eq!(shapes.len(), policies.len(), "one policy per tensor");
    let n_shards = n_shards.max(1);
    let geoms: Vec<TensorGeom> = shapes
        .iter()
        .zip(policies)
        .map(|(s, p)| {
            let numel = s.iter().product::<usize>();
            // Same relative weights as the step engine's planning:
            // frozen tensors are skipped entirely, stateless ones run the
            // cheap `w -= lr·g` path, stateful ones the full update.
            let cost = if p.frozen {
                1
            } else if p.stateless() {
                2
            } else {
                8
            };
            TensorGeom::whole(numel, cost)
        })
        .collect();
    let part = ParamPartition::plan(&geoms, n_shards);
    let mut assign = vec![0usize; shapes.len()];
    for it in part.items() {
        assign[it.tensor] = it.shard;
    }
    let mut locals = vec![Vec::new(); n_shards];
    for (t, &s) in assign.iter().enumerate() {
        locals[s].push(t);
    }
    ShardPlan { n_shards, assign, locals }
}

enum Cmd {
    /// Apply one optimizer step over the shard's tensors (ownership of
    /// the subsets moves in; the updated params move back).
    Step { lr: f32, params: Vec<Tensor>, grads: Vec<Tensor> },
    /// Collect the shard's serialized optimizer state.
    Collect,
    Stop,
}

enum Reply {
    Stepped { params: Vec<Tensor> },
    State { opt_step: u64, state_bytes: u64, blobs: Vec<Vec<u8>> },
}

struct ShardHandle {
    tx: Sender<Cmd>,
    rx: Receiver<Reply>,
    join: Option<JoinHandle<()>>,
}

/// K shard workers plus the plan mapping tensors onto them.
pub struct ShardSet {
    pub plan: ShardPlan,
    handles: Vec<ShardHandle>,
}

impl ShardSet {
    /// Plan the partition and spawn one worker per shard; each worker
    /// builds its optimizer over its tensor subset through the resolved
    /// per-tensor policy table ([`optim::build_subset`]), so per-group
    /// `StatePolicy` / lr-scale / weight-decay overrides survive
    /// sharding.
    pub fn spawn(
        kind: OptKind,
        shapes: &[Vec<usize>],
        cfg: &OptimConfig,
        policies: &[TensorPolicy],
        n_shards: usize,
    ) -> ShardSet {
        let plan = plan_shards(shapes, policies, n_shards);
        let mut handles = Vec::with_capacity(plan.n_shards);
        for s in 0..plan.n_shards {
            let (cmd_tx, cmd_rx) = mpsc::channel::<Cmd>();
            let (reply_tx, reply_rx) = mpsc::channel::<Reply>();
            let idx = plan.locals[s].clone();
            let shapes = shapes.to_vec();
            let cfg = cfg.clone();
            let policies = policies.to_vec();
            let join = std::thread::spawn(move || {
                let mut opt = optim::build_subset(kind, &shapes, &cfg, &policies, &idx);
                while let Ok(cmd) = cmd_rx.recv() {
                    match cmd {
                        Cmd::Step { lr, mut params, grads } => {
                            opt.set_lr(lr);
                            opt.step(&mut params, &grads);
                            if reply_tx.send(Reply::Stepped { params }).is_err() {
                                break;
                            }
                        }
                        Cmd::Collect => {
                            let reply = Reply::State {
                                opt_step: opt.opt_step(),
                                state_bytes: opt.state_bytes(),
                                blobs: opt.state_blobs(),
                            };
                            if reply_tx.send(reply).is_err() {
                                break;
                            }
                        }
                        Cmd::Stop => break,
                    }
                }
            });
            handles.push(ShardHandle { tx: cmd_tx, rx: reply_rx, join: Some(join) });
        }
        ShardSet { plan, handles }
    }

    /// Apply one coalesced optimizer step across all shards: scatter the
    /// per-shard parameter/gradient subsets (ownership moves, the master
    /// slots are back-filled with empty placeholders), run the shards
    /// concurrently, gather the updated parameters back in place.
    /// `grads` is consumed.
    pub fn step(&self, lr: f32, params: &mut [Tensor], grads: Vec<Tensor>) -> Result<()> {
        assert_eq!(params.len(), self.plan.assign.len());
        assert_eq!(grads.len(), self.plan.assign.len());
        let mut grads: Vec<Option<Tensor>> = grads.into_iter().map(Some).collect();
        // Empty shards (more shards than tensors) are skipped entirely —
        // their optimizers never step, and collect_state ignores them.
        for (s, h) in self.handles.iter().enumerate() {
            if self.plan.locals[s].is_empty() {
                continue;
            }
            let idx = &self.plan.locals[s];
            let sub_params: Vec<Tensor> = idx
                .iter()
                .map(|&t| std::mem::replace(&mut params[t], Tensor::scalar(0.0)))
                .collect();
            let sub_grads: Vec<Tensor> =
                idx.iter().map(|&t| grads[t].take().expect("each tensor scattered once")).collect();
            h.tx.send(Cmd::Step { lr, params: sub_params, grads: sub_grads })
                .map_err(|_| anyhow!("shard {s} worker is gone"))?;
        }
        for (s, h) in self.handles.iter().enumerate() {
            if self.plan.locals[s].is_empty() {
                continue;
            }
            match h.rx.recv() {
                Ok(Reply::Stepped { params: sub }) => {
                    for (&t, tensor) in self.plan.locals[s].iter().zip(sub) {
                        params[t] = tensor;
                    }
                }
                _ => return Err(anyhow!("shard {s} worker died mid-step")),
            }
        }
        Ok(())
    }

    /// Gather the serialized optimizer state from every shard, reordered
    /// into original inventory order: `(opt_step, live state bytes, one
    /// blob per tensor)`. Errors if the shards' internal step counters
    /// disagree (they advance in lockstep, so drift means a lost step).
    pub fn collect_state(&self) -> Result<(u64, u64, Vec<Vec<u8>>)> {
        let n_tensors = self.plan.assign.len();
        let mut blobs: Vec<Vec<u8>> = vec![Vec::new(); n_tensors];
        let mut opt_step = None;
        let mut state_bytes = 0u64;
        for (s, h) in self.handles.iter().enumerate() {
            if self.plan.locals[s].is_empty() {
                continue;
            }
            h.tx.send(Cmd::Collect).map_err(|_| anyhow!("shard {s} worker is gone"))?;
            match h.rx.recv() {
                Ok(Reply::State { opt_step: t, state_bytes: b, blobs: sub }) => {
                    if *opt_step.get_or_insert(t) != t {
                        return Err(anyhow!(
                            "shard {s} is at optimizer step {t}, others at {}",
                            opt_step.unwrap()
                        ));
                    }
                    state_bytes += b;
                    if sub.len() != self.plan.locals[s].len() {
                        return Err(anyhow!(
                            "shard {s} returned {} blobs for {} tensors",
                            sub.len(),
                            self.plan.locals[s].len()
                        ));
                    }
                    for (&t, blob) in self.plan.locals[s].iter().zip(sub) {
                        blobs[t] = blob;
                    }
                }
                _ => return Err(anyhow!("shard {s} worker died during state collection")),
            }
        }
        Ok((opt_step.unwrap_or(0), state_bytes, blobs))
    }

    /// Stop and join every worker.
    pub fn stop(mut self) {
        for h in &self.handles {
            let _ = h.tx.send(Cmd::Stop);
        }
        for h in &mut self.handles {
            if let Some(j) = h.join.take() {
                let _ = j.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::build_with_policies;
    use crate::util::rng::Pcg32;

    fn toy_shapes() -> Vec<Vec<usize>> {
        vec![vec![16, 8], vec![8], vec![4, 4, 2], vec![32], vec![1]]
    }

    fn uniform_policies(cfg: &OptimConfig, n: usize) -> Vec<TensorPolicy> {
        vec![TensorPolicy::uniform(cfg); n]
    }

    #[test]
    fn plan_covers_every_tensor_exactly_once() {
        let shapes = toy_shapes();
        let cfg = OptimConfig::default();
        let pol = uniform_policies(&cfg, shapes.len());
        for k in [1, 2, 3, 8] {
            let plan = plan_shards(&shapes, &pol, k);
            assert_eq!(plan.n_shards, k);
            assert_eq!(plan.assign.len(), shapes.len());
            let mut seen = vec![false; shapes.len()];
            for (s, local) in plan.locals.iter().enumerate() {
                for &t in local {
                    assert_eq!(plan.assign[t], s);
                    assert!(!seen[t], "tensor {t} owned twice");
                    seen[t] = true;
                }
                // ascending local order (blob reassembly relies on it)
                assert!(local.windows(2).all(|w| w[0] < w[1]));
            }
            assert!(seen.iter().all(|&x| x), "{seen:?}");
        }
        // planning is deterministic
        let a = plan_shards(&shapes, &pol, 3);
        let b = plan_shards(&shapes, &pol, 3);
        assert_eq!(a.assign, b.assign);
    }

    /// The core determinism claim: a sharded step produces bit-identical
    /// parameters and state blobs to one optimizer over the full
    /// inventory, for every optimizer kind.
    #[test]
    fn sharded_steps_match_single_optimizer_bitwise() {
        let shapes = toy_shapes();
        for kind in OptKind::every() {
            let mut cfg = OptimConfig::paper_defaults(kind);
            cfg.lr = 0.01;
            cfg.relative_step = false;
            let pol = uniform_policies(&cfg, shapes.len());
            for k in [1, 2, 4] {
                let shards = ShardSet::spawn(kind, &shapes, &cfg, &pol, k);
                let mut reference = build_with_policies(kind, &shapes, &cfg, &pol);

                let mut rng = Pcg32::new(11);
                let mut p_sharded: Vec<Tensor> = shapes
                    .iter()
                    .map(|s| {
                        let mut t = Tensor::zeros(s);
                        rng.fill_normal(t.data_mut(), 0.3);
                        t
                    })
                    .collect();
                let mut p_single = p_sharded.clone();
                let mut grng = Pcg32::new(29);
                for step in 1..=5u64 {
                    let grads: Vec<Tensor> = shapes
                        .iter()
                        .map(|s| {
                            let mut t = Tensor::zeros(s);
                            grng.fill_normal(t.data_mut(), 0.05);
                            t
                        })
                        .collect();
                    let lr = 0.01 / step as f32;
                    shards.step(lr, &mut p_sharded, grads.clone()).unwrap();
                    reference.set_lr(lr);
                    reference.step(&mut p_single, &grads);
                }
                assert_eq!(p_sharded, p_single, "{} params drift at k={k}", kind.name());
                let (opt_step, state_bytes, blobs) = shards.collect_state().unwrap();
                assert_eq!(opt_step, reference.opt_step(), "{}", kind.name());
                assert_eq!(state_bytes, reference.state_bytes(), "{}", kind.name());
                assert_eq!(blobs, reference.state_blobs(), "{} blobs drift at k={k}", kind.name());
                shards.stop();
            }
        }
    }
}
