//! State sharding: partition an inventory across K worker threads, each
//! owning the optimizer state for its tensor subset.
//!
//! The partition reuses the FLOP-balancing planner from the parallel
//! step engine ([`crate::optim::parallel::ParamPartition`]) over
//! whole-tensor units — one unsplittable [`TensorGeom`] per tensor, with
//! per-tensor cost weights derived from the resolved group policies
//! (stateless/frozen tensors are cheap to update, so the LPT packing
//! balances *effective* work, exactly like the intra-step engine). Every
//! optimizer in this crate updates tensors independently of each other
//! (the per-tensor state machines share only the internal step counter,
//! which each shard advances identically), so a sharded step is
//! bit-identical, tensor by tensor, to a single optimizer over the full
//! inventory — the property the server's snapshot e2e pins.
//!
//! Execution mirrors the persistent-worker topology of
//! `coordinator::workers::train_data_parallel`: each shard is one
//! long-lived `std::thread` owning its optimizer, driven over channels.
//! Tensor ownership *moves* through the channels (a `Vec<Tensor>` move
//! is pointer-sized — no data copies), so there is no shared mutable
//! state and no unsafe.

use anyhow::{anyhow, bail, Result};
use std::sync::mpsc::{self, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::optim::parallel::{ParamPartition, TensorGeom};
use crate::optim::{self, OptKind, OptimConfig, Optimizer, StateSerde, TensorPolicy};
use crate::tensor::Tensor;

/// Assignment of inventory tensors to shards.
#[derive(Clone, Debug)]
pub struct ShardPlan {
    /// Number of shards (>= 1).
    pub n_shards: usize,
    /// Original tensor index -> owning shard.
    pub assign: Vec<usize>,
    /// Shard -> original tensor indices, ascending (the shard's local
    /// registration order).
    pub locals: Vec<Vec<usize>>,
}

/// Plan a K-way shard assignment over the inventory with the
/// FLOP-balancing partition planner (whole-tensor units; policy-aware
/// cost weights).
pub fn plan_shards(
    shapes: &[Vec<usize>],
    policies: &[TensorPolicy],
    n_shards: usize,
) -> ShardPlan {
    assert_eq!(shapes.len(), policies.len(), "one policy per tensor");
    let n_shards = n_shards.max(1);
    let geoms: Vec<TensorGeom> = shapes
        .iter()
        .zip(policies)
        .map(|(s, p)| {
            let numel = s.iter().product::<usize>();
            // Same relative weights as the step engine's planning:
            // frozen tensors are skipped entirely, stateless ones run the
            // cheap `w -= lr·g` path, stateful ones the full update.
            let cost = if p.frozen {
                1
            } else if p.stateless() {
                2
            } else {
                8
            };
            TensorGeom::whole(numel, cost)
        })
        .collect();
    let part = ParamPartition::plan(&geoms, n_shards);
    let mut assign = vec![0usize; shapes.len()];
    for it in part.items() {
        assign[it.tensor] = it.shard;
    }
    let mut locals = vec![Vec::new(); n_shards];
    for (t, &s) in assign.iter().enumerate() {
        locals[s].push(t);
    }
    ShardPlan { n_shards, assign, locals }
}

/// Coalesce one async commit's contributions into a single
/// partial-batch gradient: `Σ_c g_c / n`, accumulated **in ascending
/// member-id order** (the caller passes the commit pre-sorted; this
/// verifies it). Fixing the reduction order — exactly as
/// `StepBatcher::take_coalesced` does at the barrier — makes the
/// committed bits depend only on *which* members contributed, never on
/// arrival timing, which is what lets `repro replay` re-execute a
/// commit log bit-identically through [`ShardSet::step`].
pub fn coalesce_commit(contributors: &[(u32, Vec<Tensor>)]) -> Result<Vec<Tensor>> {
    let Some((_, first)) = contributors.first() else {
        bail!("a commit needs at least one contributor");
    };
    if !contributors.windows(2).all(|w| w[0].0 < w[1].0) {
        bail!("commit contributors must be distinct and sorted by ascending member id");
    }
    let scale = 1.0 / contributors.len() as f32;
    let mut out: Vec<Tensor> = first.iter().map(|t| Tensor::zeros(t.shape())).collect();
    for (c, grads) in contributors {
        if grads.len() != out.len() {
            bail!("contributor {c} holds {} tensors, the commit has {}", grads.len(), out.len());
        }
        for (i, (acc, g)) in out.iter_mut().zip(grads).enumerate() {
            if acc.shape() != g.shape() {
                bail!(
                    "contributor {c} tensor {i}: shape {:?} vs the commit's {:?}",
                    g.shape(),
                    acc.shape()
                );
            }
            acc.axpy(scale, g);
        }
    }
    Ok(out)
}

enum Cmd {
    /// Apply one optimizer step over the shard's tensors (ownership of
    /// the subsets moves in; the updated params move back).
    Step { lr: f32, params: Vec<Tensor>, grads: Vec<Tensor> },
    /// Collect the shard's serialized optimizer state.
    Collect,
    /// Collect only the byte lengths of the shard's state blobs (plus
    /// the step counter) — the sizing pass of a streamed snapshot.
    CollectLens,
    /// Collect the state blob of one tensor, addressed by the shard's
    /// *local* registration index — the per-tensor pass of a streamed
    /// snapshot. The full shard state is never materialized.
    CollectOne { local: usize },
    Stop,
    /// Fault injection: the worker returns immediately without replying
    /// or draining its queue — observably identical (poisoned channels)
    /// to a panic, minus the stderr noise. Chaos tests and `repro
    /// loadgen --kill-shard` use this.
    Kill,
}

enum Reply {
    Stepped { params: Vec<Tensor> },
    State { opt_step: u64, state_bytes: u64, blobs: Vec<Vec<u8>> },
    Lens { opt_step: u64, lens: Vec<u64> },
    Blob { opt_step: u64, blob: Vec<u8> },
}

struct ShardHandle {
    tx: Sender<Cmd>,
    rx: Receiver<Reply>,
    join: Option<JoinHandle<()>>,
}

/// Everything a dead shard needs to come back exactly where it died: an
/// in-memory `SMMFCKPT` v2 image of the *whole* run after the last
/// applied step, cracked open into the pieces recovery consumes —
/// parameters and per-tensor state blobs in inventory order, plus the
/// shared optimizer step counter.
pub struct RecoveryImage {
    pub opt_step: u64,
    pub params: Vec<Tensor>,
    pub blobs: Vec<Vec<u8>>,
}

/// What a resilient step had to do to complete (all zero on the happy
/// path).
#[derive(Clone, Copy, Debug, Default)]
pub struct Recovery {
    /// Shard workers respawned during this step.
    pub respawns: u64,
    /// Wall-clock time spent detecting, respawning and replaying.
    pub elapsed: Duration,
}

/// Build one shard worker. The optimizer is constructed — and, for a
/// respawn/resume, restored from `restore = (opt_step, blobs in local
/// order)` — on the *calling* thread, so a corrupt restore fails here
/// with context instead of poisoning a channel.
fn spawn_worker(
    kind: OptKind,
    shapes: &[Vec<usize>],
    cfg: &OptimConfig,
    policies: &[TensorPolicy],
    idx: &[usize],
    restore: Option<(u64, Vec<Vec<u8>>)>,
) -> Result<ShardHandle> {
    let mut opt = optim::build_subset(kind, shapes, cfg, policies, idx);
    if let Some((opt_step, blobs)) = restore {
        opt.set_opt_step(opt_step);
        opt.load_state_blobs(&blobs)?;
    }
    let (cmd_tx, cmd_rx) = mpsc::channel::<Cmd>();
    let (reply_tx, reply_rx) = mpsc::channel::<Reply>();
    let n_local = idx.len();
    let join = std::thread::spawn(move || {
        while let Ok(cmd) = cmd_rx.recv() {
            match cmd {
                Cmd::Step { lr, mut params, grads } => {
                    opt.set_lr(lr);
                    opt.step(&mut params, &grads);
                    if reply_tx.send(Reply::Stepped { params }).is_err() {
                        break;
                    }
                }
                Cmd::Collect => {
                    let reply = Reply::State {
                        opt_step: opt.opt_step(),
                        state_bytes: opt.state_bytes(),
                        blobs: opt.state_blobs(),
                    };
                    if reply_tx.send(reply).is_err() {
                        break;
                    }
                }
                Cmd::CollectLens => {
                    // Serializes each blob once to measure it (blobs are
                    // not stored pre-encoded); the streamed-snapshot
                    // sizing pass accepts the 2x encode cost in exchange
                    // for never materializing the whole state.
                    let lens =
                        (0..n_local).map(|i| opt.state_blob(i).len() as u64).collect();
                    if reply_tx.send(Reply::Lens { opt_step: opt.opt_step(), lens }).is_err() {
                        break;
                    }
                }
                Cmd::CollectOne { local } => {
                    let reply =
                        Reply::Blob { opt_step: opt.opt_step(), blob: opt.state_blob(local) };
                    if reply_tx.send(reply).is_err() {
                        break;
                    }
                }
                Cmd::Stop | Cmd::Kill => break,
            }
        }
    });
    Ok(ShardHandle { tx: cmd_tx, rx: reply_rx, join: Some(join) })
}

/// K shard workers plus the plan mapping tensors onto them. The spawn
/// recipe (kind / shapes / config / policies) is kept so a dead worker
/// can be rebuilt mid-run.
pub struct ShardSet {
    pub plan: ShardPlan,
    handles: Vec<ShardHandle>,
    kind: OptKind,
    shapes: Vec<Vec<usize>>,
    cfg: OptimConfig,
    policies: Vec<TensorPolicy>,
}

impl ShardSet {
    /// Plan the partition and spawn one worker per shard; each worker
    /// builds its optimizer over its tensor subset through the resolved
    /// per-tensor policy table ([`optim::build_subset`]), so per-group
    /// `StatePolicy` / lr-scale / weight-decay overrides survive
    /// sharding.
    pub fn spawn(
        kind: OptKind,
        shapes: &[Vec<usize>],
        cfg: &OptimConfig,
        policies: &[TensorPolicy],
        n_shards: usize,
    ) -> ShardSet {
        Self::spawn_inner(kind, shapes, cfg, policies, n_shards, None)
            .expect("fresh spawn restores nothing and cannot fail")
    }

    /// Spawn with every shard restored from checkpointed optimizer state
    /// (`blobs` in original inventory order). `n_shards` is free to
    /// differ from the run that wrote the state: the FLOP-balancing
    /// planner re-runs and each worker restores exactly the blobs of the
    /// tensors it now owns — the K-migration path behind `repro serve
    /// --resume`.
    pub fn spawn_restored(
        kind: OptKind,
        shapes: &[Vec<usize>],
        cfg: &OptimConfig,
        policies: &[TensorPolicy],
        n_shards: usize,
        opt_step: u64,
        blobs: &[Vec<u8>],
    ) -> Result<ShardSet> {
        if blobs.len() != shapes.len() {
            bail!("restore carries {} state blobs for {} tensors", blobs.len(), shapes.len());
        }
        Self::spawn_inner(kind, shapes, cfg, policies, n_shards, Some((opt_step, blobs)))
    }

    fn spawn_inner(
        kind: OptKind,
        shapes: &[Vec<usize>],
        cfg: &OptimConfig,
        policies: &[TensorPolicy],
        n_shards: usize,
        restore: Option<(u64, &[Vec<u8>])>,
    ) -> Result<ShardSet> {
        let plan = plan_shards(shapes, policies, n_shards);
        let mut handles = Vec::with_capacity(plan.n_shards);
        for s in 0..plan.n_shards {
            let idx = &plan.locals[s];
            let sub_restore = restore.map(|(opt_step, blobs)| {
                (opt_step, idx.iter().map(|&t| blobs[t].clone()).collect())
            });
            handles.push(
                spawn_worker(kind, shapes, cfg, policies, idx, sub_restore)
                    .map_err(|e| anyhow!("restoring shard {s}: {e:#}"))?,
            );
        }
        Ok(ShardSet {
            plan,
            handles,
            kind,
            shapes: shapes.to_vec(),
            cfg: cfg.clone(),
            policies: policies.to_vec(),
        })
    }

    /// Fault injection: make shard `s`'s worker exit as if it crashed
    /// (its channels poison; the next step against it fails). Recovery
    /// is [`ShardSet::step_resilient`]'s job.
    pub fn kill(&self, s: usize) {
        if let Some(h) = self.handles.get(s) {
            let _ = h.tx.send(Cmd::Kill);
        }
    }

    /// Rebuild shard `s` from a recovery image: re-plan nothing (the
    /// plan is fixed for the server's lifetime), restore the worker's
    /// optimizer state tensor-by-tensor from the image blobs.
    fn respawn_from(&mut self, s: usize, image: &RecoveryImage) -> Result<()> {
        let idx = &self.plan.locals[s];
        let blobs: Vec<Vec<u8>> = idx.iter().map(|&t| image.blobs[t].clone()).collect();
        let fresh = spawn_worker(
            self.kind,
            &self.shapes,
            &self.cfg,
            &self.policies,
            idx,
            Some((image.opt_step, blobs)),
        )
        .map_err(|e| anyhow!("respawning shard {s}: {e:#}"))?;
        let mut old = std::mem::replace(&mut self.handles[s], fresh);
        // The dead worker's thread has already returned (that is how we
        // noticed); join just reaps it.
        if let Some(j) = old.join.take() {
            let _ = j.join();
        }
        Ok(())
    }

    /// Apply one coalesced optimizer step across all shards: scatter the
    /// per-shard parameter/gradient subsets (ownership moves, the master
    /// slots are back-filled with empty placeholders), run the shards
    /// concurrently, gather the updated parameters back in place.
    /// `grads` is consumed.
    pub fn step(&self, lr: f32, params: &mut [Tensor], grads: Vec<Tensor>) -> Result<()> {
        assert_eq!(params.len(), self.plan.assign.len());
        assert_eq!(grads.len(), self.plan.assign.len());
        let mut grads: Vec<Option<Tensor>> = grads.into_iter().map(Some).collect();
        // Empty shards (more shards than tensors) are skipped entirely —
        // their optimizers never step, and collect_state ignores them.
        for (s, h) in self.handles.iter().enumerate() {
            if self.plan.locals[s].is_empty() {
                continue;
            }
            let idx = &self.plan.locals[s];
            let sub_params: Vec<Tensor> = idx
                .iter()
                .map(|&t| std::mem::replace(&mut params[t], Tensor::scalar(0.0)))
                .collect();
            let sub_grads: Vec<Tensor> =
                idx.iter().map(|&t| grads[t].take().expect("each tensor scattered once")).collect();
            h.tx.send(Cmd::Step { lr, params: sub_params, grads: sub_grads })
                .map_err(|_| anyhow!("shard {s} worker is gone"))?;
        }
        for (s, h) in self.handles.iter().enumerate() {
            if self.plan.locals[s].is_empty() {
                continue;
            }
            match h.rx.recv() {
                Ok(Reply::Stepped { params: sub }) => {
                    for (&t, tensor) in self.plan.locals[s].iter().zip(sub) {
                        params[t] = tensor;
                    }
                }
                _ => return Err(anyhow!("shard {s} worker died mid-step")),
            }
        }
        Ok(())
    }

    /// [`ShardSet::step`] with crash-resume: a shard whose worker died
    /// (send or receive on a poisoned channel) is respawned from the
    /// coordinator's recovery image — optimizer state restored
    /// tensor-by-tensor, the shard's parameters reset from the image
    /// (they carry the last applied step exactly), and this step's
    /// gradients replayed from the clones kept at scatter time. The
    /// continuation is bit-identical to a run where the shard never
    /// died, because the replayed step consumes exactly the state and
    /// inputs the dead worker held. `recover` parses the image lazily —
    /// the happy path never touches it — and a shard that dies *again*
    /// during its own recovery is a hard error.
    pub fn step_resilient(
        &mut self,
        lr: f32,
        params: &mut [Tensor],
        grads: Vec<Tensor>,
        recover: &mut dyn FnMut() -> Result<RecoveryImage>,
    ) -> Result<Recovery> {
        assert_eq!(params.len(), self.plan.assign.len());
        assert_eq!(grads.len(), self.plan.assign.len());
        let n = self.plan.n_shards;
        let mut grads: Vec<Option<Tensor>> = grads.into_iter().map(Some).collect();
        // Clone each shard's gradient subset before it moves into the
        // channel: a dead shard's inputs must be replayable without
        // asking the clients to re-push.
        let mut sent: Vec<Option<Vec<Tensor>>> = (0..n).map(|_| None).collect();
        let mut dead = vec![false; n];
        for s in 0..n {
            if self.plan.locals[s].is_empty() {
                continue;
            }
            let idx = &self.plan.locals[s];
            let sub_params: Vec<Tensor> = idx
                .iter()
                .map(|&t| std::mem::replace(&mut params[t], Tensor::scalar(0.0)))
                .collect();
            let sub_grads: Vec<Tensor> =
                idx.iter().map(|&t| grads[t].take().expect("each tensor scattered once")).collect();
            sent[s] = Some(sub_grads.clone());
            if self.handles[s]
                .tx
                .send(Cmd::Step { lr, params: sub_params, grads: sub_grads })
                .is_err()
            {
                dead[s] = true;
            }
        }
        let mut image: Option<RecoveryImage> = None;
        let mut rec = Recovery::default();
        for s in 0..n {
            if self.plan.locals[s].is_empty() {
                continue;
            }
            if !dead[s] {
                match self.handles[s].rx.recv() {
                    Ok(Reply::Stepped { params: sub }) => {
                        for (&t, tensor) in self.plan.locals[s].iter().zip(sub) {
                            params[t] = tensor;
                        }
                        continue;
                    }
                    _ => dead[s] = true,
                }
            }
            // Recovery: respawn from the image and replay this step.
            let t0 = Instant::now();
            if image.is_none() {
                image = Some(recover()?);
            }
            let img = image.as_ref().unwrap();
            if img.params.len() != params.len() {
                bail!(
                    "recovery image holds {} tensors, inventory has {}",
                    img.params.len(),
                    params.len()
                );
            }
            self.respawn_from(s, img)?;
            let idx = &self.plan.locals[s];
            let sub_params: Vec<Tensor> = idx.iter().map(|&t| img.params[t].clone()).collect();
            let sub_grads = sent[s].take().expect("grads cloned at scatter");
            let h = &self.handles[s];
            h.tx.send(Cmd::Step { lr, params: sub_params, grads: sub_grads })
                .map_err(|_| anyhow!("shard {s}: respawned worker died immediately"))?;
            match h.rx.recv() {
                Ok(Reply::Stepped { params: sub }) => {
                    for (&t, tensor) in self.plan.locals[s].iter().zip(sub) {
                        params[t] = tensor;
                    }
                }
                _ => bail!("shard {s} died again while replaying the recovered step"),
            }
            rec.respawns += 1;
            rec.elapsed += t0.elapsed();
        }
        Ok(rec)
    }

    /// Gather the serialized optimizer state from every shard, reordered
    /// into original inventory order: `(opt_step, live state bytes, one
    /// blob per tensor)`. Errors if the shards' internal step counters
    /// disagree (they advance in lockstep, so drift means a lost step).
    pub fn collect_state(&self) -> Result<(u64, u64, Vec<Vec<u8>>)> {
        let n_tensors = self.plan.assign.len();
        let mut blobs: Vec<Vec<u8>> = vec![Vec::new(); n_tensors];
        let mut opt_step = None;
        let mut state_bytes = 0u64;
        for (s, h) in self.handles.iter().enumerate() {
            if self.plan.locals[s].is_empty() {
                continue;
            }
            h.tx.send(Cmd::Collect).map_err(|_| anyhow!("shard {s} worker is gone"))?;
            match h.rx.recv() {
                Ok(Reply::State { opt_step: t, state_bytes: b, blobs: sub }) => {
                    if *opt_step.get_or_insert(t) != t {
                        return Err(anyhow!(
                            "shard {s} is at optimizer step {t}, others at {}",
                            opt_step.unwrap()
                        ));
                    }
                    state_bytes += b;
                    if sub.len() != self.plan.locals[s].len() {
                        return Err(anyhow!(
                            "shard {s} returned {} blobs for {} tensors",
                            sub.len(),
                            self.plan.locals[s].len()
                        ));
                    }
                    for (&t, blob) in self.plan.locals[s].iter().zip(sub) {
                        blobs[t] = blob;
                    }
                }
                _ => return Err(anyhow!("shard {s} worker died during state collection")),
            }
        }
        Ok((opt_step.unwrap_or(0), state_bytes, blobs))
    }

    /// Gather only the per-tensor state-blob byte lengths (inventory
    /// order) plus the shared optimizer step — the sizing pass of a
    /// streamed snapshot. Errors on step-counter drift exactly like
    /// [`ShardSet::collect_state`].
    pub fn collect_blob_lens(&self) -> Result<(u64, Vec<u64>)> {
        let n_tensors = self.plan.assign.len();
        let mut lens = vec![0u64; n_tensors];
        let mut opt_step = None;
        for (s, h) in self.handles.iter().enumerate() {
            if self.plan.locals[s].is_empty() {
                continue;
            }
            h.tx.send(Cmd::CollectLens).map_err(|_| anyhow!("shard {s} worker is gone"))?;
            match h.rx.recv() {
                Ok(Reply::Lens { opt_step: t, lens: sub }) => {
                    if *opt_step.get_or_insert(t) != t {
                        return Err(anyhow!(
                            "shard {s} is at optimizer step {t}, others at {}",
                            opt_step.unwrap()
                        ));
                    }
                    if sub.len() != self.plan.locals[s].len() {
                        return Err(anyhow!(
                            "shard {s} returned {} blob lengths for {} tensors",
                            sub.len(),
                            self.plan.locals[s].len()
                        ));
                    }
                    for (&t, len) in self.plan.locals[s].iter().zip(sub) {
                        lens[t] = len;
                    }
                }
                _ => return Err(anyhow!("shard {s} worker died during length collection")),
            }
        }
        Ok((opt_step.unwrap_or(0), lens))
    }

    /// Fetch the state blob of one tensor by its *inventory* index,
    /// routed to the owning shard — the per-tensor pass of a streamed
    /// snapshot. Peak coordinator memory is one blob, not the
    /// inventory's worth.
    pub fn collect_blob(&self, tensor: usize) -> Result<Vec<u8>> {
        let s = *self
            .plan
            .assign
            .get(tensor)
            .ok_or_else(|| anyhow!("tensor {tensor} is not in the shard plan"))?;
        let local = self.plan.locals[s]
            .iter()
            .position(|&t| t == tensor)
            .expect("assign and locals agree by construction");
        let h = &self.handles[s];
        h.tx.send(Cmd::CollectOne { local })
            .map_err(|_| anyhow!("shard {s} worker is gone"))?;
        match h.rx.recv() {
            Ok(Reply::Blob { blob, .. }) => Ok(blob),
            _ => Err(anyhow!("shard {s} worker died collecting tensor {tensor}")),
        }
    }

    /// Stop and join every worker.
    pub fn stop(mut self) {
        for h in &self.handles {
            let _ = h.tx.send(Cmd::Stop);
        }
        for h in &mut self.handles {
            if let Some(j) = h.join.take() {
                let _ = j.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::build_with_policies;
    use crate::util::rng::Pcg32;

    fn toy_shapes() -> Vec<Vec<usize>> {
        vec![vec![16, 8], vec![8], vec![4, 4, 2], vec![32], vec![1]]
    }

    fn uniform_policies(cfg: &OptimConfig, n: usize) -> Vec<TensorPolicy> {
        vec![TensorPolicy::uniform(cfg); n]
    }

    #[test]
    fn plan_covers_every_tensor_exactly_once() {
        let shapes = toy_shapes();
        let cfg = OptimConfig::default();
        let pol = uniform_policies(&cfg, shapes.len());
        for k in [1, 2, 3, 8] {
            let plan = plan_shards(&shapes, &pol, k);
            assert_eq!(plan.n_shards, k);
            assert_eq!(plan.assign.len(), shapes.len());
            let mut seen = vec![false; shapes.len()];
            for (s, local) in plan.locals.iter().enumerate() {
                for &t in local {
                    assert_eq!(plan.assign[t], s);
                    assert!(!seen[t], "tensor {t} owned twice");
                    seen[t] = true;
                }
                // ascending local order (blob reassembly relies on it)
                assert!(local.windows(2).all(|w| w[0] < w[1]));
            }
            assert!(seen.iter().all(|&x| x), "{seen:?}");
        }
        // planning is deterministic
        let a = plan_shards(&shapes, &pol, 3);
        let b = plan_shards(&shapes, &pol, 3);
        assert_eq!(a.assign, b.assign);
    }

    /// The core determinism claim: a sharded step produces bit-identical
    /// parameters and state blobs to one optimizer over the full
    /// inventory, for every optimizer kind.
    #[test]
    fn sharded_steps_match_single_optimizer_bitwise() {
        let shapes = toy_shapes();
        for kind in OptKind::every() {
            let mut cfg = OptimConfig::paper_defaults(kind);
            cfg.lr = 0.01;
            cfg.relative_step = false;
            let pol = uniform_policies(&cfg, shapes.len());
            for k in [1, 2, 4] {
                let shards = ShardSet::spawn(kind, &shapes, &cfg, &pol, k);
                let mut reference = build_with_policies(kind, &shapes, &cfg, &pol);

                let mut rng = Pcg32::new(11);
                let mut p_sharded: Vec<Tensor> = shapes
                    .iter()
                    .map(|s| {
                        let mut t = Tensor::zeros(s);
                        rng.fill_normal(t.data_mut(), 0.3);
                        t
                    })
                    .collect();
                let mut p_single = p_sharded.clone();
                let mut grng = Pcg32::new(29);
                for step in 1..=5u64 {
                    let grads: Vec<Tensor> = shapes
                        .iter()
                        .map(|s| {
                            let mut t = Tensor::zeros(s);
                            grng.fill_normal(t.data_mut(), 0.05);
                            t
                        })
                        .collect();
                    let lr = 0.01 / step as f32;
                    shards.step(lr, &mut p_sharded, grads.clone()).unwrap();
                    reference.set_lr(lr);
                    reference.step(&mut p_single, &grads);
                }
                assert_eq!(p_sharded, p_single, "{} params drift at k={k}", kind.name());
                let (opt_step, state_bytes, blobs) = shards.collect_state().unwrap();
                assert_eq!(opt_step, reference.opt_step(), "{}", kind.name());
                assert_eq!(state_bytes, reference.state_bytes(), "{}", kind.name());
                assert_eq!(blobs, reference.state_blobs(), "{} blobs drift at k={k}", kind.name());
                shards.stop();
            }
        }
    }

    #[test]
    fn coalesce_commit_matches_the_barrier_reduction_and_rejects_disorder() {
        let shapes = vec![vec![2, 2], vec![3]];
        let grads_for = |c: u32| -> Vec<Tensor> {
            let b = c as f32;
            vec![
                Tensor::from_vec(&shapes[0], vec![b, b + 0.5, -b, 1.0]),
                Tensor::from_vec(&shapes[1], vec![0.25 * b, -1.0, b]),
            ]
        };
        // Reference: the StepBatcher reduction over the same member set.
        let members = [1u32, 4, 7];
        let scale = 1.0 / members.len() as f32;
        let mut want: Vec<Tensor> = shapes.iter().map(|s| Tensor::zeros(s)).collect();
        for &c in &members {
            for (w, g) in want.iter_mut().zip(grads_for(c)) {
                w.axpy(scale, &g);
            }
        }
        let commit: Vec<(u32, Vec<Tensor>)> =
            members.iter().map(|&c| (c, grads_for(c))).collect();
        assert_eq!(coalesce_commit(&commit).unwrap(), want);

        // empty commit
        assert!(coalesce_commit(&[]).is_err());
        // out-of-order / duplicate member ids
        let disordered = vec![(4u32, grads_for(4)), (1, grads_for(1))];
        assert!(coalesce_commit(&disordered).is_err());
        let duped = vec![(4u32, grads_for(4)), (4, grads_for(4))];
        assert!(coalesce_commit(&duped).is_err());
        // tensor count / shape drift between contributors
        let short = vec![(1u32, grads_for(1)), (4, grads_for(4)[..1].to_vec())];
        assert!(coalesce_commit(&short).is_err());
        let reshaped = vec![
            (1u32, grads_for(1)),
            (4, vec![Tensor::zeros(&[4, 1]), Tensor::zeros(&[3])]),
        ];
        assert!(coalesce_commit(&reshaped).is_err());
    }

    fn random_tensors(shapes: &[Vec<usize>], rng: &mut Pcg32, sigma: f32) -> Vec<Tensor> {
        shapes
            .iter()
            .map(|s| {
                let mut t = Tensor::zeros(s);
                rng.fill_normal(t.data_mut(), sigma);
                t
            })
            .collect()
    }

    /// Crash-resume bit-identity at the shard layer: kill a worker
    /// mid-run, let `step_resilient` respawn it from a recovery image,
    /// and the run must end bit-identical (params AND state blobs) to a
    /// run that never crashed.
    #[test]
    fn killed_shard_resumes_bit_identically() {
        let shapes = toy_shapes();
        let mut cfg = OptimConfig::paper_defaults(OptKind::Smmf);
        cfg.lr = 0.01;
        cfg.relative_step = false;
        let pol = uniform_policies(&cfg, shapes.len());

        // Uninterrupted reference over the same streams.
        let mut reference = build_with_policies(OptKind::Smmf, &shapes, &cfg, &pol);
        let mut p_ref = random_tensors(&shapes, &mut Pcg32::new(11), 0.3);

        let mut shards = ShardSet::spawn(OptKind::Smmf, &shapes, &cfg, &pol, 3);
        let mut p_live = p_ref.clone();
        // Image of step 0: initial params, fresh state.
        let (t0, _, b0) = shards.collect_state().unwrap();
        let mut img = RecoveryImage { opt_step: t0, params: p_live.clone(), blobs: b0 };

        let mut grng = Pcg32::new(29);
        let mut total_respawns = 0u64;
        for step in 1..=6u64 {
            let grads = random_tensors(&shapes, &mut grng, 0.05);
            if step == 3 {
                shards.kill(1);
            }
            if step == 5 {
                shards.kill(0);
                shards.kill(2);
            }
            let lr = 0.01 / step as f32;
            let mut recover = || -> Result<RecoveryImage> {
                Ok(RecoveryImage {
                    opt_step: img.opt_step,
                    params: img.params.clone(),
                    blobs: img.blobs.clone(),
                })
            };
            let rec = shards.step_resilient(lr, &mut p_live, grads.clone(), &mut recover).unwrap();
            total_respawns += rec.respawns;
            reference.set_lr(lr);
            reference.step(&mut p_ref, &grads);
            assert_eq!(p_live, p_ref, "params drift at step {step}");
            // Refresh the image after every applied step, like the
            // resilient coordinator does.
            let (t, _, blobs) = shards.collect_state().unwrap();
            img = RecoveryImage { opt_step: t, params: p_live.clone(), blobs };
        }
        assert_eq!(total_respawns, 3, "one respawn per injected kill");
        let (opt_step, _, blobs) = shards.collect_state().unwrap();
        assert_eq!(opt_step, reference.opt_step());
        assert_eq!(blobs, reference.state_blobs(), "state blobs drift after recovery");
        shards.stop();
    }

    /// K-migration: state collected from a K-shard run restores into a
    /// K'-shard set (the planner re-runs; each worker restores the blobs
    /// of the tensors it now owns) and continues bit-identically.
    #[test]
    fn state_migrates_across_shard_counts() {
        let shapes = toy_shapes();
        let mut cfg = OptimConfig::paper_defaults(OptKind::Smmf);
        cfg.lr = 0.01;
        cfg.relative_step = false;
        let pol = uniform_policies(&cfg, shapes.len());

        let mut reference = build_with_policies(OptKind::Smmf, &shapes, &cfg, &pol);
        let mut p_ref = random_tensors(&shapes, &mut Pcg32::new(7), 0.3);
        let first = ShardSet::spawn(OptKind::Smmf, &shapes, &cfg, &pol, 2);
        let mut p_live = p_ref.clone();

        let mut grng = Pcg32::new(31);
        for step in 1..=3u64 {
            let grads = random_tensors(&shapes, &mut grng, 0.05);
            first.step(0.01, &mut p_live, grads.clone()).unwrap();
            reference.step(&mut p_ref, &grads);
            let _ = step;
        }
        let (opt_step, _, blobs) = first.collect_state().unwrap();
        first.stop();

        // Restore onto a *different* shard count and keep going.
        let second =
            ShardSet::spawn_restored(OptKind::Smmf, &shapes, &cfg, &pol, 4, opt_step, &blobs)
                .unwrap();
        for _ in 4..=6u64 {
            let grads = random_tensors(&shapes, &mut grng, 0.05);
            second.step(0.01, &mut p_live, grads.clone()).unwrap();
            reference.step(&mut p_ref, &grads);
        }
        assert_eq!(p_live, p_ref, "params drift across the 2 -> 4 shard migration");
        let (t2, _, b2) = second.collect_state().unwrap();
        assert_eq!(t2, reference.opt_step());
        assert_eq!(b2, reference.state_blobs());
        second.stop();

        // blob/tensor count mismatch is a clear error
        let bad = ShardSet::spawn_restored(
            OptKind::Smmf,
            &shapes,
            &cfg,
            &pol,
            2,
            opt_step,
            &b2[..b2.len() - 1],
        );
        assert!(bad.is_err());
    }
}
