//! The optimizer-state server: a parameter-server-style service that
//! holds SMMF-factorized (or any baseline) optimizer state behind a
//! binary wire protocol, sharded across worker threads, with batched
//! gradient ingestion.
//!
//! SMMF's point is that factored momenta make optimizer state small
//! enough to hold and move cheaply — which makes it the natural backing
//! store for a long-running service where many clients stream gradients
//! against shared state. The subsystem is four layers, each its own
//! module:
//!
//! * [`protocol`] — the `SMMFWIRE` versioned, length-prefixed binary
//!   framing. v4 replaces the whole-inventory payloads with sequence-
//!   numbered per-tensor chunk streams (`PushBegin` / `ChunkHeader` /
//!   `ChunkData` / `StreamEnd`, `Resend` recovery, dense and SMMF-
//!   factored pull modes) so any-size inventory crosses the wire in
//!   O(chunk) frames; membership ops (`Join` / `Leave` / `EpochInfo`),
//!   bounded-staleness fields, the `TooStale` reply and commit-log
//!   frames carry over from v2/v3. Everything is decoded with the same
//!   strict bounds-checked discipline as the checkpoint container.
//! * [`batch`] — gradient coalescing: concurrent client pushes
//!   accumulate behind a per-step barrier and reduce in fixed member-id
//!   order, so the applied step is independent of network timing. The
//!   barrier is elastic: members join, leave and get evicted between
//!   steps, each change bumping the membership epoch. Async mode swaps
//!   the barrier for a bounded-staleness accumulator: whatever is
//!   pending commits as one partial batch, and a push based on
//!   parameters more than `S` steps old is turned away.
//! * [`commitlog`] — the ordered on-disk record of every applied async
//!   commit (contributors, base steps, digest, coalesced gradient),
//!   written through the wire-frame codec; `repro replay` re-executes
//!   it to a bit-identical snapshot, making async runs as auditable as
//!   synchronous ones.
//! * [`shard`] — the inventory partitioned across K worker threads by
//!   the FLOP-balancing planner, each shard owning its optimizer state
//!   (built through the param-group table, so per-shard `StatePolicy`
//!   overrides work); a dead worker is respawned from a recovery image
//!   and the interrupted step replayed, bit-identically.
//! * [`service`] / [`client`] — the TCP accept loop with a bounded
//!   request queue and explicit `Busy` backpressure, the snapshot writer
//!   (reusing the atomic `SMMFCKPT` v2 checkpoint path), crash-resume
//!   and `--resume` restore, the blocking wire client with socket
//!   timeouts and jittered backoff, the fault-injecting load generator,
//!   and the single-process reference trainer (fixed-membership and
//!   elastic) that the determinism contract is pinned against.
//!
//! End-to-end guarantee: a K-shard server driven by N concurrent
//! clients writes snapshots **bit-identical** to the equivalent
//! single-process trainer, for any K and N — and, per membership epoch,
//! under injected faults (client drops, shard-worker kills). `repro
//! serve` / `repro loadgen` expose the subsystem on the CLI;
//! `docs/SERVER_PROTOCOL.md` has the byte-level wire spec and
//! `docs/ARCHITECTURE.md` the failure model.

pub mod batch;
pub mod client;
pub mod commitlog;
pub mod protocol;
pub mod service;
pub mod shard;

pub use client::{Client, GradSource, PullReply, PushOutcome, TensorMoments, PULL_TENSOR_CAP};
pub use commitlog::{grad_digest, CommitLog, CommitLogWriter, LogInfo};
pub use protocol::{
    chunk_plan, ChunkAssembler, ChunkError, Contributor, EpochView, Frame, Msg, ServerStats,
    CHUNK_MAX_BYTES, MAX_PAYLOAD, PULL_DENSE, PULL_FACTORED,
};
pub use service::{
    reference_checkpoint, reference_checkpoint_elastic, replay_commit_log, resolve_inventory,
    run_loadgen, LoadgenOptions, LoadgenReport, ReplayReport, ServeOptions, Server,
};
pub use shard::{coalesce_commit, plan_shards, Recovery, RecoveryImage, ShardPlan, ShardSet};
