//! The ordered commit log behind bounded-staleness async serving.
//!
//! Live async application is racy by design — commits happen whenever
//! contributions are pending — but every committed partial batch is
//! appended here as one `SMMFWIRE` [`Msg::LogCommit`] frame: the
//! optimizer step it applied, the membership epoch, the contributors in
//! ascending member-id order (each with the `base_step` its gradient
//! was computed against), an FNV-1a digest of the coalesced gradient
//! bits, and those bits themselves. A log is therefore a complete,
//! ordered record of *what was applied*, which is what lets
//! `repro replay` re-execute the run through the synchronous shard
//! machinery to a byte-identical snapshot: replay does not re-derive
//! gradients (clients raced), it re-applies the logged coalesced bits
//! in commit order.
//!
//! The file layout is one [`Msg::LogHeader`] frame (the run identity a
//! replay must match: model, optimizer, seed, base lr, staleness
//! window, first step) followed by [`Msg::LogCommit`] frames. Loading
//! follows the `SMMFCKPT` strict discipline: every frame decodes
//! through the bounds-checked wire codec, digests are recomputed and
//! verified, commit steps must be contiguous from `first_step`, and
//! every contributor must sit inside the declared staleness window — a
//! truncated or tampered log is a context-rich error, never a panic.

use anyhow::{bail, Context, Result};
use std::fs::File;
use std::io::BufWriter;
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use crate::obs::{self, metrics::Histogram, trace as obs_trace};
use crate::server::protocol::{self, Contributor, Frame, Msg, HEADER_LEN};

/// FNV-1a 64 over per-tensor length-framed little-endian f32 bytes —
/// tensor boundaries are part of the digest, so moving an element
/// across tensors changes it.
pub fn grad_digest(grads: &[Vec<f32>]) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    fn eat(mut h: u64, bytes: &[u8]) -> u64 {
        for &b in bytes {
            h = (h ^ b as u64).wrapping_mul(PRIME);
        }
        h
    }
    let mut h = OFFSET;
    for t in grads {
        h = eat(h, &(t.len() as u64).to_le_bytes());
        for v in t {
            h = eat(h, &v.to_le_bytes());
        }
    }
    h
}

/// The run identity written as the log's first frame.
#[derive(Clone, Debug, PartialEq)]
pub struct LogInfo {
    pub model: String,
    pub optimizer: String,
    pub seed: u64,
    pub base_lr: f32,
    /// The bounded-staleness window the run was served under.
    pub staleness: u64,
    /// The first step the log covers (1 for a fresh server; a resumed
    /// server logs from its resume point).
    pub first_step: u64,
}

/// One committed partial batch, as recorded in the log.
#[derive(Clone, Debug, PartialEq)]
pub struct LogCommitRecord {
    pub step: u64,
    pub epoch: u64,
    /// Contributors in ascending member-id order.
    pub contributors: Vec<Contributor>,
    pub digest: u64,
    /// The coalesced gradient bits applied at `step` (flat f32 per
    /// tensor, inventory order).
    pub grads: Vec<Vec<f32>>,
}

/// Append-only commit-log writer: one header frame at create time, one
/// commit frame per applied partial batch, flushed per commit.
pub struct CommitLogWriter {
    w: BufWriter<File>,
    next_step: u64,
    staleness: u64,
    seq: u64,
    /// When set (and metrics are enabled), each append's wall time in
    /// milliseconds lands here — the server wires in its
    /// `server.log_append_ms` histogram.
    append_ms: Option<Arc<Histogram>>,
}

impl CommitLogWriter {
    /// Create (truncate) the log at `path` and write the header frame.
    pub fn create(path: &Path, info: &LogInfo) -> Result<CommitLogWriter> {
        assert!(info.staleness >= 1, "the commit log records async runs only");
        if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating commit-log directory {dir:?}"))?;
        }
        let file =
            File::create(path).with_context(|| format!("creating commit log {path:?}"))?;
        let mut w = BufWriter::new(file);
        let msg = Msg::LogHeader {
            model: info.model.clone(),
            optimizer: info.optimizer.clone(),
            seed: info.seed,
            base_lr: info.base_lr,
            staleness: info.staleness,
            first_step: info.first_step,
        };
        protocol::write_frame(&mut w, &Frame { request_id: 0, msg })
            .with_context(|| format!("writing commit-log header to {path:?}"))?;
        Ok(CommitLogWriter {
            w,
            next_step: info.first_step,
            staleness: info.staleness,
            seq: 1,
            append_ms: None,
        })
    }

    /// Route per-append timings into `hist` (observed only while
    /// metrics are enabled).
    pub fn with_append_timing(mut self, hist: Arc<Histogram>) -> CommitLogWriter {
        self.append_ms = Some(hist);
        self
    }

    /// Append one commit. Steps must arrive contiguously from the
    /// header's `first_step`; contributors must be sorted ascending and
    /// inside the staleness window — the writer enforces at append time
    /// exactly what the loader verifies at read time, so a log this
    /// writer produced always loads. Returns the recorded digest.
    pub fn append(
        &mut self,
        step: u64,
        epoch: u64,
        contributors: &[Contributor],
        grads: &[Vec<f32>],
    ) -> Result<u64> {
        if step != self.next_step {
            bail!("commit for step {step}, the log expects step {}", self.next_step);
        }
        check_commit_shape(step, self.staleness, contributors)?;
        let digest = grad_digest(grads);
        let msg = Msg::LogCommit {
            step,
            epoch,
            contributors: contributors.to_vec(),
            digest,
            grads: grads.to_vec(),
        };
        let _span = obs_trace::span("server", "server.log_append");
        let t0 = (self.append_ms.is_some() && obs::metrics_enabled()).then(Instant::now);
        protocol::write_frame(&mut self.w, &Frame { request_id: self.seq, msg })
            .with_context(|| format!("appending commit {step} to the log"))?;
        if let (Some(t0), Some(h)) = (t0, &self.append_ms) {
            h.observe(t0.elapsed().as_secs_f64() * 1e3);
        }
        self.next_step += 1;
        self.seq += 1;
        Ok(digest)
    }
}

/// Shared writer/loader validation of one commit's contributor list:
/// non-empty, ascending member ids, and every `base_step` inside the
/// staleness window relative to the step being committed.
fn check_commit_shape(step: u64, staleness: u64, contributors: &[Contributor]) -> Result<()> {
    if contributors.is_empty() {
        bail!("commit {step} has no contributors (empty commits are never logged)");
    }
    if !contributors.windows(2).all(|w| w[0].client < w[1].client) {
        bail!("commit {step}: contributors must be distinct and ascending by member id");
    }
    for c in contributors {
        // The accumulator accepted this contribution when
        // applied - base <= staleness and applied = step - 1.
        if c.base_step >= step {
            bail!(
                "commit {step}: contributor {} claims base step {} at or past the commit",
                c.client,
                c.base_step
            );
        }
        let lag = step - 1 - c.base_step;
        if lag > staleness {
            bail!(
                "commit {step}: contributor {} lags {lag} steps, window is {staleness}",
                c.client
            );
        }
    }
    Ok(())
}

/// A fully loaded and verified commit log.
#[derive(Clone, Debug)]
pub struct CommitLog {
    pub header: LogInfo,
    pub commits: Vec<LogCommitRecord>,
}

impl CommitLog {
    /// Load and verify a commit log: strict frame decode, header first,
    /// contiguous steps, ascending in-window contributors, digests
    /// recomputed and compared against the recorded ones.
    pub fn load(path: &Path) -> Result<CommitLog> {
        let bytes =
            std::fs::read(path).with_context(|| format!("reading commit log {path:?}"))?;
        let mut off = 0usize;
        let mut header: Option<LogInfo> = None;
        let mut commits = Vec::new();
        while off < bytes.len() {
            if bytes.len() - off < HEADER_LEN {
                bail!(
                    "commit log {path:?}: {} trailing bytes at offset {off} are not a full frame",
                    bytes.len() - off
                );
            }
            let hdr: [u8; HEADER_LEN] = bytes[off..off + HEADER_LEN].try_into().unwrap();
            let (_, op, len) = protocol::decode_header(&hdr)
                .with_context(|| format!("commit log {path:?}: frame header at offset {off}"))?;
            let start = off + HEADER_LEN;
            let end = start.checked_add(len as usize).filter(|&e| e <= bytes.len());
            let Some(end) = end else {
                bail!(
                    "commit log {path:?}: frame at offset {off} claims {len} payload bytes past the end of the file"
                );
            };
            let msg = protocol::decode_payload(op, &bytes[start..end])
                .with_context(|| format!("commit log {path:?}: frame at offset {off}"))?;
            off = end;
            match msg {
                Msg::LogHeader { model, optimizer, seed, base_lr, staleness, first_step } => {
                    if header.is_some() {
                        bail!("commit log {path:?}: duplicate header frame");
                    }
                    if staleness == 0 {
                        bail!("commit log {path:?}: header claims staleness 0 (synchronous runs are not logged)");
                    }
                    header =
                        Some(LogInfo { model, optimizer, seed, base_lr, staleness, first_step });
                }
                Msg::LogCommit { step, epoch, contributors, digest, grads } => {
                    let Some(h) = header.as_ref() else {
                        bail!("commit log {path:?}: first frame is LogCommit, expected LogHeader");
                    };
                    let expect = h.first_step + commits.len() as u64;
                    if step != expect {
                        bail!(
                            "commit log {path:?}: commit {step} where step {expect} was expected (steps must be contiguous)"
                        );
                    }
                    check_commit_shape(step, h.staleness, &contributors)
                        .with_context(|| format!("commit log {path:?}"))?;
                    let actual = grad_digest(&grads);
                    if actual != digest {
                        bail!(
                            "commit log {path:?}: commit {step} digest mismatch (recorded {digest:#018x}, gradient bits hash to {actual:#018x}) — the log is corrupt"
                        );
                    }
                    commits.push(LogCommitRecord { step, epoch, contributors, digest, grads });
                }
                other if header.is_none() => bail!(
                    "commit log {path:?}: first frame is {}, expected LogHeader",
                    other.name()
                ),
                other => bail!(
                    "commit log {path:?}: unexpected {} frame (only LogCommit may follow the header)",
                    other.name()
                ),
            }
        }
        let Some(header) = header else {
            bail!("commit log {path:?} is empty (no header frame)");
        };
        Ok(CommitLog { header, commits })
    }

    /// The largest contributor lag in the log:
    /// `max(commit.step - 1 - base_step)`. The bounded-staleness
    /// property tests assert this never exceeds the header's window.
    pub fn max_lag(&self) -> u64 {
        self.commits
            .iter()
            .flat_map(|c| c.contributors.iter().map(move |k| c.step - 1 - k.base_step))
            .max()
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("smmf_commitlog_{tag}_{}", std::process::id()));
        p
    }

    fn info() -> LogInfo {
        LogInfo {
            model: "synthetic:tiny_lm".into(),
            optimizer: "smmf".into(),
            seed: 3,
            base_lr: 0.05,
            staleness: 2,
            first_step: 1,
        }
    }

    fn grads(step: u64) -> Vec<Vec<f32>> {
        vec![vec![step as f32, -1.5], vec![0.25 * step as f32]]
    }

    #[test]
    fn roundtrip_preserves_every_commit_and_the_header() {
        let path = tmp("roundtrip");
        let mut w = CommitLogWriter::create(&path, &info()).unwrap();
        for step in 1..=4u64 {
            let contributors = vec![
                Contributor { client: 0, base_step: step - 1 },
                Contributor { client: 2, base_step: step.saturating_sub(2) },
            ];
            w.append(step, 1, &contributors, &grads(step)).unwrap();
        }
        drop(w);
        let log = CommitLog::load(&path).unwrap();
        assert_eq!(log.header, info());
        assert_eq!(log.commits.len(), 4);
        for (i, c) in log.commits.iter().enumerate() {
            assert_eq!(c.step, i as u64 + 1);
            assert_eq!(c.grads, grads(c.step));
            assert_eq!(c.digest, grad_digest(&c.grads));
        }
        assert!(log.max_lag() <= info().staleness, "lag {}", log.max_lag());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn writer_rejects_gaps_disorder_and_window_violations() {
        let path = tmp("writer_rejects");
        let mut w = CommitLogWriter::create(&path, &info()).unwrap();
        let one = [Contributor { client: 0, base_step: 0 }];
        // step gap
        assert!(w.append(2, 1, &one, &grads(2)).is_err());
        w.append(1, 1, &one, &grads(1)).unwrap();
        // contributors out of order
        let disordered = [
            Contributor { client: 3, base_step: 1 },
            Contributor { client: 1, base_step: 1 },
        ];
        assert!(w.append(2, 1, &disordered, &grads(2)).is_err());
        // empty contributor list
        assert!(w.append(2, 1, &[], &grads(2)).is_err());
        // outside the staleness window (step 2 would imply lag > 2 only
        // for base past the window; craft step 4 after filling in)
        w.append(2, 1, &[Contributor { client: 0, base_step: 1 }], &grads(2)).unwrap();
        w.append(3, 1, &[Contributor { client: 0, base_step: 2 }], &grads(3)).unwrap();
        let stale = [Contributor { client: 0, base_step: 0 }];
        let err = w.append(4, 1, &stale, &grads(4)).unwrap_err();
        assert!(format!("{err:#}").contains("window"), "{err:#}");
        // base step at/past the commit step
        let future = [Contributor { client: 0, base_step: 4 }];
        assert!(w.append(4, 1, &future, &grads(4)).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn loader_rejects_corruption_truncation_and_misordered_logs() {
        let path = tmp("loader_rejects");
        let mut w = CommitLogWriter::create(&path, &info()).unwrap();
        for step in 1..=3u64 {
            w.append(step, 1, &[Contributor { client: 1, base_step: step - 1 }], &grads(step))
                .unwrap();
        }
        drop(w);
        let good = std::fs::read(&path).unwrap();
        CommitLog::load(&path).unwrap();

        // flip one byte in the last commit's gradient region: digest
        // mismatch, never a panic
        let mut corrupt = good.clone();
        let n = corrupt.len();
        corrupt[n - 3] ^= 0xff;
        std::fs::write(&path, &corrupt).unwrap();
        let err = CommitLog::load(&path).unwrap_err();
        let text = format!("{err:#}");
        assert!(text.contains("digest") || text.contains("payload"), "{text}");

        // truncate mid-frame
        std::fs::write(&path, &good[..n - 7]).unwrap();
        assert!(CommitLog::load(&path).is_err());

        // a log that does not start with a header
        let mut no_header = Vec::new();
        protocol::write_frame(
            &mut no_header,
            &Frame { request_id: 0, msg: Msg::Ack { step: 1 } },
        )
        .unwrap();
        std::fs::write(&path, &no_header).unwrap();
        let err = CommitLog::load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("LogHeader"), "{err:#}");

        // empty file
        std::fs::write(&path, b"").unwrap();
        assert!(CommitLog::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
