//! The `SMMFWIRE` binary wire protocol: versioned, length-prefixed
//! framing for the optimizer-state server.
//!
//! Every message travels as one frame:
//!
//! ```text
//! offset  size  field
//! 0       8     magic  b"SMMFWIRE"
//! 8       4     u32    protocol version (= 4)
//! 12      8     u64    request id (replies echo the request's id)
//! 20      1     u8     op code (see the OP_* constants)
//! 21      8     u64    payload length in bytes (op-dependent cap)
//! 29      len   op-specific payload
//! ```
//!
//! Version 2 added membership epochs: pushes carry the epoch the client
//! believes is current, `Join`/`Leave`/`EpochInfo` renegotiate the
//! barrier, and a push tagged with a superseded epoch is answered with
//! [`Msg::StaleEpoch`] so the client can refresh and retry instead of
//! parsing error strings.
//!
//! Version 3 added bounded-staleness async ingestion (`base_step` /
//! `min_step` / the typed [`Msg::TooStale`]) and the commit-log frames
//! ([`Msg::LogHeader`], [`Msg::LogCommit`]).
//!
//! Version 4 replaces the whole-inventory `PushGrad`/`Params` frames
//! with **chunked tensor streaming**: a push is a [`Msg::PushBegin`]
//! followed by sequence-numbered [`Msg::ChunkHeader`]/[`Msg::ChunkData`]
//! pairs (one per [`chunk_plan`] span, any arrival order) closed by a
//! [`Msg::StreamEnd`]; a pull is answered by a [`Msg::ParamsBegin`]
//! followed by the same chunk-pair stream. Each chunk carries at most
//! [`CHUNK_MAX_BYTES`] of tensor data, so an inventory of any size
//! crosses the wire with O(chunk) framing memory on both ends, and the
//! live-connection payload cap shrinks from 256 MiB to [`MAX_PAYLOAD`]
//! (1 MiB) — no frame on a connection ever needs more. The commit-log
//! file ops (>= 128) keep the old roomy [`MAX_FILE_PAYLOAD`] cap
//! because a logged commit still records one whole coalesced gradient
//! set. A lost or corrupt chunk is recoverable with the
//! [`Msg::Resend`] op, answered by re-sending that single chunk pair.
//! `PullParams` also gains a `mode` byte: [`PULL_FACTORED`] ships the
//! optimizer's native state blobs (SMMF's u/v factor vectors + packed
//! 1-bit sign planes, never densified) instead of dense parameters.
//! v3 commit logs do not replay under v4 (the version check is exact);
//! re-record or replay them with a v3 binary.
//!
//! All multi-byte values are little-endian, encoded/decoded with the
//! checkpoint blob codec (`optim::blob`). Decoding follows the same
//! strict discipline as `SMMFCKPT` loading: magic/version/op are
//! validated before the payload is touched, the payload length is capped
//! before any allocation, every count field is checked against the bytes
//! actually remaining *before* the buffer is allocated, and trailing
//! payload bytes are rejected — a truncated or hostile frame produces a
//! context-rich error, never a panic or an unbounded allocation.
//! Reassembly ([`ChunkAssembler`]) applies the same rigor with typed
//! errors ([`ChunkError`]): duplicate, overlapping, out-of-range and
//! missing chunks are all rejected. The byte-level spec lives in
//! `docs/SERVER_PROTOCOL.md`; changing any layout here requires a
//! version bump and a spec update.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};

use crate::optim::blob::{BlobReader, BlobWriter};

/// Frame magic (8 bytes, never changes).
pub const MAGIC: &[u8; 8] = b"SMMFWIRE";
/// Current protocol version. Bump on any layout change.
/// v2: epoch-tagged pushes, membership ops, extended stats.
/// v3: bounded staleness (`base_step`/`min_step`/`TooStale`) and the
/// commit-log frames (`LogHeader`/`LogCommit`).
/// v4: chunked tensor streaming (`PushBegin`/`ChunkHeader`/`ChunkData`/
/// `StreamEnd`/`ParamsBegin`/`Resend`), the factored pull mode, and the
/// split live-connection / file payload caps. The observability ops
/// (`MetricsDump`/`MetricsText`) are a layout-preserving v4 extension:
/// no existing frame changed shape, and a pre-extension peer that never
/// sends the new request op never sees the new reply op.
pub const VERSION: u32 = 4;
/// Fixed frame header size: magic + version + request id + op + length.
pub const HEADER_LEN: usize = 8 + 4 + 8 + 1 + 8;
/// Hard payload cap for live-connection ops (< 128). Chunked streaming
/// means no connection frame ever carries a whole inventory, so this is
/// deliberately small: a `ChunkData` frame tops out at 8 bytes of
/// addressing + [`CHUNK_MAX_BYTES`] of tensor data.
pub const MAX_PAYLOAD: u64 = 1 << 20;
/// Hard payload cap for the commit-log file ops (>= 128): a logged
/// commit records one whole coalesced gradient set, so it keeps the
/// pre-v4 roomy cap.
pub const MAX_FILE_PAYLOAD: u64 = 256 << 20;
/// Per-frame tensor-count cap (mirrors the checkpoint loader's cap).
pub const MAX_TENSORS: usize = 1 << 20;
/// Snapshot-path / error-string length cap.
pub const MAX_STR_LEN: usize = 4096;
/// Barrier-membership list cap (an `EpochReply` can never claim more).
pub const MAX_MEMBERS: usize = 4096;
/// Most tensor-data bytes one chunk may carry (64 Ki f32 elements).
pub const CHUNK_MAX_BYTES: u64 = 256 * 1024;
/// Most chunks one tensor may be split into (with [`CHUNK_MAX_BYTES`]
/// this bounds a streamed tensor at 16 GiB — far past any inventory
/// here, but finite, so a hostile `total` cannot inflate bookkeeping).
pub const MAX_CHUNKS_PER_TENSOR: u32 = 1 << 16;

/// `PullParams.mode`: dense parameters (f32 tensor data, inventory
/// order) — the only mode v3 had.
pub const PULL_DENSE: u8 = 0;
/// `PullParams.mode`: the optimizer's native per-tensor state blobs
/// (for SMMF: u/v factor vectors + packed 1-bit sign planes, exactly
/// the `SMMFCKPT` per-tensor layout), reconstructed client-side.
pub const PULL_FACTORED: u8 = 1;

/// Request op codes (client -> server).
pub const OP_PUSH_BEGIN: u8 = 1;
pub const OP_PULL_PARAMS: u8 = 2;
pub const OP_SNAPSHOT: u8 = 3;
pub const OP_STATS: u8 = 4;
pub const OP_SHUTDOWN: u8 = 5;
pub const OP_JOIN: u8 = 6;
pub const OP_LEAVE: u8 = 7;
pub const OP_EPOCH_INFO: u8 = 8;
pub const OP_RESEND: u8 = 9;
pub const OP_METRICS_DUMP: u8 = 10;
/// Stream-frame op codes (both directions, between a `PushBegin` /
/// `ParamsBegin` and the closing `StreamEnd`).
pub const OP_CHUNK_HEADER: u8 = 16;
pub const OP_CHUNK_DATA: u8 = 17;
pub const OP_STREAM_END: u8 = 18;
/// Reply op codes (server -> client) live in a disjoint range so a
/// misdirected frame can never be confused for a request.
pub const OP_ACK: u8 = 64;
pub const OP_PARAMS_BEGIN: u8 = 65;
pub const OP_SNAPSHOT_DONE: u8 = 66;
pub const OP_STATS_REPLY: u8 = 67;
pub const OP_BUSY: u8 = 68;
pub const OP_BYE: u8 = 69;
pub const OP_ERR: u8 = 70;
pub const OP_EPOCH_REPLY: u8 = 71;
pub const OP_STALE_EPOCH: u8 = 72;
pub const OP_TOO_STALE: u8 = 73;
pub const OP_METRICS_TEXT: u8 = 74;
/// Commit-log op codes (>= 128) live in a third disjoint range: they
/// are only ever written to / read from the on-disk commit log, never
/// exchanged on a live connection.
pub const OP_LOG_HEADER: u8 = 128;
pub const OP_LOG_COMMIT: u8 = 129;

/// The payload cap that applies to `op`: file ops keep the roomy
/// pre-v4 cap, everything on a live connection gets the small one.
pub fn max_payload_for(op: u8) -> u64 {
    if op >= OP_LOG_HEADER {
        MAX_FILE_PAYLOAD
    } else {
        MAX_PAYLOAD
    }
}

/// `EpochReply::client` value meaning "no client id applies" (the reply
/// to an `EpochInfo` probe, which assigns nothing).
pub const NO_CLIENT: u32 = u32::MAX;

/// Server-side counters returned by [`Msg::Stats`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServerStats {
    /// Optimizer steps applied so far.
    pub step: u64,
    /// Shard (state-owner worker) count.
    pub shards: u32,
    /// Barrier width: gradient pushes per step.
    pub clients: u32,
    /// Total accepted gradient pushes.
    pub pushes: u64,
    /// Requests bounced with [`Msg::Busy`] (request queue full).
    pub busy: u64,
    /// Snapshots written.
    pub snapshots: u64,
    /// Current membership epoch (starts at 1, bumps on every Join /
    /// Leave / eviction).
    pub epoch: u64,
    /// Clients evicted at the barrier deadline (`client_timeout_ms`).
    pub evictions: u64,
    /// Shard workers respawned after a mid-run death.
    pub respawns: u64,
    /// Total wall-clock milliseconds spent recovering dead shards.
    pub recovery_ms: u64,
    /// Bounded-staleness window: 0 = synchronous barrier, S >= 1 =
    /// async ingestion accepting gradients up to S steps stale.
    pub staleness: u64,
}

/// One commit-log contributor: a member id and the applied step its
/// gradient was computed against (its `base_step`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Contributor {
    pub client: u32,
    pub base_step: u64,
}

/// Membership view carried by [`Msg::EpochReply`]: the epoch, the step
/// the barrier is currently assembling (a joiner starts pushing there),
/// the client id the operation concerned ([`NO_CLIENT`] for an
/// `EpochInfo` probe; the assigned id for a `Join`; the departed id for
/// a `Leave`), and the member list in ascending id order.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct EpochView {
    pub epoch: u64,
    pub next_step: u64,
    pub client: u32,
    pub members: Vec<u32>,
}

/// One protocol message (request, stream frame, reply, internal
/// coordinator message, or commit-log record).
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// Opens a push stream: client `client` is about to stream its
    /// gradient set for optimizer step `step` over `n_tensors` tensors
    /// (inventory registration order), tagged with the membership
    /// `epoch` it believes is current and the applied step
    /// (`base_step`) the gradient was computed against. The chunk pairs
    /// and the closing [`Msg::StreamEnd`] follow under the same request
    /// id; the single reply — [`Msg::Ack`], [`Msg::StaleEpoch`],
    /// [`Msg::TooStale`], [`Msg::Busy`] or [`Msg::Err`] — arrives after
    /// `StreamEnd`.
    PushBegin { client: u32, epoch: u64, step: u64, base_step: u64, n_tensors: u32 },
    /// Fetch the current parameters, but only if at least `min_step`
    /// steps have been applied (0 = unconditional). `mode` selects the
    /// representation: [`PULL_DENSE`] or [`PULL_FACTORED`]. Answered
    /// with a [`Msg::ParamsBegin`]-opened chunk stream, or a single
    /// [`Msg::TooStale`] / [`Msg::Busy`] / [`Msg::Err`].
    PullParams { min_step: u64, mode: u8 },
    /// Write a `SMMFCKPT` v2 snapshot to `path` on the server host;
    /// replied with [`Msg::SnapshotDone`]. The server streams it
    /// shard-by-shard — the full inventory's state is never
    /// materialized in one buffer.
    Snapshot { path: String },
    /// Fetch [`ServerStats`]; replied with [`Msg::StatsReply`].
    Stats,
    /// Stop the server; replied with [`Msg::Bye`].
    Shutdown,
    /// Join the barrier: the server assigns the smallest free client id,
    /// bumps the epoch, and replies with [`Msg::EpochReply`].
    Join,
    /// Politely leave the barrier (the graceful alternative to being
    /// evicted); bumps the epoch, replied with [`Msg::EpochReply`].
    Leave { client: u32 },
    /// Probe the current epoch/membership; replied with
    /// [`Msg::EpochReply`] (no membership change).
    EpochInfo,
    /// Recovery: re-send one chunk of the most recent pull stream on
    /// this connection. Answered with that chunk's
    /// [`Msg::ChunkHeader`] + [`Msg::ChunkData`] pair, or [`Msg::Err`]
    /// if there is no cached stream or the address is out of range.
    Resend { tensor_idx: u32, seq: u32 },
    /// Fetch the server's Prometheus-style text exposition (the same
    /// atomics that back [`Msg::StatsReply`], plus the commit/append
    /// latency histograms); replied with [`Msg::MetricsText`]. A v4
    /// extension op — see `docs/OBSERVABILITY.md`.
    MetricsDump,
    /// Addressing for one chunk of tensor `tensor_idx`: this is chunk
    /// `seq` of `total`, covering bytes `[start, start+count)` of the
    /// tensor's `tensor_len`-byte encoding. Always immediately followed
    /// by its [`Msg::ChunkData`]. `count` <= [`CHUNK_MAX_BYTES`].
    ChunkHeader { tensor_idx: u32, seq: u32, total: u32, start: u64, count: u64, tensor_len: u64 },
    /// The bytes of the chunk announced by the preceding
    /// [`Msg::ChunkHeader`] with the same `(tensor_idx, seq)`.
    ChunkData { tensor_idx: u32, seq: u32, bytes: Vec<u8> },
    /// Closes a chunk stream: `tensors` tensors were streamed; for a
    /// params stream `step` echoes the `ParamsBegin` step (for a push
    /// stream it echoes the `PushBegin` step).
    StreamEnd { step: u64, tensors: u32 },
    /// Push accepted and applied; `step` is the step just applied.
    Ack { step: u64 },
    /// Opens the reply stream to a [`Msg::PullParams`]: parameters (or
    /// factored state, per `mode`) after `step` applied steps follow as
    /// chunk pairs over `n_tensors` tensors, closed by
    /// [`Msg::StreamEnd`].
    ParamsBegin { step: u64, mode: u8, n_tensors: u32 },
    /// Snapshot written (`bytes` = on-disk size).
    SnapshotDone { bytes: u64 },
    /// Stats reply.
    StatsReply(ServerStats),
    /// Backpressure: the server's bounded request queue is full — retry.
    Busy,
    /// Shutdown acknowledged; the connection closes after this frame.
    Bye,
    /// Request rejected (unknown client, wrong step, bad shapes, …).
    Err { msg: String },
    /// Reply to `Join` / `Leave` / `EpochInfo`: the new membership view.
    EpochReply(EpochView),
    /// A push carried a superseded epoch; `epoch` is the current one —
    /// refresh membership knowledge and retry.
    StaleEpoch { epoch: u64 },
    /// The request fell outside the bounded-staleness window. For a
    /// push: the gradient's `base_step` is more than `staleness` steps
    /// behind the `applied` step and `required` is the oldest
    /// acceptable base — re-pull and recompute. For a pull: the server
    /// has applied only `applied` steps, short of the `required`
    /// (`min_step`) floor.
    TooStale { applied: u64, required: u64 },
    /// Reply to [`Msg::MetricsDump`]: the exposition text, raw UTF-8 as
    /// the whole payload (capped by [`MAX_PAYLOAD`], clipped at encode
    /// time on a char boundary if a pathological registry exceeds it).
    MetricsText { text: String },
    /// INTERNAL (never framed in v4): a fully reassembled gradient push,
    /// handed from the connection handler to the coordinator over the
    /// in-process request channel. The wire carries it as a
    /// `PushBegin` + chunk stream.
    PushGrad { client: u32, epoch: u64, step: u64, base_step: u64, grads: Vec<Vec<f32>> },
    /// INTERNAL (never framed in v4): the coordinator's dense-params
    /// reply, streamed out by the connection handler as a
    /// `ParamsBegin` + chunk stream.
    Params { step: u64, tensors: Vec<Vec<f32>> },
    /// INTERNAL (never framed in v4): the coordinator's factored-pull
    /// reply — one native state blob per tensor, inventory order —
    /// streamed out by the connection handler.
    StateBlobs { step: u64, blobs: Vec<Vec<u8>> },
    /// Commit-log file header (first frame of a commit log, never sent
    /// on a connection): the run identity a replay must match.
    LogHeader {
        model: String,
        optimizer: String,
        seed: u64,
        base_lr: f32,
        staleness: u64,
        first_step: u64,
    },
    /// One committed partial batch (subsequent commit-log frames):
    /// the optimizer step it applied, the membership epoch at commit
    /// time, the contributors in ascending member-id order, the FNV-1a
    /// digest of the coalesced gradient bits, and those bits themselves
    /// (flat f32 per tensor, inventory order) so `repro replay` can
    /// re-execute the step exactly.
    LogCommit {
        step: u64,
        epoch: u64,
        contributors: Vec<Contributor>,
        digest: u64,
        grads: Vec<Vec<f32>>,
    },
}

impl Msg {
    /// The wire op code of this message. Panics for the internal
    /// coordinator-channel variants — they are never framed.
    pub fn op(&self) -> u8 {
        match self {
            Msg::PushBegin { .. } => OP_PUSH_BEGIN,
            Msg::PullParams { .. } => OP_PULL_PARAMS,
            Msg::Snapshot { .. } => OP_SNAPSHOT,
            Msg::Stats => OP_STATS,
            Msg::Shutdown => OP_SHUTDOWN,
            Msg::Join => OP_JOIN,
            Msg::Leave { .. } => OP_LEAVE,
            Msg::EpochInfo => OP_EPOCH_INFO,
            Msg::Resend { .. } => OP_RESEND,
            Msg::MetricsDump => OP_METRICS_DUMP,
            Msg::ChunkHeader { .. } => OP_CHUNK_HEADER,
            Msg::ChunkData { .. } => OP_CHUNK_DATA,
            Msg::StreamEnd { .. } => OP_STREAM_END,
            Msg::Ack { .. } => OP_ACK,
            Msg::ParamsBegin { .. } => OP_PARAMS_BEGIN,
            Msg::SnapshotDone { .. } => OP_SNAPSHOT_DONE,
            Msg::StatsReply(_) => OP_STATS_REPLY,
            Msg::Busy => OP_BUSY,
            Msg::Bye => OP_BYE,
            Msg::Err { .. } => OP_ERR,
            Msg::EpochReply(_) => OP_EPOCH_REPLY,
            Msg::StaleEpoch { .. } => OP_STALE_EPOCH,
            Msg::TooStale { .. } => OP_TOO_STALE,
            Msg::MetricsText { .. } => OP_METRICS_TEXT,
            Msg::PushGrad { .. } | Msg::Params { .. } | Msg::StateBlobs { .. } => {
                panic!("{} is coordinator-internal and has no wire op in v4", self.name())
            }
            Msg::LogHeader { .. } => OP_LOG_HEADER,
            Msg::LogCommit { .. } => OP_LOG_COMMIT,
        }
    }

    /// Human-readable op name (logs and error contexts).
    pub fn name(&self) -> &'static str {
        match self {
            Msg::PushBegin { .. } => "PushBegin",
            Msg::PullParams { .. } => "PullParams",
            Msg::Snapshot { .. } => "Snapshot",
            Msg::Stats => "Stats",
            Msg::Shutdown => "Shutdown",
            Msg::Join => "Join",
            Msg::Leave { .. } => "Leave",
            Msg::EpochInfo => "EpochInfo",
            Msg::Resend { .. } => "Resend",
            Msg::MetricsDump => "MetricsDump",
            Msg::ChunkHeader { .. } => "ChunkHeader",
            Msg::ChunkData { .. } => "ChunkData",
            Msg::StreamEnd { .. } => "StreamEnd",
            Msg::Ack { .. } => "Ack",
            Msg::ParamsBegin { .. } => "ParamsBegin",
            Msg::SnapshotDone { .. } => "SnapshotDone",
            Msg::StatsReply(_) => "StatsReply",
            Msg::Busy => "Busy",
            Msg::Bye => "Bye",
            Msg::Err { .. } => "Err",
            Msg::EpochReply(_) => "EpochReply",
            Msg::StaleEpoch { .. } => "StaleEpoch",
            Msg::TooStale { .. } => "TooStale",
            Msg::MetricsText { .. } => "MetricsText",
            Msg::PushGrad { .. } => "PushGrad",
            Msg::Params { .. } => "Params",
            Msg::StateBlobs { .. } => "StateBlobs",
            Msg::LogHeader { .. } => "LogHeader",
            Msg::LogCommit { .. } => "LogCommit",
        }
    }
}

/// One wire frame: a request id plus the message.
#[derive(Clone, Debug, PartialEq)]
pub struct Frame {
    pub request_id: u64,
    pub msg: Msg,
}

// ---------------------------------------------------------------------------
// Chunk planning and reassembly
// ---------------------------------------------------------------------------

/// Split a `len`-byte tensor encoding into chunk spans `(start, count)`
/// of at most `budget` bytes each. When `0 < row_bytes <= budget`, the
/// span is rounded down to a whole number of rows, so a row-major 2-D
/// tensor streams in row-aligned pieces (a resent chunk then maps to
/// whole rows). A zero-length tensor still yields one `(0, 0)` chunk so
/// every tensor has `total >= 1` and the receiver can distinguish "an
/// empty tensor arrived" from "nothing arrived". Deterministic: both
/// ends planning over the same `(len, row_bytes, budget)` agree on
/// every span, which is what makes [`Msg::Resend`] addressable.
pub fn chunk_plan(len: u64, row_bytes: u64, budget: u64) -> Vec<(u64, u64)> {
    let budget = budget.max(1);
    let span = if row_bytes > 0 && row_bytes <= budget {
        (budget / row_bytes) * row_bytes
    } else {
        budget
    };
    if len == 0 {
        return vec![(0, 0)];
    }
    let mut out = Vec::with_capacity(len.div_ceil(span) as usize);
    let mut start = 0u64;
    while start < len {
        let count = span.min(len - start);
        out.push((start, count));
        start += count;
    }
    out
}

/// Typed chunk-reassembly error. Every hostile or lossy stream shape
/// maps to one of these — callers (and the property tests) can match on
/// the kind instead of string-parsing.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ChunkError {
    /// `tensor_idx` is past the stream's announced tensor count.
    TensorOutOfRange { tensor_idx: u32, n_tensors: u32 },
    /// `seq >= total` for this tensor.
    SeqOutOfRange { tensor_idx: u32, seq: u32, total: u32 },
    /// Two headers for the same tensor disagree on `total`.
    TotalMismatch { tensor_idx: u32, got: u32, expected: u32 },
    /// `total` is 0 or exceeds [`MAX_CHUNKS_PER_TENSOR`].
    TooManyChunks { tensor_idx: u32, total: u32 },
    /// The header's `tensor_len` disagrees with the known length (or
    /// exceeds the receiver's cap in untrusted mode).
    LenMismatch { tensor_idx: u32, got: u64, expected: u64 },
    /// `start + count` runs past `tensor_len`.
    RangeOutOfBounds { tensor_idx: u32, seq: u32 },
    /// One chunk claims more than [`CHUNK_MAX_BYTES`] bytes.
    ChunkTooLarge { tensor_idx: u32, seq: u32, count: u64 },
    /// A second header (or data) arrived for an already-filled `seq`.
    Duplicate { tensor_idx: u32, seq: u32 },
    /// This chunk's byte range intersects another chunk's.
    Overlap { tensor_idx: u32, seq: u32 },
    /// `ChunkData` arrived with no matching `ChunkHeader` first.
    DataWithoutHeader { tensor_idx: u32, seq: u32 },
    /// The data frame's byte count differs from its header's `count`.
    DataSizeMismatch { tensor_idx: u32, seq: u32, got: u64, expected: u64 },
    /// The stream ended with this chunk never received.
    Missing { tensor_idx: u32, seq: u32 },
    /// The stream ended with the tensor's bytes only partially covered
    /// (all announced chunks arrived but they don't tile `tensor_len`).
    Incomplete { tensor_idx: u32, covered: u64, expected: u64 },
}

impl std::fmt::Display for ChunkError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ChunkError::TensorOutOfRange { tensor_idx, n_tensors } => {
                write!(f, "chunk for tensor {tensor_idx}, stream has {n_tensors} tensors")
            }
            ChunkError::SeqOutOfRange { tensor_idx, seq, total } => {
                write!(f, "tensor {tensor_idx}: chunk seq {seq} out of range (total {total})")
            }
            ChunkError::TotalMismatch { tensor_idx, got, expected } => {
                write!(f, "tensor {tensor_idx}: chunk total {got} contradicts earlier {expected}")
            }
            ChunkError::TooManyChunks { tensor_idx, total } => {
                write!(
                    f,
                    "tensor {tensor_idx}: claims {total} chunks (allowed 1..={MAX_CHUNKS_PER_TENSOR})"
                )
            }
            ChunkError::LenMismatch { tensor_idx, got, expected } => {
                write!(f, "tensor {tensor_idx}: claims {got} bytes, expected {expected}")
            }
            ChunkError::RangeOutOfBounds { tensor_idx, seq } => {
                write!(f, "tensor {tensor_idx} chunk {seq}: byte range runs past the tensor")
            }
            ChunkError::ChunkTooLarge { tensor_idx, seq, count } => {
                write!(
                    f,
                    "tensor {tensor_idx} chunk {seq}: {count} bytes exceeds the \
                     {CHUNK_MAX_BYTES}-byte chunk cap"
                )
            }
            ChunkError::Duplicate { tensor_idx, seq } => {
                write!(f, "tensor {tensor_idx} chunk {seq}: duplicate")
            }
            ChunkError::Overlap { tensor_idx, seq } => {
                write!(f, "tensor {tensor_idx} chunk {seq}: overlaps another chunk's byte range")
            }
            ChunkError::DataWithoutHeader { tensor_idx, seq } => {
                write!(f, "tensor {tensor_idx} chunk {seq}: data with no preceding header")
            }
            ChunkError::DataSizeMismatch { tensor_idx, seq, got, expected } => {
                write!(
                    f,
                    "tensor {tensor_idx} chunk {seq}: {got} data bytes, header announced {expected}"
                )
            }
            ChunkError::Missing { tensor_idx, seq } => {
                write!(f, "tensor {tensor_idx}: chunk {seq} never arrived")
            }
            ChunkError::Incomplete { tensor_idx, covered, expected } => {
                write!(f, "tensor {tensor_idx}: only {covered} of {expected} bytes covered")
            }
        }
    }
}

impl std::error::Error for ChunkError {}

/// Per-chunk receive state.
#[derive(Clone, Copy, PartialEq)]
enum Slot {
    Empty,
    /// Header accepted, data pending.
    Announced { start: u64, count: u64 },
    /// Header + data both in.
    Done { count: u64 },
}

struct TensorAsm {
    /// Declared byte length. Trusted mode: fixed at construction.
    /// Untrusted mode: `None` until the first header announces it.
    len: Option<u64>,
    /// Announced chunk count (0 = no header seen yet).
    total: u32,
    slots: Vec<Slot>,
    /// Accepted spans, keyed by start byte -> end byte, for O(log n)
    /// overlap rejection at header time.
    spans: BTreeMap<u64, u64>,
    buf: Vec<u8>,
    /// Bytes of data received (sum of Done counts).
    received: u64,
}

impl TensorAsm {
    fn done(&self) -> bool {
        self.total > 0
            && self.slots.iter().all(|s| matches!(s, Slot::Done { .. }))
            && Some(self.received) == self.len
    }
}

/// Incremental chunk-stream receiver: accepts
/// [`Msg::ChunkHeader`]/[`Msg::ChunkData`] pairs in **any arrival
/// order**, rejects duplicates, overlaps and bound violations with
/// typed [`ChunkError`]s as they arrive, reports what is still
/// [`ChunkAssembler::missing`] (the driver for [`Msg::Resend`]), and
/// releases the reassembled per-tensor byte buffers only when coverage
/// is exact.
///
/// Two trust models:
/// - [`ChunkAssembler::for_lens`] — the receiver knows every tensor's
///   byte length up front (the server reassembling a push over its own
///   inventory). Buffers are preallocated; a header's `tensor_len` must
///   match exactly.
/// - [`ChunkAssembler::for_unknown`] — lengths come from the stream (a
///   client pulling an inventory it has never seen). Each announced
///   length is capped by `max_bytes`, and the buffer grows only as data
///   actually arrives — a hostile header cannot force an allocation
///   larger than the bytes it ships (plus the final in-place zero-fill
///   up to the announced length at completion, which is bounded by
///   `max_bytes` and only reachable by actually streaming the data).
pub struct ChunkAssembler {
    tensors: Vec<TensorAsm>,
    trusted: bool,
    max_bytes: u64,
}

impl ChunkAssembler {
    /// Trusted receiver over known per-tensor byte lengths.
    pub fn for_lens(lens: &[u64]) -> ChunkAssembler {
        ChunkAssembler {
            tensors: lens
                .iter()
                .map(|&l| TensorAsm {
                    len: Some(l),
                    total: 0,
                    slots: Vec::new(),
                    spans: BTreeMap::new(),
                    buf: vec![0u8; l as usize],
                    received: 0,
                })
                .collect(),
            trusted: true,
            max_bytes: u64::MAX,
        }
    }

    /// Untrusted receiver: `n_tensors` tensors of stream-announced
    /// lengths, each capped at `max_bytes`.
    pub fn for_unknown(n_tensors: usize, max_bytes: u64) -> ChunkAssembler {
        ChunkAssembler {
            tensors: (0..n_tensors)
                .map(|_| TensorAsm {
                    len: None,
                    total: 0,
                    slots: Vec::new(),
                    spans: BTreeMap::new(),
                    buf: Vec::new(),
                    received: 0,
                })
                .collect(),
            trusted: false,
            max_bytes,
        }
    }

    fn tensor(&mut self, tensor_idx: u32) -> Result<&mut TensorAsm, ChunkError> {
        let n = self.tensors.len() as u32;
        self.tensors
            .get_mut(tensor_idx as usize)
            .ok_or(ChunkError::TensorOutOfRange { tensor_idx, n_tensors: n })
    }

    /// Accept one [`Msg::ChunkHeader`].
    pub fn header(
        &mut self,
        tensor_idx: u32,
        seq: u32,
        total: u32,
        start: u64,
        count: u64,
        tensor_len: u64,
    ) -> Result<(), ChunkError> {
        let trusted = self.trusted;
        let max_bytes = self.max_bytes;
        let t = self.tensor(tensor_idx)?;
        if total == 0 || total > MAX_CHUNKS_PER_TENSOR {
            return Err(ChunkError::TooManyChunks { tensor_idx, total });
        }
        match t.len {
            Some(known) if known != tensor_len => {
                return Err(ChunkError::LenMismatch { tensor_idx, got: tensor_len, expected: known });
            }
            Some(_) => {}
            None => {
                if tensor_len > max_bytes {
                    return Err(ChunkError::LenMismatch {
                        tensor_idx,
                        got: tensor_len,
                        expected: max_bytes,
                    });
                }
                t.len = Some(tensor_len);
            }
        }
        if t.total == 0 {
            t.total = total;
            t.slots = vec![Slot::Empty; total as usize];
        } else if t.total != total {
            return Err(ChunkError::TotalMismatch { tensor_idx, got: total, expected: t.total });
        }
        if seq >= total {
            return Err(ChunkError::SeqOutOfRange { tensor_idx, seq, total });
        }
        if t.slots[seq as usize] != Slot::Empty {
            return Err(ChunkError::Duplicate { tensor_idx, seq });
        }
        if count > CHUNK_MAX_BYTES {
            return Err(ChunkError::ChunkTooLarge { tensor_idx, seq, count });
        }
        let len = t.len.unwrap();
        let end = match start.checked_add(count) {
            Some(e) if e <= len => e,
            _ => return Err(ChunkError::RangeOutOfBounds { tensor_idx, seq }),
        };
        // An empty tensor must be announced as exactly one (0, 0) chunk.
        if len == 0 && total != 1 {
            return Err(ChunkError::TooManyChunks { tensor_idx, total });
        }
        if count > 0 {
            // Overlap check against the neighbors in start order.
            if let Some((_, &prev_end)) = t.spans.range(..=start).next_back() {
                if prev_end > start {
                    return Err(ChunkError::Overlap { tensor_idx, seq });
                }
            }
            if let Some((&next_start, _)) = t.spans.range(start..).next() {
                if next_start < end {
                    return Err(ChunkError::Overlap { tensor_idx, seq });
                }
            }
            t.spans.insert(start, end);
        }
        t.slots[seq as usize] = Slot::Announced { start, count };
        Ok(())
    }

    /// Accept one [`Msg::ChunkData`] (its header must already be in).
    pub fn data(&mut self, tensor_idx: u32, seq: u32, bytes: &[u8]) -> Result<(), ChunkError> {
        let t = self.tensor(tensor_idx)?;
        let slot = t
            .slots
            .get(seq as usize)
            .copied()
            .unwrap_or(Slot::Empty);
        let (start, count) = match slot {
            Slot::Announced { start, count } => (start, count),
            Slot::Empty => return Err(ChunkError::DataWithoutHeader { tensor_idx, seq }),
            Slot::Done { .. } => return Err(ChunkError::Duplicate { tensor_idx, seq }),
        };
        if bytes.len() as u64 != count {
            return Err(ChunkError::DataSizeMismatch {
                tensor_idx,
                seq,
                got: bytes.len() as u64,
                expected: count,
            });
        }
        let end = (start + count) as usize;
        if t.buf.len() < end {
            // Untrusted mode: grow only as far as data actually lands.
            t.buf.resize(end, 0);
        }
        t.buf[start as usize..end].copy_from_slice(bytes);
        t.slots[seq as usize] = Slot::Done { count };
        t.received += count;
        Ok(())
    }

    /// The first chunk still outstanding, if any — the address a
    /// receiver puts in a [`Msg::Resend`]. A tensor no header has
    /// reached yet reports `(t, 0)` (chunk 0's header carries `total`,
    /// unlocking the rest).
    pub fn missing(&self) -> Option<(u32, u32)> {
        for (i, t) in self.tensors.iter().enumerate() {
            if t.total == 0 {
                return Some((i as u32, 0));
            }
            for (seq, s) in t.slots.iter().enumerate() {
                if !matches!(s, Slot::Done { .. }) {
                    return Some((i as u32, seq as u32));
                }
            }
        }
        None
    }

    /// True when every tensor is fully covered.
    pub fn is_complete(&self) -> bool {
        self.tensors.iter().all(|t| t.done())
    }

    /// Consume the assembler, releasing the per-tensor byte buffers.
    /// Errors with the first typed defect: a chunk that never arrived
    /// ([`ChunkError::Missing`]) or announced chunks that do not tile
    /// the tensor exactly ([`ChunkError::Incomplete`] — only reachable
    /// with zero-length chunks padding the count, since overlaps are
    /// rejected on arrival).
    pub fn finish(mut self) -> Result<Vec<Vec<u8>>, ChunkError> {
        for (i, t) in self.tensors.iter_mut().enumerate() {
            let tensor_idx = i as u32;
            if t.total == 0 {
                return Err(ChunkError::Missing { tensor_idx, seq: 0 });
            }
            for (seq, s) in t.slots.iter().enumerate() {
                if !matches!(s, Slot::Done { .. }) {
                    return Err(ChunkError::Missing { tensor_idx, seq: seq as u32 });
                }
            }
            let len = t.len.unwrap_or(0);
            if t.received != len {
                return Err(ChunkError::Incomplete { tensor_idx, covered: t.received, expected: len });
            }
            // Untrusted buffers grew to the highest written offset; with
            // exact coverage that *is* the declared length, but an empty
            // tail of zero-count chunks leaves an ungrown buffer.
            if (t.buf.len() as u64) < len {
                t.buf.resize(len as usize, 0);
            }
        }
        Ok(self.tensors.into_iter().map(|t| t.buf).collect())
    }

    /// [`ChunkAssembler::finish`] reinterpreting each buffer as
    /// little-endian f32s (dense params / gradients on the wire).
    pub fn finish_f32(self) -> Result<Vec<Vec<f32>>> {
        let bufs = self.finish()?;
        bufs.into_iter()
            .enumerate()
            .map(|(i, b)| {
                bytes_to_f32s(&b)
                    .with_context(|| format!("reassembled tensor {i} is not f32 data"))
            })
            .collect()
    }
}

/// Reinterpret a little-endian byte buffer as f32s (must be a multiple
/// of 4 bytes).
pub fn bytes_to_f32s(bytes: &[u8]) -> Result<Vec<f32>> {
    if bytes.len() % 4 != 0 {
        bail!("{} bytes is not a whole number of f32s", bytes.len());
    }
    Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
}

/// Encode f32s as the little-endian bytes the chunk stream carries.
pub fn f32s_to_bytes(vals: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(vals.len() * 4);
    for v in vals {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

// ---------------------------------------------------------------------------
// Encoding
// ---------------------------------------------------------------------------

fn write_tensor_list(w: &mut BlobWriter, tensors: &[Vec<f32>]) {
    w.u32(tensors.len() as u32);
    for t in tensors {
        w.len_prefixed_f32s(t);
    }
}

fn write_str(w: &mut BlobWriter, s: &str) {
    w.u32(s.len() as u32);
    w.bytes(s.as_bytes());
}

/// Clip a string to [`MAX_STR_LEN`] bytes on a char boundary. Applied to
/// outgoing `Err` messages (anyhow chains can exceed the cap; a reply
/// the peer's decoder rejects would kill the connection and hide the
/// real error). Snapshot paths are *not* clipped — a silently truncated
/// path is worse than a rejected frame, so over-long paths are refused
/// at the client instead.
fn clip_str(s: &str) -> &str {
    if s.len() <= MAX_STR_LEN {
        return s;
    }
    let mut end = MAX_STR_LEN;
    while !s.is_char_boundary(end) {
        end -= 1;
    }
    &s[..end]
}

fn payload(msg: &Msg) -> Vec<u8> {
    let mut w = BlobWriter::new();
    match msg {
        Msg::PushBegin { client, epoch, step, base_step, n_tensors } => {
            w.u32(*client);
            w.u64(*epoch);
            w.u64(*step);
            w.u64(*base_step);
            w.u32(*n_tensors);
        }
        Msg::Stats
        | Msg::Shutdown
        | Msg::Join
        | Msg::EpochInfo
        | Msg::MetricsDump
        | Msg::Busy
        | Msg::Bye => {}
        Msg::PullParams { min_step, mode } => {
            w.u64(*min_step);
            w.u8(*mode);
        }
        Msg::Snapshot { path } => write_str(&mut w, path),
        Msg::Leave { client } => w.u32(*client),
        Msg::Resend { tensor_idx, seq } => {
            w.u32(*tensor_idx);
            w.u32(*seq);
        }
        Msg::ChunkHeader { tensor_idx, seq, total, start, count, tensor_len } => {
            w.u32(*tensor_idx);
            w.u32(*seq);
            w.u32(*total);
            w.u64(*start);
            w.u64(*count);
            w.u64(*tensor_len);
        }
        Msg::ChunkData { tensor_idx, seq, bytes } => {
            w.u32(*tensor_idx);
            w.u32(*seq);
            w.bytes(bytes);
        }
        Msg::StreamEnd { step, tensors } => {
            w.u64(*step);
            w.u32(*tensors);
        }
        Msg::Ack { step } => w.u64(*step),
        Msg::ParamsBegin { step, mode, n_tensors } => {
            w.u64(*step);
            w.u8(*mode);
            w.u32(*n_tensors);
        }
        Msg::SnapshotDone { bytes } => w.u64(*bytes),
        Msg::StatsReply(s) => {
            w.u64(s.step);
            w.u32(s.shards);
            w.u32(s.clients);
            w.u64(s.pushes);
            w.u64(s.busy);
            w.u64(s.snapshots);
            w.u64(s.epoch);
            w.u64(s.evictions);
            w.u64(s.respawns);
            w.u64(s.recovery_ms);
            w.u64(s.staleness);
        }
        Msg::Err { msg } => write_str(&mut w, clip_str(msg)),
        Msg::EpochReply(v) => {
            w.u64(v.epoch);
            w.u64(v.next_step);
            w.u32(v.client);
            w.u32(v.members.len() as u32);
            for &m in &v.members {
                w.u32(m);
            }
        }
        Msg::StaleEpoch { epoch } => w.u64(*epoch),
        Msg::TooStale { applied, required } => {
            w.u64(*applied);
            w.u64(*required);
        }
        Msg::MetricsText { text } => {
            // Raw UTF-8 as the whole payload (the frame length is the
            // string length). Clipped on a char boundary to the live
            // cap so a pathological registry cannot trip the encoder's
            // cap assertion.
            let mut end = (text.len() as u64).min(MAX_PAYLOAD) as usize;
            while !text.is_char_boundary(end) {
                end -= 1;
            }
            w.bytes(text[..end].as_bytes());
        }
        Msg::PushGrad { .. } | Msg::Params { .. } | Msg::StateBlobs { .. } => {
            panic!("{} is coordinator-internal and never framed in v4", msg.name())
        }
        Msg::LogHeader { model, optimizer, seed, base_lr, staleness, first_step } => {
            write_str(&mut w, model);
            write_str(&mut w, optimizer);
            w.u64(*seed);
            w.f32(*base_lr);
            w.u64(*staleness);
            w.u64(*first_step);
        }
        Msg::LogCommit { step, epoch, contributors, digest, grads } => {
            w.u64(*step);
            w.u64(*epoch);
            w.u32(contributors.len() as u32);
            for c in contributors {
                w.u32(c.client);
                w.u64(c.base_step);
            }
            w.u64(*digest);
            write_tensor_list(&mut w, grads);
        }
    }
    w.finish()
}

/// Wire payload size a v3-style whole-inventory dense `PushGrad` frame
/// *would* need for these shapes. No live frame carries this anymore —
/// v4 streams chunks — but it remains the honest "dense wire" yardstick:
/// the e2e pins assert paper-scale inventories exceed [`MAX_PAYLOAD`]
/// here yet serve end-to-end, and the bench reports it as the dense
/// baseline bytes/step.
pub fn grads_payload_bytes(shapes: &[Vec<usize>]) -> u64 {
    // client u32 + epoch u64 + step u64 + base_step u64 + tensor count
    // u32, then per tensor a u64 length prefix + 4 bytes per element.
    4 + 8 + 8 + 8 + 4
        + shapes
            .iter()
            .map(|s| 8 + 4 * s.iter().product::<usize>() as u64)
            .sum::<u64>()
}

/// Serialize a frame to bytes.
pub fn encode(frame: &Frame) -> Vec<u8> {
    let op = frame.msg.op();
    let payload = payload(&frame.msg);
    assert!(
        payload.len() as u64 <= max_payload_for(op),
        "{} payload {} exceeds the op-{op} cap {}",
        frame.msg.name(),
        payload.len(),
        max_payload_for(op)
    );
    let mut w = BlobWriter::new();
    w.bytes(MAGIC);
    w.u32(VERSION);
    w.u64(frame.request_id);
    w.u8(op);
    w.u64(payload.len() as u64);
    w.bytes(&payload);
    w.finish()
}

/// Write one frame to a stream (a single buffered `write_all`).
pub fn write_frame(w: &mut impl Write, frame: &Frame) -> std::io::Result<()> {
    w.write_all(&encode(frame))?;
    w.flush()
}

// ---------------------------------------------------------------------------
// Decoding
// ---------------------------------------------------------------------------

/// Parse and validate a frame header; returns `(request_id, op, payload
/// length)`. The length is already checked against the op's cap
/// ([`max_payload_for`]).
pub fn decode_header(hdr: &[u8; HEADER_LEN]) -> Result<(u64, u8, u64)> {
    let mut r = BlobReader::new(hdr);
    let magic = r.bytes(8)?;
    if magic != MAGIC {
        bail!("not an SMMFWIRE frame (bad magic {magic:02x?})");
    }
    let version = r.u32()?;
    if version != VERSION {
        bail!("unsupported SMMFWIRE version {version} (supported: {VERSION})");
    }
    let request_id = r.u64()?;
    let op = r.u8()?;
    let len = r.u64()?;
    let cap = max_payload_for(op);
    if len > cap {
        bail!("frame op {op} claims a {len}-byte payload (cap {cap})");
    }
    r.finish()?;
    Ok((request_id, op, len))
}

fn read_tensor_list(r: &mut BlobReader<'_>, what: &str) -> Result<Vec<Vec<f32>>> {
    let n = r.u32()? as usize;
    if n > MAX_TENSORS {
        bail!("{what}: claims {n} tensors (cap {MAX_TENSORS})");
    }
    let mut out = Vec::with_capacity(n.min(1024));
    for i in 0..n {
        let numel = r.u64()? as usize;
        // Remaining-bytes check BEFORE the allocation: a hostile frame
        // cannot force an OOM with a fabricated element count.
        if r.remaining() < numel.saturating_mul(4) {
            bail!(
                "{what}: tensor {i} claims {numel} f32 elements, only {} payload bytes remain",
                r.remaining()
            );
        }
        let mut data = vec![0.0f32; numel];
        r.f32s_into(&mut data)?;
        out.push(data);
    }
    Ok(out)
}

fn read_str(r: &mut BlobReader<'_>, what: &str) -> Result<String> {
    let len = r.u32()? as usize;
    if len > MAX_STR_LEN {
        bail!("{what}: string length {len} exceeds the cap ({MAX_STR_LEN})");
    }
    String::from_utf8(r.bytes(len)?.to_vec()).with_context(|| format!("{what}: not valid UTF-8"))
}

fn check_pull_mode(mode: u8, what: &str) -> Result<u8> {
    if mode > PULL_FACTORED {
        bail!("{what}: unknown pull mode {mode} (0 = dense, 1 = factored)");
    }
    Ok(mode)
}

/// Decode an op-specific payload. The full payload must be consumed —
/// trailing bytes are rejected.
pub fn decode_payload(op: u8, payload: &[u8]) -> Result<Msg> {
    let mut r = BlobReader::new(payload);
    let msg = match op {
        OP_PUSH_BEGIN => {
            let client = r.u32()?;
            let epoch = r.u64()?;
            let step = r.u64()?;
            let base_step = r.u64()?;
            let n_tensors = r.u32()?;
            if n_tensors as usize > MAX_TENSORS {
                bail!("PushBegin: claims {n_tensors} tensors (cap {MAX_TENSORS})");
            }
            Msg::PushBegin { client, epoch, step, base_step, n_tensors }
        }
        OP_PULL_PARAMS => Msg::PullParams {
            min_step: r.u64()?,
            mode: check_pull_mode(r.u8()?, "PullParams")?,
        },
        OP_SNAPSHOT => Msg::Snapshot { path: read_str(&mut r, "Snapshot path")? },
        OP_STATS => Msg::Stats,
        OP_SHUTDOWN => Msg::Shutdown,
        OP_JOIN => Msg::Join,
        OP_LEAVE => Msg::Leave { client: r.u32()? },
        OP_EPOCH_INFO => Msg::EpochInfo,
        OP_RESEND => Msg::Resend { tensor_idx: r.u32()?, seq: r.u32()? },
        OP_METRICS_DUMP => Msg::MetricsDump,
        OP_CHUNK_HEADER => {
            let tensor_idx = r.u32()?;
            let seq = r.u32()?;
            let total = r.u32()?;
            let start = r.u64()?;
            let count = r.u64()?;
            let tensor_len = r.u64()?;
            if total == 0 || total > MAX_CHUNKS_PER_TENSOR {
                bail!("ChunkHeader: claims {total} chunks (allowed 1..={MAX_CHUNKS_PER_TENSOR})");
            }
            if count > CHUNK_MAX_BYTES {
                bail!("ChunkHeader: claims a {count}-byte chunk (cap {CHUNK_MAX_BYTES})");
            }
            Msg::ChunkHeader { tensor_idx, seq, total, start, count, tensor_len }
        }
        OP_CHUNK_DATA => {
            let tensor_idx = r.u32()?;
            let seq = r.u32()?;
            let n = r.remaining();
            if n as u64 > CHUNK_MAX_BYTES {
                bail!("ChunkData: carries {n} bytes (cap {CHUNK_MAX_BYTES})");
            }
            Msg::ChunkData { tensor_idx, seq, bytes: r.bytes(n)?.to_vec() }
        }
        OP_STREAM_END => Msg::StreamEnd { step: r.u64()?, tensors: r.u32()? },
        OP_ACK => Msg::Ack { step: r.u64()? },
        OP_PARAMS_BEGIN => {
            let step = r.u64()?;
            let mode = check_pull_mode(r.u8()?, "ParamsBegin")?;
            let n_tensors = r.u32()?;
            if n_tensors as usize > MAX_TENSORS {
                bail!("ParamsBegin: claims {n_tensors} tensors (cap {MAX_TENSORS})");
            }
            Msg::ParamsBegin { step, mode, n_tensors }
        }
        OP_SNAPSHOT_DONE => Msg::SnapshotDone { bytes: r.u64()? },
        OP_STATS_REPLY => Msg::StatsReply(ServerStats {
            step: r.u64()?,
            shards: r.u32()?,
            clients: r.u32()?,
            pushes: r.u64()?,
            busy: r.u64()?,
            snapshots: r.u64()?,
            epoch: r.u64()?,
            evictions: r.u64()?,
            respawns: r.u64()?,
            recovery_ms: r.u64()?,
            staleness: r.u64()?,
        }),
        OP_BUSY => Msg::Busy,
        OP_BYE => Msg::Bye,
        OP_ERR => Msg::Err { msg: read_str(&mut r, "Err message")? },
        OP_EPOCH_REPLY => {
            let epoch = r.u64()?;
            let next_step = r.u64()?;
            let client = r.u32()?;
            let n = r.u32()? as usize;
            if n > MAX_MEMBERS {
                bail!("EpochReply: claims {n} members (cap {MAX_MEMBERS})");
            }
            // Remaining-bytes check before the allocation, like tensors.
            if r.remaining() < n.saturating_mul(4) {
                bail!(
                    "EpochReply: claims {n} members, only {} payload bytes remain",
                    r.remaining()
                );
            }
            let mut members = Vec::with_capacity(n);
            for _ in 0..n {
                members.push(r.u32()?);
            }
            Msg::EpochReply(EpochView { epoch, next_step, client, members })
        }
        OP_STALE_EPOCH => Msg::StaleEpoch { epoch: r.u64()? },
        OP_TOO_STALE => Msg::TooStale { applied: r.u64()?, required: r.u64()? },
        OP_METRICS_TEXT => {
            // The whole payload is the text; the op's MAX_PAYLOAD cap
            // was already enforced at the header.
            let n = r.remaining();
            Msg::MetricsText {
                text: String::from_utf8(r.bytes(n)?.to_vec())
                    .context("MetricsText: not valid UTF-8")?,
            }
        }
        OP_LOG_HEADER => Msg::LogHeader {
            model: read_str(&mut r, "LogHeader model")?,
            optimizer: read_str(&mut r, "LogHeader optimizer")?,
            seed: r.u64()?,
            base_lr: r.f32()?,
            staleness: r.u64()?,
            first_step: r.u64()?,
        },
        OP_LOG_COMMIT => {
            let step = r.u64()?;
            let epoch = r.u64()?;
            let n = r.u32()? as usize;
            if n > MAX_MEMBERS {
                bail!("LogCommit: claims {n} contributors (cap {MAX_MEMBERS})");
            }
            // Remaining-bytes check before the allocation: 12 bytes
            // (u32 client + u64 base_step) per claimed contributor.
            if r.remaining() < n.saturating_mul(12) {
                bail!(
                    "LogCommit: claims {n} contributors, only {} payload bytes remain",
                    r.remaining()
                );
            }
            let mut contributors = Vec::with_capacity(n);
            for _ in 0..n {
                contributors.push(Contributor { client: r.u32()?, base_step: r.u64()? });
            }
            let digest = r.u64()?;
            let grads = read_tensor_list(&mut r, "LogCommit")?;
            Msg::LogCommit { step, epoch, contributors, digest, grads }
        }
        other => bail!("unknown SMMFWIRE op {other}"),
    };
    r.finish().with_context(|| format!("{} payload", msg.name()))?;
    Ok(msg)
}

/// Decode one complete frame from a byte slice (tests / in-memory use).
/// The slice must hold exactly one frame.
pub fn decode(buf: &[u8]) -> Result<Frame> {
    if buf.len() < HEADER_LEN {
        bail!("truncated frame: {} bytes, header alone needs {HEADER_LEN}", buf.len());
    }
    let hdr: [u8; HEADER_LEN] = buf[..HEADER_LEN].try_into().unwrap();
    let (request_id, op, len) = decode_header(&hdr)?;
    let body = &buf[HEADER_LEN..];
    if (body.len() as u64) < len {
        bail!("truncated frame: payload claims {len} bytes, {} present", body.len());
    }
    if (body.len() as u64) > len {
        bail!("frame has {} trailing bytes", body.len() as u64 - len);
    }
    let msg = decode_payload(op, body)?;
    Ok(Frame { request_id, msg })
}

/// Read one frame from a stream: header first (validated before the
/// payload is buffered), then exactly `len` payload bytes.
pub fn read_frame(r: &mut impl Read) -> Result<Frame> {
    Ok(read_frame_counted(r)?.0)
}

/// [`read_frame`] also reporting the wire bytes consumed (header +
/// payload) — the client's bytes/step accounting hangs off this.
pub fn read_frame_counted(r: &mut impl Read) -> Result<(Frame, u64)> {
    let mut hdr = [0u8; HEADER_LEN];
    r.read_exact(&mut hdr).context("reading SMMFWIRE frame header")?;
    let (request_id, op, len) = decode_header(&hdr)?;
    let mut body = vec![0u8; len as usize];
    r.read_exact(&mut body)
        .with_context(|| format!("reading {len}-byte payload of op {op}"))?;
    let msg = decode_payload(op, &body)?;
    Ok((Frame { request_id, msg }, HEADER_LEN as u64 + len))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn header_roundtrip_and_caps() {
        let f = Frame { request_id: 42, msg: Msg::Ack { step: 7 } };
        let bytes = encode(&f);
        assert_eq!(&bytes[..8], MAGIC);
        let hdr: [u8; HEADER_LEN] = bytes[..HEADER_LEN].try_into().unwrap();
        let (id, op, len) = decode_header(&hdr).unwrap();
        assert_eq!((id, op, len), (42, OP_ACK, 8));
        assert_eq!(decode(&bytes).unwrap(), f);
    }

    #[test]
    fn stream_roundtrip_back_to_back() {
        let frames = vec![
            Frame { request_id: 1, msg: Msg::PullParams { min_step: 4, mode: PULL_FACTORED } },
            Frame {
                request_id: 2,
                msg: Msg::PushBegin { client: 3, epoch: 2, step: 9, base_step: 8, n_tensors: 5 },
            },
            Frame {
                request_id: 2,
                msg: Msg::ChunkHeader {
                    tensor_idx: 1,
                    seq: 0,
                    total: 2,
                    start: 0,
                    count: 8,
                    tensor_len: 12,
                },
            },
            Frame {
                request_id: 2,
                msg: Msg::ChunkData { tensor_idx: 1, seq: 0, bytes: vec![1, 2, 3, 4, 5, 6, 7, 8] },
            },
            Frame { request_id: 2, msg: Msg::StreamEnd { step: 9, tensors: 5 } },
            Frame { request_id: 3, msg: Msg::Resend { tensor_idx: 1, seq: 1 } },
            Frame { request_id: 4, msg: Msg::ParamsBegin { step: 9, mode: PULL_DENSE, n_tensors: 5 } },
            Frame { request_id: 5, msg: Msg::Bye },
        ];
        let mut buf = Vec::new();
        for f in &frames {
            write_frame(&mut buf, f).unwrap();
        }
        let mut cur = std::io::Cursor::new(buf);
        for f in &frames {
            assert_eq!(&read_frame(&mut cur).unwrap(), f);
        }
    }

    #[test]
    fn rejects_oversized_payload_claim_before_reading() {
        let mut w = BlobWriter::new();
        w.bytes(MAGIC);
        w.u32(VERSION);
        w.u64(0);
        w.u8(OP_PULL_PARAMS);
        w.u64(MAX_PAYLOAD + 1);
        let hdr: [u8; HEADER_LEN] = w.finish()[..HEADER_LEN].try_into().unwrap();
        let e = decode_header(&hdr).unwrap_err();
        assert!(format!("{e:#}").contains("cap"), "{e:#}");
    }

    #[test]
    fn payload_cap_is_per_op_range() {
        // A connection op is capped at MAX_PAYLOAD...
        let mk = |op: u8, len: u64| {
            let mut w = BlobWriter::new();
            w.bytes(MAGIC);
            w.u32(VERSION);
            w.u64(0);
            w.u8(op);
            w.u64(len);
            let hdr: [u8; HEADER_LEN] = w.finish()[..HEADER_LEN].try_into().unwrap();
            decode_header(&hdr).map(|(_, _, l)| l)
        };
        assert!(mk(OP_PUSH_BEGIN, MAX_PAYLOAD + 1).is_err());
        // ...while a commit-log file op keeps the roomy file cap.
        assert_eq!(mk(OP_LOG_COMMIT, MAX_PAYLOAD + 1).unwrap(), MAX_PAYLOAD + 1);
        assert!(mk(OP_LOG_COMMIT, MAX_FILE_PAYLOAD + 1).is_err());
    }

    #[test]
    fn rejects_v3_frames_exactly() {
        let f = Frame { request_id: 1, msg: Msg::Stats };
        let mut bytes = encode(&f);
        bytes[8] = 3; // rewrite the version field to v3
        let e = decode(&bytes).unwrap_err();
        assert!(format!("{e:#}").contains("version 3"), "{e:#}");
    }

    #[test]
    fn chunk_plan_tiles_exactly_and_row_aligns() {
        // raw split (no row hint)
        assert_eq!(chunk_plan(10, 0, 4), vec![(0, 4), (4, 4), (8, 2)]);
        // row-aligned: rows of 3 bytes under a budget of 7 -> spans of 6
        assert_eq!(chunk_plan(12, 3, 7), vec![(0, 6), (6, 6)]);
        // a row wider than the budget falls back to raw splitting
        assert_eq!(chunk_plan(10, 64, 4), vec![(0, 4), (4, 4), (8, 2)]);
        // empty tensors still occupy one chunk
        assert_eq!(chunk_plan(0, 0, 4), vec![(0, 0)]);
        // exact tiling for a spread of sizes
        for len in [1u64, 5, 64, 1000, 4096] {
            for row in [0u64, 3, 17] {
                let plan = chunk_plan(len, row, 64);
                assert_eq!(plan[0].0, 0);
                for w in plan.windows(2) {
                    assert_eq!(w[0].0 + w[0].1, w[1].0, "{len} {row}");
                }
                let last = plan.last().unwrap();
                assert_eq!(last.0 + last.1, len);
                assert!(plan.iter().all(|&(_, c)| c <= 64));
            }
        }
    }

    #[test]
    fn assembler_roundtrips_any_order_and_rejects_abuse() {
        let tensors: Vec<Vec<u8>> = vec![(0..=255).collect(), vec![], vec![7; 10]];
        let lens: Vec<u64> = tensors.iter().map(|t| t.len() as u64).collect();
        // Build the chunk pairs, deliver them in reverse order.
        let mut pairs = Vec::new();
        for (ti, t) in tensors.iter().enumerate() {
            let plan = chunk_plan(t.len() as u64, 0, 100);
            for (seq, &(start, count)) in plan.iter().enumerate() {
                pairs.push((
                    ti as u32,
                    seq as u32,
                    plan.len() as u32,
                    start,
                    count,
                    t.len() as u64,
                    t[start as usize..(start + count) as usize].to_vec(),
                ));
            }
        }
        let mut asm = ChunkAssembler::for_lens(&lens);
        assert_eq!(asm.missing(), Some((0, 0)));
        for (ti, seq, total, start, count, len, data) in pairs.iter().rev() {
            asm.header(*ti, *seq, *total, *start, *count, *len).unwrap();
            asm.data(*ti, *seq, data).unwrap();
        }
        assert!(asm.is_complete());
        assert_eq!(asm.missing(), None);
        assert_eq!(asm.finish().unwrap(), tensors);

        // Duplicate header
        let mut asm = ChunkAssembler::for_lens(&[8]);
        asm.header(0, 0, 2, 0, 4, 8).unwrap();
        assert_eq!(asm.header(0, 0, 2, 4, 4, 8), Err(ChunkError::Duplicate { tensor_idx: 0, seq: 0 }));
        // Overlapping ranges across distinct seqs
        assert_eq!(asm.header(0, 1, 2, 2, 4, 8), Err(ChunkError::Overlap { tensor_idx: 0, seq: 1 }));
        // Out-of-bounds range
        assert_eq!(
            asm.header(0, 1, 2, 6, 4, 8),
            Err(ChunkError::RangeOutOfBounds { tensor_idx: 0, seq: 1 })
        );
        // Data without header / size mismatch / missing at finish
        assert_eq!(
            asm.data(0, 1, &[0; 4]),
            Err(ChunkError::DataWithoutHeader { tensor_idx: 0, seq: 1 })
        );
        assert_eq!(
            asm.data(0, 0, &[0; 3]),
            Err(ChunkError::DataSizeMismatch { tensor_idx: 0, seq: 0, got: 3, expected: 4 })
        );
        asm.data(0, 0, &[0; 4]).unwrap();
        assert_eq!(asm.missing(), Some((0, 1)));
        assert_eq!(asm.finish(), Err(ChunkError::Missing { tensor_idx: 0, seq: 1 }));

        // Untrusted mode caps the announced length.
        let mut asm = ChunkAssembler::for_unknown(1, 16);
        assert_eq!(
            asm.header(0, 0, 1, 0, 4, 17),
            Err(ChunkError::LenMismatch { tensor_idx: 0, got: 17, expected: 16 })
        );
        // Trusted mode pins tensor_len to the known length.
        let mut asm = ChunkAssembler::for_lens(&[8]);
        assert_eq!(
            asm.header(0, 0, 1, 0, 4, 9),
            Err(ChunkError::LenMismatch { tensor_idx: 0, got: 9, expected: 8 })
        );
    }

    #[test]
    fn f32_bytes_roundtrip() {
        let vals = vec![1.5f32, -0.0, f32::MIN_POSITIVE, 3.25e8];
        assert_eq!(bytes_to_f32s(&f32s_to_bytes(&vals)).unwrap(), vals);
        assert!(bytes_to_f32s(&[1, 2, 3]).is_err());
    }
}
