//! The `SMMFWIRE` binary wire protocol: versioned, length-prefixed
//! framing for the optimizer-state server.
//!
//! Every message travels as one frame:
//!
//! ```text
//! offset  size  field
//! 0       8     magic  b"SMMFWIRE"
//! 8       4     u32    protocol version (= 3)
//! 12      8     u64    request id (replies echo the request's id)
//! 20      1     u8     op code (see the OP_* constants)
//! 21      8     u64    payload length in bytes (<= MAX_PAYLOAD)
//! 29      len   op-specific payload
//! ```
//!
//! Version 2 added membership epochs: `PushGrad` carries the epoch the
//! client believes is current, `Join`/`Leave`/`EpochInfo` renegotiate
//! the barrier, and a push tagged with a superseded epoch is answered
//! with [`Msg::StaleEpoch`] (carrying the current epoch) so the client
//! can refresh and retry instead of parsing error strings.
//!
//! Version 3 added bounded-staleness async ingestion: `PushGrad`
//! carries the `base_step` its gradient was computed against,
//! `PullParams` carries a `min_step` freshness floor, and a push (or
//! pull) outside the staleness window is answered with the typed
//! [`Msg::TooStale`]. The commit-log frames ([`Msg::LogHeader`],
//! [`Msg::LogCommit`]) live in a third op range (>= 128): they are
//! written to the on-disk commit log through the same framing and
//! strict decode, but are never valid requests or replies on a live
//! connection.
//!
//! All multi-byte values are little-endian, encoded/decoded with the
//! checkpoint blob codec (`optim::blob`). Decoding follows the same
//! strict discipline as `SMMFCKPT` loading: magic/version/op are
//! validated before the payload is touched, the payload length is capped
//! before any allocation, every per-tensor element count is checked
//! against the bytes actually remaining *before* the buffer is
//! allocated, and trailing payload bytes are rejected — a truncated or
//! hostile frame produces a context-rich error, never a panic or an
//! unbounded allocation. The byte-level spec lives in
//! `docs/SERVER_PROTOCOL.md`; changing any layout here requires a
//! version bump and a spec update.

use anyhow::{bail, Context, Result};
use std::io::{Read, Write};

use crate::optim::blob::{BlobReader, BlobWriter};

/// Frame magic (8 bytes, never changes).
pub const MAGIC: &[u8; 8] = b"SMMFWIRE";
/// Current protocol version. Bump on any layout change.
/// v2: epoch-tagged `PushGrad`, membership ops, extended stats.
/// v3: bounded staleness (`base_step`/`min_step`/`TooStale`) and the
/// commit-log frames (`LogHeader`/`LogCommit`).
pub const VERSION: u32 = 3;
/// Fixed frame header size: magic + version + request id + op + length.
pub const HEADER_LEN: usize = 8 + 4 + 8 + 1 + 8;
/// Hard payload cap: a frame may never ask the peer to buffer more.
pub const MAX_PAYLOAD: u64 = 256 << 20;
/// Per-frame tensor-count cap (mirrors the checkpoint loader's cap).
pub const MAX_TENSORS: usize = 1 << 20;
/// Snapshot-path / error-string length cap.
pub const MAX_STR_LEN: usize = 4096;
/// Barrier-membership list cap (an `EpochReply` can never claim more).
pub const MAX_MEMBERS: usize = 4096;

/// Request op codes (client -> server).
pub const OP_PUSH_GRAD: u8 = 1;
pub const OP_PULL_PARAMS: u8 = 2;
pub const OP_SNAPSHOT: u8 = 3;
pub const OP_STATS: u8 = 4;
pub const OP_SHUTDOWN: u8 = 5;
pub const OP_JOIN: u8 = 6;
pub const OP_LEAVE: u8 = 7;
pub const OP_EPOCH_INFO: u8 = 8;
/// Reply op codes (server -> client) live in a disjoint range so a
/// misdirected frame can never be confused for a request.
pub const OP_ACK: u8 = 64;
pub const OP_PARAMS: u8 = 65;
pub const OP_SNAPSHOT_DONE: u8 = 66;
pub const OP_STATS_REPLY: u8 = 67;
pub const OP_BUSY: u8 = 68;
pub const OP_BYE: u8 = 69;
pub const OP_ERR: u8 = 70;
pub const OP_EPOCH_REPLY: u8 = 71;
pub const OP_STALE_EPOCH: u8 = 72;
pub const OP_TOO_STALE: u8 = 73;
/// Commit-log op codes (>= 128) live in a third disjoint range: they
/// are only ever written to / read from the on-disk commit log, never
/// exchanged on a live connection.
pub const OP_LOG_HEADER: u8 = 128;
pub const OP_LOG_COMMIT: u8 = 129;

/// `EpochReply::client` value meaning "no client id applies" (the reply
/// to an `EpochInfo` probe, which assigns nothing).
pub const NO_CLIENT: u32 = u32::MAX;

/// Server-side counters returned by [`Msg::Stats`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServerStats {
    /// Optimizer steps applied so far.
    pub step: u64,
    /// Shard (state-owner worker) count.
    pub shards: u32,
    /// Barrier width: gradient pushes per step.
    pub clients: u32,
    /// Total accepted `PushGrad` requests.
    pub pushes: u64,
    /// Requests bounced with [`Msg::Busy`] (request queue full).
    pub busy: u64,
    /// Snapshots written.
    pub snapshots: u64,
    /// Current membership epoch (starts at 1, bumps on every Join /
    /// Leave / eviction).
    pub epoch: u64,
    /// Clients evicted at the barrier deadline (`client_timeout_ms`).
    pub evictions: u64,
    /// Shard workers respawned after a mid-run death.
    pub respawns: u64,
    /// Total wall-clock milliseconds spent recovering dead shards.
    pub recovery_ms: u64,
    /// Bounded-staleness window: 0 = synchronous barrier, S >= 1 =
    /// async ingestion accepting gradients up to S steps stale.
    pub staleness: u64,
}

/// One commit-log contributor: a member id and the applied step its
/// gradient was computed against (its `base_step`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Contributor {
    pub client: u32,
    pub base_step: u64,
}

/// Membership view carried by [`Msg::EpochReply`]: the epoch, the step
/// the barrier is currently assembling (a joiner starts pushing there),
/// the client id the operation concerned ([`NO_CLIENT`] for an
/// `EpochInfo` probe; the assigned id for a `Join`; the departed id for
/// a `Leave`), and the member list in ascending id order.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct EpochView {
    pub epoch: u64,
    pub next_step: u64,
    pub client: u32,
    pub members: Vec<u32>,
}

/// One protocol message (request or reply).
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// Client `client` pushes its gradient set for optimizer step `step`
    /// (flat f32 data per tensor, inventory registration order),
    /// tagged with the membership `epoch` it believes is current and
    /// the applied step (`base_step`) the gradient was computed
    /// against. The reply — [`Msg::Ack`] — is deferred until the step
    /// barrier completes (sync mode) or the contribution is committed
    /// as part of a partial batch (async mode; the acked step is the
    /// commit step, which may exceed `step`). A superseded epoch is
    /// answered with [`Msg::StaleEpoch`]; a `base_step` outside the
    /// staleness window with [`Msg::TooStale`].
    PushGrad { client: u32, epoch: u64, step: u64, base_step: u64, grads: Vec<Vec<f32>> },
    /// Fetch the current parameters, but only if at least `min_step`
    /// steps have been applied (0 = unconditional); replied with
    /// [`Msg::Params`], or [`Msg::TooStale`] when the server is behind
    /// the floor.
    PullParams { min_step: u64 },
    /// Write a `SMMFCKPT` v2 snapshot to `path` on the server host;
    /// replied with [`Msg::SnapshotDone`].
    Snapshot { path: String },
    /// Fetch [`ServerStats`]; replied with [`Msg::StatsReply`].
    Stats,
    /// Stop the server; replied with [`Msg::Bye`].
    Shutdown,
    /// Join the barrier: the server assigns the smallest free client id,
    /// bumps the epoch, and replies with [`Msg::EpochReply`].
    Join,
    /// Politely leave the barrier (the graceful alternative to being
    /// evicted); bumps the epoch, replied with [`Msg::EpochReply`].
    Leave { client: u32 },
    /// Probe the current epoch/membership; replied with
    /// [`Msg::EpochReply`] (no membership change).
    EpochInfo,
    /// `PushGrad` accepted and applied; `step` is the step just applied.
    Ack { step: u64 },
    /// Current parameters after `step` applied steps.
    Params { step: u64, tensors: Vec<Vec<f32>> },
    /// Snapshot written (`bytes` = on-disk size).
    SnapshotDone { bytes: u64 },
    /// Stats reply.
    StatsReply(ServerStats),
    /// Backpressure: the server's bounded request queue is full — retry.
    Busy,
    /// Shutdown acknowledged; the connection closes after this frame.
    Bye,
    /// Request rejected (unknown client, wrong step, bad shapes, …).
    Err { msg: String },
    /// Reply to `Join` / `Leave` / `EpochInfo`: the new membership view.
    EpochReply(EpochView),
    /// A `PushGrad` carried a superseded epoch; `epoch` is the current
    /// one — refresh membership knowledge and retry.
    StaleEpoch { epoch: u64 },
    /// The request fell outside the bounded-staleness window. For a
    /// push: the gradient's `base_step` is more than `staleness` steps
    /// behind the `applied` step and `required` is the oldest
    /// acceptable base — re-pull and recompute. For a pull: the server
    /// has applied only `applied` steps, short of the `required`
    /// (`min_step`) floor.
    TooStale { applied: u64, required: u64 },
    /// Commit-log file header (first frame of a commit log, never sent
    /// on a connection): the run identity a replay must match.
    LogHeader {
        model: String,
        optimizer: String,
        seed: u64,
        base_lr: f32,
        staleness: u64,
        first_step: u64,
    },
    /// One committed partial batch (subsequent commit-log frames):
    /// the optimizer step it applied, the membership epoch at commit
    /// time, the contributors in ascending member-id order, the FNV-1a
    /// digest of the coalesced gradient bits, and those bits themselves
    /// (flat f32 per tensor, inventory order) so `repro replay` can
    /// re-execute the step exactly.
    LogCommit {
        step: u64,
        epoch: u64,
        contributors: Vec<Contributor>,
        digest: u64,
        grads: Vec<Vec<f32>>,
    },
}

impl Msg {
    /// The wire op code of this message.
    pub fn op(&self) -> u8 {
        match self {
            Msg::PushGrad { .. } => OP_PUSH_GRAD,
            Msg::PullParams { .. } => OP_PULL_PARAMS,
            Msg::Snapshot { .. } => OP_SNAPSHOT,
            Msg::Stats => OP_STATS,
            Msg::Shutdown => OP_SHUTDOWN,
            Msg::Join => OP_JOIN,
            Msg::Leave { .. } => OP_LEAVE,
            Msg::EpochInfo => OP_EPOCH_INFO,
            Msg::Ack { .. } => OP_ACK,
            Msg::Params { .. } => OP_PARAMS,
            Msg::SnapshotDone { .. } => OP_SNAPSHOT_DONE,
            Msg::StatsReply(_) => OP_STATS_REPLY,
            Msg::Busy => OP_BUSY,
            Msg::Bye => OP_BYE,
            Msg::Err { .. } => OP_ERR,
            Msg::EpochReply(_) => OP_EPOCH_REPLY,
            Msg::StaleEpoch { .. } => OP_STALE_EPOCH,
            Msg::TooStale { .. } => OP_TOO_STALE,
            Msg::LogHeader { .. } => OP_LOG_HEADER,
            Msg::LogCommit { .. } => OP_LOG_COMMIT,
        }
    }

    /// Human-readable op name (logs and error contexts).
    pub fn name(&self) -> &'static str {
        match self {
            Msg::PushGrad { .. } => "PushGrad",
            Msg::PullParams { .. } => "PullParams",
            Msg::Snapshot { .. } => "Snapshot",
            Msg::Stats => "Stats",
            Msg::Shutdown => "Shutdown",
            Msg::Join => "Join",
            Msg::Leave { .. } => "Leave",
            Msg::EpochInfo => "EpochInfo",
            Msg::Ack { .. } => "Ack",
            Msg::Params { .. } => "Params",
            Msg::SnapshotDone { .. } => "SnapshotDone",
            Msg::StatsReply(_) => "StatsReply",
            Msg::Busy => "Busy",
            Msg::Bye => "Bye",
            Msg::Err { .. } => "Err",
            Msg::EpochReply(_) => "EpochReply",
            Msg::StaleEpoch { .. } => "StaleEpoch",
            Msg::TooStale { .. } => "TooStale",
            Msg::LogHeader { .. } => "LogHeader",
            Msg::LogCommit { .. } => "LogCommit",
        }
    }
}

/// One wire frame: a request id plus the message.
#[derive(Clone, Debug, PartialEq)]
pub struct Frame {
    pub request_id: u64,
    pub msg: Msg,
}

// ---------------------------------------------------------------------------
// Encoding
// ---------------------------------------------------------------------------

fn write_tensor_list(w: &mut BlobWriter, tensors: &[Vec<f32>]) {
    w.u32(tensors.len() as u32);
    for t in tensors {
        w.len_prefixed_f32s(t);
    }
}

fn write_str(w: &mut BlobWriter, s: &str) {
    w.u32(s.len() as u32);
    w.bytes(s.as_bytes());
}

/// Clip a string to [`MAX_STR_LEN`] bytes on a char boundary. Applied to
/// outgoing `Err` messages (anyhow chains can exceed the cap; a reply
/// the peer's decoder rejects would kill the connection and hide the
/// real error). Snapshot paths are *not* clipped — a silently truncated
/// path is worse than a rejected frame, so over-long paths are refused
/// at the client instead.
fn clip_str(s: &str) -> &str {
    if s.len() <= MAX_STR_LEN {
        return s;
    }
    let mut end = MAX_STR_LEN;
    while !s.is_char_boundary(end) {
        end -= 1;
    }
    &s[..end]
}

fn payload(msg: &Msg) -> Vec<u8> {
    let mut w = BlobWriter::new();
    match msg {
        Msg::PushGrad { client, epoch, step, base_step, grads } => {
            w.u32(*client);
            w.u64(*epoch);
            w.u64(*step);
            w.u64(*base_step);
            write_tensor_list(&mut w, grads);
        }
        Msg::Stats | Msg::Shutdown | Msg::Join | Msg::EpochInfo | Msg::Busy | Msg::Bye => {}
        Msg::PullParams { min_step } => w.u64(*min_step),
        Msg::Snapshot { path } => write_str(&mut w, path),
        Msg::Leave { client } => w.u32(*client),
        Msg::Ack { step } => w.u64(*step),
        Msg::Params { step, tensors } => {
            w.u64(*step);
            write_tensor_list(&mut w, tensors);
        }
        Msg::SnapshotDone { bytes } => w.u64(*bytes),
        Msg::StatsReply(s) => {
            w.u64(s.step);
            w.u32(s.shards);
            w.u32(s.clients);
            w.u64(s.pushes);
            w.u64(s.busy);
            w.u64(s.snapshots);
            w.u64(s.epoch);
            w.u64(s.evictions);
            w.u64(s.respawns);
            w.u64(s.recovery_ms);
            w.u64(s.staleness);
        }
        Msg::Err { msg } => write_str(&mut w, clip_str(msg)),
        Msg::EpochReply(v) => {
            w.u64(v.epoch);
            w.u64(v.next_step);
            w.u32(v.client);
            w.u32(v.members.len() as u32);
            for &m in &v.members {
                w.u32(m);
            }
        }
        Msg::StaleEpoch { epoch } => w.u64(*epoch),
        Msg::TooStale { applied, required } => {
            w.u64(*applied);
            w.u64(*required);
        }
        Msg::LogHeader { model, optimizer, seed, base_lr, staleness, first_step } => {
            write_str(&mut w, model);
            write_str(&mut w, optimizer);
            w.u64(*seed);
            w.f32(*base_lr);
            w.u64(*staleness);
            w.u64(*first_step);
        }
        Msg::LogCommit { step, epoch, contributors, digest, grads } => {
            w.u64(*step);
            w.u64(*epoch);
            w.u32(contributors.len() as u32);
            for c in contributors {
                w.u32(c.client);
                w.u64(c.base_step);
            }
            w.u64(*digest);
            write_tensor_list(&mut w, grads);
        }
    }
    w.finish()
}

/// Wire payload size of a `PushGrad` frame over the given shapes — the
/// largest message either side ever sends for an inventory on a live
/// connection (a `Params` reply's prefix is `u64 step` + `u32 count` vs
/// PushGrad's `u32 client` + `u64 epoch` + `u64 step` + `u64 base_step`
/// + `u32 count`, i.e. 20 bytes smaller; a `LogCommit` frame can grow
/// larger still by its per-contributor metadata, which the server's
/// capacity check budgets separately). Servers and load generators
/// check this against [`MAX_PAYLOAD`] up front, so an inventory too
/// large for the wire fails with a clear error at startup instead of an
/// assert on the first push.
pub fn grads_payload_bytes(shapes: &[Vec<usize>]) -> u64 {
    // client u32 + epoch u64 + step u64 + base_step u64 + tensor count
    // u32, then per tensor a u64 length prefix + 4 bytes per element.
    4 + 8 + 8 + 8 + 4
        + shapes
            .iter()
            .map(|s| 8 + 4 * s.iter().product::<usize>() as u64)
            .sum::<u64>()
}

/// Serialize a frame to bytes.
pub fn encode(frame: &Frame) -> Vec<u8> {
    let payload = payload(&frame.msg);
    assert!(
        payload.len() as u64 <= MAX_PAYLOAD,
        "frame payload {} exceeds MAX_PAYLOAD",
        payload.len()
    );
    let mut w = BlobWriter::new();
    w.bytes(MAGIC);
    w.u32(VERSION);
    w.u64(frame.request_id);
    w.u8(frame.msg.op());
    w.u64(payload.len() as u64);
    w.bytes(&payload);
    w.finish()
}

/// Write one frame to a stream (a single buffered `write_all`).
pub fn write_frame(w: &mut impl Write, frame: &Frame) -> std::io::Result<()> {
    w.write_all(&encode(frame))?;
    w.flush()
}

// ---------------------------------------------------------------------------
// Decoding
// ---------------------------------------------------------------------------

/// Parse and validate a frame header; returns `(request_id, op, payload
/// length)`. The length is already checked against [`MAX_PAYLOAD`].
pub fn decode_header(hdr: &[u8; HEADER_LEN]) -> Result<(u64, u8, u64)> {
    let mut r = BlobReader::new(hdr);
    let magic = r.bytes(8)?;
    if magic != MAGIC {
        bail!("not an SMMFWIRE frame (bad magic {magic:02x?})");
    }
    let version = r.u32()?;
    if version != VERSION {
        bail!("unsupported SMMFWIRE version {version} (supported: {VERSION})");
    }
    let request_id = r.u64()?;
    let op = r.u8()?;
    let len = r.u64()?;
    if len > MAX_PAYLOAD {
        bail!("frame op {op} claims a {len}-byte payload (cap {MAX_PAYLOAD})");
    }
    r.finish()?;
    Ok((request_id, op, len))
}

fn read_tensor_list(r: &mut BlobReader<'_>, what: &str) -> Result<Vec<Vec<f32>>> {
    let n = r.u32()? as usize;
    if n > MAX_TENSORS {
        bail!("{what}: claims {n} tensors (cap {MAX_TENSORS})");
    }
    let mut out = Vec::with_capacity(n.min(1024));
    for i in 0..n {
        let numel = r.u64()? as usize;
        // Remaining-bytes check BEFORE the allocation: a hostile frame
        // cannot force an OOM with a fabricated element count.
        if r.remaining() < numel.saturating_mul(4) {
            bail!(
                "{what}: tensor {i} claims {numel} f32 elements, only {} payload bytes remain",
                r.remaining()
            );
        }
        let mut data = vec![0.0f32; numel];
        r.f32s_into(&mut data)?;
        out.push(data);
    }
    Ok(out)
}

fn read_str(r: &mut BlobReader<'_>, what: &str) -> Result<String> {
    let len = r.u32()? as usize;
    if len > MAX_STR_LEN {
        bail!("{what}: string length {len} exceeds the cap ({MAX_STR_LEN})");
    }
    String::from_utf8(r.bytes(len)?.to_vec()).with_context(|| format!("{what}: not valid UTF-8"))
}

/// Decode an op-specific payload. The full payload must be consumed —
/// trailing bytes are rejected.
pub fn decode_payload(op: u8, payload: &[u8]) -> Result<Msg> {
    let mut r = BlobReader::new(payload);
    let msg = match op {
        OP_PUSH_GRAD => {
            let client = r.u32()?;
            let epoch = r.u64()?;
            let step = r.u64()?;
            let base_step = r.u64()?;
            let grads = read_tensor_list(&mut r, "PushGrad")?;
            Msg::PushGrad { client, epoch, step, base_step, grads }
        }
        OP_PULL_PARAMS => Msg::PullParams { min_step: r.u64()? },
        OP_SNAPSHOT => Msg::Snapshot { path: read_str(&mut r, "Snapshot path")? },
        OP_STATS => Msg::Stats,
        OP_SHUTDOWN => Msg::Shutdown,
        OP_JOIN => Msg::Join,
        OP_LEAVE => Msg::Leave { client: r.u32()? },
        OP_EPOCH_INFO => Msg::EpochInfo,
        OP_ACK => Msg::Ack { step: r.u64()? },
        OP_PARAMS => {
            let step = r.u64()?;
            let tensors = read_tensor_list(&mut r, "Params")?;
            Msg::Params { step, tensors }
        }
        OP_SNAPSHOT_DONE => Msg::SnapshotDone { bytes: r.u64()? },
        OP_STATS_REPLY => Msg::StatsReply(ServerStats {
            step: r.u64()?,
            shards: r.u32()?,
            clients: r.u32()?,
            pushes: r.u64()?,
            busy: r.u64()?,
            snapshots: r.u64()?,
            epoch: r.u64()?,
            evictions: r.u64()?,
            respawns: r.u64()?,
            recovery_ms: r.u64()?,
            staleness: r.u64()?,
        }),
        OP_BUSY => Msg::Busy,
        OP_BYE => Msg::Bye,
        OP_ERR => Msg::Err { msg: read_str(&mut r, "Err message")? },
        OP_EPOCH_REPLY => {
            let epoch = r.u64()?;
            let next_step = r.u64()?;
            let client = r.u32()?;
            let n = r.u32()? as usize;
            if n > MAX_MEMBERS {
                bail!("EpochReply: claims {n} members (cap {MAX_MEMBERS})");
            }
            // Remaining-bytes check before the allocation, like tensors.
            if r.remaining() < n.saturating_mul(4) {
                bail!(
                    "EpochReply: claims {n} members, only {} payload bytes remain",
                    r.remaining()
                );
            }
            let mut members = Vec::with_capacity(n);
            for _ in 0..n {
                members.push(r.u32()?);
            }
            Msg::EpochReply(EpochView { epoch, next_step, client, members })
        }
        OP_STALE_EPOCH => Msg::StaleEpoch { epoch: r.u64()? },
        OP_TOO_STALE => Msg::TooStale { applied: r.u64()?, required: r.u64()? },
        OP_LOG_HEADER => Msg::LogHeader {
            model: read_str(&mut r, "LogHeader model")?,
            optimizer: read_str(&mut r, "LogHeader optimizer")?,
            seed: r.u64()?,
            base_lr: r.f32()?,
            staleness: r.u64()?,
            first_step: r.u64()?,
        },
        OP_LOG_COMMIT => {
            let step = r.u64()?;
            let epoch = r.u64()?;
            let n = r.u32()? as usize;
            if n > MAX_MEMBERS {
                bail!("LogCommit: claims {n} contributors (cap {MAX_MEMBERS})");
            }
            // Remaining-bytes check before the allocation: 12 bytes
            // (u32 client + u64 base_step) per claimed contributor.
            if r.remaining() < n.saturating_mul(12) {
                bail!(
                    "LogCommit: claims {n} contributors, only {} payload bytes remain",
                    r.remaining()
                );
            }
            let mut contributors = Vec::with_capacity(n);
            for _ in 0..n {
                contributors.push(Contributor { client: r.u32()?, base_step: r.u64()? });
            }
            let digest = r.u64()?;
            let grads = read_tensor_list(&mut r, "LogCommit")?;
            Msg::LogCommit { step, epoch, contributors, digest, grads }
        }
        other => bail!("unknown SMMFWIRE op {other}"),
    };
    r.finish().with_context(|| format!("{} payload", msg.name()))?;
    Ok(msg)
}

/// Decode one complete frame from a byte slice (tests / in-memory use).
/// The slice must hold exactly one frame.
pub fn decode(buf: &[u8]) -> Result<Frame> {
    if buf.len() < HEADER_LEN {
        bail!("truncated frame: {} bytes, header alone needs {HEADER_LEN}", buf.len());
    }
    let hdr: [u8; HEADER_LEN] = buf[..HEADER_LEN].try_into().unwrap();
    let (request_id, op, len) = decode_header(&hdr)?;
    let body = &buf[HEADER_LEN..];
    if (body.len() as u64) < len {
        bail!("truncated frame: payload claims {len} bytes, {} present", body.len());
    }
    if (body.len() as u64) > len {
        bail!("frame has {} trailing bytes", body.len() as u64 - len);
    }
    let msg = decode_payload(op, body)?;
    Ok(Frame { request_id, msg })
}

/// Read one frame from a stream: header first (validated before the
/// payload is buffered), then exactly `len` payload bytes.
pub fn read_frame(r: &mut impl Read) -> Result<Frame> {
    let mut hdr = [0u8; HEADER_LEN];
    r.read_exact(&mut hdr).context("reading SMMFWIRE frame header")?;
    let (request_id, op, len) = decode_header(&hdr)?;
    let mut body = vec![0u8; len as usize];
    r.read_exact(&mut body)
        .with_context(|| format!("reading {len}-byte payload of op {op}"))?;
    let msg = decode_payload(op, &body)?;
    Ok(Frame { request_id, msg })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn header_roundtrip_and_caps() {
        let f = Frame { request_id: 42, msg: Msg::Ack { step: 7 } };
        let bytes = encode(&f);
        assert_eq!(&bytes[..8], MAGIC);
        let hdr: [u8; HEADER_LEN] = bytes[..HEADER_LEN].try_into().unwrap();
        let (id, op, len) = decode_header(&hdr).unwrap();
        assert_eq!((id, op, len), (42, OP_ACK, 8));
        assert_eq!(decode(&bytes).unwrap(), f);
    }

    #[test]
    fn stream_roundtrip_back_to_back() {
        let frames = vec![
            Frame { request_id: 1, msg: Msg::PullParams { min_step: 4 } },
            Frame {
                request_id: 2,
                msg: Msg::PushGrad {
                    client: 3,
                    epoch: 2,
                    step: 9,
                    base_step: 8,
                    grads: vec![vec![1.5, -2.0], vec![]],
                },
            },
            Frame { request_id: 3, msg: Msg::Bye },
        ];
        let mut buf = Vec::new();
        for f in &frames {
            write_frame(&mut buf, f).unwrap();
        }
        let mut cur = std::io::Cursor::new(buf);
        for f in &frames {
            assert_eq!(&read_frame(&mut cur).unwrap(), f);
        }
    }

    #[test]
    fn rejects_oversized_payload_claim_before_reading() {
        let mut w = BlobWriter::new();
        w.bytes(MAGIC);
        w.u32(VERSION);
        w.u64(0);
        w.u8(OP_PULL_PARAMS);
        w.u64(MAX_PAYLOAD + 1);
        let hdr: [u8; HEADER_LEN] = w.finish()[..HEADER_LEN].try_into().unwrap();
        let e = decode_header(&hdr).unwrap_err();
        assert!(format!("{e:#}").contains("cap"), "{e:#}");
    }
}
