//! Client side of the optimizer-state server: a blocking wire client
//! plus the deterministic synthetic gradient workload shared by the
//! load generator and the single-process reference trainer.
//!
//! Under wire protocol v4 the client is a chunking peer: a gradient
//! push goes out as `PushBegin` → per-tensor chunk pairs → `StreamEnd`
//! and a parameter pull comes back the same way, reassembled through
//! [`protocol::ChunkAssembler`] with [`Msg::Resend`] recovery for any
//! chunk the stream did not deliver. The public API is unchanged from
//! v3 — callers still exchange whole `Vec<Vec<f32>>` tensor sets; the
//! chunking is invisible below [`Client::push_grad`] /
//! [`Client::pull_params`].

use anyhow::{anyhow, bail, Context, Result};
use std::io::{BufReader, BufWriter, Write};
use std::net::TcpStream;
use std::time::Duration;

use crate::optim::blob::BlobReader;
use crate::server::protocol::{self, EpochView, Frame, Msg, ServerStats};
use crate::tensor::Tensor;
use crate::util::backoff::Backoff;
use crate::util::rng::Pcg32;

/// Default socket read/write timeout: long enough for any barrier wait
/// a healthy server produces, short enough that a dead server surfaces
/// as an error instead of a forever-hung client.
pub const DEFAULT_IO_TIMEOUT: Duration = Duration::from_secs(30);

/// `Busy` backoff: starts at [`BACKOFF_BASE_US`] µs, doubles per
/// consecutive bounce, capped at [`BACKOFF_CAP_US`] µs, with ±25%
/// deterministic jitter (a fixed-seed PCG stream — reproducible runs,
/// but concurrent clients still decorrelate because each sleeps a
/// different number of times). The machinery lives in [`util::backoff`]
/// (shared with the remote suite dispatcher); the constants are
/// re-exported here for compatibility, and the extraction is pinned
/// bit-unchanged by `util::backoff`'s jitter-sequence tests.
pub use crate::util::backoff::{BACKOFF_BASE_US, BACKOFF_CAP_US};

/// Outcome of a [`Client::push_grad`]: the terminal replies a pusher
/// must distinguish without string-matching.
#[derive(Debug, PartialEq)]
pub enum PushOutcome {
    /// The gradient was applied as (part of) step `step` — the barrier
    /// step in sync mode, the commit step in async mode.
    Applied(u64),
    /// The push's epoch was superseded — `epoch` is current; refresh
    /// membership knowledge and retry.
    Stale(u64),
    /// Async mode: the gradient's `base_step` fell out of the staleness
    /// window (`applied` steps are in; `required` is the oldest
    /// acceptable base) — re-pull fresher params and recompute.
    TooStale { applied: u64, required: u64 },
    /// Rejected outright (non-member, wrong step, bad shapes, …).
    Rejected(String),
}

/// Reply to a freshness-floored pull ([`Client::pull_params_at_least`]).
#[derive(Debug, PartialEq)]
pub enum PullReply {
    /// Parameters after `step` applied steps (`step >= min_step`
    /// guaranteed).
    Params { step: u64, tensors: Vec<Vec<f32>> },
    /// The server has applied only `applied` steps, short of the
    /// `required` floor — retry later.
    TooStale { applied: u64, required: u64 },
}

/// Largest single-tensor encoding a pull client will reassemble
/// (guards allocation against a hostile/buggy server's `ChunkHeader`).
/// Generous on purpose: paper-scale tensors are the point of v4.
pub const PULL_TENSOR_CAP: u64 = 1 << 32;

/// Resend round trips a pull tolerates before declaring the server
/// broken. TCP never drops chunks, so resends only fire against a
/// misbehaving peer — the cap exists to bound that conversation.
const MAX_RESENDS: u32 = 1024;

/// What a pull stream carried, before payload decoding.
enum PullPayload {
    Stream { step: u64, tensors: Vec<Vec<u8>> },
    TooStale { applied: u64, required: u64 },
}

/// One tensor's optimizer moments reconstructed from a factored pull
/// ([`Client::pull_state_factored`]).
#[derive(Debug, Clone, PartialEq)]
pub enum TensorMoments {
    /// Dense first/second momenta — decompressed client-side from the
    /// SMMF factors + sign plane, or shipped dense for tensors the
    /// optimizer keeps unfactored.
    Dense { m: Vec<f32>, v: Vec<f32> },
    /// The tensor carries no persistent state (frozen / stateless).
    Stateless,
}

/// A blocking request/reply connection to a state server. One request
/// is outstanding at a time (the protocol is strictly request → reply
/// per connection).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    next_id: u64,
    /// `Busy` bounces absorbed by [`Client::call_retry`].
    pub busy_retries: u64,
    /// Wire bytes written (headers + payloads, every frame).
    pub bytes_sent: u64,
    /// Wire bytes read.
    pub bytes_received: u64,
    /// Shared backoff machinery: deterministic jitter stream plus the
    /// consecutive-bounce level (reset on any non-Busy reply).
    backoff: Backoff,
}

impl Client {
    /// Connect to `addr` (e.g. `127.0.0.1:7070`) with the default IO
    /// timeouts.
    pub fn connect(addr: &str) -> Result<Client> {
        Self::connect_with_timeout(addr, Some(DEFAULT_IO_TIMEOUT))
    }

    /// Connect with explicit socket read/write timeouts (`None` = block
    /// forever — the pre-timeout behavior, for tests that park a
    /// connection on purpose).
    pub fn connect_with_timeout(addr: &str, io_timeout: Option<Duration>) -> Result<Client> {
        let stream = TcpStream::connect(addr).map_err(|e| anyhow!("connecting {addr}: {e}"))?;
        stream.set_nodelay(true).ok();
        stream
            .set_read_timeout(io_timeout)
            .map_err(|e| anyhow!("setting read timeout on {addr}: {e}"))?;
        stream
            .set_write_timeout(io_timeout)
            .map_err(|e| anyhow!("setting write timeout on {addr}: {e}"))?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client {
            reader,
            writer: BufWriter::new(stream),
            next_id: 1,
            busy_retries: 0,
            bytes_sent: 0,
            bytes_received: 0,
            backoff: Backoff::new(),
        })
    }

    /// Write one frame, counting its bytes. Streams batch many sends
    /// before a reply, so this does NOT flush — callers flush once per
    /// logical request via [`Client::flush`].
    fn send(&mut self, frame: &Frame) -> Result<()> {
        let buf = protocol::encode(frame);
        self.bytes_sent += buf.len() as u64;
        self.writer.write_all(&buf)?;
        Ok(())
    }

    fn flush(&mut self) -> Result<()> {
        self.writer.flush()?;
        Ok(())
    }

    /// Read one frame, counting its bytes.
    fn recv(&mut self) -> Result<Frame> {
        let (frame, n) = protocol::read_frame_counted(&mut self.reader)?;
        self.bytes_received += n;
        Ok(frame)
    }

    /// Read one frame and require it to echo `id` (the per-connection
    /// protocol is strictly sequential, so a mismatch means a framing
    /// bug).
    fn recv_for(&mut self, id: u64) -> Result<Frame> {
        let frame = self.recv()?;
        if frame.request_id != id {
            bail!("reply for request {} while waiting on {id}", frame.request_id);
        }
        Ok(frame)
    }

    fn fresh_id(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    /// Send one single-frame request and wait for its reply.
    pub fn call(&mut self, msg: Msg) -> Result<Msg> {
        let id = self.fresh_id();
        self.send(&Frame { request_id: id, msg })?;
        self.flush()?;
        Ok(self.recv_for(id)?.msg)
    }

    /// [`Client::call`], transparently retrying [`Msg::Busy`] bounces
    /// (the server's bounded-queue backpressure) with capped exponential
    /// backoff plus deterministic jitter — a saturated server sees
    /// clients spread out instead of a tight retry spin.
    pub fn call_retry(&mut self, msg: Msg) -> Result<Msg> {
        loop {
            match self.call(msg.clone())? {
                Msg::Busy => {
                    self.busy_retries += 1;
                    self.backoff.sleep();
                }
                reply => {
                    self.backoff.reset();
                    return Ok(reply);
                }
            }
        }
    }

    /// Pull the current parameters unconditionally: `(applied step,
    /// flat tensor data)`.
    pub fn pull_params(&mut self) -> Result<(u64, Vec<Vec<f32>>)> {
        match self.pull_params_at_least(0)? {
            PullReply::Params { step, tensors } => Ok((step, tensors)),
            PullReply::TooStale { applied, required } => {
                bail!("PullParams with no floor answered TooStale ({applied} < {required})")
            }
        }
    }

    /// Pull the current parameters only if the server has applied at
    /// least `min_step` steps — the bounded-staleness freshness floor an
    /// async client holds at `last_acked - staleness`. A
    /// [`PullReply::TooStale`] is data, not an error: the caller decides
    /// whether to wait, retry, or bail.
    pub fn pull_params_at_least(&mut self, min_step: u64) -> Result<PullReply> {
        match self.pull(min_step, protocol::PULL_DENSE)? {
            PullPayload::Stream { step, tensors } => {
                let tensors = tensors
                    .iter()
                    .enumerate()
                    .map(|(t, b)| {
                        protocol::bytes_to_f32s(b)
                            .with_context(|| format!("decoding pulled tensor {t}"))
                    })
                    .collect::<Result<Vec<_>>>()?;
                Ok(PullReply::Params { step, tensors })
            }
            PullPayload::TooStale { applied, required } => {
                Ok(PullReply::TooStale { applied, required })
            }
        }
    }

    /// Pull the optimizer state in its native compressed encoding —
    /// for SMMF, the `u`/`v` factor vectors plus the packed 1-bit sign
    /// plane per tensor — and reconstruct dense first/second momenta
    /// client-side. Only the compressed state crosses the wire (the
    /// paper's memory story, applied to bandwidth). Meaningful against
    /// an SMMF server; other optimizers' blob encodings are rejected
    /// by the decoder.
    pub fn pull_state_factored(&mut self) -> Result<(u64, Vec<TensorMoments>)> {
        match self.pull(0, protocol::PULL_FACTORED)? {
            PullPayload::Stream { step, tensors } => {
                let moments = tensors
                    .iter()
                    .enumerate()
                    .map(|(t, b)| {
                        decode_smmf_state_blob(b)
                            .with_context(|| format!("decoding factored state of tensor {t}"))
                    })
                    .collect::<Result<Vec<_>>>()?;
                Ok((step, moments))
            }
            PullPayload::TooStale { applied, required } => {
                bail!("factored pull with no floor answered TooStale ({applied} < {required})")
            }
        }
    }

    /// The shared pull machinery: one `PullParams` request, then a
    /// `ParamsBegin` → chunk → `StreamEnd` reply stream reassembled in
    /// arrival order, with bounded [`Msg::Resend`] recovery for chunks
    /// the stream did not deliver. `Busy` retries resend the request
    /// (nothing is cached server-side until a stream starts).
    fn pull(&mut self, min_step: u64, mode: u8) -> Result<PullPayload> {
        loop {
            let id = self.fresh_id();
            self.send(&Frame { request_id: id, msg: Msg::PullParams { min_step, mode } })?;
            self.flush()?;
            let (step, n_tensors) = match self.recv_for(id)?.msg {
                Msg::Busy => {
                    self.busy_retries += 1;
                    self.backoff.sleep();
                    continue;
                }
                Msg::TooStale { applied, required } => {
                    self.backoff.reset();
                    return Ok(PullPayload::TooStale { applied, required });
                }
                Msg::Err { msg } => bail!("PullParams rejected: {msg}"),
                Msg::ParamsBegin { step, mode: got, n_tensors } => {
                    if got != mode {
                        bail!("pull requested mode {mode}, the stream is mode {got}");
                    }
                    (step, n_tensors)
                }
                other => bail!("PullParams answered with {}", other.name()),
            };
            self.backoff.reset();
            let mut asm =
                protocol::ChunkAssembler::for_unknown(n_tensors as usize, PULL_TENSOR_CAP);
            loop {
                let frame = self.recv_for(id)?;
                match frame.msg {
                    Msg::ChunkHeader { tensor_idx, seq, total, start, count, tensor_len } => {
                        asm.header(tensor_idx, seq, total, start, count, tensor_len)?;
                    }
                    Msg::ChunkData { tensor_idx, seq, bytes } => {
                        asm.data(tensor_idx, seq, &bytes)?;
                    }
                    Msg::StreamEnd { .. } => break,
                    other => bail!("{} inside a pull stream", other.name()),
                }
            }
            let mut resends = 0u32;
            while let Some((tensor_idx, seq)) = asm.missing() {
                resends += 1;
                if resends > MAX_RESENDS {
                    bail!("pull stream still incomplete after {MAX_RESENDS} resends");
                }
                let rid = self.fresh_id();
                self.send(&Frame { request_id: rid, msg: Msg::Resend { tensor_idx, seq } })?;
                self.flush()?;
                match self.recv_for(rid)?.msg {
                    Msg::ChunkHeader { tensor_idx, seq, total, start, count, tensor_len } => {
                        asm.header(tensor_idx, seq, total, start, count, tensor_len)?;
                        match self.recv_for(rid)?.msg {
                            Msg::ChunkData { tensor_idx, seq, bytes } => {
                                asm.data(tensor_idx, seq, &bytes)?;
                            }
                            other => bail!("Resend data frame was {}", other.name()),
                        }
                    }
                    Msg::Err { msg } => bail!("Resend rejected: {msg}"),
                    other => bail!("Resend answered with {}", other.name()),
                }
            }
            let tensors = asm.finish()?;
            return Ok(PullPayload::Stream { step, tensors });
        }
    }

    /// Push this client's gradient set for `step`, computed against
    /// applied step `base_step` and tagged with the membership `epoch`
    /// the client believes is current; blocks until the gradient is
    /// applied — at the completed barrier (sync) or in the next commit
    /// (async) — or until the server answers with a stale-epoch /
    /// too-stale / rejection outcome. All four are data, not errors,
    /// because an elastic client must react to them.
    ///
    /// On the wire this is a whole chunk stream per attempt; a `Busy`
    /// answer (the server's queue was full when the assembled push
    /// reached it) retries the entire stream after backoff.
    pub fn push_grad(
        &mut self,
        client: u32,
        epoch: u64,
        step: u64,
        base_step: u64,
        grads: Vec<Vec<f32>>,
    ) -> Result<PushOutcome> {
        loop {
            let id = self.fresh_id();
            let begin = Msg::PushBegin {
                client,
                epoch,
                step,
                base_step,
                n_tensors: grads.len() as u32,
            };
            self.send(&Frame { request_id: id, msg: begin })?;
            for (t, g) in grads.iter().enumerate() {
                let len = 4 * g.len() as u64;
                let plan = protocol::chunk_plan(len, 4, protocol::CHUNK_MAX_BYTES);
                let total = plan.len() as u32;
                for (seq, &(start, count)) in plan.iter().enumerate() {
                    let hdr = Msg::ChunkHeader {
                        tensor_idx: t as u32,
                        seq: seq as u32,
                        total,
                        start,
                        count,
                        tensor_len: len,
                    };
                    self.send(&Frame { request_id: id, msg: hdr })?;
                    // chunk_plan row-aligns to 4 bytes, so spans map to
                    // whole f32s — encode per chunk, O(chunk) scratch.
                    let lo = (start / 4) as usize;
                    let hi = ((start + count) / 4) as usize;
                    let data = Msg::ChunkData {
                        tensor_idx: t as u32,
                        seq: seq as u32,
                        bytes: protocol::f32s_to_bytes(&g[lo..hi]),
                    };
                    self.send(&Frame { request_id: id, msg: data })?;
                }
            }
            self.send(&Frame {
                request_id: id,
                msg: Msg::StreamEnd { step, tensors: grads.len() as u32 },
            })?;
            self.flush()?;
            match self.recv_for(id)?.msg {
                Msg::Busy => {
                    self.busy_retries += 1;
                    self.backoff.sleep();
                }
                reply => {
                    self.backoff.reset();
                    return match reply {
                        Msg::Ack { step: applied } => Ok(PushOutcome::Applied(applied)),
                        Msg::StaleEpoch { epoch } => Ok(PushOutcome::Stale(epoch)),
                        Msg::TooStale { applied, required } => {
                            Ok(PushOutcome::TooStale { applied, required })
                        }
                        Msg::Err { msg } => Ok(PushOutcome::Rejected(msg)),
                        other => bail!("PushGrad answered with {}", other.name()),
                    };
                }
            }
        }
    }

    /// Join the barrier: returns the new membership view (the assigned
    /// client id is `view.client`).
    pub fn join(&mut self) -> Result<EpochView> {
        match self.call_retry(Msg::Join)? {
            Msg::EpochReply(v) => Ok(v),
            Msg::Err { msg } => bail!("Join rejected: {msg}"),
            other => bail!("Join answered with {}", other.name()),
        }
    }

    /// Politely leave the barrier as `client`.
    pub fn leave(&mut self, client: u32) -> Result<EpochView> {
        match self.call_retry(Msg::Leave { client })? {
            Msg::EpochReply(v) => Ok(v),
            Msg::Err { msg } => bail!("Leave rejected: {msg}"),
            other => bail!("Leave answered with {}", other.name()),
        }
    }

    /// Probe the current epoch / membership without changing either.
    pub fn epoch_info(&mut self) -> Result<EpochView> {
        match self.call_retry(Msg::EpochInfo)? {
            Msg::EpochReply(v) => Ok(v),
            other => bail!("EpochInfo answered with {}", other.name()),
        }
    }

    /// Ask the server to write a snapshot; returns the on-disk bytes.
    pub fn snapshot(&mut self, path: &str) -> Result<u64> {
        if path.is_empty() || path.len() > protocol::MAX_STR_LEN {
            bail!(
                "snapshot path must be 1..={} bytes (got {})",
                protocol::MAX_STR_LEN,
                path.len()
            );
        }
        match self.call_retry(Msg::Snapshot { path: path.to_string() })? {
            Msg::SnapshotDone { bytes } => Ok(bytes),
            Msg::Err { msg } => bail!("Snapshot rejected: {msg}"),
            other => bail!("Snapshot answered with {}", other.name()),
        }
    }

    /// Fetch the server's counters.
    pub fn stats(&mut self) -> Result<ServerStats> {
        match self.call_retry(Msg::Stats)? {
            Msg::StatsReply(s) => Ok(s),
            other => bail!("Stats answered with {}", other.name()),
        }
    }

    /// Fetch the server's Prometheus text exposition — the same atomics
    /// behind [`Client::stats`], rendered as `# TYPE`/sample lines by
    /// the server's metrics registry ([`Msg::MetricsDump`], a v4
    /// layout-preserving extension).
    pub fn metrics_dump(&mut self) -> Result<String> {
        match self.call_retry(Msg::MetricsDump)? {
            Msg::MetricsText { text } => Ok(text),
            other => bail!("MetricsDump answered with {}", other.name()),
        }
    }

    /// Stop the server.
    pub fn shutdown(&mut self) -> Result<()> {
        match self.call_retry(Msg::Shutdown)? {
            Msg::Bye => Ok(()),
            other => bail!("Shutdown answered with {}", other.name()),
        }
    }
}

/// Decode one SMMF state blob (docs/CHECKPOINT_FORMAT.md, kind tag 4 —
/// the exact bytes `Smmf::state_blob` emits) and reconstruct dense
/// momenta the way `Smmf::step` does before applying an update:
/// `M̂ = r_m ⊗ c_m` with the sign restored from the packed 1-bit plane
/// (bit set ⇒ strictly positive), `V̂ = r_v ⊗ c_v` (non-negative, no
/// sign plane). SMMF-only: other optimizers lay their blobs out
/// differently (Adam's has no leading tag byte), so feeding them here
/// errors rather than mis-decoding.
fn decode_smmf_state_blob(blob: &[u8]) -> Result<TensorMoments> {
    let mut r = BlobReader::new(blob);
    match r.u8()? {
        2 => {
            r.finish()?;
            Ok(TensorMoments::Stateless)
        }
        0 => {
            let len = r.u64()? as usize;
            // Exact-size check before allocating: tag + u64 + 2 f32 runs.
            if blob.len() != 9 + 8 * len {
                bail!("smmf dense blob claims {len} elements in {} bytes", blob.len());
            }
            let mut m = vec![0.0f32; len];
            let mut v = vec![0.0f32; len];
            r.f32s_into(&mut m)?;
            r.f32s_into(&mut v)?;
            r.finish()?;
            Ok(TensorMoments::Dense { m, v })
        }
        1 => {
            let n = r.u32()? as usize;
            let mm = r.u32()? as usize;
            let numel = n
                .checked_mul(mm)
                .filter(|&e| (e as u64) < PULL_TENSOR_CAP)
                .ok_or_else(|| anyhow!("smmf factored blob claims {n}x{mm} elements"))?;
            // Factor vectors must fit before their buffers are allocated.
            if blob.len() < 9 + 8 * (n + mm) {
                bail!("smmf factored blob is {} bytes, too short for {n}+{mm} factors", blob.len());
            }
            let mut r_m = vec![0.0f32; n];
            let mut c_m = vec![0.0f32; mm];
            let mut r_v = vec![0.0f32; n];
            let mut c_v = vec![0.0f32; mm];
            r.f32s_into(&mut r_m)?;
            r.f32s_into(&mut c_m)?;
            r.f32s_into(&mut r_v)?;
            r.f32s_into(&mut c_v)?;
            let sign_mode = r.u8()?;
            let len = r.u64()? as usize;
            let expected = match sign_mode {
                0 => numel.div_ceil(64) * 8,
                1 => numel,
                other => bail!("smmf sign plane has unknown mode {other}"),
            };
            if len != expected {
                bail!("smmf sign plane is {len} bytes, {n}x{mm} mode {sign_mode} needs {expected}");
            }
            let sign = r.bytes(len)?.to_vec();
            r.finish()?;
            let positive = |idx: usize| -> bool {
                match sign_mode {
                    0 => {
                        let word = u64::from_le_bytes(
                            sign[(idx >> 6) * 8..(idx >> 6) * 8 + 8].try_into().unwrap(),
                        );
                        (word >> (idx & 63)) & 1 == 1
                    }
                    _ => sign[idx] != 0,
                }
            };
            let mut m = vec![0.0f32; numel];
            let mut v = vec![0.0f32; numel];
            for i in 0..n {
                for j in 0..mm {
                    let idx = i * mm + j;
                    let mag = r_m[i] * c_m[j];
                    m[idx] = if positive(idx) { mag } else { -mag };
                    v[idx] = r_v[i] * c_v[j];
                }
            }
            Ok(TensorMoments::Dense { m, v })
        }
        other => bail!("smmf state blob has unknown tag {other} (not an SMMF server?)"),
    }
}

/// The deterministic synthetic gradient workload: the noisy quadratic
/// well of `coordinator::experiments::run_synthetic_experiment`, split
/// across clients. Targets `θ*` are a function of the seed only (every
/// client optimizes the same well); the gradient noise stream is keyed
/// by `(seed, client)` so concurrent clients push distinct but fully
/// reproducible gradients. The single-process reference trainer
/// instantiates the same sources with the same keys, which is what makes
/// the server snapshot bit-comparable.
pub struct GradSource {
    targets: Vec<Tensor>,
    noise: Pcg32,
    n_total: f64,
}

/// Gradient noise scale (matches the synthetic suite workload).
pub const NOISE_SIGMA: f32 = 0.01;

impl GradSource {
    /// Workload for `client` under `seed` over the inventory shapes.
    pub fn new(shapes: &[Vec<usize>], seed: u64, client: u32) -> GradSource {
        let mut target_rng = Pcg32::new(seed ^ 0x7a67);
        let targets: Vec<Tensor> = shapes
            .iter()
            .map(|s| {
                let mut t = Tensor::zeros(s);
                target_rng.fill_normal(t.data_mut(), 0.5);
                t
            })
            .collect();
        // Distinct PCG stream per client: same seed, different inc.
        let noise = Pcg32::with_stream(seed ^ 0xda7a, 0x6f5e_ed00 + client as u64);
        let n_total = shapes.iter().map(|s| s.iter().product::<usize>() as f64).sum();
        GradSource { targets, noise, n_total }
    }

    /// Fast-forward the noise stream past `steps` gradient computations
    /// without materializing them. [`GradSource::grads`] draws exactly
    /// one normal per element per call, so skipping is just discarding
    /// `steps × Σ numel` draws — this is how a late-joining or resumed
    /// client lines its stream up with the step it starts pushing at.
    pub fn skip_steps(&mut self, steps: u64) {
        let n_elems: usize = self.targets.iter().map(|t| t.data().len()).sum();
        for _ in 0..steps {
            for _ in 0..n_elems {
                self.noise.normal();
            }
        }
    }

    /// Compute this client's gradient set at `params` (flat per-tensor
    /// data, inventory order): `g = (θ − θ*) + σ·ξ` with deterministic
    /// noise. Returns `(loss, grads)`; the loss is the exact quadratic
    /// objective (noise-free), for reporting.
    pub fn grads(&mut self, params: &[Vec<f32>]) -> Result<(f32, Vec<Vec<f32>>)> {
        if params.len() != self.targets.len() {
            bail!("pulled {} tensors, workload has {}", params.len(), self.targets.len());
        }
        let mut loss_acc = 0.0f64;
        let mut out = Vec::with_capacity(params.len());
        for (p, t) in params.iter().zip(&self.targets) {
            let td = t.data();
            if p.len() != td.len() {
                bail!("pulled tensor holds {} elements, workload expects {}", p.len(), td.len());
            }
            let mut g = Vec::with_capacity(p.len());
            for (&pv, &tv) in p.iter().zip(td) {
                let r = pv - tv;
                loss_acc += 0.5 * (r as f64) * (r as f64);
                g.push(r + NOISE_SIGMA * self.noise.normal());
            }
            out.push(g);
        }
        Ok(((loss_acc / self.n_total) as f32, out))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grad_source_is_deterministic_and_client_keyed() {
        let shapes = vec![vec![3, 2], vec![4]];
        let params: Vec<Vec<f32>> = vec![vec![0.0; 6], vec![0.1; 4]];
        let (l1, g1) = GradSource::new(&shapes, 7, 0).grads(&params).unwrap();
        let (l2, g2) = GradSource::new(&shapes, 7, 0).grads(&params).unwrap();
        assert_eq!((l1, &g1), (l2, &g2));
        // different clients share the loss surface but not the noise
        let (l3, g3) = GradSource::new(&shapes, 7, 1).grads(&params).unwrap();
        assert_eq!(l1, l3);
        assert_ne!(g1, g3);
        // shape mismatch errors
        assert!(GradSource::new(&shapes, 7, 0).grads(&params[..1]).is_err());
    }

    #[test]
    fn factored_blob_reconstructs_signed_outer_products() {
        use crate::optim::blob::BlobWriter;
        // 2x3, bit-packed sign plane: bits 0, 2, 5 set (strictly positive).
        let mut w = BlobWriter::new();
        w.u8(1);
        w.u32(2);
        w.u32(3);
        w.f32s(&[1.0, 2.0]); // r_m
        w.f32s(&[0.5, 1.0, 2.0]); // c_m
        w.f32s(&[1.0, 1.0]); // r_v
        w.f32s(&[2.0, 3.0, 4.0]); // c_v
        w.u8(0); // SignStore::Bits
        w.u64(8);
        w.bytes(&0b100101u64.to_le_bytes());
        let got = decode_smmf_state_blob(&w.finish()).unwrap();
        assert_eq!(
            got,
            TensorMoments::Dense {
                m: vec![0.5, -1.0, 2.0, -1.0, -2.0, 4.0],
                v: vec![2.0, 3.0, 4.0, 2.0, 3.0, 4.0],
            }
        );

        // Same factors with a byte-wide sign plane, signs flipped.
        let mut w = BlobWriter::new();
        w.u8(1);
        w.u32(2);
        w.u32(3);
        w.f32s(&[1.0, 2.0]);
        w.f32s(&[0.5, 1.0, 2.0]);
        w.f32s(&[1.0, 1.0]);
        w.f32s(&[2.0, 3.0, 4.0]);
        w.u8(1); // SignStore::Bytes
        w.u64(6);
        w.bytes(&[0, 1, 0, 1, 1, 0]);
        match decode_smmf_state_blob(&w.finish()).unwrap() {
            TensorMoments::Dense { m, .. } => {
                assert_eq!(m, vec![-0.5, 1.0, -2.0, 1.0, 2.0, -4.0]);
            }
            other => panic!("expected dense, got {other:?}"),
        }
    }

    #[test]
    fn dense_and_stateless_blobs_decode() {
        use crate::optim::blob::BlobWriter;
        let mut w = BlobWriter::new();
        w.u8(0);
        w.u64(2);
        w.f32s(&[0.25, -0.5]); // m
        w.f32s(&[1.5, 2.5]); // v
        assert_eq!(
            decode_smmf_state_blob(&w.finish()).unwrap(),
            TensorMoments::Dense { m: vec![0.25, -0.5], v: vec![1.5, 2.5] }
        );
        assert_eq!(decode_smmf_state_blob(&[2]).unwrap(), TensorMoments::Stateless);
    }

    #[test]
    fn malformed_smmf_blobs_are_typed_errors() {
        use crate::optim::blob::BlobWriter;
        // Unknown tag.
        assert!(decode_smmf_state_blob(&[7]).is_err());
        // Adam-style blob (no tag byte): the leading u64 len byte stream
        // starts with the length, which reads as a bogus tag.
        let mut w = BlobWriter::new();
        w.u64(3);
        w.f32s(&[0.0; 3]);
        w.f32s(&[0.0; 3]);
        assert!(decode_smmf_state_blob(&w.finish()).is_err());
        // Sign plane length disagreeing with n x m.
        let mut w = BlobWriter::new();
        w.u8(1);
        w.u32(2);
        w.u32(3);
        w.f32s(&[0.0; 10]); // all four factor vectors
        w.u8(0);
        w.u64(16); // 2x3 needs exactly one 8-byte word
        w.bytes(&[0u8; 16]);
        assert!(decode_smmf_state_blob(&w.finish()).is_err());
        // Trailing garbage after a stateless tag.
        assert!(decode_smmf_state_blob(&[2, 9]).is_err());
    }

    #[test]
    fn skip_steps_matches_discarded_grads_calls() {
        let shapes = vec![vec![2, 3], vec![5]];
        let params: Vec<Vec<f32>> = vec![vec![0.2; 6], vec![-0.3; 5]];
        let mut walked = GradSource::new(&shapes, 11, 2);
        for _ in 0..4 {
            walked.grads(&params).unwrap();
        }
        let mut skipped = GradSource::new(&shapes, 11, 2);
        skipped.skip_steps(4);
        assert_eq!(walked.grads(&params).unwrap(), skipped.grads(&params).unwrap());
    }
}
