//! Client side of the optimizer-state server: a blocking wire client
//! plus the deterministic synthetic gradient workload shared by the
//! load generator and the single-process reference trainer.

use anyhow::{anyhow, bail, Result};
use std::io::{BufReader, BufWriter};
use std::net::TcpStream;
use std::time::Duration;

use crate::server::protocol::{self, EpochView, Frame, Msg, ServerStats};
use crate::tensor::Tensor;
use crate::util::backoff::Backoff;
use crate::util::rng::Pcg32;

/// Default socket read/write timeout: long enough for any barrier wait
/// a healthy server produces, short enough that a dead server surfaces
/// as an error instead of a forever-hung client.
pub const DEFAULT_IO_TIMEOUT: Duration = Duration::from_secs(30);

/// `Busy` backoff: starts at [`BACKOFF_BASE_US`] µs, doubles per
/// consecutive bounce, capped at [`BACKOFF_CAP_US`] µs, with ±25%
/// deterministic jitter (a fixed-seed PCG stream — reproducible runs,
/// but concurrent clients still decorrelate because each sleeps a
/// different number of times). The machinery lives in [`util::backoff`]
/// (shared with the remote suite dispatcher); the constants are
/// re-exported here for compatibility, and the extraction is pinned
/// bit-unchanged by `util::backoff`'s jitter-sequence tests.
pub use crate::util::backoff::{BACKOFF_BASE_US, BACKOFF_CAP_US};

/// Outcome of a [`Client::push_grad`]: the terminal replies a pusher
/// must distinguish without string-matching.
#[derive(Debug, PartialEq)]
pub enum PushOutcome {
    /// The gradient was applied as (part of) step `step` — the barrier
    /// step in sync mode, the commit step in async mode.
    Applied(u64),
    /// The push's epoch was superseded — `epoch` is current; refresh
    /// membership knowledge and retry.
    Stale(u64),
    /// Async mode: the gradient's `base_step` fell out of the staleness
    /// window (`applied` steps are in; `required` is the oldest
    /// acceptable base) — re-pull fresher params and recompute.
    TooStale { applied: u64, required: u64 },
    /// Rejected outright (non-member, wrong step, bad shapes, …).
    Rejected(String),
}

/// Reply to a freshness-floored pull ([`Client::pull_params_at_least`]).
#[derive(Debug, PartialEq)]
pub enum PullReply {
    /// Parameters after `step` applied steps (`step >= min_step`
    /// guaranteed).
    Params { step: u64, tensors: Vec<Vec<f32>> },
    /// The server has applied only `applied` steps, short of the
    /// `required` floor — retry later.
    TooStale { applied: u64, required: u64 },
}

/// A blocking request/reply connection to a state server. One request
/// is outstanding at a time (the protocol is strictly request → reply
/// per connection).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    next_id: u64,
    /// `Busy` bounces absorbed by [`Client::call_retry`].
    pub busy_retries: u64,
    /// Shared backoff machinery: deterministic jitter stream plus the
    /// consecutive-bounce level (reset on any non-Busy reply).
    backoff: Backoff,
}

impl Client {
    /// Connect to `addr` (e.g. `127.0.0.1:7070`) with the default IO
    /// timeouts.
    pub fn connect(addr: &str) -> Result<Client> {
        Self::connect_with_timeout(addr, Some(DEFAULT_IO_TIMEOUT))
    }

    /// Connect with explicit socket read/write timeouts (`None` = block
    /// forever — the pre-timeout behavior, for tests that park a
    /// connection on purpose).
    pub fn connect_with_timeout(addr: &str, io_timeout: Option<Duration>) -> Result<Client> {
        let stream = TcpStream::connect(addr).map_err(|e| anyhow!("connecting {addr}: {e}"))?;
        stream.set_nodelay(true).ok();
        stream
            .set_read_timeout(io_timeout)
            .map_err(|e| anyhow!("setting read timeout on {addr}: {e}"))?;
        stream
            .set_write_timeout(io_timeout)
            .map_err(|e| anyhow!("setting write timeout on {addr}: {e}"))?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client {
            reader,
            writer: BufWriter::new(stream),
            next_id: 1,
            busy_retries: 0,
            backoff: Backoff::new(),
        })
    }

    /// Send one request and wait for its reply. The reply's request id
    /// must echo the request's (the per-connection protocol is strictly
    /// sequential, so a mismatch means a framing bug).
    pub fn call(&mut self, msg: Msg) -> Result<Msg> {
        let id = self.next_id;
        self.next_id += 1;
        protocol::write_frame(&mut self.writer, &Frame { request_id: id, msg })?;
        let reply = protocol::read_frame(&mut self.reader)?;
        if reply.request_id != id {
            bail!("reply for request {} while waiting on {id}", reply.request_id);
        }
        Ok(reply.msg)
    }

    /// [`Client::call`], transparently retrying [`Msg::Busy`] bounces
    /// (the server's bounded-queue backpressure) with capped exponential
    /// backoff plus deterministic jitter — a saturated server sees
    /// clients spread out instead of a tight retry spin.
    pub fn call_retry(&mut self, msg: Msg) -> Result<Msg> {
        loop {
            match self.call(msg.clone())? {
                Msg::Busy => {
                    self.busy_retries += 1;
                    self.backoff.sleep();
                }
                reply => {
                    self.backoff.reset();
                    return Ok(reply);
                }
            }
        }
    }

    /// Pull the current parameters unconditionally: `(applied step,
    /// flat tensor data)`.
    pub fn pull_params(&mut self) -> Result<(u64, Vec<Vec<f32>>)> {
        match self.pull_params_at_least(0)? {
            PullReply::Params { step, tensors } => Ok((step, tensors)),
            PullReply::TooStale { applied, required } => {
                bail!("PullParams with no floor answered TooStale ({applied} < {required})")
            }
        }
    }

    /// Pull the current parameters only if the server has applied at
    /// least `min_step` steps — the bounded-staleness freshness floor an
    /// async client holds at `last_acked - staleness`. A
    /// [`PullReply::TooStale`] is data, not an error: the caller decides
    /// whether to wait, retry, or bail.
    pub fn pull_params_at_least(&mut self, min_step: u64) -> Result<PullReply> {
        match self.call_retry(Msg::PullParams { min_step })? {
            Msg::Params { step, tensors } => Ok(PullReply::Params { step, tensors }),
            Msg::TooStale { applied, required } => Ok(PullReply::TooStale { applied, required }),
            other => bail!("PullParams answered with {}", other.name()),
        }
    }

    /// Push this client's gradient set for `step`, computed against
    /// applied step `base_step` and tagged with the membership `epoch`
    /// the client believes is current; blocks until the gradient is
    /// applied — at the completed barrier (sync) or in the next commit
    /// (async) — or until the server answers with a stale-epoch /
    /// too-stale / rejection outcome. All four are data, not errors,
    /// because an elastic client must react to them.
    pub fn push_grad(
        &mut self,
        client: u32,
        epoch: u64,
        step: u64,
        base_step: u64,
        grads: Vec<Vec<f32>>,
    ) -> Result<PushOutcome> {
        match self.call_retry(Msg::PushGrad { client, epoch, step, base_step, grads })? {
            Msg::Ack { step: applied } => Ok(PushOutcome::Applied(applied)),
            Msg::StaleEpoch { epoch } => Ok(PushOutcome::Stale(epoch)),
            Msg::TooStale { applied, required } => Ok(PushOutcome::TooStale { applied, required }),
            Msg::Err { msg } => Ok(PushOutcome::Rejected(msg)),
            other => bail!("PushGrad answered with {}", other.name()),
        }
    }

    /// Join the barrier: returns the new membership view (the assigned
    /// client id is `view.client`).
    pub fn join(&mut self) -> Result<EpochView> {
        match self.call_retry(Msg::Join)? {
            Msg::EpochReply(v) => Ok(v),
            Msg::Err { msg } => bail!("Join rejected: {msg}"),
            other => bail!("Join answered with {}", other.name()),
        }
    }

    /// Politely leave the barrier as `client`.
    pub fn leave(&mut self, client: u32) -> Result<EpochView> {
        match self.call_retry(Msg::Leave { client })? {
            Msg::EpochReply(v) => Ok(v),
            Msg::Err { msg } => bail!("Leave rejected: {msg}"),
            other => bail!("Leave answered with {}", other.name()),
        }
    }

    /// Probe the current epoch / membership without changing either.
    pub fn epoch_info(&mut self) -> Result<EpochView> {
        match self.call_retry(Msg::EpochInfo)? {
            Msg::EpochReply(v) => Ok(v),
            other => bail!("EpochInfo answered with {}", other.name()),
        }
    }

    /// Ask the server to write a snapshot; returns the on-disk bytes.
    pub fn snapshot(&mut self, path: &str) -> Result<u64> {
        if path.is_empty() || path.len() > protocol::MAX_STR_LEN {
            bail!(
                "snapshot path must be 1..={} bytes (got {})",
                protocol::MAX_STR_LEN,
                path.len()
            );
        }
        match self.call_retry(Msg::Snapshot { path: path.to_string() })? {
            Msg::SnapshotDone { bytes } => Ok(bytes),
            Msg::Err { msg } => bail!("Snapshot rejected: {msg}"),
            other => bail!("Snapshot answered with {}", other.name()),
        }
    }

    /// Fetch the server's counters.
    pub fn stats(&mut self) -> Result<ServerStats> {
        match self.call_retry(Msg::Stats)? {
            Msg::StatsReply(s) => Ok(s),
            other => bail!("Stats answered with {}", other.name()),
        }
    }

    /// Stop the server.
    pub fn shutdown(&mut self) -> Result<()> {
        match self.call_retry(Msg::Shutdown)? {
            Msg::Bye => Ok(()),
            other => bail!("Shutdown answered with {}", other.name()),
        }
    }
}

/// The deterministic synthetic gradient workload: the noisy quadratic
/// well of `coordinator::experiments::run_synthetic_experiment`, split
/// across clients. Targets `θ*` are a function of the seed only (every
/// client optimizes the same well); the gradient noise stream is keyed
/// by `(seed, client)` so concurrent clients push distinct but fully
/// reproducible gradients. The single-process reference trainer
/// instantiates the same sources with the same keys, which is what makes
/// the server snapshot bit-comparable.
pub struct GradSource {
    targets: Vec<Tensor>,
    noise: Pcg32,
    n_total: f64,
}

/// Gradient noise scale (matches the synthetic suite workload).
pub const NOISE_SIGMA: f32 = 0.01;

impl GradSource {
    /// Workload for `client` under `seed` over the inventory shapes.
    pub fn new(shapes: &[Vec<usize>], seed: u64, client: u32) -> GradSource {
        let mut target_rng = Pcg32::new(seed ^ 0x7a67);
        let targets: Vec<Tensor> = shapes
            .iter()
            .map(|s| {
                let mut t = Tensor::zeros(s);
                target_rng.fill_normal(t.data_mut(), 0.5);
                t
            })
            .collect();
        // Distinct PCG stream per client: same seed, different inc.
        let noise = Pcg32::with_stream(seed ^ 0xda7a, 0x6f5e_ed00 + client as u64);
        let n_total = shapes.iter().map(|s| s.iter().product::<usize>() as f64).sum();
        GradSource { targets, noise, n_total }
    }

    /// Fast-forward the noise stream past `steps` gradient computations
    /// without materializing them. [`GradSource::grads`] draws exactly
    /// one normal per element per call, so skipping is just discarding
    /// `steps × Σ numel` draws — this is how a late-joining or resumed
    /// client lines its stream up with the step it starts pushing at.
    pub fn skip_steps(&mut self, steps: u64) {
        let n_elems: usize = self.targets.iter().map(|t| t.data().len()).sum();
        for _ in 0..steps {
            for _ in 0..n_elems {
                self.noise.normal();
            }
        }
    }

    /// Compute this client's gradient set at `params` (flat per-tensor
    /// data, inventory order): `g = (θ − θ*) + σ·ξ` with deterministic
    /// noise. Returns `(loss, grads)`; the loss is the exact quadratic
    /// objective (noise-free), for reporting.
    pub fn grads(&mut self, params: &[Vec<f32>]) -> Result<(f32, Vec<Vec<f32>>)> {
        if params.len() != self.targets.len() {
            bail!("pulled {} tensors, workload has {}", params.len(), self.targets.len());
        }
        let mut loss_acc = 0.0f64;
        let mut out = Vec::with_capacity(params.len());
        for (p, t) in params.iter().zip(&self.targets) {
            let td = t.data();
            if p.len() != td.len() {
                bail!("pulled tensor holds {} elements, workload expects {}", p.len(), td.len());
            }
            let mut g = Vec::with_capacity(p.len());
            for (&pv, &tv) in p.iter().zip(td) {
                let r = pv - tv;
                loss_acc += 0.5 * (r as f64) * (r as f64);
                g.push(r + NOISE_SIGMA * self.noise.normal());
            }
            out.push(g);
        }
        Ok(((loss_acc / self.n_total) as f32, out))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grad_source_is_deterministic_and_client_keyed() {
        let shapes = vec![vec![3, 2], vec![4]];
        let params: Vec<Vec<f32>> = vec![vec![0.0; 6], vec![0.1; 4]];
        let (l1, g1) = GradSource::new(&shapes, 7, 0).grads(&params).unwrap();
        let (l2, g2) = GradSource::new(&shapes, 7, 0).grads(&params).unwrap();
        assert_eq!((l1, &g1), (l2, &g2));
        // different clients share the loss surface but not the noise
        let (l3, g3) = GradSource::new(&shapes, 7, 1).grads(&params).unwrap();
        assert_eq!(l1, l3);
        assert_ne!(g1, g3);
        // shape mismatch errors
        assert!(GradSource::new(&shapes, 7, 0).grads(&params[..1]).is_err());
    }

    #[test]
    fn skip_steps_matches_discarded_grads_calls() {
        let shapes = vec![vec![2, 3], vec![5]];
        let params: Vec<Vec<f32>> = vec![vec![0.2; 6], vec![-0.3; 5]];
        let mut walked = GradSource::new(&shapes, 11, 2);
        for _ in 0..4 {
            walked.grads(&params).unwrap();
        }
        let mut skipped = GradSource::new(&shapes, 11, 2);
        skipped.skip_steps(4);
        assert_eq!(walked.grads(&params).unwrap(), skipped.grads(&params).unwrap());
    }
}
