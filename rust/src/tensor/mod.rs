//! Minimal dense tensor substrate.
//!
//! Optimizers only need: contiguous f32 storage with a shape, elementwise
//! ops, outer products, axis reductions over a 2-D view, and a packed
//! bitset for SMMF's sign matrix. Kept deliberately small and allocation
//! explicit — the optimizer hot path reuses scratch buffers.

mod bitset;

pub use bitset::{word_chunk_get64, word_chunk_set64, BitMatrix};

/// A dense, contiguous, row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        let numel = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; numel] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Reinterpret as a new shape (no data movement). Panics on mismatch.
    pub fn reshaped(mut self, shape: &[usize]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    pub fn fill(&mut self, v: f32) {
        self.data.iter_mut().for_each(|x| *x = v);
    }

    /// self += alpha * other (elementwise, shapes must match).
    pub fn axpy(&mut self, alpha: f32, other: &Tensor) {
        assert_eq!(self.data.len(), other.data.len());
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    pub fn scale(&mut self, alpha: f32) {
        self.data.iter_mut().for_each(|x| *x *= alpha);
    }

    pub fn sum(&self) -> f32 {
        // Pairwise-ish: accumulate in f64 for stability on big tensors.
        self.data.iter().map(|&x| x as f64).sum::<f64>() as f32
    }

    pub fn sq_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }
}

/// 2-D helpers over a (rows, cols) view of a flat slice (the optimizer hot
/// path works on square-matricized views without reshaping tensors).
pub mod mat {
    /// out[i] = sum_j m[i, j]
    pub fn row_sums(m: &[f32], rows: usize, cols: usize, out: &mut [f32]) {
        debug_assert_eq!(m.len(), rows * cols);
        debug_assert_eq!(out.len(), rows);
        for (i, o) in out.iter_mut().enumerate() {
            let row = &m[i * cols..(i + 1) * cols];
            *o = row.iter().sum();
        }
    }

    /// out[j] = sum_i m[i, j]
    pub fn col_sums(m: &[f32], rows: usize, cols: usize, out: &mut [f32]) {
        debug_assert_eq!(m.len(), rows * cols);
        debug_assert_eq!(out.len(), cols);
        out.iter_mut().for_each(|x| *x = 0.0);
        for i in 0..rows {
            let row = &m[i * cols..(i + 1) * cols];
            for (o, &v) in out.iter_mut().zip(row) {
                *o += v;
            }
        }
    }

    /// out[i, j] = r[i] * c[j]
    pub fn outer(r: &[f32], c: &[f32], out: &mut [f32]) {
        debug_assert_eq!(out.len(), r.len() * c.len());
        for (i, &ri) in r.iter().enumerate() {
            let row = &mut out[i * c.len()..(i + 1) * c.len()];
            for (o, &cj) in row.iter_mut().zip(c) {
                *o = ri * cj;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_reshape() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.numel(), 6);
        let t2 = t.clone().reshaped(&[3, 2]);
        assert_eq!(t2.shape(), &[3, 2]);
        assert_eq!(t2.data(), t.data());
    }

    #[test]
    #[should_panic]
    fn reshape_mismatch_panics() {
        Tensor::zeros(&[2, 2]).reshaped(&[3]);
    }

    #[test]
    fn axpy_and_scale() {
        let mut a = Tensor::from_vec(&[3], vec![1., 2., 3.]);
        let b = Tensor::from_vec(&[3], vec![10., 10., 10.]);
        a.axpy(0.5, &b);
        assert_eq!(a.data(), &[6., 7., 8.]);
        a.scale(2.0);
        assert_eq!(a.data(), &[12., 14., 16.]);
    }

    #[test]
    fn mat_sums_and_outer() {
        let m = vec![1., 2., 3., 4., 5., 6.]; // 2x3
        let mut r = vec![0.; 2];
        let mut c = vec![0.; 3];
        mat::row_sums(&m, 2, 3, &mut r);
        mat::col_sums(&m, 2, 3, &mut c);
        assert_eq!(r, vec![6., 15.]);
        assert_eq!(c, vec![5., 7., 9.]);
        let mut o = vec![0.; 6];
        mat::outer(&[2., 3.], &[1., 10., 100.], &mut o);
        assert_eq!(o, vec![2., 20., 200., 3., 30., 300.]);
    }

    #[test]
    fn stats() {
        let t = Tensor::from_vec(&[4], vec![-3., 1., 2., -1.]);
        assert_eq!(t.sum(), -1.0);
        assert_eq!(t.max_abs(), 3.0);
        assert_eq!(t.sq_norm(), 15.0);
    }
}
