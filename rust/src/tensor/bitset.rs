//! Packed bit matrix — SMMF's 1-bit sign state `S_M`.
//!
//! The paper stores the sign of every 1st-momentum element as one bit
//! (32× smaller than the f32 momentum it replaces); this is the single
//! largest component of SMMF's optimizer memory and must actually be
//! bit-packed for the memory tables to mean anything.

/// Read up to 64 bits starting at bit `start` from a packed word slice
/// (bits beyond the slice read as zero). Shared by [`BitMatrix`] and the
/// SMMF sign-view hot path, so the word/offset arithmetic lives once.
#[inline]
pub fn word_chunk_get64(words: &[u64], start: usize) -> u64 {
    let w = start >> 6;
    let o = start & 63;
    let lo = words.get(w).copied().unwrap_or(0) >> o;
    if o == 0 {
        lo
    } else {
        let hi = words.get(w + 1).copied().unwrap_or(0) << (64 - o);
        lo | hi
    }
}

/// Write `len` (1..=64) bits starting at bit `start` into a packed word
/// slice. The target words (including any spill word) must be in bounds.
#[inline]
pub fn word_chunk_set64(words: &mut [u64], start: usize, bits: u64, len: usize) {
    debug_assert!(len >= 1 && len <= 64);
    let mask = if len == 64 { u64::MAX } else { (1u64 << len) - 1 };
    let bits = bits & mask;
    let w = start >> 6;
    let o = start & 63;
    words[w] = (words[w] & !(mask << o)) | (bits << o);
    let spill = (o + len).saturating_sub(64);
    if spill > 0 {
        let hi_mask = (1u64 << spill) - 1;
        words[w + 1] = (words[w + 1] & !hi_mask) | (bits >> (len - spill));
    }
}

/// Row-major packed bit matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct BitMatrix {
    rows: usize,
    cols: usize,
    words: Vec<u64>,
}

impl BitMatrix {
    pub fn zeros(rows: usize, cols: usize) -> BitMatrix {
        let nbits = rows * cols;
        BitMatrix { rows, cols, words: vec![0; nbits.div_ceil(64)] }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn nbits(&self) -> usize {
        self.rows * self.cols
    }

    /// Heap bytes actually held (the paper's S_M memory figure).
    pub fn heap_bytes(&self) -> usize {
        self.words.len() * 8
    }

    #[inline]
    pub fn get(&self, idx: usize) -> bool {
        debug_assert!(idx < self.nbits());
        (self.words[idx >> 6] >> (idx & 63)) & 1 == 1
    }

    #[inline]
    pub fn set(&mut self, idx: usize, v: bool) {
        debug_assert!(idx < self.nbits());
        let (w, b) = (idx >> 6, idx & 63);
        if v {
            self.words[w] |= 1u64 << b;
        } else {
            self.words[w] &= !(1u64 << b);
        }
    }

    #[inline]
    pub fn get2(&self, i: usize, j: usize) -> bool {
        self.get(i * self.cols + j)
    }

    /// Set bits [start, start+len) from a sign predicate over values,
    /// packing whole words at a time (hot path).
    pub fn set_range_from_signs(&mut self, start: usize, values: &[f32]) {
        for (k, &v) in values.iter().enumerate() {
            self.set(start + k, v > 0.0);
        }
    }

    /// Read up to 64 bits starting at bit `start` (bits beyond the matrix
    /// are zero). Hot-path helper for the fused SMMF step: one load pair
    /// replaces 64 `get` calls.
    #[inline]
    pub fn get_chunk64(&self, start: usize) -> u64 {
        word_chunk_get64(&self.words, start)
    }

    /// Write `len` (<= 64) bits starting at bit `start`.
    #[inline]
    pub fn set_chunk64(&mut self, start: usize, bits: u64, len: usize) {
        debug_assert!(start + len <= self.nbits().next_multiple_of(64));
        word_chunk_set64(&mut self.words, start, bits, len);
    }

    /// Raw words (for checkpointing).
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    pub fn words_mut(&mut self) -> &mut [u64] {
        &mut self.words
    }

    /// Serialize the packed words as little-endian bytes (`words * 8`
    /// bytes; rows/cols are carried by the caller). Trailing bits past
    /// `nbits()` in the last word are always zero, so the encoding is
    /// canonical and roundtrips bit-exactly.
    pub fn to_le_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.words.len() * 8);
        for &w in &self.words {
            out.extend_from_slice(&w.to_le_bytes());
        }
        out
    }

    /// Overwrite the packed words from [`BitMatrix::to_le_bytes`] output.
    /// The matrix keeps its dimensions; errors (without modifying `self`)
    /// when `bytes` does not match the word storage exactly. Bits past
    /// `nbits()` in the last word are masked to zero on load, so the
    /// canonical-encoding invariant holds even for a bit-rotted input
    /// (`count_ones`, equality and re-serialization stay exact).
    pub fn copy_from_le_bytes(&mut self, bytes: &[u8]) -> Result<(), String> {
        if bytes.len() != self.words.len() * 8 {
            return Err(format!(
                "sign-plane size mismatch: {} bytes for a {}x{} matrix ({} expected)",
                bytes.len(),
                self.rows,
                self.cols,
                self.words.len() * 8
            ));
        }
        for (w, c) in self.words.iter_mut().zip(bytes.chunks_exact(8)) {
            *w = u64::from_le_bytes(c.try_into().unwrap());
        }
        let tail = self.nbits() % 64;
        if tail != 0 {
            if let Some(last) = self.words.last_mut() {
                *last &= (1u64 << tail) - 1;
            }
        }
        Ok(())
    }

    pub fn count_ones(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_roundtrip() {
        let mut b = BitMatrix::zeros(5, 13); // 65 bits -> 2 words
        assert_eq!(b.heap_bytes(), 16);
        b.set(0, true);
        b.set(64, true);
        b.set(37, true);
        assert!(b.get(0) && b.get(64) && b.get(37));
        assert!(!b.get(1) && !b.get(63));
        b.set(64, false);
        assert!(!b.get(64));
        assert_eq!(b.count_ones(), 2);
    }

    #[test]
    fn get2_row_major() {
        let mut b = BitMatrix::zeros(3, 4);
        b.set(1 * 4 + 2, true);
        assert!(b.get2(1, 2));
        assert!(!b.get2(2, 1));
    }

    #[test]
    fn signs_from_values() {
        let mut b = BitMatrix::zeros(1, 6);
        b.set_range_from_signs(0, &[1.0, -1.0, 0.0, 2.0, -0.5, 3.0]);
        let bits: Vec<bool> = (0..6).map(|i| b.get(i)).collect();
        // strictly-positive convention (paper: sign = M > 0)
        assert_eq!(bits, vec![true, false, false, true, false, true]);
    }

    #[test]
    fn chunk_roundtrip_matches_bitwise() {
        use crate::util::prop;
        prop::cases(60, |rng| {
            let rows = 1 + rng.below(6);
            let cols = 1 + rng.below(130);
            let mut a = BitMatrix::zeros(rows, cols);
            let mut b = BitMatrix::zeros(rows, cols);
            // random fill via chunks on a, via bits on b
            for i in 0..rows {
                let base = i * cols;
                let mut j = 0;
                while j < cols {
                    let len = (cols - j).min(64);
                    let bits = rng.next_u64();
                    a.set_chunk64(base + j, bits, len);
                    for k in 0..len {
                        b.set(base + j + k, (bits >> k) & 1 == 1);
                    }
                    j += len;
                }
            }
            assert_eq!(a.words(), b.words());
            // chunk reads agree with bit reads
            for i in 0..rows {
                let base = i * cols;
                let mut j = 0;
                while j < cols {
                    let len = (cols - j).min(64);
                    let got = a.get_chunk64(base + j);
                    for k in 0..len {
                        assert_eq!((got >> k) & 1 == 1, b.get(base + j + k));
                    }
                    j += len;
                }
            }
        });
    }

    #[test]
    fn le_bytes_roundtrip_and_mismatch() {
        let mut a = BitMatrix::zeros(5, 13); // 65 bits -> 2 words
        a.set(0, true);
        a.set(37, true);
        a.set(64, true);
        let bytes = a.to_le_bytes();
        assert_eq!(bytes.len(), 16);
        let mut b = BitMatrix::zeros(5, 13);
        b.copy_from_le_bytes(&bytes).unwrap();
        assert_eq!(a, b);
        // wrong payload size: error, matrix untouched
        let before = b.clone();
        assert!(b.copy_from_le_bytes(&bytes[..8]).is_err());
        assert_eq!(b, before);
        // garbage past nbits() in the last word is masked on load: the
        // canonical encoding survives bit-rotted input.
        let mut dirty = bytes.clone();
        dirty[15] = 0xff; // 65 bits used -> bits 65..128 are tail
        let mut c = BitMatrix::zeros(5, 13);
        c.copy_from_le_bytes(&dirty).unwrap();
        assert_eq!(a, c);
        assert_eq!(c.to_le_bytes(), bytes);
    }

    #[test]
    fn memory_is_bit_packed() {
        let b = BitMatrix::zeros(1024, 1024);
        assert_eq!(b.heap_bytes(), 1024 * 1024 / 8);
    }
}
