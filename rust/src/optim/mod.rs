//! The optimizer library — the paper's contribution plus every baseline it
//! is evaluated against.
//!
//! * [`Smmf`] — Square-Matricized Momentum Factorization (this paper).
//! * [`Adam`] — Adam / AdamW (Kingma & Ba 2014; Loshchilov & Hutter 2019).
//! * [`Adafactor`] — factored 2nd moment (Shazeer & Stern 2018), HF
//!   conventions (row over the last axis, column over the second-to-last).
//! * [`Sm3`] — min-max cover accumulators (Anil et al. 2019) + momentum.
//! * [`Came`] — confidence-guided factored optimizer (Luo et al. 2023).
//! * [`Sgd`] — SGD with momentum.
//!
//! All optimizers implement [`Optimizer`] over parallel `&mut [Tensor]`
//! params / `&[Tensor]` grads and report their *live* persistent state
//! bytes; [`memory`] provides matching analytic accounting over bare shape
//! inventories (used for the LLaMA-scale tables where instantiating state
//! would need tens of GiB).
//!
//! # The SMMF pipeline: matricize → factorize → 1-bit signs
//!
//! SMMF keeps Adam-style first/second momenta in up to 96% less memory by
//! composing three ideas, each visible as a module here:
//!
//! 1. **Square matricization** ([`matricize`], paper Algorithm 2): every
//!    parameter tensor is viewed as the most nearly square `n̂ × m̂` matrix
//!    with `n̂·m̂ = numel`, which minimizes `n̂ + m̂` — the size of the
//!    factor vectors stored below (Theorem 3.2).
//! 2. **Rank-1 NNMF factorization** ([`nnmf`], Algorithms 3–5): each
//!    moment matrix is compressed to a row-mass vector and a column-mass
//!    vector (`n̂ + m̂` floats instead of `n̂·m̂`). SMMF's ordering is
//!    *decompress → update with the intact gradient → re-compress*
//!    ([`SmmfScheme::DecompressFirst`]), which is what separates it from
//!    the compress-first baselines it ablates against.
//! 3. **1-bit sign planes** ([`crate::tensor::BitMatrix`]): NNMF needs a
//!    non-negative matrix, so the first momentum's signs are stored
//!    separately at one bit per element ([`SignMode::Bit1`]).
//!
//! Construct an optimizer with [`build`] and drive it with
//! [`Optimizer::step`]:
//!
//! ```
//! use smmf_repro::optim::{build, OptKind, OptimConfig, Optimizer};
//! use smmf_repro::tensor::Tensor;
//!
//! let shapes = vec![vec![16, 16], vec![16]];
//! let cfg = OptimConfig::paper_defaults(OptKind::Smmf);
//! let mut opt = build(OptKind::Smmf, &shapes, &cfg);
//!
//! let mut params = vec![Tensor::zeros(&[16, 16]), Tensor::zeros(&[16])];
//! let grads = vec![
//!     Tensor::from_vec(&[16, 16], vec![0.01; 256]),
//!     Tensor::from_vec(&[16], vec![0.01; 16]),
//! ];
//! opt.step(&mut params, &grads);
//!
//! // Factorized state: a fraction of Adam's 2 floats/param (2176 B here).
//! assert!(opt.state_bytes() > 0 && opt.state_bytes() < 600);
//! ```
//!
//! # Checkpointing: the [`StateSerde`] trait
//!
//! Every optimizer also implements [`StateSerde`], which serializes its
//! state in the *native* compact representation — SMMF emits its factor
//! vectors and packed sign planes without ever densifying the momenta, so
//! a checkpoint costs what the in-RAM state costs (the paper's memory
//! tables carry over to disk). Blob layouts are specified in
//! `docs/CHECKPOINT_FORMAT.md`; the checkpoint container lives in
//! `crate::train::checkpoint`.
//!
//! ```
//! use smmf_repro::optim::{build, OptKind, OptimConfig, Optimizer, StateSerde};
//! use smmf_repro::tensor::Tensor;
//!
//! let shapes = vec![vec![8, 8]];
//! let cfg = OptimConfig::default();
//! let mut opt = build(OptKind::Adam, &shapes, &cfg);
//! let mut params = vec![Tensor::zeros(&[8, 8])];
//! let grads = vec![Tensor::from_vec(&[8, 8], vec![0.5; 64])];
//! opt.step(&mut params, &grads);
//!
//! // Save: one native blob per tensor + the step counter.
//! let blobs = opt.state_blobs();
//! let t = opt.opt_step();
//!
//! // Restore into a freshly built optimizer: bit-identical resume.
//! let mut opt2 = build(OptKind::Adam, &shapes, &cfg);
//! opt2.load_state_blobs(&blobs).unwrap();
//! opt2.set_opt_step(t);
//! assert_eq!(opt2.state_blobs(), blobs);
//! assert_eq!(opt2.opt_step(), 1);
//! ```
//!
//! # Param groups: per-group hyperparameters and state policies
//!
//! Real recipes treat parameters non-uniformly: bias/LayerNorm tensors
//! are weight-decay exempt, embeddings get scaled LRs, tiny vectors may
//! carry dense (or no) state. The grouped API ([`group`]) expresses this:
//! register tensors as [`ParamSpec`]s (name + shape + [`ParamRole`]),
//! describe groups with [`GroupPolicy`] matcher blocks (name globs and/or
//! role selectors; `lr_scale`, `weight_decay`, `frozen`,
//! [`StatePolicy`]), and construct with [`build_grouped`]. Every
//! optimizer resolves its effective per-tensor hyperparameters through
//! the group table at construction; [`memory`] mirrors the accounting
//! per group, and checkpoints record the resolved layout (CONFIG
//! section, `docs/CHECKPOINT_FORMAT.md`) so `--resume` can cross-check
//! it.
//!
//! **Migration note.** The pre-group API `build(kind, shapes, cfg)` is
//! now a thin shim that places every tensor in a single default group —
//! it remains bit-identical to the pre-group behavior and is fine for
//! uniform recipes and tests. New code that knows tensor names/roles
//! (model inventories expose [`crate::models::Inventory::param_specs`];
//! artifact-driven callers can use [`group::ParamRole::infer`]) should
//! construct through [`build_grouped`], which is what `train`,
//! `coordinator` and the CLI do. TOML configs gain `[[optimizer.group]]`
//! blocks and the CLI a `--group` flag (see `coordinator::config`).
//!
//! # The parallel step engine
//!
//! Every optimizer dispatches `step()` over the work-sharding engine in
//! [`parallel`] when [`OptimConfig::threads`] is greater than one: the
//! parameter inventory is statically binned once at construction into
//! cost-balanced shards ([`parallel::ParamPartition`]), large tensors are
//! additionally split intra-tensor into contiguous row ranges of their
//! update view, and each step runs the shards on scoped worker threads
//! (std::thread only). Semantics:
//!
//! * `threads = 1` (the default) reproduces the serial path bit-for-bit —
//!   it is exactly the pre-engine code.
//! * Elementwise optimizers (Adam/AdamW, SGD, SMMF's dense fallback) and
//!   the tensor-granular optimizers (Adafactor, CAME, SM3) are
//!   bit-identical to the serial path at any thread count.
//! * SMMF's fused factored path reduces per-item column partials in fixed
//!   item order: results are bit-identical across any `threads >= 2`
//!   (item boundaries do not depend on the thread count) and agree with
//!   `threads = 1` to FP-reduction-order tolerance (~1e-7 relative).
//!   Exception: SMMF's compress-first *ablation* scheme needs a
//!   whole-tensor gradient pre-pass and always runs (and plans) serially.
//!
//! The knob plumbs through the TOML layer (`[optimizer] threads = N`) and
//! the CLI (`--threads N`); see `coordinator::config`.

pub mod adafactor;
pub mod adam;
pub mod blob;
pub mod came;
pub mod group;
pub mod matricize;
pub mod memory;
pub mod nnmf;
pub mod parallel;
pub mod schedule;
pub mod sgd;
pub mod sm3;
pub mod smmf;

pub use adafactor::Adafactor;
pub use adam::Adam;
pub use came::Came;
pub use group::{GroupPolicy, GroupedConfig, ParamRole, ParamSpec, StatePolicy, TensorPolicy};
pub use sgd::Sgd;
pub use sm3::Sm3;
pub use smmf::Smmf;

use crate::tensor::Tensor;

/// Which optimizer (CLI / config selector).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OptKind {
    Sgd,
    Adam,
    AdamW,
    Adafactor,
    Sm3,
    Came,
    Smmf,
}

impl OptKind {
    pub fn parse(s: &str) -> Option<OptKind> {
        Some(match s.to_ascii_lowercase().as_str() {
            "sgd" => OptKind::Sgd,
            "adam" => OptKind::Adam,
            "adamw" => OptKind::AdamW,
            "adafactor" => OptKind::Adafactor,
            "sm3" => OptKind::Sm3,
            "came" => OptKind::Came,
            "smmf" => OptKind::Smmf,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            OptKind::Sgd => "sgd",
            OptKind::Adam => "adam",
            OptKind::AdamW => "adamw",
            OptKind::Adafactor => "adafactor",
            OptKind::Sm3 => "sm3",
            OptKind::Came => "came",
            OptKind::Smmf => "smmf",
        }
    }

    pub fn all() -> [OptKind; 5] {
        // The paper's five evaluated optimizers.
        [OptKind::Adam, OptKind::Adafactor, OptKind::Sm3, OptKind::Came, OptKind::Smmf]
    }

    /// Every optimizer the library implements (the paper's five plus SGD
    /// and decoupled AdamW) — the set covered by checkpointing tests.
    pub fn every() -> [OptKind; 7] {
        [
            OptKind::Sgd,
            OptKind::Adam,
            OptKind::AdamW,
            OptKind::Adafactor,
            OptKind::Sm3,
            OptKind::Came,
            OptKind::Smmf,
        ]
    }

    /// Stable numeric tag used by the `SMMFCKPT` v2 on-disk format
    /// (docs/CHECKPOINT_FORMAT.md). Never renumber these.
    pub fn tag(self) -> u32 {
        match self {
            OptKind::Sgd => 1,
            OptKind::Adam => 2,
            OptKind::AdamW => 3,
            OptKind::Adafactor => 4,
            OptKind::Sm3 => 5,
            OptKind::Came => 6,
            OptKind::Smmf => 7,
        }
    }

    /// Inverse of [`OptKind::tag`]; `None` for unknown tags.
    pub fn from_tag(tag: u32) -> Option<OptKind> {
        Some(match tag {
            1 => OptKind::Sgd,
            2 => OptKind::Adam,
            3 => OptKind::AdamW,
            4 => OptKind::Adafactor,
            5 => OptKind::Sm3,
            6 => OptKind::Came,
            7 => OptKind::Smmf,
            _ => return None,
        })
    }
}

/// SMMF moment-update ordering (paper §3.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SmmfScheme {
    /// The paper's contribution: decompress the stored moments, fold in
    /// the *intact* gradient, then re-compress.
    DecompressFirst,
    /// Ablation — the Adafactor-style ordering the paper argues against:
    /// the gradient is itself compressed (rank-1 + sign) before it ever
    /// touches the moments, losing the intact-gradient information.
    CompressFirst,
}

/// SMMF sign-matrix storage width.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SignMode {
    /// 1 bit per element (the paper's memory claim).
    Bit1,
    /// 1 byte per element — the faster variant the paper uses for its
    /// Table 5 timing runs ("8-bit format S_M").
    Byte8,
}

/// SMMF matricization target (ablation of Algorithm 2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MatricizeMode {
    /// Squarest factorization of numel (the paper: minimizes n̂+m̂).
    Square,
    /// Ablation — fold every leading axis into the rows and factorize
    /// (numel/last, last), the last-axes convention of Adafactor/CAME.
    FoldLast,
}

/// Weight-decay coupling mode (paper Appendix L, Algorithms 6–7).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WeightDecayMode {
    /// Adam-style: `g += wd * p` before the moment update.
    Adam,
    /// AdamW-style: `p *= 1 - lr * wd` decoupled decay.
    AdamW,
}

/// Shared hyper-parameters (union over all optimizers; each reads the
/// fields it uses; defaults follow the paper's Appendix L tables).
/// `PartialEq` backs the `SMMFCELL` wire round-trip guard
/// ([`crate::coordinator::ExperimentConfig`] `to_toml`/`from_toml_str`).
#[derive(Clone, Debug, PartialEq)]
pub struct OptimConfig {
    pub lr: f32,
    /// 1st-moment coefficient (β1 everywhere).
    pub beta1: f32,
    /// Adam / SM3 2nd-moment coefficient.
    pub beta2: f32,
    /// CAME instability coefficient (β3).
    pub beta3: f32,
    /// Regularization constants: ε1 inside/after sqrt, ε2 (CAME/Adafactor).
    pub eps1: f32,
    pub eps2: f32,
    pub weight_decay: f32,
    pub weight_decay_mode: WeightDecayMode,
    /// Adafactor/SMMF 2nd-moment decay exponent γ (in [-1, 0]).
    pub decay_rate: f32,
    /// SMMF 1st-moment growth rate λ.
    pub growth_rate: f32,
    /// Adafactor/CAME update clipping threshold d.
    pub clip_threshold: f32,
    /// SMMF: square-matricize rank-1 tensors too.
    pub vector_reshape: bool,
    /// SGD momentum.
    pub momentum: f32,
    /// Adam bias correction (the paper disables it for pre-training).
    pub bias_correction: bool,
    /// Adafactor relative-step / parameter-scaled LR (HF default true when
    /// no explicit lr is given — the paper's Adafactor configs use it).
    pub relative_step: bool,
    /// SMMF ablation knobs (see the enums above).
    pub smmf_scheme: SmmfScheme,
    pub smmf_sign_mode: SignMode,
    pub smmf_matricize: MatricizeMode,
    /// Worker threads for the parallel step engine ([`parallel`]).
    /// `1` = serial (bit-identical to the pre-engine behavior).
    pub threads: usize,
}

impl Default for OptimConfig {
    fn default() -> Self {
        Self {
            lr: 1e-3,
            beta1: 0.9,
            beta2: 0.999,
            beta3: 0.9999,
            eps1: 1e-8,
            eps2: 1e-3,
            weight_decay: 0.0,
            weight_decay_mode: WeightDecayMode::AdamW,
            decay_rate: -0.8,
            growth_rate: 0.999,
            clip_threshold: 1.0,
            vector_reshape: true,
            momentum: 0.9,
            bias_correction: true,
            relative_step: false,
            smmf_scheme: SmmfScheme::DecompressFirst,
            smmf_sign_mode: SignMode::Bit1,
            smmf_matricize: MatricizeMode::Square,
            threads: 1,
        }
    }
}

impl OptimConfig {
    /// The paper's per-optimizer defaults (Appendix L): SMMF uses ε=1e-8,
    /// Adafactor/SM3/CAME use ε1=1e-30, CAME ε2=1e-16.
    pub fn paper_defaults(kind: OptKind) -> OptimConfig {
        let mut c = OptimConfig::default();
        match kind {
            OptKind::Smmf => {
                c.eps1 = 1e-8;
            }
            // The paper's Adam/AdamW pre-training configs run without
            // bias correction (Table 3 setup); surfaced in summary.json
            // so run configs stay auditable.
            OptKind::Adam | OptKind::AdamW => {
                c.bias_correction = false;
            }
            OptKind::Adafactor => {
                c.eps1 = 1e-30;
                c.eps2 = 1e-3;
                c.relative_step = true;
            }
            OptKind::Came => {
                c.eps1 = 1e-30;
                c.eps2 = 1e-16;
            }
            OptKind::Sm3 => {
                c.eps1 = 1e-30;
            }
            _ => {}
        }
        c
    }
}

/// Native-format optimizer-state (de)serialization for checkpointing.
///
/// Each optimizer emits one binary blob per registered parameter tensor,
/// in its *native* compact representation — SMMF writes its `u`/`v`
/// factor vectors as f32 plus the packed 1-bit sign plane and never
/// densifies the momenta; Adafactor writes its row/column accumulators;
/// SM3 its per-axis covers — so checkpoints cost what the in-RAM state
/// costs. Byte layouts are specified per [`OptKind`] in
/// `docs/CHECKPOINT_FORMAT.md` and must stay stable: they are the
/// `SMMFCKPT` v2 on-disk schema.
///
/// Contract: calling [`StateSerde::load_state_blobs`] (and
/// [`StateSerde::set_opt_step`]) on a freshly built optimizer over the
/// same shapes and config, fed the output of
/// [`StateSerde::state_blobs`]/[`StateSerde::opt_step`], restores the
/// optimizer *bit-for-bit* — subsequent [`Optimizer::step`] trajectories
/// are identical to never having serialized at all. Loading validates
/// every length and tag against the constructed state and errors on any
/// mismatch or truncation; after an error the state is unspecified and
/// the optimizer should be rebuilt.
pub trait StateSerde {
    /// Internal step counter `t` (0 before the first `step` call). Drives
    /// the β1/β2 schedules, bias correction and Adafactor's relative
    /// step, so resume must restore it alongside the blobs.
    fn opt_step(&self) -> u64;

    /// Restore the internal step counter.
    fn set_opt_step(&mut self, t: u64);

    /// Serialize the persistent state of the single tensor at
    /// registration index `i`. [`StateSerde::state_blobs`] is exactly
    /// `(0..n).map(state_blob)` for every optimizer — the per-tensor
    /// entry point is what lets the server's streamed snapshot path
    /// emit one tensor at a time instead of materializing the whole
    /// inventory's state.
    fn state_blob(&self, i: usize) -> Vec<u8>;

    /// Serialize the persistent state: one native-format blob per
    /// parameter tensor, in registration order.
    fn state_blobs(&self) -> Vec<Vec<u8>>;

    /// Inverse of [`StateSerde::state_blobs`] on an optimizer built over
    /// the same shapes and config.
    fn load_state_blobs(&mut self, blobs: &[Vec<u8>]) -> anyhow::Result<()>;
}

/// A stateful optimizer over a fixed set of parameter tensors.
///
/// [`StateSerde`] is a supertrait so `Box<dyn Optimizer>` can be
/// checkpointed and resumed without knowing the concrete type.
pub trait Optimizer: Send + StateSerde {
    fn name(&self) -> &'static str;

    /// Apply one optimization step. `params[i]` and `grads[i]` must have
    /// the shapes registered at construction. Internal step counter starts
    /// at 1 on the first call (paper convention).
    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor]);

    /// Override the learning rate (for external schedules).
    fn set_lr(&mut self, lr: f32);

    /// Persistent optimizer-state heap bytes (the paper's "optimizer
    /// memory" column — excludes transient scratch, see Appendix G).
    fn state_bytes(&self) -> u64;

    /// Transient scratch bytes held between steps (Appendix G's temporary
    /// memory; reported separately for honesty).
    fn scratch_bytes(&self) -> u64 {
        0
    }

    /// The static shard plan `step()` dispatches over (see [`parallel`]).
    /// `None` means the optimizer has no planned partition.
    fn partition(&self) -> Option<&parallel::ParamPartition> {
        None
    }
}

/// Construct an optimizer for a set of bare parameter shapes with one
/// flat config — the legacy entry point, kept as a thin shim over the
/// grouped path: every tensor lands in a single default group, which is
/// bit-identical to the pre-group behavior. New callers that know tensor
/// names/roles should use [`build_grouped`].
pub fn build(kind: OptKind, shapes: &[Vec<usize>], cfg: &OptimConfig) -> Box<dyn Optimizer> {
    let policies = vec![TensorPolicy::uniform(cfg); shapes.len()];
    build_with_policies(kind, shapes, cfg, &policies)
}

/// Construct an optimizer over a role-tagged parameter inventory with
/// per-group hyperparameter overrides (see [`group`]). Group policies
/// are resolved once here; each optimizer then reads its effective
/// per-tensor `lr_scale` / `weight_decay` / `frozen` / [`StatePolicy`]
/// from the resolved table at construction and every step.
pub fn build_grouped(
    kind: OptKind,
    specs: &[ParamSpec],
    gcfg: &GroupedConfig,
) -> Box<dyn Optimizer> {
    let res = group::resolve(specs, gcfg);
    let shapes: Vec<Vec<usize>> = specs.iter().map(|s| s.shape.clone()).collect();
    build_with_policies(kind, &shapes, &gcfg.base, &res.tensor)
}

/// Construct an optimizer over a *subset* of a resolved inventory: the
/// tensors named by `indices` (ascending positions into
/// `shapes`/`policies`), carrying their already-resolved per-tensor
/// policies. This is the shard-aware build path of the optimizer-state
/// server (`crate::server::shard`): each shard owns the state for its
/// tensor subset, and because every optimizer here updates tensors
/// independently (only the internal step counter is shared, and each
/// shard advances it identically), the sharded trajectory is
/// bit-identical, tensor by tensor, to a single optimizer over the full
/// inventory. Group overrides (`StatePolicy`, lr scale, weight decay,
/// frozen) survive sharding because the policy table travels with the
/// subset.
pub fn build_subset(
    kind: OptKind,
    shapes: &[Vec<usize>],
    cfg: &OptimConfig,
    policies: &[TensorPolicy],
    indices: &[usize],
) -> Box<dyn Optimizer> {
    assert_eq!(shapes.len(), policies.len(), "one policy per tensor");
    let sub_shapes: Vec<Vec<usize>> = indices.iter().map(|&i| shapes[i].clone()).collect();
    let sub_policies: Vec<TensorPolicy> = indices.iter().map(|&i| policies[i]).collect();
    build_with_policies(kind, &sub_shapes, cfg, &sub_policies)
}

/// Construct from an already-resolved per-tensor policy table (the
/// common substrate of [`build`] and [`build_grouped`]; useful when the
/// caller also needs the [`group::Resolution`] — e.g. for the checkpoint
/// CONFIG section or per-group memory reports).
pub fn build_with_policies(
    kind: OptKind,
    shapes: &[Vec<usize>],
    cfg: &OptimConfig,
    policies: &[TensorPolicy],
) -> Box<dyn Optimizer> {
    assert_eq!(shapes.len(), policies.len(), "one policy per tensor");
    match kind {
        OptKind::Sgd => Box::new(Sgd::with_policies(shapes, cfg, policies)),
        OptKind::Adam => Box::new(Adam::with_policies(shapes, cfg, false, policies)),
        OptKind::AdamW => Box::new(Adam::with_policies(shapes, cfg, true, policies)),
        OptKind::Adafactor => Box::new(Adafactor::with_policies(shapes, cfg, policies)),
        OptKind::Sm3 => Box::new(Sm3::with_policies(shapes, cfg, policies)),
        OptKind::Came => Box::new(Came::with_policies(shapes, cfg, policies)),
        OptKind::Smmf => Box::new(Smmf::with_policies(shapes, cfg, policies)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_roundtrip() {
        for k in [
            OptKind::Sgd,
            OptKind::Adam,
            OptKind::AdamW,
            OptKind::Adafactor,
            OptKind::Sm3,
            OptKind::Came,
            OptKind::Smmf,
        ] {
            assert_eq!(OptKind::parse(k.name()), Some(k));
        }
        assert_eq!(OptKind::parse("nope"), None);
    }

    #[test]
    fn checkpoint_tags_are_stable_and_unique() {
        let mut seen = std::collections::HashSet::new();
        for k in OptKind::every() {
            let t = k.tag();
            assert!(seen.insert(t), "duplicate tag {t}");
            assert_eq!(OptKind::from_tag(t), Some(k));
        }
        // Pinned values: the on-disk format depends on them.
        assert_eq!(OptKind::Sgd.tag(), 1);
        assert_eq!(OptKind::Smmf.tag(), 7);
        assert_eq!(OptKind::from_tag(0), None);
        assert_eq!(OptKind::from_tag(99), None);
    }

    /// Shared smoke test: every optimizer reduces a convex quadratic.
    #[test]
    fn all_optimizers_minimize_quadratic() {
        let shapes = vec![vec![4, 3], vec![6]];
        for kind in OptKind::all() {
            let cfg = OptimConfig {
                lr: 0.05,
                relative_step: false,
                ..OptimConfig::paper_defaults(kind)
            };
            let mut opt = build(kind, &shapes, &cfg);
            let mut params: Vec<Tensor> = shapes
                .iter()
                .map(|s| {
                    let n: usize = s.iter().product();
                    Tensor::from_vec(s, (0..n).map(|i| 1.0 + (i % 3) as f32).collect())
                })
                .collect();
            let loss = |ps: &[Tensor]| -> f64 { ps.iter().map(|p| p.sq_norm()).sum() };
            let initial = loss(&params);
            for _ in 0..1500 {
                let grads: Vec<Tensor> = params
                    .iter()
                    .map(|p| {
                        let mut g = p.clone();
                        g.scale(2.0);
                        g
                    })
                    .collect();
                opt.step(&mut params, &grads);
            }
            let fin = loss(&params);
            assert!(
                fin < initial * 0.1,
                "{}: {initial} -> {fin}",
                kind.name()
            );
            assert!(opt.state_bytes() > 0);
        }
    }
}
