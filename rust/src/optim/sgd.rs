//! SGD with momentum — the zero/low-memory reference point.

use super::{OptimConfig, Optimizer, WeightDecayMode};
use crate::tensor::Tensor;

pub struct Sgd {
    cfg: OptimConfig,
    m: Vec<Vec<f32>>, // empty when momentum == 0
    t: u64,
}

impl Sgd {
    pub fn new(shapes: &[Vec<usize>], cfg: &OptimConfig) -> Sgd {
        let m = if cfg.momentum != 0.0 {
            shapes.iter().map(|s| vec![0.0; s.iter().product()]).collect()
        } else {
            Vec::new()
        };
        Sgd { cfg: cfg.clone(), m, t: 0 }
    }
}

impl Optimizer for Sgd {
    fn name(&self) -> &'static str {
        "sgd"
    }

    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor]) {
        self.t += 1;
        let cfg = &self.cfg;
        for (idx, (param, grad)) in params.iter_mut().zip(grads).enumerate() {
            let p = param.data_mut();
            let g = grad.data();
            if cfg.weight_decay != 0.0 && cfg.weight_decay_mode == WeightDecayMode::AdamW {
                let f = 1.0 - cfg.lr * cfg.weight_decay;
                p.iter_mut().for_each(|w| *w *= f);
            }
            let couple = cfg.weight_decay != 0.0 && cfg.weight_decay_mode == WeightDecayMode::Adam;
            if cfg.momentum != 0.0 {
                let m = &mut self.m[idx];
                for ((w, &g0), mij) in p.iter_mut().zip(g).zip(m.iter_mut()) {
                    let gij = if couple { g0 + cfg.weight_decay * *w } else { g0 };
                    *mij = cfg.momentum * *mij + gij;
                    *w -= cfg.lr * *mij;
                }
            } else {
                for (w, &g0) in p.iter_mut().zip(g) {
                    let gij = if couple { g0 + cfg.weight_decay * *w } else { g0 };
                    *w -= cfg.lr * gij;
                }
            }
        }
    }

    fn set_lr(&mut self, lr: f32) {
        self.cfg.lr = lr;
    }

    fn state_bytes(&self) -> u64 {
        self.m.iter().map(|x| (x.len() * 4) as u64).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_momentum_no_state() {
        let cfg = OptimConfig { momentum: 0.0, ..Default::default() };
        assert_eq!(Sgd::new(&[vec![100]], &cfg).state_bytes(), 0);
        let cfg = OptimConfig { momentum: 0.9, ..Default::default() };
        assert_eq!(Sgd::new(&[vec![100]], &cfg).state_bytes(), 400);
    }

    #[test]
    fn plain_step_is_lr_times_grad() {
        let cfg = OptimConfig { lr: 0.5, momentum: 0.0, ..Default::default() };
        let mut opt = Sgd::new(&[vec![2]], &cfg);
        let mut p = vec![Tensor::from_vec(&[2], vec![1.0, 2.0])];
        let g = vec![Tensor::from_vec(&[2], vec![2.0, -2.0])];
        opt.step(&mut p, &g);
        assert_eq!(p[0].data(), &[0.0, 3.0]);
    }
}
