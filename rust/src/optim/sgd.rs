//! SGD with momentum — the zero/low-memory reference point.
//!
//! Elementwise update, so the parallel path (`OptimConfig::threads > 1`)
//! splits flat element ranges and is bit-identical to the serial walk.

use anyhow::{bail, Result};

use super::blob::{BlobReader, BlobWriter};
use super::group::TensorPolicy;
use super::parallel::{self, ParamPartition, TensorGeom};
use super::{OptimConfig, Optimizer, StateSerde, WeightDecayMode};
use crate::tensor::Tensor;

pub struct Sgd {
    cfg: OptimConfig,
    /// Effective per-tensor policy resolved from the group table.
    policies: Vec<TensorPolicy>,
    /// One momentum buffer per tensor; empty when momentum is disabled
    /// globally or per group (`StatePolicy::None` / frozen).
    m: Vec<Vec<f32>>,
    t: u64,
    plan: ParamPartition,
}

impl Sgd {
    pub fn new(shapes: &[Vec<usize>], cfg: &OptimConfig) -> Sgd {
        Self::with_policies(shapes, cfg, &vec![TensorPolicy::uniform(cfg); shapes.len()])
    }

    pub fn with_policies(
        shapes: &[Vec<usize>],
        cfg: &OptimConfig,
        policies: &[TensorPolicy],
    ) -> Sgd {
        assert_eq!(shapes.len(), policies.len());
        let m: Vec<Vec<f32>> = shapes
            .iter()
            .zip(policies)
            .map(|(s, pol)| {
                if cfg.momentum != 0.0 && !pol.stateless() {
                    vec![0.0; s.iter().product()]
                } else {
                    Vec::new()
                }
            })
            .collect();
        let geoms: Vec<TensorGeom> = shapes
            .iter()
            .map(|s| TensorGeom::elementwise(s.iter().product(), 1))
            .collect();
        let plan = ParamPartition::plan(&geoms, cfg.threads);
        Sgd { cfg: cfg.clone(), policies: policies.to_vec(), m, t: 0, plan }
    }

    /// Elementwise kernel over one chunk (`m` is `None` when momentum is
    /// disabled for the tensor). `lr`/`wd` are the group-effective
    /// values.
    fn update_chunk(
        cfg: &OptimConfig,
        lr: f32,
        wd: f32,
        p: &mut [f32],
        g: &[f32],
        m: Option<&mut [f32]>,
    ) {
        if wd != 0.0 && cfg.weight_decay_mode == WeightDecayMode::AdamW {
            let f = 1.0 - lr * wd;
            p.iter_mut().for_each(|w| *w *= f);
        }
        let couple = wd != 0.0 && cfg.weight_decay_mode == WeightDecayMode::Adam;
        match m {
            Some(m) => {
                for ((w, &g0), mij) in p.iter_mut().zip(g).zip(m.iter_mut()) {
                    let gij = if couple { g0 + wd * *w } else { g0 };
                    *mij = cfg.momentum * *mij + gij;
                    *w -= lr * *mij;
                }
            }
            None => {
                for (w, &g0) in p.iter_mut().zip(g) {
                    let gij = if couple { g0 + wd * *w } else { g0 };
                    *w -= lr * gij;
                }
            }
        }
    }
}

impl StateSerde for Sgd {
    fn opt_step(&self) -> u64 {
        self.t
    }

    fn set_opt_step(&mut self, t: u64) {
        self.t = t;
    }

    /// Blob (docs/CHECKPOINT_FORMAT.md, kind tag 1): `u8 has_momentum`;
    /// when 1, `u64 len` + the momentum buffer as f32. Tensors without
    /// momentum (globally disabled, `StatePolicy::None`, or frozen) emit
    /// the single byte 0.
    fn state_blob(&self, i: usize) -> Vec<u8> {
        let m = &self.m[i];
        let mut w = BlobWriter::new();
        if m.is_empty() {
            w.u8(0);
        } else {
            w.u8(1);
            w.u64(m.len() as u64);
            w.f32s(m);
        }
        w.finish()
    }

    fn state_blobs(&self) -> Vec<Vec<u8>> {
        (0..self.m.len()).map(|i| self.state_blob(i)).collect()
    }

    fn load_state_blobs(&mut self, blobs: &[Vec<u8>]) -> Result<()> {
        if blobs.len() != self.m.len() {
            bail!(
                "sgd: checkpoint has {} tensors, optimizer has {}",
                blobs.len(),
                self.m.len()
            );
        }
        for (idx, (blob, m)) in blobs.iter().zip(self.m.iter_mut()).enumerate() {
            let mut r = BlobReader::new(blob);
            let has_m = r.u8()?;
            match (has_m, m.is_empty()) {
                (1, false) => {
                    r.expect_len(m.len(), &format!("sgd tensor {idx} momentum"))?;
                    r.f32s_into(m)?;
                }
                (0, true) => {}
                (has, empty) => bail!(
                    "sgd tensor {idx}: momentum mismatch (checkpoint has_momentum={has}, \
                     optimizer momentum {} — momentum/group configs must agree)",
                    if empty { "disabled" } else { "enabled" }
                ),
            }
            r.finish()?;
        }
        Ok(())
    }
}

impl Optimizer for Sgd {
    fn name(&self) -> &'static str {
        "sgd"
    }

    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor]) {
        self.t += 1;
        if self.cfg.threads <= 1 {
            let cfg = self.cfg.clone();
            for (idx, ((param, grad), m)) in
                params.iter_mut().zip(grads).zip(self.m.iter_mut()).enumerate()
            {
                let pol = self.policies[idx];
                if pol.frozen {
                    continue;
                }
                let mm = if m.is_empty() { None } else { Some(&mut m[..]) };
                Self::update_chunk(
                    &cfg,
                    cfg.lr * pol.lr_scale,
                    pol.weight_decay,
                    param.data_mut(),
                    grad.data(),
                    mm,
                );
            }
            return;
        }

        struct Task<'a> {
            p: &'a mut [f32],
            g: &'a [f32],
            m: Option<&'a mut [f32]>,
            lr: f32,
            wd: f32,
            frozen: bool,
        }
        let cfg = self.cfg.clone();
        let plan = &self.plan;
        let mut tasks: Vec<Task<'_>> = Vec::with_capacity(plan.n_items());
        for (idx, ((param, grad), m)) in
            params.iter_mut().zip(grads).zip(self.m.iter_mut()).enumerate()
        {
            let pol = self.policies[idx];
            let items = plan.items_of(idx);
            let p_parts = parallel::split_rows_mut(param.data_mut(), items, 1);
            let m_parts: Vec<Option<&mut [f32]>> = if m.is_empty() {
                items.iter().map(|_| None).collect()
            } else {
                parallel::split_rows_mut(m, items, 1).into_iter().map(Some).collect()
            };
            let g = grad.data();
            for ((it, p), mm) in items.iter().zip(p_parts).zip(m_parts) {
                tasks.push(Task {
                    p,
                    g: &g[it.row0..it.row1],
                    m: mm,
                    lr: cfg.lr * pol.lr_scale,
                    wd: pol.weight_decay,
                    frozen: pol.frozen,
                });
            }
        }
        let mut shards = parallel::into_shards(plan, vec![(); plan.n_shards()], tasks);
        parallel::run_shards(&mut shards, |_, t| {
            if t.frozen {
                return;
            }
            Self::update_chunk(&cfg, t.lr, t.wd, t.p, t.g, t.m.as_deref_mut());
        });
    }

    fn set_lr(&mut self, lr: f32) {
        self.cfg.lr = lr;
    }

    fn state_bytes(&self) -> u64 {
        self.m.iter().map(|x| (x.len() * 4) as u64).sum()
    }

    fn partition(&self) -> Option<&ParamPartition> {
        Some(&self.plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_momentum_no_state() {
        let cfg = OptimConfig { momentum: 0.0, ..Default::default() };
        assert_eq!(Sgd::new(&[vec![100]], &cfg).state_bytes(), 0);
        let cfg = OptimConfig { momentum: 0.9, ..Default::default() };
        assert_eq!(Sgd::new(&[vec![100]], &cfg).state_bytes(), 400);
    }

    #[test]
    fn plain_step_is_lr_times_grad() {
        let cfg = OptimConfig { lr: 0.5, momentum: 0.0, ..Default::default() };
        let mut opt = Sgd::new(&[vec![2]], &cfg);
        let mut p = vec![Tensor::from_vec(&[2], vec![1.0, 2.0])];
        let g = vec![Tensor::from_vec(&[2], vec![2.0, -2.0])];
        opt.step(&mut p, &g);
        assert_eq!(p[0].data(), &[0.0, 3.0]);
    }

    #[test]
    fn parallel_is_bit_identical_to_serial() {
        use crate::util::rng::Pcg32;
        let shapes = vec![vec![1000], vec![1], vec![31, 7]];
        let mut rng = Pcg32::new(11);
        let init: Vec<Tensor> = shapes
            .iter()
            .map(|s| {
                let mut t = Tensor::zeros(s);
                rng.fill_normal(t.data_mut(), 0.5);
                t
            })
            .collect();
        let g: Vec<Tensor> = shapes
            .iter()
            .map(|s| {
                let mut t = Tensor::zeros(s);
                rng.fill_normal(t.data_mut(), 0.1);
                t
            })
            .collect();
        for momentum in [0.0f32, 0.9] {
            let run = |threads: usize| -> Vec<Tensor> {
                let cfg = OptimConfig { lr: 0.1, momentum, weight_decay: 0.01, threads, ..Default::default() };
                let mut opt = Sgd::new(&shapes, &cfg);
                let mut p = init.clone();
                for _ in 0..3 {
                    opt.step(&mut p, &g);
                }
                p
            };
            assert_eq!(run(1), run(4));
        }
    }
}
