//! One-pass rank-1 NNMF compression / decompression (paper Algorithms 3–5).
//!
//! `compress` produces the row/column mass vectors of a non-negative
//! matrix (normalizing the side chosen by the paper's shape rule);
//! `decompress` is the outer product, with SMMF's sign restoration for the
//! 1st momentum. These are the *naive* (materializing) forms used for
//! differential testing; the production hot path in `smmf.rs` fuses them
//! and never materializes the matrix.

#![deny(missing_docs)]

use crate::tensor::BitMatrix;

/// Compress a non-negative (rows × cols) matrix `m` into `r`, `c`.
/// Normalization side rule (Appendix M code): if rows < cols normalize `r`
/// by its total mass, else normalize `c`.
///
/// ```
/// use smmf_repro::optim::nnmf::{compress, decompress};
/// // A rank-1 non-negative matrix survives the round trip exactly:
/// // m = outer([2, 1], [1, 2]).
/// let m = [2.0_f32, 4.0, 1.0, 2.0];
/// let (mut r, mut c) = (vec![0.0; 2], vec![0.0; 2]);
/// compress(&m, 2, 2, &mut r, &mut c);
/// let mut rec = vec![0.0; 4];
/// decompress(&r, &c, None, &mut rec);
/// for (a, b) in m.iter().zip(&rec) {
///     assert!((a - b).abs() < 1e-5);
/// }
/// ```
pub fn compress(m: &[f32], rows: usize, cols: usize, r: &mut [f32], c: &mut [f32]) {
    crate::tensor::mat::row_sums(m, rows, cols, r);
    crate::tensor::mat::col_sums(m, rows, cols, c);
    normalize_side(rows, cols, r, c);
}

/// Apply the normalize-shorter-side rule in place.
pub fn normalize_side(rows: usize, cols: usize, r: &mut [f32], c: &mut [f32]) {
    if rows < cols {
        let total: f32 = r.iter().sum();
        if total != 0.0 {
            r.iter_mut().for_each(|x| *x /= total);
        }
    } else {
        let total: f32 = c.iter().sum();
        if total != 0.0 {
            c.iter_mut().for_each(|x| *x /= total);
        }
    }
}

/// Compress a signed matrix: store signs (strictly-positive convention)
/// and factorize |m|.
pub fn compress_signed(
    m: &[f32],
    rows: usize,
    cols: usize,
    r: &mut [f32],
    c: &mut [f32],
    sign: &mut BitMatrix,
) {
    debug_assert_eq!(sign.nbits(), rows * cols);
    r.iter_mut().for_each(|x| *x = 0.0);
    c.iter_mut().for_each(|x| *x = 0.0);
    for i in 0..rows {
        let row = &m[i * cols..(i + 1) * cols];
        let mut rs = 0.0f32;
        for (j, &v) in row.iter().enumerate() {
            sign.set(i * cols + j, v > 0.0);
            let a = v.abs();
            rs += a;
            c[j] += a;
        }
        r[i] = rs;
    }
    normalize_side(rows, cols, r, c);
}

/// Decompress: out[i, j] = r[i] * c[j], negated where sign bit is unset.
pub fn decompress(r: &[f32], c: &[f32], sign: Option<&BitMatrix>, out: &mut [f32]) {
    let (rows, cols) = (r.len(), c.len());
    debug_assert_eq!(out.len(), rows * cols);
    crate::tensor::mat::outer(r, c, out);
    if let Some(s) = sign {
        for (idx, v) in out.iter_mut().enumerate() {
            if !s.get(idx) {
                *v = -*v;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn compress_preserves_total_mass() {
        // After decompression the total mass equals the original total:
        // Lemma E.7 (sum of the NNMF error matrix is zero).
        prop::cases(100, |rng| {
            let rows = 1 + rng.below(12);
            let cols = 1 + rng.below(12);
            let m: Vec<f32> = (0..rows * cols).map(|_| rng.uniform() + 0.01).collect();
            let mut r = vec![0.0; rows];
            let mut c = vec![0.0; cols];
            compress(&m, rows, cols, &mut r, &mut c);
            let mut rec = vec![0.0; rows * cols];
            decompress(&r, &c, None, &mut rec);
            let total: f32 = m.iter().sum();
            let rec_total: f32 = rec.iter().sum();
            assert!(
                (total - rec_total).abs() <= 1e-3 * total.abs().max(1.0),
                "mass not preserved: {total} vs {rec_total}"
            );
        });
    }

    #[test]
    fn signed_roundtrip_signs() {
        let m = vec![1.0, -2.0, 0.0, 3.0, -4.0, 5.0];
        let (rows, cols) = (2, 3);
        let mut r = vec![0.0; 2];
        let mut c = vec![0.0; 3];
        let mut s = BitMatrix::zeros(rows, cols);
        compress_signed(&m, rows, cols, &mut r, &mut c, &mut s);
        let mut rec = vec![0.0; 6];
        decompress(&r, &c, Some(&s), &mut rec);
        for (orig, rec) in m.iter().zip(&rec) {
            if *orig > 0.0 {
                assert!(*rec >= 0.0);
            }
            if *orig < 0.0 {
                assert!(*rec <= 0.0);
            }
        }
    }

    #[test]
    fn rank1_matrix_is_exact() {
        // A rank-1 non-negative matrix must be reconstructed exactly.
        let r0 = [0.5f32, 2.0, 1.0];
        let c0 = [1.0f32, 3.0];
        let mut m = vec![0.0; 6];
        crate::tensor::mat::outer(&r0, &c0, &mut m);
        let mut r = vec![0.0; 3];
        let mut c = vec![0.0; 2];
        compress(&m, 3, 2, &mut r, &mut c);
        let mut rec = vec![0.0; 6];
        decompress(&r, &c, None, &mut rec);
        for (a, b) in m.iter().zip(&rec) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn normalization_side() {
        // wide matrix (rows < cols): r sums to 1
        let m = vec![1.0f32; 2 * 5];
        let mut r = vec![0.0; 2];
        let mut c = vec![0.0; 5];
        compress(&m, 2, 5, &mut r, &mut c);
        assert!((r.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        // tall matrix: c sums to 1
        let m = vec![1.0f32; 5 * 2];
        let mut r = vec![0.0; 5];
        let mut c = vec![0.0; 2];
        compress(&m, 5, 2, &mut r, &mut c);
        assert!((c.iter().sum::<f32>() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn zero_matrix_stays_zero() {
        let m = vec![0.0f32; 12];
        let mut r = vec![0.0; 4];
        let mut c = vec![0.0; 3];
        compress(&m, 4, 3, &mut r, &mut c);
        assert!(r.iter().all(|&x| x == 0.0) && c.iter().all(|&x| x == 0.0));
    }
}
