//! The parallel optimizer step engine: static work partitioning plus a
//! scoped-thread execution primitive (std::thread only — no external
//! dependencies).
//!
//! Every optimizer in this crate walks a fixed parameter inventory each
//! step; on large models (`transformer_big` ≈ 210M params) a serial walk
//! dominates step wall time. The engine splits that walk across worker
//! threads in two stages:
//!
//! 1. **Planning** ([`ParamPartition::plan`]): the inventory is statically
//!    binned once at optimizer construction. Each tensor contributes a
//!    [`TensorGeom`] — a `(rows, cols)` view of its update loop, a row
//!    alignment constraint, and a per-element FLOP weight. Tensors whose
//!    estimated cost exceeds [`SPLIT_UNIT_COST`] are split intra-tensor
//!    into contiguous row ranges of that view; all resulting
//!    [`WorkItem`]s are then packed onto `threads` shards with an LPT
//!    (longest-processing-time-first) greedy that balances total cost.
//!    The plan is a pure function of the geometry — it does **not**
//!    depend on timing, so repeated steps (and repeated runs) execute an
//!    identical schedule, and the intra-tensor item boundaries do not
//!    depend on the thread count (only the shard *assignment* does),
//!    which is what makes results bit-reproducible across `threads >= 2`.
//! 2. **Execution** ([`run_shards`]): each shard's items run on one
//!    worker inside a `std::thread::scope`, so tasks may borrow the
//!    parameter/gradient/state slices directly — no `'static` bounds, no
//!    channels, no unsafe. Per-tensor kernels are plain `Send` functions
//!    over `(param slice, grad slice, per-tensor state)`; the engine
//!    never looks inside them.
//!
//! How each optimizer maps onto the engine:
//!
//! * **SMMF** (factored state): intra-tensor splitting over rows of the
//!   square-matricized view. Each work item owns private column
//!   accumulators; partials are reduced in fixed item order before
//!   `nnmf::normalize_side`, so a fixed shard plan yields bit-identical
//!   results regardless of how many workers execute it.
//! * **Adam / SGD / SMMF dense fallback** (elementwise state): intra-
//!   tensor splitting over flat element ranges. Elementwise updates have
//!   no cross-element reductions, so any split is bit-identical to the
//!   serial walk.
//! * **Adafactor / CAME / SM3** (whole-tensor reductions: RMS update
//!   clipping, row/col EMAs, min-max covers): tensor-granular items only
//!   (`rows = 1`), one tensor per work item — again bit-identical to the
//!   serial walk because every tensor is updated by exactly one worker
//!   running the serial kernel.
//!
//! Planning is **group-aware**: each optimizer derives its `TensorGeom`s
//! from the resolved per-tensor policies (`optim::group`), so a tensor
//! whose group forces dense state plans with the dense-kernel geometry,
//! and stateless/frozen tensors carry a reduced `cost_per_elem` — the
//! LPT packing balances the *effective* per-group work, not a uniform
//! estimate. Policy changes never alter item boundaries for unaffected
//! tensors of the same geometry, preserving the bit-reproducibility
//! guarantees above.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::ops::Range;

/// Intra-tensor splitting threshold, in weighted-cost units
/// (`elements * cost_per_elem`). Tensors cheaper than this stay whole;
/// costlier tensors are chopped into roughly `cost / SPLIT_UNIT_COST`
/// row ranges. Independent of the thread count by design (see module
/// docs: plan items must not change when only `threads` changes).
pub const SPLIT_UNIT_COST: u64 = 1 << 23;

/// The update-loop geometry of one tensor, as seen by the planner.
#[derive(Clone, Copy, Debug)]
pub struct TensorGeom {
    /// Number of divisible rows of the update view. `1` marks the tensor
    /// unsplittable (whole-tensor kernels with cross-element reductions).
    pub rows: usize,
    /// Elements per row.
    pub cols: usize,
    /// Row-boundary alignment: intra-tensor splits only occur at row
    /// indices that are multiples of this (e.g. SMMF's 1-bit sign matrix
    /// requires splits on 64-bit word edges).
    pub align: usize,
    /// Relative per-element cost weight (FLOP estimate) used for balance.
    pub cost_per_elem: u64,
}

impl TensorGeom {
    /// Unsplittable whole-tensor unit of `numel` elements.
    pub fn whole(numel: usize, cost_per_elem: u64) -> TensorGeom {
        TensorGeom { rows: 1, cols: numel.max(1), align: 1, cost_per_elem }
    }

    /// Elementwise unit: splittable anywhere (16-element granularity to
    /// keep sub-slices cache-line friendly).
    pub fn elementwise(numel: usize, cost_per_elem: u64) -> TensorGeom {
        TensorGeom { rows: numel.max(1), cols: 1, align: 16, cost_per_elem }
    }

    fn cost(&self) -> u64 {
        (self.rows.max(1) * self.cols.max(1)) as u64 * self.cost_per_elem.max(1)
    }
}

/// One contiguous row range `[row0, row1)` of one tensor's update view,
/// assigned to a shard.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WorkItem {
    pub tensor: usize,
    pub row0: usize,
    pub row1: usize,
    /// Which shard (worker) executes this item.
    pub shard: usize,
}

/// A static, balanced partition of the parameter inventory.
///
/// Invariants (checked by the property tests below):
/// * per tensor, the items tile `[0, rows)` exactly once — every element
///   of the inventory is covered by exactly one item;
/// * interior item boundaries are multiples of the tensor's `align`;
/// * item boundaries depend only on the geometry, never on `threads`.
#[derive(Clone, Debug)]
pub struct ParamPartition {
    n_shards: usize,
    /// All items, sorted by `(tensor, row0)`.
    items: Vec<WorkItem>,
    /// `items` index range of each tensor.
    tensor_ranges: Vec<Range<usize>>,
    /// Per-item cost (same order as `items`).
    costs: Vec<u64>,
}

impl ParamPartition {
    /// Bin the inventory into at most `threads` balanced shards.
    pub fn plan(geoms: &[TensorGeom], threads: usize) -> ParamPartition {
        let threads = threads.max(1);
        let mut items = Vec::new();
        let mut costs = Vec::new();
        let mut tensor_ranges = Vec::with_capacity(geoms.len());
        for (k, g) in geoms.iter().enumerate() {
            let start = items.len();
            let rows = g.rows.max(1);
            let cols = g.cols.max(1);
            let align = g.align.max(1);
            let cpe = g.cost_per_elem.max(1);
            // How many chunks this tensor wants, by cost. threads == 1
            // never splits, so the serial path sees one item per tensor.
            let want = if threads == 1 { 1 } else { g.cost().div_ceil(SPLIT_UNIT_COST) as usize };
            let chunks = want.clamp(1, rows.div_ceil(align));
            let chunk_rows = rows.div_ceil(chunks).next_multiple_of(align);
            let mut r0 = 0usize;
            while r0 < rows {
                let r1 = (r0 + chunk_rows).min(rows);
                items.push(WorkItem { tensor: k, row0: r0, row1: r1, shard: 0 });
                costs.push(((r1 - r0) * cols) as u64 * cpe);
                r0 = r1;
            }
            tensor_ranges.push(start..items.len());
        }

        // LPT greedy: heaviest item first onto the least-loaded shard.
        // Deterministic: stable sort (ties keep (tensor, row0) order) and
        // the heap breaks load ties by the lowest shard index.
        let mut order: Vec<usize> = (0..items.len()).collect();
        order.sort_by_key(|&i| Reverse(costs[i]));
        let mut heap: BinaryHeap<Reverse<(u64, usize)>> =
            (0..threads).map(|s| Reverse((0u64, s))).collect();
        for &i in &order {
            let Reverse((load, shard)) = heap.pop().expect("non-empty heap");
            items[i].shard = shard;
            heap.push(Reverse((load + costs[i], shard)));
        }

        ParamPartition { n_shards: threads, items, tensor_ranges, costs }
    }

    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    pub fn n_items(&self) -> usize {
        self.items.len()
    }

    /// Number of tensors the plan covers (one entry per registered
    /// parameter shape — used by stateless-per-tensor optimizers like
    /// momentum-free SGD to recover the inventory size).
    pub fn n_tensors(&self) -> usize {
        self.tensor_ranges.len()
    }

    /// All items, sorted by `(tensor, row0)`.
    pub fn items(&self) -> &[WorkItem] {
        &self.items
    }

    /// The items covering one tensor, sorted by `row0`.
    pub fn items_of(&self, tensor: usize) -> &[WorkItem] {
        &self.items[self.tensor_ranges[tensor].clone()]
    }

    /// Total planned cost per shard (for balance diagnostics).
    pub fn shard_costs(&self) -> Vec<u64> {
        let mut loads = vec![0u64; self.n_shards];
        for (it, &c) in self.items.iter().zip(&self.costs) {
            loads[it.shard] += c;
        }
        loads
    }
}

/// One worker's slice of the step: a per-shard context (e.g. reusable
/// scratch buffers) plus the tasks assigned to it.
pub struct Shard<C, T> {
    pub ctx: C,
    pub tasks: Vec<T>,
}

/// Distribute per-item tasks (built in `plan.items()` order) onto shards.
/// `ctxs` supplies one context per shard.
pub fn into_shards<C, T>(plan: &ParamPartition, ctxs: Vec<C>, tasks: Vec<T>) -> Vec<Shard<C, T>> {
    assert_eq!(ctxs.len(), plan.n_shards(), "one context per shard");
    assert_eq!(tasks.len(), plan.n_items(), "one task per work item");
    let mut shards: Vec<Shard<C, T>> =
        ctxs.into_iter().map(|ctx| Shard { ctx, tasks: Vec::new() }).collect();
    for (item, task) in plan.items().iter().zip(tasks) {
        shards[item.shard].tasks.push(task);
    }
    shards
}

/// Execute all shards, one scoped worker thread per non-empty shard (the
/// calling thread doubles as the first worker). `f` must be a stateless
/// kernel over `(shard context, task)`; borrows inside tasks are fine —
/// the scope guarantees they outlive the workers.
pub fn run_shards<C, T, F>(shards: &mut [Shard<C, T>], f: F)
where
    C: Send,
    T: Send,
    F: Fn(&mut C, &mut T) + Sync,
{
    let busy = shards.iter().filter(|s| !s.tasks.is_empty()).count();
    if busy <= 1 {
        for sh in shards.iter_mut().filter(|s| !s.tasks.is_empty()) {
            let _span = crate::obs::trace::span("optim", "optim.shard");
            for t in &mut sh.tasks {
                f(&mut sh.ctx, t);
            }
        }
        return;
    }
    std::thread::scope(|scope| {
        let mut iter = shards.iter_mut().filter(|s| !s.tasks.is_empty());
        let first = iter.next().expect("busy >= 1");
        for sh in iter {
            let f = &f;
            scope.spawn(move || {
                // Worker-thread side: each shard's whole task walk is
                // one span, recorded on the worker's own ring.
                let _span = crate::obs::trace::span("optim", "optim.shard");
                for t in &mut sh.tasks {
                    f(&mut sh.ctx, t);
                }
            });
        }
        let _span = crate::obs::trace::span("optim", "optim.shard");
        for t in &mut first.tasks {
            f(&mut first.ctx, t);
        }
    });
}

/// Tensor-granular dispatch for optimizers whose update has whole-tensor
/// reductions ([`TensorGeom::whole`] plans: one work item per tensor).
/// Each tensor is updated by exactly one worker running `kernel` over
/// `(shard context, param slice, grad slice, per-tensor state)` — bit-
/// identical to the serial walk at any thread count. Shared by
/// Adafactor, CAME and SM3 so the shard plumbing lives once.
pub fn run_per_tensor<S, C, F>(
    plan: &ParamPartition,
    params: &mut [crate::tensor::Tensor],
    grads: &[crate::tensor::Tensor],
    states: &mut [S],
    ctxs: Vec<C>,
    kernel: F,
) where
    S: Send,
    C: Send,
    F: Fn(&mut C, &mut [f32], &[f32], &mut S) + Sync,
{
    let tasks: Vec<(&mut [f32], &[f32], &mut S)> = params
        .iter_mut()
        .zip(grads)
        .zip(states.iter_mut())
        .map(|((p, g), st)| (p.data_mut(), g.data(), st))
        .collect();
    let mut shards = into_shards(plan, ctxs, tasks);
    run_shards(&mut shards, |ctx, (p, g, st)| kernel(ctx, p, g, st));
}

/// Split `data` into one mutable sub-slice per work item of a tensor
/// (`cols` elements per row). Items tile the tensor's rows, so the
/// sub-slices tile `data` — the borrow checker enforces disjointness.
pub fn split_rows_mut<'a, T>(
    mut data: &'a mut [T],
    items: &[WorkItem],
    cols: usize,
) -> Vec<&'a mut [T]> {
    let mut out = Vec::with_capacity(items.len());
    for it in items {
        let len = (it.row1 - it.row0) * cols;
        let (head, rest) = data.split_at_mut(len);
        out.push(head);
        data = rest;
    }
    debug_assert!(data.is_empty(), "items must tile the tensor");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn check_cover(plan: &ParamPartition, geoms: &[TensorGeom]) {
        assert_eq!(plan.tensor_ranges.len(), geoms.len());
        for (k, g) in geoms.iter().enumerate() {
            let items = plan.items_of(k);
            assert!(!items.is_empty(), "tensor {k} has no items");
            let mut expect = 0usize;
            for it in items {
                assert_eq!(it.tensor, k);
                assert_eq!(it.row0, expect, "gap/overlap in tensor {k}");
                assert!(it.row1 > it.row0, "empty item in tensor {k}");
                if it.row0 != 0 {
                    assert_eq!(it.row0 % g.align.max(1), 0, "misaligned split in tensor {k}");
                }
                assert!(it.shard < plan.n_shards());
                expect = it.row1;
            }
            assert_eq!(expect, g.rows.max(1), "tensor {k} not fully covered");
        }
        // Global view: every (tensor, row) exactly once.
        let total_items: usize = (0..geoms.len()).map(|k| plan.items_of(k).len()).sum();
        assert_eq!(total_items, plan.n_items());
    }

    #[test]
    fn covers_adversarial_inventory_exactly_once() {
        // 1-element biases next to 2048x2048 matrices, odd primes, and an
        // aligned factored view — the shapes the issue calls out.
        let geoms = vec![
            TensorGeom { rows: 1, cols: 1, align: 1, cost_per_elem: 8 },
            TensorGeom { rows: 2048, cols: 2048, align: 32, cost_per_elem: 8 },
            TensorGeom { rows: 2048, cols: 2048, align: 1, cost_per_elem: 8 },
            TensorGeom { rows: 5087, cols: 4608, align: 64, cost_per_elem: 8 },
            TensorGeom { rows: 17, cols: 1, align: 16, cost_per_elem: 1 },
            TensorGeom::whole(123_457, 6),
            TensorGeom::elementwise(3_500_000, 2),
            TensorGeom::elementwise(1, 1),
        ];
        for threads in [1, 2, 3, 4, 8, 19] {
            let plan = ParamPartition::plan(&geoms, threads);
            assert_eq!(plan.n_shards(), threads);
            check_cover(&plan, &geoms);
        }
    }

    #[test]
    fn prop_random_inventories_cover_exactly_once() {
        prop::cases(60, |rng| {
            let n = 1 + rng.below(12);
            let geoms: Vec<TensorGeom> = (0..n)
                .map(|_| TensorGeom {
                    rows: 1 + rng.below(5000),
                    cols: 1 + rng.below(3000),
                    align: [1, 2, 8, 16, 64][rng.below(5)],
                    cost_per_elem: 1 + rng.below(9) as u64,
                })
                .collect();
            let threads = 1 + rng.below(9);
            let plan = ParamPartition::plan(&geoms, threads);
            check_cover(&plan, &geoms);
        });
    }

    #[test]
    fn item_boundaries_do_not_depend_on_thread_count() {
        // Only the shard assignment may change with `threads` — the item
        // boundaries must be identical so results are bit-reproducible
        // across thread counts (see module docs).
        let geoms = vec![
            TensorGeom { rows: 4096, cols: 1024, align: 8, cost_per_elem: 8 },
            TensorGeom::elementwise(1_000_000, 1),
            TensorGeom::whole(999, 4),
        ];
        let strip = |p: &ParamPartition| -> Vec<(usize, usize, usize)> {
            p.items().iter().map(|i| (i.tensor, i.row0, i.row1)).collect()
        };
        let p2 = ParamPartition::plan(&geoms, 2);
        let p4 = ParamPartition::plan(&geoms, 4);
        let p8 = ParamPartition::plan(&geoms, 8);
        assert_eq!(strip(&p2), strip(&p4));
        assert_eq!(strip(&p4), strip(&p8));
        // ...and planning is deterministic run-to-run, shard included.
        assert_eq!(ParamPartition::plan(&geoms, 4).items(), p4.items());
    }

    #[test]
    fn big_tensors_split_and_loads_balance() {
        // One dominant tensor: without intra-tensor splitting the best
        // possible 4-shard balance would put its whole cost on one shard.
        let geoms = vec![
            TensorGeom { rows: 8192, cols: 4096, align: 64, cost_per_elem: 8 },
            TensorGeom::elementwise(100, 1),
        ];
        let plan = ParamPartition::plan(&geoms, 4);
        assert!(plan.items_of(0).len() >= 4, "dominant tensor must split");
        let loads = plan.shard_costs();
        let (min, max) = (loads.iter().min().unwrap(), loads.iter().max().unwrap());
        assert!(*max as f64 <= *min as f64 * 1.5 + SPLIT_UNIT_COST as f64, "{loads:?}");
    }

    #[test]
    fn unsplittable_tensors_stay_whole() {
        let geoms = vec![TensorGeom::whole(50_000_000, 10)];
        let plan = ParamPartition::plan(&geoms, 8);
        assert_eq!(plan.n_items(), 1);
        assert_eq!(plan.items()[0].row1, 1);
    }

    #[test]
    fn run_shards_executes_every_task_once() {
        use std::sync::atomic::{AtomicU32, Ordering};
        let geoms = vec![TensorGeom::elementwise(100_000, 1); 7];
        let plan = ParamPartition::plan(&geoms, 4);
        let hits: Vec<AtomicU32> = (0..plan.n_items()).map(|_| AtomicU32::new(0)).collect();
        let tasks: Vec<usize> = (0..plan.n_items()).collect();
        let mut shards = into_shards(&plan, vec![(); plan.n_shards()], tasks);
        run_shards(&mut shards, |_, &mut i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 1, "task {i}");
        }
    }

    #[test]
    fn split_rows_mut_tiles() {
        let geoms = vec![TensorGeom { rows: 10, cols: 3, align: 4, cost_per_elem: 1 }];
        // Force splits regardless of cost by planning through a fake
        // heavy geometry with identical rows/align.
        let heavy = vec![TensorGeom { rows: 10, cols: 3, align: 4, cost_per_elem: SPLIT_UNIT_COST }];
        let plan = ParamPartition::plan(&heavy, 4);
        check_cover(&plan, &geoms);
        let mut data: Vec<u32> = (0..30).collect();
        let parts = split_rows_mut(&mut data, plan.items_of(0), 3);
        let flat: Vec<u32> = parts.iter().flat_map(|p| p.iter().copied()).collect();
        assert_eq!(flat, (0..30).collect::<Vec<u32>>());
    }
}
