//! Adam / AdamW baseline (Kingma & Ba 2014; Loshchilov & Hutter 2019).
//!
//! Dense 1st + 2nd moments: `2N` floats of state — the memory baseline all
//! the paper's tables compare against. Bias correction is optional (the
//! paper disables it for Transformer pre-training, Table 3).

use super::{OptimConfig, Optimizer, WeightDecayMode};
use crate::tensor::Tensor;

pub struct Adam {
    cfg: OptimConfig,
    decoupled: bool, // AdamW
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    t: u64,
}

impl Adam {
    pub fn new(shapes: &[Vec<usize>], cfg: &OptimConfig, decoupled: bool) -> Adam {
        let m = shapes.iter().map(|s| vec![0.0; s.iter().product()]).collect();
        let v = shapes.iter().map(|s| vec![0.0; s.iter().product()]).collect();
        Adam { cfg: cfg.clone(), decoupled, m, v, t: 0 }
    }
}

impl Optimizer for Adam {
    fn name(&self) -> &'static str {
        if self.decoupled {
            "adamw"
        } else {
            "adam"
        }
    }

    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor]) {
        self.t += 1;
        let c = &self.cfg;
        let (b1, b2) = (c.beta1, c.beta2);
        // Bias-correction folded into a step-size scale.
        let lr_t = if c.bias_correction {
            let bc1 = 1.0 - b1.powi(self.t as i32);
            let bc2 = 1.0 - b2.powi(self.t as i32);
            c.lr * bc2.sqrt() / bc1
        } else {
            c.lr
        };
        for ((param, grad), (m, v)) in
            params.iter_mut().zip(grads).zip(self.m.iter_mut().zip(self.v.iter_mut()))
        {
            let p = param.data_mut();
            let g = grad.data();
            let wd = c.weight_decay;
            if wd != 0.0 && self.decoupled {
                let f = 1.0 - c.lr * wd;
                p.iter_mut().for_each(|w| *w *= f);
            }
            let couple = wd != 0.0 && !self.decoupled && c.weight_decay_mode == WeightDecayMode::Adam;
            for (((w, &g0), mij), vij) in p.iter_mut().zip(g).zip(m.iter_mut()).zip(v.iter_mut()) {
                let gij = if couple { g0 + wd * *w } else { g0 };
                *mij = b1 * *mij + (1.0 - b1) * gij;
                *vij = b2 * *vij + (1.0 - b2) * gij * gij;
                *w -= lr_t * *mij / (vij.sqrt() + c.eps1);
            }
        }
    }

    fn set_lr(&mut self, lr: f32) {
        self.cfg.lr = lr;
    }

    fn state_bytes(&self) -> u64 {
        self.m.iter().chain(&self.v).map(|x| (x.len() * 4) as u64).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn state_is_two_n_floats() {
        let adam = Adam::new(&[vec![10, 10], vec![7]], &OptimConfig::default(), false);
        assert_eq!(adam.state_bytes(), (2 * 107 * 4) as u64);
    }

    #[test]
    fn quadratic_convergence() {
        let mut opt = Adam::new(&[vec![4]], &OptimConfig { lr: 0.1, ..Default::default() }, false);
        let mut p = vec![Tensor::from_vec(&[4], vec![5.0, -3.0, 2.0, 1.0])];
        for _ in 0..300 {
            let g = {
                let mut g = p[0].clone();
                g.scale(2.0);
                vec![g]
            };
            opt.step(&mut p, &g);
        }
        assert!(p[0].max_abs() < 0.05, "{:?}", p[0].data());
    }

    #[test]
    fn adamw_decays_params_without_touching_moments() {
        let cfg = OptimConfig { lr: 0.1, weight_decay: 0.5, ..Default::default() };
        let mut opt = Adam::new(&[vec![1]], &cfg, true);
        let mut p = vec![Tensor::from_vec(&[1], vec![1.0])];
        let g = vec![Tensor::from_vec(&[1], vec![0.0])];
        opt.step(&mut p, &g);
        // zero grad: only the decoupled decay acts: 1 * (1 - 0.1*0.5)
        assert!((p[0].data()[0] - 0.95).abs() < 1e-6);
    }

    #[test]
    fn bias_correction_scales_first_step() {
        // With bias correction the first step is ~lr regardless of beta.
        let cfg = OptimConfig { lr: 0.1, bias_correction: true, ..Default::default() };
        let mut opt = Adam::new(&[vec![1]], &cfg, false);
        let mut p = vec![Tensor::from_vec(&[1], vec![0.0])];
        let g = vec![Tensor::from_vec(&[1], vec![1.0])];
        opt.step(&mut p, &g);
        assert!((p[0].data()[0] + 0.1).abs() < 1e-3, "{}", p[0].data()[0]);
    }
}
