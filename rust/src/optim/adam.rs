//! Adam / AdamW baseline (Kingma & Ba 2014; Loshchilov & Hutter 2019).
//!
//! Dense 1st + 2nd moments: `2N` floats of state — the memory baseline all
//! the paper's tables compare against. Bias correction is optional (the
//! paper disables it for Transformer pre-training, Table 3).
//!
//! With `OptimConfig::threads > 1` the update dispatches over the
//! [`super::parallel`] engine: the update is purely elementwise, so flat
//! element-range splitting is bit-identical to the serial walk at any
//! thread count.

use anyhow::{bail, Result};

use super::blob::{BlobReader, BlobWriter};
use super::group::{self, StatePolicy, TensorPolicy};
use super::parallel::{self, ParamPartition, TensorGeom};
use super::{OptimConfig, Optimizer, StateSerde, WeightDecayMode};
use crate::tensor::Tensor;

pub struct Adam {
    cfg: OptimConfig,
    decoupled: bool, // AdamW
    /// Effective per-tensor policy (lr scale, weight decay, frozen,
    /// state) resolved from the group table; `m`/`v` are empty for
    /// stateless (`StatePolicy::None`) and frozen tensors.
    policies: Vec<TensorPolicy>,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    t: u64,
    plan: ParamPartition,
}

impl Adam {
    pub fn new(shapes: &[Vec<usize>], cfg: &OptimConfig, decoupled: bool) -> Adam {
        Self::with_policies(shapes, cfg, decoupled, &vec![TensorPolicy::uniform(cfg); shapes.len()])
    }

    pub fn with_policies(
        shapes: &[Vec<usize>],
        cfg: &OptimConfig,
        decoupled: bool,
        policies: &[TensorPolicy],
    ) -> Adam {
        assert_eq!(shapes.len(), policies.len());
        let state_len = |s: &Vec<usize>, pol: &TensorPolicy| -> usize {
            if pol.stateless() {
                0
            } else {
                s.iter().product()
            }
        };
        let m: Vec<Vec<f32>> =
            shapes.iter().zip(policies).map(|(s, p)| vec![0.0; state_len(s, p)]).collect();
        let v: Vec<Vec<f32>> =
            shapes.iter().zip(policies).map(|(s, p)| vec![0.0; state_len(s, p)]).collect();
        let geoms: Vec<TensorGeom> = shapes
            .iter()
            .zip(policies)
            .map(|(s, p)| {
                // Group-aware planning: stateless/frozen tensors cost a
                // fraction of a full moment update.
                TensorGeom::elementwise(s.iter().product(), if p.stateless() { 1 } else { 2 })
            })
            .collect();
        let plan = ParamPartition::plan(&geoms, cfg.threads);
        Adam { cfg: cfg.clone(), decoupled, policies: policies.to_vec(), m, v, t: 0, plan }
    }

    /// The per-chunk elementwise kernel (`Send` + stateless): identical
    /// arithmetic whether the chunk is a whole tensor (serial path) or a
    /// planned sub-range (parallel path). `lr` is the group-effective
    /// base LR (drives decoupled decay), `lr_t` the bias-corrected step
    /// size, `wd` the group-effective weight decay.
    #[allow(clippy::too_many_arguments)]
    fn update_chunk(
        cfg: &OptimConfig,
        decoupled: bool,
        lr: f32,
        lr_t: f32,
        wd: f32,
        p: &mut [f32],
        g: &[f32],
        m: &mut [f32],
        v: &mut [f32],
    ) {
        let (b1, b2) = (cfg.beta1, cfg.beta2);
        if wd != 0.0 && decoupled {
            let f = 1.0 - lr * wd;
            p.iter_mut().for_each(|w| *w *= f);
        }
        let couple = wd != 0.0 && !decoupled && cfg.weight_decay_mode == WeightDecayMode::Adam;
        for (((w, &g0), mij), vij) in p.iter_mut().zip(g).zip(m.iter_mut()).zip(v.iter_mut()) {
            let gij = if couple { g0 + wd * *w } else { g0 };
            *mij = b1 * *mij + (1.0 - b1) * gij;
            *vij = b2 * *vij + (1.0 - b2) * gij * gij;
            *w -= lr_t * *mij / (vij.sqrt() + cfg.eps1);
        }
    }

    /// Weight-decay behavior for a `StatePolicy::None` tensor, mirroring
    /// exactly what [`Adam::update_chunk`] does for the same (kind,
    /// mode): AdamW decays decoupled, plain Adam couples only under
    /// `WeightDecayMode::Adam` and otherwise applies no decay at all —
    /// so stateless tensors never decay when their stateful siblings
    /// would not.
    fn stateless_decay(decoupled: bool, mode: WeightDecayMode, wd: f32) -> (f32, WeightDecayMode) {
        if decoupled {
            (wd, WeightDecayMode::AdamW)
        } else if mode == WeightDecayMode::Adam {
            (wd, WeightDecayMode::Adam)
        } else {
            (0.0, WeightDecayMode::AdamW)
        }
    }

    /// Bias-corrected step size for a group-effective base LR, matching
    /// the pre-group arithmetic exactly (`lr * sqrt(bc2) / bc1`).
    fn lr_t(&self, lr_eff: f32) -> f32 {
        if self.cfg.bias_correction {
            let bc1 = 1.0 - self.cfg.beta1.powi(self.t as i32);
            let bc2 = 1.0 - self.cfg.beta2.powi(self.t as i32);
            lr_eff * bc2.sqrt() / bc1
        } else {
            lr_eff
        }
    }
}

impl StateSerde for Adam {
    fn opt_step(&self) -> u64 {
        self.t
    }

    fn set_opt_step(&mut self, t: u64) {
        self.t = t;
    }

    /// Blob (docs/CHECKPOINT_FORMAT.md, kind tags 2/3): `u64 len`, then
    /// the dense first and second moments as f32.
    fn state_blob(&self, i: usize) -> Vec<u8> {
        let (m, v) = (&self.m[i], &self.v[i]);
        let mut w = BlobWriter::new();
        w.u64(m.len() as u64);
        w.f32s(m);
        w.f32s(v);
        w.finish()
    }

    fn state_blobs(&self) -> Vec<Vec<u8>> {
        (0..self.m.len()).map(|i| self.state_blob(i)).collect()
    }

    fn load_state_blobs(&mut self, blobs: &[Vec<u8>]) -> Result<()> {
        if blobs.len() != self.m.len() {
            bail!(
                "{}: checkpoint has {} tensors, optimizer has {}",
                self.name(),
                blobs.len(),
                self.m.len()
            );
        }
        for (idx, blob) in blobs.iter().enumerate() {
            let mut r = BlobReader::new(blob);
            r.expect_len(self.m[idx].len(), &format!("adam tensor {idx} moments"))?;
            r.f32s_into(&mut self.m[idx])?;
            r.f32s_into(&mut self.v[idx])?;
            r.finish()?;
        }
        Ok(())
    }
}

impl Optimizer for Adam {
    fn name(&self) -> &'static str {
        if self.decoupled {
            "adamw"
        } else {
            "adam"
        }
    }

    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor]) {
        self.t += 1;
        let decoupled = self.decoupled;
        if self.cfg.threads <= 1 {
            let cfg = self.cfg.clone();
            let lr_ts: Vec<f32> =
                self.policies.iter().map(|pol| self.lr_t(cfg.lr * pol.lr_scale)).collect();
            for (idx, ((param, grad), (m, v))) in params
                .iter_mut()
                .zip(grads)
                .zip(self.m.iter_mut().zip(self.v.iter_mut()))
                .enumerate()
            {
                let pol = self.policies[idx];
                if pol.frozen {
                    continue;
                }
                let lr_eff = cfg.lr * pol.lr_scale;
                if pol.state == StatePolicy::None {
                    let (wd, mode) = Self::stateless_decay(
                        decoupled,
                        cfg.weight_decay_mode,
                        pol.weight_decay,
                    );
                    group::stateless_update(param.data_mut(), grad.data(), lr_eff, wd, mode);
                    continue;
                }
                let lr_t = lr_ts[idx];
                Self::update_chunk(
                    &cfg,
                    decoupled,
                    lr_eff,
                    lr_t,
                    pol.weight_decay,
                    param.data_mut(),
                    grad.data(),
                    m,
                    v,
                );
            }
            return;
        }

        struct Task<'a> {
            p: &'a mut [f32],
            g: &'a [f32],
            /// `(m, v)` sub-ranges; `None` for stateless/frozen tensors.
            state: Option<(&'a mut [f32], &'a mut [f32])>,
            lr: f32,
            lr_t: f32,
            wd: f32,
            frozen: bool,
        }
        let cfg = self.cfg.clone();
        let lr_ts: Vec<f32> =
            self.policies.iter().map(|pol| self.lr_t(cfg.lr * pol.lr_scale)).collect();
        let plan = &self.plan;
        let mut tasks: Vec<Task<'_>> = Vec::with_capacity(plan.n_items());
        for (idx, ((param, grad), (m, v))) in params
            .iter_mut()
            .zip(grads)
            .zip(self.m.iter_mut().zip(self.v.iter_mut()))
            .enumerate()
        {
            let pol = self.policies[idx];
            let items = plan.items_of(idx);
            let p_parts = parallel::split_rows_mut(param.data_mut(), items, 1);
            let state_parts: Vec<Option<(&mut [f32], &mut [f32])>> = if pol.stateless() {
                items.iter().map(|_| None).collect()
            } else {
                parallel::split_rows_mut(m, items, 1)
                    .into_iter()
                    .zip(parallel::split_rows_mut(v, items, 1))
                    .map(Some)
                    .collect()
            };
            let g = grad.data();
            for ((it, p), st) in items.iter().zip(p_parts).zip(state_parts) {
                tasks.push(Task {
                    p,
                    g: &g[it.row0..it.row1],
                    state: st,
                    lr: cfg.lr * pol.lr_scale,
                    lr_t: lr_ts[idx],
                    wd: pol.weight_decay,
                    frozen: pol.frozen,
                });
            }
        }
        let mut shards = parallel::into_shards(plan, vec![(); plan.n_shards()], tasks);
        parallel::run_shards(&mut shards, |_, t| {
            if t.frozen {
                return;
            }
            match &mut t.state {
                Some((m, v)) => {
                    Self::update_chunk(&cfg, decoupled, t.lr, t.lr_t, t.wd, t.p, t.g, m, v)
                }
                None => {
                    let (wd, mode) =
                        Self::stateless_decay(decoupled, cfg.weight_decay_mode, t.wd);
                    group::stateless_update(t.p, t.g, t.lr, wd, mode);
                }
            }
        });
    }

    fn set_lr(&mut self, lr: f32) {
        self.cfg.lr = lr;
    }

    fn state_bytes(&self) -> u64 {
        self.m.iter().chain(&self.v).map(|x| (x.len() * 4) as u64).sum()
    }

    fn partition(&self) -> Option<&ParamPartition> {
        Some(&self.plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn state_is_two_n_floats() {
        let adam = Adam::new(&[vec![10, 10], vec![7]], &OptimConfig::default(), false);
        assert_eq!(adam.state_bytes(), (2 * 107 * 4) as u64);
    }

    #[test]
    fn quadratic_convergence() {
        let mut opt = Adam::new(&[vec![4]], &OptimConfig { lr: 0.1, ..Default::default() }, false);
        let mut p = vec![Tensor::from_vec(&[4], vec![5.0, -3.0, 2.0, 1.0])];
        for _ in 0..300 {
            let g = {
                let mut g = p[0].clone();
                g.scale(2.0);
                vec![g]
            };
            opt.step(&mut p, &g);
        }
        assert!(p[0].max_abs() < 0.05, "{:?}", p[0].data());
    }

    #[test]
    fn adamw_decays_params_without_touching_moments() {
        let cfg = OptimConfig { lr: 0.1, weight_decay: 0.5, ..Default::default() };
        let mut opt = Adam::new(&[vec![1]], &cfg, true);
        let mut p = vec![Tensor::from_vec(&[1], vec![1.0])];
        let g = vec![Tensor::from_vec(&[1], vec![0.0])];
        opt.step(&mut p, &g);
        // zero grad: only the decoupled decay acts: 1 * (1 - 0.1*0.5)
        assert!((p[0].data()[0] - 0.95).abs() < 1e-6);
    }

    #[test]
    fn bias_correction_scales_first_step() {
        // With bias correction the first step is ~lr regardless of beta.
        let cfg = OptimConfig { lr: 0.1, bias_correction: true, ..Default::default() };
        let mut opt = Adam::new(&[vec![1]], &cfg, false);
        let mut p = vec![Tensor::from_vec(&[1], vec![0.0])];
        let g = vec![Tensor::from_vec(&[1], vec![1.0])];
        opt.step(&mut p, &g);
        assert!((p[0].data()[0] + 0.1).abs() < 1e-3, "{}", p[0].data()[0]);
    }

    #[test]
    fn parallel_is_bit_identical_to_serial() {
        // Elementwise update: any split is exact. Trajectories over a mix
        // of tensor sizes must match bit-for-bit at every thread count.
        use crate::util::rng::Pcg32;
        let shapes = vec![vec![513, 37], vec![1], vec![4096], vec![64, 64]];
        let mut rng = Pcg32::new(5);
        let init: Vec<Tensor> = shapes
            .iter()
            .map(|s| {
                let mut t = Tensor::zeros(s);
                rng.fill_normal(t.data_mut(), 0.5);
                t
            })
            .collect();
        let grads: Vec<Vec<Tensor>> = (0..4)
            .map(|_| {
                shapes
                    .iter()
                    .map(|s| {
                        let mut t = Tensor::zeros(s);
                        rng.fill_normal(t.data_mut(), 0.1);
                        t
                    })
                    .collect()
            })
            .collect();
        let cfg = OptimConfig { lr: 0.01, weight_decay: 0.01, ..Default::default() };
        let run = |threads: usize| -> Vec<Tensor> {
            let mut opt = Adam::new(&shapes, &OptimConfig { threads, ..cfg.clone() }, true);
            let mut p = init.clone();
            for g in &grads {
                opt.step(&mut p, g);
            }
            p
        };
        let serial = run(1);
        assert_eq!(serial, run(2));
        assert_eq!(serial, run(4));
        assert_eq!(serial, run(8));
    }
}
