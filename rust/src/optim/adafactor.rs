//! Adafactor baseline (Shazeer & Stern 2018), Hugging Face conventions.
//!
//! Factored 2nd moment for rank >= 2 tensors: `exp_avg_sq_row` over
//! `shape[:-1]` and `exp_avg_sq_col` over `shape[:-2] + shape[-1:]`. This
//! is the convention the paper's measurements reflect — note that for 1×1
//! convolutions it stores *2N* floats for V (worse than dense Adam), which
//! is exactly why the paper's Table 1 shows Adafactor using more memory
//! than Adam on CNNs.
//!
//! With β1 > 0 a dense 1st moment (N floats) is kept, matching the paper's
//! configs (β1 = 0.9 everywhere).
//!
//! The update clips by RMS over the *whole* tensor, so the parallel path
//! (`OptimConfig::threads > 1`) shards at tensor granularity: each tensor
//! is updated by exactly one worker running the serial kernel with that
//! worker's private scratch — bit-identical to the serial walk.

use anyhow::{bail, Result};

use super::blob::{self, BlobReader, BlobWriter};
use super::group::{self, StatePolicy, TensorPolicy};
use super::parallel::{self, ParamPartition, TensorGeom};
use super::schedule::beta2_t;
use super::{OptimConfig, Optimizer, StateSerde, WeightDecayMode};
use crate::tensor::Tensor;

enum VState {
    Factored { row: Vec<f32>, col: Vec<f32>, last: usize, second: usize, lead: usize },
    Dense(Vec<f32>),
    /// `StatePolicy::None` / frozen: no accumulator at all.
    None,
}

struct PState {
    v: VState,
    m: Option<Vec<f32>>,
    /// Effective group policy for this tensor.
    pol: TensorPolicy,
}

/// Per-worker scratch: the update buffer and the per-row rsqrt(col-factor)
/// buffer (perf: hoisted out of the inner update loop).
#[derive(Default)]
struct Scratch {
    u: Vec<f32>,
    cfac: Vec<f32>,
}

pub struct Adafactor {
    cfg: OptimConfig,
    states: Vec<PState>,
    t: u64,
    plan: ParamPartition,
    /// One scratch per worker shard (index 0 doubles as the serial
    /// path's scratch).
    scratch: Vec<Scratch>,
}

fn rms(x: &[f32]) -> f32 {
    if x.is_empty() {
        return 0.0;
    }
    (x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / x.len() as f64).sqrt() as f32
}

impl Adafactor {
    pub fn new(shapes: &[Vec<usize>], cfg: &OptimConfig) -> Adafactor {
        Self::with_policies(shapes, cfg, &vec![TensorPolicy::uniform(cfg); shapes.len()])
    }

    pub fn with_policies(
        shapes: &[Vec<usize>],
        cfg: &OptimConfig,
        policies: &[TensorPolicy],
    ) -> Adafactor {
        assert_eq!(shapes.len(), policies.len());
        let states = shapes
            .iter()
            .zip(policies)
            .map(|(shape, pol)| {
                let numel: usize = shape.iter().product();
                if pol.stateless() {
                    return PState { v: VState::None, m: None, pol: *pol };
                }
                let v = if pol.state != StatePolicy::Dense && shape.len() >= 2 {
                    let last = shape[shape.len() - 1];
                    let second = shape[shape.len() - 2];
                    let lead: usize = shape[..shape.len() - 2].iter().product();
                    VState::Factored {
                        row: vec![0.0; lead * second],
                        col: vec![0.0; lead * last],
                        last,
                        second,
                        lead,
                    }
                } else {
                    VState::Dense(vec![0.0; numel])
                };
                let m = (cfg.beta1 > 0.0).then(|| vec![0.0; numel]);
                PState { v, m, pol: *pol }
            })
            .collect();
        let geoms: Vec<TensorGeom> = shapes
            .iter()
            .zip(policies)
            .map(|(s, pol)| {
                TensorGeom::whole(s.iter().product(), if pol.stateless() { 1 } else { 6 })
            })
            .collect();
        let plan = ParamPartition::plan(&geoms, cfg.threads);
        let scratch = (0..plan.n_shards()).map(|_| Scratch::default()).collect();
        Adafactor { cfg: cfg.clone(), states, t: 0, plan, scratch }
    }

    /// The whole-tensor kernel (`Send` + stateless over the per-tensor
    /// state and a worker-private scratch).
    fn update_tensor(
        cfg: &OptimConfig,
        t: u64,
        beta2: f32,
        p: &mut [f32],
        g: &[f32],
        st: &mut PState,
        scr: &mut Scratch,
    ) {
        if st.pol.frozen {
            return;
        }
        let alpha = if cfg.relative_step {
            let rel = (1.0f32 / (t as f32).sqrt()).min(1e-2);
            rel * rms(p).max(cfg.eps2)
        } else {
            cfg.lr
        };
        let alpha = alpha * st.pol.lr_scale;
        let wd = st.pol.weight_decay;
        if let VState::None = st.v {
            group::stateless_update(p, g, alpha, wd, cfg.weight_decay_mode);
            return;
        }
        // update = g / sqrt(v̂); factored v̂ via the HF approximation.
        scr.u.clear();
        scr.u.extend_from_slice(g);
        let u = &mut scr.u;
        let cfac = &mut scr.cfac;
        match &mut st.v {
            VState::Factored { row, col, last, second, lead } => {
                let (last, second, lead) = (*last, *second, *lead);
                // v_row[l, s] <- b2 v_row + (1-b2) mean_j (g²+eps1)
                // v_col[l, j] <- b2 v_col + (1-b2) mean_s (g²+eps1)
                // Perf: the column reduction walks rows sequentially
                // (cache-friendly) instead of striding by `last`.
                cfac.resize(last, 0.0);
                for l in 0..lead {
                    let block = &g[l * second * last..(l + 1) * second * last];
                    cfac.iter_mut().for_each(|x| *x = 0.0);
                    for s in 0..second {
                        let r = &block[s * last..(s + 1) * last];
                        let mut sum = 0.0f32;
                        for (acc, &x) in cfac.iter_mut().zip(r) {
                            let sq = x * x + cfg.eps1;
                            sum += sq;
                            *acc += sq;
                        }
                        let idx = l * second + s;
                        row[idx] = beta2 * row[idx] + (1.0 - beta2) * sum / last as f32;
                    }
                    let scale = (1.0 - beta2) / second as f32;
                    for (c, &acc) in col[l * last..(l + 1) * last].iter_mut().zip(cfac.iter()) {
                        *c = beta2 * *c + scale * acc;
                    }
                }
                // approx rsqrt(v̂): u = g * r_factor * c_factor.
                // Perf: hoist the per-column factor out of the s-loop
                // (it was recomputed `second` times) and use
                // sqrt().recip() instead of powf(-0.5).
                cfac.resize(last, 0.0);
                for l in 0..lead {
                    for (cf, &c) in cfac.iter_mut().zip(&col[l * last..(l + 1) * last]) {
                        *cf = c.max(1e-30).sqrt().recip();
                    }
                    let rslice = &row[l * second..(l + 1) * second];
                    let rmean = rslice.iter().sum::<f32>() / second as f32;
                    for s in 0..second {
                        let rfac = (rmean.max(1e-30) / rslice[s].max(1e-30)).sqrt();
                        let urow = &mut u[(l * second + s) * last..(l * second + s + 1) * last];
                        for (uij, &cf) in urow.iter_mut().zip(cfac.iter()) {
                            *uij *= rfac * cf;
                        }
                    }
                }
            }
            VState::Dense(v) => {
                for (vij, &gij) in v.iter_mut().zip(g) {
                    *vij = beta2 * *vij + (1.0 - beta2) * (gij * gij + cfg.eps1);
                }
                for (uij, vij) in u.iter_mut().zip(v.iter()) {
                    *uij /= vij.sqrt().max(1e-30);
                }
            }
            VState::None => unreachable!("handled above"),
        }
        // Clip by RMS(update)/d.
        let denom = (rms(u) / cfg.clip_threshold).max(1.0);
        u.iter_mut().for_each(|x| *x /= denom);
        // 1st moment.
        if let Some(m) = &mut st.m {
            for (mij, &uij) in m.iter_mut().zip(u.iter()) {
                *mij = cfg.beta1 * *mij + (1.0 - cfg.beta1) * uij;
            }
            u.copy_from_slice(m);
        }
        // Weight decay + apply.
        if wd != 0.0 {
            match cfg.weight_decay_mode {
                WeightDecayMode::AdamW => {
                    let f = 1.0 - alpha * wd;
                    p.iter_mut().for_each(|w| *w *= f);
                }
                WeightDecayMode::Adam => {
                    for (uij, &w) in u.iter_mut().zip(p.iter()) {
                        *uij += wd * w;
                    }
                }
            }
        }
        for (w, &uij) in p.iter_mut().zip(u.iter()) {
            *w -= alpha * uij;
        }
    }
}

impl StateSerde for Adafactor {
    fn opt_step(&self) -> u64 {
        self.t
    }

    fn set_opt_step(&mut self, t: u64) {
        self.t = t;
    }

    /// Blob (docs/CHECKPOINT_FORMAT.md, kind tag 4): the native factored
    /// second moment — `exp_avg_sq_row` / `exp_avg_sq_col` accumulators
    /// (Shazeer & Stern 2018) — or the dense fallback for rank-1 tensors,
    /// followed by the optional dense first moment. The factored-or-dense
    /// encoding is shared with CAME ([`blob::write_factored_or_dense`]).
    fn state_blob(&self, i: usize) -> Vec<u8> {
        let st = &self.states[i];
        let mut w = BlobWriter::new();
        match &st.v {
            VState::Factored { row, col, .. } => {
                blob::write_factored_or_dense(&mut w, Some((row.as_slice(), col.as_slice())), &[])
            }
            VState::Dense(v) => blob::write_factored_or_dense(&mut w, None, v),
            // stateless: dense layout with zero elements
            VState::None => blob::write_factored_or_dense(&mut w, None, &[]),
        }
        match &st.m {
            Some(m) => {
                w.u8(1);
                w.len_prefixed_f32s(m);
            }
            None => w.u8(0),
        }
        w.finish()
    }

    fn state_blobs(&self) -> Vec<Vec<u8>> {
        (0..self.states.len()).map(|i| self.state_blob(i)).collect()
    }

    fn load_state_blobs(&mut self, blobs: &[Vec<u8>]) -> Result<()> {
        if blobs.len() != self.states.len() {
            bail!(
                "adafactor: checkpoint has {} tensors, optimizer has {}",
                blobs.len(),
                self.states.len()
            );
        }
        for (idx, (b, st)) in blobs.iter().zip(self.states.iter_mut()).enumerate() {
            let mut r = BlobReader::new(b);
            let what = format!("adafactor tensor {idx} V");
            match &mut st.v {
                VState::Factored { row, col, .. } => blob::read_factored_or_dense(
                    &mut r,
                    Some((&mut row[..], &mut col[..])),
                    &mut [],
                    &what,
                )?,
                VState::Dense(v) => blob::read_factored_or_dense(&mut r, None, v, &what)?,
                VState::None => blob::read_factored_or_dense(&mut r, None, &mut [], &what)?,
            }
            let has_m = r.u8()?;
            match (has_m, &mut st.m) {
                (1, Some(m)) => {
                    r.expect_len(m.len(), &format!("adafactor tensor {idx} momentum"))?;
                    r.f32s_into(m)?;
                }
                (0, None) => {}
                (has, _) => bail!(
                    "adafactor tensor {idx}: momentum mismatch (checkpoint has_m={has}; \
                     β1 > 0 must agree between save and load configs)"
                ),
            }
            r.finish()?;
        }
        Ok(())
    }
}

impl Optimizer for Adafactor {
    fn name(&self) -> &'static str {
        "adafactor"
    }

    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor]) {
        self.t += 1;
        let beta2 = beta2_t(self.cfg.decay_rate, self.t);
        let t = self.t;
        if self.cfg.threads <= 1 {
            let cfg = self.cfg.clone();
            let scr = &mut self.scratch[0];
            for ((param, grad), st) in params.iter_mut().zip(grads).zip(self.states.iter_mut()) {
                Self::update_tensor(&cfg, t, beta2, param.data_mut(), grad.data(), st, scr);
            }
            return;
        }
        let cfg = self.cfg.clone();
        let ctxs: Vec<&mut Scratch> = self.scratch.iter_mut().collect();
        parallel::run_per_tensor(&self.plan, params, grads, &mut self.states, ctxs, |scr, p, g, st| {
            Self::update_tensor(&cfg, t, beta2, p, g, st, scr);
        });
    }

    fn set_lr(&mut self, lr: f32) {
        self.cfg.lr = lr;
        self.cfg.relative_step = false;
    }

    fn state_bytes(&self) -> u64 {
        self.states
            .iter()
            .map(|s| {
                let v = match &s.v {
                    VState::Factored { row, col, .. } => row.len() + col.len(),
                    VState::Dense(v) => v.len(),
                    VState::None => 0,
                };
                ((v + s.m.as_ref().map_or(0, |m| m.len())) * 4) as u64
            })
            .sum()
    }

    fn scratch_bytes(&self) -> u64 {
        self.scratch.iter().map(|s| ((s.u.len() + s.cfac.len()) * 4) as u64).sum()
    }

    fn partition(&self) -> Option<&ParamPartition> {
        Some(&self.plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::OptKind;

    #[test]
    fn factored_memory_rule() {
        // (64, 32): V = 64 + 32 floats; M = 2048 floats.
        let cfg = OptimConfig::paper_defaults(OptKind::Adafactor);
        let a = Adafactor::new(&[vec![64, 32]], &cfg);
        assert_eq!(a.state_bytes(), ((64 + 32 + 64 * 32) * 4) as u64);
        // 1x1 conv (Co, Ci, 1, 1): rows Co*Ci*1 + cols Co*Ci*1 = 2N — the
        // pathology the paper exploits in Table 1.
        let b = Adafactor::new(&[vec![8, 4, 1, 1]], &cfg);
        assert_eq!(b.state_bytes(), ((2 * 32 + 32) * 4) as u64);
    }

    #[test]
    fn quadratic_convergence_fixed_lr() {
        let cfg = OptimConfig {
            lr: 0.05,
            relative_step: false,
            ..OptimConfig::paper_defaults(OptKind::Adafactor)
        };
        let mut opt = Adafactor::new(&[vec![3, 3]], &cfg);
        let mut p = vec![Tensor::from_vec(&[3, 3], (1..=9).map(|i| i as f32 / 3.0).collect())];
        for _ in 0..400 {
            let mut g = p[0].clone();
            g.scale(2.0);
            opt.step(&mut p, &[g]);
        }
        assert!(p[0].max_abs() < 0.1, "{:?}", p[0].data());
    }

    #[test]
    fn relative_step_uses_param_scale() {
        let cfg = OptimConfig {
            relative_step: true,
            ..OptimConfig::paper_defaults(OptKind::Adafactor)
        };
        let mut opt = Adafactor::new(&[vec![4]], &cfg);
        let mut p = vec![Tensor::from_vec(&[4], vec![100.0, 100.0, 100.0, 100.0])];
        let before = p[0].data()[0];
        let g = vec![Tensor::from_vec(&[4], vec![1.0, 1.0, 1.0, 1.0])];
        opt.step(&mut p, &g);
        // alpha = min(1e-2, 1/sqrt(1)) * RMS(p)=100 -> 1.0; first-step
        // momentum dampens the update to (1-beta1)=0.1 of that.
        let delta = before - p[0].data()[0];
        assert!(delta > 0.05 && delta < 0.2, "delta={delta}");
        // A 100x smaller parameter gets a 100x smaller absolute step.
        let mut opt2 = Adafactor::new(&[vec![4]], &cfg);
        let mut p2 = vec![Tensor::from_vec(&[4], vec![1.0, 1.0, 1.0, 1.0])];
        let g2 = vec![Tensor::from_vec(&[4], vec![1.0, 1.0, 1.0, 1.0])];
        opt2.step(&mut p2, &g2);
        let delta2 = 1.0 - p2[0].data()[0];
        assert!((delta / delta2 - 100.0).abs() < 5.0, "ratio={}", delta / delta2);
    }

    #[test]
    fn parallel_is_bit_identical_to_serial() {
        // Tensor-granular sharding: every tensor is updated by exactly
        // one worker running the serial kernel.
        use crate::util::rng::Pcg32;
        let shapes = vec![vec![48, 32], vec![96], vec![4, 8, 1, 1], vec![1]];
        let mut rng = Pcg32::new(23);
        let init: Vec<Tensor> = shapes
            .iter()
            .map(|s| {
                let mut t = Tensor::zeros(s);
                rng.fill_normal(t.data_mut(), 0.5);
                t
            })
            .collect();
        let grads: Vec<Vec<Tensor>> = (0..3)
            .map(|_| {
                shapes
                    .iter()
                    .map(|s| {
                        let mut t = Tensor::zeros(s);
                        rng.fill_normal(t.data_mut(), 0.1);
                        t
                    })
                    .collect()
            })
            .collect();
        let run = |threads: usize| -> Vec<Tensor> {
            let cfg = OptimConfig {
                lr: 0.05,
                relative_step: false,
                weight_decay: 0.01,
                threads,
                ..OptimConfig::paper_defaults(OptKind::Adafactor)
            };
            let mut opt = Adafactor::new(&shapes, &cfg);
            let mut p = init.clone();
            for g in &grads {
                opt.step(&mut p, g);
            }
            p
        };
        let serial = run(1);
        assert_eq!(serial, run(3));
        assert_eq!(serial, run(8));
    }
}
