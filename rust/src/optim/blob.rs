//! Little-endian binary blob encode/decode for optimizer-state
//! serialization ([`super::StateSerde`]) and the checkpoint container
//! (`train::checkpoint`).
//!
//! Writers are infallible appends; readers are strictly bounds-checked —
//! every read validates the remaining length *before* touching the
//! buffer, lengths read from the blob are never used to allocate without
//! an explicit cap or an expected-size check, and [`BlobReader::finish`]
//! rejects trailing garbage. This is what makes loading a truncated or
//! corrupt checkpoint an error instead of a panic or an OOM.

use anyhow::{bail, Result};

/// Append-only little-endian encoder.
#[derive(Default)]
pub struct BlobWriter {
    buf: Vec<u8>,
}

impl BlobWriter {
    pub fn new() -> BlobWriter {
        BlobWriter::default()
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f32s(&mut self, v: &[f32]) {
        self.buf.reserve(v.len() * 4);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    /// `u64` element count followed by the f32 payload.
    pub fn len_prefixed_f32s(&mut self, v: &[f32]) {
        self.u64(v.len() as u64);
        self.f32s(v);
    }

    pub fn bytes(&mut self, v: &[u8]) {
        self.buf.extend_from_slice(v);
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn finish(self) -> Vec<u8> {
        self.buf
    }
}

/// Bounds-checked little-endian decoder over a borrowed byte slice.
pub struct BlobReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> BlobReader<'a> {
    pub fn new(buf: &'a [u8]) -> BlobReader<'a> {
        BlobReader { buf, pos: 0 }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            bail!(
                "truncated: need {n} bytes at offset {}, only {} remain",
                self.pos,
                self.remaining()
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    /// Fill `out` exactly — the caller supplies the expected length
    /// (state buffers are preallocated at optimizer construction, so a
    /// checkpoint can never dictate an allocation size here).
    pub fn f32s_into(&mut self, out: &mut [f32]) -> Result<()> {
        let raw = self.take(out.len() * 4)?;
        for (o, c) in out.iter_mut().zip(raw.chunks_exact(4)) {
            *o = f32::from_le_bytes(c.try_into().unwrap());
        }
        Ok(())
    }

    /// Read a `u64` length prefix and require it to equal `expect`.
    pub fn expect_len(&mut self, expect: usize, what: &str) -> Result<()> {
        let got = self.u64()? as usize;
        if got != expect {
            bail!("{what}: blob has {got} elements, optimizer expects {expect}");
        }
        Ok(())
    }

    pub fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        self.take(n)
    }

    /// Require the blob to be fully consumed (no trailing garbage).
    pub fn finish(self) -> Result<()> {
        if self.pos != self.buf.len() {
            bail!("blob has {} trailing bytes", self.buf.len() - self.pos);
        }
        Ok(())
    }
}

/// Shared factored-or-dense accumulator encoding (Adafactor's V, CAME's
/// V and U — docs/CHECKPOINT_FORMAT.md): `u8` layout tag (1 = factored
/// row/col pair, 0 = dense), then the length-prefixed payload(s). Pass
/// `fact` when the accumulator is factored (dense is then ignored).
pub fn write_factored_or_dense(w: &mut BlobWriter, fact: Option<(&[f32], &[f32])>, dense: &[f32]) {
    match fact {
        Some((row, col)) => {
            w.u8(1);
            w.len_prefixed_f32s(row);
            w.len_prefixed_f32s(col);
        }
        None => {
            w.u8(0);
            w.len_prefixed_f32s(dense);
        }
    }
}

/// Inverse of [`write_factored_or_dense`]: the caller passes the layout
/// its constructed state actually has; a blob with the other layout (or
/// mismatched lengths) is rejected.
pub fn read_factored_or_dense(
    r: &mut BlobReader<'_>,
    fact: Option<(&mut [f32], &mut [f32])>,
    dense: &mut [f32],
    what: &str,
) -> Result<()> {
    let tag = r.u8()?;
    match (tag, fact) {
        (1, Some((row, col))) => {
            r.expect_len(row.len(), &format!("{what} row factor"))?;
            r.f32s_into(row)?;
            r.expect_len(col.len(), &format!("{what} col factor"))?;
            r.f32s_into(col)?;
        }
        (0, None) => {
            r.expect_len(dense.len(), &format!("{what} dense"))?;
            r.f32s_into(dense)?;
        }
        (tag, _) => bail!(
            "{what}: layout mismatch (blob tag {tag}; factored vs dense is decided by tensor rank)"
        ),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factored_or_dense_roundtrip_and_mismatch() {
        let mut w = BlobWriter::new();
        write_factored_or_dense(&mut w, Some((&[1.0, 2.0], &[3.0])), &[]);
        write_factored_or_dense(&mut w, None, &[4.0, 5.0]);
        let blob = w.finish();

        let (mut row, mut col, mut dense) = ([0.0f32; 2], [0.0f32; 1], [0.0f32; 2]);
        let mut r = BlobReader::new(&blob);
        read_factored_or_dense(&mut r, Some((&mut row[..], &mut col[..])), &mut [], "a").unwrap();
        read_factored_or_dense(&mut r, None, &mut dense[..], "b").unwrap();
        r.finish().unwrap();
        assert_eq!((row, col, dense), ([1.0, 2.0], [3.0], [4.0, 5.0]));

        // layout mismatch: factored blob read as dense
        let mut r = BlobReader::new(&blob);
        assert!(read_factored_or_dense(&mut r, None, &mut dense[..], "a").is_err());
    }

    #[test]
    fn roundtrip_all_types() {
        let mut w = BlobWriter::new();
        w.u8(7);
        w.u32(0xdead_beef);
        w.u64(1 << 40);
        w.f32(-1.5);
        w.len_prefixed_f32s(&[1.0, 2.0, 3.0]);
        w.bytes(&[9, 9]);
        let blob = w.finish();

        let mut r = BlobReader::new(&blob);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 0xdead_beef);
        assert_eq!(r.u64().unwrap(), 1 << 40);
        assert_eq!(r.f32().unwrap(), -1.5);
        r.expect_len(3, "vec").unwrap();
        let mut out = [0.0f32; 3];
        r.f32s_into(&mut out).unwrap();
        assert_eq!(out, [1.0, 2.0, 3.0]);
        assert_eq!(r.bytes(2).unwrap(), &[9, 9]);
        r.finish().unwrap();
    }

    #[test]
    fn truncated_reads_error_not_panic() {
        let mut w = BlobWriter::new();
        w.u32(5);
        let blob = w.finish();
        let mut r = BlobReader::new(&blob);
        assert!(r.u64().is_err()); // 4 bytes present, 8 requested
        let mut r = BlobReader::new(&blob[..2]);
        assert!(r.u32().is_err());
        let mut r = BlobReader::new(&[]);
        assert!(r.u8().is_err());
    }

    #[test]
    fn length_mismatch_and_trailing_bytes_error() {
        let mut w = BlobWriter::new();
        w.len_prefixed_f32s(&[1.0]);
        w.u8(0);
        let blob = w.finish();
        let mut r = BlobReader::new(&blob);
        assert!(r.expect_len(2, "vec").is_err());
        let mut r = BlobReader::new(&blob);
        r.expect_len(1, "vec").unwrap();
        let mut out = [0.0f32; 1];
        r.f32s_into(&mut out).unwrap();
        assert!(r.finish().is_err()); // the trailing u8
    }
}
