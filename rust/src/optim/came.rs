//! CAME baseline (Luo et al. 2023): Confidence-guided Adaptive Memory
//! Efficient optimizer.
//!
//! Adafactor's factored 2nd moment plus (a) a dense 1st moment and (b) a
//! *factored instability/confidence* matrix `U = (û − m)²` with its own
//! decay β3, used to rescale the momentum update. State per rank-≥2
//! tensor: `N` (momentum) + rows+cols (V) + rows+cols (U) — for 1×1 convs
//! that is ≈ 5N floats, reproducing CAME's surprisingly *large* CNN
//! memory in the paper's Table 1.
//!
//! Like Adafactor, the update clips by whole-tensor RMS, so the parallel
//! path (`OptimConfig::threads > 1`) shards at tensor granularity — each
//! tensor updated by one worker with private scratch, bit-identical to
//! the serial walk.

use anyhow::{bail, Result};

use super::blob::{self, BlobReader, BlobWriter};
use super::group::{self, StatePolicy, TensorPolicy};
use super::parallel::{self, ParamPartition, TensorGeom};
use super::schedule::beta2_t;
use super::{OptimConfig, Optimizer, StateSerde, WeightDecayMode};
use crate::tensor::Tensor;

struct Factored {
    row: Vec<f32>,
    col: Vec<f32>,
    last: usize,
    second: usize,
    lead: usize,
}

impl Factored {
    fn new(shape: &[usize]) -> Option<Factored> {
        if shape.len() < 2 {
            return None;
        }
        let last = shape[shape.len() - 1];
        let second = shape[shape.len() - 2];
        let lead: usize = shape[..shape.len() - 2].iter().product();
        Some(Factored {
            row: vec![0.0; lead * second],
            col: vec![0.0; lead * last],
            last,
            second,
            lead,
        })
    }

    /// EMA-update the factors with row/col means of `sq` and then scale
    /// `out` by the approximate rsqrt of the reconstructed matrix.
    /// Perf (§Perf): column EMA accumulated row-wise (sequential reads),
    /// per-column rsqrt hoisted out of the s-loop, powf -> sqrt.recip.
    fn update_and_rsqrt(&mut self, sq: &[f32], beta: f32, out: &mut [f32], cfac: &mut Vec<f32>) {
        let (last, second, lead) = (self.last, self.second, self.lead);
        cfac.resize(last, 0.0);
        for l in 0..lead {
            let block = &sq[l * second * last..(l + 1) * second * last];
            let colslice = &mut self.col[l * last..(l + 1) * last];
            cfac.iter_mut().for_each(|x| *x = 0.0);
            for s in 0..second {
                let brow = &block[s * last..(s + 1) * last];
                let mean = brow.iter().sum::<f32>() / last as f32;
                let idx = l * second + s;
                self.row[idx] = beta * self.row[idx] + (1.0 - beta) * mean;
                for (acc, &x) in cfac.iter_mut().zip(brow) {
                    *acc += x;
                }
            }
            let scale = (1.0 - beta) / second as f32;
            for (c, &acc) in colslice.iter_mut().zip(cfac.iter()) {
                *c = beta * *c + scale * acc;
            }
        }
        for l in 0..lead {
            for (cf, &c) in cfac.iter_mut().zip(&self.col[l * last..(l + 1) * last]) {
                *cf = c.max(1e-30).sqrt().recip();
            }
            let rslice = &self.row[l * second..(l + 1) * second];
            let rmean = rslice.iter().sum::<f32>() / second as f32;
            for s in 0..second {
                let rfac = (rmean.max(1e-30) / rslice[s].max(1e-30)).sqrt();
                let orow = &mut out[(l * second + s) * last..(l * second + s + 1) * last];
                for (o, &cf) in orow.iter_mut().zip(cfac.iter()) {
                    *o *= rfac * cf;
                }
            }
        }
    }

    fn len(&self) -> usize {
        self.row.len() + self.col.len()
    }
}

struct PState {
    v: Option<Factored>,
    /// Dense V: rank < 2 tensors or `StatePolicy::Dense` groups.
    v_dense: Vec<f32>,
    u: Option<Factored>,
    u_dense: Vec<f32>,
    /// Dense momentum; empty for stateless/frozen tensors.
    m: Vec<f32>,
    /// Effective group policy for this tensor.
    pol: TensorPolicy,
}

/// Per-worker scratch buffers (perf: no per-step allocs).
#[derive(Default)]
struct Scratch {
    uhat: Vec<f32>,
    sq: Vec<f32>,
    cfac: Vec<f32>,
    inst: Vec<f32>,
    upd: Vec<f32>,
}

impl Scratch {
    fn len(&self) -> usize {
        self.uhat.len() + self.sq.len() + self.cfac.len() + self.inst.len() + self.upd.len()
    }
}

pub struct Came {
    cfg: OptimConfig,
    states: Vec<PState>,
    t: u64,
    plan: ParamPartition,
    /// One scratch per worker shard (index 0 doubles as the serial one).
    scratch: Vec<Scratch>,
}

fn rms(x: &[f32]) -> f32 {
    if x.is_empty() {
        return 0.0;
    }
    (x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / x.len() as f64).sqrt() as f32
}

impl Came {
    pub fn new(shapes: &[Vec<usize>], cfg: &OptimConfig) -> Came {
        Self::with_policies(shapes, cfg, &vec![TensorPolicy::uniform(cfg); shapes.len()])
    }

    pub fn with_policies(
        shapes: &[Vec<usize>],
        cfg: &OptimConfig,
        policies: &[TensorPolicy],
    ) -> Came {
        assert_eq!(shapes.len(), policies.len());
        let states = shapes
            .iter()
            .zip(policies)
            .map(|(shape, pol)| {
                let numel: usize = shape.iter().product();
                if pol.stateless() {
                    return PState {
                        v: None,
                        v_dense: Vec::new(),
                        u: None,
                        u_dense: Vec::new(),
                        m: Vec::new(),
                        pol: *pol,
                    };
                }
                let (v, u) = if pol.state == StatePolicy::Dense {
                    (None, None)
                } else {
                    (Factored::new(shape), Factored::new(shape))
                };
                PState {
                    v_dense: if v.is_none() { vec![0.0; numel] } else { Vec::new() },
                    u_dense: if u.is_none() { vec![0.0; numel] } else { Vec::new() },
                    v,
                    u,
                    m: vec![0.0; numel],
                    pol: *pol,
                }
            })
            .collect();
        let geoms: Vec<TensorGeom> = shapes
            .iter()
            .zip(policies)
            .map(|(s, pol)| {
                TensorGeom::whole(s.iter().product(), if pol.stateless() { 1 } else { 10 })
            })
            .collect();
        let plan = ParamPartition::plan(&geoms, cfg.threads);
        let scratch = (0..plan.n_shards()).map(|_| Scratch::default()).collect();
        Came { cfg: cfg.clone(), states, t: 0, plan, scratch }
    }

    /// The whole-tensor kernel (`Send` + stateless over per-tensor state
    /// and a worker-private scratch).
    fn update_tensor(
        cfg: &OptimConfig,
        beta2: f32,
        p: &mut [f32],
        g: &[f32],
        st: &mut PState,
        scr: &mut Scratch,
    ) {
        if st.pol.frozen {
            return;
        }
        let lr = cfg.lr * st.pol.lr_scale;
        let wd = st.pol.weight_decay;
        if st.pol.stateless() {
            group::stateless_update(p, g, lr, wd, cfg.weight_decay_mode);
            return;
        }
        // û = g / sqrt(V̂ + eps1)
        scr.uhat.clear();
        scr.uhat.extend_from_slice(g);
        let uhat = &mut scr.uhat;
        scr.sq.clear();
        scr.sq.extend(g.iter().map(|&x| x * x + cfg.eps1));
        let sq = &scr.sq;
        match &mut st.v {
            Some(f) => f.update_and_rsqrt(sq, beta2, uhat, &mut scr.cfac),
            None => {
                for (vij, &s) in st.v_dense.iter_mut().zip(sq) {
                    *vij = beta2 * *vij + (1.0 - beta2) * s;
                }
                for (u, vij) in uhat.iter_mut().zip(&st.v_dense) {
                    *u /= vij.sqrt().max(1e-30);
                }
            }
        }
        // clip
        let denom = (rms(uhat) / cfg.clip_threshold).max(1.0);
        uhat.iter_mut().for_each(|x| *x /= denom);
        // m = β1 m + (1-β1) û
        for (mij, &u) in st.m.iter_mut().zip(uhat.iter()) {
            *mij = cfg.beta1 * *mij + (1.0 - cfg.beta1) * u;
        }
        // instability U = (û − m)², factored with β3; confidence-scaled
        // update = m / sqrt(Û + eps2)
        let m = &st.m;
        scr.inst.clear();
        scr.inst.extend(
            uhat.iter().zip(m.iter()).map(|(&u, &mij)| (u - mij) * (u - mij) + cfg.eps2),
        );
        let inst = &scr.inst;
        scr.upd.clear();
        scr.upd.extend_from_slice(m);
        let update = &mut scr.upd;
        match &mut st.u {
            Some(f) => f.update_and_rsqrt(inst, cfg.beta3, update, &mut scr.cfac),
            None => {
                for (uij, &s) in st.u_dense.iter_mut().zip(inst) {
                    *uij = cfg.beta3 * *uij + (1.0 - cfg.beta3) * s;
                }
                for (x, uij) in update.iter_mut().zip(&st.u_dense) {
                    *x /= uij.sqrt().max(1e-30);
                }
            }
        }
        // weight decay + apply
        if wd != 0.0 {
            match cfg.weight_decay_mode {
                WeightDecayMode::AdamW => {
                    let f = 1.0 - lr * wd;
                    p.iter_mut().for_each(|w| *w *= f);
                }
                WeightDecayMode::Adam => {
                    for (x, &w) in update.iter_mut().zip(p.iter()) {
                        *x += wd * w;
                    }
                }
            }
        }
        for (w, &x) in p.iter_mut().zip(update.iter()) {
            *w -= lr * x;
        }
    }
}

impl StateSerde for Came {
    fn opt_step(&self) -> u64 {
        self.t
    }

    fn set_opt_step(&mut self, t: u64) {
        self.t = t;
    }

    /// Blob (docs/CHECKPOINT_FORMAT.md, kind tag 6): the factored second
    /// moment `V`, the factored confidence/instability matrix `U` (CAME's
    /// extra state, Luo et al. 2023), then the dense momentum.
    fn state_blob(&self, i: usize) -> Vec<u8> {
        let st = &self.states[i];
        let mut w = BlobWriter::new();
        blob::write_factored_or_dense(
            &mut w,
            st.v.as_ref().map(|f| (f.row.as_slice(), f.col.as_slice())),
            &st.v_dense,
        );
        blob::write_factored_or_dense(
            &mut w,
            st.u.as_ref().map(|f| (f.row.as_slice(), f.col.as_slice())),
            &st.u_dense,
        );
        w.len_prefixed_f32s(&st.m);
        w.finish()
    }

    fn state_blobs(&self) -> Vec<Vec<u8>> {
        (0..self.states.len()).map(|i| self.state_blob(i)).collect()
    }

    fn load_state_blobs(&mut self, blobs: &[Vec<u8>]) -> Result<()> {
        if blobs.len() != self.states.len() {
            bail!(
                "came: checkpoint has {} tensors, optimizer has {}",
                blobs.len(),
                self.states.len()
            );
        }
        for (idx, (blob, st)) in blobs.iter().zip(self.states.iter_mut()).enumerate() {
            let mut r = BlobReader::new(blob);
            blob::read_factored_or_dense(
                &mut r,
                st.v.as_mut().map(|f| (&mut f.row[..], &mut f.col[..])),
                &mut st.v_dense,
                &format!("came tensor {idx} V"),
            )?;
            blob::read_factored_or_dense(
                &mut r,
                st.u.as_mut().map(|f| (&mut f.row[..], &mut f.col[..])),
                &mut st.u_dense,
                &format!("came tensor {idx} U"),
            )?;
            r.expect_len(st.m.len(), &format!("came tensor {idx} momentum"))?;
            r.f32s_into(&mut st.m)?;
            r.finish()?;
        }
        Ok(())
    }
}

impl Optimizer for Came {
    fn name(&self) -> &'static str {
        "came"
    }

    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor]) {
        self.t += 1;
        let beta2 = beta2_t(self.cfg.decay_rate, self.t);
        if self.cfg.threads <= 1 {
            let cfg = self.cfg.clone();
            let scr = &mut self.scratch[0];
            for ((param, grad), st) in params.iter_mut().zip(grads).zip(self.states.iter_mut()) {
                Self::update_tensor(&cfg, beta2, param.data_mut(), grad.data(), st, scr);
            }
            return;
        }
        let cfg = self.cfg.clone();
        let ctxs: Vec<&mut Scratch> = self.scratch.iter_mut().collect();
        parallel::run_per_tensor(&self.plan, params, grads, &mut self.states, ctxs, |scr, p, g, st| {
            Self::update_tensor(&cfg, beta2, p, g, st, scr);
        });
    }

    fn set_lr(&mut self, lr: f32) {
        self.cfg.lr = lr;
    }

    fn state_bytes(&self) -> u64 {
        self.states
            .iter()
            .map(|s| {
                let v = s.v.as_ref().map_or(s.v_dense.len(), |f| f.len());
                let u = s.u.as_ref().map_or(s.u_dense.len(), |f| f.len());
                ((v + u + s.m.len()) * 4) as u64
            })
            .sum()
    }

    fn scratch_bytes(&self) -> u64 {
        self.scratch.iter().map(|s| (s.len() * 4) as u64).sum()
    }

    fn partition(&self) -> Option<&ParamPartition> {
        Some(&self.plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::OptKind;

    #[test]
    fn memory_rule_matches_paper_pathology() {
        let cfg = OptimConfig::paper_defaults(OptKind::Came);
        // 2D (n, m): N + 2(n + m)
        let a = Came::new(&[vec![64, 32]], &cfg);
        assert_eq!(a.state_bytes(), ((64 * 32 + 2 * (64 + 32)) * 4) as u64);
        // 1×1 conv: N + 2·2N = 5N — CAME's CNN blow-up (paper Table 1).
        let b = Came::new(&[vec![16, 8, 1, 1]], &cfg);
        assert_eq!(b.state_bytes(), ((5 * 128) * 4) as u64);
    }

    #[test]
    fn quadratic_convergence() {
        let cfg = OptimConfig {
            lr: 0.05,
            ..OptimConfig::paper_defaults(OptKind::Came)
        };
        let mut opt = Came::new(&[vec![4, 4]], &cfg);
        let mut p = vec![Tensor::from_vec(&[4, 4], (1..=16).map(|i| i as f32 / 4.0).collect())];
        for _ in 0..500 {
            let mut g = p[0].clone();
            g.scale(2.0);
            opt.step(&mut p, &[g]);
        }
        assert!(p[0].max_abs() < 0.2, "{:?}", p[0].data());
    }

    #[test]
    fn confidence_dampens_unstable_coordinates() {
        // A coordinate whose û flips sign every step has high instability
        // and must receive a smaller effective update than a stable one.
        let cfg = OptimConfig { lr: 1.0, eps2: 1e-6, ..OptimConfig::paper_defaults(OptKind::Came) };
        let mut opt = Came::new(&[vec![1, 2]], &cfg);
        let mut p = vec![Tensor::zeros(&[1, 2])];
        for t in 0..30 {
            let flip = if t % 2 == 0 { 1.0 } else { -1.0 };
            let g = vec![Tensor::from_vec(&[1, 2], vec![1.0, flip])];
            opt.step(&mut p, &g);
        }
        // stable coordinate moved much further
        let d = p[0].data();
        assert!(d[0].abs() > 3.0 * d[1].abs(), "{:?}", d);
    }

    #[test]
    fn parallel_is_bit_identical_to_serial() {
        use crate::util::rng::Pcg32;
        let shapes = vec![vec![24, 16], vec![40], vec![2, 4, 1, 1]];
        let mut rng = Pcg32::new(31);
        let init: Vec<Tensor> = shapes
            .iter()
            .map(|s| {
                let mut t = Tensor::zeros(s);
                rng.fill_normal(t.data_mut(), 0.5);
                t
            })
            .collect();
        let grads: Vec<Vec<Tensor>> = (0..3)
            .map(|_| {
                shapes
                    .iter()
                    .map(|s| {
                        let mut t = Tensor::zeros(s);
                        rng.fill_normal(t.data_mut(), 0.1);
                        t
                    })
                    .collect()
            })
            .collect();
        let run = |threads: usize| -> Vec<Tensor> {
            let cfg = OptimConfig {
                lr: 0.05,
                weight_decay: 0.01,
                threads,
                ..OptimConfig::paper_defaults(OptKind::Came)
            };
            let mut opt = Came::new(&shapes, &cfg);
            let mut p = init.clone();
            for g in &grads {
                opt.step(&mut p, g);
            }
            p
        };
        let serial = run(1);
        assert_eq!(serial, run(2));
        assert_eq!(serial, run(8));
    }
}
