//! CAME baseline (Luo et al. 2023): Confidence-guided Adaptive Memory
//! Efficient optimizer.
//!
//! Adafactor's factored 2nd moment plus (a) a dense 1st moment and (b) a
//! *factored instability/confidence* matrix `U = (û − m)²` with its own
//! decay β3, used to rescale the momentum update. State per rank-≥2
//! tensor: `N` (momentum) + rows+cols (V) + rows+cols (U) — for 1×1 convs
//! that is ≈ 5N floats, reproducing CAME's surprisingly *large* CNN
//! memory in the paper's Table 1.

use super::schedule::beta2_t;
use super::{OptimConfig, Optimizer, WeightDecayMode};
use crate::tensor::Tensor;

struct Factored {
    row: Vec<f32>,
    col: Vec<f32>,
    last: usize,
    second: usize,
    lead: usize,
}

impl Factored {
    fn new(shape: &[usize]) -> Option<Factored> {
        if shape.len() < 2 {
            return None;
        }
        let last = shape[shape.len() - 1];
        let second = shape[shape.len() - 2];
        let lead: usize = shape[..shape.len() - 2].iter().product();
        Some(Factored {
            row: vec![0.0; lead * second],
            col: vec![0.0; lead * last],
            last,
            second,
            lead,
        })
    }

    /// EMA-update the factors with row/col means of `sq` and then scale
    /// `out` by the approximate rsqrt of the reconstructed matrix.
    /// Perf (§Perf): column EMA accumulated row-wise (sequential reads),
    /// per-column rsqrt hoisted out of the s-loop, powf -> sqrt.recip.
    fn update_and_rsqrt(&mut self, sq: &[f32], beta: f32, out: &mut [f32], cfac: &mut Vec<f32>) {
        let (last, second, lead) = (self.last, self.second, self.lead);
        cfac.resize(last, 0.0);
        for l in 0..lead {
            let block = &sq[l * second * last..(l + 1) * second * last];
            let colslice = &mut self.col[l * last..(l + 1) * last];
            cfac.iter_mut().for_each(|x| *x = 0.0);
            for s in 0..second {
                let brow = &block[s * last..(s + 1) * last];
                let mean = brow.iter().sum::<f32>() / last as f32;
                let idx = l * second + s;
                self.row[idx] = beta * self.row[idx] + (1.0 - beta) * mean;
                for (acc, &x) in cfac.iter_mut().zip(brow) {
                    *acc += x;
                }
            }
            let scale = (1.0 - beta) / second as f32;
            for (c, &acc) in colslice.iter_mut().zip(cfac.iter()) {
                *c = beta * *c + scale * acc;
            }
        }
        for l in 0..lead {
            for (cf, &c) in cfac.iter_mut().zip(&self.col[l * last..(l + 1) * last]) {
                *cf = c.max(1e-30).sqrt().recip();
            }
            let rslice = &self.row[l * second..(l + 1) * second];
            let rmean = rslice.iter().sum::<f32>() / second as f32;
            for s in 0..second {
                let rfac = (rmean.max(1e-30) / rslice[s].max(1e-30)).sqrt();
                let orow = &mut out[(l * second + s) * last..(l * second + s + 1) * last];
                for (o, &cf) in orow.iter_mut().zip(cfac.iter()) {
                    *o *= rfac * cf;
                }
            }
        }
    }

    fn len(&self) -> usize {
        self.row.len() + self.col.len()
    }
}

struct PState {
    v: Option<Factored>,
    v_dense: Vec<f32>, // used when rank < 2
    u: Option<Factored>,
    u_dense: Vec<f32>,
    m: Vec<f32>,
}

pub struct Came {
    cfg: OptimConfig,
    states: Vec<PState>,
    t: u64,
    scratch: Vec<f32>,
    scratch2: Vec<f32>,
    /// Reusable per-column factor buffer (perf).
    cfac: Vec<f32>,
    /// Reusable instability / update buffers (perf: no per-step allocs).
    inst: Vec<f32>,
    upd: Vec<f32>,
}

fn rms(x: &[f32]) -> f32 {
    if x.is_empty() {
        return 0.0;
    }
    (x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / x.len() as f64).sqrt() as f32
}

impl Came {
    pub fn new(shapes: &[Vec<usize>], cfg: &OptimConfig) -> Came {
        let states = shapes
            .iter()
            .map(|shape| {
                let numel: usize = shape.iter().product();
                let v = Factored::new(shape);
                let u = Factored::new(shape);
                PState {
                    v_dense: if v.is_none() { vec![0.0; numel] } else { Vec::new() },
                    u_dense: if u.is_none() { vec![0.0; numel] } else { Vec::new() },
                    v,
                    u,
                    m: vec![0.0; numel],
                }
            })
            .collect();
        Came { cfg: cfg.clone(), states, t: 0, scratch: Vec::new(), scratch2: Vec::new(), cfac: Vec::new(), inst: Vec::new(), upd: Vec::new() }
    }
}

impl Optimizer for Came {
    fn name(&self) -> &'static str {
        "came"
    }

    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor]) {
        self.t += 1;
        let cfg = self.cfg.clone();
        let beta2 = beta2_t(cfg.decay_rate, self.t);
        for ((param, grad), st) in params.iter_mut().zip(grads).zip(self.states.iter_mut()) {
            let p = param.data_mut();
            let g = grad.data();
            // û = g / sqrt(V̂ + eps1)
            self.scratch.clear();
            self.scratch.extend_from_slice(g);
            let uhat = &mut self.scratch;
            self.scratch2.clear();
            self.scratch2.extend(g.iter().map(|&x| x * x + cfg.eps1));
            let sq = &self.scratch2;
            match &mut st.v {
                Some(f) => f.update_and_rsqrt(sq, beta2, uhat, &mut self.cfac),
                None => {
                    for (vij, &s) in st.v_dense.iter_mut().zip(sq) {
                        *vij = beta2 * *vij + (1.0 - beta2) * s;
                    }
                    for (u, vij) in uhat.iter_mut().zip(&st.v_dense) {
                        *u /= vij.sqrt().max(1e-30);
                    }
                }
            }
            // clip
            let denom = (rms(uhat) / cfg.clip_threshold).max(1.0);
            uhat.iter_mut().for_each(|x| *x /= denom);
            // m = β1 m + (1-β1) û
            for (mij, &u) in st.m.iter_mut().zip(uhat.iter()) {
                *mij = cfg.beta1 * *mij + (1.0 - cfg.beta1) * u;
            }
            // instability U = (û − m)², factored with β3; confidence-scaled
            // update = m / sqrt(Û + eps2)
            let m = &st.m;
            self.inst.clear();
            self.inst.extend(
                uhat.iter().zip(m.iter()).map(|(&u, &mij)| (u - mij) * (u - mij) + cfg.eps2),
            );
            let inst = &self.inst;
            self.upd.clear();
            self.upd.extend_from_slice(m);
            let update = &mut self.upd;
            match &mut st.u {
                Some(f) => f.update_and_rsqrt(inst, cfg.beta3, update, &mut self.cfac),
                None => {
                    for (uij, &s) in st.u_dense.iter_mut().zip(inst) {
                        *uij = cfg.beta3 * *uij + (1.0 - cfg.beta3) * s;
                    }
                    for (x, uij) in update.iter_mut().zip(&st.u_dense) {
                        *x /= uij.sqrt().max(1e-30);
                    }
                }
            }
            // weight decay + apply
            if cfg.weight_decay != 0.0 {
                match cfg.weight_decay_mode {
                    WeightDecayMode::AdamW => {
                        let f = 1.0 - cfg.lr * cfg.weight_decay;
                        p.iter_mut().for_each(|w| *w *= f);
                    }
                    WeightDecayMode::Adam => {
                        for (x, &w) in update.iter_mut().zip(p.iter()) {
                            *x += cfg.weight_decay * w;
                        }
                    }
                }
            }
            for (w, &x) in p.iter_mut().zip(update.iter()) {
                *w -= cfg.lr * x;
            }
        }
    }

    fn set_lr(&mut self, lr: f32) {
        self.cfg.lr = lr;
    }

    fn state_bytes(&self) -> u64 {
        self.states
            .iter()
            .map(|s| {
                let v = s.v.as_ref().map_or(s.v_dense.len(), |f| f.len());
                let u = s.u.as_ref().map_or(s.u_dense.len(), |f| f.len());
                ((v + u + s.m.len()) * 4) as u64
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::OptKind;

    #[test]
    fn memory_rule_matches_paper_pathology() {
        let cfg = OptimConfig::paper_defaults(OptKind::Came);
        // 2D (n, m): N + 2(n + m)
        let a = Came::new(&[vec![64, 32]], &cfg);
        assert_eq!(a.state_bytes(), ((64 * 32 + 2 * (64 + 32)) * 4) as u64);
        // 1×1 conv: N + 2·2N = 5N — CAME's CNN blow-up (paper Table 1).
        let b = Came::new(&[vec![16, 8, 1, 1]], &cfg);
        assert_eq!(b.state_bytes(), ((5 * 128) * 4) as u64);
    }

    #[test]
    fn quadratic_convergence() {
        let cfg = OptimConfig {
            lr: 0.05,
            ..OptimConfig::paper_defaults(OptKind::Came)
        };
        let mut opt = Came::new(&[vec![4, 4]], &cfg);
        let mut p = vec![Tensor::from_vec(&[4, 4], (1..=16).map(|i| i as f32 / 4.0).collect())];
        for _ in 0..500 {
            let mut g = p[0].clone();
            g.scale(2.0);
            opt.step(&mut p, &[g]);
        }
        assert!(p[0].max_abs() < 0.2, "{:?}", p[0].data());
    }

    #[test]
    fn confidence_dampens_unstable_coordinates() {
        // A coordinate whose û flips sign every step has high instability
        // and must receive a smaller effective update than a stable one.
        let cfg = OptimConfig { lr: 1.0, eps2: 1e-6, ..OptimConfig::paper_defaults(OptKind::Came) };
        let mut opt = Came::new(&[vec![1, 2]], &cfg);
        let mut p = vec![Tensor::zeros(&[1, 2])];
        for t in 0..30 {
            let flip = if t % 2 == 0 { 1.0 } else { -1.0 };
            let g = vec![Tensor::from_vec(&[1, 2], vec![1.0, flip])];
            opt.step(&mut p, &g);
        }
        // stable coordinate moved much further
        let d = p[0].data();
        assert!(d[0].abs() > 3.0 * d[1].abs(), "{:?}", d);
    }
}
