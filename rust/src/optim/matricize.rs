//! Square-matricization (paper Algorithm 2).
//!
//! Given a tensor with `numel` elements, find the factorization
//! `numel = n * m` minimizing `|n - m|` (equivalently `n + m`, Theorem 3.2)
//! by scanning `i = floor(sqrt(numel)) .. 1` for the largest divisor.
//! Computed once per tensor at optimizer construction — O(sqrt N).
//!
//! Construction-time only: the step hot path never re-derives shapes —
//! `Smmf::with_policies` calls [`effective_shape`] once per tensor and
//! caches the `(n̂, m̂)` pair next to the factor vectors it sizes.

#![deny(missing_docs)]

/// Returns `(n, m)` with `n >= m`, `n * m == numel`, `|n - m|` minimal.
///
/// ```
/// use smmf_repro::optim::matricize::effective_shape;
/// assert_eq!(effective_shape(12), (4, 3));
/// // BERT's 30522×768 embedding flattens to a near-square 5087×4608
/// // (paper §5.2) — factor vectors cost 9695 floats instead of 23.4M.
/// assert_eq!(effective_shape(30522 * 768), (5087, 4608));
/// ```
pub fn effective_shape(numel: usize) -> (usize, usize) {
    assert!(numel > 0, "effective_shape of empty tensor");
    let s = isqrt(numel);
    if s * s == numel {
        return (s, s);
    }
    for i in (1..=s).rev() {
        if numel % i == 0 {
            return (numel / i, i);
        }
    }
    (numel, 1) // unreachable: i == 1 divides everything
}

/// Integer square root (floor).
pub fn isqrt(n: usize) -> usize {
    if n == 0 {
        return 0;
    }
    let mut x = (n as f64).sqrt() as usize;
    // Correct float rounding in both directions (checked_mul guards the
    // x*x overflow near usize::MAX).
    while x.checked_mul(x).map_or(true, |v| v > n) {
        x -= 1;
    }
    while (x + 1).checked_mul(x + 1).map_or(false, |v| v <= n) {
        x += 1;
    }
    x
}

/// The paper's `squeeze`-based rank used to pick the non-factorized
/// fallback: rank after dropping all size-1 axes.
pub fn squeezed_rank(shape: &[usize]) -> usize {
    shape.iter().filter(|&&d| d != 1).count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn known_examples() {
        assert_eq!(effective_shape(1), (1, 1));
        assert_eq!(effective_shape(12), (4, 3));
        assert_eq!(effective_shape(16), (4, 4));
        assert_eq!(effective_shape(17), (17, 1)); // prime
        assert_eq!(effective_shape(30522 * 768), (5087, 4608)); // paper §5.2
    }

    #[test]
    fn isqrt_edges() {
        assert_eq!(isqrt(0), 0);
        assert_eq!(isqrt(1), 1);
        assert_eq!(isqrt(15), 3);
        assert_eq!(isqrt(16), 4);
        assert_eq!(isqrt(usize::MAX), 4294967295);
    }

    #[test]
    fn prop_factorization_is_optimal() {
        prop::cases(300, |rng| {
            let numel = 1 + rng.below(500_000);
            let (n, m) = effective_shape(numel);
            assert_eq!(n * m, numel);
            assert!(n >= m && m >= 1);
            // No divisor between m and sqrt gives a tighter split.
            let s = isqrt(numel);
            for i in (m + 1)..=s {
                assert_ne!(numel % i, 0, "numel={numel} better divisor {i}");
            }
        });
    }

    #[test]
    fn squeezed_rank_matches_paper_semantics() {
        assert_eq!(squeezed_rank(&[64]), 1);
        assert_eq!(squeezed_rank(&[1, 64, 1]), 1);
        assert_eq!(squeezed_rank(&[32, 16]), 2);
        assert_eq!(squeezed_rank(&[1]), 0); // scalar-like
    }
}
