//! Analytic optimizer-state memory accounting.
//!
//! The paper's headline tables are memory tables; optimizer state is a
//! pure function of the parameter-shape inventory, so the full-scale
//! models (ResNet-50 … LLaMA-7b) are accounted *analytically* here with
//! rules that exactly mirror the live implementations (asserted by tests
//! at instantiable sizes — see `live_matches_analytic`).
//!
//! Two columns are produced:
//! * `bytes` — exact heap bytes of persistent state (our measurement).
//! * `alloc_model_bytes` — the same state under a CUDA-caching-allocator
//!   model (every tensor rounded up to 512 B blocks), approximating what
//!   `torch.cuda.memory_allocated` reports in the paper's setup.

use super::group::{self, GroupedConfig, ParamSpec, StatePolicy, TensorPolicy};
use super::matricize::{effective_shape, squeezed_rank};
use super::{OptKind, OptimConfig};

/// Per-tensor persistent state: sizes in bytes of each separately
/// allocated state tensor, under the native (ungrouped) policy.
pub fn state_allocs(kind: OptKind, shape: &[usize], cfg: &OptimConfig) -> Vec<u64> {
    state_allocs_with(kind, shape, cfg, &TensorPolicy::uniform(cfg))
}

/// Per-tensor persistent state under a resolved group policy: frozen and
/// `StatePolicy::None` tensors hold nothing; `StatePolicy::Dense` forces
/// the dense fallback where the optimizer has one (SMMF: 2N Adam-style
/// moments; Adafactor: dense V; CAME: dense V and U). Mirrors the live
/// `with_policies` constructors byte-for-byte (asserted by tests).
pub fn state_allocs_with(
    kind: OptKind,
    shape: &[usize],
    cfg: &OptimConfig,
    pol: &TensorPolicy,
) -> Vec<u64> {
    let numel: u64 = shape.iter().product::<usize>() as u64;
    let f = 4u64; // f32
    if pol.stateless() {
        return Vec::new();
    }
    let dense = pol.state == StatePolicy::Dense;
    match kind {
        OptKind::Sgd => {
            if cfg.momentum != 0.0 {
                vec![numel * f]
            } else {
                vec![]
            }
        }
        OptKind::Adam | OptKind::AdamW => vec![numel * f, numel * f],
        OptKind::Adafactor => {
            let mut out = Vec::new();
            if !dense && shape.len() >= 2 {
                let last = shape[shape.len() - 1] as u64;
                let second = shape[shape.len() - 2] as u64;
                let lead: u64 = shape[..shape.len() - 2].iter().product::<usize>() as u64;
                out.push(lead * second * f); // exp_avg_sq_row
                out.push(lead * last * f); // exp_avg_sq_col
            } else {
                out.push(numel * f); // dense V
            }
            if cfg.beta1 > 0.0 {
                out.push(numel * f); // dense momentum
            }
            out
        }
        OptKind::Sm3 => {
            let shape_nz: Vec<usize> = if shape.is_empty() { vec![1] } else { shape.to_vec() };
            let mut out: Vec<u64> = shape_nz.iter().map(|&d| d as u64 * f).collect();
            if cfg.beta1 > 0.0 {
                out.push(numel * f);
            }
            out
        }
        OptKind::Came => {
            let mut out = vec![numel * f]; // momentum
            if !dense && shape.len() >= 2 {
                let last = shape[shape.len() - 1] as u64;
                let second = shape[shape.len() - 2] as u64;
                let lead: u64 = shape[..shape.len() - 2].iter().product::<usize>() as u64;
                // V factors + instability factors
                out.extend([lead * second * f, lead * last * f, lead * second * f, lead * last * f]);
            } else {
                out.extend([numel * f, numel * f]);
            }
            out
        }
        OptKind::Smmf => {
            if dense || (squeezed_rank(shape) == 1 && !cfg.vector_reshape) {
                vec![numel * f, numel * f]
            } else {
                let (n, m) = match cfg.smmf_matricize {
                    super::MatricizeMode::Square => effective_shape(numel as usize),
                    super::MatricizeMode::FoldLast => {
                        let last = *shape.last().unwrap_or(&1);
                        (numel as usize / last, last)
                    }
                };
                let (n, m) = (n as u64, m as u64);
                let sign_bytes = match cfg.smmf_sign_mode {
                    super::SignMode::Bit1 => (n * m).div_ceil(64) * 8, // packed words
                    super::SignMode::Byte8 => n * m,
                };
                vec![n * f, m * f, sign_bytes, n * f, m * f]
            }
        }
    }
}

/// Exact persistent state bytes for one tensor.
pub fn tensor_state_bytes(kind: OptKind, shape: &[usize], cfg: &OptimConfig) -> u64 {
    state_allocs(kind, shape, cfg).iter().sum()
}

/// Exact persistent state bytes over a whole parameter inventory.
pub fn inventory_state_bytes(kind: OptKind, shapes: &[Vec<usize>], cfg: &OptimConfig) -> u64 {
    shapes.iter().map(|s| tensor_state_bytes(kind, s, cfg)).sum()
}

/// Serialized size in bytes of one tensor's `StateSerde` blob — the
/// exact length `state_blobs()[i].len()` would report, mirrored
/// analytically so on-disk checkpoint cost can be tabulated for
/// inventories too large to instantiate (asserted against the live
/// optimizers by `blob_bytes_match_live` below; layouts in
/// docs/CHECKPOINT_FORMAT.md).
pub fn tensor_blob_bytes(kind: OptKind, shape: &[usize], cfg: &OptimConfig) -> u64 {
    tensor_blob_bytes_with(kind, shape, cfg, &TensorPolicy::uniform(cfg))
}

/// [`tensor_blob_bytes`] under a resolved group policy: stateless/frozen
/// tensors shrink to their framing bytes, `StatePolicy::Dense` switches
/// to the dense blob layout.
pub fn tensor_blob_bytes_with(
    kind: OptKind,
    shape: &[usize],
    cfg: &OptimConfig,
    pol: &TensorPolicy,
) -> u64 {
    let numel: u64 = shape.iter().product::<usize>() as u64;
    let f = 4u64; // f32
    let vec = |len: u64| 8 + len * f; // u64 length prefix + payload
    let stateless = pol.stateless();
    let dense = pol.state == StatePolicy::Dense;
    if stateless {
        // Framing-only blobs, per docs/CHECKPOINT_FORMAT.md.
        return match kind {
            OptKind::Sgd => 1,                     // has_momentum = 0
            OptKind::Adam | OptKind::AdamW => 8,   // numel = 0
            OptKind::Adafactor => 1 + 8 + 1,       // dense V len 0, has_m 0
            OptKind::Sm3 => 4 + 1,                 // n_axes 0, has_m 0
            OptKind::Came => (1 + 8) * 2 + 8,      // dense V/U len 0, m len 0
            OptKind::Smmf => 1,                    // state kind tag 2
        };
    }
    match kind {
        OptKind::Sgd => 1 + if cfg.momentum != 0.0 { vec(numel) } else { 0 },
        OptKind::Adam | OptKind::AdamW => 8 + 2 * numel * f,
        OptKind::Adafactor => {
            let v = if !dense && shape.len() >= 2 {
                let last = shape[shape.len() - 1] as u64;
                let second = shape[shape.len() - 2] as u64;
                let lead: u64 = shape[..shape.len() - 2].iter().product::<usize>() as u64;
                vec(lead * second) + vec(lead * last)
            } else {
                vec(numel)
            };
            1 + v + 1 + if cfg.beta1 > 0.0 { vec(numel) } else { 0 }
        }
        OptKind::Sm3 => {
            let shape_nz: Vec<usize> = if shape.is_empty() { vec![1] } else { shape.to_vec() };
            let axes: u64 = shape_nz.iter().map(|&d| vec(d as u64)).sum();
            4 + axes + 1 + if cfg.beta1 > 0.0 { vec(numel) } else { 0 }
        }
        OptKind::Came => {
            let fact = if !dense && shape.len() >= 2 {
                let last = shape[shape.len() - 1] as u64;
                let second = shape[shape.len() - 2] as u64;
                let lead: u64 = shape[..shape.len() - 2].iter().product::<usize>() as u64;
                vec(lead * second) + vec(lead * last)
            } else {
                vec(numel)
            };
            (1 + fact) * 2 + vec(numel)
        }
        OptKind::Smmf => {
            if dense || (squeezed_rank(shape) == 1 && !cfg.vector_reshape) {
                1 + 8 + 2 * numel * f
            } else {
                let (n, m) = match cfg.smmf_matricize {
                    super::MatricizeMode::Square => effective_shape(numel as usize),
                    super::MatricizeMode::FoldLast => {
                        let last = *shape.last().unwrap_or(&1);
                        (numel as usize / last, last)
                    }
                };
                let (n, m) = (n as u64, m as u64);
                let sign_bytes = match cfg.smmf_sign_mode {
                    super::SignMode::Bit1 => (n * m).div_ceil(64) * 8,
                    super::SignMode::Byte8 => n * m,
                };
                1 + 4 + 4 + 2 * (n + m) * f + 1 + 8 + sign_bytes
            }
        }
    }
}

/// On-disk bytes of a whole inventory's optimizer-state section in a
/// `SMMFCKPT` v2 checkpoint: the section payload is `u32` kind tag +
/// `u64` step counter + `u32` tensor count + one length-prefixed blob
/// per tensor (see `train::checkpoint`).
pub fn inventory_checkpoint_bytes(kind: OptKind, shapes: &[Vec<usize>], cfg: &OptimConfig) -> u64 {
    4 + 8
        + 4
        + shapes
            .iter()
            .map(|s| 8 + tensor_blob_bytes(kind, s, cfg))
            .sum::<u64>()
}

/// CUDA-caching-allocator model: every allocation rounds up to 512 B.
pub fn inventory_alloc_model_bytes(
    kind: OptKind,
    shapes: &[Vec<usize>],
    cfg: &OptimConfig,
) -> u64 {
    const BLOCK: u64 = 512;
    shapes
        .iter()
        .flat_map(|s| state_allocs(kind, s, cfg))
        .map(|b| b.div_ceil(BLOCK) * BLOCK)
        .sum()
}

/// The paper's two memory columns for one (model, optimizer) cell:
/// optimizer state and end-to-end one-batch training memory
/// (params + grads + optimizer state; activations excluded — see
/// EXPERIMENTS.md for the comparison discussion).
#[derive(Clone, Copy, Debug)]
pub struct MemoryReport {
    pub param_count: u64,
    pub param_bytes: u64,
    pub opt_bytes: u64,
    pub opt_alloc_model_bytes: u64,
    pub e2e_bytes: u64,
    /// On-disk bytes of the optimizer-state checkpoint section
    /// ([`inventory_checkpoint_bytes`]) — the native serialization keeps
    /// this within framing overhead of `opt_bytes`.
    pub ckpt_opt_bytes: u64,
}

/// Policy-aware inventory totals (one resolved policy per tensor).
pub fn inventory_state_bytes_with(
    kind: OptKind,
    shapes: &[Vec<usize>],
    cfg: &OptimConfig,
    policies: &[TensorPolicy],
) -> u64 {
    shapes
        .iter()
        .zip(policies)
        .map(|(s, p)| state_allocs_with(kind, s, cfg, p).iter().sum::<u64>())
        .sum()
}

/// One memory-accounting row per resolved param group: how many tensors
/// and parameters the group captures and what its optimizer state costs
/// in RAM and on disk (`SMMFCKPT` OPT-section blob bytes).
#[derive(Clone, Debug)]
pub struct GroupMemoryRow {
    pub group: String,
    pub tensors: usize,
    pub params: u64,
    pub opt_bytes: u64,
    pub ckpt_opt_bytes: u64,
    pub frozen: bool,
    pub state: StatePolicy,
}

/// Per-group memory breakdown of a grouped config over a role-tagged
/// inventory — the grouped counterpart of [`report`]. Row order matches
/// the resolved group table (index 0 = the implicit default group).
pub fn grouped_report(
    kind: OptKind,
    specs: &[ParamSpec],
    gcfg: &GroupedConfig,
) -> Vec<GroupMemoryRow> {
    let res = group::resolve(specs, gcfg);
    let mut rows: Vec<GroupMemoryRow> = res
        .groups
        .iter()
        .map(|g| GroupMemoryRow {
            group: g.name.clone(),
            tensors: g.tensors,
            params: g.params,
            opt_bytes: 0,
            ckpt_opt_bytes: 0,
            frozen: g.frozen,
            state: g.state,
        })
        .collect();
    for (spec, pol) in specs.iter().zip(&res.tensor) {
        let row = &mut rows[pol.group];
        row.opt_bytes +=
            state_allocs_with(kind, &spec.shape, &gcfg.base, pol).iter().sum::<u64>();
        // + u64 per-blob length prefix, as in the OPT section framing.
        row.ckpt_opt_bytes += 8 + tensor_blob_bytes_with(kind, &spec.shape, &gcfg.base, pol);
    }
    rows
}

pub fn report(kind: OptKind, shapes: &[Vec<usize>], cfg: &OptimConfig) -> MemoryReport {
    let param_count: u64 = shapes.iter().map(|s| s.iter().product::<usize>() as u64).sum();
    let param_bytes = param_count * 4;
    let opt_bytes = inventory_state_bytes(kind, shapes, cfg);
    MemoryReport {
        param_count,
        param_bytes,
        opt_bytes,
        opt_alloc_model_bytes: inventory_alloc_model_bytes(kind, shapes, cfg),
        e2e_bytes: opt_bytes + 2 * param_bytes, // params + grads + state
        ckpt_opt_bytes: inventory_checkpoint_bytes(kind, shapes, cfg),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{build, OptimConfig};
    use crate::util::prop;

    /// The analytic rules must match the live optimizers byte-for-byte.
    #[test]
    fn live_matches_analytic() {
        prop::cases(30, |rng| {
            let n_tensors = 1 + rng.below(4);
            let shapes: Vec<Vec<usize>> =
                (0..n_tensors).map(|_| prop::gen_shape(rng, 4, 4096)).collect();
            for kind in OptKind::all() {
                let cfg = OptimConfig::paper_defaults(kind);
                let opt = build(kind, &shapes, &cfg);
                let analytic = inventory_state_bytes(kind, &shapes, &cfg);
                assert_eq!(
                    opt.state_bytes(),
                    analytic,
                    "{} on {shapes:?}",
                    kind.name()
                );
            }
        });
    }

    /// The analytic blob sizes must match the live serializers exactly.
    #[test]
    fn blob_bytes_match_live() {
        use crate::optim::{OptKind, StateSerde};
        prop::cases(25, |rng| {
            let n_tensors = 1 + rng.below(4);
            let shapes: Vec<Vec<usize>> =
                (0..n_tensors).map(|_| prop::gen_shape(rng, 4, 4096)).collect();
            for kind in OptKind::every() {
                let cfg = OptimConfig::paper_defaults(kind);
                let opt = build(kind, &shapes, &cfg);
                let blobs = opt.state_blobs();
                for (shape, blob) in shapes.iter().zip(&blobs) {
                    assert_eq!(
                        blob.len() as u64,
                        tensor_blob_bytes(kind, shape, &cfg),
                        "{} on {shape:?}",
                        kind.name()
                    );
                }
                let section: u64 =
                    4 + 8 + 4 + blobs.iter().map(|b| 8 + b.len() as u64).sum::<u64>();
                assert_eq!(section, inventory_checkpoint_bytes(kind, &shapes, &cfg));
            }
        });
    }

    /// Grouped analytic rules must match the live `with_policies`
    /// optimizers byte-for-byte, for state and serialized blobs alike.
    #[test]
    fn grouped_analytic_matches_live() {
        use crate::optim::group::{GroupPolicy, ParamRole};
        use crate::optim::{build_grouped, StateSerde};
        let specs = vec![
            ParamSpec::new("w1", &[48, 32], ParamRole::Kernel),
            ParamSpec::new("b1", &[48], ParamRole::Bias),
            ParamSpec::new("ln.weight", &[48], ParamRole::Norm),
            ParamSpec::new("emb.weight", &[64, 16], ParamRole::Embedding),
            ParamSpec::new("head.weight", &[10, 16], ParamRole::Kernel),
        ];
        let shapes: Vec<Vec<usize>> = specs.iter().map(|s| s.shape.clone()).collect();
        for kind in OptKind::every() {
            let mut gcfg = GroupedConfig::uniform(&OptimConfig::paper_defaults(kind));
            gcfg.base.weight_decay = 0.01;
            gcfg.groups.push(GroupPolicy {
                name: "dense_no_decay".into(),
                match_roles: vec![ParamRole::Bias, ParamRole::Norm],
                weight_decay: Some(0.0),
                state: StatePolicy::Dense,
                ..GroupPolicy::default()
            });
            gcfg.groups.push(GroupPolicy {
                name: "frozen_emb".into(),
                match_roles: vec![ParamRole::Embedding],
                frozen: true,
                ..GroupPolicy::default()
            });
            gcfg.groups.push(GroupPolicy {
                name: "stateless_head".into(),
                match_names: vec!["head.*".into()],
                state: StatePolicy::None,
                ..GroupPolicy::default()
            });
            let res = group::resolve(&specs, &gcfg);
            let opt = build_grouped(kind, &specs, &gcfg);
            assert_eq!(
                opt.state_bytes(),
                inventory_state_bytes_with(kind, &shapes, &gcfg.base, &res.tensor),
                "{}",
                kind.name()
            );
            for ((spec, pol), blob) in
                specs.iter().zip(&res.tensor).zip(&opt.state_blobs())
            {
                assert_eq!(
                    blob.len() as u64,
                    tensor_blob_bytes_with(kind, &spec.shape, &gcfg.base, pol),
                    "{} {}",
                    kind.name(),
                    spec.name
                );
            }
            let rows = grouped_report(kind, &specs, &gcfg);
            assert_eq!(rows.len(), 4);
            assert_eq!(rows.iter().map(|r| r.opt_bytes).sum::<u64>(), opt.state_bytes());
            assert_eq!(rows.iter().map(|r| r.params).sum::<u64>(), 48 * 32 + 48 + 48 + 64 * 16 + 160);
            // frozen/stateless groups hold zero state
            assert_eq!(rows[2].opt_bytes, 0, "{}", kind.name());
            assert_eq!(rows[3].opt_bytes, 0, "{}", kind.name());
        }
    }

    #[test]
    fn checkpoint_overhead_is_framing_only() {
        // Native serialization: the on-disk section stays within the
        // per-tensor/per-vector length prefixes of the in-RAM state.
        let shapes = vec![vec![512, 512], vec![512]];
        for kind in OptKind::all() {
            let cfg = OptimConfig::paper_defaults(kind);
            let ram = inventory_state_bytes(kind, &shapes, &cfg);
            let disk = inventory_checkpoint_bytes(kind, &shapes, &cfg);
            assert!(disk >= ram, "{}", kind.name());
            assert!(disk - ram < 1024, "{}: ram={ram} disk={disk}", kind.name());
        }
    }

    #[test]
    fn smmf_beats_all_on_large_matrices() {
        let shapes = vec![vec![4096, 4096], vec![4096]];
        let mut sizes = std::collections::BTreeMap::new();
        for kind in OptKind::all() {
            let cfg = OptimConfig::paper_defaults(kind);
            sizes.insert(kind.name(), inventory_state_bytes(kind, &shapes, &cfg));
        }
        let smmf = sizes["smmf"];
        for (name, &b) in &sizes {
            if *name != "smmf" {
                assert!(smmf < b / 10, "smmf {smmf} vs {name} {b}");
            }
        }
    }

    #[test]
    fn conv1x1_pathology_ordering() {
        // On a pointwise-conv inventory the paper's ordering is
        // smmf << sm3 < adam < adafactor < came.
        let shapes = vec![vec![512, 256, 1, 1], vec![256, 128, 1, 1]];
        let b = |k: OptKind| {
            inventory_state_bytes(k, &shapes, &OptimConfig::paper_defaults(k))
        };
        let (smmf, sm3, adam, ada, came) = (
            b(OptKind::Smmf),
            b(OptKind::Sm3),
            b(OptKind::Adam),
            b(OptKind::Adafactor),
            b(OptKind::Came),
        );
        assert!(smmf < sm3 && sm3 < adam && adam < ada && ada < came,
            "smmf={smmf} sm3={sm3} adam={adam} ada={ada} came={came}");
    }

    #[test]
    fn alloc_model_rounds_up() {
        let shapes = vec![vec![2, 2]]; // tiny tensors -> heavy rounding
        let cfg = OptimConfig::paper_defaults(OptKind::Adam);
        let exact = inventory_state_bytes(OptKind::Adam, &shapes, &cfg);
        let modeled = inventory_alloc_model_bytes(OptKind::Adam, &shapes, &cfg);
        assert_eq!(exact, 32);
        assert_eq!(modeled, 1024); // two 512-B blocks
    }

    #[test]
    fn report_e2e_composition() {
        let shapes = vec![vec![1000, 1000]];
        let cfg = OptimConfig::paper_defaults(OptKind::Adam);
        let r = report(OptKind::Adam, &shapes, &cfg);
        assert_eq!(r.param_count, 1_000_000);
        assert_eq!(r.e2e_bytes, r.opt_bytes + 2 * r.param_bytes);
        // Adam e2e = 4N floats = 16 MB
        assert_eq!(r.e2e_bytes, 16_000_000);
    }
}
