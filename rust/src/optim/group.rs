//! Param groups: per-group hyperparameters and state policies.
//!
//! Every training recipe the paper evaluates treats parameters
//! non-uniformly — bias/LayerNorm tensors are exempt from weight decay,
//! embeddings get scaled learning rates, tiny vectors may carry dense
//! (or no) optimizer state. This module is the vocabulary for expressing
//! that:
//!
//! * [`ParamSpec`] — a named, shaped, role-tagged parameter tensor
//!   (roles: [`ParamRole`]), emitted by every model inventory in
//!   `crate::models` and derivable from artifact specs via
//!   [`ParamRole::infer`].
//! * [`GroupPolicy`] — one matcher block (`[[optimizer.group]]` in TOML):
//!   name globs and/or role selectors, plus the per-group overrides
//!   `lr_scale`, `weight_decay`, `frozen` and a [`StatePolicy`].
//! * [`GroupedConfig`] — the base [`OptimConfig`] plus an ordered list of
//!   group policies (first match wins; unmatched tensors fall into the
//!   implicit `default` group carrying the base config).
//! * [`resolve`] — flattens specs × policies into a [`Resolution`]: a
//!   group table plus one effective [`TensorPolicy`] per tensor, which is
//!   what the optimizer constructors actually consume.
//!
//! Construct through [`crate::optim::build_grouped`]:
//!
//! ```
//! use smmf_repro::optim::group::{GroupPolicy, GroupedConfig, ParamRole, ParamSpec, StatePolicy};
//! use smmf_repro::optim::{build_grouped, OptKind, OptimConfig, Optimizer};
//! use smmf_repro::tensor::Tensor;
//!
//! let specs = vec![
//!     ParamSpec::new("fc.weight", &[16, 16], ParamRole::Kernel),
//!     ParamSpec::new("fc.bias", &[16], ParamRole::Bias),
//! ];
//! let mut gcfg = GroupedConfig::uniform(&OptimConfig {
//!     weight_decay: 0.01,
//!     ..OptimConfig::paper_defaults(OptKind::Smmf)
//! });
//! // Exempt biases from weight decay and keep their state dense.
//! gcfg.groups.push(GroupPolicy {
//!     name: "no_decay".into(),
//!     match_roles: vec![ParamRole::Bias, ParamRole::Norm],
//!     weight_decay: Some(0.0),
//!     state: StatePolicy::Dense,
//!     ..GroupPolicy::default()
//! });
//! let mut opt = build_grouped(OptKind::Smmf, &specs, &gcfg);
//! let mut params = vec![Tensor::zeros(&[16, 16]), Tensor::zeros(&[16])];
//! let grads = vec![
//!     Tensor::from_vec(&[16, 16], vec![0.01; 256]),
//!     Tensor::from_vec(&[16], vec![0.01; 16]),
//! ];
//! opt.step(&mut params, &grads);
//! assert!(opt.state_bytes() > 0);
//! ```

use super::{OptimConfig, WeightDecayMode};

/// The role a parameter tensor plays in its model. Emitted by the
/// inventory builders in `crate::models`; inferable from HF-style tensor
/// names via [`ParamRole::infer`] for artifact-derived inventories.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ParamRole {
    /// Dense/conv/attention weight matrices (rank >= 2, decayed).
    Kernel,
    /// Additive bias vectors (conventionally weight-decay exempt).
    Bias,
    /// LayerNorm/BatchNorm/RMSNorm scales and shifts (decay exempt).
    Norm,
    /// Embedding tables (often LR-rescaled).
    Embedding,
    /// Anything else (scalars, odd buffers).
    Other,
}

impl ParamRole {
    pub fn name(&self) -> &'static str {
        match self {
            ParamRole::Kernel => "kernel",
            ParamRole::Bias => "bias",
            ParamRole::Norm => "norm",
            ParamRole::Embedding => "embedding",
            ParamRole::Other => "other",
        }
    }

    pub fn parse(s: &str) -> Option<ParamRole> {
        Some(match s.to_ascii_lowercase().as_str() {
            "kernel" => ParamRole::Kernel,
            "bias" => ParamRole::Bias,
            "norm" => ParamRole::Norm,
            "embedding" => ParamRole::Embedding,
            "other" => ParamRole::Other,
            _ => return None,
        })
    }

    pub fn all() -> [ParamRole; 5] {
        [ParamRole::Kernel, ParamRole::Bias, ParamRole::Norm, ParamRole::Embedding, ParamRole::Other]
    }

    /// Infer a role from an HF/torchvision-style tensor name plus its
    /// shape — the fallback for inventories that only carry names (AOT
    /// artifact specs). The explicit roles set by `crate::models` builders
    /// take precedence over this heuristic.
    pub fn infer(name: &str, shape: &[usize]) -> ParamRole {
        let lower = name.to_ascii_lowercase();
        let base = lower.rsplit('.').next().unwrap_or(&lower);
        let numbered = |seg: &str, prefix: &str| {
            seg.len() > prefix.len()
                && seg.starts_with(prefix)
                && seg[prefix.len()..].chars().all(|c| c.is_ascii_digit())
        };
        let norm_ctx = lower.split('.').any(|seg| {
            seg.contains("norm")
                || seg == "ln"
                || seg.starts_with("ln_")
                || numbered(seg, "ln")
                || numbered(seg, "bn")
        });
        if norm_ctx {
            return ParamRole::Norm;
        }
        if base.ends_with("bias") || base == "b" {
            return ParamRole::Bias;
        }
        if lower.contains("emb") || base == "wte" || base == "wpe" || base == "shared" {
            return ParamRole::Embedding;
        }
        // Declared rank, not squeezed rank: a [1, 512] projection is a
        // real weight matrix, only genuinely 1-D "weight"s are norm
        // scales in the conventions we model.
        if shape.len() >= 2 {
            ParamRole::Kernel
        } else if base == "weight" || base == "g" || base == "gamma" || base == "scale" {
            ParamRole::Norm
        } else {
            ParamRole::Other
        }
    }
}

/// One named, shaped, role-tagged parameter tensor — the registration
/// unit of the grouped optimizer API.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub role: ParamRole,
}

impl ParamSpec {
    pub fn new(name: impl Into<String>, shape: &[usize], role: ParamRole) -> ParamSpec {
        ParamSpec { name: name.into(), shape: shape.to_vec(), role }
    }

    /// Build a spec with the role inferred from the name/shape.
    pub fn inferred(name: impl Into<String>, shape: &[usize]) -> ParamSpec {
        let name = name.into();
        let role = ParamRole::infer(&name, shape);
        ParamSpec { name, shape: shape.to_vec(), role }
    }

    pub fn numel(&self) -> u64 {
        self.shape.iter().product::<usize>() as u64
    }
}

/// Per-group optimizer-state policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StatePolicy {
    /// The optimizer's native layout — factored for SMMF / Adafactor /
    /// CAME, dense moments for Adam, covers for SM3, momentum for SGD.
    /// This is the default and reproduces the ungrouped behavior exactly.
    Factored,
    /// Force dense per-element state: SMMF keeps dense Adam-style
    /// first/second moments for the group, Adafactor a dense V, CAME
    /// dense V and U. Optimizers whose state is already element-dense or
    /// axis-wise (Adam, AdamW, SGD, SM3) treat this as `Factored`.
    Dense,
    /// No persistent state for the group: the update degenerates to plain
    /// `w -= lr · g` (with the group's weight decay). Zero state bytes.
    None,
}

impl StatePolicy {
    pub fn name(&self) -> &'static str {
        match self {
            StatePolicy::Factored => "factored",
            StatePolicy::Dense => "dense",
            StatePolicy::None => "none",
        }
    }

    pub fn parse(s: &str) -> Option<StatePolicy> {
        Some(match s.to_ascii_lowercase().as_str() {
            "factored" | "native" | "default" => StatePolicy::Factored,
            "dense" => StatePolicy::Dense,
            "none" | "stateless" => StatePolicy::None,
            _ => return None,
        })
    }

    /// Stable numeric tag for the checkpoint CONFIG section.
    pub fn tag(self) -> u8 {
        match self {
            StatePolicy::Factored => 0,
            StatePolicy::Dense => 1,
            StatePolicy::None => 2,
        }
    }

    pub fn from_tag(tag: u8) -> Option<StatePolicy> {
        Some(match tag {
            0 => StatePolicy::Factored,
            1 => StatePolicy::Dense,
            2 => StatePolicy::None,
            _ => return None,
        })
    }
}

/// One `[[optimizer.group]]` matcher block: which tensors it captures
/// (name globs and/or roles; a tensor must satisfy both non-empty
/// selector lists; two empty lists match everything) and the per-group
/// hyperparameter overrides.
#[derive(Clone, Debug, PartialEq)]
pub struct GroupPolicy {
    /// Label used in reports and the checkpoint CONFIG section.
    pub name: String,
    /// Name globs (`*` any substring, `?` any char); empty = match all.
    pub match_names: Vec<String>,
    /// Role selectors; empty = match all.
    pub match_roles: Vec<ParamRole>,
    /// Multiplies the (scheduled) base learning rate for the group.
    pub lr_scale: f32,
    /// Overrides the base weight decay; `None` inherits it.
    pub weight_decay: Option<f32>,
    /// Frozen tensors receive no updates and carry no optimizer state.
    pub frozen: bool,
    pub state: StatePolicy,
}

impl Default for GroupPolicy {
    fn default() -> Self {
        GroupPolicy {
            name: "group".into(),
            match_names: Vec::new(),
            match_roles: Vec::new(),
            lr_scale: 1.0,
            weight_decay: None,
            frozen: false,
            state: StatePolicy::Factored,
        }
    }
}

impl GroupPolicy {
    /// Does this policy capture the given spec?
    pub fn matches(&self, spec: &ParamSpec) -> bool {
        let role_ok =
            self.match_roles.is_empty() || self.match_roles.contains(&spec.role);
        let name_ok = self.match_names.is_empty()
            || self.match_names.iter().any(|p| glob_match(p, &spec.name));
        role_ok && name_ok
    }

    /// Parse the compact CLI spelling: comma-separated `key=value` fields
    /// (`name=`, `role=bias|norm`, `match=*.bias|*ln*`, `lr_scale=`,
    /// `wd=`/`weight_decay=`, `state=factored|dense|none`, `frozen`).
    pub fn parse_cli(spec: &str) -> Result<GroupPolicy, String> {
        let mut g = GroupPolicy::default();
        for field in spec.split(',').map(str::trim).filter(|f| !f.is_empty()) {
            let (key, value) = field.split_once('=').unwrap_or((field, ""));
            match key {
                "name" => g.name = value.to_string(),
                "role" => {
                    for r in value.split('|') {
                        g.match_roles
                            .push(ParamRole::parse(r).ok_or_else(|| format!("unknown role {r}"))?);
                    }
                }
                "match" => g.match_names.extend(value.split('|').map(String::from)),
                "lr_scale" => {
                    g.lr_scale =
                        value.parse().map_err(|_| format!("bad lr_scale {value}"))?
                }
                "wd" | "weight_decay" => {
                    g.weight_decay =
                        Some(value.parse().map_err(|_| format!("bad weight_decay {value}"))?)
                }
                "state" => {
                    g.state = StatePolicy::parse(value)
                        .ok_or_else(|| format!("unknown state policy {value}"))?
                }
                "frozen" => g.frozen = value.is_empty() || value == "true",
                other => return Err(format!("unknown group field {other}")),
            }
        }
        Ok(g)
    }

    /// Parse a `;`-separated list of CLI group specs.
    pub fn parse_cli_list(specs: &str) -> Result<Vec<GroupPolicy>, String> {
        specs
            .split(';')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(GroupPolicy::parse_cli)
            .collect()
    }
}

/// Base config + ordered group policies (first match wins).
#[derive(Clone, Debug)]
pub struct GroupedConfig {
    pub base: OptimConfig,
    pub groups: Vec<GroupPolicy>,
}

impl GroupedConfig {
    /// A grouped config with no groups: every tensor lands in the default
    /// group and behavior is identical to the legacy flat-config path.
    pub fn uniform(cfg: &OptimConfig) -> GroupedConfig {
        GroupedConfig { base: cfg.clone(), groups: Vec::new() }
    }
}

/// The effective per-tensor knobs an optimizer consults at construction
/// (state layout) and every step (lr scale, weight decay, frozen).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TensorPolicy {
    /// Index into [`Resolution::groups`] (0 = the implicit default).
    pub group: usize,
    pub lr_scale: f32,
    pub weight_decay: f32,
    pub frozen: bool,
    pub state: StatePolicy,
}

impl TensorPolicy {
    /// The default-group policy: behaviorally identical to the flat
    /// config (`lr_scale` 1, base weight decay, native state).
    pub fn uniform(cfg: &OptimConfig) -> TensorPolicy {
        TensorPolicy {
            group: 0,
            lr_scale: 1.0,
            weight_decay: cfg.weight_decay,
            frozen: false,
            state: StatePolicy::Factored,
        }
    }

    /// True when the tensor carries no persistent optimizer state.
    pub fn stateless(&self) -> bool {
        self.frozen || self.state == StatePolicy::None
    }
}

/// One row of the resolved group table.
#[derive(Clone, Debug, PartialEq)]
pub struct ResolvedGroup {
    pub name: String,
    pub lr_scale: f32,
    pub weight_decay: f32,
    pub frozen: bool,
    pub state: StatePolicy,
    /// Tensors captured by this group.
    pub tensors: usize,
    /// Total parameter count captured by this group.
    pub params: u64,
}

/// Specs × policies, flattened: the group table plus one effective
/// [`TensorPolicy`] per tensor in registration order.
#[derive(Clone, Debug, PartialEq)]
pub struct Resolution {
    /// Index 0 is always the implicit default group.
    pub groups: Vec<ResolvedGroup>,
    pub tensor: Vec<TensorPolicy>,
}

impl Resolution {
    /// All-default resolution over `n` tensors (the legacy `build`
    /// path). Note: this shortcut has no shapes, so the default group's
    /// `params` diagnostic is 0 — use [`resolve`] with real specs when
    /// the group table feeds reports or the checkpoint CONFIG section.
    pub fn uniform(cfg: &OptimConfig, n: usize) -> Resolution {
        Resolution {
            groups: vec![ResolvedGroup {
                name: "default".into(),
                lr_scale: 1.0,
                weight_decay: cfg.weight_decay,
                frozen: false,
                state: StatePolicy::Factored,
                tensors: n,
                params: 0,
            }],
            tensor: vec![TensorPolicy::uniform(cfg); n],
        }
    }
}

/// Resolve a grouped config over a parameter inventory. Policies are
/// tried in order, first match wins; unmatched tensors fall into the
/// implicit `default` group (index 0) carrying the base config.
pub fn resolve(specs: &[ParamSpec], gcfg: &GroupedConfig) -> Resolution {
    let base = &gcfg.base;
    let mut groups = vec![ResolvedGroup {
        name: "default".into(),
        lr_scale: 1.0,
        weight_decay: base.weight_decay,
        frozen: false,
        state: StatePolicy::Factored,
        tensors: 0,
        params: 0,
    }];
    for g in &gcfg.groups {
        groups.push(ResolvedGroup {
            name: g.name.clone(),
            lr_scale: g.lr_scale,
            weight_decay: g.weight_decay.unwrap_or(base.weight_decay),
            frozen: g.frozen,
            state: g.state,
            tensors: 0,
            params: 0,
        });
    }
    let mut tensor = Vec::with_capacity(specs.len());
    for spec in specs {
        let gi = gcfg
            .groups
            .iter()
            .position(|g| g.matches(spec))
            .map(|i| i + 1)
            .unwrap_or(0);
        let g = &groups[gi];
        let pol = TensorPolicy {
            group: gi,
            lr_scale: g.lr_scale,
            weight_decay: g.weight_decay,
            frozen: g.frozen,
            state: g.state,
        };
        groups[gi].tensors += 1;
        groups[gi].params += spec.numel();
        tensor.push(pol);
    }
    Resolution { groups, tensor }
}

/// Plain `w -= lr · g` update with weight decay, shared by every
/// optimizer for `StatePolicy::None` tensors.
pub(crate) fn stateless_update(
    p: &mut [f32],
    g: &[f32],
    lr: f32,
    wd: f32,
    mode: WeightDecayMode,
) {
    if wd != 0.0 && mode == WeightDecayMode::AdamW {
        let f = 1.0 - lr * wd;
        p.iter_mut().for_each(|w| *w *= f);
    }
    let couple = wd != 0.0 && mode == WeightDecayMode::Adam;
    for (w, &g0) in p.iter_mut().zip(g) {
        let gij = if couple { g0 + wd * *w } else { g0 };
        *w -= lr * gij;
    }
}

/// Glob match with `*` (any substring, including empty) and `?` (any
/// single char); everything else is literal. Iterative backtracking —
/// linear in practice, no recursion.
pub fn glob_match(pattern: &str, text: &str) -> bool {
    let p: Vec<char> = pattern.chars().collect();
    let t: Vec<char> = text.chars().collect();
    let (mut pi, mut ti) = (0usize, 0usize);
    let (mut star_p, mut star_t) = (usize::MAX, 0usize);
    while ti < t.len() {
        if pi < p.len() && (p[pi] == '?' || p[pi] == t[ti]) {
            pi += 1;
            ti += 1;
        } else if pi < p.len() && p[pi] == '*' {
            star_p = pi;
            star_t = ti;
            pi += 1;
        } else if star_p != usize::MAX {
            pi = star_p + 1;
            star_t += 1;
            ti = star_t;
        } else {
            return false;
        }
    }
    while pi < p.len() && p[pi] == '*' {
        pi += 1;
    }
    pi == p.len()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<ParamSpec> {
        vec![
            ParamSpec::new("encoder.0.attn.q.weight", &[64, 64], ParamRole::Kernel),
            ParamSpec::new("encoder.0.attn.q.bias", &[64], ParamRole::Bias),
            ParamSpec::new("encoder.0.ln1.weight", &[64], ParamRole::Norm),
            ParamSpec::new("encoder.0.ln1.bias", &[64], ParamRole::Norm),
            ParamSpec::new("tok_emb.weight", &[1000, 64], ParamRole::Embedding),
        ]
    }

    #[test]
    fn glob_basics() {
        assert!(glob_match("*", ""));
        assert!(glob_match("*", "anything"));
        assert!(glob_match("*.bias", "a.b.bias"));
        assert!(!glob_match("*.bias", "a.b.weight"));
        assert!(glob_match("encoder.*.ln?.weight", "encoder.11.ln2.weight"));
        assert!(!glob_match("encoder.*.ln?.weight", "decoder.11.ln2.weight"));
        assert!(glob_match("a*b*c", "axxbyyc"));
        assert!(!glob_match("a*b*c", "axxbyy"));
        assert!(glob_match("exact", "exact"));
        assert!(!glob_match("exact", "exact2"));
    }

    #[test]
    fn role_inference_heuristics() {
        assert_eq!(ParamRole::infer("encoder.0.attn.q.weight", &[64, 64]), ParamRole::Kernel);
        assert_eq!(ParamRole::infer("encoder.0.attn.q.bias", &[64]), ParamRole::Bias);
        assert_eq!(ParamRole::infer("encoder.0.ln1.weight", &[64]), ParamRole::Norm);
        assert_eq!(ParamRole::infer("encoder.0.ln1.bias", &[64]), ParamRole::Norm);
        assert_eq!(ParamRole::infer("bn3.weight", &[32]), ParamRole::Norm);
        assert_eq!(ParamRole::infer("final_layernorm.weight", &[32]), ParamRole::Norm);
        assert_eq!(ParamRole::infer("tok_emb.weight", &[1000, 64]), ParamRole::Embedding);
        assert_eq!(ParamRole::infer("wte", &[1000, 64]), ParamRole::Embedding);
        assert_eq!(ParamRole::infer("conv1.weight", &[8, 3, 3, 3]), ParamRole::Kernel);
        assert_eq!(ParamRole::infer("detect.m.0.bias", &[18]), ParamRole::Bias);
        assert_eq!(ParamRole::infer("temperature", &[1]), ParamRole::Other);
        // declared rank wins: squeezed-rank-1 matrices are still kernels
        assert_eq!(ParamRole::infer("proj.weight", &[1, 512]), ParamRole::Kernel);
        assert_eq!(ParamRole::infer("scale.weight", &[512]), ParamRole::Norm);
    }

    #[test]
    fn role_roundtrip() {
        for r in ParamRole::all() {
            assert_eq!(ParamRole::parse(r.name()), Some(r));
        }
        assert_eq!(ParamRole::parse("nope"), None);
    }

    #[test]
    fn state_policy_tags_stable() {
        for s in [StatePolicy::Factored, StatePolicy::Dense, StatePolicy::None] {
            assert_eq!(StatePolicy::from_tag(s.tag()), Some(s));
            assert_eq!(StatePolicy::parse(s.name()), Some(s));
        }
        assert_eq!(StatePolicy::from_tag(9), None);
    }

    #[test]
    fn first_match_wins_and_default_catches_rest() {
        let cfg = OptimConfig { weight_decay: 0.1, ..OptimConfig::default() };
        let gcfg = GroupedConfig {
            base: cfg,
            groups: vec![
                GroupPolicy {
                    name: "no_decay".into(),
                    match_roles: vec![ParamRole::Bias, ParamRole::Norm],
                    weight_decay: Some(0.0),
                    ..GroupPolicy::default()
                },
                GroupPolicy {
                    name: "emb".into(),
                    match_names: vec!["*emb*".into()],
                    lr_scale: 0.5,
                    state: StatePolicy::Dense,
                    ..GroupPolicy::default()
                },
                // would also match the biases, but no_decay wins
                GroupPolicy {
                    name: "late".into(),
                    match_names: vec!["*.bias".into()],
                    lr_scale: 7.0,
                    ..GroupPolicy::default()
                },
            ],
        };
        let res = resolve(&specs(), &gcfg);
        assert_eq!(res.groups.len(), 4);
        assert_eq!(res.groups[0].name, "default");
        // kernel -> default, bias/norms -> no_decay, emb -> emb
        assert_eq!(
            res.tensor.iter().map(|t| t.group).collect::<Vec<_>>(),
            vec![0, 1, 1, 1, 2]
        );
        assert_eq!(res.tensor[0].weight_decay, 0.1);
        assert_eq!(res.tensor[1].weight_decay, 0.0);
        assert_eq!(res.tensor[4].lr_scale, 0.5);
        assert_eq!(res.tensor[4].state, StatePolicy::Dense);
        assert_eq!(res.groups[1].tensors, 3);
        assert_eq!(res.groups[2].params, 1000 * 64);
        assert_eq!(res.groups[3].tensors, 0, "shadowed group captures nothing");
        assert_eq!(res.groups[0].tensors, 1);
    }

    #[test]
    fn uniform_resolution_is_all_default() {
        let cfg = OptimConfig { weight_decay: 0.02, ..OptimConfig::default() };
        let res = resolve(&specs(), &GroupedConfig::uniform(&cfg));
        assert_eq!(res.groups.len(), 1);
        for t in &res.tensor {
            assert_eq!(*t, TensorPolicy::uniform(&cfg));
        }
        // and matches the shortcut constructor
        let short = Resolution::uniform(&cfg, specs().len());
        assert_eq!(short.tensor, res.tensor);
    }

    #[test]
    fn both_selector_kinds_must_agree() {
        let g = GroupPolicy {
            match_names: vec!["encoder.*".into()],
            match_roles: vec![ParamRole::Bias],
            ..GroupPolicy::default()
        };
        let s = specs();
        assert!(g.matches(&s[1])); // encoder bias
        assert!(!g.matches(&s[0])); // encoder kernel: role fails
        assert!(!g.matches(&s[4])); // embedding: name fails
        // empty selectors match everything
        assert!(GroupPolicy::default().matches(&s[0]));
    }

    #[test]
    fn cli_spec_parses() {
        let gs = GroupPolicy::parse_cli_list(
            "name=no_decay,role=bias|norm,wd=0; match=*emb*,lr_scale=0.5,state=dense; role=other,frozen",
        )
        .unwrap();
        assert_eq!(gs.len(), 3);
        assert_eq!(gs[0].name, "no_decay");
        assert_eq!(gs[0].match_roles, vec![ParamRole::Bias, ParamRole::Norm]);
        assert_eq!(gs[0].weight_decay, Some(0.0));
        assert_eq!(gs[1].match_names, vec!["*emb*".to_string()]);
        assert_eq!(gs[1].lr_scale, 0.5);
        assert_eq!(gs[1].state, StatePolicy::Dense);
        assert!(gs[2].frozen);
        assert!(GroupPolicy::parse_cli("role=nope").is_err());
        assert!(GroupPolicy::parse_cli("bogus=1").is_err());
    }

    #[test]
    fn stateless_update_matches_plain_sgd() {
        let mut p = vec![1.0f32, -2.0, 3.0];
        let g = vec![0.5f32, 0.5, 0.5];
        stateless_update(&mut p, &g, 0.1, 0.0, WeightDecayMode::AdamW);
        assert_eq!(p, vec![0.95, -2.05, 2.95]);
        // AdamW decay scales first
        let mut p2 = vec![1.0f32];
        stateless_update(&mut p2, &[0.0], 0.1, 0.5, WeightDecayMode::AdamW);
        assert!((p2[0] - 0.95).abs() < 1e-6);
        // Adam-coupled decay folds into the gradient
        let mut p3 = vec![1.0f32];
        stateless_update(&mut p3, &[0.0], 0.1, 0.5, WeightDecayMode::Adam);
        assert!((p3[0] - 0.95).abs() < 1e-6);
    }
}
