//! Hyper-parameter schedules shared by the optimizers.
//!
//! * SMMF's beta schedules (paper Algorithm 8): `β1_t = β1·λ^(t−1)` and
//!   `β2_t = 1 − t^γ` (also used by Adafactor/CAME for their 2nd-moment
//!   decay).
//! * Learning-rate schedules used by the experiment harness: constant,
//!   linear-warmup + linear/cosine decay, inverse-sqrt (transformer), and
//!   ReduceLROnPlateau (the paper's CNN recipe).
//!
//! Schedules are plain state (no trait objects): optimizers call the β
//! functions directly each step, and the trainer samples
//! [`LrSchedule::at`] before every [`crate::optim::Optimizer::step`].
//! The suite/TOML spelling lives in `coordinator::config`
//! (`[schedule] kind = "warmup" | "linear" | "invsqrt" | "constant"`).

#![deny(missing_docs)]

/// SMMF / AdamNC 1st-momentum growth schedule.
#[inline]
pub fn beta1_t(beta1: f32, growth_rate: f32, t: u64) -> f32 {
    beta1 * growth_rate.powf((t - 1) as f32)
}

/// Adafactor-style 2nd-momentum decay schedule. `decay_rate` in [-1, 0].
#[inline]
pub fn beta2_t(decay_rate: f32, t: u64) -> f32 {
    1.0 - (t as f32).powf(decay_rate)
}

/// Learning-rate schedules.
#[derive(Clone, Debug, PartialEq)]
pub enum LrSchedule {
    /// The base LR at every step (the default).
    Constant,
    /// Linear warmup to the base LR over `warmup` steps, then constant.
    Warmup {
        /// Ramp length in steps (0 = no ramp).
        warmup: u64,
    },
    /// Linear warmup then linear decay to zero at `total` steps.
    Linear {
        /// Ramp length in steps.
        warmup: u64,
        /// Step at which the decayed LR reaches zero.
        total: u64,
    },
    /// Transformer inverse-sqrt: lr * min(t^-0.5, t * warmup^-1.5) * warmup^0.5.
    InvSqrt {
        /// Step at which the schedule peaks at the base LR.
        warmup: u64,
    },
    /// Cosine decay to `floor` fraction after warmup.
    Cosine {
        /// Ramp length in steps.
        warmup: u64,
        /// Step at which the cosine reaches its floor.
        total: u64,
        /// Fraction of the base LR kept at the end (0.0–1.0).
        floor: f32,
    },
}

impl LrSchedule {
    /// The LR this schedule yields at (1-based) step `t` for `base_lr`.
    ///
    /// ```
    /// use smmf_repro::optim::schedule::LrSchedule;
    /// let s = LrSchedule::Warmup { warmup: 10 };
    /// assert!((s.at(1.0, 5) - 0.5).abs() < 1e-6); // mid-ramp
    /// assert_eq!(s.at(1.0, 100), 1.0); // past warmup: the base LR
    /// ```
    pub fn at(&self, base_lr: f32, t: u64) -> f32 {
        let t = t.max(1);
        match *self {
            LrSchedule::Constant => base_lr,
            LrSchedule::Warmup { warmup } => {
                if warmup > 0 && t <= warmup {
                    base_lr * t as f32 / warmup as f32
                } else {
                    base_lr
                }
            }
            LrSchedule::Linear { warmup, total } => {
                if warmup > 0 && t <= warmup {
                    base_lr * t as f32 / warmup as f32
                } else if total > warmup {
                    let frac = (total.saturating_sub(t)) as f32 / (total - warmup) as f32;
                    base_lr * frac.max(0.0)
                } else {
                    base_lr
                }
            }
            LrSchedule::InvSqrt { warmup } => {
                let w = warmup.max(1) as f32;
                let tf = t as f32;
                base_lr * w.sqrt() * (tf.powf(-0.5)).min(tf * w.powf(-1.5))
            }
            LrSchedule::Cosine { warmup, total, floor } => {
                if warmup > 0 && t <= warmup {
                    base_lr * t as f32 / warmup as f32
                } else if total > warmup {
                    let frac =
                        ((t - warmup) as f32 / (total - warmup) as f32).clamp(0.0, 1.0);
                    let cos = 0.5 * (1.0 + (std::f32::consts::PI * frac).cos());
                    base_lr * (floor + (1.0 - floor) * cos)
                } else {
                    base_lr
                }
            }
        }
    }
}

impl LrSchedule {
    /// Stable numeric encoding for the `SMMFCKPT` v2 SCHEDULE section
    /// (docs/CHECKPOINT_FORMAT.md): `(kind tag, a, b, c)`. Unused fields
    /// are zero. Never renumber the tags.
    pub fn encode(&self) -> (u8, u64, u64, f32) {
        match *self {
            LrSchedule::Constant => (0, 0, 0, 0.0),
            LrSchedule::Warmup { warmup } => (1, warmup, 0, 0.0),
            LrSchedule::Linear { warmup, total } => (2, warmup, total, 0.0),
            LrSchedule::InvSqrt { warmup } => (3, warmup, 0, 0.0),
            LrSchedule::Cosine { warmup, total, floor } => (4, warmup, total, floor),
        }
    }

    /// Inverse of [`LrSchedule::encode`]; `None` for unknown tags.
    pub fn decode(tag: u8, a: u64, b: u64, c: f32) -> Option<LrSchedule> {
        Some(match tag {
            0 => LrSchedule::Constant,
            1 => LrSchedule::Warmup { warmup: a },
            2 => LrSchedule::Linear { warmup: a, total: b },
            3 => LrSchedule::InvSqrt { warmup: a },
            4 => LrSchedule::Cosine { warmup: a, total: b, floor: c },
            _ => return None,
        })
    }
}

/// ReduceLROnPlateau (the paper's CNN training scheduler): multiply LR by
/// `factor` when the monitored metric fails to improve for `patience`
/// evaluations.
#[derive(Clone, Debug)]
pub struct ReduceOnPlateau {
    /// Multiplier applied to the LR scale on each reduction (< 1).
    pub factor: f32,
    /// Non-improving evaluations tolerated before reducing.
    pub patience: u32,
    /// Lower bound on the cumulative LR scale.
    pub min_lr: f32,
    best: f32,
    bad_evals: u32,
    /// Current cumulative LR scale (starts at 1.0).
    pub lr_scale: f32,
}

impl ReduceOnPlateau {
    /// A fresh scheduler (scale 1.0, no observations yet).
    pub fn new(factor: f32, patience: u32, min_lr: f32) -> Self {
        Self { factor, patience, min_lr, best: f32::INFINITY, bad_evals: 0, lr_scale: 1.0 }
    }

    /// Report a new (lower-is-better) metric; returns the current LR scale.
    pub fn observe(&mut self, metric: f32) -> f32 {
        if metric < self.best - 1e-6 {
            self.best = metric;
            self.bad_evals = 0;
        } else {
            self.bad_evals += 1;
            if self.bad_evals > self.patience {
                self.lr_scale = (self.lr_scale * self.factor).max(self.min_lr);
                self.bad_evals = 0;
            }
        }
        self.lr_scale
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn beta_schedules_match_paper() {
        assert!((beta1_t(0.9, 0.999, 1) - 0.9).abs() < 1e-7);
        assert!((beta1_t(0.9, 0.999, 2) - 0.9 * 0.999).abs() < 1e-7);
        assert!((beta2_t(-0.5, 1) - 0.0).abs() < 1e-7); // 1 - 1 = 0
        assert!((beta2_t(-0.5, 4) - 0.5).abs() < 1e-7); // 1 - 4^-.5
        assert!((beta2_t(-0.8, 1) - 0.0).abs() < 1e-7);
    }

    #[test]
    fn beta2_monotone_towards_one() {
        let mut prev = 0.0;
        for t in 1..100 {
            let b = beta2_t(-0.8, t);
            assert!(b >= prev && b < 1.0);
            prev = b;
        }
    }

    #[test]
    fn warmup_ramps() {
        let s = LrSchedule::Warmup { warmup: 10 };
        assert!((s.at(1.0, 1) - 0.1).abs() < 1e-6);
        assert!((s.at(1.0, 10) - 1.0).abs() < 1e-6);
        assert!((s.at(1.0, 100) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn linear_decays_to_zero() {
        let s = LrSchedule::Linear { warmup: 2, total: 10 };
        assert!(s.at(1.0, 10) < 1e-6);
        assert!(s.at(1.0, 6) > s.at(1.0, 9));
    }

    #[test]
    fn invsqrt_peaks_at_warmup() {
        let s = LrSchedule::InvSqrt { warmup: 100 };
        let peak = s.at(1.0, 100);
        assert!(s.at(1.0, 50) < peak && s.at(1.0, 400) < peak);
    }

    #[test]
    fn encode_decode_roundtrip() {
        for s in [
            LrSchedule::Constant,
            LrSchedule::Warmup { warmup: 10 },
            LrSchedule::Linear { warmup: 5, total: 100 },
            LrSchedule::InvSqrt { warmup: 400 },
            LrSchedule::Cosine { warmup: 3, total: 50, floor: 0.1 },
        ] {
            let (tag, a, b, c) = s.encode();
            assert_eq!(LrSchedule::decode(tag, a, b, c), Some(s));
        }
        assert_eq!(LrSchedule::decode(99, 0, 0, 0.0), None);
    }

    #[test]
    fn plateau_reduces() {
        let mut p = ReduceOnPlateau::new(0.5, 1, 0.01);
        assert_eq!(p.observe(1.0), 1.0); // improves
        assert_eq!(p.observe(1.0), 1.0); // bad 1 (== patience)
        assert_eq!(p.observe(1.0), 0.5); // bad 2 -> reduce
        assert_eq!(p.observe(0.5), 0.5); // improves again
    }
}
