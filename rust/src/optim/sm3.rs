//! SM3 baseline (Anil, Gupta, Koren, Singer 2019) with momentum.
//!
//! Per rank-d tensor, one accumulator vector per axis (`Σ_r n_r` floats).
//! The effective 2nd moment of element `(i1..id)` is `min_r μ_r[i_r]`;
//! after each step every accumulator is raised to the max of the covered
//! ν values (the min-max cover scheme). A dense momentum buffer (N floats)
//! is kept because the paper runs SM3 with β1 = 0.9 (Appendix L) — which
//! is also why SM3's memory in Table 1 is ≈ half of Adam's, not tiny.
//!
//! The min-max cover couples every element of a tensor through the
//! per-axis accumulators, so the parallel path
//! (`OptimConfig::threads > 1`) shards at tensor granularity — each
//! tensor updated by exactly one worker, bit-identical to the serial
//! walk.

use anyhow::{bail, Result};

use super::blob::{BlobReader, BlobWriter};
use super::group::{self, TensorPolicy};
use super::parallel::{self, ParamPartition, TensorGeom};
use super::{OptimConfig, Optimizer, StateSerde, WeightDecayMode};
use crate::tensor::Tensor;

struct PState {
    shape: Vec<usize>,
    /// One accumulator per axis; empty for stateless/frozen tensors.
    acc: Vec<Vec<f32>>,
    /// Dense momentum (β1 > 0).
    m: Option<Vec<f32>>,
    /// Effective group policy for this tensor. SM3 has no dense-vs-
    /// factored distinction (its covers are already axis-wise), so
    /// `StatePolicy::Dense` behaves like `Factored`; `None`/frozen drop
    /// the state entirely.
    pol: TensorPolicy,
}

pub struct Sm3 {
    cfg: OptimConfig,
    states: Vec<PState>,
    t: u64,
    plan: ParamPartition,
}

impl Sm3 {
    pub fn new(shapes: &[Vec<usize>], cfg: &OptimConfig) -> Sm3 {
        Self::with_policies(shapes, cfg, &vec![TensorPolicy::uniform(cfg); shapes.len()])
    }

    pub fn with_policies(
        shapes: &[Vec<usize>],
        cfg: &OptimConfig,
        policies: &[TensorPolicy],
    ) -> Sm3 {
        assert_eq!(shapes.len(), policies.len());
        let states = shapes
            .iter()
            .zip(policies)
            .map(|(shape, pol)| {
                let numel: usize = shape.iter().product();
                let shape = if shape.is_empty() { vec![1] } else { shape.clone() };
                if pol.stateless() {
                    return PState { acc: Vec::new(), m: None, shape, pol: *pol };
                }
                PState {
                    acc: shape.iter().map(|&d| vec![0.0; d]).collect(),
                    m: (cfg.beta1 > 0.0).then(|| vec![0.0; numel]),
                    shape,
                    pol: *pol,
                }
            })
            .collect();
        let geoms: Vec<TensorGeom> = shapes
            .iter()
            .zip(policies)
            .map(|(s, pol)| {
                TensorGeom::whole(
                    s.iter().product::<usize>().max(1),
                    if pol.stateless() { 1 } else { 4 },
                )
            })
            .collect();
        let plan = ParamPartition::plan(&geoms, cfg.threads);
        Sm3 { cfg: cfg.clone(), states, t: 0, plan }
    }

    /// The whole-tensor kernel (`Send` + stateless over per-tensor state).
    fn update_tensor(cfg: &OptimConfig, p: &mut [f32], g: &[f32], st: &mut PState) {
        if st.pol.frozen {
            return;
        }
        let lr = cfg.lr * st.pol.lr_scale;
        let wd = st.pol.weight_decay;
        if st.pol.stateless() {
            group::stateless_update(p, g, lr, wd, cfg.weight_decay_mode);
            return;
        }
        if wd != 0.0 && cfg.weight_decay_mode == WeightDecayMode::AdamW {
            let f = 1.0 - lr * wd;
            p.iter_mut().for_each(|w| *w *= f);
        }
        let rank = st.shape.len();
        // Per-axis max of ν for the cover update, accumulated this step.
        let mut new_max: Vec<Vec<f32>> = st.shape.iter().map(|&d| vec![0.0; d]).collect();
        // Perf (§Perf): odometer multi-index (increment + carry)
        // instead of div/mod per element, and the min over the leading
        // rank-1 axes hoisted out of the innermost (last-axis) loop.
        let mut idx = vec![0usize; rank];
        let couple = wd != 0.0 && cfg.weight_decay_mode == WeightDecayMode::Adam;
        let last_dim = *st.shape.last().unwrap();
        let n = g.len();
        let mut flat = 0;
        while flat < n {
            // min over the non-last axes is constant across this row
            let mut vmin_head = f32::INFINITY;
            for r in 0..rank - 1 {
                vmin_head = vmin_head.min(st.acc[r][idx[r]]);
            }
            let acc_last = &st.acc[rank - 1];
            let new_last = &mut new_max[rank - 1];
            let mut row_max = 0.0f32; // max ν over this row (other axes)
            for j in 0..last_dim {
                let w = &mut p[flat + j];
                let gij = if couple { g[flat + j] + wd * *w } else { g[flat + j] };
                // ν = min_r μ_r[i_r] + g²
                let nu = vmin_head.min(acc_last[j]) + gij * gij;
                new_last[j] = new_last[j].max(nu);
                row_max = row_max.max(nu);
                let update = gij / (nu.sqrt() + cfg.eps1.max(1e-30));
                if let Some(m) = &mut st.m {
                    let mij = &mut m[flat + j];
                    *mij = cfg.beta1 * *mij + (1.0 - cfg.beta1) * update;
                    *w -= lr * *mij;
                } else {
                    *w -= lr * update;
                }
            }
            for r in 0..rank - 1 {
                let e = &mut new_max[r][idx[r]];
                *e = e.max(row_max);
            }
            // odometer carry over the leading axes
            flat += last_dim;
            for r in (0..rank.saturating_sub(1)).rev() {
                idx[r] += 1;
                if idx[r] < st.shape[r] {
                    break;
                }
                idx[r] = 0;
            }
        }
        st.acc = new_max;
    }
}

impl StateSerde for Sm3 {
    fn opt_step(&self) -> u64 {
        self.t
    }

    fn set_opt_step(&mut self, t: u64) {
        self.t = t;
    }

    /// Blob (docs/CHECKPOINT_FORMAT.md, kind tag 5): `u32 n_axes`, one
    /// length-prefixed per-axis cover accumulator each, then the optional
    /// dense momentum.
    fn state_blob(&self, i: usize) -> Vec<u8> {
        let st = &self.states[i];
        let mut w = BlobWriter::new();
        w.u32(st.acc.len() as u32);
        for axis in &st.acc {
            w.len_prefixed_f32s(axis);
        }
        match &st.m {
            Some(m) => {
                w.u8(1);
                w.len_prefixed_f32s(m);
            }
            None => w.u8(0),
        }
        w.finish()
    }

    fn state_blobs(&self) -> Vec<Vec<u8>> {
        (0..self.states.len()).map(|i| self.state_blob(i)).collect()
    }

    fn load_state_blobs(&mut self, blobs: &[Vec<u8>]) -> Result<()> {
        if blobs.len() != self.states.len() {
            bail!(
                "sm3: checkpoint has {} tensors, optimizer has {}",
                blobs.len(),
                self.states.len()
            );
        }
        for (idx, (blob, st)) in blobs.iter().zip(self.states.iter_mut()).enumerate() {
            let mut r = BlobReader::new(blob);
            let n_axes = r.u32()? as usize;
            if n_axes != st.acc.len() {
                bail!(
                    "sm3 tensor {idx}: checkpoint has {n_axes} axes, optimizer expects {}",
                    st.acc.len()
                );
            }
            for (axis_idx, axis) in st.acc.iter_mut().enumerate() {
                r.expect_len(axis.len(), &format!("sm3 tensor {idx} axis {axis_idx}"))?;
                r.f32s_into(axis)?;
            }
            let has_m = r.u8()?;
            match (has_m, &mut st.m) {
                (1, Some(m)) => {
                    r.expect_len(m.len(), &format!("sm3 tensor {idx} momentum"))?;
                    r.f32s_into(m)?;
                }
                (0, None) => {}
                (has, _) => bail!(
                    "sm3 tensor {idx}: momentum mismatch (checkpoint has_m={has}; \
                     β1 > 0 must agree between save and load configs)"
                ),
            }
            r.finish()?;
        }
        Ok(())
    }
}

impl Optimizer for Sm3 {
    fn name(&self) -> &'static str {
        "sm3"
    }

    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor]) {
        self.t += 1;
        if self.cfg.threads <= 1 {
            let cfg = self.cfg.clone();
            for ((param, grad), st) in params.iter_mut().zip(grads).zip(self.states.iter_mut()) {
                Self::update_tensor(&cfg, param.data_mut(), grad.data(), st);
            }
            return;
        }
        let cfg = self.cfg.clone();
        let ctxs = vec![(); self.plan.n_shards()];
        parallel::run_per_tensor(&self.plan, params, grads, &mut self.states, ctxs, |_, p, g, st| {
            Self::update_tensor(&cfg, p, g, st);
        });
    }

    fn set_lr(&mut self, lr: f32) {
        self.cfg.lr = lr;
    }

    fn state_bytes(&self) -> u64 {
        self.states
            .iter()
            .map(|s| {
                let acc: usize = s.acc.iter().map(|a| a.len()).sum();
                ((acc + s.m.as_ref().map_or(0, |m| m.len())) * 4) as u64
            })
            .sum()
    }

    fn partition(&self) -> Option<&ParamPartition> {
        Some(&self.plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn memory_is_axis_sums_plus_momentum() {
        let cfg = OptimConfig::default(); // beta1 = 0.9 -> momentum kept
        let s = Sm3::new(&[vec![10, 20, 30]], &cfg);
        assert_eq!(s.state_bytes(), (((10 + 20 + 30) + 6000) * 4) as u64);
        let cfg0 = OptimConfig { beta1: 0.0, ..OptimConfig::default() };
        let s0 = Sm3::new(&[vec![10, 20, 30]], &cfg0);
        assert_eq!(s0.state_bytes(), ((10 + 20 + 30) * 4) as u64);
    }

    #[test]
    fn accumulators_cover_squared_gradients() {
        // After one step with g, ν for each coordinate >= g², so each axis
        // accumulator >= max row/col g².
        let mut opt = Sm3::new(&[vec![2, 2]], &OptimConfig { beta1: 0.0, ..Default::default() });
        let mut p = vec![Tensor::zeros(&[2, 2])];
        let g = vec![Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0])];
        opt.step(&mut p, &g);
        let acc0 = &opt.states[0].acc[0];
        let acc1 = &opt.states[0].acc[1];
        assert!((acc0[0] - 4.0).abs() < 1e-6); // row 0 max g² = 2²
        assert!((acc0[1] - 16.0).abs() < 1e-6); // row 1 max = 4²
        assert!((acc1[0] - 9.0).abs() < 1e-6); // col 0 max = 3²
        assert!((acc1[1] - 16.0).abs() < 1e-6);
    }

    #[test]
    fn quadratic_convergence() {
        // SM3 is Adagrad-like: the accumulators only grow, so the
        // effective step decays as 1/sqrt(sum g²) — convergence needs
        // more iterations than Adam at the same lr.
        let cfg = OptimConfig { lr: 0.1, ..Default::default() };
        let mut opt = Sm3::new(&[vec![5]], &cfg);
        let mut p = vec![Tensor::from_vec(&[5], vec![2.0, -1.5, 3.0, -0.5, 1.0])];
        for _ in 0..3000 {
            let mut g = p[0].clone();
            g.scale(2.0);
            opt.step(&mut p, &[g]);
        }
        assert!(p[0].max_abs() < 0.15, "{:?}", p[0].data());
    }

    #[test]
    fn scalar_tensor_ok() {
        let mut opt = Sm3::new(&[vec![]], &OptimConfig::default());
        let mut p = vec![Tensor::scalar(4.0)];
        let g = vec![Tensor::scalar(1.0)];
        opt.step(&mut p, &g);
        assert!(p[0].data()[0] < 4.0);
    }

    #[test]
    fn parallel_is_bit_identical_to_serial() {
        use crate::util::rng::Pcg32;
        let shapes = vec![vec![13, 5, 3], vec![100], vec![], vec![8, 8]];
        let mut rng = Pcg32::new(17);
        let init: Vec<Tensor> = shapes
            .iter()
            .map(|s| {
                let mut t = Tensor::zeros(s);
                rng.fill_normal(t.data_mut(), 0.5);
                t
            })
            .collect();
        let grads: Vec<Vec<Tensor>> = (0..3)
            .map(|_| {
                shapes
                    .iter()
                    .map(|s| {
                        let mut t = Tensor::zeros(s);
                        rng.fill_normal(t.data_mut(), 0.1);
                        t
                    })
                    .collect()
            })
            .collect();
        let run = |threads: usize| -> Vec<Tensor> {
            let cfg = OptimConfig { lr: 0.1, threads, ..Default::default() };
            let mut opt = Sm3::new(&shapes, &cfg);
            let mut p = init.clone();
            for g in &grads {
                opt.step(&mut p, g);
            }
            p
        };
        let serial = run(1);
        assert_eq!(serial, run(2));
        assert_eq!(serial, run(4));
    }
}
