//! SMMF — Square-Matricized Momentum Factorization (the paper).
//!
//! Per parameter tensor the persistent state is `r_m, c_m` (1st-momentum
//! factors), a bit-packed sign matrix `S_M`, and `r_v, c_v` (2nd-momentum
//! factors): `2(n̂+m̂)` floats + `n̂·m̂` bits for a tensor of `n̂·m̂`
//! elements — versus Adam's `2·n̂·m̂` floats.
//!
//! Three step implementations:
//!
//! * [`Smmf::step`] with `threads == 1` — the fused **serial** path:
//!   decompression, moment update, re-compression reductions, update term
//!   and parameter write happen in a *single pass* over each row of the
//!   matricized view, with O(n̂+m̂) scratch. The full moment matrices are
//!   never materialized — this beats even the paper's reference
//!   implementation, whose temporary memory is O(n̂·m̂) (Appendix G).
//! * [`Smmf::step`] with `threads > 1` — the same fused kernel dispatched
//!   over the [`super::parallel`] engine: the matricized view is split
//!   into contiguous row ranges (sign-word aligned), each work item runs
//!   the kernel over its rows with private column accumulators
//!   (`acc_cm`/`acc_cv`), and the partials are reduced in fixed item
//!   order before `nnmf::normalize_side`. For a fixed shard plan the
//!   result is bit-identical no matter how many workers execute it; the
//!   plan's item boundaries are thread-count independent, so any
//!   `threads >= 2` produce bit-identical trajectories, and `threads = 1`
//!   (one item per tensor) reproduces the serial path exactly.
//! * [`Smmf::step_naive`] — a literal transcription of Algorithms 1/3/4
//!   that materializes M and V; kept for differential testing and the
//!   perf ablation bench.

use anyhow::{anyhow, bail, Result};

use super::blob::{BlobReader, BlobWriter};
use super::group::{self, StatePolicy, TensorPolicy};
use super::matricize::{effective_shape, squeezed_rank};
use super::nnmf;
use super::parallel::{self, ParamPartition, TensorGeom, WorkItem};
use super::schedule::{beta1_t, beta2_t};
use super::{MatricizeMode, OptimConfig, Optimizer, SignMode, SmmfScheme, StateSerde, WeightDecayMode};
use crate::tensor::{word_chunk_get64, word_chunk_set64, BitMatrix, Tensor};

/// Sign-matrix storage: 1-bit packed (the paper's memory claim) or one
/// byte per element (the "8-bit S_M" timing variant of Table 5).
pub enum SignStore {
    Bits(BitMatrix),
    Bytes(Vec<u8>),
}

/// A mutable view over the sign storage of a contiguous row range of one
/// tensor (bit/byte index 0 = first element of the range). Row-range
/// views are storage-disjoint — for the 1-bit store this requires splits
/// on 64-bit word edges, which [`SignStore::row_align`] guarantees.
pub enum SignViewMut<'a> {
    Bits(&'a mut [u64]),
    Bytes(&'a mut [u8]),
}

impl SignViewMut<'_> {
    /// Read `len` (<=64) sign bits starting at `start` into a word.
    #[inline]
    fn get_chunk64(&self, start: usize, len: usize) -> u64 {
        match self {
            SignViewMut::Bits(words) => word_chunk_get64(words, start),
            SignViewMut::Bytes(v) => {
                let mut bits = 0u64;
                for (k, &byte) in v[start..start + len].iter().enumerate() {
                    bits |= ((byte != 0) as u64) << k;
                }
                bits
            }
        }
    }

    /// Write `len` (<=64) sign bits starting at `start` from a word.
    #[inline]
    fn set_chunk64(&mut self, start: usize, bits: u64, len: usize) {
        match self {
            SignViewMut::Bits(words) => word_chunk_set64(words, start, bits, len),
            SignViewMut::Bytes(v) => {
                for (k, byte) in v[start..start + len].iter_mut().enumerate() {
                    *byte = ((bits >> k) & 1) as u8;
                }
            }
        }
    }
}

fn gcd(mut a: usize, mut b: usize) -> usize {
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a
}

impl SignStore {
    fn new(mode: SignMode, n: usize, m: usize) -> SignStore {
        match mode {
            SignMode::Bit1 => SignStore::Bits(BitMatrix::zeros(n, m)),
            SignMode::Byte8 => SignStore::Bytes(vec![0u8; n * m]),
        }
    }

    fn heap_bytes(&self) -> u64 {
        match self {
            SignStore::Bits(b) => b.heap_bytes() as u64,
            SignStore::Bytes(v) => v.len() as u64,
        }
    }

    #[inline]
    fn get(&self, idx: usize) -> bool {
        match self {
            SignStore::Bits(b) => b.get(idx),
            SignStore::Bytes(v) => v[idx] != 0,
        }
    }

    #[inline]
    fn set(&mut self, idx: usize, val: bool) {
        match self {
            SignStore::Bits(b) => b.set(idx, val),
            SignStore::Bytes(v) => v[idx] = val as u8,
        }
    }

    /// Minimum row granularity for storage-disjoint row-range views: the
    /// 1-bit store requires range boundaries on 64-bit word edges, i.e.
    /// row indices that are multiples of `64 / gcd(m, 64)`.
    fn row_align(mode: SignMode, m: usize) -> usize {
        match mode {
            SignMode::Bit1 => 64 / gcd(m.max(1), 64),
            SignMode::Byte8 => 1,
        }
    }

    /// View over the whole matrix (the serial path).
    fn view_all(&mut self) -> SignViewMut<'_> {
        match self {
            SignStore::Bits(b) => SignViewMut::Bits(b.words_mut()),
            SignStore::Bytes(v) => SignViewMut::Bytes(v),
        }
    }

    /// One disjoint view per work item (items tile the rows; interior
    /// boundaries are `row_align`-aligned by the shard planner).
    fn views_mut<'a>(&'a mut self, items: &[WorkItem], m: usize) -> Vec<SignViewMut<'a>> {
        match self {
            SignStore::Bits(b) => {
                let mut words: &mut [u64] = b.words_mut();
                let mut out = Vec::with_capacity(items.len());
                let mut consumed = 0usize; // words handed out so far
                for (i, it) in items.iter().enumerate() {
                    let take = if i + 1 == items.len() {
                        words.len()
                    } else {
                        let bit_end = it.row1 * m;
                        debug_assert_eq!(bit_end % 64, 0, "unaligned sign split");
                        bit_end / 64 - consumed
                    };
                    let (head, rest) = words.split_at_mut(take);
                    out.push(SignViewMut::Bits(head));
                    words = rest;
                    consumed += take;
                }
                out
            }
            SignStore::Bytes(v) => {
                let mut bytes: &mut [u8] = v;
                let mut out = Vec::with_capacity(items.len());
                for it in items {
                    let (head, rest) = bytes.split_at_mut((it.row1 - it.row0) * m);
                    out.push(SignViewMut::Bytes(head));
                    bytes = rest;
                }
                out
            }
        }
    }
}

enum State {
    /// Factorized (square-matricized) state.
    Factored {
        n: usize,
        m: usize,
        r_m: Vec<f32>,
        c_m: Vec<f32>,
        sign: SignStore,
        r_v: Vec<f32>,
        c_v: Vec<f32>,
    },
    /// Dense Adam-style moments: rank-1 tensors when
    /// `vector_reshape = false`, or any tensor whose group declares
    /// `StatePolicy::Dense`.
    Dense { m: Vec<f32>, v: Vec<f32> },
    /// No persistent state (`StatePolicy::None` or frozen groups): the
    /// update degenerates to plain `w -= lr · g` (frozen: no update).
    Stateless,
}

impl State {
    fn bytes(&self) -> u64 {
        match self {
            State::Factored { r_m, c_m, sign, r_v, c_v, .. } => {
                (4 * (r_m.len() + c_m.len() + r_v.len() + c_v.len())) as u64
                    + sign.heap_bytes()
            }
            State::Dense { m, v } => (4 * (m.len() + v.len())) as u64,
            State::Stateless => 0,
        }
    }
}

/// Per-work-item scratch for the parallel path: private column
/// accumulators (reduced after the join) and a weight-decay gradient
/// buffer (Adam-coupled decay only; lazily grown).
#[derive(Default)]
struct ItemScratch {
    acc_cm: Vec<f32>,
    acc_cv: Vec<f32>,
    g_wd: Vec<f32>,
}

pub struct Smmf {
    cfg: OptimConfig,
    /// Effective per-tensor policy resolved from the group table.
    policies: Vec<TensorPolicy>,
    states: Vec<State>,
    t: u64,
    /// Static shard plan over the matricized views (see `optim::parallel`).
    plan: ParamPartition,
    /// Reusable per-step scratch: column accumulators sized to max m̂.
    scratch_cm: Vec<f32>,
    scratch_cv: Vec<f32>,
    /// Parallel-path per-item scratch (empty when `threads == 1`).
    item_scratch: Vec<ItemScratch>,
    /// Scratch for the naive path (lazily grown; only used by step_naive)
    /// and the compress-first ablation.
    scratch_mat: Vec<f32>,
    scratch_mat2: Vec<f32>,
}

impl Smmf {
    pub fn new(shapes: &[Vec<usize>], cfg: &OptimConfig) -> Smmf {
        Self::with_policies(shapes, cfg, &vec![TensorPolicy::uniform(cfg); shapes.len()])
    }

    pub fn with_policies(
        shapes: &[Vec<usize>],
        cfg: &OptimConfig,
        policies: &[TensorPolicy],
    ) -> Smmf {
        assert_eq!(shapes.len(), policies.len());
        let mut max_m = 0;
        let mut geoms = Vec::with_capacity(shapes.len());
        let states: Vec<State> = shapes
            .iter()
            .zip(policies)
            .map(|(shape, pol)| {
                let numel: usize = shape.iter().product();
                assert!(numel > 0, "empty tensor {shape:?}");
                if pol.stateless() {
                    geoms.push(TensorGeom::elementwise(numel, 1));
                    State::Stateless
                } else if pol.state == StatePolicy::Dense
                    || (squeezed_rank(shape) == 1 && !cfg.vector_reshape)
                {
                    geoms.push(TensorGeom::elementwise(numel, 4));
                    State::Dense { m: vec![0.0; numel], v: vec![0.0; numel] }
                } else {
                    let (n, m) = match cfg.smmf_matricize {
                        MatricizeMode::Square => effective_shape(numel),
                        // Ablation: Adafactor/CAME-style last-axis fold.
                        MatricizeMode::FoldLast => {
                            let last = *shape.last().unwrap();
                            (numel / last, last)
                        }
                    };
                    max_m = max_m.max(m);
                    geoms.push(TensorGeom {
                        rows: n,
                        cols: m,
                        align: SignStore::row_align(cfg.smmf_sign_mode, m),
                        cost_per_elem: 8,
                    });
                    State::Factored {
                        n,
                        m,
                        r_m: vec![0.0; n],
                        c_m: vec![0.0; m],
                        sign: SignStore::new(cfg.smmf_sign_mode, n, m),
                        r_v: vec![0.0; n],
                        c_v: vec![0.0; m],
                    }
                }
            })
            .collect();
        // The compress-first ablation needs a whole-tensor gradient
        // pre-pass, so it stays on the serial path (no item scratch) and
        // plans serially too, so `partition()` reflects what actually runs.
        let engine_threads =
            if cfg.smmf_scheme == SmmfScheme::DecompressFirst { cfg.threads } else { 1 };
        let plan = ParamPartition::plan(&geoms, engine_threads);
        let item_scratch: Vec<ItemScratch> =
            if engine_threads > 1 {
                plan.items()
                    .iter()
                    .map(|it| match &states[it.tensor] {
                        State::Factored { m, .. } => ItemScratch {
                            acc_cm: vec![0.0; *m],
                            acc_cv: vec![0.0; *m],
                            g_wd: Vec::new(),
                        },
                        State::Dense { .. } | State::Stateless => ItemScratch::default(),
                    })
                    .collect()
            } else {
                Vec::new()
            };
        Smmf {
            cfg: cfg.clone(),
            policies: policies.to_vec(),
            states,
            t: 0,
            plan,
            scratch_cm: vec![0.0; max_m],
            scratch_cv: vec![0.0; max_m],
            item_scratch,
            scratch_mat: Vec::new(),
            scratch_mat2: Vec::new(),
        }
    }

    /// The paper's β schedules at the current step.
    fn betas(&self, t: u64) -> (f32, f32) {
        (
            beta1_t(self.cfg.beta1, self.cfg.growth_rate, t),
            beta2_t(self.cfg.decay_rate, t),
        )
    }

    /// Serial fused path (exactly the pre-engine behavior): one work unit
    /// per tensor, column accumulators folded in place.
    fn step_serial(&mut self, params: &mut [Tensor], grads: &[Tensor], beta_m: f32, beta_v: f32) {
        let cfg = self.cfg.clone();
        let mut g_wd: Vec<f32> = Vec::new();
        for (idx, (param, grad)) in params.iter_mut().zip(grads).enumerate() {
            debug_assert_eq!(param.numel(), grad.numel());
            let pol = self.policies[idx];
            if pol.frozen {
                continue;
            }
            let lr = cfg.lr * pol.lr_scale;
            let wd = pol.weight_decay;
            let p = param.data_mut();
            if matches!(self.states[idx], State::Stateless) {
                group::stateless_update(p, grad.data(), lr, wd, cfg.weight_decay_mode);
                continue;
            }
            let g = effective_grad(p, grad.data(), wd, cfg.weight_decay_mode, lr, &mut g_wd);
            match &mut self.states[idx] {
                State::Factored { n, m, r_m, c_m, sign, r_v, c_v } => {
                    let (n, m) = (*n, *m);
                    let g: &[f32] = if cfg.smmf_scheme == SmmfScheme::CompressFirst {
                        Self::compress_then_decompress(g, n, m, &mut self.scratch_mat);
                        &self.scratch_mat
                    } else {
                        g
                    };
                    let mut view = sign.view_all();
                    fused_rows(
                        p,
                        g,
                        n,
                        m,
                        r_m,
                        c_m,
                        &mut view,
                        r_v,
                        c_v,
                        beta_m,
                        beta_v,
                        lr,
                        cfg.eps1,
                        &mut self.scratch_cm,
                        &mut self.scratch_cv,
                    );
                    c_m.copy_from_slice(&self.scratch_cm[..m]);
                    c_v.copy_from_slice(&self.scratch_cv[..m]);
                    nnmf::normalize_side(n, m, r_m, c_m);
                    nnmf::normalize_side(n, m, r_v, c_v);
                }
                State::Dense { m, v } => {
                    dense_update(p, g, m, v, beta_m, beta_v, lr, cfg.eps1);
                }
                State::Stateless => unreachable!("handled above"),
            }
        }
    }

    /// Parallel fused path: dispatch the shard plan over the worker pool,
    /// then reduce the per-item column partials in fixed item order.
    fn step_parallel(&mut self, params: &mut [Tensor], grads: &[Tensor], beta_m: f32, beta_v: f32) {
        enum Task<'a> {
            Factored {
                p: &'a mut [f32],
                g: &'a [f32],
                rows: usize,
                m: usize,
                r_m: &'a mut [f32],
                r_v: &'a mut [f32],
                c_m: &'a [f32],
                c_v: &'a [f32],
                sign: SignViewMut<'a>,
                acc_cm: &'a mut [f32],
                acc_cv: &'a mut [f32],
                g_wd: &'a mut Vec<f32>,
                lr: f32,
                wd: f32,
            },
            Dense {
                p: &'a mut [f32],
                g: &'a [f32],
                mom: &'a mut [f32],
                vel: &'a mut [f32],
                g_wd: &'a mut Vec<f32>,
                lr: f32,
                wd: f32,
            },
            Stateless {
                p: &'a mut [f32],
                g: &'a [f32],
                lr: f32,
                wd: f32,
            },
            /// Frozen tensors: the item exists (plans tile every tensor)
            /// but the worker does nothing.
            Skip,
        }

        let plan = &self.plan;
        let states = &mut self.states;
        let policies = &self.policies;
        let item_scratch = &mut self.item_scratch;
        let (lr_base, eps, wd_mode) = (self.cfg.lr, self.cfg.eps1, self.cfg.weight_decay_mode);

        {
            let mut tasks: Vec<Task<'_>> = Vec::with_capacity(plan.n_items());
            let mut scratch_iter = item_scratch.iter_mut();
            // Square-matricization phase: carve every tensor's flat
            // storage into the plan's row-range items.
            let matricize = crate::obs::trace::span("optim", "optim.matricize");
            for (idx, ((param, grad), state)) in
                params.iter_mut().zip(grads).zip(states.iter_mut()).enumerate()
            {
                debug_assert_eq!(param.numel(), grad.numel());
                let pol = policies[idx];
                let lr = lr_base * pol.lr_scale;
                let wd = pol.weight_decay;
                let items = plan.items_of(idx);
                let p_full = param.data_mut();
                let g_full = grad.data();
                match state {
                    State::Factored { m, r_m, c_m, sign, r_v, c_v, .. } => {
                        let m = *m;
                        let p_parts = parallel::split_rows_mut(p_full, items, m);
                        let rm_parts = parallel::split_rows_mut(r_m, items, 1);
                        let rv_parts = parallel::split_rows_mut(r_v, items, 1);
                        let sign_views = sign.views_mut(items, m);
                        let c_m_ro: &[f32] = c_m;
                        let c_v_ro: &[f32] = c_v;
                        for ((((it, p), rm), rv), sv) in items
                            .iter()
                            .zip(p_parts)
                            .zip(rm_parts)
                            .zip(rv_parts)
                            .zip(sign_views)
                        {
                            let scr = scratch_iter.next().expect("one scratch per item");
                            tasks.push(Task::Factored {
                                p,
                                g: &g_full[it.row0 * m..it.row1 * m],
                                rows: it.row1 - it.row0,
                                m,
                                r_m: rm,
                                r_v: rv,
                                c_m: c_m_ro,
                                c_v: c_v_ro,
                                sign: sv,
                                acc_cm: &mut scr.acc_cm,
                                acc_cv: &mut scr.acc_cv,
                                g_wd: &mut scr.g_wd,
                                lr,
                                wd,
                            });
                        }
                    }
                    State::Dense { m: mom, v: vel } => {
                        let p_parts = parallel::split_rows_mut(p_full, items, 1);
                        let m_parts = parallel::split_rows_mut(mom, items, 1);
                        let v_parts = parallel::split_rows_mut(vel, items, 1);
                        for (((it, p), mm), vv) in
                            items.iter().zip(p_parts).zip(m_parts).zip(v_parts)
                        {
                            let scr = scratch_iter.next().expect("one scratch per item");
                            tasks.push(Task::Dense {
                                p,
                                g: &g_full[it.row0..it.row1],
                                mom: mm,
                                vel: vv,
                                g_wd: &mut scr.g_wd,
                                lr,
                                wd,
                            });
                        }
                    }
                    State::Stateless if pol.frozen => {
                        for _ in items {
                            let _ = scratch_iter.next().expect("one scratch per item");
                            tasks.push(Task::Skip);
                        }
                    }
                    State::Stateless => {
                        let p_parts = parallel::split_rows_mut(p_full, items, 1);
                        for (it, p) in items.iter().zip(p_parts) {
                            let _ = scratch_iter.next().expect("one scratch per item");
                            tasks.push(Task::Stateless {
                                p,
                                g: &g_full[it.row0..it.row1],
                                lr,
                                wd,
                            });
                        }
                    }
                }
            }

            drop(matricize);

            let mut shards = parallel::into_shards(plan, vec![(); plan.n_shards()], tasks);
            parallel::run_shards(&mut shards, |_, task| match task {
                Task::Factored {
                    p, g, rows, m, r_m, r_v, c_m, c_v, sign, acc_cm, acc_cv, g_wd, lr, wd,
                } => {
                    // NNMF factor update + sign-plane pack + write-back,
                    // fused over this item's rows.
                    let _span = crate::obs::trace::span("optim", "optim.factor_update");
                    let g = effective_grad(p, g, *wd, wd_mode, *lr, g_wd);
                    fused_rows(
                        p, g, *rows, *m, r_m, c_m, sign, r_v, c_v, beta_m, beta_v, *lr, eps,
                        acc_cm, acc_cv,
                    );
                }
                Task::Dense { p, g, mom, vel, g_wd, lr, wd } => {
                    let _span = crate::obs::trace::span("optim", "optim.dense_update");
                    let g = effective_grad(p, g, *wd, wd_mode, *lr, g_wd);
                    dense_update(p, g, mom, vel, beta_m, beta_v, *lr, eps);
                }
                Task::Stateless { p, g, lr, wd } => {
                    let _span = crate::obs::trace::span("optim", "optim.stateless_update");
                    group::stateless_update(p, g, *lr, *wd, wd_mode);
                }
                Task::Skip => {}
            });
        }

        // Reduce the per-item column partials in fixed (tensor, row0)
        // order — deterministic for a fixed shard plan — then fold into
        // the factors and normalize.
        let _span = crate::obs::trace::span("optim", "optim.reduce_normalize");
        let mut item_idx = 0usize;
        for (idx, state) in states.iter_mut().enumerate() {
            let n_items = plan.items_of(idx).len();
            if let State::Factored { n, m, r_m, c_m, r_v, c_v, .. } = state {
                let (n, m) = (*n, *m);
                let cm_acc = &mut self.scratch_cm[..m];
                let cv_acc = &mut self.scratch_cv[..m];
                cm_acc.copy_from_slice(&item_scratch[item_idx].acc_cm);
                cv_acc.copy_from_slice(&item_scratch[item_idx].acc_cv);
                for scr in &item_scratch[item_idx + 1..item_idx + n_items] {
                    for j in 0..m {
                        cm_acc[j] += scr.acc_cm[j];
                        cv_acc[j] += scr.acc_cv[j];
                    }
                }
                c_m.copy_from_slice(cm_acc);
                c_v.copy_from_slice(cv_acc);
                nnmf::normalize_side(n, m, r_m, c_m);
                nnmf::normalize_side(n, m, r_v, c_v);
            }
            item_idx += n_items;
        }
    }

    /// Literal Algorithms 1/3/4 with materialized M, V (differential
    /// oracle + perf ablation baseline).
    pub fn step_naive(&mut self, params: &mut [Tensor], grads: &[Tensor]) {
        self.t += 1;
        let (beta_m, beta_v) = self.betas(self.t);
        let cfg = self.cfg.clone();
        let mut g_wd: Vec<f32> = Vec::new();
        for (idx, (param, grad)) in params.iter_mut().zip(grads).enumerate() {
            let pol = self.policies[idx];
            if pol.frozen {
                continue;
            }
            let lr = cfg.lr * pol.lr_scale;
            let wd = pol.weight_decay;
            let p = param.data_mut();
            if matches!(self.states[idx], State::Stateless) {
                group::stateless_update(p, grad.data(), lr, wd, cfg.weight_decay_mode);
                continue;
            }
            let g = effective_grad(p, grad.data(), wd, cfg.weight_decay_mode, lr, &mut g_wd);
            match &mut self.states[idx] {
                State::Factored { n, m, r_m, c_m, sign, r_v, c_v } => {
                    let (n, m) = (*n, *m);
                    self.scratch_mat.resize(n * m, 0.0);
                    self.scratch_mat2.resize(n * m, 0.0);
                    let mm = &mut self.scratch_mat;
                    let vv = &mut self.scratch_mat2;
                    // Decompression (Algorithm 3).
                    crate::tensor::mat::outer(r_m, c_m, mm);
                    for (idx2, x) in mm.iter_mut().enumerate() {
                        if !sign.get(idx2) {
                            *x = -*x;
                        }
                    }
                    nnmf::decompress(r_v, c_v, None, vv);
                    // Moment update.
                    for ((mij, vij), &gij) in mm.iter_mut().zip(vv.iter_mut()).zip(g) {
                        *mij = beta_m * *mij + (1.0 - beta_m) * gij;
                        *vij = beta_v * *vij + (1.0 - beta_v) * gij * gij;
                    }
                    // Compression (Algorithm 4).
                    for (idx2, &x) in mm.iter().enumerate() {
                        sign.set(idx2, x > 0.0);
                    }
                    let abs_m: Vec<f32> = mm.iter().map(|x| x.abs()).collect();
                    nnmf::compress(&abs_m, n, m, r_m, c_m);
                    nnmf::compress(vv, n, m, r_v, c_v);
                    // Weight update.
                    for ((w, &mij), &vij) in p.iter_mut().zip(mm.iter()).zip(vv.iter()) {
                        *w -= lr * (mij / (vij.sqrt() + cfg.eps1));
                    }
                }
                State::Dense { m, v } => {
                    dense_update(p, g, m, v, beta_m, beta_v, lr, cfg.eps1);
                }
                State::Stateless => unreachable!("handled above"),
            }
        }
    }

    pub fn step_count(&self) -> u64 {
        self.t
    }

    /// Ablation (§3.2): the compression→decompression ordering used by
    /// existing memory-efficient optimizers — the gradient itself is
    /// factorized to rank-1 (+ signs) *before* it reaches the moments, so
    /// the intact-gradient information the paper's scheme preserves is
    /// destroyed. `out` receives the reconstructed Ĝ.
    fn compress_then_decompress(g: &[f32], n: usize, m: usize, out: &mut Vec<f32>) {
        let mut r = vec![0.0f32; n];
        let mut c = vec![0.0f32; m];
        out.resize(n * m, 0.0);
        for i in 0..n {
            let row = &g[i * m..(i + 1) * m];
            let mut rs = 0.0f32;
            for (j, &x) in row.iter().enumerate() {
                let a = x.abs();
                rs += a;
                c[j] += a;
            }
            r[i] = rs;
        }
        nnmf::normalize_side(n, m, &mut r, &mut c);
        for i in 0..n {
            for j in 0..m {
                let v = r[i] * c[j];
                out[i * m + j] = if g[i * m + j] > 0.0 { v } else { -v };
            }
        }
    }
}

/// The fused decompress→update→compress kernel over a contiguous row
/// range of one matricized tensor (`rows` rows of `m` columns). Column
/// factors are read-only inputs; the caller owns the column-accumulator
/// reduction and `normalize_side`. This single kernel serves both the
/// serial path (one range covering all rows) and the parallel path (one
/// range per work item), so the two compute identical per-row arithmetic.
#[allow(clippy::too_many_arguments)]
fn fused_rows(
    p: &mut [f32],
    g: &[f32],
    rows: usize,
    m: usize,
    r_m: &mut [f32],
    c_m: &[f32],
    sign: &mut SignViewMut<'_>,
    r_v: &mut [f32],
    c_v: &[f32],
    beta_m: f32,
    beta_v: f32,
    lr: f32,
    eps: f32,
    acc_cm: &mut [f32],
    acc_cv: &mut [f32],
) {
    debug_assert_eq!(p.len(), rows * m);
    debug_assert_eq!(g.len(), rows * m);
    let one_m = 1.0 - beta_m;
    let one_v = 1.0 - beta_v;
    let acc_cm = &mut acc_cm[..m];
    let acc_cv = &mut acc_cv[..m];
    acc_cm.iter_mut().for_each(|x| *x = 0.0);
    acc_cv.iter_mut().for_each(|x| *x = 0.0);

    for i in 0..rows {
        let ri_m = r_m[i];
        let ri_v = r_v[i];
        let row_p = &mut p[i * m..(i + 1) * m];
        let row_g = &g[i * m..(i + 1) * m];
        let mut rsum_m = 0.0f32;
        let mut rsum_v = 0.0f32;
        let base = i * m;
        // Perf (§Perf in EXPERIMENTS.md): process 64-column chunks so
        // the sign matrix is touched one word at a time, and keep the
        // arithmetic branchless (sign via ±1 multiplier, bit build via
        // bool cast) so the compiler can vectorize the FP work.
        let mut m_buf = [0.0f32; 64];
        let mut v_buf = [0.0f32; 64];
        let mut j0 = 0;
        while j0 < m {
            let len = (m - j0).min(64);
            let old_bits = sign.get_chunk64(base + j0, len);
            // Phase 1 (vectorizable): decompress M̂/V̂ from the factors
            // (sign-restored; bit=1 means positive) and apply the
            // moment update with the intact gradient
            // (decompression→compression scheme, §3.2).
            for k in 0..len {
                let j = j0 + k;
                let s = f32::from_bits(
                    0x3f80_0000 | ((((old_bits >> k) & 1) ^ 1) as u32) << 31,
                );
                let gij = row_g[j];
                m_buf[k] = beta_m * (ri_m * c_m[j] * s) + one_m * gij;
                v_buf[k] = beta_v * (ri_v * c_v[j]) + one_v * gij * gij;
            }
            // Phase 2: sign capture (integer bit chain, no FP).
            let mut new_bits = 0u64;
            for (k, &mk) in m_buf[..len].iter().enumerate() {
                new_bits |= ((mk > 0.0) as u64) << k;
            }
            sign.set_chunk64(base + j0, new_bits, len);
            // Phase 3 (vectorizable): update term + parameter write;
            // |M| computed once and reused by both reductions.
            for k in 0..len {
                let j = j0 + k;
                row_p[j] -= lr * (m_buf[k] / (v_buf[k].sqrt() + eps));
                m_buf[k] = m_buf[k].abs();
                acc_cm[j] += m_buf[k];
                acc_cv[j] += v_buf[k];
            }
            // Phase 4: row reductions with 4-way partials (breaks the
            // serial FP dependence chain).
            let (mut a0, mut a1, mut a2, mut a3) = (0.0f32, 0.0, 0.0, 0.0);
            let (mut b0, mut b1, mut b2, mut b3) = (0.0f32, 0.0, 0.0, 0.0);
            let mut k = 0;
            while k + 4 <= len {
                a0 += m_buf[k];
                a1 += m_buf[k + 1];
                a2 += m_buf[k + 2];
                a3 += m_buf[k + 3];
                b0 += v_buf[k];
                b1 += v_buf[k + 1];
                b2 += v_buf[k + 2];
                b3 += v_buf[k + 3];
                k += 4;
            }
            while k < len {
                a0 += m_buf[k];
                b0 += v_buf[k];
                k += 1;
            }
            rsum_m += (a0 + a1) + (a2 + a3);
            rsum_v += (b0 + b1) + (b2 + b3);
            j0 += len;
        }
        r_m[i] = rsum_m;
        r_v[i] = rsum_v;
    }
}

/// Weight decay over one chunk, shared by every step path (serial and
/// naive: the whole tensor; parallel: one work item's rows — identical
/// element arithmetic either way). AdamW decay scales the parameters in
/// place and returns the gradient unchanged; Adam-coupled decay
/// materializes the effective gradient into the caller's reusable buffer.
fn effective_grad<'a>(
    p: &mut [f32],
    g: &'a [f32],
    wd: f32,
    mode: WeightDecayMode,
    lr: f32,
    g_wd: &'a mut Vec<f32>,
) -> &'a [f32] {
    if wd == 0.0 {
        return g;
    }
    match mode {
        WeightDecayMode::Adam => {
            g_wd.clear();
            g_wd.extend(g.iter().zip(p.iter()).map(|(&gij, &w)| gij + wd * w));
            g_wd
        }
        WeightDecayMode::AdamW => {
            let f = 1.0 - lr * wd;
            p.iter_mut().for_each(|w| *w *= f);
            g
        }
    }
}

fn dense_update(
    p: &mut [f32],
    g: &[f32],
    m: &mut [f32],
    v: &mut [f32],
    beta_m: f32,
    beta_v: f32,
    lr: f32,
    eps: f32,
) {
    for (((w, &gij), mij), vij) in p.iter_mut().zip(g).zip(m.iter_mut()).zip(v.iter_mut()) {
        *mij = beta_m * *mij + (1.0 - beta_m) * gij;
        *vij = beta_v * *vij + (1.0 - beta_v) * gij * gij;
        *w -= lr * (*mij / (vij.sqrt() + eps));
    }
}

impl StateSerde for Smmf {
    fn opt_step(&self) -> u64 {
        self.t
    }

    fn set_opt_step(&mut self, t: u64) {
        self.t = t;
    }

    /// Native blob (docs/CHECKPOINT_FORMAT.md, kind tag 7): the factor
    /// vectors as f32 plus the sign plane in its stored width — the
    /// momenta are *never* densified, so an SMMF checkpoint stays
    /// `2(n̂+m̂)` floats + `n̂·m̂` bits per tensor.
    fn state_blob(&self, i: usize) -> Vec<u8> {
        let mut w = BlobWriter::new();
        match &self.states[i] {
            State::Factored { n, m, r_m, c_m, sign, r_v, c_v } => {
                w.u8(1);
                w.u32(*n as u32);
                w.u32(*m as u32);
                w.f32s(r_m);
                w.f32s(c_m);
                w.f32s(r_v);
                w.f32s(c_v);
                match sign {
                    SignStore::Bits(b) => {
                        w.u8(0);
                        let bytes = b.to_le_bytes();
                        w.u64(bytes.len() as u64);
                        w.bytes(&bytes);
                    }
                    SignStore::Bytes(v) => {
                        w.u8(1);
                        w.u64(v.len() as u64);
                        w.bytes(v);
                    }
                }
            }
            State::Dense { m, v } => {
                w.u8(0);
                w.u64(m.len() as u64);
                w.f32s(m);
                w.f32s(v);
            }
            // StatePolicy::None / frozen: nothing to persist.
            State::Stateless => w.u8(2),
        }
        w.finish()
    }

    fn state_blobs(&self) -> Vec<Vec<u8>> {
        (0..self.states.len()).map(|i| self.state_blob(i)).collect()
    }

    fn load_state_blobs(&mut self, blobs: &[Vec<u8>]) -> Result<()> {
        if blobs.len() != self.states.len() {
            bail!("smmf: checkpoint has {} tensors, optimizer has {}", blobs.len(), self.states.len());
        }
        for (idx, (blob, st)) in blobs.iter().zip(self.states.iter_mut()).enumerate() {
            let mut r = BlobReader::new(blob);
            let tag = r.u8()?;
            match (tag, st) {
                (1, State::Factored { n, m, r_m, c_m, sign, r_v, c_v }) => {
                    let (bn, bm) = (r.u32()? as usize, r.u32()? as usize);
                    if (bn, bm) != (*n, *m) {
                        bail!("smmf tensor {idx}: checkpoint is {bn}x{bm}, optimizer expects {n}x{m}");
                    }
                    r.f32s_into(r_m)?;
                    r.f32s_into(c_m)?;
                    r.f32s_into(r_v)?;
                    r.f32s_into(c_v)?;
                    let mode = r.u8()?;
                    let len = r.u64()? as usize;
                    let payload = r.bytes(len)?;
                    match (mode, sign) {
                        (0, SignStore::Bits(b)) => {
                            b.copy_from_le_bytes(payload)
                                .map_err(|e| anyhow!("smmf tensor {idx}: {e}"))?;
                        }
                        (1, SignStore::Bytes(v)) => {
                            if payload.len() != v.len() {
                                bail!(
                                    "smmf tensor {idx}: byte sign plane has {} bytes, expects {}",
                                    payload.len(),
                                    v.len()
                                );
                            }
                            v.copy_from_slice(payload);
                        }
                        (mode, _) => bail!(
                            "smmf tensor {idx}: sign mode mismatch (checkpoint mode {mode}, \
                             see OptimConfig::smmf_sign_mode)"
                        ),
                    }
                }
                (0, State::Dense { m, v }) => {
                    r.expect_len(m.len(), &format!("smmf tensor {idx} dense state"))?;
                    r.f32s_into(m)?;
                    r.f32s_into(v)?;
                }
                (2, State::Stateless) => {}
                (tag, _) => bail!(
                    "smmf tensor {idx}: state kind mismatch (blob tag {tag}; factored vs dense \
                     vs stateless is decided by shape, OptimConfig::vector_reshape and the \
                     group StatePolicy)"
                ),
            }
            r.finish()?;
        }
        Ok(())
    }
}

impl Optimizer for Smmf {
    fn name(&self) -> &'static str {
        "smmf"
    }

    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor]) {
        let _span = crate::obs::trace::span("optim", "optim.step");
        assert_eq!(params.len(), self.states.len());
        self.t += 1;
        let (beta_m, beta_v) = self.betas(self.t);
        if self.item_scratch.is_empty() {
            self.step_serial(params, grads, beta_m, beta_v);
        } else {
            self.step_parallel(params, grads, beta_m, beta_v);
        }
    }

    fn set_lr(&mut self, lr: f32) {
        self.cfg.lr = lr;
    }

    fn state_bytes(&self) -> u64 {
        self.states.iter().map(|s| s.bytes()).sum()
    }

    fn scratch_bytes(&self) -> u64 {
        let items: usize = self
            .item_scratch
            .iter()
            .map(|s| s.acc_cm.len() + s.acc_cv.len() + s.g_wd.len())
            .sum();
        (4 * (self.scratch_cm.len()
            + self.scratch_cv.len()
            + self.scratch_mat.len()
            + self.scratch_mat2.len()
            + items)) as u64
    }

    fn partition(&self) -> Option<&ParamPartition> {
        Some(&self.plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Pcg32;

    fn rand_tensors(rng: &mut Pcg32, shapes: &[Vec<usize>], scale: f32) -> Vec<Tensor> {
        shapes
            .iter()
            .map(|s| {
                let n: usize = s.iter().product();
                Tensor::from_vec(s, prop::gen_vec(rng, n, scale))
            })
            .collect()
    }

    #[test]
    fn fused_matches_naive_trajectory() {
        // The production fused path must equal the literal-algorithm path
        // bit-for-bit-ish over multi-step trajectories of random shapes.
        prop::cases(40, |rng| {
            let n_tensors = 1 + rng.below(3);
            let shapes: Vec<Vec<usize>> =
                (0..n_tensors).map(|_| prop::gen_shape(rng, 4, 2048)).collect();
            let cfg = OptimConfig {
                lr: 0.01,
                weight_decay: 0.01,
                ..OptimConfig::paper_defaults(super::super::OptKind::Smmf)
            };
            let mut fused = Smmf::new(&shapes, &cfg);
            let mut naive = Smmf::new(&shapes, &cfg);
            let mut p1 = rand_tensors(rng, &shapes, 1.0);
            let mut p2 = p1.clone();
            for _ in 0..3 {
                let grads = rand_tensors(rng, &shapes, 1.0);
                fused.step(&mut p1, &grads);
                naive.step_naive(&mut p2, &grads);
                for (a, b) in p1.iter().zip(&p2) {
                    for (x, y) in a.data().iter().zip(b.data()) {
                        assert!(
                            (x - y).abs() <= 1e-5 * x.abs().max(1.0),
                            "fused {x} vs naive {y}"
                        );
                    }
                }
            }
        });
    }

    #[test]
    fn parallel_matches_serial_trajectory() {
        // threads = 4 vs threads = 1 over random shapes: the parallel
        // engine only changes the column-partial reduction order, so
        // trajectories agree to tight FP tolerance.
        prop::cases(15, |rng| {
            let n_tensors = 1 + rng.below(3);
            let shapes: Vec<Vec<usize>> =
                (0..n_tensors).map(|_| prop::gen_shape(rng, 4, 4096)).collect();
            let cfg1 = OptimConfig {
                lr: 0.01,
                weight_decay: 0.01,
                ..OptimConfig::paper_defaults(super::super::OptKind::Smmf)
            };
            let cfg4 = OptimConfig { threads: 4, ..cfg1.clone() };
            let mut serial = Smmf::new(&shapes, &cfg1);
            let mut par = Smmf::new(&shapes, &cfg4);
            let mut p1 = rand_tensors(rng, &shapes, 1.0);
            let mut p4 = p1.clone();
            for _ in 0..3 {
                let grads = rand_tensors(rng, &shapes, 1.0);
                serial.step(&mut p1, &grads);
                par.step(&mut p4, &grads);
                for (a, b) in p1.iter().zip(&p4) {
                    for (x, y) in a.data().iter().zip(b.data()) {
                        assert!(
                            (x - y).abs() <= 1e-6 * x.abs().max(1.0),
                            "serial {x} vs parallel {y}"
                        );
                    }
                }
            }
        });
    }

    #[test]
    fn parallel_bit_exact_across_thread_counts() {
        // The shard plan's item boundaries are thread-count independent,
        // and partials reduce in fixed item order: any threads >= 2 are
        // bit-identical (the "fixed shard plan" guarantee). Exercised on
        // a big-enough matrix that the plan really splits intra-tensor.
        let shapes = vec![vec![1536, 1536], vec![128, 64], vec![7]];
        let mut rng = Pcg32::new(42);
        let p0 = rand_tensors(&mut rng, &shapes, 1.0);
        let grads: Vec<Vec<Tensor>> =
            (0..3).map(|_| rand_tensors(&mut rng, &shapes, 1.0)).collect();
        let mut results = Vec::new();
        for threads in [2usize, 4, 8] {
            let cfg = OptimConfig {
                lr: 0.01,
                threads,
                ..OptimConfig::paper_defaults(super::super::OptKind::Smmf)
            };
            let mut opt = Smmf::new(&shapes, &cfg);
            assert!(opt.plan.items_of(0).len() > 1, "plan must split the 1536x1536 tensor");
            let mut p = p0.clone();
            for g in &grads {
                opt.step(&mut p, g);
            }
            results.push(p);
        }
        assert_eq!(results[0], results[1], "threads=2 vs threads=4");
        assert_eq!(results[1], results[2], "threads=4 vs threads=8");
    }

    #[test]
    fn state_is_factorized_memory() {
        // 1024x1024 tensor: Adam would hold 8 MiB of moments; SMMF holds
        // 2*(1024+1024)*4 B of vectors + 1 Mbit of signs = 147,456 B.
        let shapes = vec![vec![1024, 1024]];
        let opt = Smmf::new(&shapes, &OptimConfig::default());
        let expect = 4 * 4 * 1024 + 1024 * 1024 / 8;
        assert_eq!(opt.state_bytes(), expect as u64);
        // >96% smaller than Adam's 2N floats — the paper's headline.
        let adam = 2 * 1024 * 1024 * 4;
        assert!((opt.state_bytes() as f64) < 0.04 * adam as f64);
    }

    #[test]
    fn dense_fallback_when_vector_reshape_off() {
        let cfg = OptimConfig { vector_reshape: false, ..OptimConfig::default() };
        let opt = Smmf::new(&[vec![100]], &cfg);
        assert_eq!(opt.state_bytes(), 2 * 100 * 4);
        let opt2 = Smmf::new(&[vec![100]], &OptimConfig::default());
        // 100 = 10x10 factored: (10+10+10+10) floats + 100 bits (2 words)
        assert_eq!(opt2.state_bytes(), (40 * 4 + 16) as u64);
    }

    #[test]
    fn converges_on_rosenbrock_like() {
        // Non-convex sanity: SMMF reduces a banana-ish function.
        let shapes = vec![vec![2]];
        let cfg = OptimConfig { lr: 1e-2, ..OptimConfig::default() };
        let mut opt = Smmf::new(&shapes, &cfg);
        let mut p = vec![Tensor::from_vec(&[2], vec![-1.2, 1.0])];
        let f = |x: f32, y: f32| (1.0 - x).powi(2) + 5.0 * (y - x * x).powi(2);
        let initial = f(p[0].data()[0], p[0].data()[1]);
        for _ in 0..2000 {
            let (x, y) = (p[0].data()[0], p[0].data()[1]);
            let gx = -2.0 * (1.0 - x) - 20.0 * x * (y - x * x);
            let gy = 10.0 * (y - x * x);
            let g = vec![Tensor::from_vec(&[2], vec![gx, gy])];
            opt.step(&mut p, &g);
        }
        let fin = f(p[0].data()[0], p[0].data()[1]);
        assert!(fin < initial * 0.05, "{initial} -> {fin}");
    }

    #[test]
    fn first_step_equals_sign_scaled() {
        // At t=1 both β are 0 (β2_1 = 1-1=0, β1_1=0.9 but state is zero so
        // M = 0.1 g, V = g²): U = 0.1g/(|g|+eps) ≈ 0.1*sign(g).
        let shapes = vec![vec![3, 3]];
        let mut opt = Smmf::new(&shapes, &OptimConfig { lr: 1.0, ..OptimConfig::default() });
        let mut p = vec![Tensor::zeros(&[3, 3])];
        let g = vec![Tensor::from_vec(&[3, 3], vec![2., -3., 4., -5., 6., -7., 8., -9., 10.])];
        opt.step(&mut p, &g);
        for (w, &gij) in p[0].data().iter().zip(g[0].data()) {
            let expect = -0.1 * gij.signum();
            assert!((w - expect).abs() < 1e-3, "{w} vs {expect}");
        }
    }

    #[test]
    fn prop_state_invariants_hold_over_trajectories() {
        // After any number of steps: V factors are non-negative, the
        // normalized side sums to 1 (or the state is all-zero), and the
        // sign matrix agrees with the sign of the decompressed moment.
        prop::cases(25, |rng| {
            let shape = prop::gen_shape(rng, 3, 1024);
            let cfg = OptimConfig::default();
            let mut opt = Smmf::new(&[shape.clone()], &cfg);
            let mut p = rand_tensors(rng, &[shape.clone()], 0.5);
            let steps = 1 + rng.below(4);
            for _ in 0..steps {
                let g = rand_tensors(rng, &[shape.clone()], 0.5);
                opt.step(&mut p, &g);
            }
            match &opt.states[0] {
                State::Factored { n, m, r_m, c_m, r_v, c_v, .. } => {
                    assert!(r_v.iter().all(|&x| x >= 0.0));
                    assert!(c_v.iter().all(|&x| x >= 0.0));
                    // normalize-shorter-side rule: the chosen side is a
                    // probability vector (within float tolerance).
                    let (side_m, side_v): (&[f32], &[f32]) =
                        if n < m { (r_m, r_v) } else { (c_m, c_v) };
                    for side in [side_m, side_v] {
                        let total: f32 = side.iter().sum();
                        assert!(
                            total == 0.0 || (total - 1.0).abs() < 1e-3,
                            "side sum {total}"
                        );
                    }
                }
                _ => unreachable!(),
            }
        });
    }

    #[test]
    fn byte8_sign_mode_matches_bit1_trajectory() {
        // The 8-bit S_M variant (paper Table 5) must be numerically
        // identical to the 1-bit variant — only the storage differs.
        prop::cases(15, |rng| {
            let shapes = vec![prop::gen_shape(rng, 3, 1024)];
            let cfg1 = OptimConfig::default();
            let cfg8 = OptimConfig {
                smmf_sign_mode: super::super::SignMode::Byte8,
                ..OptimConfig::default()
            };
            let mut o1 = Smmf::new(&shapes, &cfg1);
            let mut o8 = Smmf::new(&shapes, &cfg8);
            let mut p1 = rand_tensors(rng, &shapes, 1.0);
            let mut p8 = p1.clone();
            for _ in 0..3 {
                let g = rand_tensors(rng, &shapes, 1.0);
                o1.step(&mut p1, &g);
                o8.step(&mut p8, &g);
            }
            assert_eq!(p1, p8);
            // ...and the byte store is larger whenever numel > ~64.
            let numel: usize = shapes[0].iter().product();
            if numel > 128 {
                assert!(o8.state_bytes() > o1.state_bytes(), "{numel}");
            }
        });
    }

    #[test]
    fn compress_first_scheme_uses_rank1_gradient() {
        // Mechanism check for the §3.2 ablation: compression→decompression
        // replaces the intact gradient with its rank-1 (+sign)
        // reconstruction — same total |mass| (Lemma E.7) but a different
        // matrix — so a single step from zero state must differ from the
        // decompression→compression scheme, while the first-step V (and
        // hence the scale of updates) stays comparable.
        let mut rng = Pcg32::new(9);
        let g: Vec<f32> = (0..64).map(|_| rng.normal()).collect();
        let mut ghat = Vec::new();
        Smmf::compress_then_decompress(&g, 8, 8, &mut ghat);
        // mass preserved...
        let mass: f32 = g.iter().map(|x| x.abs()).sum();
        let mass_hat: f32 = ghat.iter().map(|x| x.abs()).sum();
        assert!((mass - mass_hat).abs() < 1e-3 * mass);
        // ...signs preserved...
        for (a, b) in g.iter().zip(&ghat) {
            assert_eq!(*a > 0.0, *b > 0.0);
        }
        // ...but the matrix itself is degraded (not equal).
        let err: f32 = g.iter().zip(&ghat).map(|(a, b)| (a - b).abs()).sum();
        assert!(err > 0.05 * mass, "err={err} mass={mass}");

        // And the two schemes produce different parameter updates.
        let shapes = vec![vec![8, 8]];
        let mk = |scheme| OptimConfig { lr: 0.1, smmf_scheme: scheme, ..OptimConfig::default() };
        let gt = Tensor::from_vec(&[8, 8], g.clone());
        let mut p1 = vec![Tensor::zeros(&[8, 8])];
        let mut p2 = vec![Tensor::zeros(&[8, 8])];
        Smmf::new(&shapes, &mk(SmmfScheme::DecompressFirst)).step(&mut p1, &[gt.clone()]);
        Smmf::new(&shapes, &mk(SmmfScheme::CompressFirst)).step(&mut p2, &[gt]);
        assert_ne!(p1, p2);
    }

    #[test]
    fn fold_last_matricize_uses_more_memory() {
        // Square-matricization is the memory win (Theorem 3.1/3.2): the
        // last-axis fold ablation stores much longer vectors on conv
        // shapes.
        let shapes = vec![vec![512, 256, 3, 3]];
        let sq = Smmf::new(&shapes, &OptimConfig::default());
        let fold = Smmf::new(
            &shapes,
            &OptimConfig {
                smmf_matricize: super::super::MatricizeMode::FoldLast,
                ..OptimConfig::default()
            },
        );
        // fold: r has numel/3 entries vs ~sqrt(numel) for square.
        assert!(fold.state_bytes() > 2 * sq.state_bytes());
    }

    #[test]
    fn update_is_bounded_by_lr_over_eps() {
        // |Δw| per step is at most lr * |M|/(sqrt(V)+eps); with M,V built
        // from the same gradient this is O(lr) — no blow-ups even for
        // huge gradients.
        let shapes = vec![vec![8, 8]];
        let cfg = OptimConfig { lr: 0.01, ..OptimConfig::default() };
        let mut opt = Smmf::new(&shapes, &cfg);
        let mut p = vec![Tensor::zeros(&[8, 8])];
        let g = vec![Tensor::from_vec(&[8, 8], vec![1e6; 64])];
        opt.step(&mut p, &g);
        assert!(p[0].max_abs() <= 0.011, "{}", p[0].max_abs());
    }

    #[test]
    fn scratch_is_bounded_by_vectors_not_matrix() {
        let shapes = vec![vec![512, 512]];
        let mut opt = Smmf::new(&shapes, &OptimConfig::default());
        let mut p = vec![Tensor::zeros(&[512, 512])];
        let g = vec![Tensor::zeros(&[512, 512])];
        opt.step(&mut p, &g);
        // Fused path scratch: 2 column accumulators only.
        assert_eq!(opt.scratch_bytes(), 2 * 512 * 4);
    }

    #[test]
    fn group_policies_change_state_layout_and_freeze() {
        let shapes = vec![vec![32, 32], vec![64]];
        let cfg = OptimConfig::default();
        let mut pols = vec![TensorPolicy::uniform(&cfg); 2];
        pols[0].state = StatePolicy::None;
        pols[1].state = StatePolicy::Dense;
        let opt = Smmf::with_policies(&shapes, &cfg, &pols);
        // tensor 0 carries no state; tensor 1 dense Adam-style 2N floats
        assert_eq!(opt.state_bytes(), (2 * 64 * 4) as u64);

        let mut pols2 = vec![TensorPolicy::uniform(&cfg); 2];
        pols2[0].frozen = true;
        for threads in [1usize, 4] {
            let cfg_t = OptimConfig { threads, ..cfg.clone() };
            let mut opt2 = Smmf::with_policies(&shapes, &cfg_t, &pols2);
            let mut p =
                vec![Tensor::from_vec(&[32, 32], vec![1.0; 1024]), Tensor::zeros(&[64])];
            let g = vec![
                Tensor::from_vec(&[32, 32], vec![0.5; 1024]),
                Tensor::from_vec(&[64], vec![0.5; 64]),
            ];
            opt2.step(&mut p, &g);
            assert!(
                p[0].data().iter().all(|&x| x == 1.0),
                "frozen tensor must not move (threads={threads})"
            );
            assert!(p[1].data().iter().any(|&x| x != 0.0));
            // frozen tensor holds nothing; the 64-vector matricizes to
            // 8x8: 4 factor vectors of 8 f32 + one 64-bit sign word.
            assert_eq!(opt2.state_bytes(), (4 * 4 * 8 + 8) as u64);
        }
    }

    #[test]
    fn sign_row_alignment_lands_on_word_edges() {
        // For the 1-bit store, a row boundary at any multiple of
        // row_align must be a 64-bit word edge.
        for m in [1usize, 3, 17, 48, 64, 100, 1000, 4608] {
            let a = SignStore::row_align(SignMode::Bit1, m);
            assert_eq!((a * m) % 64, 0, "m={m} align={a}");
            assert_eq!(SignStore::row_align(SignMode::Byte8, m), 1);
        }
    }
}
