//! Observability: a flight-recorder tracer, a metrics registry, and
//! exporters — std-only, and provably non-perturbing.
//!
//! Three pieces (see `docs/OBSERVABILITY.md` for the user guide):
//!
//! - [`trace`] — a lock-light per-thread **flight recorder**: each
//!   thread owns a fixed-capacity ring buffer of span events (oldest
//!   overwritten on wrap, with overflow accounting), stamped by a
//!   monotonic microsecond clock injected at recorder construction so
//!   tests can pin byte-deterministic output.
//! - [`metrics`] — a **registry** of named counters, gauges and
//!   fixed-bucket histograms (p50/p99 extraction), plus the exact
//!   sorted-sample percentile/mean helpers the loadgen bench records
//!   use.
//! - [`export`] — Chrome trace-event JSON (loadable in Perfetto /
//!   `chrome://tracing`), Prometheus-style text exposition, and the
//!   bridge that turns measured histograms into `BENCH_*.json` records.
//!
//! The whole subsystem is gated by two process-wide switches, set once
//! at startup from the `[obs]` config section and the `--trace` /
//! `--metrics` CLI flags. When a switch is off the instrumented hot
//! paths pay exactly one relaxed atomic load and a predictable branch —
//! no allocation, no lock, no clock read. When a switch is on, the
//! instrumentation only ever *observes* (timestamps, byte counts); it
//! never touches optimizer or wire data, which is why every bit-identity
//! pin (thread sweep, shard × client e2e, commit-log replay) must and
//! does hold with tracing enabled — `rust/tests/obs.rs` and the traced
//! pin in `rust/tests/server_e2e.rs` enforce it.

pub mod export;
pub mod metrics;
pub mod trace;

use std::sync::atomic::{AtomicBool, Ordering};

use anyhow::{anyhow, Context, Result};

use crate::util::cli::Args;
use crate::util::toml::TomlDoc;

/// Process-wide tracing switch ([`trace::span`] is a no-op when clear).
static TRACE_ON: AtomicBool = AtomicBool::new(false);
/// Process-wide metrics switch (histogram timing sites skip the clock
/// read when clear; plain counters that back wire replies stay live).
static METRICS_ON: AtomicBool = AtomicBool::new(false);

/// Is span recording on? One relaxed load — safe to call per task.
#[inline]
pub fn trace_enabled() -> bool {
    TRACE_ON.load(Ordering::Relaxed)
}

/// Is histogram/exposition collection on? One relaxed load.
#[inline]
pub fn metrics_enabled() -> bool {
    METRICS_ON.load(Ordering::Relaxed)
}

/// Flip the tracing switch directly (tests and the `repro trace`
/// wrapper; everything else goes through [`init`]).
pub fn set_trace_enabled(on: bool) {
    TRACE_ON.store(on, Ordering::Relaxed);
}

/// Flip the metrics switch directly (tests; everything else goes
/// through [`init`]).
pub fn set_metrics_enabled(on: bool) {
    METRICS_ON.store(on, Ordering::Relaxed);
}

/// Resolved observability configuration: the `[obs]` config section
/// layered under the CLI flags, exactly like `ServeOptions`.
#[derive(Clone, Debug)]
pub struct ObsConfig {
    /// Record spans and write Chrome trace JSON on exit.
    pub trace: bool,
    /// Collect histograms; write the Prometheus text exposition and the
    /// measured `BENCH_*.json` records on exit.
    pub metrics: bool,
    /// Where the Chrome trace JSON goes (`--trace-out`).
    pub trace_path: String,
    /// Where the Prometheus text exposition goes (`--metrics-out`).
    pub metrics_path: String,
}

impl Default for ObsConfig {
    fn default() -> Self {
        ObsConfig {
            trace: false,
            metrics: false,
            trace_path: "trace.json".to_string(),
            metrics_path: "metrics.prom".to_string(),
        }
    }
}

impl ObsConfig {
    /// Defaults -> `[obs]` section of `--config` (if any) -> CLI flags.
    /// `--trace` implies `--metrics` (a trace run should also leave the
    /// measured histograms behind).
    pub fn load(args: &Args) -> Result<ObsConfig> {
        let mut cfg = ObsConfig::default();
        if let Some(path) = args.opt("config") {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading config {path}"))?;
            let doc = TomlDoc::parse(&text).map_err(|e| anyhow!("{path:?}: {e}"))?;
            cfg.apply_toml(&doc);
        }
        cfg.apply_args(args);
        Ok(cfg)
    }

    fn apply_toml(&mut self, doc: &TomlDoc) {
        self.trace = doc.bool_or("obs.trace", self.trace);
        self.metrics = doc.bool_or("obs.metrics", self.metrics);
        self.trace_path = doc.str_or("obs.trace_path", &self.trace_path).to_string();
        self.metrics_path = doc.str_or("obs.metrics_path", &self.metrics_path).to_string();
    }

    fn apply_args(&mut self, args: &Args) {
        if args.has_flag("trace") {
            self.trace = true;
        }
        if args.has_flag("metrics") {
            self.metrics = true;
        }
        if let Some(p) = args.opt("trace-out") {
            self.trace_path = p.to_string();
        }
        if let Some(p) = args.opt("metrics-out") {
            self.metrics_path = p.to_string();
        }
        if self.trace {
            // A trace run without the registry would leave the bench
            // bridge empty; tracing implies metrics.
            self.metrics = true;
        }
    }
}

/// Arm the process-wide switches from a resolved config. Call once,
/// before any instrumented work runs.
pub fn init(cfg: &ObsConfig) {
    set_trace_enabled(cfg.trace);
    set_metrics_enabled(cfg.metrics);
}

/// Drain and export everything the run recorded: the Chrome trace JSON
/// (when tracing), the Prometheus text exposition, and the measured
/// histogram records bridged into `BENCH_optimizer_step.json` /
/// `BENCH_server.json` (when metrics). A no-op for untraced, unmetered
/// runs. Prints one line per artifact written.
pub fn finish(cfg: &ObsConfig) -> Result<()> {
    if cfg.trace {
        let dump = trace::global().drain();
        let json = export::chrome_trace_json(&dump);
        std::fs::write(&cfg.trace_path, json)
            .with_context(|| format!("writing trace to {}", cfg.trace_path))?;
        let dropped = if dump.dropped > 0 {
            format!(" ({} oldest events overwritten)", dump.dropped)
        } else {
            String::new()
        };
        println!(
            "[obs] wrote {} span events to {}{dropped} — open in Perfetto (ui.perfetto.dev)",
            dump.events.len(),
            cfg.trace_path
        );
    }
    if cfg.metrics {
        let snap = metrics::global().snapshot();
        std::fs::write(&cfg.metrics_path, export::prometheus_text(&snap))
            .with_context(|| format!("writing metrics to {}", cfg.metrics_path))?;
        println!(
            "[obs] wrote {} metrics to {}",
            snap.counters.len() + snap.gauges.len() + snap.histograms.len(),
            cfg.metrics_path
        );
        export::write_bench_records(&snap)?;
    }
    Ok(())
}
