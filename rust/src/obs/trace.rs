//! The flight recorder: per-thread ring buffers of span events.
//!
//! Design goals, in order:
//!
//! 1. **Non-perturbing.** Recording only ever reads a clock and writes
//!    into a preallocated per-thread ring. No instrumented code path
//!    changes shape based on what was recorded — which is what lets the
//!    bit-identity pins run with tracing on.
//! 2. **Lock-light.** Each thread records into its *own* ring behind
//!    its own mutex, reached through a thread-local handle — the lock
//!    is uncontended on the hot path (one CAS), and threads never
//!    serialize against each other while recording. The recorder's
//!    shared state (the ring list) is only locked on first use per
//!    thread and at drain time.
//! 3. **Bounded.** Rings have fixed capacity; when full, the oldest
//!    event is overwritten and counted in `dropped` — a flight
//!    recorder keeps the most recent window, it never grows.
//! 4. **Deterministic under test.** The microsecond clock is injected
//!    at construction ([`Recorder::with_clock`]); a counter clock plus
//!    the sorted [`Recorder::drain`] order pins the exported Chrome
//!    trace byte-for-byte (`rust/tests/obs.rs`).
//!
//! Production code uses the free functions [`span`] / [`mark`], which
//! hit the process-global recorder and cost one relaxed atomic load
//! when tracing is off. Tests build standalone [`Recorder`]s.

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Injected monotonic clock: microseconds since some fixed origin.
pub type Clock = Arc<dyn Fn() -> u64 + Send + Sync>;

/// Event phase, mirroring the Chrome trace-event `ph` field.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// A complete span (`ph: "X"`): `ts` + `dur`.
    Complete,
    /// An instant marker (`ph: "i"`): a point in time, no duration.
    Instant,
}

/// One recorded event. Names and categories are `&'static str` so
/// recording never allocates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Event {
    /// Span/marker name (e.g. `optim.factor_update`).
    pub name: &'static str,
    /// Category (e.g. `optim`, `server`, `remote`).
    pub cat: &'static str,
    /// Start timestamp, microseconds on the recorder's clock.
    pub ts_us: u64,
    /// Duration in microseconds (0 for [`Phase::Instant`]).
    pub dur_us: u64,
    /// Recorder-assigned thread id (registration order, from 1).
    pub tid: u64,
    pub ph: Phase,
}

/// Fixed-capacity event ring: overwrites the oldest event when full.
struct Ring {
    cap: usize,
    buf: Vec<Event>,
    /// Next write position (== buf.len() until the first wrap).
    next: usize,
    /// Events overwritten because the ring was full.
    dropped: u64,
}

impl Ring {
    fn push(&mut self, ev: Event) {
        if self.buf.len() < self.cap {
            self.buf.push(ev);
        } else {
            self.buf[self.next] = ev;
            self.dropped += 1;
        }
        self.next = (self.next + 1) % self.cap;
    }

    /// Oldest-first copy of the surviving events.
    fn ordered(&self) -> Vec<Event> {
        if self.buf.len() < self.cap {
            self.buf.clone()
        } else {
            let mut out = Vec::with_capacity(self.cap);
            out.extend_from_slice(&self.buf[self.next..]);
            out.extend_from_slice(&self.buf[..self.next]);
            out
        }
    }
}

/// One thread's slice of the recorder.
pub struct ThreadRing {
    tid: u64,
    ring: Mutex<Ring>,
}

/// Everything [`Recorder::drain`] hands to the exporters.
pub struct TraceDump {
    /// All surviving events, sorted by `(ts_us, tid, name)` so the
    /// exported bytes do not depend on thread scheduling or drain
    /// order.
    pub events: Vec<Event>,
    /// Total events overwritten across all rings.
    pub dropped: u64,
}

/// Default per-thread ring capacity (events). At ~48 bytes per event
/// this is ~768 KiB per recording thread, and a shard thread inside a
/// 50-step loadgen run stays well under it.
pub const DEFAULT_RING_CAPACITY: usize = 16 * 1024;

/// The flight recorder: a clock plus a list of per-thread rings.
pub struct Recorder {
    /// Distinguishes recorders in the thread-local cache, so a test's
    /// standalone recorder never writes into the global one's rings.
    id: u64,
    clock: Clock,
    ring_capacity: usize,
    rings: Mutex<Vec<Arc<ThreadRing>>>,
    next_tid: AtomicU64,
}

static NEXT_RECORDER_ID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    /// (recorder id, this thread's ring in that recorder).
    static THREAD_RING: RefCell<Option<(u64, Arc<ThreadRing>)>> = const { RefCell::new(None) };
}

impl Recorder {
    /// Production recorder: wall-clock microseconds since construction.
    pub fn new() -> Recorder {
        let origin = Instant::now();
        Self::with_clock(Arc::new(move || origin.elapsed().as_micros() as u64))
    }

    /// Recorder with an injected clock (tests pin deterministic output
    /// with a counter clock).
    pub fn with_clock(clock: Clock) -> Recorder {
        Recorder {
            id: NEXT_RECORDER_ID.fetch_add(1, Ordering::Relaxed),
            clock,
            ring_capacity: DEFAULT_RING_CAPACITY,
            rings: Mutex::new(Vec::new()),
            next_tid: AtomicU64::new(1),
        }
    }

    /// Override the per-thread ring capacity (wraparound tests use tiny
    /// rings). Applies to rings registered after the call.
    pub fn with_capacity(mut self, events: usize) -> Recorder {
        self.ring_capacity = events.max(1);
        self
    }

    /// Current time on the injected clock, in microseconds.
    pub fn now_us(&self) -> u64 {
        (self.clock)()
    }

    /// This thread's ring (registering it on first use).
    fn thread_ring(&self) -> Arc<ThreadRing> {
        THREAD_RING.with(|slot| {
            let mut slot = slot.borrow_mut();
            if let Some((id, ring)) = slot.as_ref() {
                if *id == self.id {
                    return Arc::clone(ring);
                }
            }
            let ring = Arc::new(ThreadRing {
                tid: self.next_tid.fetch_add(1, Ordering::Relaxed),
                ring: Mutex::new(Ring {
                    cap: self.ring_capacity,
                    buf: Vec::new(),
                    next: 0,
                    dropped: 0,
                }),
            });
            self.rings.lock().unwrap().push(Arc::clone(&ring));
            *slot = Some((self.id, Arc::clone(&ring)));
            ring
        })
    }

    /// Open a span: records one [`Phase::Complete`] event when the
    /// returned guard drops.
    pub fn span(self: &Arc<Recorder>, cat: &'static str, name: &'static str) -> Span {
        Span {
            inner: Some(SpanInner {
                rec: Arc::clone(self),
                ring: self.thread_ring(),
                cat,
                name,
                start_us: self.now_us(),
            }),
        }
    }

    /// Record an instant marker on the calling thread.
    pub fn mark(&self, cat: &'static str, name: &'static str) {
        let ring = self.thread_ring();
        let ts_us = self.now_us();
        ring.ring.lock().unwrap().push(Event {
            name,
            cat,
            ts_us,
            dur_us: 0,
            tid: ring.tid,
            ph: Phase::Instant,
        });
    }

    /// Collect every ring's surviving events into one deterministic
    /// ordering (see [`TraceDump::events`]). Non-destructive: rings
    /// keep recording afterwards.
    pub fn drain(&self) -> TraceDump {
        let rings = self.rings.lock().unwrap();
        let mut events = Vec::new();
        let mut dropped = 0u64;
        for tr in rings.iter() {
            let g = tr.ring.lock().unwrap();
            events.extend(g.ordered());
            dropped += g.dropped;
        }
        events.sort_by(|a, b| {
            (a.ts_us, a.tid, a.name).cmp(&(b.ts_us, b.tid, b.name))
        });
        TraceDump { events, dropped }
    }
}

impl Default for Recorder {
    fn default() -> Self {
        Self::new()
    }
}

/// RAII span guard: records one complete event on drop. The disabled
/// path carries `None` and drops for free.
pub struct Span {
    inner: Option<SpanInner>,
}

struct SpanInner {
    rec: Arc<Recorder>,
    ring: Arc<ThreadRing>,
    cat: &'static str,
    name: &'static str,
    start_us: u64,
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some(s) = self.inner.take() {
            let end = s.rec.now_us();
            s.ring.ring.lock().unwrap().push(Event {
                name: s.name,
                cat: s.cat,
                ts_us: s.start_us,
                dur_us: end.saturating_sub(s.start_us),
                tid: s.ring.tid,
                ph: Phase::Complete,
            });
        }
    }
}

static GLOBAL: OnceLock<Arc<Recorder>> = OnceLock::new();

/// The process-global recorder (created on first touch with the
/// wall-clock Instant anchor).
pub fn global() -> &'static Arc<Recorder> {
    GLOBAL.get_or_init(|| Arc::new(Recorder::new()))
}

/// Open a span on the global recorder — a no-op guard when tracing is
/// off (one relaxed atomic load, no allocation, no clock read).
#[inline]
pub fn span(cat: &'static str, name: &'static str) -> Span {
    if !crate::obs::trace_enabled() {
        return Span { inner: None };
    }
    global().span(cat, name)
}

/// Record an instant marker on the global recorder — a no-op when
/// tracing is off.
#[inline]
pub fn mark(cat: &'static str, name: &'static str) {
    if crate::obs::trace_enabled() {
        global().mark(cat, name);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn counter_clock() -> Clock {
        let t = AtomicU64::new(0);
        Arc::new(move || t.fetch_add(10, Ordering::Relaxed))
    }

    #[test]
    fn span_records_complete_event() {
        let rec = Arc::new(Recorder::with_clock(counter_clock()));
        {
            let _s = rec.span("test", "outer");
            rec.mark("test", "tick");
        }
        let dump = rec.drain();
        assert_eq!(dump.dropped, 0);
        assert_eq!(dump.events.len(), 2);
        // The span started at t=0 (first clock read), the mark landed
        // at t=10, the span closed at t=20.
        assert_eq!(dump.events[0].name, "outer");
        assert_eq!(dump.events[0].ph, Phase::Complete);
        assert_eq!((dump.events[0].ts_us, dump.events[0].dur_us), (0, 20));
        assert_eq!(dump.events[1].name, "tick");
        assert_eq!(dump.events[1].ph, Phase::Instant);
        assert_eq!(dump.events[1].ts_us, 10);
    }

    #[test]
    fn ring_wraparound_keeps_newest_and_counts_dropped() {
        let rec = Arc::new(Recorder::with_clock(counter_clock()).with_capacity(4));
        for _ in 0..7 {
            rec.mark("test", "m");
        }
        let dump = rec.drain();
        assert_eq!(dump.dropped, 3);
        assert_eq!(dump.events.len(), 4);
        // The three oldest (ts 0, 10, 20) were overwritten.
        let ts: Vec<u64> = dump.events.iter().map(|e| e.ts_us).collect();
        assert_eq!(ts, vec![30, 40, 50, 60]);
    }

    #[test]
    fn drain_is_non_destructive() {
        let rec = Arc::new(Recorder::with_clock(counter_clock()));
        rec.mark("test", "a");
        assert_eq!(rec.drain().events.len(), 1);
        rec.mark("test", "b");
        assert_eq!(rec.drain().events.len(), 2);
    }

    #[test]
    fn disabled_global_span_is_inert() {
        crate::obs::set_trace_enabled(false);
        let before = global().drain().events.len();
        {
            let _s = span("test", "nothing");
            mark("test", "nothing");
        }
        assert_eq!(global().drain().events.len(), before);
    }
}
