//! The metrics registry: named counters, gauges and fixed-bucket
//! histograms, plus the exact sorted-sample percentile/mean helpers.
//!
//! Two usage styles, both alloc-free after setup:
//!
//! - **Get-or-create** ([`Registry::counter`] / [`Registry::gauge`] /
//!   [`Registry::histogram`]): callers cache the returned `Arc` and
//!   bump it directly. One registry lookup per site, ever.
//! - **Publish** ([`Registry::publish_counter`], …): a subsystem that
//!   already owns its atomics (the server's `ServerMetrics`, whose
//!   counters also back the wire `StatsReply`) registers those same
//!   handles under canonical names, replacing any previous handle.
//!   The wire reply and the exposition then read the *same* atomic —
//!   they cannot drift. Replace-semantics also means a process that
//!   starts two servers (loadgen's healthy-baseline pass) exports the
//!   most recently published server's values while each server's wire
//!   stats stay its own.
//!
//! [`Histogram`] is fixed-bucket (log-spaced bounds chosen at
//! construction), so `observe` is a binary search plus two relaxed
//! atomic adds — no allocation, no lock, safe from shard threads.
//! Quantiles come from the bucket counts with linear interpolation
//! inside the winning bucket: cheap, deterministic, and accurate to
//! bucket resolution (~2× spacing here — plenty for a p50/p99 digest;
//! the bench records keep the exact sorted-sample [`percentile`]).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Exact percentile over an **ascending-sorted** slice, nearest-rank
/// with round-half-up: `q` in [0, 1]; returns NaN for an empty slice.
/// This is the exact rank rule `run_loadgen` has always used for the
/// bench records (p50 of 1..=100 is 51), kept here so every caller
/// shares one definition.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    sorted[((sorted.len() - 1) as f64 * q).round() as usize]
}

/// Arithmetic mean; NaN for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Default histogram bounds: log-spaced (×2) from 1 µs to ~17 s,
/// in milliseconds. 25 buckets + one overflow bucket.
pub fn default_bounds_ms() -> Vec<f64> {
    (0..25).map(|k| 0.001 * (1u64 << k) as f64).collect()
}

/// A fixed-bucket histogram. Bounds are upper edges (a value lands in
/// the first bucket whose bound is `>= v`); values past the last bound
/// land in the overflow bucket, which quantile extraction reports at
/// the last finite bound.
pub struct Histogram {
    bounds: Vec<f64>,
    /// bounds.len() + 1 slots; the last is the overflow bucket.
    counts: Vec<AtomicU64>,
    count: AtomicU64,
    /// f64 bits of the running sum, advanced by compare-exchange.
    sum_bits: AtomicU64,
}

impl Histogram {
    /// Histogram over the default millisecond bounds.
    pub fn new_ms() -> Histogram {
        Self::with_bounds(default_bounds_ms())
    }

    /// Histogram over caller-chosen ascending upper edges.
    pub fn with_bounds(bounds: Vec<f64>) -> Histogram {
        let n = bounds.len() + 1;
        Histogram {
            bounds,
            counts: (0..n).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0f64.to_bits()),
        }
    }

    /// Record one value. Lock-free and alloc-free.
    pub fn observe(&self, v: f64) {
        let idx = self.bounds.partition_point(|b| *b < v);
        self.counts[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        let mut cur = self.sum_bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match self.sum_bits.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> f64 {
        f64::from_bits(self.sum_bits.load(Ordering::Relaxed))
    }

    /// Mean of all observed values; NaN when empty.
    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return f64::NAN;
        }
        self.sum() / n as f64
    }

    /// Quantile `q` in [0, 1] from the bucket counts, linearly
    /// interpolated between the winning bucket's edges; NaN when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return f64::NAN;
        }
        let rank = (q.clamp(0.0, 1.0) * total as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            let c = c.load(Ordering::Relaxed);
            if c == 0 {
                continue;
            }
            if seen + c >= rank {
                let hi = if i < self.bounds.len() {
                    self.bounds[i]
                } else {
                    // Overflow bucket: report the last finite edge.
                    return *self.bounds.last().unwrap_or(&f64::NAN);
                };
                let lo = if i == 0 { 0.0 } else { self.bounds[i - 1] };
                let frac = (rank - seen) as f64 / c as f64;
                return lo + (hi - lo) * frac;
            }
            seen += c;
        }
        *self.bounds.last().unwrap_or(&f64::NAN)
    }

    /// Bucket `(upper_edge, count)` pairs, overflow last with an
    /// infinite edge — the exposition's `le` series.
    pub fn buckets(&self) -> Vec<(f64, u64)> {
        self.counts
            .iter()
            .enumerate()
            .map(|(i, c)| {
                let edge = self.bounds.get(i).copied().unwrap_or(f64::INFINITY);
                (edge, c.load(Ordering::Relaxed))
            })
            .collect()
    }
}

/// A point-in-time copy of the registry, for the exporters.
pub struct MetricsSnapshot {
    pub counters: Vec<(String, u64)>,
    pub gauges: Vec<(String, u64)>,
    /// Live handles — histograms are cheap to read at export time.
    pub histograms: Vec<(String, Arc<Histogram>)>,
}

/// Named metrics, `.`-separated names (`server.pushes_total`). The
/// exposition replaces `.` with `_` and prefixes `smmf_`.
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, Arc<AtomicU64>>>,
    gauges: Mutex<BTreeMap<String, Arc<AtomicU64>>>,
    histograms: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Get-or-create a counter. Cache the handle; don't look up per hit.
    pub fn counter(&self, name: &str) -> Arc<AtomicU64> {
        Arc::clone(
            self.counters
                .lock()
                .unwrap()
                .entry(name.to_string())
                .or_insert_with(|| Arc::new(AtomicU64::new(0))),
        )
    }

    /// Get-or-create a gauge (a settable u64).
    pub fn gauge(&self, name: &str) -> Arc<AtomicU64> {
        Arc::clone(
            self.gauges
                .lock()
                .unwrap()
                .entry(name.to_string())
                .or_insert_with(|| Arc::new(AtomicU64::new(0))),
        )
    }

    /// Get-or-create a histogram over the default ms bounds.
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        Arc::clone(
            self.histograms
                .lock()
                .unwrap()
                .entry(name.to_string())
                .or_insert_with(|| Arc::new(Histogram::new_ms())),
        )
    }

    /// Register an externally-owned counter handle under `name`,
    /// replacing any previous one (see the module docs on why).
    pub fn publish_counter(&self, name: &str, handle: Arc<AtomicU64>) {
        self.counters.lock().unwrap().insert(name.to_string(), handle);
    }

    /// Register an externally-owned gauge handle under `name`.
    pub fn publish_gauge(&self, name: &str, handle: Arc<AtomicU64>) {
        self.gauges.lock().unwrap().insert(name.to_string(), handle);
    }

    /// Register an externally-owned histogram under `name`.
    pub fn publish_histogram(&self, name: &str, handle: Arc<Histogram>) {
        self.histograms.lock().unwrap().insert(name.to_string(), handle);
    }

    /// Current value of a counter or gauge, if registered — the CLI
    /// digest lines read lane counters through this.
    pub fn value(&self, name: &str) -> Option<u64> {
        if let Some(c) = self.counters.lock().unwrap().get(name) {
            return Some(c.load(Ordering::Relaxed));
        }
        self.gauges.lock().unwrap().get(name).map(|g| g.load(Ordering::Relaxed))
    }

    /// Sorted point-in-time copy for the exporters.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let counters = self
            .counters
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
            .collect();
        let gauges = self
            .gauges
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
            .collect();
        let histograms = self
            .histograms
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), Arc::clone(v)))
            .collect();
        MetricsSnapshot { counters, gauges, histograms }
    }
}

static GLOBAL: OnceLock<Registry> = OnceLock::new();

/// The process-global registry.
pub fn global() -> &'static Registry {
    GLOBAL.get_or_init(Registry::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_picks_expected_ranks() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 0.5), 51.0);
        assert_eq!(percentile(&xs, 0.99), 99.0);
        assert_eq!(percentile(&xs, 1.0), 100.0);
        assert!(percentile(&[], 0.5).is_nan());
    }

    #[test]
    fn mean_matches_hand_math() {
        assert_eq!(mean(&[1.0, 2.0, 3.0, 6.0]), 3.0);
        assert!(mean(&[]).is_nan());
    }

    #[test]
    fn histogram_counts_sum_and_quantiles() {
        let h = Histogram::with_bounds(vec![1.0, 2.0, 4.0, 8.0]);
        for v in [0.5, 1.5, 1.5, 3.0, 7.0, 100.0] {
            h.observe(v);
        }
        assert_eq!(h.count(), 6);
        assert!((h.sum() - 113.5).abs() < 1e-9);
        assert!((h.mean() - 113.5 / 6.0).abs() < 1e-9);
        // Buckets: le=1 -> 1, le=2 -> 2, le=4 -> 1, le=8 -> 1, +inf -> 1.
        let b = h.buckets();
        assert_eq!(b.len(), 5);
        assert_eq!(b.iter().map(|(_, c)| *c).collect::<Vec<_>>(), vec![1, 2, 1, 1, 1]);
        // p50: rank 3 of 6 lands in the (1, 2] bucket at its far edge.
        assert_eq!(h.quantile(0.5), 2.0);
        // p99: rank 6 lands in the overflow bucket -> last finite edge.
        assert_eq!(h.quantile(0.99), 8.0);
        assert!(Histogram::new_ms().quantile(0.5).is_nan());
    }

    #[test]
    fn registry_handles_are_shared_and_publish_replaces() {
        let r = Registry::new();
        let c = r.counter("x.hits");
        c.fetch_add(3, Ordering::Relaxed);
        assert_eq!(r.value("x.hits"), Some(3));
        // Same name -> same handle.
        r.counter("x.hits").fetch_add(1, Ordering::Relaxed);
        assert_eq!(c.load(Ordering::Relaxed), 4);
        // Publish replaces the handle; the exposition follows the new one.
        let owned = Arc::new(AtomicU64::new(70));
        r.publish_counter("x.hits", Arc::clone(&owned));
        assert_eq!(r.value("x.hits"), Some(70));
        let snap = r.snapshot();
        assert_eq!(snap.counters, vec![("x.hits".to_string(), 70)]);
    }
}
