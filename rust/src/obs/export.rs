//! Exporters: Chrome trace-event JSON, Prometheus text exposition, and
//! the bridge that turns measured histograms into `BENCH_*.json`
//! records.
//!
//! All three are deterministic given their inputs: the trace exporter
//! works off the sorted [`TraceDump`], object keys come out of the
//! in-tree JSON writer's `BTreeMap` (sorted), and the exposition sorts
//! by metric name — so a run with an injected clock pins the exported
//! bytes exactly (`rust/tests/obs.rs`).

use anyhow::{Context, Result};

use crate::obs::metrics::{Histogram, MetricsSnapshot};
use crate::obs::trace::{Phase, TraceDump};
use crate::util::bench::JsonSink;
use crate::util::json::{Json, ObjBuilder};

/// Serialize a drained trace as Chrome trace-event JSON (the "JSON
/// array format" with a `traceEvents` wrapper), loadable in Perfetto
/// (ui.perfetto.dev) or `chrome://tracing`. Complete spans use
/// `"ph":"X"` with `ts`/`dur` in microseconds; markers use the
/// thread-scoped instant `"ph":"i"`.
pub fn chrome_trace_json(dump: &TraceDump) -> String {
    let events: Vec<Json> = dump
        .events
        .iter()
        .map(|e| {
            let mut b = ObjBuilder::new()
                .str("cat", e.cat)
                .str("name", e.name)
                .num("pid", 1.0)
                .num("tid", e.tid as f64)
                .num("ts", e.ts_us as f64);
            b = match e.ph {
                Phase::Complete => b.str("ph", "X").num("dur", e.dur_us as f64),
                Phase::Instant => b.str("ph", "i").str("s", "t"),
            };
            b.build()
        })
        .collect();
    ObjBuilder::new()
        .val("traceEvents", Json::Arr(events))
        .num("droppedEvents", dump.dropped as f64)
        .build()
        .to_string()
        + "\n"
}

/// `smmf_server_pushes_total` from `server.pushes_total`.
fn prom_name(name: &str) -> String {
    let mut out = String::with_capacity(name.len() + 5);
    out.push_str("smmf_");
    for c in name.chars() {
        out.push(if c == '.' || c == '-' { '_' } else { c });
    }
    out
}

/// Prometheus floats: integers print bare (`3`, not `3.0`), matching
/// the in-tree JSON writer's rule so the two artifacts agree.
fn prom_num(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 9e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

/// Render a registry snapshot as Prometheus text exposition (one
/// `# TYPE` line per family, sorted by name; histograms export
/// summary-style `quantile` series plus `_sum`/`_count`).
pub fn prometheus_text(snap: &MetricsSnapshot) -> String {
    let mut out = String::new();
    for (name, v) in &snap.counters {
        let n = prom_name(name);
        out.push_str(&format!("# TYPE {n} counter\n{n} {v}\n"));
    }
    for (name, v) in &snap.gauges {
        let n = prom_name(name);
        out.push_str(&format!("# TYPE {n} gauge\n{n} {v}\n"));
    }
    for (name, h) in &snap.histograms {
        let n = prom_name(name);
        out.push_str(&format!("# TYPE {n} summary\n"));
        if h.count() > 0 {
            for q in [0.5, 0.99] {
                out.push_str(&format!(
                    "{n}{{quantile=\"{q}\"}} {}\n",
                    prom_num(h.quantile(q))
                ));
            }
        }
        out.push_str(&format!("{n}_sum {}\n", prom_num(h.sum())));
        out.push_str(&format!("{n}_count {}\n", h.count()));
    }
    out
}

/// One measured bench record for histogram `name`.
fn hist_record(name: &str, h: &Histogram) -> Json {
    ObjBuilder::new()
        .str("name", &format!("obs/{name}"))
        .num("count", h.count() as f64)
        .num("mean_ms", h.mean())
        .num("p50_ms", h.quantile(0.5))
        .num("p99_ms", h.quantile(0.99))
        .build()
}

/// Resolve a repo-root bench file from inside `rust/` or at the root —
/// the same layout probe `repro loadgen` uses for its default
/// `--bench-json`.
fn bench_path(file: &str) -> String {
    if std::path::Path::new("docs").is_dir() || !std::path::Path::new("../docs").is_dir() {
        file.to_string()
    } else {
        format!("../{file}")
    }
}

/// Bridge the measured histograms into the tracked bench reports:
/// `optim.*` histograms become `obs/…` records in
/// `BENCH_optimizer_step.json` (path overridable with
/// `SMMF_BENCH_JSON`), `server.*` histograms in `BENCH_server.json`
/// (`SMMF_SERVER_BENCH_JSON`) — merged update-in-place by
/// [`JsonSink::write`], so the timing records land next to the
/// loadgen/bench rows without disturbing them. Histograms with no
/// observations are skipped.
pub fn write_bench_records(snap: &MetricsSnapshot) -> Result<()> {
    let optim_path = std::env::var("SMMF_BENCH_JSON")
        .ok()
        .filter(|p| !p.is_empty())
        .unwrap_or_else(|| bench_path("BENCH_optimizer_step.json"));
    let server_path = std::env::var("SMMF_SERVER_BENCH_JSON")
        .ok()
        .filter(|p| !p.is_empty())
        .unwrap_or_else(|| bench_path("BENCH_server.json"));
    let mut optim = JsonSink::new("optimizer_step", &optim_path);
    let mut server = JsonSink::new("server_loadgen", &server_path);
    for (name, h) in &snap.histograms {
        if h.count() == 0 {
            continue;
        }
        if name.starts_with("optim.") {
            optim.push(hist_record(name, h));
        } else if name.starts_with("server.") {
            server.push(hist_record(name, h));
        }
    }
    for sink in [&optim, &server] {
        if !sink.is_empty() {
            sink.write()
                .with_context(|| format!("writing bench records to {}", sink.path().display()))?;
            println!(
                "[obs] merged {} measured histogram record(s) into {}",
                sink.len(),
                sink.path().display()
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::metrics::Registry;
    use crate::obs::trace::{Clock, Recorder};
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Arc;

    fn counter_clock() -> Clock {
        let t = AtomicU64::new(0);
        Arc::new(move || t.fetch_add(5, Ordering::Relaxed))
    }

    #[test]
    fn chrome_trace_bytes_are_deterministic_with_injected_clock() {
        let rec = Arc::new(Recorder::with_clock(counter_clock()));
        {
            let _outer = rec.span("optim", "optim.step");
            rec.mark("server", "lane.submit");
        }
        let json = chrome_trace_json(&rec.drain());
        assert_eq!(
            json,
            concat!(
                r#"{"droppedEvents":0,"traceEvents":["#,
                r#"{"cat":"optim","dur":10,"name":"optim.step","ph":"X","pid":1,"tid":1,"ts":0},"#,
                r#"{"cat":"server","name":"lane.submit","ph":"i","pid":1,"s":"t","tid":1,"ts":5}"#,
                "]}\n"
            )
        );
        // Parseable by the in-tree reader too.
        let parsed = Json::parse(&json).unwrap();
        assert_eq!(parsed.get("traceEvents").and_then(Json::as_arr).unwrap().len(), 2);
    }

    #[test]
    fn prometheus_text_is_sorted_and_typed() {
        let r = Registry::new();
        r.counter("server.pushes_total").store(42, Ordering::Relaxed);
        r.gauge("server.epoch").store(3, Ordering::Relaxed);
        let h = r.histogram("server.commit_ms");
        h.observe(0.5);
        h.observe(0.5);
        let text = prometheus_text(&r.snapshot());
        assert!(text.contains("# TYPE smmf_server_pushes_total counter\nsmmf_server_pushes_total 42\n"));
        assert!(text.contains("# TYPE smmf_server_epoch gauge\nsmmf_server_epoch 3\n"));
        assert!(text.contains("# TYPE smmf_server_commit_ms summary\n"));
        assert!(text.contains("smmf_server_commit_ms_count 2\n"));
        assert!(text.contains("smmf_server_commit_ms_sum 1\n"));
        assert!(text.contains("smmf_server_commit_ms{quantile=\"0.5\"}"));
        // An empty histogram exports no quantile series (NaN is not
        // valid exposition), just _sum/_count.
        let r2 = Registry::new();
        r2.histogram("optim.step_ms");
        let t2 = prometheus_text(&r2.snapshot());
        assert!(t2.contains("smmf_optim_step_ms_count 0\n"));
        assert!(!t2.contains("quantile"));
    }
}
