//! In-tree substrates.
//!
//! The build environment is offline with only the `xla` crate vendored, so
//! every auxiliary dependency a framework normally pulls from crates.io is
//! implemented here: a seeded PCG RNG, a JSON parser/writer (for the AOT
//! manifest and metrics), a TOML-subset config parser, a CLI argument
//! parser, byte/duration formatting, a micro-benchmark harness, a
//! property-testing harness, a deterministic wire-corruption fuzz
//! driver and the shared `Busy`-backoff machinery.

pub mod backoff;
pub mod bench;
pub mod cli;
pub mod fmt;
pub mod fuzzwire;
pub mod json;
pub mod prop;
pub mod rng;
pub mod toml;
