//! Seeded PCG32 random number generator (O'Neill 2014).
//!
//! Deterministic across platforms; used for synthetic data, parameter
//! initialization and the property-testing harness.

/// PCG-XSH-RR 64/32 generator.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Self { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Snapshot the full generator state `(state, inc)` for
    /// checkpointing; [`Pcg32::from_state`] restores the exact stream
    /// position, which is what makes resumed runs bit-identical.
    pub fn state(&self) -> (u64, u64) {
        (self.state, self.inc)
    }

    /// Rebuild a generator from a [`Pcg32::state`] snapshot.
    pub fn from_state(state: u64, inc: u64) -> Self {
        Self { state, inc }
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(6364136223846793005).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n). n must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection method.
        let n = n as u64;
        loop {
            let x = self.next_u32() as u64;
            let m = x.wrapping_mul(n);
            let l = m as u32 as u64;
            if l >= n && (l as u32) < (u32::MAX - (u32::MAX % n as u32)) {
                return (m >> 32) as usize;
            }
            if l >= (n.wrapping_neg() % n) {
                return (m >> 32) as usize;
            }
        }
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.uniform().max(1e-12);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Fill a slice with N(0, scale^2) samples.
    pub fn fill_normal(&mut self, out: &mut [f32], scale: f32) {
        for v in out.iter_mut() {
            *v = self.normal() * scale;
        }
    }

    /// Sample from a Zipf(s) distribution over [0, n) (rank-frequency
    /// text-like token stream).
    pub fn zipf(&mut self, n: usize, s: f64, harmonic: f64) -> usize {
        // Inverse-CDF by linear scan is too slow; use rejection-inversion lite:
        // draw u, walk a precomputed-free approximation via the integral of
        // x^-s. Good enough for synthetic corpora.
        let u = self.uniform() as f64 * harmonic;
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            if acc >= u {
                return k - 1;
            }
        }
        n - 1
    }
}

/// Precompute the harmonic normalizer for [`Pcg32::zipf`].
pub fn zipf_harmonic(n: usize, s: f64) -> f64 {
    (1..=n).map(|k| 1.0 / (k as f64).powf(s)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::new(42);
        let mut b = Pcg32::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn state_snapshot_resumes_the_stream() {
        let mut a = Pcg32::new(9);
        for _ in 0..17 {
            a.next_u32();
        }
        let (s, inc) = a.state();
        let mut b = Pcg32::from_state(s, inc);
        for _ in 0..50 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg32::new(1);
        let mut b = Pcg32::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u32()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u32()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut rng = Pcg32::new(7);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u as f64;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn below_in_range() {
        let mut rng = Pcg32::new(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let k = rng.below(7);
            assert!(k < 7);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::new(11);
        let n = 20_000;
        let (mut sum, mut sq) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = rng.normal() as f64;
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn zipf_is_skewed() {
        let n = 100;
        let h = zipf_harmonic(n, 1.1);
        let mut rng = Pcg32::new(5);
        let mut counts = vec![0usize; n];
        for _ in 0..5000 {
            counts[rng.zipf(n, 1.1, h)] += 1;
        }
        assert!(counts[0] > counts[10] && counts[10] > counts[50]);
    }
}
