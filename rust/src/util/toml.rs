//! TOML-subset parser for experiment configs.
//!
//! Supports: `[section]` / `[a.b]` headers, `[[a.b]]` array-of-tables
//! headers (each occurrence appends an indexed table; values land under
//! `a.b.<index>.key`, enumerable via [`TomlDoc::array_len`]), `key =
//! value` with string, integer, float, boolean and flat-array values,
//! `#` comments. This covers every config shipped under `configs/`;
//! exotic TOML (dates, inline tables, multiline strings) is
//! intentionally rejected with an error.

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// A parsed config: dotted-path key -> value (e.g. "optimizer.lr").
/// Array-of-tables entries are flattened to `name.<index>.key`; the
/// per-name occurrence counts live in `arrays`.
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    pub values: BTreeMap<String, TomlValue>,
    pub arrays: BTreeMap<String, usize>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc, String> {
        let mut doc = TomlDoc::default();
        let mut prefix = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix("[[") {
                let name = rest
                    .strip_suffix("]]")
                    .ok_or_else(|| format!("line {}: unterminated table array", lineno + 1))?
                    .trim();
                if name.is_empty() {
                    return Err(format!("line {}: empty table-array name", lineno + 1));
                }
                let idx = doc.arrays.entry(name.to_string()).or_insert(0);
                prefix = format!("{name}.{idx}.");
                *idx += 1;
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: unterminated section", lineno + 1))?
                    .trim();
                if name.is_empty() {
                    return Err(format!("line {}: empty section name", lineno + 1));
                }
                prefix = format!("{name}.");
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = line[..eq].trim();
            if key.is_empty() {
                return Err(format!("line {}: empty key", lineno + 1));
            }
            let value = parse_value(line[eq + 1..].trim())
                .map_err(|e| format!("line {}: {e}", lineno + 1))?;
            doc.values.insert(format!("{prefix}{key}"), value);
        }
        Ok(doc)
    }

    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.values.get(key)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(|v| v.as_str()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn i64_or(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(|v| v.as_i64()).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    /// Strict count key: absent -> `default`; present but not an
    /// integer >= 1 -> a clear error (callers prefix their section).
    /// The shared validator behind `[suite] workers` and the `[server]`
    /// count knobs — count config where 0 is a mistake the user must
    /// see, not a value to clamp.
    pub fn count_or(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => match v.as_i64() {
                Some(n) if n >= 1 => Ok(n as usize),
                _ => Err(format!("{key} must be an integer >= 1")),
            },
        }
    }

    /// Number of `[[name]]` table-array occurrences (0 when absent).
    pub fn array_len(&self, name: &str) -> usize {
        self.arrays.get(name).copied().unwrap_or(0)
    }

    /// A key's value as a list of strings: either a TOML array of
    /// strings or a single bare string. `None` when absent or not
    /// string-valued.
    pub fn str_list(&self, key: &str) -> Option<Vec<String>> {
        match self.get(key)? {
            TomlValue::Str(s) => Some(vec![s.clone()]),
            TomlValue::Arr(items) => items
                .iter()
                .map(|v| v.as_str().map(String::from))
                .collect::<Option<Vec<String>>>(),
            _ => None,
        }
    }

    /// A key's value as a list of integers: either a TOML array of
    /// integers or a single bare integer (`seeds = [0, 1, 2]` /
    /// `seeds = 3`). `None` when absent or not integer-valued.
    pub fn i64_list(&self, key: &str) -> Option<Vec<i64>> {
        match self.get(key)? {
            TomlValue::Int(i) => Some(vec![*i]),
            TomlValue::Arr(items) => {
                items.iter().map(|v| v.as_i64()).collect::<Option<Vec<i64>>>()
            }
            _ => None,
        }
    }

    /// Iterate the key suffixes under a dotted prefix (e.g. prefix
    /// `"suite.run.0"` yields `"steps"`, `"optimizers"`, …). Used by the
    /// suite parser to reject unknown keys instead of silently ignoring
    /// typos.
    pub fn keys_under<'a>(&'a self, prefix: &'a str) -> impl Iterator<Item = &'a str> + 'a {
        self.values.keys().filter_map(move |k| {
            k.strip_prefix(prefix).and_then(|rest| rest.strip_prefix('.'))
        })
    }
}

fn strip_comment(line: &str) -> &str {
    // A '#' outside of quotes starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let end = rest.rfind('"').ok_or("unterminated string")?;
        return Ok(TomlValue::Str(rest[..end].to_string()));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or("unterminated array")?;
        let mut out = Vec::new();
        let trimmed = inner.trim();
        if !trimmed.is_empty() {
            for part in split_top_level(trimmed) {
                out.push(parse_value(part.trim())?);
            }
        }
        return Ok(TomlValue::Arr(out));
    }
    match s {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.replace('_', "").parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = s.replace('_', "").parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value: {s}"))
}

fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let (mut depth, mut start, mut in_str) = (0usize, 0usize, false);
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn count_or_validates_instead_of_clamping() {
        let doc = TomlDoc::parse("[server]\nshards = 2\nbad = 0\nworse = -1\nnan = \"x\"").unwrap();
        assert_eq!(doc.count_or("server.shards", 1), Ok(2));
        assert_eq!(doc.count_or("server.absent", 7), Ok(7));
        for k in ["server.bad", "server.worse", "server.nan"] {
            assert!(doc.count_or(k, 1).unwrap_err().contains(">= 1"), "{k}");
        }
    }

    #[test]
    fn parse_full_config() {
        let text = r#"
# experiment config
name = "fig2"
steps = 400

[optimizer]
kind = "smmf"
lr = 1e-3
decay_rate = -0.8
vector_reshape = true

[model]
sizes = [128, 256]
"#;
        let doc = TomlDoc::parse(text).unwrap();
        assert_eq!(doc.str_or("name", ""), "fig2");
        assert_eq!(doc.i64_or("steps", 0), 400);
        assert_eq!(doc.str_or("optimizer.kind", ""), "smmf");
        assert_eq!(doc.f64_or("optimizer.lr", 0.0), 1e-3);
        assert_eq!(doc.f64_or("optimizer.decay_rate", 0.0), -0.8);
        assert!(doc.bool_or("optimizer.vector_reshape", false));
        assert_eq!(
            doc.get("model.sizes").unwrap(),
            &TomlValue::Arr(vec![TomlValue::Int(128), TomlValue::Int(256)])
        );
    }

    #[test]
    fn comments_and_strings_with_hash() {
        let doc = TomlDoc::parse("a = \"x # y\" # trailing\nb = 2").unwrap();
        assert_eq!(doc.str_or("a", ""), "x # y");
        assert_eq!(doc.i64_or("b", 0), 2);
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(TomlDoc::parse("[unterminated").is_err());
        assert!(TomlDoc::parse("keyonly").is_err());
        assert!(TomlDoc::parse("k = @oops").is_err());
    }

    #[test]
    fn array_of_tables_flattens_with_indices() {
        let text = r#"
[optimizer]
kind = "smmf"

[[optimizer.group]]
name = "no_decay"
match_role = ["bias", "norm"]
weight_decay = 0.0

[[optimizer.group]]
name = "emb"
match_name = "*emb*"
lr_scale = 0.5
state = "dense"
"#;
        let doc = TomlDoc::parse(text).unwrap();
        assert_eq!(doc.array_len("optimizer.group"), 2);
        assert_eq!(doc.array_len("absent"), 0);
        assert_eq!(doc.str_or("optimizer.group.0.name", ""), "no_decay");
        assert_eq!(doc.f64_or("optimizer.group.0.weight_decay", 1.0), 0.0);
        assert_eq!(
            doc.str_list("optimizer.group.0.match_role"),
            Some(vec!["bias".to_string(), "norm".to_string()])
        );
        assert_eq!(
            doc.str_list("optimizer.group.1.match_name"),
            Some(vec!["*emb*".to_string()])
        );
        assert_eq!(doc.f64_or("optimizer.group.1.lr_scale", 0.0), 0.5);
        assert_eq!(doc.str_or("optimizer.group.1.state", ""), "dense");
        // plain section parsing is unaffected
        assert_eq!(doc.str_or("optimizer.kind", ""), "smmf");
        assert!(TomlDoc::parse("[[oops]").is_err());
        assert!(TomlDoc::parse("[[]]").is_err());
    }

    #[test]
    fn int_lists_and_key_enumeration() {
        let doc = TomlDoc::parse(
            "[suite]\nseeds = [0, 1, 7]\nsolo = 3\n[[suite.run]]\nsteps = 5\nmodels = [\"a\"]\n",
        )
        .unwrap();
        assert_eq!(doc.i64_list("suite.seeds"), Some(vec![0, 1, 7]));
        assert_eq!(doc.i64_list("suite.solo"), Some(vec![3]));
        assert_eq!(doc.i64_list("absent"), None);
        // non-integer lists are rejected, not coerced
        let bad = TomlDoc::parse("seeds = [1, \"x\"]").unwrap();
        assert_eq!(bad.i64_list("seeds"), None);
        let mut keys: Vec<&str> = doc.keys_under("suite.run.0").collect();
        keys.sort();
        assert_eq!(keys, vec!["models", "steps"]);
        // the prefix match is segment-aware: `suite.runx` keys don't leak in
        assert_eq!(doc.keys_under("suite.run").count(), 2); // "0.steps", "0.models"
    }

    #[test]
    fn underscored_numbers() {
        let doc = TomlDoc::parse("n = 1_000_000\nf = 2_5.5").unwrap();
        assert_eq!(doc.i64_or("n", 0), 1_000_000);
        assert_eq!(doc.f64_or("f", 0.0), 25.5);
    }
}
