//! Capped exponential `Busy` backoff with deterministic jitter.
//!
//! Extracted from the optimizer-state server client so every subsystem
//! that absorbs `Busy` backpressure — [`crate::server::Client::call_retry`]
//! and the remote suite dispatcher (`coordinator::remote`) — shares one
//! retry-timing implementation. The sequence is a pure function of the
//! seed: a fixed-seed PCG stream keeps runs reproducible, while
//! concurrent clients still decorrelate because each sleeps a different
//! number of times. The exact delay sequence is pinned by the unit tests
//! below, so refactors cannot silently change retry timing.

use std::time::Duration;

use crate::util::rng::Pcg32;

/// First-bounce delay in microseconds; doubles per consecutive bounce.
pub const BACKOFF_BASE_US: u64 = 200;
/// Per-bounce delay ceiling in microseconds.
pub const BACKOFF_CAP_US: u64 = 50_000;
/// Default jitter-stream seed (the historical `server::Client` seed —
/// kept so extraction leaves existing retry timing bit-unchanged).
pub const JITTER_SEED: u64 = 0x6a17_7e72;

/// Backoff state: a jitter stream plus the consecutive-bounce level.
///
/// [`Backoff::reset`] zeroes the level on success but never rewinds the
/// jitter stream — each sleep consumes one fresh draw, exactly like the
/// pre-extraction client fields (`jitter`, `backoff_level`) did.
#[derive(Clone, Debug)]
pub struct Backoff {
    jitter: Pcg32,
    level: u32,
    bounces: u64,
}

impl Default for Backoff {
    fn default() -> Self {
        Self::new()
    }
}

impl Backoff {
    /// A backoff with the default [`JITTER_SEED`] stream.
    pub fn new() -> Backoff {
        Backoff::with_seed(JITTER_SEED)
    }

    pub fn with_seed(seed: u64) -> Backoff {
        Backoff { jitter: Pcg32::new(seed), level: 0, bounces: 0 }
    }

    /// The next delay: `BACKOFF_BASE_US << level` capped at
    /// [`BACKOFF_CAP_US`], scaled by a ±25% jitter factor in
    /// `[0.75, 1.25)`. Advances both the level and the jitter stream.
    pub fn next_delay(&mut self) -> Duration {
        let base = (BACKOFF_BASE_US << self.level.min(16)).min(BACKOFF_CAP_US);
        // ±25% jitter: scale by a factor in [0.75, 1.25).
        let us = base * (750 + self.jitter.below(500) as u64) / 1000;
        self.level += 1;
        self.bounces += 1;
        Duration::from_micros(us)
    }

    /// Sleep for [`Backoff::next_delay`].
    pub fn sleep(&mut self) {
        std::thread::sleep(self.next_delay());
    }

    /// Success: restart the exponential ramp (the jitter stream keeps
    /// advancing from where it is).
    pub fn reset(&mut self) {
        self.level = 0;
    }

    /// Total sleeps taken over the life of this backoff.
    pub fn bounces(&self) -> u64 {
        self.bounces
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The extraction contract: with the historical seed, the delay
    /// sequence is bit-identical to what `server::Client::call_retry`
    /// computed inline before `util::backoff` existed. These constants
    /// were derived from the PCG32 stream definition independently of
    /// this implementation — if they drift, retry timing changed.
    #[test]
    fn pins_the_default_jitter_delay_sequence() {
        let mut b = Backoff::new();
        let expect_us = [174u64, 394, 741, 1547, 3660, 7411, 10803, 25830];
        for (i, &us) in expect_us.iter().enumerate() {
            assert_eq!(b.next_delay(), Duration::from_micros(us), "bounce {i}");
        }
        assert_eq!(b.bounces(), expect_us.len() as u64);
    }

    /// `reset` restarts the exponential ramp but must not rewind the
    /// jitter stream (a reconnect-free success mid-burst keeps drawing
    /// fresh jitter — the pre-extraction behavior).
    #[test]
    fn reset_restarts_level_but_not_the_jitter_stream() {
        let mut b = Backoff::new();
        let mut seq = Vec::new();
        for i in 0..6 {
            if i == 3 {
                b.reset();
            }
            seq.push(b.next_delay().as_micros() as u64);
        }
        assert_eq!(seq, [174, 394, 741, 193, 457, 926]);
    }

    /// The doubling is capped: past level 16 the shift stops growing and
    /// the 50ms ceiling bounds every delay (jitter can only lower it).
    #[test]
    fn delays_are_capped() {
        let mut b = Backoff::new();
        for _ in 0..64 {
            let d = b.next_delay();
            assert!(d <= Duration::from_micros(BACKOFF_CAP_US * 1250 / 1000));
        }
        // deep into the ramp every delay sits at the cap (± jitter)
        let d = b.next_delay().as_micros() as u64;
        assert!(d >= BACKOFF_CAP_US * 750 / 1000, "capped delay too small: {d}");
    }

    #[test]
    fn distinct_seeds_decorrelate() {
        let mut a = Backoff::with_seed(1);
        let mut b = Backoff::with_seed(2);
        let sa: Vec<_> = (0..8).map(|_| a.next_delay()).collect();
        let sb: Vec<_> = (0..8).map(|_| b.next_delay()).collect();
        assert_ne!(sa, sb);
    }
}
