//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Warms up, auto-scales iteration counts to a target measurement time,
//! reports median / mean / p10 / p90 over sample batches, and prints
//! criterion-like one-line summaries. Used by `rust/benches/*`.
//!
//! [`JsonSink`] additionally emits a machine-readable report (one record
//! per measurement: model, optimizer, thread count, median/p10/p90/mean
//! nanoseconds) so the perf trajectory is tracked across PRs — wire it
//! up with `SMMF_BENCH_JSON=<path>`.

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use super::json::{Json, ObjBuilder};

#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters_per_sample: u64,
    pub samples: Vec<Duration>,
    pub median: Duration,
    pub mean: Duration,
    pub p10: Duration,
    pub p90: Duration,
}

impl BenchStats {
    pub fn summary(&self) -> String {
        format!(
            "{:<44} median {:>12}  mean {:>12}  [p10 {} .. p90 {}]  ({} samples x {} iters)",
            self.name,
            super::fmt::duration(self.median),
            super::fmt::duration(self.mean),
            super::fmt::duration(self.p10),
            super::fmt::duration(self.p90),
            self.samples.len(),
            self.iters_per_sample,
        )
    }
}

pub struct Bencher {
    pub warmup: Duration,
    pub target_sample: Duration,
    pub samples: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(300),
            target_sample: Duration::from_millis(100),
            samples: 12,
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(50),
            target_sample: Duration::from_millis(30),
            samples: 6,
        }
    }

    /// Run `f` repeatedly and gather statistics. `f` should perform one
    /// logical iteration and return something opaque to keep it alive.
    pub fn bench<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchStats {
        // Warmup + estimate single-iteration cost.
        let warm_start = Instant::now();
        let mut warm_iters = 0u64;
        while warm_start.elapsed() < self.warmup || warm_iters < 1 {
            std::hint::black_box(f());
            warm_iters += 1;
            if warm_iters > 1_000_000 {
                break;
            }
        }
        let per_iter = warm_start.elapsed().as_secs_f64() / warm_iters as f64;
        let iters = ((self.target_sample.as_secs_f64() / per_iter).ceil() as u64).clamp(1, 10_000_000);

        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                std::hint::black_box(f());
            }
            samples.push(Duration::from_secs_f64(t0.elapsed().as_secs_f64() / iters as f64));
        }
        let mut sorted = samples.clone();
        sorted.sort();
        let pick = |q: f64| sorted[((sorted.len() - 1) as f64 * q).round() as usize];
        let mean = Duration::from_secs_f64(
            samples.iter().map(|d| d.as_secs_f64()).sum::<f64>() / samples.len() as f64,
        );
        BenchStats {
            name: name.to_string(),
            iters_per_sample: iters,
            median: pick(0.5),
            mean,
            p10: pick(0.1),
            p90: pick(0.9),
            samples,
        }
    }

    /// Bench and print the one-line summary; returns the stats.
    pub fn run<T>(&self, name: &str, f: impl FnMut() -> T) -> BenchStats {
        let stats = self.bench(name, f);
        println!("{}", stats.summary());
        stats
    }
}

/// Machine-readable bench report writer (`BENCH_*.json`).
///
/// Collects one record per measurement and serializes
/// `{ "benchmark": ..., "records": [...] }` with the in-tree JSON
/// writer. Records carry the model, optimizer, engine thread count and
/// median/p10/p90/mean nanoseconds, so successive PRs can diff the perf
/// trajectory mechanically.
pub struct JsonSink {
    benchmark: String,
    path: PathBuf,
    records: Vec<Json>,
}

impl JsonSink {
    pub fn new(benchmark: &str, path: impl AsRef<Path>) -> JsonSink {
        JsonSink {
            benchmark: benchmark.to_string(),
            path: path.as_ref().to_path_buf(),
            records: Vec::new(),
        }
    }

    /// Construct from an environment variable holding the output path
    /// (e.g. `SMMF_BENCH_JSON=BENCH_optimizer_step.json`); `None` when
    /// the variable is unset or empty.
    pub fn from_env(benchmark: &str, var: &str) -> Option<JsonSink> {
        match std::env::var(var) {
            Ok(path) if !path.is_empty() => Some(JsonSink::new(benchmark, path)),
            _ => None,
        }
    }

    /// Record one measurement.
    pub fn record(&mut self, model: &str, optimizer: &str, threads: usize, stats: &BenchStats) {
        let ns = |d: Duration| d.as_secs_f64() * 1e9;
        self.records.push(
            ObjBuilder::new()
                .str("name", &stats.name)
                .str("model", model)
                .str("optimizer", optimizer)
                .num("threads", threads as f64)
                .num("median_ns", ns(stats.median))
                .num("p10_ns", ns(stats.p10))
                .num("p90_ns", ns(stats.p90))
                .num("mean_ns", ns(stats.mean))
                .num("iters_per_sample", stats.iters_per_sample as f64)
                .num("samples", stats.samples.len() as f64)
                .build(),
        );
    }

    /// Record an arbitrary pre-built JSON object — for non-timing
    /// measurements tracked alongside the perf trajectory (e.g. the
    /// SMMF-vs-Adam checkpoint size ratio emitted by the optimizer
    /// bench).
    pub fn push(&mut self, record: Json) {
        self.records.push(record);
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Serialize and write the report, merging into an existing file
    /// update-in-place: a new record whose `"name"` matches an existing
    /// record replaces it; everything else (unmatched records, and
    /// extra top-level keys like the seed files' `"note"`) is kept.
    /// Repeated smoke runs therefore refresh their rows instead of
    /// appending duplicates, and different smokes writing to the same
    /// file never erase each other's records.
    pub fn write(&self) -> std::io::Result<()> {
        let mut doc = std::fs::read_to_string(&self.path)
            .ok()
            .and_then(|t| Json::parse(&t).ok())
            .and_then(|j| j.as_obj().cloned())
            .unwrap_or_default();
        let mut records: Vec<Json> =
            doc.get("records").and_then(Json::as_arr).map(<[Json]>::to_vec).unwrap_or_default();
        for rec in &self.records {
            let key = rec.get("name").and_then(Json::as_str);
            let slot = key.and_then(|k| {
                records.iter_mut().find(|r| r.get("name").and_then(Json::as_str) == Some(k))
            });
            match slot {
                Some(slot) => *slot = rec.clone(),
                // No name (or a fresh one): append, preserving order.
                None => records.push(rec.clone()),
            }
        }
        doc.insert("benchmark".to_string(), Json::Str(self.benchmark.clone()));
        doc.insert("records".to_string(), Json::Arr(records));
        std::fs::write(&self.path, Json::Obj(doc).to_string() + "\n")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bencher {
            warmup: Duration::from_millis(5),
            target_sample: Duration::from_millis(2),
            samples: 3,
        };
        // black_box the loop bound so release builds can't fold the whole
        // closure to a constant (which would measure as exactly zero).
        let stats = b.bench("noop-ish", || {
            let n = std::hint::black_box(100u64);
            let mut acc = 0u64;
            for i in 0..n {
                acc = acc.wrapping_add(std::hint::black_box(i));
            }
            acc
        });
        assert!(stats.mean > Duration::ZERO);
        assert_eq!(stats.samples.len(), 3);
        assert!(stats.p10 <= stats.p90);
    }

    #[test]
    fn json_sink_roundtrips() {
        let b = Bencher {
            warmup: Duration::from_millis(2),
            target_sample: Duration::from_millis(1),
            samples: 2,
        };
        let stats = b.bench("mobilenet_v2_imagenet/smmf", || std::hint::black_box(1 + 1));
        let path = std::env::temp_dir().join(format!("smmf_bench_{}.json", std::process::id()));
        let mut sink = JsonSink::new("optimizer_step", &path);
        sink.record("mobilenet_v2_imagenet", "smmf", 4, &stats);
        assert_eq!(sink.len(), 1);
        sink.push(
            ObjBuilder::new()
                .str("name", "checkpoint_size/mobilenet_v2_imagenet")
                .num("smmf_vs_adam_ratio", 0.02)
                .build(),
        );
        assert_eq!(sink.len(), 2);
        sink.write().unwrap();
        let parsed = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(parsed.get("benchmark").and_then(Json::as_str), Some("optimizer_step"));
        let recs = parsed.get("records").and_then(Json::as_arr).unwrap();
        let rec = &recs[0];
        assert_eq!(rec.get("optimizer").and_then(Json::as_str), Some("smmf"));
        assert_eq!(rec.get("threads").and_then(Json::as_f64), Some(4.0));
        assert!(rec.get("median_ns").and_then(Json::as_f64).unwrap() >= 0.0);
        assert_eq!(recs[1].get("smmf_vs_adam_ratio").and_then(Json::as_f64), Some(0.02));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn json_sink_merges_by_record_name_instead_of_clobbering() {
        let path = std::env::temp_dir().join(format!("smmf_bench_merge_{}.json", std::process::id()));
        // Seed file with a note and one record, as the checked-in
        // BENCH_*.json seeds look.
        std::fs::write(
            &path,
            r#"{"benchmark":"server_loadgen","note":"seed","records":[{"name":"loadgen/a","steps_per_s":1},{"name":"loadgen/b","steps_per_s":2}]}"#,
        )
        .unwrap();
        let mut sink = JsonSink::new("server_loadgen", &path);
        sink.push(
            ObjBuilder::new().str("name", "loadgen/a").num("steps_per_s", 9.0).build(),
        );
        sink.push(
            ObjBuilder::new().str("name", "obs/server.commit_ms").num("p50_ms", 0.5).build(),
        );
        sink.write().unwrap();
        let parsed = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        // The note survives, the matching record was updated in place,
        // the unmatched one kept, the new one appended.
        assert_eq!(parsed.get("note").and_then(Json::as_str), Some("seed"));
        let recs = parsed.get("records").and_then(Json::as_arr).unwrap();
        assert_eq!(recs.len(), 3);
        assert_eq!(recs[0].get("name").and_then(Json::as_str), Some("loadgen/a"));
        assert_eq!(recs[0].get("steps_per_s").and_then(Json::as_f64), Some(9.0));
        assert_eq!(recs[1].get("steps_per_s").and_then(Json::as_f64), Some(2.0));
        assert_eq!(recs[2].get("name").and_then(Json::as_str), Some("obs/server.commit_ms"));
        // A second identical write must not grow the file.
        sink.write().unwrap();
        let parsed = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(parsed.get("records").and_then(Json::as_arr).unwrap().len(), 3);
        std::fs::remove_file(&path).unwrap();
    }
}
