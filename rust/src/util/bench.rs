//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Warms up, auto-scales iteration counts to a target measurement time,
//! reports median / mean / p10 / p90 over sample batches, and prints
//! criterion-like one-line summaries. Used by `rust/benches/*`.

use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters_per_sample: u64,
    pub samples: Vec<Duration>,
    pub median: Duration,
    pub mean: Duration,
    pub p10: Duration,
    pub p90: Duration,
}

impl BenchStats {
    pub fn summary(&self) -> String {
        format!(
            "{:<44} median {:>12}  mean {:>12}  [p10 {} .. p90 {}]  ({} samples x {} iters)",
            self.name,
            super::fmt::duration(self.median),
            super::fmt::duration(self.mean),
            super::fmt::duration(self.p10),
            super::fmt::duration(self.p90),
            self.samples.len(),
            self.iters_per_sample,
        )
    }
}

pub struct Bencher {
    pub warmup: Duration,
    pub target_sample: Duration,
    pub samples: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(300),
            target_sample: Duration::from_millis(100),
            samples: 12,
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(50),
            target_sample: Duration::from_millis(30),
            samples: 6,
        }
    }

    /// Run `f` repeatedly and gather statistics. `f` should perform one
    /// logical iteration and return something opaque to keep it alive.
    pub fn bench<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchStats {
        // Warmup + estimate single-iteration cost.
        let warm_start = Instant::now();
        let mut warm_iters = 0u64;
        while warm_start.elapsed() < self.warmup || warm_iters < 1 {
            std::hint::black_box(f());
            warm_iters += 1;
            if warm_iters > 1_000_000 {
                break;
            }
        }
        let per_iter = warm_start.elapsed().as_secs_f64() / warm_iters as f64;
        let iters = ((self.target_sample.as_secs_f64() / per_iter).ceil() as u64).clamp(1, 10_000_000);

        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                std::hint::black_box(f());
            }
            samples.push(Duration::from_secs_f64(t0.elapsed().as_secs_f64() / iters as f64));
        }
        let mut sorted = samples.clone();
        sorted.sort();
        let pick = |q: f64| sorted[((sorted.len() - 1) as f64 * q).round() as usize];
        let mean = Duration::from_secs_f64(
            samples.iter().map(|d| d.as_secs_f64()).sum::<f64>() / samples.len() as f64,
        );
        BenchStats {
            name: name.to_string(),
            iters_per_sample: iters,
            median: pick(0.5),
            mean,
            p10: pick(0.1),
            p90: pick(0.9),
            samples,
        }
    }

    /// Bench and print the one-line summary; returns the stats.
    pub fn run<T>(&self, name: &str, f: impl FnMut() -> T) -> BenchStats {
        let stats = self.bench(name, f);
        println!("{}", stats.summary());
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bencher {
            warmup: Duration::from_millis(5),
            target_sample: Duration::from_millis(2),
            samples: 3,
        };
        // black_box the loop bound so release builds can't fold the whole
        // closure to a constant (which would measure as exactly zero).
        let stats = b.bench("noop-ish", || {
            let n = std::hint::black_box(100u64);
            let mut acc = 0u64;
            for i in 0..n {
                acc = acc.wrapping_add(std::hint::black_box(i));
            }
            acc
        });
        assert!(stats.mean > Duration::ZERO);
        assert_eq!(stats.samples.len(), 3);
        assert!(stats.p10 <= stats.p90);
    }
}
