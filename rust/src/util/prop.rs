//! Property-testing harness (proptest is unavailable offline).
//!
//! `Cases` drives a closure over many seeded random inputs and, on failure,
//! re-runs a simple shrink loop over the failing seed's generated values
//! where the generator supports it. Generators are plain functions over
//! [`crate::util::rng::Pcg32`].

use super::rng::Pcg32;

/// Run `f` for `n` seeded cases; panics with the failing seed on error.
pub fn cases(n: u64, f: impl Fn(&mut Pcg32)) {
    // Fixed base seed for reproducibility; override with SMMF_PROP_SEED.
    let base = std::env::var("SMMF_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5eed_0000u64);
    for case in 0..n {
        let seed = base.wrapping_add(case);
        let mut rng = Pcg32::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property failed on case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Random tensor shape of rank 1..=max_rank with numel <= max_numel.
pub fn gen_shape(rng: &mut Pcg32, max_rank: usize, max_numel: usize) -> Vec<usize> {
    let rank = 1 + rng.below(max_rank);
    let mut shape = Vec::with_capacity(rank);
    let mut numel = 1usize;
    for i in 0..rank {
        let remaining = (max_numel / numel).max(1);
        let cap = match rank - i {
            1 => remaining,
            _ => ((remaining as f64).powf(1.0 / (rank - i) as f64) as usize).max(1),
        };
        let d = 1 + rng.below(cap.min(64).max(1));
        shape.push(d);
        numel *= d;
    }
    shape
}

/// Random f32 vector with values in [-scale, scale].
pub fn gen_vec(rng: &mut Pcg32, len: usize, scale: f32) -> Vec<f32> {
    (0..len).map(|_| rng.range_f32(-scale, scale)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cases_run_all() {
        let counter = std::cell::Cell::new(0u64);
        cases(25, |_| {
            counter.set(counter.get() + 1);
        });
        assert_eq!(counter.get(), 25);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failure_reports_seed() {
        cases(10, |rng| {
            // deterministic failure with very high probability per case
            assert!(rng.below(100) < 2, "too big");
        });
    }

    #[test]
    fn gen_shape_respects_bounds() {
        cases(50, |rng| {
            let s = gen_shape(rng, 4, 4096);
            assert!(!s.is_empty() && s.len() <= 4);
            let numel: usize = s.iter().product();
            assert!(numel >= 1 && numel <= 4096, "{s:?}");
        });
    }
}
