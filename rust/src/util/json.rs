//! Minimal JSON parser + writer (RFC 8259 subset sufficient for the AOT
//! manifest, metrics JSONL and checkpoints).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value. Numbers are kept as f64 (the manifest only uses
/// integers that fit exactly).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if !n.is_finite() {
                    // JSON has no NaN/Infinity literals; emit null so the
                    // output always reparses (e.g. a resumed run whose
                    // loop never executed leaves summary losses as NaN).
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builder for writing JSON objects field-by-field (metrics).
pub struct ObjBuilder(BTreeMap<String, Json>);

impl ObjBuilder {
    pub fn new() -> Self {
        Self(BTreeMap::new())
    }
    pub fn str(mut self, k: &str, v: &str) -> Self {
        self.0.insert(k.into(), Json::Str(v.into()));
        self
    }
    pub fn num(mut self, k: &str, v: f64) -> Self {
        self.0.insert(k.into(), Json::Num(v));
        self
    }
    pub fn bool(mut self, k: &str, v: bool) -> Self {
        self.0.insert(k.into(), Json::Bool(v));
        self
    }
    pub fn val(mut self, k: &str, v: Json) -> Self {
        self.0.insert(k.into(), v);
        self
    }
    pub fn build(self) -> Json {
        Json::Obj(self.0)
    }
}

impl Default for ObjBuilder {
    fn default() -> Self {
        Self::new()
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?;
        s.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number {s}: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|e| e.to_string())?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|e| e.to_string())?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full UTF-8 scalar
                    let s = std::str::from_utf8(&self.b[self.i..]).map_err(|e| e.to_string())?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        // JSON has no NaN/Infinity: the writer must stay parseable even
        // when a metric is undefined (e.g. a resumed run with no steps).
        let doc = ObjBuilder::new()
            .num("nan", f64::NAN)
            .num("inf", f64::INFINITY)
            .num("ok", 1.5)
            .build();
        let text = doc.to_string();
        let parsed = Json::parse(&text).expect("writer output must reparse");
        assert!(matches!(parsed.get("nan"), Some(Json::Null)));
        assert!(matches!(parsed.get("inf"), Some(Json::Null)));
        assert_eq!(parsed.get("ok").and_then(Json::as_f64), Some(1.5));
    }

    #[test]
    fn roundtrip_manifest_like() {
        let text = r#"{"artifacts": {"mlp": {"file": "mlp.hlo.txt",
            "inputs": [{"name": "w1", "shape": [32, 64], "dtype": "f32"}],
            "meta": {"lr": 1e-3, "neg": -2.5, "flag": true, "none": null}}}}"#;
        let v = Json::parse(text).unwrap();
        let mlp = v.get("artifacts").unwrap().get("mlp").unwrap();
        assert_eq!(mlp.get("file").unwrap().as_str(), Some("mlp.hlo.txt"));
        let inp = &mlp.get("inputs").unwrap().as_arr().unwrap()[0];
        assert_eq!(inp.get("shape").unwrap().as_arr().unwrap()[1].as_usize(), Some(64));
        assert_eq!(mlp.get("meta").unwrap().get("lr").unwrap().as_f64(), Some(1e-3));
        // reparse of serialization is identical
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(re, v);
    }

    #[test]
    fn escapes() {
        let v = Json::Str("a\"b\\c\nd\u{1}".into());
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"abc").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn builder() {
        let j = ObjBuilder::new().str("a", "x").num("b", 2.0).bool("c", false).build();
        assert_eq!(j.to_string(), r#"{"a":"x","b":2,"c":false}"#);
    }
}
