//! Tiny CLI argument parser (GNU-style `--flag value` / `--flag=value`).

use std::collections::BTreeMap;

/// Parsed command line: a subcommand, positional args and `--key value`
/// options.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub command: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = String>>(iter: I) -> Args {
        let mut out = Args::default();
        let mut items = iter.into_iter().peekable();
        while let Some(item) = items.next() {
            if let Some(name) = item.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if items.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = items.next().unwrap();
                    out.options.insert(name.to_string(), v);
                } else {
                    out.flags.push(name.to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(item);
            } else {
                out.positional.push(item);
            }
        }
        out
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.opt(key).unwrap_or(default).to_string()
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.opt(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.opt(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// Like [`Args::usize_or`] but clamped to >= 1 — for count knobs
    /// where 0 is meaningless (`--threads`, `--workers`).
    pub fn positive_usize_or(&self, key: &str, default: usize) -> usize {
        self.usize_or(key, default).max(1)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.opt(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// Strict count parsing: absent -> `default`; present but not an
    /// integer >= 1 -> a clear error instead of a silent clamp. Used by
    /// knobs where `--clients 0` is a config mistake the user must see
    /// (server shards, loadgen connections), as opposed to
    /// [`Args::positive_usize_or`]'s forgiving clamp.
    pub fn count_or(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.opt(key) {
            None => Ok(default),
            Some(s) => match s.parse::<usize>() {
                Ok(n) if n >= 1 => Ok(n),
                _ => Err(format!("--{key} must be an integer >= 1 (got {s:?})")),
            },
        }
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_options() {
        // Note: a bare `--name value` is greedy (option), so flags either
        // precede another `--` token or sit at the end.
        let a = parse("table1 extra --optimizer smmf --lr=0.001 --quiet");
        assert_eq!(a.command.as_deref(), Some("table1"));
        assert_eq!(a.opt("optimizer"), Some("smmf"));
        assert_eq!(a.f64_or("lr", 0.0), 0.001);
        assert!(a.has_flag("quiet"));
        assert_eq!(a.positional, vec!["extra".to_string()]);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("run --verbose --steps 10");
        assert!(a.has_flag("verbose"));
        assert_eq!(a.usize_or("steps", 0), 10);
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert!(a.command.is_none());
        assert_eq!(a.str_or("x", "d"), "d");
    }

    #[test]
    fn positive_usize_clamps_zero() {
        let a = parse("run --threads 0 --workers 4");
        assert_eq!(a.positive_usize_or("threads", 1), 1);
        assert_eq!(a.positive_usize_or("workers", 1), 4);
        assert_eq!(a.positive_usize_or("absent", 3), 3);
    }

    #[test]
    fn count_or_errors_instead_of_clamping() {
        let a = parse("serve --shards 2 --clients 0 --steps x");
        assert_eq!(a.count_or("shards", 1), Ok(2));
        assert_eq!(a.count_or("absent", 5), Ok(5));
        assert!(a.count_or("clients", 1).unwrap_err().contains(">= 1"));
        assert!(a.count_or("steps", 1).is_err());
    }
}
