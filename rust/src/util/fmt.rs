//! Human-readable byte / count / duration formatting for reports.

/// Format a byte count the way the paper's tables do (MiB / GiB).
pub fn bytes(b: u64) -> String {
    const KIB: f64 = 1024.0;
    let b = b as f64;
    if b >= KIB * KIB * KIB {
        format!("{:.2} GiB", b / (KIB * KIB * KIB))
    } else if b >= KIB * KIB {
        format!("{:.1} MiB", b / (KIB * KIB))
    } else if b >= KIB {
        format!("{:.1} KiB", b / KIB)
    } else {
        format!("{b:.0} B")
    }
}

/// Bytes as MiB with one decimal (paper table convention).
pub fn mib(b: u64) -> f64 {
    b as f64 / (1024.0 * 1024.0)
}

/// Bytes as GiB.
pub fn gib(b: u64) -> f64 {
    b as f64 / (1024.0 * 1024.0 * 1024.0)
}

/// Parameter counts: 25.6M, 6.7B, ...
pub fn count(n: u64) -> String {
    let n = n as f64;
    if n >= 1e9 {
        format!("{:.2}B", n / 1e9)
    } else if n >= 1e6 {
        format!("{:.1}M", n / 1e6)
    } else if n >= 1e3 {
        format!("{:.1}K", n / 1e3)
    } else {
        format!("{n:.0}")
    }
}

/// Duration in adaptive units.
pub fn duration(d: std::time::Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 60.0 {
        format!("{:.1} min", s / 60.0)
    } else if s >= 1.0 {
        format!("{s:.2} s")
    } else if s >= 1e-3 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.1} µs", s * 1e6)
    }
}

/// Render an aligned text table (used by every `repro tableN` report).
pub fn render_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:<w$}", c, w = widths.get(i).copied().unwrap_or(c.len())))
            .collect::<Vec<_>>()
            .join("  ")
    };
    let head: Vec<String> = headers.iter().map(|s| s.to_string()).collect();
    out.push_str(&fmt_row(&head, &widths));
    out.push('\n');
    out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_units() {
        assert_eq!(bytes(512), "512 B");
        assert_eq!(bytes(2048), "2.0 KiB");
        assert_eq!(bytes(3 * 1024 * 1024), "3.0 MiB");
        assert_eq!(bytes(5 * 1024 * 1024 * 1024), "5.00 GiB");
    }

    #[test]
    fn counts() {
        assert_eq!(count(999), "999");
        assert_eq!(count(25_600_000), "25.6M");
        assert_eq!(count(6_700_000_000), "6.70B");
    }

    #[test]
    fn table_alignment() {
        let t = render_table(
            &["model", "mem"],
            &[
                vec!["resnet50".into(), "3.5 MiB".into()],
                vec!["x".into(), "y".into()],
            ],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert!(lines[0].starts_with("model"));
        assert!(lines[2].starts_with("resnet50"));
        assert_eq!(lines.len(), 4);
    }
}
