//! Deterministic wire-corruption driver shared by every length-prefixed
//! codec in the tree (`SMMFWIRE` frames, `SMMFCELL` frames, `SMMFCKPT`
//! checkpoint images).
//!
//! The decoders all promise the same discipline: a hostile or damaged
//! byte stream is *rejected with an error* — never a panic, never an
//! allocation sized by an unvalidated count. This module turns that
//! promise into a reusable harness: given a corpus of valid encodings
//! and a decode closure, it replays four corruption families against
//! every item —
//!
//! 1. **Truncation** at every strict prefix length (a length-prefixed
//!    encoding can never have a valid strict prefix, so each one MUST
//!    be rejected);
//! 2. **Bit flips** at PRNG-chosen positions (may still decode — a flip
//!    inside an f32 payload is legal data — but must never panic);
//! 3. **Length-prefix inflation**: a deterministic sweep writing huge
//!    little-endian values over every 4/8-byte window in the leading
//!    bytes, where magic/version/length fields and the first payload
//!    count fields live;
//! 4. **Fabricated counts**: the same huge-value overwrites at
//!    PRNG-chosen aligned offsets anywhere in the item, modelling a
//!    peer that lies about an interior vector length.
//!
//! Panics propagate — a panicking decoder fails the calling test, which
//! is exactly the contract under test. The PRNG is seeded per call
//! (layered under `SMMF_PROP_SEED` conventions by the callers), so a
//! failure reproduces bit-exactly.

use crate::util::rng::Pcg32;

/// Leading-byte window that gets the exhaustive overwrite sweep: wide
/// enough to cover every codec's fixed header (29 bytes for the frame
/// protocols) plus the first few payload count fields.
const SWEEP_BYTES: usize = 96;

/// Huge values written over suspected length/count fields. `!0` probes
/// absolute-cap checks; the mid-range value probes arithmetic-overflow
/// paths that a saturating check might miss.
const INFLATE_VALUES: [u64; 3] = [!0u64, 0x7fff_ffff_ffff_ffff, 1 << 33];

/// Outcome counts for one corpus run (diagnostics — the hard assertions
/// fire inside [`fuzz_codec`]).
#[derive(Debug, Default)]
pub struct FuzzReport {
    /// Corrupted inputs fed to the decoder.
    pub cases: u64,
    /// Inputs the decoder rejected with an error.
    pub rejected: u64,
    /// Inputs the decoder still accepted (possible for payload-interior
    /// bit flips and overwrites that land on plain data bytes).
    pub accepted: u64,
}

/// Run the full corruption battery for one codec.
///
/// `decode` must attempt a full decode of the buffer and report
/// success/failure; `flips` and `overwrites` set the PRNG-driven case
/// counts per corpus item (the truncation and leading-sweep families
/// are exhaustive and not tunable).
///
/// Asserts (test-failing, with the codec `name` and a reproduction
/// description in the message):
/// * every corpus item decodes cleanly before corruption;
/// * every strict-prefix truncation is rejected;
/// * every corruption case returns (panics propagate to the caller).
pub fn fuzz_codec(
    name: &str,
    corpus: &[Vec<u8>],
    seed: u64,
    flips: usize,
    overwrites: usize,
    decode: &mut dyn FnMut(&[u8]) -> Result<(), String>,
) -> FuzzReport {
    let mut rng = Pcg32::new(seed ^ 0xf022_0000);
    let mut rep = FuzzReport::default();
    for (i, item) in corpus.iter().enumerate() {
        assert!(
            decode(item).is_ok(),
            "{name}: corpus item {i} ({} bytes) does not decode clean",
            item.len()
        );

        // 1. Every strict prefix must be rejected.
        for cut in 0..item.len() {
            rep.cases += 1;
            match decode(&item[..cut]) {
                Err(_) => rep.rejected += 1,
                Ok(()) => panic!(
                    "{name}: item {i} truncated to {cut}/{} bytes decoded successfully",
                    item.len()
                ),
            }
        }

        // 2. PRNG bit flips — must return, may legitimately accept.
        let mut buf = item.clone();
        for _ in 0..flips {
            let pos = rng.below(buf.len());
            let bit = 1u8 << (rng.below(8) as u8);
            buf[pos] ^= bit;
            rep.count(decode(&buf));
            buf[pos] ^= bit;
        }

        // 3. Exhaustive huge-value sweep over the leading bytes.
        for start in 0..SWEEP_BYTES.min(item.len()) {
            for width in [4usize, 8] {
                if start + width > buf.len() {
                    continue;
                }
                for v in INFLATE_VALUES {
                    let saved: Vec<u8> = buf[start..start + width].to_vec();
                    buf[start..start + width].copy_from_slice(&v.to_le_bytes()[..width]);
                    rep.count(decode(&buf));
                    buf[start..start + width].copy_from_slice(&saved);
                }
            }
        }

        // 4. PRNG-positioned fabricated counts anywhere in the item.
        for _ in 0..overwrites {
            let width = if rng.below(2) == 0 { 4usize } else { 8 };
            if buf.len() < width {
                break;
            }
            let start = rng.below(buf.len() - width + 1);
            let v = INFLATE_VALUES[rng.below(INFLATE_VALUES.len())];
            let saved: Vec<u8> = buf[start..start + width].to_vec();
            buf[start..start + width].copy_from_slice(&v.to_le_bytes()[..width]);
            rep.count(decode(&buf));
            buf[start..start + width].copy_from_slice(&saved);
        }
    }
    rep
}

impl FuzzReport {
    fn count(&mut self, r: Result<(), String>) {
        self.cases += 1;
        match r {
            Ok(()) => self.accepted += 1,
            Err(_) => self.rejected += 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A toy length-prefixed codec: `u32 len` + payload, strict.
    fn toy_decode(buf: &[u8]) -> Result<(), String> {
        if buf.len() < 4 {
            return Err("short header".into());
        }
        let len = u32::from_le_bytes(buf[..4].try_into().unwrap()) as usize;
        if len > 1 << 16 {
            return Err("cap".into());
        }
        if buf.len() != 4 + len {
            return Err("length mismatch".into());
        }
        Ok(())
    }

    #[test]
    fn driver_exercises_all_families_deterministically() {
        let corpus = vec![{
            let mut v = 40u32.to_le_bytes().to_vec();
            v.extend(std::iter::repeat(0xABu8).take(40));
            v
        }];
        let a = fuzz_codec("toy", &corpus, 7, 32, 32, &mut toy_decode);
        let b = fuzz_codec("toy", &corpus, 7, 32, 32, &mut toy_decode);
        assert_eq!((a.cases, a.rejected, a.accepted), (b.cases, b.rejected, b.accepted));
        // 44 truncations + 32 flips + sweep + 32 overwrites all ran.
        assert!(a.cases > 44 + 32 + 32, "{a:?}");
        assert!(a.rejected >= 44, "{a:?}");
    }

    #[test]
    #[should_panic(expected = "truncated")]
    fn prefix_tolerant_codec_is_caught() {
        // Accepts any prefix — the driver must flag it.
        let corpus = vec![vec![1, 2, 3, 4, 5, 6, 7, 8]];
        fuzz_codec("lax", &corpus, 1, 0, 0, &mut |_| Ok(()));
    }
}
