//! # smmf-repro — SMMF: Square-Matricized Momentum Factorization
//!
//! Full-system reproduction of *SMMF: Square-Matricized Momentum
//! Factorization for Memory-Efficient Optimization* (Park & Lee, AAAI 2025).
//!
//! The system is a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 1 (Pallas, build time)** — the SMMF fused
//!   decompress→update→compress optimizer kernel, written with
//!   `jax.experimental.pallas` and validated against a pure-`jnp` oracle.
//! * **Layer 2 (JAX, build time)** — model forward/backward graphs (MLP,
//!   char-level transformer LM, CNN) and SMMF-fused train steps, lowered
//!   once by `python/compile/aot.py` to HLO text under `artifacts/`.
//! * **Layer 3 (Rust, runtime)** — this crate: the training coordinator.
//!   It loads the AOT artifacts through the PJRT CPU client (`xla` crate),
//!   owns the training loop, the optimizer library (SMMF plus the Adam /
//!   Adafactor / SM3 / CAME baselines), data pipelines, metrics, and the
//!   experiment harness that regenerates every table and figure of the
//!   paper. Python never runs on the training path.
//!
//! Entry points:
//! * [`optim`] — the optimizer library (the paper's contribution).
//! * [`train`] — the trainer that composes runtime + optim + data.
//! * [`coordinator`] — experiment registry and launcher.
//! * [`runtime`] — PJRT artifact loading/execution.
//! * [`server`] — the optimizer-state server: sharded, batched gradient
//!   ingestion over the `SMMFWIRE` binary protocol (`repro serve` /
//!   `repro loadgen`).
//! * [`obs`] — observability: the flight-recorder tracer, the metrics
//!   registry, and the Chrome-trace / Prometheus / bench-JSON
//!   exporters (`repro trace`, `--trace` / `--metrics`).

pub mod coordinator;
pub mod data;
pub mod models;
pub mod obs;
pub mod optim;
pub mod runtime;
pub mod server;
pub mod tensor;
pub mod train;
pub mod util;
