//! PJRT runtime: load and execute the AOT artifacts from `artifacts/`.
//!
//! Build-time Python lowers every graph to HLO *text* (xla_extension 0.5.1
//! rejects jax≥0.5 serialized protos — 64-bit instruction ids); this module
//! parses the manifest, compiles each artifact once on the PJRT CPU client
//! and exposes a typed [`Graph::run`]. Python never runs here.

pub mod artifact;

pub use artifact::{ArtifactSpec, IoSpec, Manifest, ParamInit};

use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

use crate::tensor::Tensor;
use crate::util::rng::Pcg32;

/// Dtypes crossing the Rust <-> XLA boundary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
    Pred,
}

impl Dtype {
    pub fn parse(s: &str) -> Result<Dtype> {
        Ok(match s {
            "f32" => Dtype::F32,
            "i32" => Dtype::I32,
            "pred" => Dtype::Pred,
            other => bail!("unsupported dtype {other}"),
        })
    }

    pub fn bytes(&self) -> usize {
        match self {
            Dtype::F32 | Dtype::I32 => 4,
            Dtype::Pred => 1,
        }
    }
}

/// The PJRT CPU client + artifact directory.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
}

impl Runtime {
    /// Open the artifact directory (reads `manifest.json`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {dir:?} (run `make artifacts`)"))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime { client, dir, manifest })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile one artifact (HLO text -> executable).
    pub fn load(&self, name: &str) -> Result<Graph> {
        let spec = self
            .manifest
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name} not in manifest"))?
            .clone();
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        Ok(Graph { exe, spec })
    }
}

/// A compiled computation plus its manifest I/O spec.
pub struct Graph {
    exe: xla::PjRtLoadedExecutable,
    pub spec: ArtifactSpec,
}

impl Graph {
    /// Execute with host literals; returns output literals in manifest
    /// order (the AOT side lowers with `return_tuple=True`).
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.spec.file,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        let result = self.exe.execute::<xla::Literal>(inputs).map_err(|e| anyhow!("{e:?}"))?;
        let tuple = result[0][0].to_literal_sync().map_err(|e| anyhow!("{e:?}"))?;
        let outs = tuple.to_tuple().map_err(|e| anyhow!("{e:?}"))?;
        if outs.len() != self.spec.outputs.len() {
            bail!(
                "{}: expected {} outputs, got {}",
                self.spec.file,
                self.spec.outputs.len(),
                outs.len()
            );
        }
        Ok(outs)
    }
}

// ---------------------------------------------------------------------------
// Literal construction / extraction helpers
// ---------------------------------------------------------------------------

pub fn lit_f32(shape: &[usize], data: &[f32]) -> Result<xla::Literal> {
    debug_assert_eq!(shape.iter().product::<usize>(), data.len());
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    let lit = xla::Literal::vec1(data);
    lit.reshape(&dims).map_err(|e| anyhow!("{e:?}"))
}

pub fn lit_i32(shape: &[usize], data: &[i32]) -> Result<xla::Literal> {
    debug_assert_eq!(shape.iter().product::<usize>(), data.len());
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    let lit = xla::Literal::vec1(data);
    lit.reshape(&dims).map_err(|e| anyhow!("{e:?}"))
}

pub fn lit_scalar_f32(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// PRED tensor from bools (XLA stores PRED as one byte per element).
pub fn lit_pred(shape: &[usize], data: &[bool]) -> Result<xla::Literal> {
    let bytes: Vec<u8> = data.iter().map(|&b| b as u8).collect();
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::Pred, shape, &bytes)
        .map_err(|e| anyhow!("{e:?}"))
}

/// All-zero literal of a manifest dtype/shape.
pub fn lit_zeros(dtype: Dtype, shape: &[usize]) -> Result<xla::Literal> {
    let numel: usize = shape.iter().product();
    match dtype {
        Dtype::F32 => lit_f32(shape, &vec![0.0; numel]),
        Dtype::I32 => lit_i32(shape, &vec![0; numel]),
        Dtype::Pred => lit_pred(shape, &vec![false; numel]),
    }
}

pub fn lit_to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))
}

pub fn lit_to_scalar_f32(lit: &xla::Literal) -> Result<f32> {
    lit.get_first_element::<f32>().map_err(|e| anyhow!("{e:?}"))
}

/// Initialize parameter tensors from the manifest init specs.
pub fn init_params(inits: &[ParamInit], seed: u64) -> Vec<Tensor> {
    let mut rng = Pcg32::new(seed);
    inits
        .iter()
        .map(|p| {
            let mut t = Tensor::zeros(&p.shape);
            match p.init.as_str() {
                "zeros" => {}
                "ones" => t.fill(1.0),
                _ => rng.fill_normal(t.data_mut(), p.scale),
            }
            t
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_parse() {
        assert_eq!(Dtype::parse("f32").unwrap(), Dtype::F32);
        assert_eq!(Dtype::parse("pred").unwrap(), Dtype::Pred);
        assert!(Dtype::parse("f64").is_err());
    }

    #[test]
    fn literals_roundtrip() {
        let l = lit_f32(&[2, 3], &[1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(lit_to_vec_f32(&l).unwrap(), vec![1., 2., 3., 4., 5., 6.]);
        let s = lit_scalar_f32(7.5);
        assert_eq!(lit_to_scalar_f32(&s).unwrap(), 7.5);
        let i = lit_i32(&[4], &[1, -2, 3, -4]).unwrap();
        assert_eq!(i.to_vec::<i32>().unwrap(), vec![1, -2, 3, -4]);
    }

    #[test]
    fn pred_literal_size() {
        let p = lit_pred(&[2, 2], &[true, false, true, true]).unwrap();
        assert_eq!(p.size_bytes(), 4); // 1 byte per PRED element
    }

    #[test]
    fn init_params_respects_spec() {
        let inits = vec![
            ParamInit { name: "w".into(), shape: vec![4, 4], init: "normal".into(), scale: 0.1 },
            ParamInit { name: "b".into(), shape: vec![4], init: "zeros".into(), scale: 0.0 },
            ParamInit { name: "g".into(), shape: vec![4], init: "ones".into(), scale: 0.0 },
        ];
        let ps = init_params(&inits, 0);
        assert!(ps[0].data().iter().any(|&x| x != 0.0));
        assert!(ps[0].max_abs() < 1.0);
        assert!(ps[1].data().iter().all(|&x| x == 0.0));
        assert!(ps[2].data().iter().all(|&x| x == 1.0));
    }
}
