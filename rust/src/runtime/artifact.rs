//! `artifacts/manifest.json` parsing (written by `python/compile/aot.py`).

use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

use crate::util::json::Json;

/// One input/output tensor of an artifact.
#[derive(Clone, Debug)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// Parameter initialization spec (so Rust can create initial weights
/// without Python at runtime).
#[derive(Clone, Debug)]
pub struct ParamInit {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: String,
    pub scale: f32,
}

/// One lowered artifact.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub file: String,
    pub kind: String, // grads | smmf_step | smmf_tensor
    /// Model family ("mlp" | "lm" | "cnn" | "lora_lm" | "").
    pub model: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
    pub params: Vec<ParamInit>,
    /// smmf_step only: the factorized-state tensors (5 per param).
    pub state: Vec<IoSpec>,
    pub meta: BTreeMap<String, f64>,
}

#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

fn parse_io(v: &Json) -> Result<IoSpec> {
    Ok(IoSpec {
        name: v.get("name").and_then(Json::as_str).ok_or_else(|| anyhow!("io.name"))?.into(),
        shape: v
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("io.shape"))?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| anyhow!("io.shape elem")))
            .collect::<Result<_>>()?,
        dtype: v.get("dtype").and_then(Json::as_str).unwrap_or("f32").into(),
    })
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path).with_context(|| format!("{path:?}"))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let root = Json::parse(text).map_err(|e| anyhow!("manifest JSON: {e}"))?;
        let arts = root
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?;
        let mut out = Manifest::default();
        for (name, art) in arts {
            let io = |key: &str| -> Result<Vec<IoSpec>> {
                art.get(key)
                    .and_then(Json::as_arr)
                    .map(|a| a.iter().map(parse_io).collect())
                    .unwrap_or_else(|| Ok(Vec::new()))
            };
            let params = art
                .get("params")
                .and_then(Json::as_arr)
                .map(|a| {
                    a.iter()
                        .map(|p| {
                            Ok(ParamInit {
                                name: p
                                    .get("name")
                                    .and_then(Json::as_str)
                                    .ok_or_else(|| anyhow!("param.name"))?
                                    .into(),
                                shape: p
                                    .get("shape")
                                    .and_then(Json::as_arr)
                                    .ok_or_else(|| anyhow!("param.shape"))?
                                    .iter()
                                    .map(|d| d.as_usize().ok_or_else(|| anyhow!("shape elem")))
                                    .collect::<Result<_>>()?,
                                init: p.get("init").and_then(Json::as_str).unwrap_or("normal").into(),
                                scale: p.get("scale").and_then(Json::as_f64).unwrap_or(0.02) as f32,
                            })
                        })
                        .collect::<Result<Vec<_>>>()
                })
                .unwrap_or_else(|| Ok(Vec::new()))?;
            let meta = art
                .get("meta")
                .and_then(Json::as_obj)
                .map(|m| {
                    m.iter()
                        .filter_map(|(k, v)| v.as_f64().map(|f| (k.clone(), f)))
                        .collect()
                })
                .unwrap_or_default();
            out.artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    file: art
                        .get("file")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("{name}: file"))?
                        .into(),
                    kind: art.get("kind").and_then(Json::as_str).unwrap_or("grads").into(),
                    model: art.get("model").and_then(Json::as_str).unwrap_or("").into(),
                    inputs: io("inputs")?,
                    outputs: io("outputs")?,
                    state: io("state")?,
                    params,
                    meta,
                },
            );
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "artifacts": {
        "mlp_grads": {
          "file": "mlp_grads.hlo.txt",
          "kind": "grads",
          "inputs": [
            {"name": "w1", "shape": [4, 8], "dtype": "f32"},
            {"name": "y", "shape": [16], "dtype": "i32"}
          ],
          "outputs": [{"name": "loss", "shape": [], "dtype": "f32"}],
          "params": [{"name": "w1", "shape": [4, 8], "init": "normal", "scale": 0.05}],
          "meta": {"batch": 16, "classes": 10}
        }
      }
    }"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let a = &m.artifacts["mlp_grads"];
        assert_eq!(a.file, "mlp_grads.hlo.txt");
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[0].shape, vec![4, 8]);
        assert_eq!(a.inputs[1].dtype, "i32");
        assert_eq!(a.outputs[0].shape, Vec::<usize>::new());
        assert_eq!(a.params[0].scale, 0.05);
        assert_eq!(a.meta["batch"], 16.0);
    }

    #[test]
    fn parse_real_manifest_if_built() {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json");
        if !path.exists() {
            return; // artifacts not built in this checkout
        }
        let m = Manifest::load(&path).unwrap();
        assert!(m.artifacts.contains_key("mlp_grads"));
        let step = &m.artifacts["mlp_smmf_step"];
        assert_eq!(step.kind, "smmf_step");
        assert_eq!(step.state.len(), 5 * step.params.len());
        // inputs = step + params + state + batch
        assert!(step.inputs.len() > step.params.len() + step.state.len());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse("{\"artifacts\": {\"x\": {}}}").is_err());
    }
}
