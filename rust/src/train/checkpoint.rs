//! Checkpointing: parameters (and trainer step) in a simple binary format.
//!
//! Layout (little-endian):
//! `b"SMMFCKPT" | u32 version | u64 step | u32 n_tensors |`
//! per tensor: `u32 name_len | name | u32 rank | u64 dims[rank] | f32 data[]`.

use anyhow::{bail, Context, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::tensor::Tensor;

const MAGIC: &[u8; 8] = b"SMMFCKPT";
const VERSION: u32 = 1;

pub fn save(path: &Path, step: u64, names: &[String], tensors: &[Tensor]) -> Result<()> {
    assert_eq!(names.len(), tensors.len());
    let mut w = BufWriter::new(std::fs::File::create(path).with_context(|| format!("{path:?}"))?);
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&step.to_le_bytes())?;
    w.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for (name, t) in names.iter().zip(tensors) {
        w.write_all(&(name.len() as u32).to_le_bytes())?;
        w.write_all(name.as_bytes())?;
        w.write_all(&(t.shape().len() as u32).to_le_bytes())?;
        for &d in t.shape() {
            w.write_all(&(d as u64).to_le_bytes())?;
        }
        for &v in t.data() {
            w.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

pub fn load(path: &Path) -> Result<(u64, Vec<String>, Vec<Tensor>)> {
    let mut r = BufReader::new(std::fs::File::open(path).with_context(|| format!("{path:?}"))?);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not a SMMF checkpoint: {path:?}");
    }
    let version = read_u32(&mut r)?;
    if version != VERSION {
        bail!("unsupported checkpoint version {version}");
    }
    let step = read_u64(&mut r)?;
    let n = read_u32(&mut r)? as usize;
    let mut names = Vec::with_capacity(n);
    let mut tensors = Vec::with_capacity(n);
    for _ in 0..n {
        let name_len = read_u32(&mut r)? as usize;
        if name_len > 4096 {
            bail!("corrupt checkpoint: name_len {name_len}");
        }
        let mut name = vec![0u8; name_len];
        r.read_exact(&mut name)?;
        let rank = read_u32(&mut r)? as usize;
        if rank > 16 {
            bail!("corrupt checkpoint: rank {rank}");
        }
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(read_u64(&mut r)? as usize);
        }
        let numel: usize = shape.iter().product();
        let mut data = vec![0f32; numel];
        let mut buf = [0u8; 4];
        for v in data.iter_mut() {
            r.read_exact(&mut buf)?;
            *v = f32::from_le_bytes(buf);
        }
        names.push(String::from_utf8(name)?);
        tensors.push(Tensor::from_vec(&shape, data));
    }
    Ok((step, names, tensors))
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let tmp = std::env::temp_dir().join(format!("smmf_ckpt_{}.bin", std::process::id()));
        let names = vec!["w1".to_string(), "b1".to_string()];
        let tensors = vec![
            Tensor::from_vec(&[2, 3], vec![1., -2., 3., 4., 5.5, -6.]),
            Tensor::from_vec(&[3], vec![0.1, 0.2, 0.3]),
        ];
        save(&tmp, 42, &names, &tensors).unwrap();
        let (step, n2, t2) = load(&tmp).unwrap();
        assert_eq!(step, 42);
        assert_eq!(n2, names);
        assert_eq!(t2, tensors);
        std::fs::remove_file(&tmp).unwrap();
    }

    #[test]
    fn rejects_garbage() {
        let tmp = std::env::temp_dir().join(format!("smmf_bad_{}.bin", std::process::id()));
        std::fs::write(&tmp, b"not a checkpoint").unwrap();
        assert!(load(&tmp).is_err());
        std::fs::remove_file(&tmp).unwrap();
    }
}
