//! Checkpointing: the versioned `SMMFCKPT` binary container.
//!
//! Two on-disk versions (full byte-level spec in
//! `docs/CHECKPOINT_FORMAT.md`):
//!
//! * **v1** (legacy, still readable): parameters and the trainer step
//!   only — `b"SMMFCKPT" | u32 version=1 | u64 step | tensor table`.
//!   Resuming from a v1 file restarts all optimizer state cold.
//! * **v2** (written by [`save_v2`]): `b"SMMFCKPT" | u32 version=2 |
//!   u32 n_sections`, then tagged length-prefixed sections — parameters,
//!   trainer step + data-RNG snapshot, LR-schedule position, and one
//!   native [`crate::optim::StateSerde`] blob per tensor tagged by
//!   [`OptKind`]. Unknown section tags are skipped, so older readers of
//!   future versions degrade gracefully.
//!
//! All multi-byte values are little-endian. Loading is strictly
//! validated: magic/version/section bounds, name UTF-8 and length caps,
//! rank caps, and per-tensor element counts checked against the actual
//! remaining bytes *before* any allocation — a truncated or corrupt file
//! produces a context-rich error, never a panic or a blind multi-GiB
//! allocation.

use anyhow::{bail, Context, Result};
use std::io::{BufWriter, Write};
use std::path::Path;

use crate::optim::blob::BlobWriter;
use crate::optim::group::Resolution;
use crate::optim::schedule::LrSchedule;
use crate::optim::{
    MatricizeMode, OptKind, OptimConfig, SignMode, SmmfScheme, WeightDecayMode,
};
use crate::tensor::Tensor;

const MAGIC: &[u8; 8] = b"SMMFCKPT";
pub const VERSION_V1: u32 = 1;
pub const VERSION_V2: u32 = 2;

/// v2 section tags (never renumber).
const SEC_PARAMS: u32 = 1;
const SEC_TRAINER: u32 = 2;
const SEC_SCHEDULE: u32 = 3;
const SEC_OPT: u32 = 4;
const SEC_CONFIG: u32 = 5;

/// Sanity caps for untrusted header fields.
const MAX_NAME_LEN: usize = 4096;
const MAX_RANK: usize = 16;
const MAX_TENSORS: usize = 1 << 20;
const MAX_DIM: u64 = 1 << 40;
const MAX_GROUPS: usize = 4096;

/// Native optimizer state: the `OptKind`, its internal step counter, and
/// one [`crate::optim::StateSerde`] blob per parameter tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct OptSection {
    pub kind: OptKind,
    pub opt_step: u64,
    pub blobs: Vec<Vec<u8>>,
}

/// LR-schedule position: the base LR and the schedule shape. Combined
/// with the trainer step this pins the resumed LR exactly.
#[derive(Clone, Debug, PartialEq)]
pub struct ScheduleSection {
    pub base_lr: f32,
    pub schedule: LrSchedule,
}

/// One resolved param group as recorded in the CONFIG section.
#[derive(Clone, Debug, PartialEq)]
pub struct GroupRecord {
    pub name: String,
    pub lr_scale: f32,
    pub weight_decay: f32,
    pub frozen: bool,
    /// `StatePolicy` tag (see `optim::group::StatePolicy::tag`).
    pub state: u8,
}

/// Resolved hyperparameter + group-layout fingerprint (CONFIG, tag 5).
///
/// Closes the PR 2 limitation that scalar hyperparameters were not
/// cross-checkable on resume: every knob that shapes the trajectory but
/// not the state layout is recorded (the LR itself lives in SCHEDULE),
/// plus the resolved group table and the per-tensor group assignment
/// (the group layout of every OPT blob). `Trainer::resume_from`
/// compares this section field-by-field against the running
/// configuration and errors on any drift; files without it (pre-group
/// v2, or v1) are accepted with a warning.
#[derive(Clone, Debug, PartialEq)]
pub struct ConfigSection {
    pub beta1: f32,
    pub beta2: f32,
    pub beta3: f32,
    pub eps1: f32,
    pub eps2: f32,
    pub weight_decay: f32,
    /// 0 = Adam-coupled, 1 = AdamW-decoupled.
    pub weight_decay_mode: u8,
    pub decay_rate: f32,
    pub growth_rate: f32,
    pub clip_threshold: f32,
    pub momentum: f32,
    pub bias_correction: bool,
    pub relative_step: bool,
    pub vector_reshape: bool,
    /// 0 = DecompressFirst, 1 = CompressFirst.
    pub smmf_scheme: u8,
    /// 0 = Bit1, 1 = Byte8.
    pub smmf_sign_mode: u8,
    /// 0 = Square, 1 = FoldLast.
    pub smmf_matricize: u8,
    /// Resolved group table (index 0 = default group).
    pub groups: Vec<GroupRecord>,
    /// Per-tensor group index, in PARAMS tensor order.
    pub assign: Vec<u32>,
}

impl ConfigSection {
    /// Fingerprint a flat config + resolved group table.
    pub fn from_config(cfg: &OptimConfig, res: &Resolution) -> ConfigSection {
        ConfigSection {
            beta1: cfg.beta1,
            beta2: cfg.beta2,
            beta3: cfg.beta3,
            eps1: cfg.eps1,
            eps2: cfg.eps2,
            weight_decay: cfg.weight_decay,
            weight_decay_mode: match cfg.weight_decay_mode {
                WeightDecayMode::Adam => 0,
                WeightDecayMode::AdamW => 1,
            },
            decay_rate: cfg.decay_rate,
            growth_rate: cfg.growth_rate,
            clip_threshold: cfg.clip_threshold,
            momentum: cfg.momentum,
            bias_correction: cfg.bias_correction,
            relative_step: cfg.relative_step,
            vector_reshape: cfg.vector_reshape,
            smmf_scheme: match cfg.smmf_scheme {
                SmmfScheme::DecompressFirst => 0,
                SmmfScheme::CompressFirst => 1,
            },
            smmf_sign_mode: match cfg.smmf_sign_mode {
                SignMode::Bit1 => 0,
                SignMode::Byte8 => 1,
            },
            smmf_matricize: match cfg.smmf_matricize {
                MatricizeMode::Square => 0,
                MatricizeMode::FoldLast => 1,
            },
            groups: res
                .groups
                .iter()
                .map(|g| GroupRecord {
                    name: g.name.clone(),
                    lr_scale: g.lr_scale,
                    weight_decay: g.weight_decay,
                    frozen: g.frozen,
                    state: g.state.tag(),
                })
                .collect(),
            assign: res.tensor.iter().map(|t| t.group as u32).collect(),
        }
    }

    /// Human-readable field-level differences (empty = identical).
    /// `self` is the checkpoint side, `other` the running config.
    pub fn mismatches(&self, other: &ConfigSection) -> Vec<String> {
        let mut out = Vec::new();
        let mut f32_field = |name: &str, a: f32, b: f32| {
            if a.to_bits() != b.to_bits() {
                out.push(format!("{name}: checkpoint {a} vs run {b}"));
            }
        };
        f32_field("beta1", self.beta1, other.beta1);
        f32_field("beta2", self.beta2, other.beta2);
        f32_field("beta3", self.beta3, other.beta3);
        f32_field("eps1", self.eps1, other.eps1);
        f32_field("eps2", self.eps2, other.eps2);
        f32_field("weight_decay", self.weight_decay, other.weight_decay);
        f32_field("decay_rate", self.decay_rate, other.decay_rate);
        f32_field("growth_rate", self.growth_rate, other.growth_rate);
        f32_field("clip_threshold", self.clip_threshold, other.clip_threshold);
        f32_field("momentum", self.momentum, other.momentum);
        let mut tag_field = |name: &str, a: u8, b: u8| {
            if a != b {
                out.push(format!("{name}: checkpoint {a} vs run {b}"));
            }
        };
        tag_field("weight_decay_mode", self.weight_decay_mode, other.weight_decay_mode);
        tag_field("bias_correction", self.bias_correction as u8, other.bias_correction as u8);
        tag_field("relative_step", self.relative_step as u8, other.relative_step as u8);
        tag_field("vector_reshape", self.vector_reshape as u8, other.vector_reshape as u8);
        tag_field("smmf_scheme", self.smmf_scheme, other.smmf_scheme);
        tag_field("smmf_sign_mode", self.smmf_sign_mode, other.smmf_sign_mode);
        tag_field("smmf_matricize", self.smmf_matricize, other.smmf_matricize);
        if self.groups.len() != other.groups.len() {
            out.push(format!(
                "group count: checkpoint {} vs run {}",
                self.groups.len(),
                other.groups.len()
            ));
        } else {
            for (i, (a, b)) in self.groups.iter().zip(&other.groups).enumerate() {
                if a != b {
                    out.push(format!("group {i}: checkpoint {a:?} vs run {b:?}"));
                }
            }
        }
        if self.assign != other.assign {
            let where_ = self
                .assign
                .iter()
                .zip(&other.assign)
                .position(|(a, b)| a != b)
                .map(|i| format!("first differs at tensor {i}"))
                .unwrap_or_else(|| {
                    format!("lengths {} vs {}", self.assign.len(), other.assign.len())
                });
            out.push(format!("per-tensor group assignment: {where_}"));
        }
        out
    }

    fn payload(&self) -> Vec<u8> {
        let mut w = BlobWriter::new();
        for v in [
            self.beta1,
            self.beta2,
            self.beta3,
            self.eps1,
            self.eps2,
            self.weight_decay,
            self.decay_rate,
            self.growth_rate,
            self.clip_threshold,
            self.momentum,
        ] {
            w.f32(v);
        }
        for v in [
            self.weight_decay_mode,
            self.bias_correction as u8,
            self.relative_step as u8,
            self.vector_reshape as u8,
            self.smmf_scheme,
            self.smmf_sign_mode,
            self.smmf_matricize,
        ] {
            w.u8(v);
        }
        w.u32(self.groups.len() as u32);
        for g in &self.groups {
            w.u32(g.name.len() as u32);
            w.bytes(g.name.as_bytes());
            w.f32(g.lr_scale);
            w.f32(g.weight_decay);
            w.u8(g.frozen as u8);
            w.u8(g.state);
        }
        w.u32(self.assign.len() as u32);
        for &a in &self.assign {
            w.u32(a);
        }
        w.finish()
    }
}

/// Everything a checkpoint can carry. v1 files populate only
/// `step`/`names`/`params`.
#[derive(Debug)]
pub struct Checkpoint {
    pub version: u32,
    pub step: u64,
    pub names: Vec<String>,
    pub params: Vec<Tensor>,
    /// Data-stream RNG snapshot `(state, inc)` (see `util::rng::Pcg32`).
    pub rng: Option<(u64, u64)>,
    pub schedule: Option<ScheduleSection>,
    pub opt: Option<OptSection>,
    pub config: Option<ConfigSection>,
}

// ---------------------------------------------------------------------------
// Saving
// ---------------------------------------------------------------------------

/// Save a v1 (params-only) checkpoint. Kept for compatibility and for
/// producing fixtures; new code should use [`save_v2`].
pub fn save(path: &Path, step: u64, names: &[String], tensors: &[Tensor]) -> Result<()> {
    assert_eq!(names.len(), tensors.len());
    atomic_write(path, |w| {
        w.write_all(MAGIC)?;
        w_u32(w, VERSION_V1)?;
        w_u64(w, step)?;
        stream_tensor_table(w, names, tensors)
    })
}

/// Save a v2 checkpoint: parameters + trainer step, optional data-RNG
/// snapshot, optional LR-schedule position, optional native optimizer
/// state, optional resolved config fingerprint (CONFIG section).
///
/// The large payloads (tensor data, optimizer blobs) stream straight to
/// the file — section lengths are computed up front, so no whole-section
/// buffer is materialized — and the write is atomic (temp file + rename),
/// so a crash mid-save never destroys the previous checkpoint.
pub fn save_v2(
    path: &Path,
    step: u64,
    names: &[String],
    params: &[Tensor],
    rng: Option<(u64, u64)>,
    schedule: Option<&ScheduleSection>,
    opt: Option<&OptSection>,
    config: Option<&ConfigSection>,
) -> Result<()> {
    atomic_write(path, |w| write_v2(w, step, names, params, rng, schedule, opt, config))
}

/// Stream a v2 checkpoint to any writer — the body of [`save_v2`],
/// shared with the in-memory snapshot path ([`snapshot_to_bytes`]) so a
/// file snapshot and a recovery image are byte-identical by
/// construction.
#[allow(clippy::too_many_arguments)]
pub fn write_v2(
    w: &mut impl Write,
    step: u64,
    names: &[String],
    params: &[Tensor],
    rng: Option<(u64, u64)>,
    schedule: Option<&ScheduleSection>,
    opt: Option<&OptSection>,
    config: Option<&ConfigSection>,
) -> std::io::Result<()> {
    assert_eq!(names.len(), params.len());

    // Small sections are assembled in memory; PARAMS/OPT stream.
    let mut t = BlobWriter::new();
    t.u64(step);
    match rng {
        Some((state, inc)) => {
            t.u8(1);
            t.u64(state);
            t.u64(inc);
        }
        None => t.u8(0),
    }
    let trainer_payload = t.finish();

    let sched_payload = schedule.map(|s| {
        let mut w = BlobWriter::new();
        w.f32(s.base_lr);
        let (tag, a, b, c) = s.schedule.encode();
        w.u8(tag);
        w.u64(a);
        w.u64(b);
        w.f32(c);
        w.finish()
    });

    let config_payload = config.map(|c| c.payload());

    let n_sections = 2
        + sched_payload.is_some() as u32
        + opt.is_some() as u32
        + config_payload.is_some() as u32;
    w.write_all(MAGIC)?;
    w_u32(w, VERSION_V2)?;
    w_u32(w, n_sections)?;

    w_u32(w, SEC_PARAMS)?;
    w_u64(w, tensor_table_len(names, params))?;
    stream_tensor_table(w, names, params)?;

    w_u32(w, SEC_TRAINER)?;
    w_u64(w, trainer_payload.len() as u64)?;
    w.write_all(&trainer_payload)?;

    if let Some(p) = &sched_payload {
        w_u32(w, SEC_SCHEDULE)?;
        w_u64(w, p.len() as u64)?;
        w.write_all(p)?;
    }

    if let Some(o) = opt {
        w_u32(w, SEC_OPT)?;
        let len: u64 = 4 + 8 + 4 + o.blobs.iter().map(|b| 8 + b.len() as u64).sum::<u64>();
        w_u64(w, len)?;
        w_u32(w, o.kind.tag())?;
        w_u64(w, o.opt_step)?;
        w_u32(w, o.blobs.len() as u32)?;
        for blob in &o.blobs {
            w_u64(w, blob.len() as u64)?;
            w.write_all(blob)?;
        }
    }

    if let Some(p) = &config_payload {
        w_u32(w, SEC_CONFIG)?;
        w_u64(w, p.len() as u64)?;
        w.write_all(p)?;
    }
    Ok(())
}

/// One-call snapshot writer for the optimizer-state server (and its
/// single-process reference trainer): assembles the standard section set
/// — PARAMS, TRAINER (no data-RNG: the gradient streams live in the
/// clients), SCHEDULE, OPT, CONFIG — and writes it through the same
/// atomic [`save_v2`] path a trainer checkpoint uses, so a server
/// snapshot *is* a regular `SMMFCKPT` v2 file (`repro train --resume`
/// can consume it). Returns the on-disk size in bytes. Both the server
/// and the reference trainer funnel through this one writer, which is
/// what makes their outputs byte-comparable.
#[allow(clippy::too_many_arguments)]
pub fn save_snapshot(
    path: &Path,
    step: u64,
    names: &[String],
    params: &[Tensor],
    base_lr: f32,
    schedule: &LrSchedule,
    kind: OptKind,
    opt_step: u64,
    blobs: Vec<Vec<u8>>,
    config: &ConfigSection,
) -> Result<u64> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("creating snapshot dir {parent:?}"))?;
        }
    }
    let sched = ScheduleSection { base_lr, schedule: schedule.clone() };
    let opt = OptSection { kind, opt_step, blobs };
    save_v2(path, step, names, params, None, Some(&sched), Some(&opt), Some(config))?;
    Ok(std::fs::metadata(path).with_context(|| format!("stat {path:?}"))?.len())
}

/// [`save_snapshot`] without ever materializing the full optimizer
/// state: the caller supplies the per-tensor blob *lengths* up front
/// (so the SEC_OPT section length can be written before any blob
/// exists) and a `feed` callback that produces one tensor's blob at a
/// time, which streams straight into the file writer. Peak memory is
/// one blob, which is what lets a sharded server snapshot an inventory
/// larger than any single buffer it is willing to allocate.
///
/// The section sequence mirrors [`write_v2`] with `rng = None` exactly
/// — a streamed snapshot is byte-identical to the [`save_snapshot`]
/// dense path given the same inputs (pinned by a test below), which is
/// what keeps the server's determinism contract checkable with `cmp`.
/// Each fed blob must match its announced length; a mismatch aborts
/// the write (the previous checkpoint survives, courtesy of
/// [`atomic_write`]).
#[allow(clippy::too_many_arguments)]
pub fn save_snapshot_streamed(
    path: &Path,
    step: u64,
    names: &[String],
    params: &[Tensor],
    base_lr: f32,
    schedule: &LrSchedule,
    kind: OptKind,
    opt_step: u64,
    blob_lens: &[u64],
    config: &ConfigSection,
    feed: &mut dyn FnMut(usize) -> Result<Vec<u8>>,
) -> Result<u64> {
    assert_eq!(names.len(), params.len());
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("creating snapshot dir {parent:?}"))?;
        }
    }

    let mut t = BlobWriter::new();
    t.u64(step);
    t.u8(0); // no data-RNG section content, same as save_snapshot
    let trainer_payload = t.finish();

    let sched_payload = {
        let mut w = BlobWriter::new();
        w.f32(base_lr);
        let (tag, a, b, c) = schedule.encode();
        w.u8(tag);
        w.u64(a);
        w.u64(b);
        w.f32(c);
        w.finish()
    };

    let config_payload = config.payload();

    atomic_write(path, |w| {
        w.write_all(MAGIC)?;
        w_u32(w, VERSION_V2)?;
        w_u32(w, 5)?; // PARAMS, TRAINER, SCHEDULE, OPT, CONFIG

        w_u32(w, SEC_PARAMS)?;
        w_u64(w, tensor_table_len(names, params))?;
        stream_tensor_table(w, names, params)?;

        w_u32(w, SEC_TRAINER)?;
        w_u64(w, trainer_payload.len() as u64)?;
        w.write_all(&trainer_payload)?;

        w_u32(w, SEC_SCHEDULE)?;
        w_u64(w, sched_payload.len() as u64)?;
        w.write_all(&sched_payload)?;

        w_u32(w, SEC_OPT)?;
        let len: u64 = 4 + 8 + 4 + blob_lens.iter().map(|l| 8 + l).sum::<u64>();
        w_u64(w, len)?;
        w_u32(w, kind.tag())?;
        w_u64(w, opt_step)?;
        w_u32(w, blob_lens.len() as u32)?;
        for (i, &announced) in blob_lens.iter().enumerate() {
            let blob = feed(i).map_err(|e| std::io::Error::other(format!("{e:#}")))?;
            if blob.len() as u64 != announced {
                return Err(std::io::Error::other(format!(
                    "streamed snapshot: tensor {i} blob is {} bytes, sizing pass \
                     announced {announced} (state mutated mid-snapshot?)",
                    blob.len()
                )));
            }
            w_u64(w, announced)?;
            w.write_all(&blob)?;
        }

        w_u32(w, SEC_CONFIG)?;
        w_u64(w, config_payload.len() as u64)?;
        w.write_all(&config_payload)
    })?;
    Ok(std::fs::metadata(path).with_context(|| format!("stat {path:?}"))?.len())
}

/// [`save_snapshot`]'s section set serialized to memory instead of
/// disk: the server's crash-recovery image. Byte-identical to what
/// [`save_snapshot`] would write (both funnel through [`write_v2`]), so
/// a recovery image doubles as a snapshot and vice versa.
#[allow(clippy::too_many_arguments)]
pub fn snapshot_to_bytes(
    step: u64,
    names: &[String],
    params: &[Tensor],
    base_lr: f32,
    schedule: &LrSchedule,
    kind: OptKind,
    opt_step: u64,
    blobs: Vec<Vec<u8>>,
    config: &ConfigSection,
) -> Vec<u8> {
    let sched = ScheduleSection { base_lr, schedule: schedule.clone() };
    let opt = OptSection { kind, opt_step, blobs };
    let mut buf = Vec::new();
    write_v2(&mut buf, step, names, params, None, Some(&sched), Some(&opt), Some(config))
        .expect("writing a checkpoint to memory cannot fail");
    buf
}

/// Atomically persist an already-serialized checkpoint image (e.g. a
/// crash-recovery image from [`snapshot_to_bytes`]) to `path`, creating
/// parent directories like [`save_snapshot`]. Returns the byte count.
pub fn write_snapshot_bytes(path: &Path, bytes: &[u8]) -> Result<u64> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("creating snapshot dir {parent:?}"))?;
        }
    }
    atomic_write(path, |w| w.write_all(bytes))?;
    Ok(bytes.len() as u64)
}

/// Stream the writer's output to `<path>.tmp` in the same directory,
/// fsync, then atomically rename over `path` — a crash mid-save can
/// never destroy the previous checkpoint (the whole point of
/// checkpointing).
fn atomic_write(
    path: &Path,
    f: impl FnOnce(&mut BufWriter<std::fs::File>) -> std::io::Result<()>,
) -> Result<()> {
    let mut tmp_name =
        path.file_name().map(|n| n.to_os_string()).unwrap_or_else(|| "checkpoint".into());
    tmp_name.push(".tmp");
    let tmp = path.with_file_name(tmp_name);
    let write_all = || -> std::io::Result<()> {
        let mut w = BufWriter::new(std::fs::File::create(&tmp)?);
        f(&mut w)?;
        w.flush()?;
        w.into_inner().map_err(|e| e.into_error())?.sync_all()
    };
    if let Err(e) = write_all() {
        let _ = std::fs::remove_file(&tmp);
        return Err(e).with_context(|| format!("writing {tmp:?}"));
    }
    if let Err(e) = std::fs::rename(&tmp, path) {
        // A failed rename (target is a directory, cross-device target
        // appeared, permissions flipped) must not strand the temp file
        // next to the checkpoint.
        let _ = std::fs::remove_file(&tmp);
        return Err(e).with_context(|| format!("renaming {tmp:?} over {path:?}"));
    }
    Ok(())
}

/// Byte length of the streamed tensor table (the PARAMS section payload).
fn tensor_table_len(names: &[String], tensors: &[Tensor]) -> u64 {
    4 + names
        .iter()
        .zip(tensors)
        .map(|(n, t)| 4 + n.len() as u64 + 4 + 8 * t.shape().len() as u64 + 4 * t.numel() as u64)
        .sum::<u64>()
}

fn w_u32(w: &mut impl Write, v: u32) -> std::io::Result<()> {
    w.write_all(&v.to_le_bytes())
}

fn w_u64(w: &mut impl Write, v: u64) -> std::io::Result<()> {
    w.write_all(&v.to_le_bytes())
}

fn w_f32s(w: &mut impl Write, vals: &[f32]) -> std::io::Result<()> {
    // Encode in 4 KiB chunks so the hot path is memcpy, not per-element
    // write_all bookkeeping.
    let mut buf = [0u8; 4096];
    for chunk in vals.chunks(1024) {
        let mut n = 0;
        for &v in chunk {
            buf[n..n + 4].copy_from_slice(&v.to_le_bytes());
            n += 4;
        }
        w.write_all(&buf[..n])?;
    }
    Ok(())
}

fn stream_tensor_table(
    w: &mut impl Write,
    names: &[String],
    tensors: &[Tensor],
) -> std::io::Result<()> {
    w_u32(w, tensors.len() as u32)?;
    for (name, t) in names.iter().zip(tensors) {
        w_u32(w, name.len() as u32)?;
        w.write_all(name.as_bytes())?;
        w_u32(w, t.shape().len() as u32)?;
        for &d in t.shape() {
            w_u64(w, d as u64)?;
        }
        w_f32s(w, t.data())?;
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Loading
// ---------------------------------------------------------------------------

/// Load a checkpoint of any supported version. Tensor data and optimizer
/// blobs stream from the file straight into their final buffers — peak
/// transient memory is one 4 KiB chunk, not a second copy of the file.
pub fn load_any(path: &Path) -> Result<Checkpoint> {
    let total = std::fs::metadata(path).with_context(|| format!("reading {path:?}"))?.len();
    let file = std::fs::File::open(path).with_context(|| format!("reading {path:?}"))?;
    parse(std::io::BufReader::new(file), total)
        .with_context(|| format!("corrupt checkpoint {path:?}"))
}

/// Parse an in-memory checkpoint image (a [`snapshot_to_bytes`] recovery
/// image) with the same strict bounds-checked loader as [`load_any`].
pub fn load_bytes(bytes: &[u8]) -> Result<Checkpoint> {
    parse(bytes, bytes.len() as u64).context("corrupt in-memory checkpoint image")
}

/// Legacy v1 loader signature: `(step, names, params)` of any readable
/// checkpoint (v2 files simply drop the extra sections).
pub fn load(path: &Path) -> Result<(u64, Vec<String>, Vec<Tensor>)> {
    let ck = load_any(path)?;
    Ok((ck.step, ck.names, ck.params))
}

/// Bounded streaming reader: every read (and every allocation) is
/// validated against the bytes actually remaining in the file first, so
/// a corrupt length field can produce an error but never an OOM.
struct Src<R> {
    r: R,
    left: u64,
}

impl<R: std::io::Read> Src<R> {
    fn take_into(&mut self, buf: &mut [u8], what: &str) -> Result<()> {
        if (buf.len() as u64) > self.left {
            bail!("truncated: need {} bytes for {what}, only {} remain", buf.len(), self.left);
        }
        self.r.read_exact(buf).with_context(|| format!("reading {what}"))?;
        self.left -= buf.len() as u64;
        Ok(())
    }

    fn u8(&mut self, what: &str) -> Result<u8> {
        let mut b = [0u8; 1];
        self.take_into(&mut b, what)?;
        Ok(b[0])
    }

    fn u32(&mut self, what: &str) -> Result<u32> {
        let mut b = [0u8; 4];
        self.take_into(&mut b, what)?;
        Ok(u32::from_le_bytes(b))
    }

    fn u64(&mut self, what: &str) -> Result<u64> {
        let mut b = [0u8; 8];
        self.take_into(&mut b, what)?;
        Ok(u64::from_le_bytes(b))
    }

    fn f32(&mut self, what: &str) -> Result<f32> {
        let mut b = [0u8; 4];
        self.take_into(&mut b, what)?;
        Ok(f32::from_le_bytes(b))
    }

    fn bytes_vec(&mut self, n: usize, what: &str) -> Result<Vec<u8>> {
        if (n as u64) > self.left {
            bail!("{what}: claims {n} bytes, only {} remain", self.left);
        }
        let mut v = vec![0u8; n];
        self.r.read_exact(&mut v).with_context(|| format!("reading {what}"))?;
        self.left -= n as u64;
        Ok(v)
    }

    /// Read `numel` little-endian f32s in 4 KiB chunks.
    fn f32s_vec(&mut self, numel: usize, what: &str) -> Result<Vec<f32>> {
        if (numel as u64) > self.left / 4 {
            bail!("{what}: claims {numel} f32 elements but only {} bytes remain", self.left);
        }
        let mut out = Vec::with_capacity(numel);
        let mut buf = [0u8; 4096];
        let mut rem = numel;
        while rem > 0 {
            let take = rem.min(1024);
            let bytes = &mut buf[..take * 4];
            self.r.read_exact(bytes).with_context(|| format!("reading {what}"))?;
            self.left -= (take as u64) * 4;
            for c in bytes.chunks_exact(4) {
                out.push(f32::from_le_bytes(c.try_into().unwrap()));
            }
            rem -= take;
        }
        Ok(out)
    }

    fn skip(&mut self, mut n: u64, what: &str) -> Result<()> {
        if n > self.left {
            bail!("{what}: claims {n} bytes, only {} remain", self.left);
        }
        let mut buf = [0u8; 4096];
        while n > 0 {
            let take = n.min(4096) as usize;
            self.r.read_exact(&mut buf[..take]).with_context(|| format!("skipping {what}"))?;
            self.left -= take as u64;
            n -= take as u64;
        }
        Ok(())
    }

    /// Require the source to be fully consumed (no trailing garbage).
    fn finish(self) -> Result<()> {
        if self.left != 0 {
            bail!("checkpoint has {} trailing bytes", self.left);
        }
        Ok(())
    }
}

fn parse<R: std::io::Read>(r: R, total: u64) -> Result<Checkpoint> {
    let mut s = Src { r, left: total };
    let mut magic = [0u8; 8];
    s.take_into(&mut magic, "magic")?;
    if &magic != MAGIC {
        bail!("not a SMMF checkpoint (bad magic)");
    }
    let version = s.u32("version")?;
    match version {
        VERSION_V1 => parse_v1(s),
        VERSION_V2 => parse_v2(s),
        other => bail!("unsupported checkpoint version {other} (supported: 1, 2)"),
    }
}

fn parse_v1<R: std::io::Read>(mut s: Src<R>) -> Result<Checkpoint> {
    let step = s.u64("step")?;
    let (names, params) = read_tensor_table(&mut s)?;
    s.finish()?;
    Ok(Checkpoint {
        version: VERSION_V1,
        step,
        names,
        params,
        rng: None,
        schedule: None,
        opt: None,
        config: None,
    })
}

fn parse_v2<R: std::io::Read>(mut s: Src<R>) -> Result<Checkpoint> {
    let n_sections = s.u32("section count")? as usize;
    if n_sections > 64 {
        bail!("implausible section count {n_sections}");
    }
    let mut ck = Checkpoint {
        version: VERSION_V2,
        step: 0,
        names: Vec::new(),
        params: Vec::new(),
        rng: None,
        schedule: None,
        opt: None,
        config: None,
    };
    // Known tags may appear at most once; TRAINER and PARAMS must both
    // be present (a corrupt tag could otherwise drop the step silently
    // and resume would retrain from step 0 on trained parameters).
    let mut seen = [false; 6];
    for i in 0..n_sections {
        let tag = s.u32(&format!("section {i} tag"))?;
        if let Some(flag) = seen.get_mut(tag as usize) {
            if *flag {
                bail!("duplicate section tag {tag}");
            }
            *flag = true;
        }
        let len = s.u64(&format!("section {i} length"))?;
        if len > s.left {
            bail!("section {i} (tag {tag}) claims {len} bytes, only {} remain", s.left);
        }
        let end = s.left - len;
        match tag {
            SEC_PARAMS => {
                let (names, params) = read_tensor_table(&mut s).context("PARAMS section")?;
                ck.names = names;
                ck.params = params;
            }
            SEC_TRAINER => {
                ck.step = s.u64("TRAINER step")?;
                if s.u8("TRAINER rng flag")? == 1 {
                    ck.rng = Some((s.u64("TRAINER rng state")?, s.u64("TRAINER rng inc")?));
                }
            }
            SEC_SCHEDULE => {
                let base_lr = s.f32("SCHEDULE base_lr")?;
                let stag = s.u8("SCHEDULE kind")?;
                let a = s.u64("SCHEDULE a")?;
                let b = s.u64("SCHEDULE b")?;
                let c = s.f32("SCHEDULE c")?;
                let schedule = LrSchedule::decode(stag, a, b, c)
                    .with_context(|| format!("unknown schedule tag {stag}"))?;
                ck.schedule = Some(ScheduleSection { base_lr, schedule });
            }
            SEC_OPT => {
                let ktag = s.u32("OPT kind tag")?;
                let kind = OptKind::from_tag(ktag)
                    .with_context(|| format!("unknown optimizer tag {ktag}"))?;
                let opt_step = s.u64("OPT step")?;
                let n = s.u32("OPT tensor count")? as usize;
                if n > MAX_TENSORS {
                    bail!("OPT section claims {n} tensors (max {MAX_TENSORS})");
                }
                let mut blobs = Vec::with_capacity(n.min(1024));
                for b in 0..n {
                    let blen = s.u64(&format!("OPT blob {b} length"))? as usize;
                    blobs.push(s.bytes_vec(blen, &format!("OPT blob {b}"))?);
                }
                ck.opt = Some(OptSection { kind, opt_step, blobs });
            }
            SEC_CONFIG => ck.config = Some(read_config_section(&mut s)?),
            // unknown section: forward-compatible skip
            _ => s.skip(len, &format!("section {i} (tag {tag})"))?,
        }
        if s.left != end {
            bail!(
                "section {i} (tag {tag}): declared {len} bytes but {} were consumed",
                (end + len) - s.left
            );
        }
    }
    s.finish()?;
    if !seen[SEC_PARAMS as usize] {
        bail!("checkpoint has no PARAMS section");
    }
    if !seen[SEC_TRAINER as usize] {
        bail!("checkpoint has no TRAINER section");
    }
    // Sections may arrive in any order, so cross-section invariants are
    // checked once everything is read: the CONFIG per-tensor group
    // assignment must cover exactly the PARAMS tensors.
    if let Some(c) = &ck.config {
        if c.assign.len() != ck.params.len() {
            bail!(
                "CONFIG assigns groups to {} tensors but PARAMS holds {}",
                c.assign.len(),
                ck.params.len()
            );
        }
    }
    Ok(ck)
}

fn read_config_section<R: std::io::Read>(s: &mut Src<R>) -> Result<ConfigSection> {
    let mut f = |what: &str| s.f32(&format!("CONFIG {what}"));
    let (beta1, beta2, beta3) = (f("beta1")?, f("beta2")?, f("beta3")?);
    let (eps1, eps2) = (f("eps1")?, f("eps2")?);
    let weight_decay = f("weight_decay")?;
    let (decay_rate, growth_rate) = (f("decay_rate")?, f("growth_rate")?);
    let (clip_threshold, momentum) = (f("clip_threshold")?, f("momentum")?);
    let mut b = |what: &str| s.u8(&format!("CONFIG {what}"));
    let weight_decay_mode = b("weight_decay_mode")?;
    let bias_correction = b("bias_correction")? != 0;
    let relative_step = b("relative_step")? != 0;
    let vector_reshape = b("vector_reshape")? != 0;
    let smmf_scheme = b("smmf_scheme")?;
    let smmf_sign_mode = b("smmf_sign_mode")?;
    let smmf_matricize = b("smmf_matricize")?;
    let n_groups = s.u32("CONFIG group count")? as usize;
    if n_groups > MAX_GROUPS {
        bail!("CONFIG claims {n_groups} groups (max {MAX_GROUPS})");
    }
    let mut groups = Vec::with_capacity(n_groups);
    for i in 0..n_groups {
        let name_len = s.u32(&format!("CONFIG group {i} name length"))? as usize;
        if name_len > MAX_NAME_LEN {
            bail!("CONFIG group {i}: name length {name_len} exceeds the cap ({MAX_NAME_LEN})");
        }
        let name = String::from_utf8(s.bytes_vec(name_len, &format!("CONFIG group {i} name"))?)
            .with_context(|| format!("CONFIG group {i}: name is not valid UTF-8"))?;
        let lr_scale = s.f32(&format!("CONFIG group {i} lr_scale"))?;
        let weight_decay = s.f32(&format!("CONFIG group {i} weight_decay"))?;
        let frozen = s.u8(&format!("CONFIG group {i} frozen"))? != 0;
        let state = s.u8(&format!("CONFIG group {i} state"))?;
        groups.push(GroupRecord { name, lr_scale, weight_decay, frozen, state });
    }
    let n_tensors = s.u32("CONFIG tensor count")? as usize;
    if n_tensors > MAX_TENSORS {
        bail!("CONFIG claims {n_tensors} tensors (max {MAX_TENSORS})");
    }
    let mut assign = Vec::with_capacity(n_tensors.min(1024));
    for i in 0..n_tensors {
        let g = s.u32(&format!("CONFIG tensor {i} group index"))?;
        if g as usize >= groups.len().max(1) {
            bail!("CONFIG tensor {i}: group index {g} out of range ({} groups)", groups.len());
        }
        assign.push(g);
    }
    Ok(ConfigSection {
        beta1,
        beta2,
        beta3,
        eps1,
        eps2,
        weight_decay,
        weight_decay_mode,
        decay_rate,
        growth_rate,
        clip_threshold,
        momentum,
        bias_correction,
        relative_step,
        vector_reshape,
        smmf_scheme,
        smmf_sign_mode,
        smmf_matricize,
        groups,
        assign,
    })
}

fn read_tensor_table<R: std::io::Read>(s: &mut Src<R>) -> Result<(Vec<String>, Vec<Tensor>)> {
    let n = s.u32("tensor count")? as usize;
    if n > MAX_TENSORS {
        bail!("tensor count {n} exceeds the sanity cap ({MAX_TENSORS})");
    }
    let mut names = Vec::with_capacity(n.min(1024));
    let mut tensors = Vec::with_capacity(n.min(1024));
    for i in 0..n {
        let name_len = s.u32(&format!("tensor {i}: name length"))? as usize;
        if name_len > MAX_NAME_LEN {
            bail!("tensor {i}: name length {name_len} exceeds the cap ({MAX_NAME_LEN})");
        }
        let name = String::from_utf8(s.bytes_vec(name_len, &format!("tensor {i} name"))?)
            .with_context(|| format!("tensor {i}: name is not valid UTF-8"))?;
        let rank = s.u32(&format!("tensor {i} ({name}): rank"))? as usize;
        if rank > MAX_RANK {
            bail!("tensor {i} ({name}): rank {rank} exceeds the cap ({MAX_RANK})");
        }
        let mut shape = Vec::with_capacity(rank);
        let mut numel: usize = 1;
        for a in 0..rank {
            let d = s.u64(&format!("tensor {i} ({name}): dim {a}"))?;
            if d > MAX_DIM {
                bail!("tensor {i} ({name}): dim {a} = {d} exceeds the cap ({MAX_DIM})");
            }
            numel = numel
                .checked_mul(d as usize)
                .with_context(|| format!("tensor {i} ({name}): element count overflows"))?;
            shape.push(d as usize);
        }
        // f32s_vec validates the claimed payload against the bytes
        // actually remaining BEFORE allocating — a corrupt header can
        // not force an OOM.
        let data = s.f32s_vec(numel, &format!("tensor {i} ({name})"))?;
        names.push(name);
        tensors.push(Tensor::from_vec(&shape, data));
    }
    Ok((names, tensors))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("smmf_ckpt_{tag}_{}.bin", std::process::id()))
    }

    fn parse_bytes(data: &[u8]) -> Result<Checkpoint> {
        super::parse(data, data.len() as u64)
    }

    fn sample_tensors() -> (Vec<String>, Vec<Tensor>) {
        (
            vec!["w1".to_string(), "b1".to_string()],
            vec![
                Tensor::from_vec(&[2, 3], vec![1., -2., 3., 4., 5.5, -6.]),
                Tensor::from_vec(&[3], vec![0.1, 0.2, 0.3]),
            ],
        )
    }

    #[test]
    fn roundtrip() {
        let tmp = tmp("v1");
        let (names, tensors) = sample_tensors();
        save(&tmp, 42, &names, &tensors).unwrap();
        let (step, n2, t2) = load(&tmp).unwrap();
        assert_eq!(step, 42);
        assert_eq!(n2, names);
        assert_eq!(t2, tensors);
        std::fs::remove_file(&tmp).unwrap();
    }

    fn sample_config() -> ConfigSection {
        use crate::optim::group::{GroupedConfig, ParamRole, ParamSpec, StatePolicy};
        use crate::optim::{group, GroupPolicy};
        let specs = vec![
            ParamSpec::new("w1", &[2, 3], ParamRole::Kernel),
            ParamSpec::new("b1", &[3], ParamRole::Bias),
        ];
        let mut gcfg = GroupedConfig::uniform(&OptimConfig {
            weight_decay: 0.01,
            ..OptimConfig::default()
        });
        gcfg.groups.push(GroupPolicy {
            name: "no_decay".into(),
            match_roles: vec![ParamRole::Bias],
            weight_decay: Some(0.0),
            state: StatePolicy::Dense,
            ..GroupPolicy::default()
        });
        ConfigSection::from_config(&gcfg.base, &group::resolve(&specs, &gcfg))
    }

    #[test]
    fn v2_roundtrip_all_sections() {
        let tmp = tmp("v2");
        let (names, tensors) = sample_tensors();
        let sched = ScheduleSection {
            base_lr: 1e-3,
            schedule: LrSchedule::Cosine { warmup: 10, total: 100, floor: 0.05 },
        };
        let opt = OptSection {
            kind: OptKind::Smmf,
            opt_step: 17,
            blobs: vec![vec![1, 2, 3], vec![]],
        };
        let config = sample_config();
        save_v2(
            &tmp,
            17,
            &names,
            &tensors,
            Some((99, 7)),
            Some(&sched),
            Some(&opt),
            Some(&config),
        )
        .unwrap();
        let ck = load_any(&tmp).unwrap();
        assert_eq!(ck.version, VERSION_V2);
        assert_eq!(ck.step, 17);
        assert_eq!(ck.names, names);
        assert_eq!(ck.params, tensors);
        assert_eq!(ck.rng, Some((99, 7)));
        assert_eq!(ck.schedule, Some(sched));
        assert_eq!(ck.opt, Some(opt));
        // CONFIG roundtrips bit-exactly and self-compares clean
        let loaded = ck.config.expect("CONFIG section present");
        assert_eq!(loaded, config);
        assert!(loaded.mismatches(&config).is_empty());
        assert_eq!(loaded.groups.len(), 2);
        assert_eq!(loaded.groups[1].name, "no_decay");
        assert_eq!(loaded.assign, vec![0, 1]);
        // a drifted run config is caught field-by-field
        let mut drifted = config.clone();
        drifted.beta2 = 0.5;
        drifted.groups[1].weight_decay = 0.1;
        let diffs = loaded.mismatches(&drifted);
        assert_eq!(diffs.len(), 2, "{diffs:?}");
        assert!(diffs[0].contains("beta2"), "{diffs:?}");
        assert!(diffs[1].contains("group 1"), "{diffs:?}");
        // legacy signature also reads v2
        let (step, n2, t2) = load(&tmp).unwrap();
        assert_eq!((step, n2, t2), (17, names, tensors));
        std::fs::remove_file(&tmp).unwrap();
    }

    #[test]
    fn streamed_snapshot_is_byte_identical_to_dense() {
        let dense_path = tmp("snap_dense");
        let streamed_path = tmp("snap_streamed");
        let (names, tensors) = sample_tensors();
        let schedule = LrSchedule::Cosine { warmup: 10, total: 100, floor: 0.05 };
        let config = sample_config();
        let blobs = vec![vec![9u8; 33], vec![], vec![1, 2, 3, 4]];
        // Three blobs vs two tensors is fine here: the OPT section is an
        // opaque list, only the loader cross-checks counts.
        let names3 = names.clone();
        save_snapshot(
            &dense_path,
            12,
            &names3,
            &tensors,
            2e-3,
            &schedule,
            OptKind::Smmf,
            12,
            blobs.clone(),
            &config,
        )
        .unwrap();
        let lens: Vec<u64> = blobs.iter().map(|b| b.len() as u64).collect();
        let n = save_snapshot_streamed(
            &streamed_path,
            12,
            &names3,
            &tensors,
            2e-3,
            &schedule,
            OptKind::Smmf,
            12,
            &lens,
            &config,
            &mut |i| Ok(blobs[i].clone()),
        )
        .unwrap();
        let dense = std::fs::read(&dense_path).unwrap();
        let streamed = std::fs::read(&streamed_path).unwrap();
        assert_eq!(n, streamed.len() as u64);
        assert_eq!(dense, streamed, "streamed snapshot drifted from the dense writer");

        // A blob that disagrees with its announced length aborts the
        // write and leaves the previous file intact (atomic_write).
        let err = save_snapshot_streamed(
            &streamed_path,
            13,
            &names3,
            &tensors,
            2e-3,
            &schedule,
            OptKind::Smmf,
            13,
            &lens,
            &config,
            &mut |i| Ok(vec![0u8; blobs[i].len() + 1]),
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("sizing pass announced"), "{err:#}");
        assert_eq!(std::fs::read(&streamed_path).unwrap(), dense);
        std::fs::remove_file(&dense_path).unwrap();
        std::fs::remove_file(&streamed_path).unwrap();
    }

    #[test]
    fn v1_file_loads_through_load_any() {
        let tmp = tmp("v1_compat");
        let (names, tensors) = sample_tensors();
        save(&tmp, 5, &names, &tensors).unwrap();
        let ck = load_any(&tmp).unwrap();
        assert_eq!(ck.version, VERSION_V1);
        assert_eq!(ck.step, 5);
        assert_eq!(ck.params, tensors);
        assert!(ck.rng.is_none() && ck.schedule.is_none() && ck.opt.is_none());
        std::fs::remove_file(&tmp).unwrap();
    }

    #[test]
    fn save_overwrites_atomically_without_tmp_residue() {
        let path = tmp("atomic");
        let (names, tensors) = sample_tensors();
        save_v2(&path, 1, &names, &tensors, None, None, None, None).unwrap();
        // Overwriting an existing checkpoint goes through rename, leaves
        // no .tmp sibling, and the declared PARAMS length matches the
        // streamed bytes exactly (parse's finish() would reject drift).
        save_v2(&path, 2, &names, &tensors, None, None, None, None).unwrap();
        assert_eq!(load_any(&path).unwrap().step, 2);
        let mut side = path.file_name().unwrap().to_os_string();
        side.push(".tmp");
        assert!(!path.with_file_name(side).exists());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn rejects_garbage() {
        let tmp = tmp("bad");
        std::fs::write(&tmp, b"not a checkpoint").unwrap();
        assert!(load(&tmp).is_err());
        std::fs::remove_file(&tmp).unwrap();
    }

    #[test]
    fn rejects_truncation_at_every_prefix() {
        // Every strict prefix of a valid v2 file must error cleanly.
        let tmp = tmp("trunc");
        let (names, tensors) = sample_tensors();
        let opt =
            OptSection { kind: OptKind::Adam, opt_step: 3, blobs: vec![vec![0u8; 16], vec![]] };
        let config = sample_config();
        save_v2(&tmp, 3, &names, &tensors, Some((1, 2)), None, Some(&opt), Some(&config))
            .unwrap();
        let full = std::fs::read(&tmp).unwrap();
        for cut in 0..full.len() {
            assert!(parse_bytes(&full[..cut]).is_err(), "prefix of {cut} bytes parsed");
        }
        assert!(parse_bytes(&full).is_ok());
        std::fs::remove_file(&tmp).unwrap();
    }

    #[test]
    fn rejects_oversized_and_non_utf8_fields() {
        // Hand-build hostile v1 files: the loader must refuse before
        // allocating.
        let mut w = BlobWriter::new();
        w.bytes(MAGIC);
        w.u32(VERSION_V1);
        w.u64(0);
        w.u32(1); // one tensor
        w.u32(u32::MAX); // absurd name length
        let e = parse_bytes(&w.finish()).unwrap_err();
        assert!(format!("{e:#}").contains("name length"), "{e:#}");

        let mut w = BlobWriter::new();
        w.bytes(MAGIC);
        w.u32(VERSION_V1);
        w.u64(0);
        w.u32(1);
        w.u32(2);
        w.bytes(&[0xff, 0xfe]); // invalid UTF-8 name
        w.u32(0);
        let e = parse_bytes(&w.finish()).unwrap_err();
        assert!(format!("{e:#}").contains("UTF-8"), "{e:#}");

        let mut w = BlobWriter::new();
        w.bytes(MAGIC);
        w.u32(VERSION_V1);
        w.u64(0);
        w.u32(1);
        w.u32(1);
        w.bytes(b"w");
        w.u32(99); // absurd rank
        let e = parse_bytes(&w.finish()).unwrap_err();
        assert!(format!("{e:#}").contains("rank"), "{e:#}");

        // Huge claimed dims: must be caught by the remaining-bytes check
        // (or the dim cap), never by an allocation attempt.
        let mut w = BlobWriter::new();
        w.bytes(MAGIC);
        w.u32(VERSION_V1);
        w.u64(0);
        w.u32(1);
        w.u32(1);
        w.bytes(b"w");
        w.u32(2);
        w.u64(1 << 30);
        w.u64(1 << 30);
        let e = parse_bytes(&w.finish()).unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("remain") || msg.contains("overflow"), "{msg}");
    }

    #[test]
    fn unknown_version_and_sections() {
        let mut w = BlobWriter::new();
        w.bytes(MAGIC);
        w.u32(99);
        assert!(parse_bytes(&w.finish()).is_err());

        // Unknown section tag is skipped; params still load.
        let (names, tensors) = sample_tensors();
        let mut params = Vec::new();
        super::stream_tensor_table(&mut params, &names, &tensors).unwrap();
        let trainer: &[u8] = &[3, 0, 0, 0, 0, 0, 0, 0, 0]; // step=3, no rng
        let mut w = BlobWriter::new();
        w.bytes(MAGIC);
        w.u32(VERSION_V2);
        w.u32(3);
        w.u32(777); // future section
        w.u64(3);
        w.bytes(&[1, 2, 3]);
        w.u32(SEC_PARAMS);
        w.u64(params.len() as u64);
        w.bytes(&params);
        w.u32(SEC_TRAINER);
        w.u64(trainer.len() as u64);
        w.bytes(trainer);
        let ck = parse_bytes(&w.finish()).unwrap();
        assert_eq!(ck.params, tensors);
        assert_eq!(ck.step, 3);

        // A v2 file missing the TRAINER section must be rejected — step
        // would silently default to 0 and resume would retrain from the
        // start on already-trained parameters.
        let mut w = BlobWriter::new();
        w.bytes(MAGIC);
        w.u32(VERSION_V2);
        w.u32(1);
        w.u32(SEC_PARAMS);
        w.u64(params.len() as u64);
        w.bytes(&params);
        let e = parse_bytes(&w.finish()).unwrap_err();
        assert!(format!("{e:#}").contains("TRAINER"), "{e:#}");

        // Duplicate known tags are rejected (last-wins would mask a
        // corrupt tag byte).
        let mut w = BlobWriter::new();
        w.bytes(MAGIC);
        w.u32(VERSION_V2);
        w.u32(3);
        w.u32(SEC_PARAMS);
        w.u64(params.len() as u64);
        w.bytes(&params);
        w.u32(SEC_TRAINER);
        w.u64(trainer.len() as u64);
        w.bytes(trainer);
        w.u32(SEC_TRAINER);
        w.u64(trainer.len() as u64);
        w.bytes(trainer);
        let e = parse_bytes(&w.finish()).unwrap_err();
        assert!(format!("{e:#}").contains("duplicate"), "{e:#}");
    }
}
