//! Training loop: AOT fwd/bwd + Rust optimizer step.
//!
//! Two paths exercise the paper end-to-end:
//!
//! * [`TrainGraph`] + [`Trainer`] — the framework path: the HLO artifact
//!   computes `(loss, grads…)`, any [`crate::optim::Optimizer`] (SMMF or a
//!   baseline) updates parameters in Rust. This is what the experiment
//!   harness uses to compare the five optimizers under identical budgets.
//! * [`FusedSmmfStep`] — the compiled path: the whole train step including
//!   the SMMF update (through the L1 Pallas kernel) is one XLA program;
//!   Rust only feeds batches and carries the factorized state between
//!   calls. Used by the quickstart and the L1/L2 perf benches.
//!
//! [`Trainer::save_checkpoint`] / [`Trainer::resume_from`] persist and
//! restore the full training state (parameters, step, data-RNG position,
//! LR schedule, native optimizer state) through the versioned
//! [`checkpoint`] container, making long runs restart-safe with
//! bit-identical trajectories.

pub mod checkpoint;
pub mod metrics;

pub use metrics::RunLogger;

use anyhow::{bail, Context, Result};
use std::path::Path;

use crate::optim::schedule::LrSchedule;
use crate::optim::{Optimizer, StateSerde};
use crate::runtime::{
    init_params, lit_f32, lit_scalar_f32, lit_to_scalar_f32, lit_to_vec_f32, lit_zeros, Dtype,
    Graph, Runtime,
};
use crate::tensor::Tensor;

/// A `(params…, batch…) -> (loss, grads…)` artifact.
pub struct TrainGraph {
    graph: Graph,
    n_params: usize,
}

impl TrainGraph {
    pub fn load(rt: &Runtime, name: &str) -> Result<TrainGraph> {
        let graph = rt.load(name)?;
        if graph.spec.kind != "grads" {
            bail!("{name} is kind {}, expected grads", graph.spec.kind);
        }
        let n_params = graph.spec.params.len();
        Ok(TrainGraph { graph, n_params })
    }

    pub fn n_params(&self) -> usize {
        self.n_params
    }

    pub fn spec(&self) -> &crate::runtime::ArtifactSpec {
        &self.graph.spec
    }

    pub fn param_shapes(&self) -> Vec<Vec<usize>> {
        self.graph.spec.params.iter().map(|p| p.shape.clone()).collect()
    }

    pub fn init_params(&self, seed: u64) -> Vec<Tensor> {
        init_params(&self.graph.spec.params, seed)
    }

    /// Batch input specs (everything after the params).
    pub fn batch_inputs(&self) -> &[crate::runtime::IoSpec] {
        &self.graph.spec.inputs[self.n_params..]
    }

    /// Run fwd/bwd; fills `grads_out` (reused across steps) and returns
    /// the loss.
    pub fn loss_and_grads(
        &self,
        params: &[Tensor],
        batch: &[xla::Literal],
        grads_out: &mut Vec<Tensor>,
    ) -> Result<f32> {
        let mut inputs = Vec::with_capacity(self.n_params + batch.len());
        for (p, spec) in params.iter().zip(&self.graph.spec.params) {
            inputs.push(lit_f32(&spec.shape, p.data())?);
        }
        inputs.extend(batch.iter().cloned());
        let outs = self.graph.run(&inputs)?;
        let loss = lit_to_scalar_f32(&outs[0])?;
        grads_out.clear();
        for (out, spec) in outs[1..].iter().zip(&self.graph.spec.params) {
            grads_out.push(Tensor::from_vec(&spec.shape, lit_to_vec_f32(out)?));
        }
        Ok(loss)
    }
}

/// Trainer: composes a [`TrainGraph`] with an optimizer and LR schedule.
pub struct Trainer {
    pub graph: TrainGraph,
    pub opt: Box<dyn Optimizer>,
    pub params: Vec<Tensor>,
    grads: Vec<Tensor>,
    pub step: u64,
    pub base_lr: f32,
    pub schedule: LrSchedule,
    /// Resolved hyperparameter/group fingerprint written into the
    /// checkpoint CONFIG section and cross-checked on resume (set via
    /// [`Trainer::set_config_section`]; `None` = legacy caller, no
    /// cross-check).
    pub config: Option<checkpoint::ConfigSection>,
}

impl Trainer {
    pub fn new(
        graph: TrainGraph,
        opt: Box<dyn Optimizer>,
        seed: u64,
        base_lr: f32,
        schedule: LrSchedule,
    ) -> Trainer {
        let params = graph.init_params(seed);
        Trainer {
            graph,
            opt,
            params,
            grads: Vec::new(),
            step: 0,
            base_lr,
            schedule,
            config: None,
        }
    }

    /// Register the resolved config fingerprint (see
    /// [`checkpoint::ConfigSection::from_config`]) so checkpoints carry
    /// it and resumes validate against it.
    pub fn set_config_section(&mut self, config: checkpoint::ConfigSection) {
        self.config = Some(config);
    }

    /// One optimization step on a batch; returns the loss.
    pub fn train_step(&mut self, batch: &[xla::Literal]) -> Result<f32> {
        self.step += 1;
        let lr = self.schedule.at(self.base_lr, self.step);
        self.opt.set_lr(lr);
        let loss = self.graph.loss_and_grads(&self.params, batch, &mut self.grads)?;
        if !loss.is_finite() {
            bail!("loss diverged at step {}: {loss}", self.step);
        }
        self.opt.step(&mut self.params, &self.grads);
        Ok(loss)
    }

    /// Evaluate loss without updating (e.g. on a held-out batch).
    pub fn eval_loss(&mut self, batch: &[xla::Literal]) -> Result<f32> {
        self.graph.loss_and_grads(&self.params, batch, &mut self.grads)
    }

    pub fn optimizer_state_bytes(&self) -> u64 {
        self.opt.state_bytes()
    }

    /// Parameter names from the artifact spec, in registration order
    /// (the tensor names written to checkpoints).
    pub fn param_names(&self) -> Vec<String> {
        self.graph.spec().params.iter().map(|p| p.name.clone()).collect()
    }

    /// Write a `SMMFCKPT` v2 checkpoint: parameters, trainer step, the
    /// data-stream RNG snapshot (if the caller has one), the LR-schedule
    /// position, the optimizer's native state blobs, and (when
    /// registered) the resolved config/group fingerprint — everything a
    /// bit-identical, cross-checked resume needs.
    pub fn save_checkpoint(&self, path: &Path, rng: Option<(u64, u64)>) -> Result<()> {
        let names = self.param_names();
        let sched = checkpoint::ScheduleSection {
            base_lr: self.base_lr,
            schedule: self.schedule.clone(),
        };
        let kind = crate::optim::OptKind::parse(self.opt.name())
            .expect("optimizer name always parses back to its kind");
        let opt = checkpoint::OptSection {
            kind,
            opt_step: self.opt.opt_step(),
            blobs: self.opt.state_blobs(),
        };
        checkpoint::save_v2(
            path,
            self.step,
            &names,
            &self.params,
            rng,
            Some(&sched),
            Some(&opt),
            self.config.as_ref(),
        )
    }

    /// Resume from a checkpoint written by [`Trainer::save_checkpoint`]
    /// (or a legacy v1 file — parameters restore, optimizer momentum
    /// restarts cold with a warning). Validates tensor names/shapes, the
    /// optimizer kind and the LR schedule against this trainer's
    /// configuration and errors on any mismatch. Returns the data-RNG
    /// snapshot for the caller to restore into its batch source.
    ///
    /// Hyperparameter/group cross-check: checkpoints written with a
    /// CONFIG section (any grouped-API run) are validated field-by-field
    /// against this trainer's registered fingerprint and rejected with a
    /// per-field diff on drift. Files without the section (pre-group v2,
    /// or v1) are accepted with a warning — state-layout disagreements
    /// (momentum on/off, sign width, factored-vs-dense) still fail at
    /// blob load. See docs/CHECKPOINT_FORMAT.md § Compatibility rules.
    pub fn resume_from(&mut self, path: &Path) -> Result<Option<(u64, u64)>> {
        let ck = checkpoint::load_any(path)?;
        match (&self.config, &ck.config) {
            (Some(mine), Some(theirs)) => {
                let diffs = theirs.mismatches(mine);
                if !diffs.is_empty() {
                    bail!(
                        "checkpoint {path:?} was written under a different optimizer \
                         config/group layout — resumes must keep the recipe:\n  {}",
                        diffs.join("\n  ")
                    );
                }
            }
            (Some(_), None) => eprintln!(
                "warning: {path:?} carries no CONFIG section (pre-group checkpoint) — \
                 hyperparameters and group layout not cross-checked"
            ),
            (None, _) => {}
        }
        let names = self.param_names();
        if ck.names != names {
            bail!(
                "checkpoint {path:?} holds tensors {:?}, artifact expects {:?}",
                ck.names,
                names
            );
        }
        for ((name, have), want) in names.iter().zip(&ck.params).zip(&self.params) {
            if have.shape() != want.shape() {
                bail!(
                    "checkpoint {path:?}: tensor {name} has shape {:?}, artifact expects {:?}",
                    have.shape(),
                    want.shape()
                );
            }
        }
        if let Some(s) = &ck.schedule {
            if s.schedule != self.schedule || s.base_lr != self.base_lr {
                bail!(
                    "checkpoint {path:?} was written with lr={} schedule={:?}, this run is \
                     configured with lr={} schedule={:?} — resumes must keep the recipe \
                     (pass matching --lr / [schedule])",
                    s.base_lr,
                    s.schedule,
                    self.base_lr,
                    self.schedule
                );
            }
        }
        match &ck.opt {
            Some(o) => {
                if o.kind.name() != self.opt.name() {
                    bail!(
                        "checkpoint {path:?} holds {} state, this run uses {}",
                        o.kind.name(),
                        self.opt.name()
                    );
                }
                self.opt
                    .load_state_blobs(&o.blobs)
                    .with_context(|| format!("restoring optimizer state from {path:?}"))?;
                self.opt.set_opt_step(o.opt_step);
            }
            None => eprintln!(
                "warning: {path:?} is a v{} checkpoint with no optimizer state — \
                 momentum restarts cold",
                ck.version
            ),
        }
        self.params = ck.params;
        self.step = ck.step;
        Ok(ck.rng)
    }
}

/// The compiled whole-train-step path: `(step, params…, state…, batch…) ->
/// (loss, params'…, state'…)` with the SMMF update inside the XLA program.
pub struct FusedSmmfStep {
    graph: Graph,
    n_params: usize,
    n_state: usize,
    /// Current parameters + factorized optimizer state, kept as literals
    /// and threaded through consecutive executions.
    params: Vec<xla::Literal>,
    state: Vec<xla::Literal>,
    pub step: u64,
}

impl FusedSmmfStep {
    pub fn load(rt: &Runtime, name: &str, seed: u64) -> Result<FusedSmmfStep> {
        let graph = rt.load(name)?;
        if graph.spec.kind != "smmf_step" {
            bail!("{name} is kind {}, expected smmf_step", graph.spec.kind);
        }
        let n_params = graph.spec.params.len();
        let n_state = graph.spec.state.len();
        let init = init_params(&graph.spec.params, seed);
        let params = init
            .iter()
            .zip(&graph.spec.params)
            .map(|(t, s)| lit_f32(&s.shape, t.data()))
            .collect::<Result<Vec<_>>>()?;
        let state = graph
            .spec
            .state
            .iter()
            .map(|s| lit_zeros(Dtype::parse(&s.dtype)?, &s.shape))
            .collect::<Result<Vec<_>>>()?;
        Ok(FusedSmmfStep { graph, n_params, n_state, params, state, step: 0 })
    }

    pub fn batch_inputs(&self) -> &[crate::runtime::IoSpec] {
        &self.graph.spec.inputs[1 + self.n_params + self.n_state..]
    }

    /// One fused train step; returns the loss.
    pub fn train_step(&mut self, batch: &[xla::Literal]) -> Result<f32> {
        self.step += 1;
        let mut inputs = Vec::with_capacity(1 + self.n_params + self.n_state + batch.len());
        inputs.push(lit_scalar_f32(self.step as f32));
        inputs.extend(self.params.iter().cloned());
        inputs.extend(self.state.iter().cloned());
        inputs.extend(batch.iter().cloned());
        let mut outs = self.graph.run(&inputs)?;
        let loss = lit_to_scalar_f32(&outs[0])?;
        // outs = [loss, params'…, state'…]
        let state_new: Vec<_> = outs.drain(1 + self.n_params..).collect();
        let params_new: Vec<_> = outs.drain(1..).collect();
        self.params = params_new;
        self.state = state_new;
        Ok(loss)
    }

    /// Copy the current value of parameter `idx` back to the host.
    pub fn param_f32(&self, idx: usize) -> Result<Vec<f32>> {
        lit_to_vec_f32(&self.params[idx])
    }

    /// Persistent optimizer-state bytes of the compiled path: the
    /// factorized vectors (f32) + sign matrices (1 byte/elem as PRED —
    /// the paper's Table-5 "8-bit S_M" configuration).
    pub fn state_bytes(&self) -> u64 {
        self.graph
            .spec
            .state
            .iter()
            .map(|s| {
                let numel: usize = s.shape.iter().product();
                (numel * if s.dtype == "pred" { 1 } else { 4 }) as u64
            })
            .sum()
    }

    pub fn param_specs(&self) -> &[crate::runtime::ParamInit] {
        &self.graph.spec.params
    }

    pub fn spec(&self) -> &crate::runtime::ArtifactSpec {
        &self.graph.spec
    }
}
