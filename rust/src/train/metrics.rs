//! Run metrics: JSONL (machine) + CSV (plotting) writers under `runs/`.

use anyhow::{Context, Result};
use std::fs::{self, File};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::time::Instant;

use crate::util::json::ObjBuilder;

pub struct RunLogger {
    pub dir: PathBuf,
    jsonl: BufWriter<File>,
    csv: BufWriter<File>,
    csv_header_written: bool,
    started: Instant,
}

impl RunLogger {
    /// Create `runs/<name>/` with `metrics.jsonl` and `metrics.csv`.
    pub fn create(root: impl AsRef<Path>, name: &str) -> Result<RunLogger> {
        let dir = root.as_ref().join(name);
        fs::create_dir_all(&dir).with_context(|| format!("mkdir {dir:?}"))?;
        let jsonl = BufWriter::new(File::create(dir.join("metrics.jsonl"))?);
        let csv = BufWriter::new(File::create(dir.join("metrics.csv"))?);
        Ok(RunLogger { dir, jsonl, csv, csv_header_written: false, started: Instant::now() })
    }

    /// Log one step record: fixed fields + extra named values.
    pub fn log(&mut self, step: u64, loss: f32, extra: &[(&str, f64)]) -> Result<()> {
        let elapsed = self.started.elapsed().as_secs_f64();
        let mut obj = ObjBuilder::new()
            .num("step", step as f64)
            .num("loss", loss as f64)
            .num("elapsed_s", elapsed);
        for (k, v) in extra {
            obj = obj.num(k, *v);
        }
        writeln!(self.jsonl, "{}", obj.build().to_string())?;
        if !self.csv_header_written {
            let mut head = vec!["step".to_string(), "loss".into(), "elapsed_s".into()];
            head.extend(extra.iter().map(|(k, _)| k.to_string()));
            writeln!(self.csv, "{}", head.join(","))?;
            self.csv_header_written = true;
        }
        let mut row = vec![step.to_string(), format!("{loss}"), format!("{elapsed:.3}")];
        row.extend(extra.iter().map(|(_, v)| format!("{v}")));
        writeln!(self.csv, "{}", row.join(","))?;
        // Flush per record: logs are sparse (every log_every steps) and
        // live tailing during long runs matters more than write batching.
        self.jsonl.flush()?;
        self.csv.flush()?;
        Ok(())
    }

    /// Write a free-form summary JSON next to the metrics.
    pub fn write_summary(&self, json: &crate::util::json::Json) -> Result<()> {
        fs::write(self.dir.join("summary.json"), json.to_string())?;
        Ok(())
    }

    pub fn flush(&mut self) -> Result<()> {
        self.jsonl.flush()?;
        self.csv.flush()?;
        Ok(())
    }
}

impl Drop for RunLogger {
    fn drop(&mut self) {
        let _ = self.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn writes_jsonl_and_csv() {
        let tmp = std::env::temp_dir().join(format!("smmf_metrics_{}", std::process::id()));
        {
            let mut log = RunLogger::create(&tmp, "t1").unwrap();
            log.log(1, 2.5, &[("lr", 1e-3)]).unwrap();
            log.log(2, 2.0, &[("lr", 1e-3)]).unwrap();
            log.flush().unwrap();
        }
        let jsonl = std::fs::read_to_string(tmp.join("t1/metrics.jsonl")).unwrap();
        let lines: Vec<_> = jsonl.lines().collect();
        assert_eq!(lines.len(), 2);
        let rec = Json::parse(lines[0]).unwrap();
        assert_eq!(rec.get("loss").unwrap().as_f64(), Some(2.5));
        let csv = std::fs::read_to_string(tmp.join("t1/metrics.csv")).unwrap();
        assert!(csv.starts_with("step,loss,elapsed_s,lr"));
        std::fs::remove_dir_all(&tmp).unwrap();
    }
}
