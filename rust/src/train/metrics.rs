//! Run metrics: JSONL (machine) + CSV (plotting) writers under `runs/`.

use anyhow::{anyhow, Context, Result};
use std::fs::{self, File};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::time::Instant;

use crate::util::json::ObjBuilder;

pub struct RunLogger {
    pub dir: PathBuf,
    jsonl: BufWriter<File>,
    csv: BufWriter<File>,
    csv_header_written: bool,
    started: Instant,
}

impl RunLogger {
    /// Create `runs/<name>/` with fresh `metrics.jsonl` and `metrics.csv`
    /// (truncating any previous run of the same name).
    pub fn create(root: impl AsRef<Path>, name: &str) -> Result<RunLogger> {
        Self::open(root, name, false)
    }

    /// Open `runs/<name>/` keeping existing metrics and appending — used
    /// by resumed runs so the pre-checkpoint history (the training
    /// curves) survives the restart. Rows logged *after* `resume_step`
    /// are pruned first: a run killed between its last checkpoint and
    /// its last log line would otherwise leave rows the resumed run
    /// re-logs, producing duplicate steps in the curves. The CSV header
    /// is only emitted when the file is new/empty.
    pub fn append(root: impl AsRef<Path>, name: &str, resume_step: u64) -> Result<RunLogger> {
        let dir = root.as_ref().join(name);
        prune_rows_after(&dir.join("metrics.jsonl"), resume_step, |line| {
            crate::util::json::Json::parse(line)
                .ok()
                .and_then(|j| j.get("step").and_then(crate::util::json::Json::as_f64))
                .map(|s| s as u64)
        })?;
        prune_rows_after(&dir.join("metrics.csv"), resume_step, |line| {
            // header ("step,...") fails the parse and is kept
            line.split(',').next().and_then(|f| f.parse::<u64>().ok())
        })?;
        Self::open(root, name, true)
    }

    fn open(root: impl AsRef<Path>, name: &str, append: bool) -> Result<RunLogger> {
        let dir = root.as_ref().join(name);
        fs::create_dir_all(&dir).with_context(|| format!("mkdir {dir:?}"))?;
        let open_log = |file: &str| -> Result<(File, bool)> {
            let path = dir.join(file);
            if append {
                let f = fs::OpenOptions::new().create(true).append(true).open(&path)?;
                let nonempty = f.metadata()?.len() > 0;
                Ok((f, nonempty))
            } else {
                Ok((File::create(&path)?, false))
            }
        };
        let (jsonl, _) = open_log("metrics.jsonl")?;
        let (csv, csv_nonempty) = open_log("metrics.csv")?;
        Ok(RunLogger {
            dir,
            jsonl: BufWriter::new(jsonl),
            csv: BufWriter::new(csv),
            csv_header_written: csv_nonempty,
            started: Instant::now(),
        })
    }

    /// Log one step record: fixed fields + extra named values.
    pub fn log(&mut self, step: u64, loss: f32, extra: &[(&str, f64)]) -> Result<()> {
        let elapsed = self.started.elapsed().as_secs_f64();
        let mut obj = ObjBuilder::new()
            .num("step", step as f64)
            .num("loss", loss as f64)
            .num("elapsed_s", elapsed);
        for (k, v) in extra {
            obj = obj.num(k, *v);
        }
        writeln!(self.jsonl, "{}", obj.build().to_string())?;
        if !self.csv_header_written {
            let mut head = vec!["step".to_string(), "loss".into(), "elapsed_s".into()];
            head.extend(extra.iter().map(|(k, _)| k.to_string()));
            writeln!(self.csv, "{}", head.join(","))?;
            self.csv_header_written = true;
        }
        let mut row = vec![step.to_string(), format!("{loss}"), format!("{elapsed:.3}")];
        row.extend(extra.iter().map(|(_, v)| format!("{v}")));
        writeln!(self.csv, "{}", row.join(","))?;
        // Flush per record: logs are sparse (every log_every steps) and
        // live tailing during long runs matters more than write batching.
        self.jsonl.flush()?;
        self.csv.flush()?;
        Ok(())
    }

    /// Write a free-form summary JSON next to the metrics.
    ///
    /// Atomic (temp file + fsync + rename), because the suite scheduler
    /// uses `summary.json`'s existence as its "cell finished" marker: a
    /// partial file left by an interrupt would otherwise make the cell
    /// skip forever while the report generator can't parse it.
    pub fn write_summary(&self, json: &crate::util::json::Json) -> Result<()> {
        let tmp = self.dir.join("summary.json.tmp");
        {
            let mut f = File::create(&tmp).with_context(|| format!("creating {tmp:?}"))?;
            f.write_all(json.to_string().as_bytes())?;
            f.sync_all()?;
        }
        fs::rename(&tmp, self.dir.join("summary.json"))?;
        Ok(())
    }

    pub fn flush(&mut self) -> Result<()> {
        self.jsonl.flush()?;
        self.csv.flush()?;
        Ok(())
    }
}

impl Drop for RunLogger {
    fn drop(&mut self) {
        let _ = self.flush();
    }
}

/// Path of the summary a [`RunLogger::write_summary`] call would produce
/// for `root/name` — the suite scheduler's "this cell already ran"
/// marker.
pub fn summary_path(root: impl AsRef<Path>, name: &str) -> PathBuf {
    root.as_ref().join(name).join("summary.json")
}

/// Parse a run directory's `summary.json` (the inverse of
/// [`RunLogger::write_summary`]) — used by the suite report generator to
/// aggregate finished cells.
pub fn read_summary(dir: &Path) -> Result<crate::util::json::Json> {
    let path = dir.join("summary.json");
    let text = fs::read_to_string(&path).with_context(|| format!("reading {path:?}"))?;
    crate::util::json::Json::parse(&text).map_err(|e| anyhow!("parsing {path:?}: {e}"))
}

/// Drop lines whose parsed step exceeds `resume_step` (lines that don't
/// parse — headers — are kept), plus any unterminated final line: a run
/// killed mid-write leaves a partial record with no trailing newline,
/// and appending onto it would corrupt the file. Missing files are a
/// no-op.
fn prune_rows_after(
    path: &Path,
    resume_step: u64,
    step_of: impl Fn(&str) -> Option<u64>,
) -> Result<()> {
    let Ok(text) = fs::read_to_string(path) else {
        return Ok(());
    };
    let complete = text.is_empty() || text.ends_with('\n');
    let mut lines: Vec<&str> = text.lines().collect();
    if !complete {
        lines.pop(); // partial trailing record from a mid-write crash
    }
    let before = lines.len();
    let kept: Vec<&str> =
        lines.into_iter().filter(|l| step_of(l).map_or(true, |s| s <= resume_step)).collect();
    if !complete || kept.len() != before {
        let mut out = kept.join("\n");
        if !out.is_empty() {
            out.push('\n');
        }
        fs::write(path, out).with_context(|| format!("pruning {path:?}"))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn writes_jsonl_and_csv() {
        let tmp = std::env::temp_dir().join(format!("smmf_metrics_{}", std::process::id()));
        {
            let mut log = RunLogger::create(&tmp, "t1").unwrap();
            log.log(1, 2.5, &[("lr", 1e-3)]).unwrap();
            log.log(2, 2.0, &[("lr", 1e-3)]).unwrap();
            log.write_summary(
                &crate::util::json::ObjBuilder::new().num("final_loss", 2.0).build(),
            )
            .unwrap();
            log.flush().unwrap();
        }
        // summary round-trips through the suite-report reader
        assert!(summary_path(&tmp, "t1").exists());
        let summary = read_summary(&tmp.join("t1")).unwrap();
        assert_eq!(summary.get("final_loss").unwrap().as_f64(), Some(2.0));
        assert!(read_summary(&tmp.join("absent")).is_err());
        let jsonl = std::fs::read_to_string(tmp.join("t1/metrics.jsonl")).unwrap();
        let lines: Vec<_> = jsonl.lines().collect();
        assert_eq!(lines.len(), 2);
        let rec = Json::parse(lines[0]).unwrap();
        assert_eq!(rec.get("loss").unwrap().as_f64(), Some(2.5));
        let csv = std::fs::read_to_string(tmp.join("t1/metrics.csv")).unwrap();
        assert!(csv.starts_with("step,loss,elapsed_s,lr"));
        std::fs::remove_dir_all(&tmp).unwrap();
    }

    #[test]
    fn append_keeps_history_prunes_post_checkpoint_rows_and_skips_duplicate_header() {
        let tmp = std::env::temp_dir().join(format!("smmf_metrics_app_{}", std::process::id()));
        {
            let mut log = RunLogger::create(&tmp, "t2").unwrap();
            log.log(1, 2.5, &[("lr", 1e-3)]).unwrap();
            // Simulates a crash after the step-1 checkpoint: steps 2-3
            // were logged but never checkpointed.
            log.log(2, 2.0, &[("lr", 1e-3)]).unwrap();
            log.log(3, 1.8, &[("lr", 1e-3)]).unwrap();
        }
        // Resume from the step-1 checkpoint: rows > 1 are pruned, the
        // surviving history is kept, and the re-run rows append cleanly.
        {
            let mut log = RunLogger::append(&tmp, "t2", 1).unwrap();
            log.log(2, 2.0, &[("lr", 1e-3)]).unwrap();
        }
        let jsonl = std::fs::read_to_string(tmp.join("t2/metrics.jsonl")).unwrap();
        let steps: Vec<f64> = jsonl
            .lines()
            .map(|l| Json::parse(l).unwrap().get("step").unwrap().as_f64().unwrap())
            .collect();
        assert_eq!(steps, vec![1.0, 2.0], "no duplicate steps: {jsonl}");
        let csv = std::fs::read_to_string(tmp.join("t2/metrics.csv")).unwrap();
        let headers = csv.lines().filter(|l| l.starts_with("step,")).count();
        assert_eq!(headers, 1, "{csv}");
        assert_eq!(csv.lines().count(), 3); // header + steps 1, 2
        // Appending into a fresh dir still writes the header.
        {
            let mut log = RunLogger::append(&tmp, "t3", 0).unwrap();
            log.log(1, 1.0, &[("lr", 1e-3)]).unwrap();
        }
        let csv3 = std::fs::read_to_string(tmp.join("t3/metrics.csv")).unwrap();
        assert!(csv3.starts_with("step,loss,elapsed_s,lr"));
        // A partial trailing record (crash mid-write, no newline) is
        // dropped before appending — the file stays line-parseable.
        let jsonl_path = tmp.join("t3/metrics.jsonl");
        let mut contents = std::fs::read_to_string(&jsonl_path).unwrap();
        contents.push_str("{\"step\":2,\"lo"); // unterminated
        std::fs::write(&jsonl_path, contents).unwrap();
        {
            let mut log = RunLogger::append(&tmp, "t3", 1).unwrap();
            log.log(2, 0.9, &[("lr", 1e-3)]).unwrap();
        }
        let fixed = std::fs::read_to_string(&jsonl_path).unwrap();
        assert_eq!(fixed.lines().count(), 2);
        for line in fixed.lines() {
            Json::parse(line).expect("every line parses");
        }
        std::fs::remove_dir_all(&tmp).unwrap();
    }
}
