//! T5 inventories (Raffel et al. 2020): T5-small (fine-tuning, Tables 4,
//! 9–11) and T5-base (pre-training, Table 3). HF layout: RMSNorm scales
//! only, no biases in linears, relative-attention bias tables in the first
//! layer of each stack, lm_head tied to the shared embedding.

use super::Inventory;

pub struct T5Cfg {
    pub layers: usize, // per stack
    pub d_model: usize,
    pub d_ff: usize,
    pub d_kv: usize,
    pub heads: usize,
    pub vocab: usize,
}

pub const SMALL: T5Cfg =
    T5Cfg { layers: 6, d_model: 512, d_ff: 2048, d_kv: 64, heads: 8, vocab: 32128 };
pub const BASE: T5Cfg =
    T5Cfg { layers: 12, d_model: 768, d_ff: 3072, d_kv: 64, heads: 12, vocab: 32128 };

fn t5_attention(inv: &mut Inventory, p: &str, cfg: &T5Cfg, rel_bias: bool) {
    let inner = cfg.d_kv * cfg.heads;
    inv.linear_nb(&format!("{p}.q"), cfg.d_model, inner);
    inv.linear_nb(&format!("{p}.k"), cfg.d_model, inner);
    inv.linear_nb(&format!("{p}.v"), cfg.d_model, inner);
    inv.linear_nb(&format!("{p}.o"), inner, cfg.d_model);
    if rel_bias {
        inv.push(format!("{p}.relative_attention_bias"), &[32, cfg.heads]);
    }
}

pub fn t5(name: &str, cfg: &T5Cfg) -> Inventory {
    let mut inv = Inventory::new(name);
    inv.embedding("shared", cfg.vocab, cfg.d_model);
    for stack in ["encoder", "decoder"] {
        let is_dec = stack == "decoder";
        for l in 0..cfg.layers {
            let p = format!("{stack}.block.{l}");
            inv.rmsnorm(&format!("{p}.layer.0.layer_norm"), cfg.d_model);
            t5_attention(&mut inv, &format!("{p}.layer.0.SelfAttention"), cfg, l == 0);
            let mut li = 1;
            if is_dec {
                inv.rmsnorm(&format!("{p}.layer.1.layer_norm"), cfg.d_model);
                t5_attention(&mut inv, &format!("{p}.layer.1.EncDecAttention"), cfg, false);
                li = 2;
            }
            inv.rmsnorm(&format!("{p}.layer.{li}.layer_norm"), cfg.d_model);
            inv.linear_nb(&format!("{p}.layer.{li}.DenseReluDense.wi"), cfg.d_model, cfg.d_ff);
            inv.linear_nb(&format!("{p}.layer.{li}.DenseReluDense.wo"), cfg.d_ff, cfg.d_model);
        }
        inv.rmsnorm(&format!("{stack}.final_layer_norm"), cfg.d_model);
    }
    inv
}

pub fn t5_small() -> Inventory {
    t5("t5_small", &SMALL)
}

pub fn t5_base() -> Inventory {
    t5("t5_base", &BASE)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_is_60m() {
        // HF t5-small: 60.5M parameters.
        let n = t5_small().param_count();
        assert!((59_000_000..62_000_000).contains(&n), "{n}");
    }

    #[test]
    fn base_is_223m() {
        // Paper Table 3: Adam = 1.7 GiB -> N ≈ 228M; HF t5-base 222.9M.
        let n = t5_base().param_count();
        assert!((218_000_000..228_000_000).contains(&n), "{n}");
    }
}
