//! Name -> inventory registry used by the CLI and experiment harness.

use super::{bart, bert, gpt2, llama, mobilenet, resnet, t5, transformer, yolo, Inventory};

/// All named inventories with the dataset context the paper pairs them
/// with (classes / vocab already baked in).
pub fn list_inventories() -> Vec<(&'static str, &'static str)> {
    vec![
        ("mobilenet_v2_cifar100", "Table 1 (CIFAR100)"),
        ("mobilenet_v2_imagenet", "Table 1 (ImageNet)"),
        ("resnet50_cifar100", "Table 1 (CIFAR100)"),
        ("resnet50_imagenet", "Table 1 (ImageNet)"),
        ("yolov5s", "Table 1 (COCO)"),
        ("yolov5m", "Table 1 (COCO)"),
        ("transformer_base", "Table 2 (WMT32k)"),
        ("transformer_big", "Table 2 (WMT32k)"),
        ("bert_345m", "Table 3 (pre-training)"),
        ("gpt2_345m", "Table 3 (pre-training)"),
        ("t5_base", "Table 3 (pre-training)"),
        ("gpt2_124m", "Table 4 (GLUE fine-tuning)"),
        ("t5_small", "Table 4 (GLUE fine-tuning)"),
        ("llama7b_lora_r8", "Tables 4/7 (LoRA fine-tuning)"),
        ("bert_base", "Table 6 (GLUE fine-tuning)"),
        ("roberta_base", "Table 8 (SQuAD)"),
        ("albert_base_v2", "Table 8 (SQuAD)"),
        ("bart_base", "Table 12 (summarization)"),
        ("mbart_large", "Table 13 (summarization)"),
        ("marian_mt", "Table 10 (WMT16 En-Ro)"),
        ("tiny_lm", "suite smoke (synthetic workload)"),
        ("tiny_lm_x8", "chunked-streaming tests (8x vocab)"),
        ("tiny_lm_x64", "chunked-streaming tests (64x vocab, > 1 frame)"),
    ]
}

pub fn inventory_by_name(name: &str) -> Option<Inventory> {
    Some(match name {
        "mobilenet_v2_cifar100" => mobilenet::mobilenet_v2(100),
        "mobilenet_v2_imagenet" => mobilenet::mobilenet_v2(1000),
        "resnet50_cifar100" => resnet::resnet50(100),
        "resnet50_imagenet" => resnet::resnet50(1000),
        "yolov5s" => yolo::yolov5s(80),
        "yolov5m" => yolo::yolov5m(80),
        "transformer_base" => transformer::transformer_base(),
        "transformer_big" => transformer::transformer_big(),
        "bert_base" => bert::bert_base(),
        "bert_345m" => bert::bert_345m(),
        "roberta_base" => bert::roberta_base(),
        "albert_base_v2" => bert::albert_base_v2(),
        "gpt2_124m" => gpt2::gpt2_124m(),
        "gpt2_345m" => gpt2::gpt2_345m(),
        "t5_small" => t5::t5_small(),
        "t5_base" => t5::t5_base(),
        "llama7b_lora_r8" => llama::llama7b_lora(8),
        "bart_base" => bart::bart_base(),
        "mbart_large" => bart::mbart_large(),
        "marian_mt" => bart::marian_mt(),
        "tiny_lm" => transformer::tiny_lm(),
        "tiny_lm_x8" => transformer::tiny_lm_scaled(8),
        "tiny_lm_x64" => transformer::tiny_lm_scaled(64),
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_listed_name_resolves() {
        for (name, _) in list_inventories() {
            let inv = inventory_by_name(name).unwrap_or_else(|| panic!("missing {name}"));
            assert!(inv.param_count() > 0, "{name}");
            assert!(!inv.tensors.is_empty(), "{name}");
        }
    }

    #[test]
    fn unknown_is_none() {
        assert!(inventory_by_name("gpt5").is_none());
    }
}
