//! YOLOv5 s/m (Ultralytics, v6.0 architecture) parameter inventory:
//! CSPDarknet backbone + SPPF + PANet head + Detect, with the
//! depth/width-multiple scaling that differentiates the s and m variants.

use super::{make_divisible, Inventory};

struct Builder {
    inv: Inventory,
    width: f64,
    depth: f64,
    idx: usize,
}

impl Builder {
    fn ch(&self, c: usize) -> usize {
        make_divisible(c as f64 * self.width, 8)
    }

    fn depth(&self, n: usize) -> usize {
        ((n as f64 * self.depth).round() as usize).max(1)
    }

    /// Conv = conv2d(k) + BN (+ SiLU).
    fn conv(&mut self, cin: usize, cout: usize, k: usize) -> usize {
        let name = format!("m{}.conv", self.idx);
        self.idx += 1;
        self.inv.conv(&name, cout, cin, k);
        self.inv.norm(&format!("{name}.bn"), cout);
        cout
    }

    /// C3 module: cv1/cv2 1×1 into c/2, n bottlenecks, cv3 1×1 out.
    fn c3(&mut self, cin: usize, cout: usize, n: usize) -> usize {
        let c_ = cout / 2;
        self.conv(cin, c_, 1); // cv1
        self.conv(cin, c_, 1); // cv2
        for _ in 0..self.depth(n) {
            self.conv(c_, c_, 1); // bottleneck cv1
            self.conv(c_, c_, 3); // bottleneck cv2
        }
        self.conv(2 * c_, cout, 1) // cv3
    }

    /// SPPF: cv1 1×1 c→c/2, pyramid pooling (no params), cv2 1×1 2c→c.
    fn sppf(&mut self, cin: usize, cout: usize) -> usize {
        let c_ = cin / 2;
        self.conv(cin, c_, 1);
        self.conv(c_ * 4, cout, 1)
    }
}

/// Build YOLOv5 with the given multiples. nc = classes (80 for COCO),
/// 3 anchors per scale, 3 detection scales (P3/P4/P5).
pub fn yolov5(name: &str, depth: f64, width: f64, nc: usize) -> Inventory {
    let mut b = Builder { inv: Inventory::new(name), width, depth, idx: 0 };
    // backbone
    let c64 = b.ch(64);
    let c128 = b.ch(128);
    let c256 = b.ch(256);
    let c512 = b.ch(512);
    let c1024 = b.ch(1024);
    b.conv(3, c64, 6); // P1/2 stem (v6.0: 6x6 stride-2)
    b.conv(c64, c128, 3); // P2/4
    b.c3(c128, c128, 3);
    b.conv(c128, c256, 3); // P3/8
    b.c3(c256, c256, 6);
    b.conv(c256, c512, 3); // P4/16
    b.c3(c512, c512, 9);
    b.conv(c512, c1024, 3); // P5/32
    b.c3(c1024, c1024, 3);
    b.sppf(c1024, c1024);
    // head (PANet)
    b.conv(c1024, c512, 1);
    b.c3(c512 + c512, c512, 3); // cat with backbone P4
    b.conv(c512, c256, 1);
    b.c3(c256 + c256, c256, 3); // cat with backbone P3 -> P3 out
    b.conv(c256, c256, 3); // downsample
    b.c3(c256 + c256, c512, 3); // -> P4 out
    b.conv(c512, c512, 3); // downsample
    b.c3(c512 + c512, c1024, 3); // -> P5 out
    // Detect: 1×1 conv per scale to 3*(5+nc), with bias.
    let no = 3 * (5 + nc);
    for (i, c) in [c256, c512, c1024].iter().enumerate() {
        b.inv.conv(&format!("detect.m.{i}"), no, *c, 1);
        b.inv.push(format!("detect.m.{i}.bias"), &[no]);
    }
    b.inv
}

pub fn yolov5s(nc: usize) -> Inventory {
    yolov5("yolov5s", 0.33, 0.50, nc)
}

pub fn yolov5m(nc: usize) -> Inventory {
    yolov5("yolov5m", 0.67, 0.75, nc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn yolov5s_coco_param_count() {
        // Ultralytics reports 7.2M params for YOLOv5s (80 classes).
        let n = yolov5s(80).param_count();
        assert!((7_000_000..7_500_000).contains(&n), "{n}");
    }

    #[test]
    fn yolov5m_coco_param_count() {
        // Ultralytics reports 21.2M params for YOLOv5m.
        let n = yolov5m(80).param_count();
        assert!((20_800_000..21_600_000).contains(&n), "{n}");
    }

    #[test]
    fn m_deeper_and_wider_than_s() {
        assert!(yolov5m(80).tensors.len() > yolov5s(80).tensors.len());
    }
}
