//! MobileNetV2 (Sandler et al. 2018) parameter inventory, torchvision
//! layout: inverted residual blocks with 1×1 expand → 3×3 depthwise →
//! 1×1 project, each followed by BatchNorm. Dominated by 1×1 convolutions,
//! which is exactly the shape where Adafactor/CAME's last-two-dims
//! factorization degenerates (paper Table 1).

use super::{make_divisible, Inventory};

/// (expansion t, output channels c, repeats n, stride s) per the paper.
const CFG: [(usize, usize, usize, usize); 7] = [
    (1, 16, 1, 1),
    (6, 24, 2, 2),
    (6, 32, 3, 2),
    (6, 64, 4, 2),
    (6, 96, 3, 1),
    (6, 160, 3, 2),
    (6, 320, 1, 1),
];

pub fn mobilenet_v2(classes: usize) -> Inventory {
    mobilenet_v2_width(classes, 1.0)
}

pub fn mobilenet_v2_width(classes: usize, width: f64) -> Inventory {
    let mut inv = Inventory::new(&format!("mobilenet_v2_c{classes}"));
    let mut cin = make_divisible(32.0 * width, 8);
    inv.conv("features.0.conv", cin, 3, 3);
    inv.norm("features.0.bn", cin);
    let mut idx = 1;
    for (t, c, n, _s) in CFG {
        let cout = make_divisible(c as f64 * width, 8);
        for _ in 0..n {
            let p = format!("features.{idx}");
            let hidden = cin * t;
            if t != 1 {
                inv.conv(&format!("{p}.expand"), hidden, cin, 1);
                inv.norm(&format!("{p}.expand_bn"), hidden);
            }
            inv.dwconv(&format!("{p}.dw"), hidden, 3);
            inv.norm(&format!("{p}.dw_bn"), hidden);
            inv.conv(&format!("{p}.project"), cout, hidden, 1);
            inv.norm(&format!("{p}.project_bn"), cout);
            cin = cout;
            idx += 1;
        }
    }
    let last = make_divisible(1280.0 * width.max(1.0), 8);
    inv.conv("features.head", last, cin, 1);
    inv.norm("features.head_bn", last);
    inv.linear("classifier", last, classes);
    inv
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn imagenet_param_count() {
        // torchvision mobilenet_v2: 3,504,872 parameters.
        assert_eq!(mobilenet_v2(1000).param_count(), 3_504_872);
    }

    #[test]
    fn cifar_head() {
        let d = mobilenet_v2(1000).param_count() - mobilenet_v2(100).param_count();
        assert_eq!(d, (1280 * 900 + 900) as u64);
    }

    #[test]
    fn pointwise_dominated() {
        // >60% of parameters live in 1x1 convolutions.
        let inv = mobilenet_v2(1000);
        let pw: u64 = inv
            .tensors
            .iter()
            .filter(|t| t.shape.len() == 4 && t.shape[2] == 1 && t.shape[1] > 1)
            .map(|t| t.numel())
            .sum();
        assert!(pw as f64 > 0.6 * inv.param_count() as f64);
    }
}
