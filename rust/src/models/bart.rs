//! BART-family inventories: BART-base (summarization, Table 12),
//! mBART-large (multilingual summarization, Table 13) and MarianMT
//! (WMT16 En-Ro, Table 10 — a BART variant without embedding LayerNorm).

use super::Inventory;

pub struct BartCfg {
    pub layers: usize, // per stack
    pub d_model: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub max_pos: usize,
    /// LayerNorm after the embedding (BART yes, Marian no).
    pub emb_layernorm: bool,
    /// Extra final LayerNorm per stack (mBART).
    pub final_layernorm: bool,
}

pub fn bart(name: &str, cfg: &BartCfg) -> Inventory {
    let mut inv = Inventory::new(name);
    let d = cfg.d_model;
    inv.embedding("shared", cfg.vocab, d); // tied enc/dec/lm_head
    for stack in ["encoder", "decoder"] {
        let is_dec = stack == "decoder";
        inv.embedding(&format!("{stack}.embed_positions"), cfg.max_pos, d);
        if cfg.emb_layernorm {
            inv.norm(&format!("{stack}.layernorm_embedding"), d);
        }
        for l in 0..cfg.layers {
            let p = format!("{stack}.layers.{l}");
            for proj in ["q_proj", "k_proj", "v_proj", "out_proj"] {
                inv.linear(&format!("{p}.self_attn.{proj}"), d, d);
            }
            inv.norm(&format!("{p}.self_attn_layer_norm"), d);
            if is_dec {
                for proj in ["q_proj", "k_proj", "v_proj", "out_proj"] {
                    inv.linear(&format!("{p}.encoder_attn.{proj}"), d, d);
                }
                inv.norm(&format!("{p}.encoder_attn_layer_norm"), d);
            }
            inv.linear(&format!("{p}.fc1"), d, cfg.d_ff);
            inv.linear(&format!("{p}.fc2"), cfg.d_ff, d);
            inv.norm(&format!("{p}.final_layer_norm"), d);
        }
        if cfg.final_layernorm {
            inv.norm(&format!("{stack}.layer_norm"), d);
        }
    }
    inv
}

pub fn bart_base() -> Inventory {
    bart(
        "bart_base",
        &BartCfg {
            layers: 6,
            d_model: 768,
            d_ff: 3072,
            vocab: 50265,
            max_pos: 1026,
            emb_layernorm: true,
            final_layernorm: false,
        },
    )
}

pub fn mbart_large() -> Inventory {
    bart(
        "mbart_large",
        &BartCfg {
            layers: 12,
            d_model: 1024,
            d_ff: 4096,
            vocab: 250054,
            max_pos: 1026,
            emb_layernorm: true,
            final_layernorm: true,
        },
    )
}

/// MarianMT en-ro: BART-small-like, no embedding LayerNorm, static
/// sinusoidal positions (no learned position parameters).
pub fn marian_mt() -> Inventory {
    let mut inv = bart(
        "marian_mt",
        &BartCfg {
            layers: 6,
            d_model: 512,
            d_ff: 2048,
            vocab: 59543,
            max_pos: 0, // sinusoidal -> drop below
            emb_layernorm: false,
            final_layernorm: false,
        },
    );
    // remove zero-size position tables injected by the generic builder
    inv.tensors.retain(|t| t.numel() > 0);
    inv
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bart_base_is_140m() {
        // Paper Table 12: Adam = 1068 MiB -> N ≈ 140M.
        let n = bart_base().param_count();
        assert!((137_000_000..143_000_000).contains(&n), "{n}");
    }

    #[test]
    fn mbart_large_is_610m() {
        // Paper Table 13: Adam = 4661 MiB -> N ≈ 611M.
        let n = mbart_large().param_count();
        assert!((600_000_000..625_000_000).contains(&n), "{n}");
    }

    #[test]
    fn marian_is_74m() {
        // Paper Table 10: Adam = 569 MiB -> N ≈ 74.6M.
        let n = marian_mt().param_count();
        assert!((72_000_000..77_000_000).contains(&n), "{n}");
    }
}
