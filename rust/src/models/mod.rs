//! Exact parameter-shape inventories for every model the paper evaluates.
//!
//! Optimizer memory is a pure function of the trainable-parameter shapes,
//! so the paper's memory tables are regenerated from these inventories
//! without instantiating multi-GiB models. Each builder enumerates every
//! weight/bias/norm tensor in declaration order with HF/torchvision
//! naming conventions; `tests` pin total parameter counts against the
//! published sizes.

pub mod bart;
pub mod bert;
pub mod gpt2;
pub mod llama;
pub mod mobilenet;
pub mod registry;
pub mod resnet;
pub mod t5;
pub mod transformer;
pub mod yolo;

pub use registry::{inventory_by_name, list_inventories};

/// One named parameter tensor.
#[derive(Clone, Debug)]
pub struct ParamTensor {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamTensor {
    pub fn numel(&self) -> u64 {
        self.shape.iter().product::<usize>() as u64
    }
}

/// A model as a flat list of trainable tensors (plus optional frozen
/// bytes, for LoRA fine-tuning where the base model is kept in memory but
/// carries no optimizer state or gradients).
#[derive(Clone, Debug, Default)]
pub struct Inventory {
    pub name: String,
    pub tensors: Vec<ParamTensor>,
    /// Frozen (non-trainable) parameter bytes resident during training.
    pub frozen_bytes: u64,
}

impl Inventory {
    pub fn new(name: &str) -> Inventory {
        Inventory { name: name.to_string(), tensors: Vec::new(), frozen_bytes: 0 }
    }

    pub fn push(&mut self, name: impl Into<String>, shape: &[usize]) {
        self.tensors.push(ParamTensor { name: name.into(), shape: shape.to_vec() });
    }

    /// conv weight (Cout, Cin, k, k)
    pub fn conv(&mut self, name: &str, cout: usize, cin: usize, k: usize) {
        self.push(format!("{name}.weight"), &[cout, cin, k, k]);
    }

    /// depthwise conv weight (C, 1, k, k)
    pub fn dwconv(&mut self, name: &str, c: usize, k: usize) {
        self.push(format!("{name}.weight"), &[c, 1, k, k]);
    }

    /// batch-norm / layer-norm scale + shift
    pub fn norm(&mut self, name: &str, c: usize) {
        self.push(format!("{name}.weight"), &[c]);
        self.push(format!("{name}.bias"), &[c]);
    }

    /// norm with scale only (T5 RMSNorm, LLaMA RMSNorm)
    pub fn rmsnorm(&mut self, name: &str, c: usize) {
        self.push(format!("{name}.weight"), &[c]);
    }

    /// linear layer with bias
    pub fn linear(&mut self, name: &str, inf: usize, outf: usize) {
        self.push(format!("{name}.weight"), &[outf, inf]);
        self.push(format!("{name}.bias"), &[outf]);
    }

    /// linear layer without bias
    pub fn linear_nb(&mut self, name: &str, inf: usize, outf: usize) {
        self.push(format!("{name}.weight"), &[outf, inf]);
    }

    /// embedding table
    pub fn embedding(&mut self, name: &str, vocab: usize, dim: usize) {
        self.push(format!("{name}.weight"), &[vocab, dim]);
    }

    pub fn param_count(&self) -> u64 {
        self.tensors.iter().map(|t| t.numel()).sum()
    }

    pub fn shapes(&self) -> Vec<Vec<usize>> {
        self.tensors.iter().map(|t| t.shape.clone()).collect()
    }

    pub fn param_bytes(&self) -> u64 {
        self.param_count() * 4
    }
}

/// Round channels to the nearest multiple of `div` (torchvision /
/// YOLO width-multiple convention, never dropping below 90%).
pub fn make_divisible(v: f64, div: usize) -> usize {
    let d = div as f64;
    let new_v = ((v + d / 2.0) / d).floor() * d;
    let new_v = new_v.max(d);
    if new_v < 0.9 * v {
        (new_v + d) as usize
    } else {
        new_v as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inventory_helpers() {
        let mut inv = Inventory::new("toy");
        inv.conv("c1", 8, 3, 3);
        inv.norm("bn1", 8);
        inv.linear("fc", 8, 2);
        assert_eq!(inv.param_count(), (8 * 3 * 9 + 16 + 8 * 2 + 2) as u64);
        assert_eq!(inv.tensors.len(), 5);
        assert_eq!(inv.tensors[0].shape, vec![8, 3, 3, 3]);
    }

    #[test]
    fn divisible() {
        assert_eq!(make_divisible(32.0 * 0.5, 8), 16);
        assert_eq!(make_divisible(64.0 * 0.75, 8), 48);
        assert_eq!(make_divisible(3.0, 8), 8);
    }
}
