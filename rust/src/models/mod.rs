//! Exact parameter-shape inventories for every model the paper evaluates.
//!
//! Optimizer memory is a pure function of the trainable-parameter shapes,
//! so the paper's memory tables are regenerated from these inventories
//! without instantiating multi-GiB models. Each builder enumerates every
//! weight/bias/norm tensor in declaration order with HF/torchvision
//! naming conventions; `tests` pin total parameter counts against the
//! published sizes.

pub mod bart;
pub mod bert;
pub mod gpt2;
pub mod llama;
pub mod mobilenet;
pub mod registry;
pub mod resnet;
pub mod t5;
pub mod transformer;
pub mod yolo;

pub use registry::{inventory_by_name, list_inventories};

use crate::optim::group::{ParamRole, ParamSpec};

/// One named parameter tensor with its model role (see
/// [`crate::optim::group::ParamRole`]) — the helpers below set roles
/// explicitly; raw [`Inventory::push`] infers them from the name/shape.
#[derive(Clone, Debug)]
pub struct ParamTensor {
    pub name: String,
    pub shape: Vec<usize>,
    pub role: ParamRole,
}

impl ParamTensor {
    pub fn numel(&self) -> u64 {
        self.shape.iter().product::<usize>() as u64
    }
}

/// A model as a flat list of trainable tensors (plus optional frozen
/// bytes, for LoRA fine-tuning where the base model is kept in memory but
/// carries no optimizer state or gradients).
#[derive(Clone, Debug, Default)]
pub struct Inventory {
    pub name: String,
    pub tensors: Vec<ParamTensor>,
    /// Frozen (non-trainable) parameter bytes resident during training.
    pub frozen_bytes: u64,
}

impl Inventory {
    pub fn new(name: &str) -> Inventory {
        Inventory { name: name.to_string(), tensors: Vec::new(), frozen_bytes: 0 }
    }

    /// Push with the role inferred from the name/shape (HF conventions).
    pub fn push(&mut self, name: impl Into<String>, shape: &[usize]) {
        let name = name.into();
        let role = ParamRole::infer(&name, shape);
        self.tensors.push(ParamTensor { name, shape: shape.to_vec(), role });
    }

    /// Push with an explicit role (used by all the helpers below).
    pub fn push_as(&mut self, name: impl Into<String>, shape: &[usize], role: ParamRole) {
        self.tensors.push(ParamTensor { name: name.into(), shape: shape.to_vec(), role });
    }

    /// conv weight (Cout, Cin, k, k)
    pub fn conv(&mut self, name: &str, cout: usize, cin: usize, k: usize) {
        self.push_as(format!("{name}.weight"), &[cout, cin, k, k], ParamRole::Kernel);
    }

    /// depthwise conv weight (C, 1, k, k)
    pub fn dwconv(&mut self, name: &str, c: usize, k: usize) {
        self.push_as(format!("{name}.weight"), &[c, 1, k, k], ParamRole::Kernel);
    }

    /// batch-norm / layer-norm scale + shift
    pub fn norm(&mut self, name: &str, c: usize) {
        self.push_as(format!("{name}.weight"), &[c], ParamRole::Norm);
        self.push_as(format!("{name}.bias"), &[c], ParamRole::Norm);
    }

    /// norm with scale only (T5 RMSNorm, LLaMA RMSNorm)
    pub fn rmsnorm(&mut self, name: &str, c: usize) {
        self.push_as(format!("{name}.weight"), &[c], ParamRole::Norm);
    }

    /// linear layer with bias
    pub fn linear(&mut self, name: &str, inf: usize, outf: usize) {
        self.push_as(format!("{name}.weight"), &[outf, inf], ParamRole::Kernel);
        self.push_as(format!("{name}.bias"), &[outf], ParamRole::Bias);
    }

    /// linear layer without bias
    pub fn linear_nb(&mut self, name: &str, inf: usize, outf: usize) {
        self.push_as(format!("{name}.weight"), &[outf, inf], ParamRole::Kernel);
    }

    /// embedding table
    pub fn embedding(&mut self, name: &str, vocab: usize, dim: usize) {
        self.push_as(format!("{name}.weight"), &[vocab, dim], ParamRole::Embedding);
    }

    pub fn param_count(&self) -> u64 {
        self.tensors.iter().map(|t| t.numel()).sum()
    }

    pub fn shapes(&self) -> Vec<Vec<usize>> {
        self.tensors.iter().map(|t| t.shape.clone()).collect()
    }

    /// The inventory as grouped-API registration specs (name + shape +
    /// role), consumed by [`crate::optim::build_grouped`] and the
    /// per-group memory reports.
    pub fn param_specs(&self) -> Vec<ParamSpec> {
        self.tensors
            .iter()
            .map(|t| ParamSpec::new(t.name.clone(), &t.shape, t.role))
            .collect()
    }

    /// `(role, tensor count, param count)` per role that occurs in the
    /// inventory, in [`ParamRole::all`] order — used by `repro list` so
    /// group matchers can be sanity-checked against real inventories.
    pub fn role_breakdown(&self) -> Vec<(ParamRole, usize, u64)> {
        ParamRole::all()
            .into_iter()
            .map(|role| {
                let (mut count, mut params) = (0usize, 0u64);
                for t in self.tensors.iter().filter(|t| t.role == role) {
                    count += 1;
                    params += t.numel();
                }
                (role, count, params)
            })
            .filter(|&(_, count, _)| count > 0)
            .collect()
    }

    pub fn param_bytes(&self) -> u64 {
        self.param_count() * 4
    }
}

/// Round channels to the nearest multiple of `div` (torchvision /
/// YOLO width-multiple convention, never dropping below 90%).
pub fn make_divisible(v: f64, div: usize) -> usize {
    let d = div as f64;
    let new_v = ((v + d / 2.0) / d).floor() * d;
    let new_v = new_v.max(d);
    if new_v < 0.9 * v {
        (new_v + d) as usize
    } else {
        new_v as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inventory_helpers() {
        let mut inv = Inventory::new("toy");
        inv.conv("c1", 8, 3, 3);
        inv.norm("bn1", 8);
        inv.linear("fc", 8, 2);
        assert_eq!(inv.param_count(), (8 * 3 * 9 + 16 + 8 * 2 + 2) as u64);
        assert_eq!(inv.tensors.len(), 5);
        assert_eq!(inv.tensors[0].shape, vec![8, 3, 3, 3]);
    }

    #[test]
    fn helpers_tag_roles_and_breakdown_counts() {
        let mut inv = Inventory::new("toy");
        inv.conv("c1", 8, 3, 3);
        inv.norm("bn1", 8);
        inv.linear("fc", 8, 2);
        inv.embedding("emb", 10, 4);
        inv.push("head.bias", &[2]); // raw push: role inferred
        let roles: Vec<ParamRole> = inv.tensors.iter().map(|t| t.role).collect();
        assert_eq!(
            roles,
            vec![
                ParamRole::Kernel,
                ParamRole::Norm,
                ParamRole::Norm,
                ParamRole::Kernel,
                ParamRole::Bias,
                ParamRole::Embedding,
                ParamRole::Bias,
            ]
        );
        let bd = inv.role_breakdown();
        let get = |r: ParamRole| bd.iter().find(|&&(role, ..)| role == r).copied().unwrap();
        assert_eq!(get(ParamRole::Kernel), (ParamRole::Kernel, 2, (8 * 3 * 9 + 16) as u64));
        assert_eq!(get(ParamRole::Norm), (ParamRole::Norm, 2, 16));
        assert_eq!(get(ParamRole::Bias), (ParamRole::Bias, 2, 4));
        assert_eq!(get(ParamRole::Embedding), (ParamRole::Embedding, 1, 40));
        assert!(bd.iter().all(|&(r, ..)| r != ParamRole::Other));
        let specs = inv.param_specs();
        assert_eq!(specs.len(), inv.tensors.len());
        assert_eq!(specs[0].role, ParamRole::Kernel);
        assert_eq!(specs[0].name, "c1.weight");
        // breakdown totals cover the whole inventory
        let total: u64 = bd.iter().map(|&(_, _, p)| p).sum();
        assert_eq!(total, inv.param_count());
    }

    #[test]
    fn divisible() {
        assert_eq!(make_divisible(32.0 * 0.5, 8), 16);
        assert_eq!(make_divisible(64.0 * 0.75, 8), 48);
        assert_eq!(make_divisible(3.0, 8), 8);
    }
}
