//! Transformer-base / big (Vaswani et al. 2017) for WMT32k — the paper's
//! full-training workload (Table 2). tensor2tensor conventions: separate
//! source/target embeddings, softmax weights tied to the target embedding,
//! learned biases in attention/FFN, LayerNorm everywhere.

use super::Inventory;

pub struct TransformerCfg {
    pub d_model: usize,
    pub d_ff: usize,
    pub layers: usize,
    pub vocab: usize,
}

pub const BASE: TransformerCfg =
    TransformerCfg { d_model: 512, d_ff: 2048, layers: 6, vocab: 32768 };
pub const BIG: TransformerCfg =
    TransformerCfg { d_model: 1024, d_ff: 4096, layers: 6, vocab: 32768 };

fn attention(inv: &mut Inventory, p: &str, d: usize) {
    for proj in ["q", "k", "v", "o"] {
        inv.linear(&format!("{p}.attn.{proj}"), d, d);
    }
}

fn ffn(inv: &mut Inventory, p: &str, d: usize, ff: usize) {
    inv.linear(&format!("{p}.ffn.w1"), d, ff);
    inv.linear(&format!("{p}.ffn.w2"), ff, d);
}

pub fn transformer_mt(name: &str, cfg: &TransformerCfg) -> Inventory {
    let mut inv = Inventory::new(name);
    let d = cfg.d_model;
    // Separate source/target embeddings and softmax projection (the
    // unshared tensor2tensor configuration the paper's 0.7 GiB Adam
    // footprint implies).
    inv.embedding("src_emb", cfg.vocab, d);
    inv.embedding("tgt_emb", cfg.vocab, d);
    inv.linear_nb("softmax", d, cfg.vocab);
    for l in 0..cfg.layers {
        let p = format!("encoder.{l}");
        inv.norm(&format!("{p}.ln1"), d);
        attention(&mut inv, &p, d);
        inv.norm(&format!("{p}.ln2"), d);
        ffn(&mut inv, &p, d, cfg.d_ff);
    }
    inv.norm("encoder.ln_final", d);
    for l in 0..cfg.layers {
        let p = format!("decoder.{l}");
        inv.norm(&format!("{p}.ln1"), d);
        attention(&mut inv, &p, d); // self-attention
        inv.norm(&format!("{p}.ln2"), d);
        for proj in ["q", "k", "v", "o"] {
            inv.linear(&format!("{p}.cross.{proj}"), d, d);
        }
        inv.norm(&format!("{p}.ln3"), d);
        ffn(&mut inv, &p, d, cfg.d_ff);
    }
    inv.norm("decoder.ln_final", d);
    inv
}

pub fn transformer_base() -> Inventory {
    transformer_mt("transformer_base", &BASE)
}

pub fn transformer_big() -> Inventory {
    transformer_mt("transformer_big", &BIG)
}

/// A deliberately tiny (~15K param) char-LM-shaped inventory covering
/// every [`super::ParamTensor`] role (embedding, kernel, bias, norm) —
/// the workload behind the artifact-free `synthetic:` suite cells
/// (`rust/tests/suite_smoke.toml`) and a fast target for group-matcher
/// examples. Small enough that a full optimizer sweep over several
/// seeds runs in milliseconds on one core.
pub fn tiny_lm() -> Inventory {
    tiny_lm_scaled(1)
}

/// [`tiny_lm`] with the vocabulary widened `scale`× (96·scale entries)
/// and everything else unchanged. The embedding and head grow linearly
/// with `scale` while the transformer block stays fixed, so scaled
/// variants stress *inventory size* (wire payloads, snapshot streaming)
/// without changing the workload's character. `x64` (~400K params,
/// ~1.6 MB of f32 — past the 1 MiB connection-frame cap) is the
/// paper-scale stand-in the chunked-streaming tests pin against.
pub fn tiny_lm_scaled(scale: usize) -> Inventory {
    assert!(scale >= 1);
    let name = match scale {
        1 => "tiny_lm".to_string(),
        s => format!("tiny_lm_x{s}"),
    };
    let mut inv = Inventory::new(&name);
    let (vocab, d, ff) = (96 * scale, 32, 64);
    inv.embedding("tok_emb", vocab, d);
    inv.norm("block.0.ln1", d);
    inv.linear("block.0.attn.qkv", d, 3 * d);
    inv.linear("block.0.attn.o", d, d);
    inv.norm("block.0.ln2", d);
    inv.linear("block.0.ffn.w1", d, ff);
    inv.linear("block.0.ffn.w2", ff, d);
    inv.norm("ln_final", d);
    inv.linear_nb("head", d, vocab);
    inv
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn base_matches_paper_memory_scale() {
        // Paper Table 2: Adam on Transformer-base = 0.7 GiB = 2N floats
        // -> N ≈ 94M. Our inventory must land in that band.
        let n = transformer_base().param_count();
        assert!((85_000_000..100_000_000).contains(&n), "{n}");
    }

    #[test]
    fn big_matches_paper_memory_scale() {
        // Adam on big = 2.1 GiB -> N ≈ 282M.
        let n = transformer_big().param_count();
        assert!((260_000_000..300_000_000).contains(&n), "{n}");
    }

    #[test]
    fn all_matrices_are_2d() {
        let inv = transformer_base();
        assert!(inv.tensors.iter().all(|t| t.shape.len() <= 2));
    }

    #[test]
    fn scaled_tiny_lm_grows_vocab_only() {
        assert_eq!(tiny_lm_scaled(1).param_count(), tiny_lm().param_count());
        let base = tiny_lm();
        for scale in [8usize, 64] {
            let inv = tiny_lm_scaled(scale);
            assert_eq!(inv.name, format!("tiny_lm_x{scale}"));
            assert_eq!(inv.tensors.len(), base.tensors.len());
            // Only tok_emb and head widen; everything else is unchanged.
            for (t, b) in inv.tensors.iter().zip(&base.tensors) {
                assert_eq!(t.name, b.name);
                if t.name == "tok_emb.weight" || t.name == "head.weight" {
                    assert_eq!(t.shape.iter().product::<usize>(), scale * b.shape.iter().product::<usize>(), "{}", t.name);
                } else {
                    assert_eq!(t.shape, b.shape, "{}", t.name);
                }
            }
        }
        // The x64 inventory is the paper-scale stand-in: its dense f32
        // image must not fit in one v4 connection frame.
        let bytes: usize = tiny_lm_scaled(64).tensors.iter().map(|t| 4 * t.shape.iter().product::<usize>()).sum();
        assert!(bytes as u64 > crate::server::protocol::MAX_PAYLOAD, "{bytes}");
    }

    #[test]
    fn tiny_lm_is_tiny_and_covers_all_roles() {
        use crate::optim::group::ParamRole;
        let inv = tiny_lm();
        assert_eq!(inv.param_count(), 14752);
        let roles: Vec<ParamRole> = inv.role_breakdown().into_iter().map(|(r, _, _)| r).collect();
        for want in [ParamRole::Kernel, ParamRole::Bias, ParamRole::Norm, ParamRole::Embedding] {
            assert!(roles.contains(&want), "missing {want:?}");
        }
    }
}
